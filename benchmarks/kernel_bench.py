"""SPM operator scaling benchmark (paper §5 complexity claim) + kernel
traffic model + fused-vs-unfused end-to-end ``linear_apply``, including the
RECTANGULAR hot shapes (fused q/k/v, d->4d FFN up/down, LM head) that the
rectangular-native kernel serves without XLA pad/slice.

Wall-clock on this CPU container: dense O(n^2) matmul vs SPM O(nL)
composition at growing width (the paper's crossover, Tables 1-2 compute
columns), plus the end-to-end ``linear_apply`` hot path with the fused
full-operator Pallas kernel ON vs OFF, forward and forward+backward.

Off-TPU the fused path runs in interpret mode, so its wall-clock is a
correctness/validation number, NOT a hardware claim (rows are tagged with
the backend).  The TPU claim is reported via the traffic model: the fused
full operator performs 1 HBM read + 1 write of the activation per boundary
run — diag and bias folded in — vs the L+4 round-trips of the per-stage
composition (L stages lowered separately cost L+1, and the d_in multiply,
d_out multiply, and bias add each add one more).

Emits ``BENCH_kernel.json`` (repo root by default) so later PRs have a
trajectory to compare against.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_step
from repro.analysis.recompile import assert_compiles
from repro.core import SPMConfig, init_spm, spm_apply
from repro.core.linear import LinearConfig, init_linear, linear_apply
from repro.core.eligibility import quant_acts_eligible
from repro.core.pairings import default_n_stages, two_level_schedule
from repro.kernels.ops import (pick_block_rows_for_plan, plan_runs,
                               plan_runs_for_rows)
from repro.kernels.spm_stack import vmem_bytes
from repro.launch.hlo_analysis import HW, sharded_stage_traffic
from repro.parallel.spm_shard import plan_steps

KEY = jax.random.PRNGKey(0)

SHARD_DEVICES = 8   # virtual host devices for the sharded timing subprocess


def bench_width(n: int, batch: int = 256):
    L = default_n_stages(n)
    cfg = SPMConfig(n=n, n_stages=L, variant="general", backward="custom",
                    use_kernel=False)
    p = init_spm(KEY, cfg)
    x = jax.random.normal(KEY, (batch, n))
    w = jax.random.normal(KEY, (n, n)) / n ** 0.5

    spm_f = jax.jit(lambda x: spm_apply(p, x, cfg))
    dense_f = jax.jit(lambda x: x @ w)
    # fwd+bwd (training step shape)
    spm_g = jax.jit(jax.grad(lambda x: jnp.sum(spm_apply(p, x, cfg) ** 2)))
    dense_g = jax.jit(jax.grad(lambda x: jnp.sum((x @ w) ** 2)))
    with assert_compiles(1, spm_f=spm_f, dense_f=dense_f,
                         spm_g=spm_g, dense_g=dense_g):
        t_spm = time_step(spm_f, x)
        t_dense = time_step(dense_f, x)
        tg_spm = time_step(spm_g, x)
        tg_dense = time_step(dense_g, x)
    return {"L": L, "fwd_spm_us": t_spm * 1e6, "fwd_dense_us": t_dense * 1e6,
            "bwd_spm_us": tg_spm * 1e6, "bwd_dense_us": tg_dense * 1e6}


def bench_linear_apply(n: int, batch: int = 64):
    """End-to-end linear_apply (full operator: diag + stages + bias),
    fused Pallas kernel vs unfused XLA composition, fwd and fwd+bwd.

    Off-TPU the fused variant runs the kernels in interpret mode —
    validation wall-clock only."""
    return bench_linear_rect(n, n, batch)


def bench_linear_rect(d_in: int, d_out: int, batch: int = 64):
    """linear_apply for an arbitrary (d_in, d_out), fused vs unfused.  The
    fused path is rectangular-NATIVE (in-kernel zero-fill / partial final
    store); the unfused path pays the XLA pad + slice around the square
    n-wide composition."""
    n = LinearConfig(d_in=d_in, d_out=d_out, impl="spm_general").n
    L = default_n_stages(n)
    mk = lambda uk: LinearConfig(d_in=d_in, d_out=d_out, impl="spm_general",
                                 n_stages=L, backward="custom",
                                 use_kernel=uk)
    cfg0, cfg1 = mk(False), mk(True)
    p = init_linear(KEY, cfg0)
    x = jax.random.normal(KEY, (batch, d_in))

    res = {"n": n, "L": L}
    for tag, cfg in (("unfused", cfg0), ("fused", cfg1)):
        f = jax.jit(lambda x, cfg=cfg: linear_apply(p, x, cfg))
        g = jax.jit(jax.grad(
            lambda p, x, cfg=cfg: jnp.sum(linear_apply(p, x, cfg) ** 2)))
        # the sentinel turns a silent mid-loop retrace (which would time
        # compiles, not steps) into a hard failure of the bench run
        with assert_compiles(1, fwd=f, bwd=g):
            res[f"linear_fwd_{tag}_us"] = time_step(f, x) * 1e6
            res[f"linear_fwdbwd_{tag}_us"] = time_step(g, p, x) * 1e6
    return res


# Rectangular hot shapes of the reproduced architectures (smoke-scaled
# proportions): every one of these was pad-to-n + slice before the
# rectangular-native kernel landed.
RECT_SHAPES = [
    ("qkv_fused", 256, 768),    # d -> 3d fused q/k/v projection
    ("ffn_up", 256, 1024),      # d -> 4d FFN up-projection
    ("ffn_down", 1024, 256),    # 4d -> d FFN down-projection
    ("lm_head", 384, 2048),     # d -> vocab head (d_in << d_out)
]


def rect_traffic(d_in: int, d_out: int, n: int, batch: int, L: int) -> dict:
    """HBM bytes for a rectangular FULL-operator call (f32 activations).

    unfused — XLA pad (read d_in, write n — only issued when d_in < n) +
    the L+4 square round-trips + output slice (read n, write d_out — only
    when d_out < n; n = even_ceil(max) makes one side exactly n).
    fused — reads batch*d_in once, writes batch*d_out once, plus one
    n-wide round-trip per INTERIOR run boundary of the kernel plan (and
    the O(nL) coefficient reads).
    quant — the fused plan with int8 activation I/O and an int8
    coefficient table: every activation byte above moves at width 1
    instead of 4, joined by the per-(row-block, feature-tile) f32 scale
    arrays riding each activation pass and the (L, 1) per-stage
    coefficient scales; diag/bias stay f32.  Only modeled when the int8
    run plan is uniform-tile (``core/eligibility.quant_acts_eligible`` —
    the same rule the kernel path engages under); otherwise the quant
    columns report the f32 bytes and reduction 1.0."""
    strides = tuple(
        SPMConfig(n=n, n_stages=L, variant="general").pairing.strides())
    n_runs = len(plan_runs(n, strides))
    act_n = batch * n * 4
    act_in = batch * d_in * 4
    act_out = batch * d_out * 4
    coeff_bytes = L * (n // 2) * 16 + 3 * n * 4
    unfused = (L + 4) * 2 * act_n
    if d_in < n:
        unfused += act_in + act_n     # pad pass
    if d_out < n:
        unfused += act_n + act_out    # slice pass
    fused = act_in + act_out + (n_runs - 1) * 2 * act_n + coeff_bytes
    runs_q = plan_runs_for_rows(n, strides, batch, 1)
    quant_ok = quant_acts_eligible(runs_q)
    if quant_ok:
        nq = len(runs_q)
        br = pick_block_rows_for_plan(runs_q, batch, 1)
        # one (row_blocks, feature_tiles) f32 scale array per activation
        # pass: the input read, each interior boundary (write + re-read),
        # and the output write
        scale_pass = -(-batch // br) * -(-n // runs_q[0][1]) * 4
        n_passes = 2 * nq
        coeff_q = L * (n // 2) * 4 + L * 4 + 3 * n * 4
        quant = (batch * d_in + batch * d_out
                 + (nq - 1) * 2 * batch * n
                 + n_passes * scale_pass + coeff_q)
    else:
        quant = fused
    return {"n_runs": n_runs, "coeff_bytes": coeff_bytes,
            "unfused_bytes": unfused, "fused_bytes": fused,
            "reduction": unfused / fused,
            "quant_eligible": quant_ok, "quant_bytes": quant,
            "quant_reduction": fused / quant}


# Residual-block hot shapes (d_model, d_ff = 4 * d_model): the
# norm -> up -> activation -> down -> residual chain the block megakernel
# lowers as ONE Pallas region.  Smoke halves them like RECT_SHAPES.
BLOCK_SHAPES = [
    ("ffn_d256", 256, 1024),
    ("ffn_d512", 512, 2048),
]


def block_traffic(d_model: int, d_ff: int, rows: int,
                  L: int | None = None) -> dict:
    """Modeled HBM bytes of one residual FFN block (norm -> SPM up ->
    activation -> SPM down -> residual add), f32 activations.

    perlinear — the per-linear fused plan (the pre-block baseline): the
    RMSNorm round-trips the (rows, d_model) activation, each SPM operator
    runs the rectangular-native fused kernel (``rect_traffic``'s fused
    accounting, coefficients included), the activation is one elementwise
    round-trip of the (rows, d_ff) hidden, and the residual add reads two
    (rows, d_model) operands and writes one.

    block — the megakernel: reads x once, writes y once, plus the (rows,)
    f32 row statistics, both stacks' O(nL) coefficient tables and the
    diag/bias/gamma vectors.  The normalized input, the mid activation,
    and the second stack's input never touch HBM — they live in VMEM for
    the whole chain (``kernels/ops.spm_block_fused``)."""
    n = LinearConfig(d_in=d_model, d_out=d_ff, impl="spm_general").n
    L = L if L is not None else default_n_stages(n)
    up = rect_traffic(d_model, d_ff, n, rows, L)
    down = rect_traffic(d_ff, d_model, n, rows, L)
    act_d = rows * d_model * 4
    act_ff = rows * d_ff * 4
    perlinear = (2 * act_d                   # norm round-trip
                 + up["fused_bytes"]
                 + 2 * act_ff                # activation round-trip
                 + down["fused_bytes"]
                 + 3 * act_d)                # residual: read y + x, write
    coeff = L * (n // 2) * 16 + 3 * n * 4    # one stack's tables + vecs
    block = 2 * act_d + rows * 4 + 2 * coeff + n * 4   # + rstd + gamma
    return {"n": n, "L": L,
            "perlinear_bytes": perlinear, "block_bytes": block,
            "reduction": perlinear / block}


def bench_block(d_model: int, d_ff: int, batch: int = 16):
    """End-to-end residual FFN block (norm -> up -> gelu -> down ->
    residual): the block megakernel vs the per-linear fused composition,
    fwd and fwd+bwd.  Off-TPU the fused path runs in interpret mode —
    validation wall-clock only (the HBM claim rides ``block_traffic``)."""
    from repro.layers.ffn import FFNConfig, ffn_block_apply, init_ffn
    from repro.layers.norms import init_rms_norm

    mk = lambda fuse: FFNConfig(
        d_model=d_model, d_ff=d_ff, linear_impl="spm_general",
        activation="gelu", spm_backward="custom", spm_use_kernel=True,
        spm_block_fuse=fuse)
    cfg0, cfg1 = mk(False), mk(True)
    p = init_ffn(KEY, cfg0)
    np_ = init_rms_norm(d_model)
    x = jax.random.normal(KEY, (batch, d_model))

    res = {}
    for tag, cfg in (("perlinear", cfg0), ("block", cfg1)):
        f = jax.jit(lambda x, cfg=cfg: ffn_block_apply(p, np_, x, cfg))
        g = jax.jit(jax.grad(
            lambda p, x, cfg=cfg: jnp.sum(
                ffn_block_apply(p, np_, x, cfg) ** 2)))
        with assert_compiles(1, fwd=f, bwd=g):
            res[f"block_fwd_{tag}_us"] = time_step(f, x) * 1e6
            res[f"block_fwdbwd_{tag}_us"] = time_step(g, p, x) * 1e6
    return res


def traffic_model(n: int, batch: int, L: int,
                  kernel_rows: int | None = None) -> dict:
    """HBM bytes per SQUARE full-operator call (f32 activations).

    Byte counts come from ``rect_traffic(n, n, ...)`` — the square
    operator is the d_in == d_out == n special case (no pad/slice passes,
    fused = n_runs round-trips + coefficients), so the two BENCH sections
    share one accounting.  Adds the round-trip counts, the pre-fold
    ``kernel_only`` baseline (stage stack fused, diag/bias still separate
    XLA passes), and the block_rows/VMEM configuration spm_stack_fused
    actually runs (per-run budgeting — ops.pick_block_rows_for_plan) at
    ``kernel_rows`` rows: the batch the fused linear rows of the SAME
    record are timed with, which caps the row block."""
    act = batch * n * 4
    strides = tuple(
        SPMConfig(n=n, n_stages=L, variant="general").pairing.strides())
    runs = plan_runs(n, strides)
    t = rect_traffic(n, n, n, batch, L)
    n_runs = t["n_runs"]
    kernel_only = (n_runs + 3) * 2 * act + t["coeff_bytes"]
    max_tile = max(tile for _, tile in runs)
    br = pick_block_rows_for_plan(runs, kernel_rows or batch, 4)
    return {"unfused_roundtrips": L + 4,
            "fused_roundtrips": n_runs,
            "n_runs": n_runs,
            "unfused_bytes": t["unfused_bytes"],
            "kernel_only_bytes": kernel_only,
            "fused_bytes": t["fused_bytes"],
            "reduction": t["reduction"],
            "reduction_vs_kernel_only": kernel_only / t["fused_bytes"],
            "quant_eligible": t["quant_eligible"],
            "quant_bytes": t["quant_bytes"],
            "quant_reduction": t["quant_reduction"],
            "max_tile": max_tile,
            "block_rows": br,
            "vmem_bytes": max(vmem_bytes(br, tile, len(rs))
                              for rs, tile in runs)}


def sharded_model(n: int, batch: int, L: int,
                  n_shards: int = SHARD_DEVICES,
                  in_width: int | None = None,
                  out_width: int | None = None) -> dict:
    """Modeled sharded-vs-replicated traffic for one two_level operator.

    replicated — one chip runs the full n-wide fused plan (PR 1/2 model).
    sharded    — each of n_shards chips runs the n_local-wide slab; cross
    stages each move the slab once over ICI (collective_permute partner
    exchange).  Bytes are per chip, f32 activations.

    The sharded traffic is modeled THREE ways for the full operator (diag
    + bias, plus any rectangular widths): ``modeled`` is the kernel-native
    step-serial executor (diag/bias folded into the boundary kernel runs,
    the rectangular input window-read in VMEM), ``modeled_overlap`` the
    overlap-scheduled executor (row-block pipelined cross-shard exchanges
    — same HBM, but the per-stage permute bytes split into exposed vs
    hidden), and ``modeled_pr3`` the PR 3 baseline (explicit elementwise
    diag/bias ops in the shard body and an XLA pad/slice around the
    square core).  ``boundary_reduction`` is the folded/pre-fold HBM
    ratio; ``exposed_reduction`` the serial/overlap exposed-comm ratio.
    """
    strides = tuple(two_level_schedule(n, L, n_shards).strides())
    steps = plan_steps(n, strides, n_shards)
    n_local = n // n_shards
    # mirror the executor's width normalization (spm_apply_sharded): a
    # full-width side is square — no boundary op exists to charge for
    if in_width == n:
        in_width = None
    if out_width == n:
        out_width = None
    kw = dict(use_diag=True, use_bias=True,
              in_width=in_width, out_width=out_width)
    sh = sharded_stage_traffic(n_local, batch, steps,
                               fold_boundaries=True, **kw)
    sh_ov = sharded_stage_traffic(n_local, batch, steps,
                                  fold_boundaries=True, overlap=True, **kw)
    sh_pr3 = sharded_stage_traffic(n_local, batch, steps,
                                   fold_boundaries=False, **kw)
    act = batch * n * 4
    n_runs = len(plan_runs(n, strides))
    coeff_bytes = L * (n // 2) * 16 + 3 * n * 4
    rep_bytes = 2 * n_runs * act + coeff_bytes
    rep_s = rep_bytes / HW["hbm_bw"]
    shard_s = sh["memory_s"] + sh["collective_s"]
    return {"n": n, "L": L, "n_shards": n_shards, "n_local": n_local,
            "in_width": in_width, "out_width": out_width,
            "n_cross_stages": sum(1 for s in steps if s[0] == "cross"),
            "n_local_runs": sum(1 for s in steps if s[0] == "local"),
            "modeled": sh,
            "modeled_overlap": sh_ov,
            "modeled_pr3": sh_pr3,
            "boundary_reduction": (sh_pr3["hbm_bytes_per_chip"]
                                   / sh["hbm_bytes_per_chip"]),
            "exposed_reduction": (
                sh["exposed_permute_bytes_per_chip"]
                / max(sh_ov["exposed_permute_bytes_per_chip"], 1)),
            "replicated_hbm_bytes": rep_bytes,
            "replicated_s": rep_s,
            "sharded_s": shard_s,
            "speedup_model": rep_s / shard_s if shard_s else None}


def time_sharded_subprocess(n: int, batch: int, L: int,
                            n_shards: int = SHARD_DEVICES,
                            timeout: int = 600) -> dict:
    """Wall-clock the distributed executor on virtual host devices.

    The forced device count must be set before jax initializes, and this
    process already owns a 1-device backend (conftest's rule), so the
    measurement re-execs THIS file with ``--sharded-worker`` in a child
    whose XLA_FLAGS request ``n_shards`` host devices.  Interpret-safe:
    the worker keeps the XLA composition (use_kernel=False) on CPU."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n_shards}")
    cmd = [sys.executable, os.path.abspath(__file__),
           "--sharded-worker", f"{n},{batch},{L},{n_shards}"]
    try:
        r = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=timeout, env=env)
        if r.returncode != 0:
            return {"error": (r.stderr or r.stdout)[-500:]}
        return json.loads(r.stdout.strip().splitlines()[-1])
    except Exception as e:   # noqa: BLE001 — bench rows degrade, never fail
        return {"error": f"{type(e).__name__}: {e}"}


def run_sharded_worker(spec: str) -> None:
    """Child entry (forced multi-device backend): time sharded vs
    replicated spm_apply on the same params and print one JSON line."""
    import numpy as np
    from jax.sharding import Mesh
    from repro.parallel.ctx import activation_sharding

    import dataclasses

    n, batch, L, n_shards = map(int, spec.split(","))
    cfg = SPMConfig(n=n, n_stages=L, schedule="two_level",
                    n_shards=n_shards, backward="custom", use_kernel=False,
                    overlap=False)
    cfg_ov = dataclasses.replace(cfg, overlap=True)
    p = init_spm(KEY, cfg)
    x = jax.random.normal(KEY, (batch, n))
    mesh = Mesh(np.asarray(jax.devices()[:n_shards]).reshape(n_shards,),
                ("model",))
    rep_f = jax.jit(lambda x: spm_apply(p, x, cfg))
    rep_g = jax.jit(jax.grad(lambda x: jnp.sum(spm_apply(p, x, cfg) ** 2)))
    out = {"n": n, "batch": batch, "L": L, "n_shards": n_shards,
           "devices": jax.device_count(),
           "replicated_fwd_us": time_step(rep_f, x) * 1e6,
           "replicated_fwdbwd_us": time_step(rep_g, x) * 1e6}
    with activation_sharding(mesh, shard_feature=True):
        sh_f = jax.jit(lambda x: spm_apply(p, x, cfg))
        sh_g = jax.jit(jax.grad(
            lambda x: jnp.sum(spm_apply(p, x, cfg) ** 2)))
        out["sharded_fwd_us"] = time_step(sh_f, x) * 1e6
        out["sharded_fwdbwd_us"] = time_step(sh_g, x) * 1e6
        # overlap schedule (per-block ppermute transport on host devices —
        # correctness wall-clock only; the ICI overlap claim rides the
        # exposed/hidden traffic model)
        ov_f = jax.jit(lambda x: spm_apply(p, x, cfg_ov))
        ov_g = jax.jit(jax.grad(
            lambda x: jnp.sum(spm_apply(p, x, cfg_ov) ** 2)))
        out["sharded_overlap_fwd_us"] = time_step(ov_f, x) * 1e6
        out["sharded_overlap_fwdbwd_us"] = time_step(ov_g, x) * 1e6
    print(json.dumps(out))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: one width, small batches")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--linear-batch", type=int, default=64,
                    help="batch for the end-to-end linear_apply rows "
                         "(kept small: interpret mode off-TPU)")
    ap.add_argument("--out", default="BENCH_kernel.json",
                    help="JSON trajectory output ('' to skip)")
    ap.add_argument("--skip-fused-timing", action="store_true",
                    help="traffic model only (no interpret-mode wall-clock)")
    ap.add_argument("--skip-sharded-timing", action="store_true",
                    help="modeled sharded rows only (no timing subprocess)")
    ap.add_argument("--sharded-worker", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    if args.sharded_worker:
        run_sharded_worker(args.sharded_worker)
        return
    widths = (512, 1024, 2048, 4096) if args.full else (256, 512, 1024)
    rect_shapes = RECT_SHAPES
    if args.smoke:
        widths = (256,)
        rect_shapes = [(t, i // 2, o // 2) for t, i, o in RECT_SHAPES]
        args.batch = min(args.batch, 64)
        args.linear_batch = min(args.linear_batch, 16)
    backend = jax.default_backend()

    print(f"# SPM vs dense scaling + fused-operator bench (backend={backend})")
    print("n,L,fwd_dense_us,fwd_spm_us,fwd_speedup,"
          "bwd_dense_us,bwd_spm_us,bwd_speedup,hbm_reduction,"
          "fused_roundtrips,unfused_roundtrips,vmem_bytes")
    records = []
    for n in widths:
        r = bench_width(n, args.batch)
        t = traffic_model(n, args.batch, r["L"],
                          kernel_rows=args.linear_batch)
        rec = {"n": n, **r, "traffic": t}
        if not args.skip_fused_timing:
            rec.update(bench_linear_apply(n, args.linear_batch))
        records.append(rec)
        print(f"{n},{r['L']},{r['fwd_dense_us']:.0f},{r['fwd_spm_us']:.0f},"
              f"{r['fwd_dense_us']/r['fwd_spm_us']:.2f}x,"
              f"{r['bwd_dense_us']:.0f},{r['bwd_spm_us']:.0f},"
              f"{r['bwd_dense_us']/r['bwd_spm_us']:.2f}x,"
              f"{t['reduction']:.1f}x,{t['fused_roundtrips']},"
              f"{t['unfused_roundtrips']},{t['vmem_bytes']}")
        emit(f"kernel/n{n}/spm_fwd", r["fwd_spm_us"],
             f"dense={r['fwd_dense_us']:.0f}us")
        if not args.skip_fused_timing:
            emit(f"kernel/n{n}/linear_fused_fwd", rec["linear_fwd_fused_us"],
                 f"unfused={rec['linear_fwd_unfused_us']:.0f}us "
                 f"(interpret={backend != 'tpu'})")

    # rectangular hot shapes: fused (rectangular-native kernel) vs unfused
    # (XLA pad + square composition + slice), fwd and fwd+bwd
    print("# rectangular hot shapes (d_in,d_out,n,L,"
          "fwd_unfused_us,fwd_fused_us,fwdbwd_unfused_us,fwdbwd_fused_us,"
          "hbm_reduction,quant_bytes,quant_reduction)")
    rect_records = []
    for tag, d_in, d_out in rect_shapes:
        rr = {"shape": tag, "d_in": d_in, "d_out": d_out}
        if not args.skip_fused_timing:
            rr.update(bench_linear_rect(d_in, d_out, args.linear_batch))
        else:
            rr["n"] = LinearConfig(d_in=d_in, d_out=d_out,
                                   impl="spm_general").n
            rr["L"] = default_n_stages(rr["n"])
        rr["traffic"] = rect_traffic(d_in, d_out, rr["n"],
                                     args.linear_batch, rr["L"])
        rect_records.append(rr)
        if not args.skip_fused_timing:
            print(f"{tag},{d_in},{d_out},{rr['n']},{rr['L']},"
                  f"{rr['linear_fwd_unfused_us']:.0f},"
                  f"{rr['linear_fwd_fused_us']:.0f},"
                  f"{rr['linear_fwdbwd_unfused_us']:.0f},"
                  f"{rr['linear_fwdbwd_fused_us']:.0f},"
                  f"{rr['traffic']['reduction']:.1f}x,"
                  f"{rr['traffic']['quant_bytes']},"
                  f"{rr['traffic']['quant_reduction']:.2f}x")
            emit(f"kernel/rect_{tag}/linear_fused_fwd",
                 rr["linear_fwd_fused_us"],
                 f"unfused={rr['linear_fwd_unfused_us']:.0f}us "
                 f"(interpret={backend != 'tpu'})")

    # residual-block fusion: the whole norm -> up -> act -> down ->
    # residual chain as ONE Pallas region vs the per-linear fused plan
    print("# residual-block fusion (shape,d_model,d_ff,n,L,"
          "fwd_perlinear_us,fwd_block_us,fwdbwd_perlinear_us,"
          "fwdbwd_block_us,perlinear_bytes,block_bytes,hbm_reduction)")
    block_shapes = BLOCK_SHAPES
    if args.smoke:
        block_shapes = [(t, d // 2, f // 2) for t, d, f in BLOCK_SHAPES]
    block_records = []
    for tag, d_model, d_ff in block_shapes:
        br = {"shape": tag, "d_model": d_model, "d_ff": d_ff}
        br["traffic"] = block_traffic(d_model, d_ff, args.linear_batch)
        if not args.skip_fused_timing:
            br.update(bench_block(d_model, d_ff, args.linear_batch))
        block_records.append(br)
        t = br["traffic"]
        if not args.skip_fused_timing:
            print(f"{tag},{d_model},{d_ff},{t['n']},{t['L']},"
                  f"{br['block_fwd_perlinear_us']:.0f},"
                  f"{br['block_fwd_block_us']:.0f},"
                  f"{br['block_fwdbwd_perlinear_us']:.0f},"
                  f"{br['block_fwdbwd_block_us']:.0f},"
                  f"{t['perlinear_bytes']},{t['block_bytes']},"
                  f"{t['reduction']:.2f}x")
            emit(f"kernel/block_{tag}/fused_fwd", br["block_fwd_block_us"],
                 f"perlinear={br['block_fwd_perlinear_us']:.0f}us "
                 f"(interpret={backend != 'tpu'})")
        else:
            print(f"{tag},{d_model},{d_ff},{t['n']},{t['L']},,,,,"
                  f"{t['perlinear_bytes']},{t['block_bytes']},"
                  f"{t['reduction']:.2f}x")

    # sharded (two_level over 8 virtual devices) vs replicated: modeled
    # per-stage collective_permute bytes next to the HBM traffic model,
    # plus an interpret-safe wall-clock from a forced-device-count child
    # for the smallest width.
    print("# sharded vs replicated (n,L,n_shards,cross_stages,"
          "permute_bytes/chip,exposed_serial,exposed_overlap,"
          "exposed_reduction,hbm_bytes/chip,pr3_hbm_bytes/chip,"
          "boundary_reduction,replicated_bytes,model_speedup)")
    sharded_records = []
    shapes = [(n, None, None, None) for n in widths]
    # one rectangular sharded row (FFN-up-like proportions): the windowed
    # kernel boundaries drop the PR 3 pad/slice terms entirely
    shapes.append((widths[0], widths[0] - widths[0] // 4, widths[0], None))
    # and one local-ending row: L padded to end the two_level cycle on a
    # LOCAL step, so d_out/bias fold into the last kernel run (the
    # default-L schedules end on a cross stage and fold them into the mix
    # epilogue's role vectors instead — both shapes are output-fold-free
    # in the model; this row keeps the kernel-run fold covered)
    n0 = widths[0]
    for L_fold in range(default_n_stages(n0), default_n_stages(n0) + 16):
        st = plan_steps(n0, tuple(two_level_schedule(
            n0, L_fold, SHARD_DEVICES).strides()), SHARD_DEVICES)
        if st[0][0] == "local" and st[-1][0] == "local":
            shapes.append((n0, None, None, L_fold))
            break
    for i, (n, iw, ow, L_override) in enumerate(shapes):
        L = L_override if L_override is not None else default_n_stages(n)
        sr = sharded_model(n, args.batch, L, in_width=iw, out_width=ow)
        if i == 0 and not (args.skip_fused_timing
                           or args.skip_sharded_timing):
            # same batch as the modeled row: the JSON record's modeled
            # seconds and measured microseconds describe ONE workload
            sr["timing"] = time_sharded_subprocess(n, args.batch, L)
        sharded_records.append(sr)
        m, mo = sr["modeled"], sr["modeled_overlap"]
        print(f"{n},{sr['L']},{sr['n_shards']},{sr['n_cross_stages']},"
              f"{m['permute_bytes_per_chip']},"
              f"{m['exposed_permute_bytes_per_chip']},"
              f"{mo['exposed_permute_bytes_per_chip']},"
              f"{sr['exposed_reduction']:.2f}x,"
              f"{m['hbm_bytes_per_chip']},"
              f"{sr['modeled_pr3']['hbm_bytes_per_chip']},"
              f"{sr['boundary_reduction']:.2f}x,"
              f"{sr['replicated_hbm_bytes']},{sr['speedup_model']:.2f}x")
        if sr.get("timing") and "error" not in sr["timing"]:
            t = sr["timing"]
            emit(f"kernel/n{n}/sharded_fwd", t["sharded_fwd_us"],
                 f"replicated={t['replicated_fwd_us']:.0f}us "
                 f"devices={t['devices']}")

    if args.out:
        payload = {
            "generated_by": "benchmarks/kernel_bench.py",
            "backend": backend,
            "batch": args.batch,
            "linear_batch": args.linear_batch,
            "note": ("fused wall-clock is interpret-mode (validation only) "
                     "off-TPU; the traffic model carries the HBM claim"),
            "results": records,
            "rect_results": rect_records,
            "block_results": block_records,
            "sharded_results": sharded_records,
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
