"""SPM operator scaling benchmark (paper §5 complexity claim) + kernel
traffic model + fused-vs-unfused end-to-end ``linear_apply``.

Wall-clock on this CPU container: dense O(n^2) matmul vs SPM O(nL)
composition at growing width (the paper's crossover, Tables 1-2 compute
columns), plus the end-to-end ``linear_apply`` hot path with the fused
full-operator Pallas kernel ON vs OFF, forward and forward+backward.

Off-TPU the fused path runs in interpret mode, so its wall-clock is a
correctness/validation number, NOT a hardware claim (rows are tagged with
the backend).  The TPU claim is reported via the traffic model: the fused
full operator performs 1 HBM read + 1 write of the activation per boundary
run — diag and bias folded in — vs the L+4 round-trips of the per-stage
composition (L stages lowered separately cost L+1, and the d_in multiply,
d_out multiply, and bias add each add one more).

Emits ``BENCH_kernel.json`` (repo root by default) so later PRs have a
trajectory to compare against.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_step
from repro.core import SPMConfig, init_spm, spm_apply
from repro.core.linear import LinearConfig, init_linear, linear_apply
from repro.core.pairings import default_n_stages
from repro.kernels.ops import plan_runs
from repro.kernels.spm_stack import pick_block_rows, vmem_bytes

KEY = jax.random.PRNGKey(0)


def bench_width(n: int, batch: int = 256):
    L = default_n_stages(n)
    cfg = SPMConfig(n=n, n_stages=L, variant="general", backward="custom",
                    use_kernel=False)
    p = init_spm(KEY, cfg)
    x = jax.random.normal(KEY, (batch, n))
    w = jax.random.normal(KEY, (n, n)) / n ** 0.5

    spm_f = jax.jit(lambda x: spm_apply(p, x, cfg))
    dense_f = jax.jit(lambda x: x @ w)
    t_spm = time_step(spm_f, x)
    t_dense = time_step(dense_f, x)

    # fwd+bwd (training step shape)
    spm_g = jax.jit(jax.grad(lambda x: jnp.sum(spm_apply(p, x, cfg) ** 2)))
    dense_g = jax.jit(jax.grad(lambda x: jnp.sum((x @ w) ** 2)))
    tg_spm = time_step(spm_g, x)
    tg_dense = time_step(dense_g, x)
    return {"L": L, "fwd_spm_us": t_spm * 1e6, "fwd_dense_us": t_dense * 1e6,
            "bwd_spm_us": tg_spm * 1e6, "bwd_dense_us": tg_dense * 1e6}


def bench_linear_apply(n: int, batch: int = 64):
    """End-to-end linear_apply (full operator: diag + stages + bias),
    fused Pallas kernel vs unfused XLA composition, fwd and fwd+bwd.

    Off-TPU the fused variant runs the kernels in interpret mode —
    validation wall-clock only."""
    L = default_n_stages(n)
    mk = lambda uk: LinearConfig(d_in=n, d_out=n, impl="spm_general",
                                 n_stages=L, backward="custom",
                                 use_kernel=uk)
    cfg0, cfg1 = mk(False), mk(True)
    p = init_linear(KEY, cfg0)
    x = jax.random.normal(KEY, (batch, n))

    res = {}
    for tag, cfg in (("unfused", cfg0), ("fused", cfg1)):
        f = jax.jit(lambda x, cfg=cfg: linear_apply(p, x, cfg))
        g = jax.jit(jax.grad(
            lambda p, x, cfg=cfg: jnp.sum(linear_apply(p, x, cfg) ** 2)))
        res[f"linear_fwd_{tag}_us"] = time_step(f, x) * 1e6
        res[f"linear_fwdbwd_{tag}_us"] = time_step(g, p, x) * 1e6
    return res


def traffic_model(n: int, batch: int, L: int) -> dict:
    """HBM bytes per FULL-operator call (f32 activations).

    unfused — per-stage XLA composition with separate diag/bias: L+1
    round-trips for the stage chain plus one each for d_in, d_out, bias
    (L+4 total, each a read+write of the activation).
    fused — 1 read + 1 write per boundary run of the kernel plan, diag and
    bias folded into the boundary runs (plus the O(nL) coefficient reads,
    which are batch-independent)."""
    act = batch * n * 4
    strides = tuple(
        SPMConfig(n=n, n_stages=L, variant="general").pairing.strides())
    runs = plan_runs(n, strides)
    n_runs = len(runs)
    coeff_bytes = L * (n // 2) * 16 + 3 * n * 4    # (a,b,c,d) + diag/bias
    unfused = (L + 4) * 2 * act
    kernel_only = (n_runs + 3) * 2 * act + coeff_bytes  # pre-PR: diag/bias out
    fused = n_runs * 2 * act + coeff_bytes
    # block_rows/vmem describe the configuration spm_stack_fused actually
    # runs: sized against the plan's LARGEST tile (matches ops.py)
    max_tile = max(t for _, t in runs)
    br = pick_block_rows(max_tile, L)
    return {"unfused_roundtrips": L + 4,
            "fused_roundtrips": n_runs,
            "n_runs": n_runs,
            "unfused_bytes": unfused,
            "kernel_only_bytes": kernel_only,
            "fused_bytes": fused,
            "reduction": unfused / fused,
            "reduction_vs_kernel_only": kernel_only / fused,
            "max_tile": max_tile,
            "block_rows": br,
            "vmem_bytes": vmem_bytes(br, max_tile, L)}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--linear-batch", type=int, default=64,
                    help="batch for the end-to-end linear_apply rows "
                         "(kept small: interpret mode off-TPU)")
    ap.add_argument("--out", default="BENCH_kernel.json",
                    help="JSON trajectory output ('' to skip)")
    ap.add_argument("--skip-fused-timing", action="store_true",
                    help="traffic model only (no interpret-mode wall-clock)")
    args = ap.parse_args(argv)
    widths = (512, 1024, 2048, 4096) if args.full else (256, 512, 1024)
    backend = jax.default_backend()

    print(f"# SPM vs dense scaling + fused-operator bench (backend={backend})")
    print("n,L,fwd_dense_us,fwd_spm_us,fwd_speedup,"
          "bwd_dense_us,bwd_spm_us,bwd_speedup,hbm_reduction,"
          "fused_roundtrips,unfused_roundtrips,vmem_bytes")
    records = []
    for n in widths:
        r = bench_width(n, args.batch)
        t = traffic_model(n, args.batch, r["L"])
        rec = {"n": n, **r, "traffic": t}
        if not args.skip_fused_timing:
            rec.update(bench_linear_apply(n, args.linear_batch))
        records.append(rec)
        print(f"{n},{r['L']},{r['fwd_dense_us']:.0f},{r['fwd_spm_us']:.0f},"
              f"{r['fwd_dense_us']/r['fwd_spm_us']:.2f}x,"
              f"{r['bwd_dense_us']:.0f},{r['bwd_spm_us']:.0f},"
              f"{r['bwd_dense_us']/r['bwd_spm_us']:.2f}x,"
              f"{t['reduction']:.1f}x,{t['fused_roundtrips']},"
              f"{t['unfused_roundtrips']},{t['vmem_bytes']}")
        emit(f"kernel/n{n}/spm_fwd", r["fwd_spm_us"],
             f"dense={r['fwd_dense_us']:.0f}us")
        if not args.skip_fused_timing:
            emit(f"kernel/n{n}/linear_fused_fwd", rec["linear_fwd_fused_us"],
                 f"unfused={rec['linear_fwd_unfused_us']:.0f}us "
                 f"(interpret={backend != 'tpu'})")

    if args.out:
        payload = {
            "generated_by": "benchmarks/kernel_bench.py",
            "backend": backend,
            "batch": args.batch,
            "linear_batch": args.linear_batch,
            "note": ("fused wall-clock is interpret-mode (validation only) "
                     "off-TPU; the traffic model carries the HBM claim"),
            "results": records,
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
