"""SPM operator scaling benchmark (paper §5 complexity claim) + kernel
traffic model.

Wall-clock on this CPU container: dense O(n^2) matmul vs SPM O(nL)
composition at growing width (the paper's crossover, Tables 1-2 compute
columns).  The Pallas kernel itself is validated in interpret mode
(timing it under interpret is meaningless), so the TPU claim is reported
via the traffic model: fused VMEM kernel = 1 HBM read + 1 write vs L+1
round-trips for the naive composition.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_step
from repro.core import SPMConfig, init_spm, spm_apply
from repro.core.pairings import default_n_stages
from repro.kernels.spm_stack import pick_block_rows, vmem_bytes

KEY = jax.random.PRNGKey(0)


def bench_width(n: int, batch: int = 256):
    L = default_n_stages(n)
    cfg = SPMConfig(n=n, n_stages=L, variant="general", backward="custom")
    p = init_spm(KEY, cfg)
    x = jax.random.normal(KEY, (batch, n))
    w = jax.random.normal(KEY, (n, n)) / n ** 0.5

    spm_f = jax.jit(lambda x: spm_apply(p, x, cfg))
    dense_f = jax.jit(lambda x: x @ w)
    t_spm = time_step(spm_f, x)
    t_dense = time_step(dense_f, x)

    # fwd+bwd (training step shape)
    spm_g = jax.jit(jax.grad(lambda x: jnp.sum(spm_apply(p, x, cfg) ** 2)))
    dense_g = jax.jit(jax.grad(lambda x: jnp.sum((x @ w) ** 2)))
    tg_spm = time_step(spm_g, x)
    tg_dense = time_step(dense_g, x)
    return {"L": L, "fwd_spm_us": t_spm * 1e6, "fwd_dense_us": t_dense * 1e6,
            "bwd_spm_us": tg_spm * 1e6, "bwd_dense_us": tg_dense * 1e6}


def traffic_model(n: int, batch: int, L: int) -> dict:
    """HBM bytes per call: naive composition vs fused kernel (f32)."""
    act = batch * n * 4
    naive = (L + 1) * 2 * act            # read+write per stage
    fused = 2 * act + L * (n // 2) * 16  # one read+write + coeffs
    br = pick_block_rows(min(n, 2048), L)
    return {"naive_bytes": naive, "fused_bytes": fused,
            "reduction": naive / fused,
            "block_rows": br,
            "vmem_bytes": vmem_bytes(br, min(n, 2048), L)}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    widths = (512, 1024, 2048, 4096) if args.full else (256, 512, 1024)

    print("# SPM vs dense scaling (CPU wall-clock) + kernel traffic model")
    print("n,L,fwd_dense_us,fwd_spm_us,fwd_speedup,"
          "bwd_dense_us,bwd_spm_us,bwd_speedup,hbm_reduction,vmem_bytes")
    for n in widths:
        r = bench_width(n)
        t = traffic_model(n, 256, r["L"])
        print(f"{n},{r['L']},{r['fwd_dense_us']:.0f},{r['fwd_spm_us']:.0f},"
              f"{r['fwd_dense_us']/r['fwd_spm_us']:.2f}x,"
              f"{r['bwd_dense_us']:.0f},{r['bwd_spm_us']:.0f},"
              f"{r['bwd_dense_us']/r['bwd_spm_us']:.2f}x,"
              f"{t['reduction']:.1f}x,{t['vmem_bytes']}")
        emit(f"kernel/n{n}/spm_fwd", r["fwd_spm_us"],
             f"dense={r['fwd_dense_us']:.0f}us")


if __name__ == "__main__":
    main()
