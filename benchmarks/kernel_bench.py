"""SPM operator scaling benchmark (paper §5 complexity claim) + kernel
traffic model + fused-vs-unfused end-to-end ``linear_apply``, including the
RECTANGULAR hot shapes (fused q/k/v, d->4d FFN up/down, LM head) that the
rectangular-native kernel serves without XLA pad/slice.

Wall-clock on this CPU container: dense O(n^2) matmul vs SPM O(nL)
composition at growing width (the paper's crossover, Tables 1-2 compute
columns), plus the end-to-end ``linear_apply`` hot path with the fused
full-operator Pallas kernel ON vs OFF, forward and forward+backward.

Off-TPU the fused path runs in interpret mode, so its wall-clock is a
correctness/validation number, NOT a hardware claim (rows are tagged with
the backend).  The TPU claim is reported via the traffic model: the fused
full operator performs 1 HBM read + 1 write of the activation per boundary
run — diag and bias folded in — vs the L+4 round-trips of the per-stage
composition (L stages lowered separately cost L+1, and the d_in multiply,
d_out multiply, and bias add each add one more).

Emits ``BENCH_kernel.json`` (repo root by default) so later PRs have a
trajectory to compare against.
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_step
from repro.core import SPMConfig, init_spm, spm_apply
from repro.core.linear import LinearConfig, init_linear, linear_apply
from repro.core.pairings import default_n_stages
from repro.kernels.ops import pick_block_rows_for_plan, plan_runs
from repro.kernels.spm_stack import vmem_bytes

KEY = jax.random.PRNGKey(0)


def bench_width(n: int, batch: int = 256):
    L = default_n_stages(n)
    cfg = SPMConfig(n=n, n_stages=L, variant="general", backward="custom",
                    use_kernel=False)
    p = init_spm(KEY, cfg)
    x = jax.random.normal(KEY, (batch, n))
    w = jax.random.normal(KEY, (n, n)) / n ** 0.5

    spm_f = jax.jit(lambda x: spm_apply(p, x, cfg))
    dense_f = jax.jit(lambda x: x @ w)
    t_spm = time_step(spm_f, x)
    t_dense = time_step(dense_f, x)

    # fwd+bwd (training step shape)
    spm_g = jax.jit(jax.grad(lambda x: jnp.sum(spm_apply(p, x, cfg) ** 2)))
    dense_g = jax.jit(jax.grad(lambda x: jnp.sum((x @ w) ** 2)))
    tg_spm = time_step(spm_g, x)
    tg_dense = time_step(dense_g, x)
    return {"L": L, "fwd_spm_us": t_spm * 1e6, "fwd_dense_us": t_dense * 1e6,
            "bwd_spm_us": tg_spm * 1e6, "bwd_dense_us": tg_dense * 1e6}


def bench_linear_apply(n: int, batch: int = 64):
    """End-to-end linear_apply (full operator: diag + stages + bias),
    fused Pallas kernel vs unfused XLA composition, fwd and fwd+bwd.

    Off-TPU the fused variant runs the kernels in interpret mode —
    validation wall-clock only."""
    return bench_linear_rect(n, n, batch)


def bench_linear_rect(d_in: int, d_out: int, batch: int = 64):
    """linear_apply for an arbitrary (d_in, d_out), fused vs unfused.  The
    fused path is rectangular-NATIVE (in-kernel zero-fill / partial final
    store); the unfused path pays the XLA pad + slice around the square
    n-wide composition."""
    n = LinearConfig(d_in=d_in, d_out=d_out, impl="spm_general").n
    L = default_n_stages(n)
    mk = lambda uk: LinearConfig(d_in=d_in, d_out=d_out, impl="spm_general",
                                 n_stages=L, backward="custom",
                                 use_kernel=uk)
    cfg0, cfg1 = mk(False), mk(True)
    p = init_linear(KEY, cfg0)
    x = jax.random.normal(KEY, (batch, d_in))

    res = {"n": n, "L": L}
    for tag, cfg in (("unfused", cfg0), ("fused", cfg1)):
        f = jax.jit(lambda x, cfg=cfg: linear_apply(p, x, cfg))
        g = jax.jit(jax.grad(
            lambda p, x, cfg=cfg: jnp.sum(linear_apply(p, x, cfg) ** 2)))
        res[f"linear_fwd_{tag}_us"] = time_step(f, x) * 1e6
        res[f"linear_fwdbwd_{tag}_us"] = time_step(g, p, x) * 1e6
    return res


# Rectangular hot shapes of the reproduced architectures (smoke-scaled
# proportions): every one of these was pad-to-n + slice before the
# rectangular-native kernel landed.
RECT_SHAPES = [
    ("qkv_fused", 256, 768),    # d -> 3d fused q/k/v projection
    ("ffn_up", 256, 1024),      # d -> 4d FFN up-projection
    ("ffn_down", 1024, 256),    # 4d -> d FFN down-projection
    ("lm_head", 384, 2048),     # d -> vocab head (d_in << d_out)
]


def rect_traffic(d_in: int, d_out: int, n: int, batch: int, L: int) -> dict:
    """HBM bytes for a rectangular FULL-operator call (f32 activations).

    unfused — XLA pad (read d_in, write n — only issued when d_in < n) +
    the L+4 square round-trips + output slice (read n, write d_out — only
    when d_out < n; n = even_ceil(max) makes one side exactly n).
    fused — reads batch*d_in once, writes batch*d_out once, plus one
    n-wide round-trip per INTERIOR run boundary of the kernel plan (and
    the O(nL) coefficient reads)."""
    strides = tuple(
        SPMConfig(n=n, n_stages=L, variant="general").pairing.strides())
    n_runs = len(plan_runs(n, strides))
    act_n = batch * n * 4
    act_in = batch * d_in * 4
    act_out = batch * d_out * 4
    coeff_bytes = L * (n // 2) * 16 + 3 * n * 4
    unfused = (L + 4) * 2 * act_n
    if d_in < n:
        unfused += act_in + act_n     # pad pass
    if d_out < n:
        unfused += act_n + act_out    # slice pass
    fused = act_in + act_out + (n_runs - 1) * 2 * act_n + coeff_bytes
    return {"n_runs": n_runs, "coeff_bytes": coeff_bytes,
            "unfused_bytes": unfused, "fused_bytes": fused,
            "reduction": unfused / fused}


def traffic_model(n: int, batch: int, L: int,
                  kernel_rows: int | None = None) -> dict:
    """HBM bytes per SQUARE full-operator call (f32 activations).

    Byte counts come from ``rect_traffic(n, n, ...)`` — the square
    operator is the d_in == d_out == n special case (no pad/slice passes,
    fused = n_runs round-trips + coefficients), so the two BENCH sections
    share one accounting.  Adds the round-trip counts, the pre-fold
    ``kernel_only`` baseline (stage stack fused, diag/bias still separate
    XLA passes), and the block_rows/VMEM configuration spm_stack_fused
    actually runs (per-run budgeting — ops.pick_block_rows_for_plan) at
    ``kernel_rows`` rows: the batch the fused linear rows of the SAME
    record are timed with, which caps the row block."""
    act = batch * n * 4
    strides = tuple(
        SPMConfig(n=n, n_stages=L, variant="general").pairing.strides())
    runs = plan_runs(n, strides)
    t = rect_traffic(n, n, n, batch, L)
    n_runs = t["n_runs"]
    kernel_only = (n_runs + 3) * 2 * act + t["coeff_bytes"]
    max_tile = max(tile for _, tile in runs)
    br = pick_block_rows_for_plan(runs, kernel_rows or batch, 4)
    return {"unfused_roundtrips": L + 4,
            "fused_roundtrips": n_runs,
            "n_runs": n_runs,
            "unfused_bytes": t["unfused_bytes"],
            "kernel_only_bytes": kernel_only,
            "fused_bytes": t["fused_bytes"],
            "reduction": t["reduction"],
            "reduction_vs_kernel_only": kernel_only / t["fused_bytes"],
            "max_tile": max_tile,
            "block_rows": br,
            "vmem_bytes": max(vmem_bytes(br, tile, len(rs))
                              for rs, tile in runs)}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: one width, small batches")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--linear-batch", type=int, default=64,
                    help="batch for the end-to-end linear_apply rows "
                         "(kept small: interpret mode off-TPU)")
    ap.add_argument("--out", default="BENCH_kernel.json",
                    help="JSON trajectory output ('' to skip)")
    ap.add_argument("--skip-fused-timing", action="store_true",
                    help="traffic model only (no interpret-mode wall-clock)")
    args = ap.parse_args(argv)
    widths = (512, 1024, 2048, 4096) if args.full else (256, 512, 1024)
    rect_shapes = RECT_SHAPES
    if args.smoke:
        widths = (256,)
        rect_shapes = [(t, i // 2, o // 2) for t, i, o in RECT_SHAPES]
        args.batch = min(args.batch, 64)
        args.linear_batch = min(args.linear_batch, 16)
    backend = jax.default_backend()

    print(f"# SPM vs dense scaling + fused-operator bench (backend={backend})")
    print("n,L,fwd_dense_us,fwd_spm_us,fwd_speedup,"
          "bwd_dense_us,bwd_spm_us,bwd_speedup,hbm_reduction,"
          "fused_roundtrips,unfused_roundtrips,vmem_bytes")
    records = []
    for n in widths:
        r = bench_width(n, args.batch)
        t = traffic_model(n, args.batch, r["L"],
                          kernel_rows=args.linear_batch)
        rec = {"n": n, **r, "traffic": t}
        if not args.skip_fused_timing:
            rec.update(bench_linear_apply(n, args.linear_batch))
        records.append(rec)
        print(f"{n},{r['L']},{r['fwd_dense_us']:.0f},{r['fwd_spm_us']:.0f},"
              f"{r['fwd_dense_us']/r['fwd_spm_us']:.2f}x,"
              f"{r['bwd_dense_us']:.0f},{r['bwd_spm_us']:.0f},"
              f"{r['bwd_dense_us']/r['bwd_spm_us']:.2f}x,"
              f"{t['reduction']:.1f}x,{t['fused_roundtrips']},"
              f"{t['unfused_roundtrips']},{t['vmem_bytes']}")
        emit(f"kernel/n{n}/spm_fwd", r["fwd_spm_us"],
             f"dense={r['fwd_dense_us']:.0f}us")
        if not args.skip_fused_timing:
            emit(f"kernel/n{n}/linear_fused_fwd", rec["linear_fwd_fused_us"],
                 f"unfused={rec['linear_fwd_unfused_us']:.0f}us "
                 f"(interpret={backend != 'tpu'})")

    # rectangular hot shapes: fused (rectangular-native kernel) vs unfused
    # (XLA pad + square composition + slice), fwd and fwd+bwd
    print("# rectangular hot shapes (d_in,d_out,n,L,"
          "fwd_unfused_us,fwd_fused_us,fwdbwd_unfused_us,fwdbwd_fused_us,"
          "hbm_reduction)")
    rect_records = []
    for tag, d_in, d_out in rect_shapes:
        rr = {"shape": tag, "d_in": d_in, "d_out": d_out}
        if not args.skip_fused_timing:
            rr.update(bench_linear_rect(d_in, d_out, args.linear_batch))
        else:
            rr["n"] = LinearConfig(d_in=d_in, d_out=d_out,
                                   impl="spm_general").n
            rr["L"] = default_n_stages(rr["n"])
        rr["traffic"] = rect_traffic(d_in, d_out, rr["n"],
                                     args.linear_batch, rr["L"])
        rect_records.append(rr)
        if not args.skip_fused_timing:
            print(f"{tag},{d_in},{d_out},{rr['n']},{rr['L']},"
                  f"{rr['linear_fwd_unfused_us']:.0f},"
                  f"{rr['linear_fwd_fused_us']:.0f},"
                  f"{rr['linear_fwdbwd_unfused_us']:.0f},"
                  f"{rr['linear_fwdbwd_fused_us']:.0f},"
                  f"{rr['traffic']['reduction']:.1f}x")
            emit(f"kernel/rect_{tag}/linear_fused_fwd",
                 rr["linear_fwd_fused_us"],
                 f"unfused={rr['linear_fwd_unfused_us']:.0f}us "
                 f"(interpret={backend != 'tpu'})")

    if args.out:
        payload = {
            "generated_by": "benchmarks/kernel_bench.py",
            "backend": backend,
            "batch": args.batch,
            "linear_batch": args.linear_batch,
            "note": ("fused wall-clock is interpret-mode (validation only) "
                     "off-TPU; the traffic model carries the HBM claim"),
            "results": records,
            "rect_results": rect_records,
        }
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
