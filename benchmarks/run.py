"""Benchmark runner: one section per paper table + kernel + roofline.

Emits ``name,us_per_call,derived`` CSV lines (benchmarks/common.emit)
interleaved with per-table reports.  Quick mode by default (CPU-sized);
``--full`` reproduces paper-scale widths.
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    choices=(None, "table1", "table2", "table34", "kernel",
                             "roofline"))
    args = ap.parse_args()
    flags = ["--full"] if args.full else []

    from benchmarks import (kernel_bench, roofline, table1_teacher,
                            table2_agnews, table34_charlm)
    sections = {
        "table1": lambda: table1_teacher.main(flags),
        "table2": lambda: table2_agnews.main(flags),
        "table34": lambda: table34_charlm.main(flags),
        "kernel": lambda: kernel_bench.main(flags),
        "roofline": lambda: roofline.main([]),
    }
    for name, fn in sections.items():
        if args.only and name != args.only:
            continue
        print(f"\n===== {name} =====", flush=True)
        try:
            fn()
        except Exception as e:    # noqa: BLE001 — report, continue suite
            print(f"[bench {name} FAILED] {e}", file=sys.stderr)


if __name__ == "__main__":
    main()
