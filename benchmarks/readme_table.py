"""Generate the README results tables from ``BENCH_kernel.json``.

Only the DETERMINISTIC traffic-model columns are rendered (byte counts and
reduction factors from the HBM/ICI accounting in ``launch/hlo_analysis`` and
``benchmarks/kernel_bench``) — interpret-mode wall-clock off-TPU is a
validation number, not a hardware claim, so it stays out of the README.

Usage:
  PYTHONPATH=src:. python benchmarks/readme_table.py            # print tables
  PYTHONPATH=src:. python benchmarks/readme_table.py --update   # rewrite the
        block between the BENCH-TABLE markers in README.md in place
"""

from __future__ import annotations

import argparse
import json
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
START = "<!-- BENCH-TABLE:START (benchmarks/readme_table.py) -->"
END = "<!-- BENCH-TABLE:END -->"


def _quant_cell(t: dict) -> str:
    """int8 column for one traffic record: bytes + reduction vs the f32
    fused plan, or an em-dash when the plan is quant-ineligible (or the
    payload predates the quant model)."""
    if not t.get("quant_eligible"):
        return "—"
    return f"{t['quant_bytes']:,} ({t['quant_reduction']:.1f}x)"


def render(bench: dict) -> str:
    """The README tables as one markdown string."""
    out = []
    out.append("Square full-operator HBM traffic (f32, batch "
               f"{bench['batch']}): fused Pallas plan vs per-stage XLA "
               "composition with unfused diag/bias, plus the int8-I/O "
               "bytes (`--quantize`, docs/quantization.md):\n")
    out.append("| n | L | round-trips (fused / unfused) | HBM bytes "
               "(fused / unfused) | reduction | int8 bytes (vs fused) |")
    out.append("|---|---|---|---|---|---|")
    for r in bench["results"]:
        t = r["traffic"]
        out.append(
            f"| {r['n']} | {r['L']} | {t['fused_roundtrips']} / "
            f"{t['unfused_roundtrips']} | {t['fused_bytes']:,} / "
            f"{t['unfused_bytes']:,} | {t['reduction']:.1f}x | "
            f"{_quant_cell(t)} |")
    out.append("")
    out.append("Rectangular hot shapes (rectangular-native kernel "
               "boundaries vs XLA pad + square compose + slice):\n")
    out.append("| shape | d_in → d_out | n | HBM bytes (fused / unfused) "
               "| reduction | int8 bytes (vs fused) |")
    out.append("|---|---|---|---|---|---|")
    for r in bench["rect_results"]:
        t = r["traffic"]
        out.append(
            f"| {r['shape']} | {r['d_in']} → {r['d_out']} | {r['n']} | "
            f"{t['fused_bytes']:,} / {t['unfused_bytes']:,} | "
            f"{t['reduction']:.1f}x | {_quant_cell(t)} |")
    out.append("")
    out.append("Residual-block fusion (norm → SPM up → activation → SPM "
               "down → residual-add as ONE Pallas region, "
               "docs/kernels.md § Block fusion) on the FFN hot shapes: "
               "modeled HBM bytes for the whole block vs the per-linear "
               "fused plan (each linear its own kernel; norm, activation "
               "and residual round-tripping in XLA):\n")
    out.append("| shape | d_model → d_ff | n | L per stack | HBM bytes "
               "(block / per-linear) | reduction |")
    out.append("|---|---|---|---|---|---|")
    for r in bench.get("block_results", []):
        t = r["traffic"]
        out.append(
            f"| {r['shape']} | {r['d_model']} → {r['d_ff']} | {t['n']} | "
            f"{t['L']} | {t['block_bytes']:,} / {t['perlinear_bytes']:,} | "
            f"{t['reduction']:.1f}x |")
    out.append("")
    out.append("Feature-sharded two_level executor, per chip "
               f"({bench['sharded_results'][0]['n_shards']}-way): "
               "kernel-native boundaries vs the pre-fold executor, and "
               "exposed communication under the overlap schedule "
               "(row-block pipelined cross-shard exchanges) vs the "
               "step-serial executor:\n")
    out.append("| n | L | widths | cross stages | permute bytes | "
               "exposed comm (serial / overlap) | exposed reduction | HBM "
               "bytes (now / pre-fold) | boundary reduction |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for r in bench["sharded_results"]:
        iw, ow = r.get("in_width"), r.get("out_width")
        w = ("square" if iw is None and ow is None
             else f"{iw or r['n']} → {ow or r['n']}")
        m, mo, m3 = r["modeled"], r["modeled_overlap"], r["modeled_pr3"]
        out.append(
            f"| {r['n']} | {r['L']} | {w} | {r['n_cross_stages']} | "
            f"{m['permute_bytes_per_chip']:,} | "
            f"{m['exposed_permute_bytes_per_chip']:,} / "
            f"{mo['exposed_permute_bytes_per_chip']:,} | "
            f"{r['exposed_reduction']:.2f}x | "
            f"{m['hbm_bytes_per_chip']:,} / {m3['hbm_bytes_per_chip']:,} | "
            f"{r['boundary_reduction']:.2f}x |")
    out.append("")
    out.append("(Both boundary sides fold on EVERY schedule shape: d_in "
               "into the first local kernel run, and d_out/bias into the "
               "last kernel run on a local ending or onto the final "
               "cross-mix epilogue's store on a cross ending — an "
               "O(n_local) vector cost the model no longer charges as "
               "slab traffic.  The last row pads L to end on a local "
               "step, covering the kernel-run fold.  Exposed comm "
               "is the modeled non-hidden share of the permute bytes: the "
               "overlap schedule pipelines row blocks so a block's "
               "exchange hides under other blocks' compute and under "
               "other cross stages' exchanges on distinct XOR links — "
               "see docs/sharding.md.)")
    return "\n".join(out)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=os.path.join(REPO,
                                                    "BENCH_kernel.json"))
    ap.add_argument("--readme", default=os.path.join(REPO, "README.md"))
    ap.add_argument("--update", action="store_true",
                    help="rewrite the README block between the markers")
    args = ap.parse_args(argv)
    with open(args.bench) as f:
        bench = json.load(f)
    tables = render(bench)
    if not args.update:
        print(tables)
        return
    with open(args.readme) as f:
        readme = f.read()
    if START not in readme or END not in readme:
        raise SystemExit(f"markers not found in {args.readme}")
    head, rest = readme.split(START, 1)
    _, tail = rest.split(END, 1)
    with open(args.readme, "w") as f:
        f.write(head + START + "\n" + tables + "\n" + END + tail)
    print(f"updated {args.readme}")


if __name__ == "__main__":
    main()
