"""Continuous-batching serving benchmark -> ``BENCH_serve.json``.

Drives ``serve.ContinuousBatchingEngine`` with a seeded Poisson arrival
process at several offered loads (requests per decode tick) and reports,
per load: total decode ticks, slot occupancy, and p50/p99 per-request
latency in TICKS (arrival -> final token), plus wall-clock tokens/sec.

The regression gate (``check_regression.py --serve-baseline``) consumes
only the SCHEDULE-DETERMINISTIC numbers — ticks, tokens, occupancy,
latency percentiles, and the single-compile count of the decode tick.
Those depend on the seeded arrivals and the admit/evict policy, never on
model weights or sampled token values (eviction triggers on token COUNT),
so they reproduce bit-for-bit across machines.  Wall-clock (``wall_s``,
``tokens_per_s``) is recorded for the trajectory but never gated: off-TPU
it is XLA-CPU noise, not a hardware claim.

  PYTHONPATH=src:. python benchmarks/serve_bench.py --smoke \
      --out BENCH_serve.json
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.analysis.recompile import CompileTracker
from repro.configs import get_smoke
from repro.models import transformer as T
from repro.serve import ContinuousBatchingEngine, Request

DEFAULT_LOADS = (0.2, 0.5, 2.0)   # requests per decode tick
PROMPT_LENS = (5, 12, 24, 7)      # cycled per request: mixes buckets
SCHEMA = "serve_bench/v1"


def poisson_arrivals(n: int, rate: float, seed: int) -> list:
    """Arrival tick (int) per request: cumulative exponential
    inter-arrival gaps at ``rate`` requests/tick, seeded — deterministic
    for the gate."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=1.0 / rate, size=n)
    return [int(t) for t in np.floor(np.cumsum(gaps))]


def percentile_ticks(lat: list, q: float) -> int:
    """Nearest-rank percentile over integer tick latencies (deterministic,
    no interpolation)."""
    s = sorted(lat)
    idx = min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))
    return int(s[idx])


def run_load(eng: ContinuousBatchingEngine, load: float, n_requests: int,
             max_new: int, vocab: int, seed: int) -> dict:
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n_requests):
        plen = PROMPT_LENS[i % len(PROMPT_LENS)]
        prompt = jax.random.randint(jax.random.fold_in(key, i),
                                    (plen,), 0, vocab)
        reqs.append(Request(prompt=prompt, max_new_tokens=max_new, rid=i))
    arrivals = poisson_arrivals(n_requests, load, seed)
    t0 = time.perf_counter()
    results, stats = eng.serve(reqs, arrival_ticks=arrivals)
    wall = time.perf_counter() - t0
    lat = [results[i]["finished_tick"] - arrivals[i]
           for i in range(n_requests)]
    occ = stats["occupied_slot_ticks"] * 1000 \
        // max(stats["ticks"] * eng.slots, 1)
    return {
        "offered_load": load,
        "ticks": stats["ticks"],
        "tokens": stats["tokens"],
        "occupancy_milli": int(occ),
        "p50_latency_ticks": percentile_ticks(lat, 0.50),
        "p99_latency_ticks": percentile_ticks(lat, 0.99),
        # wall-clock: reported, never gated
        "wall_s": round(wall, 3),
        "tokens_per_s": round(stats["tokens"] / wall, 1),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="smoke-scale config (the committed-baseline scale)")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--loads", type=float, nargs="+",
                    default=list(DEFAULT_LOADS))
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    if not args.smoke:
        print("note: full-scale serve bench off-TPU is slow; the gate "
              "runs --smoke")
    cfg = get_smoke(args.arch)
    params = T.init_model(jax.random.PRNGKey(args.seed), cfg)
    eng = ContinuousBatchingEngine(cfg, params, slots=args.slots,
                                   max_len=args.max_len,
                                   base_key=jax.random.PRNGKey(args.seed))

    # warm the tick on a single throwaway request so the per-load loop —
    # and the compile sentinel — measure the steady state
    warm = Request(prompt=jax.numpy.zeros((4,), jax.numpy.int32),
                   max_new_tokens=2, rid=10**9)
    eng.serve([warm])
    with CompileTracker(tick=eng._tick) as tracker:
        loads = [run_load(eng, load, args.requests, args.max_new,
                          cfg.vocab_size, args.seed)
                 for load in sorted(args.loads)]
    tick_compiles = tracker.new_compiles()["tick"]

    payload = {
        "schema": SCHEMA,
        "arch": cfg.name,
        "backend": jax.default_backend(),
        "slots": args.slots,
        "requests": args.requests,
        "max_new": args.max_new,
        # steady-state compile count of the decode tick across EVERY load:
        # 0 new entries after warmup == one compiled tick serves all churn
        "tick_compiles": tick_compiles,
        "loads": loads,
    }
    out = args.out
    if not os.path.isabs(out):
        out = os.path.join(os.getcwd(), out)
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=1, sort_keys=True)
        fh.write("\n")
    for row in loads:
        print(f"load={row['offered_load']:<4} ticks={row['ticks']:<4} "
              f"occ={row['occupancy_milli']/10:.0f}% "
              f"p50={row['p50_latency_ticks']} "
              f"p99={row['p99_latency_ticks']} "
              f"({row['tokens_per_s']} tok/s wall)")
    print(f"tick compiles after warmup: {tick_compiles} -> {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
