"""Paper Table 1: compositional teacher — Dense vs SPM students.

Sweeps width; reports test accuracy and ms/step for both students under
an identical recipe (same optimizer/lr/batch/steps, paper §9.1).  Quick
mode shrinks widths/steps to finish on this 1-core CPU container; --full
runs the paper's exact widths/steps.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import emit, time_step
from repro.configs.paper import T1_BATCH, T1_CLASSES, student_cfg
from repro.data import DeterministicLoader, TeacherConfig, make_teacher, teacher_batch
from repro.models import init_mlp, mlp_loss
from repro.optim import OptimizerConfig
from repro.train import make_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def run_one(width: int, impl: str, steps: int, batch: int) -> dict:
    tc = TeacherConfig(width=width, n_classes=T1_CLASSES)
    teacher = make_teacher(tc)
    loader = DeterministicLoader(
        lambda k, n: teacher_batch(teacher, tc, k, n), batch, seed=0)
    cfg = student_cfg(width, T1_CLASSES, impl)
    state = make_train_state(init_mlp(KEY, cfg))
    step = jax.jit(make_train_step(
        lambda p, b: mlp_loss(p, b, cfg),
        OptimizerConfig(lr=3e-3, total_steps=steps)))
    b0 = loader.batch_at(0)
    ms = time_step(lambda s, b: step(s, b)[0], state, b0) * 1e3
    for s in range(steps):
        state, _ = step(state, loader.batch_at(s))
    accs = []
    for s in range(10_000, 10_005):
        _, m = mlp_loss(state["params"], loader.batch_at(s), cfg)
        accs.append(float(m["acc"]))
    return {"acc": float(np.mean(accs)), "ms_per_step": ms}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-exact widths/steps (slow on CPU)")
    args = ap.parse_args(argv)
    widths = (256, 512, 1024, 2048) if args.full else (128, 256, 512)
    steps = 1200 if args.full else 300
    batch = T1_BATCH if args.full else 128

    print("# Table 1 repro: compositional teacher (hard labels)")
    print("width,dense_acc,spm_acc,delta_acc,dense_ms,spm_ms,speedup")
    for w in widths:
        d = run_one(w, "dense", steps, batch)
        s = run_one(w, "spm_general", steps, batch)
        speed = d["ms_per_step"] / max(s["ms_per_step"], 1e-9)
        print(f"{w},{d['acc']:.4f},{s['acc']:.4f},"
              f"{s['acc']-d['acc']:+.4f},{d['ms_per_step']:.3f},"
              f"{s['ms_per_step']:.3f},{speed:.2f}x")
        emit(f"table1/width{w}/dense", d["ms_per_step"] * 1e3,
             f"acc={d['acc']:.4f}")
        emit(f"table1/width{w}/spm", s["ms_per_step"] * 1e3,
             f"acc={s['acc']:.4f}")


if __name__ == "__main__":
    main()
