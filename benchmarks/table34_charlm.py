"""Paper Tables 3–4: char-level LM with one wide projection (d=4096).

Model mirrors the paper's §9.3 setup: token embedding -> ONE wide linear
projection of dimension d (dense vs SPM butterfly L=12) -> ReLU -> tied
head; T=128, B=32, lr=1e-3.  The corpus is a synthesized Bard proxy
(data/char_corpus.py, SIMULATED).  Reports NLL/BPC trajectory + ms/step.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.paper import CHARLM_B, CHARLM_D, CHARLM_L, CHARLM_LR, CHARLM_T
from repro.core.linear import LinearConfig, init_linear, linear_apply
from repro.data import build_corpus
from repro.optim import OptimizerConfig
from repro.train import make_train_state, make_train_step

KEY = jax.random.PRNGKey(0)
VOCAB = 256


@dataclasses.dataclass(frozen=True)
class CharLMCfg:
    d: int
    impl: str
    n_stages: int = CHARLM_L

    @property
    def proj(self) -> LinearConfig:
        return LinearConfig(d_in=self.d, d_out=self.d, impl=self.impl,
                            n_stages=self.n_stages, schedule="butterfly",
                            backward="custom")


def init_charlm(cfg: CharLMCfg) -> dict:
    k1, k2 = jax.random.split(KEY)
    return {"embed": 0.02 * jax.random.normal(k1, (VOCAB, cfg.d)),
            "proj": init_linear(k2, cfg.proj)}


def charlm_loss(params, batch, cfg: CharLMCfg):
    h = params["embed"][batch["tokens"]]
    h = jax.nn.relu(linear_apply(params["proj"], h, cfg.proj))
    logits = h @ params["embed"].T
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], -1)[..., 0]
    loss = jnp.mean(nll)
    return loss, {"loss": loss, "nll": loss, "bpc": loss / jnp.log(2.0)}


def run_one(d: int, impl: str, steps: int, eval_every: int,
            corpus: np.ndarray, batch: int, seq: int):
    cfg = CharLMCfg(d=d, impl=impl)
    state = make_train_state(init_charlm(cfg))
    step = jax.jit(make_train_step(
        lambda p, b: charlm_loss(p, b, cfg),
        OptimizerConfig(lr=CHARLM_LR, total_steps=steps, warmup_steps=0)))
    rng = np.random.default_rng(0)
    split = int(0.9 * len(corpus))
    train_c, valid_c = corpus[:split], corpus[split:]

    def draw(c):
        starts = rng.integers(0, len(c) - seq - 1, size=batch)
        idx = starts[:, None] + np.arange(seq + 1)[None, :]
        ch = c[idx]
        return {"tokens": jnp.asarray(ch[:, :-1], jnp.int32),
                "labels": jnp.asarray(ch[:, 1:], jnp.int32)}

    rows, t_total = [], 0.0
    for s in range(1, steps + 1):
        b = draw(train_c)
        t0 = time.perf_counter()
        state, m = step(state, b)
        jax.block_until_ready(m["loss"])
        t_total += time.perf_counter() - t0
        if s == 1 or s % eval_every == 0:
            vl = np.mean([float(charlm_loss(state["params"], draw(valid_c),
                                            cfg)[0]) for _ in range(3)])
            rows.append((s, float(m["loss"]), vl, vl / np.log(2),
                         t_total / s * 1e3))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help=f"paper scale d={CHARLM_D} (slow on 1-core CPU)")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args(argv)
    d = CHARLM_D if args.full else 1024
    steps = args.steps or (800 if args.full else 60)
    eval_every = max(steps // 5, 1)
    batch, seq = (CHARLM_B, CHARLM_T) if args.full else (16, 64)
    corpus = build_corpus(1_100_000 if args.full else 200_000)

    print(f"# Tables 3-4 repro: char-LM d={d} L={CHARLM_L} (SIMULATED corpus)")
    for impl in ("dense", "spm_general"):
        rows = run_one(d, impl, steps, eval_every, corpus, batch, seq)
        print(f"## {impl}")
        print("step,train_nll,valid_nll,valid_bpc,ms_per_step")
        for r in rows:
            print(f"{r[0]},{r[1]:.3f},{r[2]:.3f},{r[3]:.3f},{r[4]:.1f}")
        emit(f"table34/{impl}/d{d}", rows[-1][4] * 1e3,
             f"valid_bpc={rows[-1][3]:.3f}")


if __name__ == "__main__":
    main()
