"""§Perf I5: fused-Pallas-kernel projection of the memory roofline term.

The dry-run lowers the SPM composition as separate XLA stage ops: every
stage is ≥1 HBM read + 1 write of the full activation (L+1 round-trips
per SPM linear).  The Pallas kernel (kernels/spm_stack.py, validated in
interpret mode) keeps the tile in VMEM across all fused stages: 1 read +
1 write per run boundary (kernels/ops.plan_runs).  This script computes
both traffic models analytically per cell and projects the memory term
with SPM traffic replaced by the fused model — the number a real-TPU run
would see.

Projection = measured_bytes − unfused_spm_bytes(analytic)
             + fused_spm_bytes(analytic), floored at fused-only traffic.
"""

from __future__ import annotations

import argparse
import json
import os

from repro.configs import SHAPES, get_config
from repro.core.linear import LinearConfig
from repro.core.pairings import default_n_stages
from repro.kernels.ops import plan_runs
from repro.launch.hlo_analysis import HW, roofline_terms

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

DTYPE_B = 2   # bf16 activations


def spm_linear_sites(cfg):
    """(n, L, calls-per-layer-stack) for every SPM linear site."""
    sites = []

    def lin(d_in, d_out, count=1):
        n = max(d_in, d_out)
        n += n % 2
        L = cfg.spm_stages or default_n_stages(n)
        sites.append((n, L, count))

    H, Hkv, dh, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    for spec in cfg.layers:
        if spec.mixer == "attn":
            lin(d, H * dh)
            lin(d, Hkv * dh)
            lin(d, Hkv * dh)
            lin(H * dh, d)
        else:  # mamba
            d_inner = 2 * d
            lin(d, 2 * d_inner + 2 * cfg.ssm_state + d_inner // cfg.ssm_head)
            lin(d_inner, d)
        if spec.mlp == "dense":
            lin(d, cfg.d_ff)
            lin(d, cfg.d_ff)
            lin(cfg.d_ff, d)
        elif spec.mlp == "moe":
            # routed tokens ≈ top_k/n_experts of batch hit each expert; in
            # aggregate every token passes through top_k experts:
            frac = cfg.top_k
            lin(d, cfg.moe_d_ff, count=frac)
            lin(d, cfg.moe_d_ff, count=frac)
            lin(cfg.moe_d_ff, d, count=frac)
            if cfg.shared_d_ff:
                lin(d, cfg.shared_d_ff)
                lin(d, cfg.shared_d_ff)
                lin(cfg.shared_d_ff, d)
        if spec.shared_block:
            lin(d, H * dh)
            lin(d, Hkv * dh)
            lin(d, Hkv * dh)
            lin(H * dh, d)
            lin(d, cfg.shared_attn_d_ff)
            lin(d, cfg.shared_attn_d_ff)
            lin(cfg.shared_attn_d_ff, d)
    return sites


def spm_traffic(cfg, tokens_local: int, passes: float = 3.0):
    """(unfused_bytes, fused_bytes) per chip per step.

    passes: fwd + remat-recompute + bwd ≈ 3 activation passes.
    Unfused: each of L stages reads+writes the (tokens, n) activation.
    Fused:   1 read + 1 write per kernel run (plan_runs boundaries).
    """
    unfused = fused = 0.0
    for n, L, count in spm_linear_sites(cfg):
        act = tokens_local * n * DTYPE_B
        runs = plan_runs(n if n % 2 == 0 else n + 1,
                         tuple([1] * L))  # stride values don't matter for
        # run count at tile cap; real schedules give same-or-fewer runs
        n_runs = len(runs)
        unfused += count * passes * L * 2 * act
        fused += count * passes * n_runs * 2 * act
    return unfused, fused


def project(arch: str, shape_name: str, profile_file: str):
    fp = os.path.join(RESULTS, "single", profile_file)
    with open(fp) as f:
        rec = json.load(f)
    assert rec["ok"], rec.get("error")
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_chips = rec["n_chips"]
    if shape.kind == "train":
        tokens_local = shape.global_batch * shape.seq_len // n_chips
    elif shape.kind == "prefill":
        tokens_local = shape.global_batch * shape.seq_len // n_chips
    else:
        tokens_local = max(shape.global_batch // n_chips, 1)
    passes = 3.0 if shape.kind == "train" else 1.0
    unfused, fused = spm_traffic(cfg, tokens_local, passes)
    measured = rec["cost"]["bytes_accessed"]
    projected = max(measured - unfused + fused, fused)
    terms_now = rec["roofline"]
    terms_proj = roofline_terms(rec["cost"]["flops"], projected,
                                rec["collectives"]["total"])
    return {
        "cell": f"{arch} x {shape_name}",
        "measured_bytes": measured,
        "unfused_spm_bytes": unfused,
        "fused_spm_bytes": fused,
        "projected_bytes": projected,
        "memory_s_now": terms_now["memory_s"],
        "memory_s_projected": terms_proj["memory_s"],
        "dominant_projected": terms_proj["dominant"],
        "roofline_frac_projected": terms_proj["roofline_fraction"],
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    args = ap.parse_args(argv)
    cells = [
        ("qwen3-1.7b", "train_4k", "qwen3-1.7b__train_4k__spm_dp_g.json"),
        ("zamba2-1.2b", "train_4k", "zamba2-1.2b__train_4k__spm_dp_g.json"),
        ("qwen3-moe-30b-a3b", "decode_32k",
         "qwen3-moe-30b-a3b__decode_32k__spm_dp_g.json"),
    ]
    print("# I5 fused-kernel projection (Pallas VMEM stage fusion)")
    for arch, shape, f in cells:
        try:
            r = project(arch, shape, f)
        except FileNotFoundError:
            print(f"{arch} x {shape}: (optimized dry-run record missing)")
            continue
        print(f"\n{r['cell']}:")
        for k in ("measured_bytes", "unfused_spm_bytes", "fused_spm_bytes",
                  "projected_bytes"):
            print(f"  {k:22s} {r[k]:.3e}")
        print(f"  memory term {r['memory_s_now']*1e3:.1f} ms -> "
              f"{r['memory_s_projected']*1e3:.1f} ms projected; dominant "
              f"-> {r['dominant_projected']}, roofline frac "
              f"{r['roofline_frac_projected']:.1%}")


if __name__ == "__main__":
    main()
