"""CI bench-regression gate over ``BENCH_kernel.json``.

Compares a freshly generated bench file against the committed baseline on
the DETERMINISTIC traffic-model numbers only — modeled HBM bytes per chip
and exposed-communication bytes (wall-clock off-TPU is interpret-mode
noise and is never gated).  A fresh value may not exceed its baseline by
more than ``--tol`` (relative): a PR that grows the modeled traffic of an
existing shape fails CI instead of silently landing, while IMPROVEMENTS
and brand-new rows land free (a key missing from the baseline is skipped
with a note; a baseline key missing from the fresh file fails, since
dropping a row is how a regression would hide).

Both files must be generated at the same scale (the smoke CI bench vs the
committed smoke baseline): records are matched on their identity keys
including the batch sizes, and a top-level batch mismatch is an error
rather than a vacuous pass.

Usage:
  PYTHONPATH=src:. python benchmarks/kernel_bench.py --smoke --out fresh.json
  PYTHONPATH=src:. python benchmarks/check_regression.py \
      --baseline BENCH_kernel.json --fresh fresh.json [--tol 0.02]
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Tuple

DEFAULT_TOL = 0.02


def gated_metrics(bench: dict) -> Dict[Tuple, float]:
    """Flatten one bench payload into {key: value} for every gated metric.

    Keys are fully self-describing tuples, so two files generated at the
    same scale produce the same key set and any structural drift shows up
    as missing/new keys rather than silent misalignment.
    """
    out: Dict[Tuple, float] = {}
    batch, lb = bench.get("batch"), bench.get("linear_batch")
    for r in bench.get("results", []):
        t = r["traffic"]
        base = ("square", r["n"], batch, lb)
        out[base + ("fused_bytes",)] = t["fused_bytes"]
        out[base + ("fused_roundtrips",)] = t["fused_roundtrips"]
    for r in bench.get("rect_results", []):
        t = r["traffic"]
        base = ("rect", r["shape"], r["d_in"], r["d_out"], lb)
        out[base + ("fused_bytes",)] = t["fused_bytes"]
    for r in bench.get("sharded_results", []):
        base = ("sharded", r["n"], r["L"], r["n_shards"],
                r.get("in_width"), r.get("out_width"), batch)
        m, mo = r["modeled"], r.get("modeled_overlap", {})
        out[base + ("hbm_bytes_per_chip",)] = m["hbm_bytes_per_chip"]
        out[base + ("permute_bytes_per_chip",)] = m["permute_bytes_per_chip"]
        if "exposed_permute_bytes_per_chip" in m:
            out[base + ("exposed_serial",)] = \
                m["exposed_permute_bytes_per_chip"]
        if mo:
            out[base + ("exposed_overlap",)] = \
                mo["exposed_permute_bytes_per_chip"]
    return out


def compare(baseline: dict, fresh: dict,
            tol: float = DEFAULT_TOL) -> Tuple[list, list, list]:
    """Returns (regressions, dropped, new) key lists; the gate passes iff
    the first two are empty.  A regression entry is (key, base, fresh)."""
    b, f = gated_metrics(baseline), gated_metrics(fresh)
    regressions = []
    for key, bv in b.items():
        if key not in f:
            continue
        fv = f[key]
        if fv > bv * (1.0 + tol):
            regressions.append((key, bv, fv))
    dropped = sorted((k for k in b if k not in f), key=repr)
    new = sorted((k for k in f if k not in b), key=repr)
    return regressions, dropped, new


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_kernel.json")
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--tol", type=float, default=DEFAULT_TOL,
                    help="relative headroom before a grown metric fails")
    ap.add_argument("--allow-dropped", action="store_true",
                    help="do not fail when a baseline row disappears")
    args = ap.parse_args(argv)
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    if (baseline.get("batch"), baseline.get("linear_batch")) != \
            (fresh.get("batch"), fresh.get("linear_batch")):
        print(f"ERROR: scale mismatch — baseline batch="
              f"{baseline.get('batch')}/{baseline.get('linear_batch')}, "
              f"fresh batch={fresh.get('batch')}/"
              f"{fresh.get('linear_batch')}; regenerate at the same scale")
        return 2
    regressions, dropped, new = compare(baseline, fresh, args.tol)
    for key in new:
        print(f"note: new bench row (no baseline, not gated): {key}")
    for key in dropped:
        print(f"{'note' if args.allow_dropped else 'FAIL'}: "
              f"baseline row missing from fresh bench: {key}")
    for key, bv, fv in regressions:
        print(f"FAIL: {key}: {bv:,} -> {fv:,} "
              f"(+{(fv / bv - 1) * 100:.1f}% > tol {args.tol * 100:.0f}%)")
    if regressions or (dropped and not args.allow_dropped):
        print(f"bench regression gate FAILED "
              f"({len(regressions)} regressions, {len(dropped)} dropped)")
        return 1
    print(f"bench regression gate passed "
          f"({len(gated_metrics(fresh))} metrics, {len(new)} new)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
