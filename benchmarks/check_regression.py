"""CI bench-regression gate over ``BENCH_kernel.json``.

Compares a freshly generated bench file against the committed baseline on
the DETERMINISTIC traffic-model numbers only — modeled HBM bytes per chip
and exposed-communication bytes (wall-clock off-TPU is interpret-mode
noise and is never gated).  A fresh value may not exceed its baseline by
more than ``--tol`` (relative): a PR that grows the modeled traffic of an
existing shape fails CI instead of silently landing, while IMPROVEMENTS
and brand-new rows land free (a key missing from the baseline is skipped
with a note; a baseline key missing from the fresh file fails, since
dropping a row is how a regression would hide).

Both files must be generated at the same scale (the smoke CI bench vs the
committed smoke baseline): records are matched on their identity keys
including the batch sizes, and a top-level batch mismatch is an error
rather than a vacuous pass.

One ABSOLUTE floor rides along: every ``block_results`` row (the residual
-block megakernel) must model at least a 1.5x HBM-bytes reduction over
its per-linear fused plan — the block-fusion acceptance bar, enforced on
the fresh file regardless of what the baseline says.

The gate can ALSO consume the compile-contract report
(``python -m repro.analysis check`` -> ``ANALYSIS_contracts.json``): any
contract failure fails the gate, and a cell present in the committed
contract baseline but missing from the fresh report fails too — a config
silently dropping off the kernel path is a regression even when the
modeled bytes of the remaining cells look fine.

The serving benchmark rides the same gate: ``--serve-baseline`` /
``--serve-fresh`` compare ``BENCH_serve.json`` payloads on their
schedule-deterministic metrics (decode ticks, latency percentiles, slot
idleness, and the decode tick's steady-state compile count — wall-clock
is never gated; see ``gated_serve_metrics``).

Usage:
  PYTHONPATH=src:. python benchmarks/kernel_bench.py --smoke --out fresh.json
  PYTHONPATH=src:. python benchmarks/check_regression.py \
      --baseline BENCH_kernel.json --fresh fresh.json [--tol 0.02] \
      [--contract-report fresh_contracts.json \
       --contract-baseline ANALYSIS_contracts.json] \
      [--serve-baseline BENCH_serve.json --serve-fresh fresh_serve.json]
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Tuple

DEFAULT_TOL = 0.02


def gated_metrics(bench: dict) -> Dict[Tuple, float]:
    """Flatten one bench payload into {key: value} for every gated metric.

    Keys are fully self-describing tuples, so two files generated at the
    same scale produce the same key set and any structural drift shows up
    as missing/new keys rather than silent misalignment.
    """
    out: Dict[Tuple, float] = {}
    batch, lb = bench.get("batch"), bench.get("linear_batch")
    for r in bench.get("results", []):
        t = r["traffic"]
        base = ("square", r["n"], batch, lb)
        out[base + ("fused_bytes",)] = t["fused_bytes"]
        out[base + ("fused_roundtrips",)] = t["fused_roundtrips"]
        if "quant_bytes" in t:
            out[base + ("quant_bytes",)] = t["quant_bytes"]
    for r in bench.get("rect_results", []):
        t = r["traffic"]
        base = ("rect", r["shape"], r["d_in"], r["d_out"], lb)
        out[base + ("fused_bytes",)] = t["fused_bytes"]
        if "quant_bytes" in t:
            out[base + ("quant_bytes",)] = t["quant_bytes"]
    for r in bench.get("block_results", []):
        t = r["traffic"]
        base = ("block", r["shape"], r["d_model"], r["d_ff"], lb)
        out[base + ("block_bytes",)] = t["block_bytes"]
        out[base + ("perlinear_bytes",)] = t["perlinear_bytes"]
    for r in bench.get("sharded_results", []):
        base = ("sharded", r["n"], r["L"], r["n_shards"],
                r.get("in_width"), r.get("out_width"), batch)
        m, mo = r["modeled"], r.get("modeled_overlap", {})
        out[base + ("hbm_bytes_per_chip",)] = m["hbm_bytes_per_chip"]
        out[base + ("permute_bytes_per_chip",)] = m["permute_bytes_per_chip"]
        if "exposed_permute_bytes_per_chip" in m:
            out[base + ("exposed_serial",)] = \
                m["exposed_permute_bytes_per_chip"]
        if mo:
            out[base + ("exposed_overlap",)] = \
                mo["exposed_permute_bytes_per_chip"]
    return out


def gated_serve_metrics(bench: dict) -> Dict[Tuple, float]:
    """Flatten a ``BENCH_serve.json`` payload into {key: value} for every
    gated metric — the schedule-deterministic ones only (ticks, tokens,
    latency percentiles, slot idleness, tick compile count).  Wall-clock
    fields are excluded by construction.  Each gated number is
    smaller-is-better so the shared ``compare`` direction applies:
    occupancy is gated as ``idle_milli`` (1000 - occupancy_milli)."""
    out: Dict[Tuple, float] = {}
    base = ("serve", bench.get("arch"), bench.get("slots"),
            bench.get("requests"), bench.get("max_new"))
    out[base + ("tick_compiles",)] = bench.get("tick_compiles", 0)
    for row in bench.get("loads", []):
        k = base + (row["offered_load"],)
        out[k + ("ticks",)] = row["ticks"]
        out[k + ("tokens",)] = row["tokens"]
        out[k + ("idle_milli",)] = 1000 - row["occupancy_milli"]
        out[k + ("p50_latency_ticks",)] = row["p50_latency_ticks"]
        out[k + ("p99_latency_ticks",)] = row["p99_latency_ticks"]
    return out


def compare(baseline: dict, fresh: dict, tol: float = DEFAULT_TOL,
            metrics_fn=None) -> Tuple[list, list, list]:
    """Returns (regressions, dropped, new) key lists; the gate passes iff
    the first two are empty.  A regression entry is (key, base, fresh).
    ``metrics_fn`` flattens a payload into gated {key: value} (default:
    the kernel-bench metrics; pass ``gated_serve_metrics`` for
    BENCH_serve payloads)."""
    metrics_fn = metrics_fn or gated_metrics
    b, f = metrics_fn(baseline), metrics_fn(fresh)
    regressions = []
    for key, bv in b.items():
        if key not in f:
            continue
        fv = f[key]
        if fv > bv * (1.0 + tol):
            regressions.append((key, bv, fv))
    dropped = sorted((k for k in b if k not in f), key=repr)
    new = sorted((k for k in f if k not in b), key=repr)
    return regressions, dropped, new


def compare_contracts(fresh: dict, baseline: dict = None
                      ) -> Tuple[list, list]:
    """(failures, dropped_cells) over contract reports.

    ``failures`` are the fresh report's own contract failures.  With a
    baseline, ``dropped_cells`` lists cell ids the baseline proved that
    the fresh report no longer even checks, PLUS baseline kernel-path
    cells whose fresh twin fell off the kernel path — both are how a
    fast-path regression would hide from a failures-only gate."""
    failures = list(fresh.get("failures", []))
    dropped = []
    if baseline:
        bcells = baseline.get("cells", {})
        fcells = fresh.get("cells", {})
        for cid, bc in sorted(bcells.items()):
            fc = fcells.get(cid)
            if fc is None:
                dropped.append(f"{cid}: cell missing from fresh report")
            elif bc.get("kernel_path") and not fc.get("kernel_path"):
                dropped.append(f"{cid}: fell off the kernel path "
                               "(baseline proved it engaged)")
    return failures, dropped


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_kernel.json")
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--tol", type=float, default=DEFAULT_TOL,
                    help="relative headroom before a grown metric fails")
    ap.add_argument("--allow-dropped", action="store_true",
                    help="do not fail when a baseline row disappears")
    ap.add_argument("--contract-report", default=None,
                    help="fresh ANALYSIS_contracts.json to gate on")
    ap.add_argument("--contract-baseline", default=None,
                    help="committed contract report; fresh must cover "
                         "every baseline cell")
    ap.add_argument("--serve-baseline", default=None,
                    help="committed BENCH_serve.json")
    ap.add_argument("--serve-fresh", default=None,
                    help="fresh BENCH_serve.json to gate (requires "
                         "--serve-baseline)")
    args = ap.parse_args(argv)
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    with open(args.fresh) as fh:
        fresh = json.load(fh)
    if (baseline.get("batch"), baseline.get("linear_batch")) != \
            (fresh.get("batch"), fresh.get("linear_batch")):
        print(f"ERROR: scale mismatch — baseline batch="
              f"{baseline.get('batch')}/{baseline.get('linear_batch')}, "
              f"fresh batch={fresh.get('batch')}/"
              f"{fresh.get('linear_batch')}; regenerate at the same scale")
        return 2
    regressions, dropped, new = compare(baseline, fresh, args.tol)
    # absolute acceptance floor (not just no-worse-than-baseline): the
    # block megakernel must model >= 1.5x fewer HBM bytes than the
    # per-linear fused plan on every residual-block hot shape
    block_floor = []
    for r in fresh.get("block_results", []):
        t = r["traffic"]
        if t["block_bytes"] * 1.5 > t["perlinear_bytes"]:
            block_floor.append((r["shape"], t["perlinear_bytes"],
                                t["block_bytes"]))
    for shape, pb, bb in block_floor:
        print(f"FAIL: block fusion floor: {shape}: block {bb:,} bytes vs "
              f"perlinear {pb:,} ({pb / bb:.2f}x < 1.5x)")
    for key in new:
        print(f"note: new bench row (no baseline, not gated): {key}")
    for key in dropped:
        print(f"{'note' if args.allow_dropped else 'FAIL'}: "
              f"baseline row missing from fresh bench: {key}")
    for key, bv, fv in regressions:
        print(f"FAIL: {key}: {bv:,} -> {fv:,} "
              f"(+{(fv / bv - 1) * 100:.1f}% > tol {args.tol * 100:.0f}%)")
    s_regressions, s_dropped = [], []
    if args.serve_fresh:
        if not args.serve_baseline:
            print("ERROR: --serve-fresh requires --serve-baseline")
            return 2
        with open(args.serve_baseline) as fh:
            s_base = json.load(fh)
        with open(args.serve_fresh) as fh:
            s_fresh = json.load(fh)
        scale = ("arch", "slots", "requests", "max_new")
        if any(s_base.get(k) != s_fresh.get(k) for k in scale):
            print("ERROR: serve-bench scale mismatch — baseline "
                  f"{[s_base.get(k) for k in scale]} vs fresh "
                  f"{[s_fresh.get(k) for k in scale]}; regenerate at the "
                  "same scale")
            return 2
        s_regressions, s_dropped, s_new = compare(
            s_base, s_fresh, args.tol, metrics_fn=gated_serve_metrics)
        for key in s_new:
            print(f"note: new serve row (no baseline, not gated): {key}")
        for key in s_dropped:
            print(f"FAIL: baseline serve row missing from fresh bench: "
                  f"{key}")
        for key, bv, fv in s_regressions:
            print(f"FAIL: serve {key}: {bv:,} -> {fv:,}")
    c_failures, c_dropped = [], []
    if args.contract_report:
        with open(args.contract_report) as fh:
            c_fresh = json.load(fh)
        c_base = None
        if args.contract_baseline:
            with open(args.contract_baseline) as fh:
                c_base = json.load(fh)
        c_failures, c_dropped = compare_contracts(c_fresh, c_base)
        for f_ in c_failures:
            print(f"FAIL: contract: {f_}")
        for d in c_dropped:
            print(f"FAIL: contract coverage: {d}")
    if regressions or (dropped and not args.allow_dropped) \
            or c_failures or c_dropped or s_regressions or s_dropped \
            or block_floor:
        print(f"bench regression gate FAILED "
              f"({len(regressions)} regressions, {len(dropped)} dropped, "
              f"{len(block_floor)} block-fusion floor misses, "
              f"{len(s_regressions)} serve regressions, "
              f"{len(s_dropped)} serve rows dropped, "
              f"{len(c_failures)} contract failures, "
              f"{len(c_dropped)} contract coverage losses)")
        return 1
    n_contract = ""
    if args.contract_report:
        n_contract = (f", {c_fresh['counts']['contract_checks']} "
                      "contract checks")
    n_serve = ""
    if args.serve_fresh:
        n_serve = f", {len(gated_serve_metrics(s_fresh))} serve metrics"
    print(f"bench regression gate passed "
          f"({len(gated_metrics(fresh))} metrics, {len(new)} new"
          f"{n_serve}{n_contract})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
