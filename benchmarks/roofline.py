"""Roofline table builder: reads results/dryrun/<mesh>/*.json (produced by
launch/dryrun.py) and prints the §Roofline table per (arch x shape):
three terms in seconds, dominant bottleneck, MODEL_FLOPS ratio."""

from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load(mesh: str):
    recs = []
    for fp in sorted(glob.glob(os.path.join(RESULTS, mesh, "*.json"))):
        with open(fp) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r: dict) -> str:
    if not r.get("ok"):
        return (f"| {r['arch']} | {r['shape']} | FAIL | | | | | "
                f"{r.get('error','')[:60]} |")
    t = r["roofline"]
    ratio = r.get("useful_flops_ratio")
    return ("| {arch} | {shape} | {c:.3g} | {m:.3g} | {x:.3g} | {dom} | "
            "{rf:.2%} | {ur} |".format(
                arch=r["arch"], shape=r["shape"],
                c=t["compute_s"], m=t["memory_s"], x=t["collective_s"],
                dom=t["dominant"].replace("_s", ""),
                rf=t["roofline_fraction"],
                ur=f"{ratio:.2f}" if ratio else "-"))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args(argv)
    recs = load(args.mesh)
    if not recs:
        print(f"(no dry-run results for mesh={args.mesh} yet — run "
              f"python -m repro.launch.dryrun --all)")
        return
    print(f"# Roofline table ({args.mesh} mesh, per-chip terms, TPU v5e "
          f"constants)")
    print("| arch | shape | compute_s | memory_s | collective_s | dominant "
          "| roofline_frac | useful_flops |")
    print("|---|---|---|---|---|---|---|---|")
    n_ok = 0
    for r in recs:
        print(fmt_row(r))
        n_ok += bool(r.get("ok"))
    print(f"\n{n_ok}/{len(recs)} cells OK")


if __name__ == "__main__":
    main()
