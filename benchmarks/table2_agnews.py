"""Paper Table 2: hashed-sparse text classification (AG News proxy).

Dense vs SPM at fixed stage depth L=12, width sweep.  The corpus is
SIMULATED (class-conditional hashed features — data/hashed_text.py);
the tested CLAIM is the paper's: at large width SPM trains several times
faster per step while matching/exceeding dense accuracy.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import emit, time_step
from repro.configs.paper import AGNEWS_CLASSES, AGNEWS_L, student_cfg
from repro.data import DeterministicLoader
from repro.data.hashed_text import HashedTextConfig, hashed_text_batch
from repro.models import init_mlp, mlp_loss
from repro.optim import OptimizerConfig
from repro.train import make_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def run_one(width: int, impl: str, steps: int, batch: int) -> dict:
    hc = HashedTextConfig(width=width, n_classes=AGNEWS_CLASSES)
    loader = DeterministicLoader(
        lambda k, n: hashed_text_batch(hc, k, n), batch, seed=0)
    cfg = student_cfg(width, AGNEWS_CLASSES, impl, n_stages=AGNEWS_L)
    state = make_train_state(init_mlp(KEY, cfg))
    step = jax.jit(make_train_step(
        lambda p, b: mlp_loss(p, b, cfg),
        OptimizerConfig(lr=3e-3, total_steps=steps)))
    ms = time_step(lambda s, b: step(s, b)[0], state, loader.batch_at(0)) * 1e3
    for s in range(steps):
        state, _ = step(state, loader.batch_at(s))
    accs = []
    for s in range(10_000, 10_005):
        _, m = mlp_loss(state["params"], loader.batch_at(s), cfg)
        accs.append(float(m["acc"]))
    return {"acc": float(np.mean(accs)), "ms_per_step": ms}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    widths = (2048, 4096) if args.full else (512, 1024)
    steps = 800 if args.full else 200
    batch = 256 if args.full else 128

    print(f"# Table 2 repro: hashed sparse text (L={AGNEWS_L}, SIMULATED)")
    print("width,dense_acc,spm_acc,delta_acc,dense_ms,spm_ms,speedup")
    for w in widths:
        d = run_one(w, "dense", steps, batch)
        s = run_one(w, "spm_general", steps, batch)
        speed = d["ms_per_step"] / max(s["ms_per_step"], 1e-9)
        print(f"{w},{d['acc']:.4f},{s['acc']:.4f},"
              f"{s['acc']-d['acc']:+.4f},{d['ms_per_step']:.3f},"
              f"{s['ms_per_step']:.3f},{speed:.2f}x")
        emit(f"table2/width{w}/dense", d["ms_per_step"] * 1e3,
             f"acc={d['acc']:.4f}")
        emit(f"table2/width{w}/spm", s["ms_per_step"] * 1e3,
             f"acc={s['acc']:.4f}")


if __name__ == "__main__":
    main()
