"""Batched serving demo: prefill a batch of prompts, decode with the
KV-cache engine, report aggregate tokens/sec.

  PYTHONPATH=src python examples/serve_batched.py --arch qwen3-1.7b
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke
from repro.models import init_model
from repro.serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=20)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    if cfg.input_kind != "tokens":
        raise SystemExit(f"{args.arch} is embeddings-input; pick a token "
                         f"arch for this demo")
    key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    engine = ServeEngine(cfg=cfg, params=params,
                         max_len=args.prompt_len + args.new_tokens,
                         cache_dtype=jnp.float32)

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    print(f"{args.arch} (smoke config) — batch={args.batch} "
          f"prompt={args.prompt_len} new={args.new_tokens}")
    t0 = time.time()
    out = engine.generate(prompts, max_new_tokens=args.new_tokens,
                          temperature=args.temperature, key=key)
    dt = time.time() - t0
    print(f"decoded {out.shape} in {dt:.2f}s "
          f"({args.batch*args.new_tokens/dt:.1f} tok/s aggregate)")
    print("sample token ids:", out[0][:10].tolist())


if __name__ == "__main__":
    main()
