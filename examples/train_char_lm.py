"""End-to-end driver: train a char-level transformer LM (a few hundred
steps) with SPM projections, deterministic data, checkpoints, and resume.

Default is CPU-sized; --d-model 512 --layers 8 gives a ~20M model, and the
same script scales to ~100M (--d-model 1024 --layers 12) given time.

  PYTHONPATH=src python examples/train_char_lm.py --steps 200
  PYTHONPATH=src python examples/train_char_lm.py --steps 400  # resumes
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import build_corpus
from repro.models import LayerSpec, ModelConfig, init_model
from repro.models import causal_lm as LM
from repro.optim import OptimizerConfig
from repro.train import (latest_step, make_train_state, make_train_step,
                         restore_checkpoint, save_checkpoint)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--impl", default="spm_general",
                    choices=("dense", "spm_general", "spm_rotation"))
    ap.add_argument("--fused", default="auto", choices=("auto", "on", "off"),
                    help="fused Pallas SPM operator (auto = on TPU only; "
                         "'on' forces interpret mode off-TPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_char_lm")
    args = ap.parse_args()
    use_kernel = {"auto": None, "on": True, "off": False}[args.fused]

    cfg = ModelConfig(
        name="char-lm", d_model=args.d_model, n_layers=args.layers,
        n_heads=args.heads, n_kv_heads=args.heads,
        head_dim=args.d_model // args.heads, d_ff=4 * args.d_model,
        vocab_size=256, layers=tuple([LayerSpec()] * args.layers),
        scan_group=1, linear_impl=args.impl, spm_backward="custom",
        spm_use_kernel=use_kernel, dtype=jnp.float32,
        q_chunk=64, k_chunk=64)

    params = init_model(jax.random.PRNGKey(0), cfg)
    state = make_train_state(params)
    print(f"char-LM {args.impl}: "
          f"{sum(x.size for x in jax.tree.leaves(params)):,} params")

    corpus = build_corpus(400_000)
    split = int(0.9 * len(corpus))
    rng = np.random.default_rng(0)

    def draw(lo, hi, batch):
        starts = rng.integers(lo, hi - args.seq - 1, size=batch)
        idx = starts[:, None] + np.arange(args.seq + 1)[None, :]
        ch = corpus[idx]
        return {"tokens": jnp.asarray(ch[:, :-1], jnp.int32),
                "labels": jnp.asarray(ch[:, 1:], jnp.int32)}

    opt = OptimizerConfig(lr=1e-3, total_steps=args.steps, warmup_steps=20)
    step = jax.jit(make_train_step(lambda p, b: LM.lm_loss(p, b, cfg), opt))

    start = 0
    if latest_step(args.ckpt_dir) is not None:
        state, extra = restore_checkpoint(args.ckpt_dir, state)
        start = int(extra["step"])
        print(f"resumed at step {start}")

    t0 = time.time()
    for s in range(start, args.steps):
        state, m = step(state, draw(0, split, args.batch))
        if (s + 1) % 20 == 0:
            vb = draw(split, len(corpus), args.batch)
            _, vm = LM.lm_loss(state["params"], vb, cfg)
            dt = (time.time() - t0) / (s + 1 - start) * 1e3
            print(f"step {s+1:4d}  train={float(m['ce']):.3f} "
                  f"valid={float(vm['ce']):.3f} "
                  f"bpc={float(vm['ce'])/np.log(2):.3f}  {dt:.0f} ms/step")
        if (s + 1) % 100 == 0:
            save_checkpoint(args.ckpt_dir, s + 1, state,
                            extra={"step": s + 1})
    print("done")


if __name__ == "__main__":
    main()
