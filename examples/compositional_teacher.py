"""Paper §9.1 experiment, runnable end-to-end: SPM vs dense students on a
compositional teacher.

  PYTHONPATH=src python examples/compositional_teacher.py --width 256
"""

import argparse

import jax
import numpy as np

from repro.data import DeterministicLoader, TeacherConfig, make_teacher, teacher_batch
from repro.models import MLPConfig, init_mlp, mlp_loss
from repro.optim import OptimizerConfig
from repro.train import make_train_state, make_train_step


def train_student(impl: str, width: int, steps: int, loader) -> float:
    cfg = MLPConfig(n_features=width, n_classes=10, linear_impl=impl,
                    spm_backward="custom")
    state = make_train_state(init_mlp(jax.random.PRNGKey(0), cfg))
    step = jax.jit(make_train_step(
        lambda p, b: mlp_loss(p, b, cfg),
        OptimizerConfig(lr=3e-3, total_steps=steps)))
    for s in range(steps):
        state, m = step(state, loader.batch_at(s))
    accs = [float(mlp_loss(state["params"], loader.batch_at(9000 + i),
                           cfg)[1]["acc"]) for i in range(5)]
    return float(np.mean(accs))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=int, default=256)
    ap.add_argument("--steps", type=int, default=400)
    args = ap.parse_args()

    tc = TeacherConfig(width=args.width)
    teacher = make_teacher(tc)
    loader = DeterministicLoader(
        lambda k, n: teacher_batch(teacher, tc, k, n), 128, seed=0)

    print(f"teacher: SPM -> ReLU -> dense argmax, width={args.width}")
    acc_d = train_student("dense", args.width, args.steps, loader)
    acc_s = train_student("spm_general", args.width, args.steps, loader)
    print(f"dense student acc: {acc_d:.4f}")
    print(f"SPM   student acc: {acc_s:.4f}  (delta {acc_s-acc_d:+.4f})")
    print("=> inductive-bias fit: the student matching the teacher's "
          "structured-mixing hypothesis class wins (paper Table 1).")


if __name__ == "__main__":
    main()
