"""Quickstart: SPM as a drop-in replacement for a dense linear layer.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import LinearConfig, init_linear, linear_apply, linear_param_count
from repro.core import SPMConfig, init_spm, spm_apply, spm_matrix

key = jax.random.PRNGKey(0)

# --- 1. the raw SPM operator (paper §2) -----------------------------------
cfg = SPMConfig(n=256, n_stages=8, variant="rotation", schedule="butterfly")
params = init_spm(key, cfg)
x = jax.random.normal(key, (4, 256))
y = spm_apply(params, x, cfg)
print(f"SPM(256, L=8, rotation): {x.shape} -> {y.shape}, "
      f"params={cfg.param_count():,} (dense would be {256*256:,})")
print(f"norm preservation (orthogonal variant): "
      f"|x|={float(jnp.linalg.norm(x[0])):.4f} "
      f"|core(x)|={float(jnp.linalg.norm(spm_apply({**params, 'd_in': jnp.ones(256), 'd_out': jnp.ones(256), 'bias': jnp.zeros(256)}, x, cfg)[0])):.4f}")

# --- 2. drop-in linear factory (dense | spm_general | spm_rotation) -------
for impl in ("dense", "spm_general", "spm_rotation"):
    lc = LinearConfig(d_in=512, d_out=1024, impl=impl)
    lp = init_linear(jax.random.PRNGKey(1), lc)
    out = linear_apply(lp, jax.random.normal(key, (2, 512)), lc)
    print(f"{impl:13s}: (2, 512) -> {out.shape}, "
          f"params={linear_param_count(lc):,}")

# --- 3. exact gradients through the factorized operator (paper §4) --------
loss = lambda p: jnp.sum(spm_apply(p, x, cfg) ** 2)
grads = jax.grad(loss)(params)
print("closed-form VJP grad norms:",
      {k: f"{float(jnp.linalg.norm(v)):.3f}" for k, v in grads.items()})

# --- 4. materialize the operator (analysis only) ---------------------------
cfg8 = SPMConfig(n=8, n_stages=3, variant="rotation",
                 use_diag=False, use_bias=False)
W = spm_matrix(init_spm(jax.random.PRNGKey(2), cfg8), cfg8)
print("8x8 rotation-SPM operator, W W^T == I:",
      bool(jnp.allclose(W @ W.T, jnp.eye(8), atol=1e-5)))
