"""VMEM-budget accounting tests: ``ops.pick_block_rows_for_plan`` and the
overlap kernels' per-block send/recv buffer accounting
(``spm_stack.overlap_vmem_bytes``).

The contract under test: the row block every kernel run of a plan shares
must keep EACH run's own working set — and, when the RDMA transport may
engage, the double-buffered send/recv slots — inside the VMEM budget, for
f32 and bf16 activation I/O and for degenerate tiny-row inputs (where the
row cap, not the budget, binds).
"""

import pytest

from repro.core.pairings import default_n_stages
from repro.core.spm import SPMConfig
from repro.kernels.ops import pick_block_rows_for_plan, plan_runs
from repro.kernels.spm_stack import (overlap_vmem_bytes, pick_block_rows,
                                     vmem_bytes)

BUDGET = 12 * 2**20      # pick_block_rows' default


def _plan(n, L=None):
    L = L if L is not None else default_n_stages(n)
    strides = SPMConfig(n=n, n_stages=L, variant="general").pairing.strides()
    return plan_runs(n, tuple(strides))


@pytest.mark.parametrize("dtype_bytes", [4, 2], ids=["f32", "bf16"])
@pytest.mark.parametrize("n", [256, 2048, 4096])
def test_plan_row_block_respects_every_runs_budget(n, dtype_bytes):
    runs = _plan(n)
    br = pick_block_rows_for_plan(runs, 1 << 20, dtype_bytes)
    assert br >= 8
    for run_strides, n_tile in runs:
        assert vmem_bytes(br, n_tile, len(run_strides),
                          dtype_bytes) <= BUDGET, (n_tile, br)


def test_mixed_tile_plan_binds_on_its_largest_run():
    # n = 4096 with the default butterfly plans to multiple runs whose
    # tiles differ (the lcm of pair spans caps at MAX_TILE); the shared
    # row block must be the min over runs, i.e. sized by the widest tile.
    runs = _plan(4096)
    assert len(runs) > 1
    tiles = {tile for _, tile in runs}
    assert len(tiles) > 1, "expected a mixed-tile plan"
    br = pick_block_rows_for_plan(runs, 1 << 20, 4)
    per_run = [pick_block_rows(tile, len(rs), dtype_bytes=4)
               for rs, tile in runs]
    assert br == min(min(per_run), 8 << 17) and br == min(per_run)


@pytest.mark.parametrize("dtype_bytes", [4, 2], ids=["f32", "bf16"])
def test_overlap_accounting_adds_send_recv_double_buffers(dtype_bytes):
    rb, nt, L = 64, 512, 6
    comm = 2 * 2 * 2 * rb * nt * dtype_bytes   # slots x tensors x ends
    x_walk = rb * nt * dtype_bytes             # bwd's second x window
    assert overlap_vmem_bytes(rb, nt, L, dtype_bytes) == \
        vmem_bytes(rb, nt, L, dtype_bytes) + comm + x_walk


@pytest.mark.parametrize("dtype_bytes", [4, 2], ids=["f32", "bf16"])
@pytest.mark.parametrize("n", [256, 2048])
def test_overlap_budget_ceiling_respected(n, dtype_bytes):
    runs = _plan(n)
    br = pick_block_rows_for_plan(runs, 1 << 20, dtype_bytes,
                                  overlap_bufs=True)
    assert br >= 8
    for run_strides, n_tile in runs:
        assert overlap_vmem_bytes(br, n_tile, len(run_strides),
                                  dtype_bytes) <= BUDGET
    # reserving the comm slots can only shrink the row block
    assert br <= pick_block_rows_for_plan(runs, 1 << 20, dtype_bytes)


def test_tiny_rows_cap_the_row_block_not_the_budget():
    runs = _plan(256)
    for rows in (1, 3, 8, 9):
        br = pick_block_rows_for_plan(runs, rows, 4)
        assert br == max(8, 1 << (rows - 1).bit_length())
        # with the row cap binding, reserving the comm slots is a no-op
        assert br == pick_block_rows_for_plan(runs, rows, 4,
                                              overlap_bufs=True)


@pytest.mark.parametrize("dtype_bytes", [4, 2], ids=["f32", "bf16"])
def test_block_accounting_adds_chain_live_buffers(dtype_bytes):
    # the residual-block kernels keep THREE extra f32 activation tiles
    # live across the whole chain (x-hat, pre-act u, post-act h) plus the
    # (block_rows, 1) row statistics, on top of the per-run working set
    from repro.kernels.spm_stack import block_vmem_bytes
    rb, nt, L = 32, 1024, 14
    assert block_vmem_bytes(rb, nt, L, dtype_bytes) == \
        vmem_bytes(rb, nt, L, dtype_bytes) + 3 * rb * nt * 4 + rb * 4


def test_block_budget_ceiling_respected():
    # the block entry budgets ONE pseudo-run holding both stacks' strides
    # at the full width (the chain never re-tiles between the stacks)
    from repro.kernels.spm_stack import block_vmem_bytes
    strides = SPMConfig(n=2048, n_stages=11,
                        variant="general").pairing.strides()
    runs = [(tuple(strides) * 2, 2048)]
    br_block = pick_block_rows_for_plan(runs, 1 << 20, 4, block_bufs=True)
    br_plain = pick_block_rows_for_plan(runs, 1 << 20, 4)
    # reserving the chain buffers can only shrink the row block
    assert 8 <= br_block <= br_plain
    assert block_vmem_bytes(br_block, 2048, 22, 4) <= BUDGET
    # tiny rows: the row cap binds identically with and without the bufs
    assert pick_block_rows_for_plan(runs, 8, 4, block_bufs=True) == 8


def test_pick_row_blocks_partitions_rows_into_kernel_multiples():
    from repro.parallel.spm_shard import pick_row_blocks
    # padded slab: every block a block_rows multiple, sizes sum to rows
    rb = pick_row_blocks(256, 16)
    assert sum(rb) == 256 and len(rb) == 4
    assert all(b % 16 == 0 for b in rb)
    # fewer kernel row-blocks than the target -> fewer pipeline blocks
    assert pick_row_blocks(32, 16) == (16, 16)
    assert pick_row_blocks(16, 16) == (16,)
    assert pick_row_blocks(8, 16) == (8,)      # degenerate: single block
    # XLA path (block_rows=1): any split that sums to rows
    rb = pick_row_blocks(37, 1)
    assert sum(rb) == 37 and len(rb) == 4
