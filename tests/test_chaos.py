"""Chaos-engineering harness: deterministic fault injection, verified
checkpoint integrity, quarantine + walk-back, recovery orchestration —
capped by the single-device parity test: a run that suffers a NaN burst,
a corrupted checkpoint, AND a preemption must finish bitwise-identical
to the fault-free run."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data import DeterministicLoader, TeacherConfig, make_teacher, \
    teacher_batch
from repro.launch.train import build_parser, train
from repro.models import MLPConfig, init_mlp, mlp_loss
from repro.models import transformer as T
from repro.optim import OptimizerConfig
from repro.serve import ServeEngine
from repro.train import (CheckpointCorruptError, FaultEventLog,
                         FaultPolicy, RESUME_LATEST, StragglerDetector,
                         latest_step, latest_valid_step, list_checkpoints,
                         make_train_state, make_train_step,
                         restore_checkpoint, run_with_recovery,
                         save_checkpoint, verify_checkpoint)
from repro.train.chaos import (CORRUPTION_MODES, ChaosPreemption,
                               ChaosSchedule, corrupt_checkpoint)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# chaos spec parsing + fire-once semantics
# ---------------------------------------------------------------------------

def test_chaos_spec_parsing():
    sched = ChaosSchedule.parse(
        "nan@13+5; corrupt@18:truncate; preempt@19; slow@3:0.01")
    kinds = [(e.kind, e.step, e.arg) for e in sched.events]
    assert ("preempt", 19, None) in kinds
    assert ("corrupt", 18, "truncate") in kinds
    assert ("slow", 3, "0.01") in kinds
    assert [s for k, s, _ in kinds if k == "nan"] == [13, 14, 15, 16, 17]


def test_chaos_spec_defaults_and_errors():
    sched = ChaosSchedule.parse("corrupt@5;slow@2")
    by_kind = {e.kind: e for e in sched.events}
    assert by_kind["corrupt"].arg == "bitflip"
    assert float(by_kind["slow"].arg) > 0
    for bad in ("explode@3", "nan@x", "corrupt@5:gamma",
                "preempt@5:arg", "corrupt@5+3", "nan@"):
        with pytest.raises(ValueError):
            ChaosSchedule.parse(bad)
    assert ChaosSchedule.parse("").events == []


def test_chaos_events_fire_once():
    """A fired event stays fired across replayed step numbers — otherwise
    recovery would re-trigger the same fault forever."""
    sched = ChaosSchedule.parse("nan@3;preempt@5")
    assert sched.poison(2) == 0.0
    assert sched.poison(3) == 1.0
    assert sched.poison(3) == 0.0            # consumed
    with pytest.raises(ChaosPreemption):
        sched.post_step(5, None)
    sched.post_step(5, None)                 # replay: no second preemption
    assert sched.remaining() == ()


def test_chaos_slow_step_injection_and_detection():
    log = FaultEventLog()
    det = StragglerDetector(factor=1.5, min_samples=3, event_log=log)
    sched = ChaosSchedule.parse("slow@6:0.05")
    for s in range(8):
        delay = sched.pre_step(s)
        flagged = det.observe(s, 0.001 + delay)
        assert flagged == (s == 6), s
    assert log.kinds() == ["slow_step"]
    assert log.events[0]["step"] == 6


# ---------------------------------------------------------------------------
# in-graph poison port
# ---------------------------------------------------------------------------

def _mlp_setup(width=32):
    cfg = MLPConfig(n_features=width, n_classes=10)
    tc = TeacherConfig(width=width)
    teacher = make_teacher(tc)
    loader = DeterministicLoader(
        lambda k, n: teacher_batch(teacher, tc, k, n), 64, seed=1)
    return cfg, loader


def test_chaos_guard_poison_skips_and_healthy_is_bit_identical():
    cfg, loader = _mlp_setup()
    ocfg = OptimizerConfig(lr=1e-2, total_steps=10)
    plain = jax.jit(make_train_step(
        lambda p, b: mlp_loss(p, b, cfg), ocfg))
    guarded = jax.jit(make_train_step(
        lambda p, b: mlp_loss(p, b, cfg), ocfg, chaos_guard=True))
    state = make_train_state(init_mlp(KEY, cfg))
    batch = loader.batch_at(0)

    # poison=0: the chaos-guard build is BITWISE the plain build
    s_plain, _ = plain(state, batch)
    s_clean, m = guarded(state, batch, 0.0)
    assert float(m["skipped"]) == 0.0
    for a, b in zip(jax.tree.leaves(s_plain), jax.tree.leaves(s_clean)):
        np.testing.assert_array_equal(a, b)

    # poison=1: update skipped, params/opt pass through, step advances
    s_bad, m = guarded(state, batch, 1.0)
    assert float(m["skipped"]) == 1.0
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(s_bad["params"])):
        np.testing.assert_array_equal(a, b)
    assert int(s_bad["step"]) == 1

    with pytest.raises(TypeError, match="poison"):
        guarded(state, batch)
    with pytest.raises(ValueError, match="nan_guard"):
        make_train_step(lambda p, b: mlp_loss(p, b, cfg), ocfg,
                        chaos_guard=True, nan_guard=False)


# ---------------------------------------------------------------------------
# checkpoint integrity: manifest, verify, quarantine, walk-back
# ---------------------------------------------------------------------------

def _saved_state(d, steps=(10, 20)):
    cfg, _ = _mlp_setup()
    state = make_train_state(init_mlp(KEY, cfg))
    for s in steps:
        save_checkpoint(d, s, state,
                        extra={"cursor": {"seed": 1, "step": s}})
    return state


def test_verify_checkpoint_clean_pass_and_manifest(tmp_path):
    d = str(tmp_path)
    _saved_state(d)
    assert verify_checkpoint(d, 20) == []
    with open(os.path.join(d, "step_20", "meta.json")) as f:
        meta = json.load(f)
    assert meta["format"] >= 2 and meta["meta_sha256"]
    assert set(meta["manifest"]) == {f"a{i}"
                                     for i in range(meta["n_arrays"])}
    for ent in meta["manifest"].values():
        assert set(ent) == {"sha256", "shape", "dtype"}


@pytest.mark.parametrize("mode", CORRUPTION_MODES)
def test_each_corruption_mode_is_caught(tmp_path, mode):
    d = str(tmp_path)
    state = _saved_state(d)
    corrupt_checkpoint(d, mode)
    if mode == "orphan":
        # staging debris is not a corruption of step_20 itself: the step
        # still verifies, the tmp dir must never be (re)published or
        # picked as a step, and the next save sweeps it
        assert verify_checkpoint(d, 20) == []
        assert latest_valid_step(d) == 20
        save_checkpoint(d, 30, state)
        assert not [f for f in os.listdir(d) if f.startswith("tmp.")]
        return
    assert verify_checkpoint(d, 20) != []
    # walk-back: 20 quarantined, 10 selected
    assert latest_valid_step(d) == 10
    assert any(f.startswith("corrupt.20.") for f in os.listdir(d))
    restored, extra = restore_checkpoint(d, state)
    assert extra["cursor"]["step"] == 10


def test_any_byte_flip_fails_verification(tmp_path):
    """Acceptance: corrupting ANY byte of the checkpoint payload makes
    verify_checkpoint fail — sampled across both files at spread offsets."""
    d = str(tmp_path)
    _saved_state(d, steps=(20,))
    step_dir = os.path.join(d, "step_20")
    for fname in ("arrays.npz", "meta.json"):
        path = os.path.join(step_dir, fname)
        orig = open(path, "rb").read()
        size = len(orig)
        for off in {0, 1, size // 3, size // 2, (2 * size) // 3, size - 1}:
            with open(path, "r+b") as f:
                f.seek(off)
                f.write(bytes([orig[off] ^ 0xFF]))
            assert verify_checkpoint(d, 20) != [], (fname, off)
            with open(path, "wb") as f:
                f.write(orig)
        assert verify_checkpoint(d, 20) == [], fname


def test_explicit_restore_of_corrupt_step_raises_and_quarantines(tmp_path):
    d = str(tmp_path)
    state = _saved_state(d)
    corrupt_checkpoint(d, "bitflip", step=20)
    with pytest.raises(CheckpointCorruptError):
        restore_checkpoint(d, state, step=20)
    assert any(f.startswith("corrupt.20.") for f in os.listdir(d))
    # and quarantined steps never reappear via the unverified lister
    assert latest_step(d) == 10


def test_quarantined_dirs_survive_keep_n_gc(tmp_path):
    d = str(tmp_path)
    state = _saved_state(d, steps=(10,))
    corrupt_checkpoint(d, "bitflip", step=10)
    assert latest_valid_step(d) is None        # quarantined, nothing valid
    for s in (20, 30, 40, 50):
        save_checkpoint(d, s, state, keep=3)
    assert list_checkpoints(d) == [30, 40, 50]
    assert any(f.startswith("corrupt.10.") for f in os.listdir(d))


def test_treedef_mismatch_refuses_restore(tmp_path):
    d = str(tmp_path)
    cfg, _ = _mlp_setup()
    state = make_train_state(init_mlp(KEY, cfg))
    save_checkpoint(d, 5, state)
    flat = jax.tree_util.tree_flatten(state)[0]
    wrong = {f"k{i}": x for i, x in enumerate(flat)}  # same leaf count
    with pytest.raises(ValueError, match="treedef"):
        restore_checkpoint(d, wrong, step=5)


# ---------------------------------------------------------------------------
# recovery orchestration
# ---------------------------------------------------------------------------

def test_run_with_recovery_backoff_and_resume_intent():
    calls, slept = [], []

    def loop(resume):
        calls.append(resume)
        if len(calls) < 3:
            raise ChaosPreemption("boom")
        return "done"

    log = FaultEventLog()
    assert run_with_recovery(loop, max_restarts=3, backoff_base=0.5,
                             event_log=log, sleep=slept.append) == "done"
    assert calls == [None, RESUME_LATEST, RESUME_LATEST]
    assert slept == [0.5, 1.0]                 # exponential backoff
    assert log.kinds() == ["restart", "restart"]


def test_run_with_recovery_budget_exhaustion_reraises():
    def loop(resume):
        raise RuntimeError("hard fault")

    log = FaultEventLog()
    with pytest.raises(RuntimeError, match="hard fault"):
        run_with_recovery(loop, max_restarts=2, event_log=log,
                          sleep=lambda s: None)
    assert log.kinds() == ["restart", "restart",
                           "restart_budget_exhausted"]

    def interrupted(resume):
        raise KeyboardInterrupt

    with pytest.raises(KeyboardInterrupt):     # never swallowed
        run_with_recovery(interrupted, sleep=lambda s: None)


def test_fault_event_log_jsonl(tmp_path):
    path = str(tmp_path / "sub" / "events.jsonl")
    log = FaultEventLog(path)
    log.emit("skip", step=3, cause="non-finite grads")
    log.emit("restart", attempt=1, backoff_s=0.5)
    lines = [json.loads(l) for l in open(path)]
    assert [e["kind"] for e in lines] == ["skip", "restart"]
    assert lines[0]["step"] == 3 and lines[0]["t"] > 0
    assert lines[1]["backoff_s"] == 0.5


def test_loader_resume_hardening():
    cfg, loader = _mlp_setup()
    assert loader.resume({"seed": 7, "step": 42})
    assert loader.cursor.seed == 7 and loader.cursor.step == 42
    # old/partial checkpoint formats degrade to a fresh cursor, no crash
    assert not loader.resume(None)
    assert not loader.resume({"step": 5})      # missing seed
    assert not loader.resume("garbage")
    assert loader.cursor.seed == 7             # kept the last good cursor
    assert loader.state_dict() == loader.cursor.state_dict()


# ---------------------------------------------------------------------------
# serve engine: non-finite logits guard
# ---------------------------------------------------------------------------

def test_serve_guards_non_finite_logits():
    cfg = get_smoke("qwen3-1.7b")
    params = T.init_model(KEY, cfg)
    eng = ServeEngine(cfg=cfg, params=params, max_len=16,
                      cache_dtype=jnp.float32)
    prompts = jax.random.randint(KEY, (2, 4), 0, cfg.vocab_size)
    out, flags = eng.generate(prompts, max_new_tokens=4, return_flags=True)
    assert not bool(flags.any())               # healthy model: no flags

    # poison the params: every logit row goes NaN
    bad_params = jax.tree.map(lambda x: x * jnp.nan, params)
    beng = ServeEngine(cfg=cfg, params=bad_params, max_len=16,
                       cache_dtype=jnp.float32)
    out, flags = beng.generate(prompts, max_new_tokens=4,
                               return_flags=True)
    assert bool(flags.all())                   # every request flagged
    np.testing.assert_array_equal(out, 0)      # deterministic fallback
    # sampling path too: in-range fallback instead of NaN categoricals
    out, flags = beng.generate(prompts, max_new_tokens=4, temperature=0.8,
                               key=KEY, return_flags=True)
    assert bool(flags.all())
    assert bool(((out >= 0) & (out < cfg.vocab_size)).all())


# ---------------------------------------------------------------------------
# end-to-end single-device chaos parity (the acceptance test)
# ---------------------------------------------------------------------------

def _driver_args(ckpt_dir, extra=()):
    return build_parser().parse_args(
        ["--smoke", "--steps", "24", "--batch", "4", "--seq", "16",
         "--ckpt-every", "6", "--log-every", "6", "--backoff-base", "0.0",
         "--ckpt-dir", ckpt_dir, *extra])


def test_single_device_chaos_parity(tmp_path):
    """One run suffers a 5-step NaN burst (→ fault-policy rollback), a
    bit-flipped newest checkpoint (→ quarantine + walk-back on restore),
    and an injected preemption (→ run_with_recovery restart) — and must
    finish BITWISE-identical to the fault-free run."""
    clean = train(_driver_args(str(tmp_path / "clean")))

    chaos = ChaosSchedule.parse("nan@13+5;corrupt@17:bitflip;preempt@18")
    chaos_dir = str(tmp_path / "chaos")
    state = train(_driver_args(chaos_dir), chaos=chaos)

    for a, b in zip(jax.tree.leaves(clean), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    assert chaos.remaining() == ()             # every fault actually fired
    names = os.listdir(chaos_dir)
    assert any(n.startswith("corrupt.18.") for n in names)  # quarantined
    assert verify_checkpoint(chaos_dir, 24) == []
    kinds = [json.loads(l)["kind"]
             for l in open(os.path.join(chaos_dir, "events.jsonl"))]
    assert kinds.count("skip") == 5            # the NaN burst
    assert "rollback" in kinds                 # fault-policy rewind
    assert "quarantine" in kinds               # corrupt ckpt walked past
    assert "restart" in kinds                  # recovery orchestration
    assert kinds.index("rollback") < kinds.index("restart")


def test_rollback_without_any_checkpoint_restarts_fresh(tmp_path):
    """The old driver crashed with FileNotFoundError when the fault
    policy tripped before the first save (or with no --ckpt-dir at all);
    now it restarts the loop from scratch and still finishes."""
    # burst of 5 at steps 2..6, first save would be at step 6
    chaos = ChaosSchedule.parse("nan@2+5")
    state = train(_driver_args(str(tmp_path / "ck"),
                               extra=["--steps", "8", "--ckpt-every",
                                      "100"]), chaos=chaos)
    assert int(state["step"]) == 8
    # no checkpoint dir at all exercises the same guard
    args = build_parser().parse_args(
        ["--smoke", "--steps", "8", "--batch", "4", "--seq", "16",
         "--backoff-base", "0.0"])
    state = train(args, chaos=ChaosSchedule.parse("nan@2+5"))
    assert int(state["step"]) == 8
