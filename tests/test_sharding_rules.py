"""PartitionSpec rule-table tests (no multi-device needed: specs are pure
metadata; a 1x1 mesh carries the axis names)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_smoke
from repro.launch.hlo_analysis import (collective_bytes, parse_shape_bytes,
                                       roofline_terms)
from repro.launch.specs import abstract_cache, abstract_state, input_specs
from repro.configs.shapes import SHAPES
from repro.models import transformer as T
from repro.parallel import sharding as SH


def tiny_mesh(axes=("data", "model")):
    shape = (1,) * len(axes)
    return Mesh(np.asarray(jax.devices()[:1]).reshape(shape), axes)


MESH = tiny_mesh()
MESH3 = tiny_mesh(("pod", "data", "model"))


def test_embedding_vocab_parallel():
    assert SH.param_spec("embed/table", 2, MESH) == P("model", "data")


def test_dense_col_vs_row_parallel():
    assert SH.param_spec("layers/l0/mixer/q/w", 2, MESH) == P("data", "model")
    assert SH.param_spec("layers/l0/mixer/o/w", 2, MESH) == P("model", "data")
    assert SH.param_spec("layers/l0/mlp/up/w", 2, MESH) == P("data", "model")
    assert SH.param_spec("layers/l0/mlp/down/w", 2, MESH) == P("model", "data")


def test_scan_stacking_pads_leading_none():
    # scanned models stack a group axis in front: rules are trailing-dim
    assert SH.param_spec("layers/l0/mixer/q/w", 3, MESH) == \
        P(None, "data", "model")
    assert SH.param_spec("layers/l0/mixer/q/mix", 4, MESH) == \
        P(None, None, "model", None)


def test_spm_params_pair_parallel():
    assert SH.param_spec("layers/l0/mlp/up/mix", 3, MESH) == \
        P(None, "model", None)
    assert SH.param_spec("layers/l0/mixer/q/theta", 2, MESH) == \
        P(None, "model")
    assert SH.param_spec("layers/l0/mlp/up/d_in", 1, MESH) == P("model")


def test_expert_axis_gets_model():
    # scanned MoE: (G, E, d_in, d_ff)
    spec = SH.param_spec("layers/l0/mlp/experts/up/w", 4, MESH)
    assert spec == P(None, "model", "data", None)
    # expert SPM coeffs (G, E, L, pairs, 4): pairs must NOT reuse model
    spec = SH.param_spec("layers/l0/mlp/experts/up/mix", 5, MESH)
    assert spec == P(None, "model", None, None, None)


def test_spm_feat_profile_shard_splits_spm_params():
    """spm_feat: SPM stage coeffs split on the pair axis, diagonals/bias on
    the feature axis — the exact blocks parallel/spm_shard.py reads —
    while everything else keeps the spm_dp layout."""
    pf = "spm_feat"
    assert SH.param_spec("layers/l0/mlp/up/mix", 3, MESH, pf) == \
        P(None, "model", None)
    assert SH.param_spec("layers/l0/mixer/q/theta", 2, MESH, pf) == \
        P(None, "model")
    assert SH.param_spec("layers/l0/mlp/up/d_in", 1, MESH, pf) == P("model")
    assert SH.param_spec("layers/l0/mlp/up/bias", 1, MESH, pf) == P("model")
    # scanned stacking axes stay replicated (trailing-dim rules)
    assert SH.param_spec("layers/l0/mlp/up/mix", 4, MESH, pf) == \
        P(None, None, "model", None)
    # expert parallelism still wins for expert-stacked SPM params
    assert SH.param_spec("layers/l0/mlp/experts/up/mix", 5, MESH, pf) == \
        P(None, "model", None, None, None)
    # non-SPM params keep the spm_dp layout
    assert SH.param_spec("embed/table", 2, MESH, pf) == P("model", None)
    assert SH.param_spec("layers/l0/norm1/scale", 1, MESH, pf) == P(None)
    assert SH.param_spec("layers/l0/mixer/q/w", 2, MESH, pf) == P(None, None)


def test_router_replicated_norm_replicated():
    assert SH.param_spec("layers/l0/mlp/router", 2, MESH) == P(None, None)
    assert SH.param_spec("layers/l0/norm1/scale", 1, MESH) == P(None)


def test_data_axes_multi_pod():
    assert SH.data_axes(MESH) == ("data",)
    assert SH.data_axes(MESH3) == ("pod", "data")
    assert SH.batch_spec(MESH3) == P(("pod", "data"))
    assert SH.batch_spec(MESH, seq_sharded=True) == P(None, "data")


def test_param_shardings_cover_whole_tree():
    cfg = get_smoke("qwen3-moe-30b-a3b")
    state = abstract_state(cfg)
    sh = SH.param_shardings(MESH, state["params"])
    n_params = len(jax.tree.leaves(state["params"]))
    n_specs = len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))
    assert n_params == n_specs


def test_cache_specs_scanned_and_seq_sharded():
    cfg = get_smoke("qwen3-1.7b")
    cache = abstract_cache(cfg, 4, 64)
    sh = SH.cache_specs(MESH, cache)
    flat = jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))
    assert all(hasattr(s, "spec") for s in flat)
    # scanned cache: leading group axis replicated, heads on model
    k_sh = sh[jax.tree_util.SequenceKey] if False else None
    sh_seq = SH.cache_specs(MESH, cache, seq_sharded=True)
    specs = [s.spec for s in jax.tree.leaves(
        sh_seq, is_leaf=lambda x: hasattr(x, "spec"))]
    assert any("data" in str(s) for s in specs)


# ---------------------------------------------------------------------------
# launch/specs + hlo analysis units
# ---------------------------------------------------------------------------

def test_input_specs_per_kind():
    cfg = get_smoke("qwen3-1.7b")
    tr = input_specs(cfg, SHAPES["train_4k"])
    assert tr["tokens"].shape == (256, 4096) and "labels" in tr
    pf = input_specs(cfg, SHAPES["prefill_32k"])
    assert pf["tokens"].shape == (32, 32768) and "labels" not in pf
    dc = input_specs(cfg, SHAPES["decode_32k"])
    assert dc["tokens"].shape == (128,) and dc["index"].shape == ()
    vl = get_smoke("qwen2-vl-7b")
    pv = input_specs(vl, SHAPES["prefill_32k"])
    assert pv["embeds"].shape == (32, 32768, vl.d_model)
    assert pv["positions"].shape == (3, 32, 32768)


def test_parse_shape_bytes():
    assert parse_shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert parse_shape_bytes("bf16[2,3]") == 12
    assert parse_shape_bytes("(f32[8], s32[4])") == 32 + 16
    assert parse_shape_bytes("pred[]") == 1


def test_collective_bytes_parsing():
    hlo = """
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={}
  %ag = bf16[64,32]{1,0} all-gather(bf16[8,32]{1,0} %y), dimensions={0}
  %cp = f32[16]{0} collective-permute(f32[16]{0} %z)
  %no = f32[99]{0} add(f32[99]{0} %a, f32[99]{0} %b)
"""
    cb = collective_bytes(hlo)
    assert cb["all-reduce"] == 4096
    assert cb["all-gather"] == 64 * 32 * 2
    assert cb["collective-permute"] == 64
    assert cb["total"] == 4096 + 4096 + 64


def test_roofline_terms_dominance():
    t = roofline_terms(197e12, 0.0, 0.0)        # 1s of pure compute
    assert t["dominant"] == "compute_s"
    assert t["roofline_fraction"] == pytest.approx(1.0)
    t = roofline_terms(1e12, 819e9 * 2, 0.0)    # memory-bound
    assert t["dominant"] == "memory_s"
    assert t["roofline_fraction"] < 0.01
