"""Property + unit tests for the SPM operator (paper §2–§5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (SPMConfig, connectivity_components, init_spm,
                        make_schedule, spm_apply, spm_matrix)
from repro.core.spm import stage_coeffs

KEY = jax.random.PRNGKey(0)


def _cfg(n=16, L=4, variant="general", schedule="butterfly",
         backward="autodiff", **kw):
    return SPMConfig(n=n, n_stages=L, variant=variant, schedule=schedule,
                     backward=backward, **kw)


# ---------------------------------------------------------------------------
# linearity + exactness properties
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([4, 8, 16, 32, 96]),
       variant=st.sampled_from(["general", "rotation"]),
       schedule=st.sampled_from(["butterfly", "random"]))
def test_spm_is_linear(n, variant, schedule):
    """SPM (bias off) is a linear operator: f(ax + by) = a f(x) + b f(y)."""
    cfg = _cfg(n=n, variant=variant, schedule=schedule, use_bias=False)
    p = init_spm(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (n,))
    y = jax.random.normal(jax.random.PRNGKey(2), (n,))
    f = lambda v: spm_apply(p, v, cfg)
    lhs = f(2.5 * x - 1.5 * y)
    rhs = 2.5 * f(x) - 1.5 * f(y)
    np.testing.assert_allclose(lhs, rhs, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([8, 16, 64]),
       variant=st.sampled_from(["general", "rotation"]),
       schedule=st.sampled_from(["butterfly", "random"]))
def test_custom_backward_matches_autodiff(n, variant, schedule):
    """Paper §4 closed forms == reverse-mode AD through the forward."""
    cfg_a = _cfg(n=n, variant=variant, schedule=schedule,
                 backward="autodiff")
    cfg_c = _cfg(n=n, variant=variant, schedule=schedule, backward="custom")
    p = init_spm(KEY, cfg_a)
    x = jax.random.normal(jax.random.PRNGKey(3), (5, n))

    def loss(cfg):
        return lambda p, x: jnp.sum(jnp.sin(spm_apply(p, x, cfg)))

    ga = jax.grad(loss(cfg_a), argnums=(0, 1))(p, x)
    gc = jax.grad(loss(cfg_c), argnums=(0, 1))(p, x)
    for a, c in zip(jax.tree.leaves(ga), jax.tree.leaves(gc)):
        np.testing.assert_allclose(a, c, atol=1e-4)


def test_custom_inverse_matches_autodiff():
    """Reversible backward (O(n) residuals) — rotation variant only."""
    cfg_a = _cfg(n=32, L=6, variant="rotation", backward="autodiff")
    cfg_i = _cfg(n=32, L=6, variant="rotation", backward="custom_inverse")
    p = init_spm(KEY, cfg_a)
    x = jax.random.normal(jax.random.PRNGKey(4), (3, 32))
    f = lambda cfg: (lambda p, x: jnp.sum(spm_apply(p, x, cfg) ** 2))
    ga = jax.grad(f(cfg_a), argnums=(0, 1))(p, x)
    gi = jax.grad(f(cfg_i), argnums=(0, 1))(p, x)
    for a, i in zip(jax.tree.leaves(ga), jax.tree.leaves(gi)):
        np.testing.assert_allclose(a, i, atol=1e-4)


# ---------------------------------------------------------------------------
# orthogonality / norm preservation (paper §3.1, §8.4)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([8, 32, 128]), L=st.integers(1, 8))
def test_rotation_preserves_norm(n, L):
    cfg = _cfg(n=n, L=L, variant="rotation", use_diag=False, use_bias=False)
    p = init_spm(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (7, n))
    y = spm_apply(p, x, cfg)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)


def test_rotation_matrix_is_orthogonal():
    cfg = _cfg(n=16, L=5, variant="rotation", use_diag=False, use_bias=False)
    p = init_spm(KEY, cfg)
    W = spm_matrix(p, cfg)
    np.testing.assert_allclose(W.T @ W, np.eye(16), atol=1e-5)
    # operator norm of the composition == 1 (paper §8.4)
    s = np.linalg.svd(np.asarray(W), compute_uv=False)
    assert abs(s[0] - 1.0) < 1e-5


# ---------------------------------------------------------------------------
# structure: parameters, complexity, connectivity (paper §5, §8.2)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([8, 16, 64, 256]),
       L=st.integers(1, 12),
       variant=st.sampled_from(["general", "rotation"]))
def test_param_count_is_O_nL(n, L, variant):
    cfg = _cfg(n=n, L=L, variant=variant)
    p = init_spm(KEY, cfg)
    actual = sum(x.size for x in jax.tree.leaves(p))
    assert actual == cfg.param_count()
    per_pair = 1 if variant == "rotation" else 4
    assert actual == L * (n // 2) * per_pair + 3 * n   # + diag x2 + bias
    # paper §5: Θ(nL) ≪ Θ(n²) for L < n
    if L < n // 8:
        assert actual < n * n


def test_butterfly_connectivity():
    """log2(n) butterfly stages connect every coordinate pair."""
    for n in (8, 64, 256, 96, 48):
        L = int(np.ceil(np.log2(n)))
        sched = make_schedule("butterfly", n, L)
        assert connectivity_components(sched) == 1, n


def test_spm_matrix_equals_stage_product():
    cfg = _cfg(n=8, L=3, use_diag=True, use_bias=True)
    p = init_spm(KEY, cfg)
    W = spm_matrix(p, cfg)
    # build explicitly: D_out @ B3 @ B2 @ B1 @ D_in
    coeffs = stage_coeffs(p, cfg)
    M = np.diag(np.asarray(p["d_in"]))
    for ell, stage in enumerate(cfg.pairing.stages):
        B = np.zeros((8, 8))
        s = stage.stride
        g = 8 // (2 * s)
        cf = np.asarray(coeffs[ell])
        idx = np.arange(8).reshape(g, 2, s)
        for gi in range(g):
            for si in range(s):
                i0, i1 = idx[gi, 0, si], idx[gi, 1, si]
                a, b, c, d = cf[gi * s + si]
                B[i0, i0], B[i0, i1] = a, b
                B[i1, i0], B[i1, i1] = c, d
        M = B @ M
    M = np.diag(np.asarray(p["d_out"])) @ M
    np.testing.assert_allclose(W, M, atol=1e-5)


def test_odd_n_residual_lane():
    """Paper §5: odd n leaves one coordinate unpaired with a learned 1x1."""
    cfg = _cfg(n=9, L=3, schedule="random")
    p = init_spm(KEY, cfg)
    assert "res_scale" in p and p["res_scale"].shape == (3,)
    x = jax.random.normal(KEY, (4, 9))
    y = spm_apply(p, x, cfg)
    assert y.shape == (4, 9) and bool(jnp.all(jnp.isfinite(y)))


def test_flops_scaling_is_near_linear():
    """O(nL) ops: count jaxpr mul/add ops grows ~linearly in n."""
    def count_ops(n):
        cfg = _cfg(n=n, L=4)
        p = init_spm(KEY, cfg)
        jaxpr = jax.make_jaxpr(lambda x: spm_apply(p, x, cfg))(
            jnp.zeros((1, n)))
        return sum(1 for e in jaxpr.jaxpr.eqns)
    # op-count is schedule-structure dependent but must NOT grow with n
    assert count_ops(512) <= count_ops(64) + 8
