"""Documentation system checks (ISSUE 4 satellite).

Three guarantees, all cheap enough for every CI run:

* **docstring coverage** — every public symbol (``__all__``, else
  non-underscore module attributes) of the public API surface modules has
  a non-empty docstring, as does every public method of public classes
  defined in those modules;
* **README snippets execute** — every ```python fenced block in README.md
  runs top-to-bottom in one shared namespace (doctest-style: the blocks
  are written to be cumulative and assert their own claims);
* **no dead links** — every relative markdown link target in README.md
  and docs/*.md exists on disk (http(s) links are skipped: CI has no
  business depending on the network).
"""

import inspect
import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

API_MODULES = [
    "repro.core.spm",
    "repro.core.linear",
    "repro.core.eligibility",
    "repro.configs.base",
    "repro.parallel",
    "repro.serve.engine",
    "repro.kernels.quant",
    "repro.optim.compression",
]

DOC_FILES = ["README.md"] + sorted(
    os.path.join("docs", f) for f in os.listdir(os.path.join(REPO, "docs"))
    if f.endswith(".md")) if os.path.isdir(os.path.join(REPO, "docs")) \
    else ["README.md"]


@pytest.mark.parametrize("mod_name", API_MODULES)
def test_public_api_has_docstrings(mod_name):
    mod = __import__(mod_name, fromlist=["_"])
    assert inspect.getdoc(mod), f"{mod_name} has no module docstring"
    names = getattr(mod, "__all__", None) or [
        n for n in dir(mod) if not n.startswith("_")]
    missing = []
    for name in names:
        obj = getattr(mod, name)
        if not (inspect.isfunction(obj) or inspect.isclass(obj)):
            continue
        if not inspect.getdoc(obj):
            missing.append(name)
        if inspect.isclass(obj):
            for mname, meth in inspect.getmembers(obj, inspect.isfunction):
                if mname.startswith("_"):
                    continue
                if not inspect.getdoc(meth):
                    missing.append(f"{name}.{mname}")
            for pname, prop in inspect.getmembers(
                    obj, lambda o: isinstance(o, property)):
                if not pname.startswith("_") and not inspect.getdoc(prop):
                    missing.append(f"{name}.{pname} (property)")
    assert not missing, f"{mod_name}: undocumented public symbols {missing}"


def _python_blocks(md_path):
    text = open(md_path).read()
    return re.findall(r"```python\n(.*?)```", text, flags=re.S)


def test_readme_snippets_execute():
    blocks = _python_blocks(os.path.join(REPO, "README.md"))
    assert blocks, "README.md has no ```python snippets"
    ns = {}
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"README.md[python block {i}]", "exec"), ns)
        except Exception as e:   # pragma: no cover - failure path
            raise AssertionError(
                f"README python block {i} failed: {e}\n{block}") from e


def test_markdown_links_resolve():
    link_re = re.compile(r"\[[^\]]*\]\(([^)#\s]+)[^)]*\)")
    dead = []
    for rel in DOC_FILES:
        path = os.path.join(REPO, rel)
        base = os.path.dirname(path)
        for target in link_re.findall(open(path).read()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            cand = (os.path.join(REPO, target) if target.startswith("/")
                    else os.path.join(base, target))
            if not os.path.exists(cand):
                dead.append(f"{rel} -> {target}")
    assert not dead, f"dead markdown links: {dead}"


def test_readme_has_generated_results_table():
    """The results table between the BENCH-TABLE markers is generated from
    BENCH_kernel.json by benchmarks/readme_table.py — assert the markers
    exist and the block between them is non-trivial (regenerating it
    verbatim in CI would couple the test to bench reruns; the generator
    itself is exercised here instead)."""
    import importlib.util
    readme = open(os.path.join(REPO, "README.md")).read()
    start = "<!-- BENCH-TABLE:START (benchmarks/readme_table.py) -->"
    end = "<!-- BENCH-TABLE:END -->"
    assert start in readme and end in readme
    block = readme.split(start, 1)[1].split(end, 1)[0]
    assert block.count("|") > 20, "results table looks empty"
    spec = importlib.util.spec_from_file_location(
        "readme_table", os.path.join(REPO, "benchmarks", "readme_table.py"))
    rt = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(rt)
    import json
    with open(os.path.join(REPO, "BENCH_kernel.json")) as f:
        rendered = rt.render(json.load(f))
    for needle in ("reduction", "permute bytes", "| n |"):
        assert needle in rendered
