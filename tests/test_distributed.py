"""Multi-device parity harness for the distributed two_level SPM executor.

conftest.py forbids setting ``--xla_force_host_platform_device_count``
globally (smoke tests and benches must see exactly 1 device), so the
multi-device tests run OUT OF PROCESS: the single parent-side test re-execs
pytest on this very file in a subprocess whose ``XLA_FLAGS`` force 8 host
devices (and whose env marks it as the worker); the worker-side tests —
guarded by that env var — then collect and the parent asserts the child
suite passed, forwarding its output on failure.

Worker coverage (ISSUE 3 + ISSUE 4 acceptance):
  * sharded ``spm_apply`` == unsharded reference, forward AND grads
    (params + input), f32 and bf16, on 2/4/8-way meshes;
  * even and odd-factor n, rectangular in/out widths, use_diag/use_bias
    on and off, both SPM variants, the fused-kernel path inside shard_map
    (interpret mode), and a multi-axis ("data", "model") mesh;
  * the kernel-native boundaries: diag/bias folded into the boundary
    kernel runs (cases whose schedule ends on a local step fold BOTH
    sides) and rectangular widths served by windowed (col_base) kernel
    reads, including jaxpr acceptance (no pad, no unfused diag/bias
    elementwise ops in the shard body, a single local output slice) and
    HLO acceptance for the rectangular case;
  * HLO acceptance: the lowered sharded module contains collective-permute
    and NO all-gather / all-reduce of the feature axis (the backward's one
    all-gather is the O(nL) replicated coefficient-grad assembly, bounded
    by parameter bytes).

The schedule-planning tests at the top are device-free and run in both the
parent and the worker.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

WORKER_ENV = "SPM_DISTRIBUTED_WORKER"
N_DEV = 8
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _in_worker() -> bool:
    return os.environ.get(WORKER_ENV) == "1"


# ---------------------------------------------------------------------------
# device-free planning units (both processes)
# ---------------------------------------------------------------------------

def test_plan_steps_groups_local_runs_and_tags_crosses():
    from repro.core.pairings import two_level_schedule
    from repro.parallel.spm_shard import plan_steps

    strides = two_level_schedule(64, 8, 4).strides()   # n_local = 16
    steps = plan_steps(64, strides, 4)
    kinds = [s[0] for s in steps]
    assert kinds == ["local", "cross", "cross", "local"], steps
    assert steps[0][2] == (1, 2, 4, 8)        # one fused run of locals
    assert steps[1][2] == 1 and steps[2][2] == 2   # k of s=16, s=32
    # stage bookkeeping: local offset + run length meets the next cross
    assert steps[0][1] == 0 and steps[1][1] == 4 and steps[2][1] == 5
    with pytest.raises(ValueError):
        plan_steps(64, (3,), 4)               # 64 % 6 != 0: invalid stage
    with pytest.raises(ValueError):
        plan_steps(48, (8,), 8)               # straddles n_local=6 blocks


def test_sharded_eligible_rules():
    from repro.core.spm import SPMConfig
    from repro.parallel.spm_shard import sharded_eligible

    ok = SPMConfig(n=64, n_stages=6, schedule="two_level", n_shards=4)
    assert sharded_eligible(ok)
    assert not sharded_eligible(
        SPMConfig(n=64, n_stages=6, schedule="two_level", n_shards=1))
    assert not sharded_eligible(          # odd n_local=3: stride-1 fallback
        SPMConfig(n=24, n_stages=4, schedule="two_level", n_shards=8))
    assert not sharded_eligible(          # reversible backward stores outputs
        SPMConfig(n=64, n_stages=6, schedule="two_level", n_shards=4,
                  variant="rotation", backward="custom_inverse"))
    assert not sharded_eligible(          # permutation pairings
        SPMConfig(n=64, n_stages=4, schedule="random", n_shards=4))


def test_rdma_pair_plan_and_placeholder_residuals():
    """Device-free structure of the TPU RDMA dispatch: a {local -> cross}
    pair whose local run plans to one kernel run is marked as an RDMA
    cross, and its saved stage input becomes a replicated placeholder
    spec (the backward kernel remats it in VMEM) — the rest of the
    residual layout is untouched."""
    from jax.sharding import Mesh, PartitionSpec as P

    import jax
    from repro.core.pairings import two_level_schedule
    from repro.parallel.spm_shard import (ShardPlan, _rdma_cross_indices,
                                          plan_steps)

    steps = plan_steps(64, two_level_schedule(64, 8, 4).strides(), 4)
    assert [s[0] for s in steps] == ["local", "cross", "cross", "local"]
    # the paired cross (idx 1) is RDMA-able; the unpaired one (idx 2) not
    assert _rdma_cross_indices(steps, 16) == (1,)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("model",))
    plan = ShardPlan(mesh=mesh, n=64, n_local=16, n_shards=4, steps=steps,
                     has_din=True, has_dout=True, has_bias=True,
                     use_kernel=True, block_rows=8, interpret=False,
                     row_blocks=(8, 8), rdma_crosses=(1,))
    assert plan.overlap
    assert [s[0] for s in plan.segments] == ["pair", "one", "one"]
    _, step_ins, _ = plan.res_specs()
    assert step_ins[1] == P(None)            # RDMA cross: placeholder
    assert step_ins[0] != P(None) and step_ins[2] != P(None)
    serial = ShardPlan(mesh=mesh, n=64, n_local=16, n_shards=4,
                       steps=steps, has_din=True, has_dout=True,
                       has_bias=True, use_kernel=True, block_rows=8,
                       interpret=False)
    assert not serial.overlap
    assert serial.res_specs()[1][1] != P(None)


# ---------------------------------------------------------------------------
# parent: re-exec this file under forced device count
# ---------------------------------------------------------------------------

if not _in_worker():

    def test_distributed_suite_in_subprocess():
        env = dict(os.environ)
        env[WORKER_ENV] = "1"
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count="
                              f"{N_DEV}")
        env["PYTHONPATH"] = (os.path.join(REPO, "src") + os.pathsep
                             + env.get("PYTHONPATH", ""))
        r = subprocess.run(
            [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
             os.path.abspath(__file__)],
            capture_output=True, text=True, timeout=1500, cwd=REPO, env=env)
        assert r.returncode == 0, (
            f"multi-device worker suite failed (rc={r.returncode}):\n"
            f"--- stdout ---\n{r.stdout[-6000:]}\n"
            f"--- stderr ---\n{r.stderr[-2000:]}")
        assert "passed" in r.stdout


# ---------------------------------------------------------------------------
# worker: the actual multi-device tests
# ---------------------------------------------------------------------------

else:
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh

    from repro.analysis.hlo_match import (assert_bwd_gather_bounded,
                                          assert_permute_only)
    from repro.core.spm import SPMConfig, init_spm, spm_apply
    from repro.launch.hlo_analysis import collective_bytes
    from repro.parallel import spm_shard
    from repro.parallel.ctx import activation_sharding, feature_mesh

    KEY = jax.random.PRNGKey(0)

    def _mesh(shards: int) -> Mesh:
        return Mesh(np.asarray(jax.devices()[:shards]).reshape(shards),
                    ("model",))

    def test_worker_sees_forced_devices():
        assert jax.device_count() == N_DEV

    CASES = [
        # (id, n, shards, L, dtype, diag, bias, kernel, variant, in_w, out_w)
        ("pow2_2way", 64, 2, 6, "f32", True, True, False, "general",
         None, None),
        ("pow2_4way", 64, 4, 8, "f32", True, True, False, "general",
         None, None),
        ("pow2_8way", 64, 8, 7, "f32", True, True, False, "general",
         None, None),
        ("oddfactor_n96", 96, 4, 8, "f32", True, True, False, "general",
         None, None),
        ("oddfactor_local48", 48, 4, 6, "f32", True, True, False, "general",
         None, None),
        ("no_diag_no_bias", 64, 4, 8, "f32", False, False, False, "general",
         None, None),
        ("rect_narrowing", 64, 4, 8, "f32", True, True, False, "general",
         50, 40),
        ("rect_widening", 64, 4, 8, "f32", True, True, False, "general",
         40, 60),
        ("rotation_variant", 64, 4, 6, "f32", True, True, False, "rotation",
         None, None),
        ("fused_kernel_runs", 64, 4, 6, "f32", True, True, True, "general",
         None, None),
        # L=7 on n=64/4 shards ends the cycle on a local step, so BOTH
        # boundaries fold into kernel runs (d_in into the first, d_out/bias
        # into the last) and rectangular widths use the windowed
        # (col_base) kernel reads on both sides.
        ("fused_fold_both", 64, 4, 7, "f32", True, True, True, "general",
         None, None),
        ("fused_rect", 64, 4, 7, "f32", True, True, True, "general",
         50, 40),
        ("fused_rect_widen", 64, 4, 7, "f32", True, True, True, "general",
         40, 60),
        ("fused_rect_bf16", 64, 4, 7, "bf16", True, True, True, "general",
         50, 40),
        ("fused_no_diag_bias", 64, 4, 7, "f32", False, False, True,
         "general", None, None),
        ("fused_8way_rect", 64, 8, 9, "f32", True, True, True, "general",
         50, 40),
        ("fused_rotation_fold", 64, 4, 7, "f32", True, True, True,
         "rotation", None, None),
        ("bf16", 64, 4, 8, "bf16", True, True, False, "general",
         None, None),
        ("bf16_rect", 64, 4, 6, "bf16", True, True, False, "general",
         50, 40),
    ]

    @pytest.mark.parametrize(
        "case", CASES, ids=[c[0] for c in CASES])
    def test_sharded_matches_unsharded_fwd_and_grads(case):
        (_, n, shards, L, dt, diag, bias, kernel, variant,
         in_w, out_w) = case
        dtype = jnp.bfloat16 if dt == "bf16" else jnp.float32
        f_tol = dict(atol=5e-2, rtol=5e-2) if dt == "bf16" else \
            dict(atol=2e-5, rtol=2e-5)
        g_tol = dict(atol=2e-1, rtol=2e-1) if dt == "bf16" else \
            dict(atol=2e-4, rtol=2e-4)

        def cfg_for(use_kernel):
            return SPMConfig(
                n=n, n_stages=L, variant=variant, schedule="two_level",
                n_shards=shards, use_diag=diag, use_bias=bias,
                backward="custom", use_kernel=use_kernel)

        cfg = cfg_for(kernel)
        ref_cfg = cfg_for(False)
        p = init_spm(KEY, cfg)
        d_in = in_w if in_w is not None else n
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, d_in))
        x = x.astype(dtype)
        kw = dict(in_width=in_w, out_width=out_w)

        def ref_loss(p, x):
            y = spm_apply(p, x, ref_cfg, **kw)
            return jnp.sum(y.astype(jnp.float32) ** 2)

        y_ref = jax.jit(lambda p, x: spm_apply(p, x, ref_cfg, **kw))(p, x)
        g_ref = jax.jit(jax.grad(ref_loss, argnums=(0, 1)))(p, x)

        mesh = _mesh(shards)
        with activation_sharding(mesh, shard_feature=True):
            assert feature_mesh(shards) is mesh      # ctx is live
            assert spm_shard.sharded_eligible(cfg)   # and the case routes

            def sh_loss(p, x):
                y = spm_apply(p, x, cfg, **kw)
                return jnp.sum(y.astype(jnp.float32) ** 2)

            y = jax.jit(lambda p, x: spm_apply(p, x, cfg, **kw))(p, x)
            g = jax.jit(jax.grad(sh_loss, argnums=(0, 1)))(p, x)

        out_d = out_w if out_w is not None else n
        assert y.shape == (2, 3, out_d) and y.dtype == dtype
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(y_ref, np.float32), **f_tol)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                **g_tol),
            g[0], g_ref[0])
        np.testing.assert_allclose(np.asarray(g[1], np.float32),
                                   np.asarray(g_ref[1], np.float32), **g_tol)

    def test_parity_on_multi_axis_mesh_with_batch_sharded_input():
        """The production meshes carry ("data", "model") with activations
        batch-sharded over "data": rows must co-shard into the executor
        (NO batch all-gather) and parameter grads must psum over the DP
        axes only — fwd and grads still match the unsharded reference."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = SPMConfig(n=64, n_stages=6, schedule="two_level", n_shards=4,
                        backward="custom", use_kernel=False)
        p = init_spm(KEY, cfg)
        x = jax.random.normal(KEY, (8, 64))

        def loss(p, x):
            return jnp.sum(spm_apply(p, x, cfg) ** 2)

        y_ref = spm_apply(p, x, cfg)
        g_ref = jax.jit(jax.grad(loss, argnums=(0, 1)))(p, x)

        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4),
                    ("data", "model"))
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
        with activation_sharding(mesh, shard_feature=True):
            fwd = jax.jit(lambda p, x: spm_apply(p, x, cfg))
            y = fwd(p, xs)
            # batch enters sharded: permute-only, no all-gather/all-reduce
            assert_permute_only(fwd.lower(p, xs).compile().as_text())
            bwd = jax.jit(jax.grad(loss, argnums=(0, 1)))
            g = bwd(p, xs)
            # backward communicates parameter-sized grads only: the table
            # assembly all-gather + the DP psum — never activations
            param_bytes = (cfg.n_stages * (cfg.n // 2) * 4 + 3 * cfg.n) * 4
            assert_permute_only(bwd.lower(p, xs).compile().as_text(),
                                require_permute=False,
                                allow={"all-gather": 2 * param_bytes,
                                       "all-reduce": 2 * param_bytes})
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=2e-5, rtol=2e-5)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=2e-4, rtol=2e-4),
            g[0], g_ref[0])
        np.testing.assert_allclose(np.asarray(g[1]), np.asarray(g_ref[1]),
                                   atol=2e-4, rtol=2e-4)

    def test_no_route_without_context_or_on_mismatched_mesh():
        """Outside a feature-sharding block (or with the wrong model-axis
        size) the operator must keep its unsharded semantics."""
        cfg = SPMConfig(n=64, n_stages=6, schedule="two_level", n_shards=4,
                        backward="custom", use_kernel=False)
        p = init_spm(KEY, cfg)
        x = jax.random.normal(KEY, (4, 64))
        y_ref = spm_apply(p, x, cfg)            # no context at all
        assert feature_mesh(4) is None
        with activation_sharding(_mesh(8), shard_feature=True):
            assert feature_mesh(4) is None       # 8-way mesh, 4-shard op
            y = spm_apply(p, x, cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=0, rtol=0)

    def test_hlo_collective_permute_only_on_feature_axis():
        """ISSUE 3 acceptance: the compiled sharded path communicates via
        collective-permute; the feature axis is never all-gathered or
        all-reduced.  Backward may all-gather the O(nL) coefficient-grad
        tables (replicated-param assembly) — bounded by parameter bytes,
        strictly below the smallest activation buffer."""
        cfg = SPMConfig(n=64, n_stages=8, schedule="two_level", n_shards=8,
                        backward="custom", use_kernel=False)
        p = init_spm(KEY, cfg)
        rows = 128
        x = jax.random.normal(KEY, (rows, 64))
        mesh = _mesh(8)
        with activation_sharding(mesh, shard_feature=True):
            fwd = jax.jit(lambda p, x: spm_apply(p, x, cfg))
            assert_permute_only(fwd.lower(p, x).compile().as_text())

            bwd = jax.jit(jax.grad(
                lambda p, x: jnp.sum(spm_apply(p, x, cfg) ** 2),
                argnums=(0, 1)))
            param_bytes = cfg.n_stages * (cfg.n // 2) * 4 * 4
            act_bytes = rows * cfg.n * 4
            assert 2 * param_bytes < act_bytes     # the bound is meaningful
            # permute-only with the one bounded all-gather budget also
            # asserts the permute actually exists in the backward module
            assert_permute_only(bwd.lower(p, x).compile().as_text(),
                                allow={"all-gather": 2 * param_bytes})

    # -- overlap-scheduled executor (ISSUE 5) -------------------------------

    OVERLAP_CASES = [
        # (id, n, shards, L, dtype, diag, bias, kernel, in_w, out_w)
        ("ov_2way", 64, 2, 6, "f32", True, True, False, None, None),
        ("ov_4way", 64, 4, 8, "f32", True, True, False, None, None),
        ("ov_8way", 64, 8, 9, "f32", True, True, False, None, None),
        ("ov_kernel", 64, 4, 7, "f32", True, True, True, None, None),
        ("ov_kernel_8way", 64, 8, 9, "f32", True, True, True, None, None),
        ("ov_no_diag_bias", 64, 4, 8, "f32", False, False, True,
         None, None),
        ("ov_rect", 64, 4, 7, "f32", True, True, True, 50, 40),
        ("ov_rect_widen", 64, 4, 7, "f32", True, True, True, 40, 60),
        ("ov_bf16", 64, 4, 8, "bf16", True, True, False, None, None),
        ("ov_bf16_kernel_rect", 64, 4, 7, "bf16", True, True, True,
         50, 40),
    ]

    @pytest.mark.parametrize(
        "case", OVERLAP_CASES, ids=[c[0] for c in OVERLAP_CASES])
    def test_overlap_matches_serial_and_unsharded(case):
        """ISSUE 5 acceptance: the overlap-scheduled executor (row-block
        pipelined cross-shard exchanges; per-block ppermute transport in
        interpret mode — the same schedule code the TPU RDMA path runs)
        matches BOTH the step-serial sharded executor and the unsharded
        reference, forward and grads, with the row-block pipeline actually
        engaged (> 1 block)."""
        from repro.core.eligibility import resolve_overlap
        _, n, shards, L, dt, diag, bias, kernel, in_w, out_w = case
        dtype = jnp.bfloat16 if dt == "bf16" else jnp.float32
        f_tol = dict(atol=5e-2, rtol=5e-2) if dt == "bf16" else \
            dict(atol=2e-5, rtol=2e-5)
        g_tol = dict(atol=2e-1, rtol=2e-1) if dt == "bf16" else \
            dict(atol=2e-4, rtol=2e-4)

        def cfg_for(overlap, use_kernel=kernel):
            return SPMConfig(
                n=n, n_stages=L, schedule="two_level", n_shards=shards,
                use_diag=diag, use_bias=bias, backward="custom",
                use_kernel=use_kernel, overlap=overlap)

        cfg_ov, cfg_ser = cfg_for(True), cfg_for(False)
        ref_cfg = cfg_for(False, use_kernel=False)
        steps = spm_shard.plan_steps(n, cfg_ov.pairing.strides(), shards)
        assert resolve_overlap(cfg_ov, steps, False)       # forced on CPU
        assert not resolve_overlap(cfg_ser, steps, False)
        p = init_spm(KEY, cfg_ov)
        d_in = in_w if in_w is not None else n
        # rows sized so the kernel path yields > 1 row block per shard
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 40, d_in))
        x = x.astype(dtype)
        kw = dict(in_width=in_w, out_width=out_w)

        def loss(cfg):
            return lambda p, x: jnp.sum(
                spm_apply(p, x, cfg, **kw).astype(jnp.float32) ** 2)

        y_ref = jax.jit(lambda p, x: spm_apply(p, x, ref_cfg, **kw))(p, x)
        g_ref = jax.jit(jax.grad(loss(ref_cfg), argnums=(0, 1)))(p, x)
        mesh = _mesh(shards)
        with activation_sharding(mesh, shard_feature=True):
            y_ov = jax.jit(
                lambda p, x: spm_apply(p, x, cfg_ov, **kw))(p, x)
            y_ser = jax.jit(
                lambda p, x: spm_apply(p, x, cfg_ser, **kw))(p, x)
            g_ov = jax.jit(jax.grad(loss(cfg_ov), argnums=(0, 1)))(p, x)
            g_ser = jax.jit(jax.grad(loss(cfg_ser), argnums=(0, 1)))(p, x)

        out_d = out_w if out_w is not None else n
        assert y_ov.shape == (4, 40, out_d) and y_ov.dtype == dtype
        # overlap vs serial is the sharp claim: identical math, re-blocked
        # rows — in f32 the parameter grads agree to reordering noise.  In
        # bf16 the XLA fallback batch-sums in bf16, so re-blocking changes
        # the accumulation grouping itself (the overlap grouping is the
        # more accurate one: shorter bf16 chains combined in f32) and the
        # comparison needs the same cancellation-aware tolerance as the
        # reference
        ser_g_tol = (dict(atol=1e-3, rtol=1e-3) if dt == "f32"
                     else dict(atol=1.0, rtol=2e-1))
        np.testing.assert_allclose(np.asarray(y_ov, np.float32),
                                   np.asarray(y_ser, np.float32), **f_tol)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                **ser_g_tol),
            g_ov, g_ser)
        # vs the unsharded reference the bf16 tolerance must absorb
        # near-cancellation residue: the XLA reference accumulates in bf16
        # over 160 rows (per-term epsilon ~0.008 of grads ~O(10^2)), so
        # near-zero elements keep an O(1) absolute residue the kernel's
        # f32 accumulation does not reproduce
        if dt == "bf16":
            g_tol["atol"] = 1.0
        np.testing.assert_allclose(np.asarray(y_ov, np.float32),
                                   np.asarray(y_ref, np.float32), **f_tol)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                **g_tol),
            g_ov, g_ref)

    def test_overlap_pipeline_actually_blocks_the_rows():
        """The engaged plan must pipeline > 1 row block (the schedule
        degenerates to step-serial at 1), and the per-block exchanges must
        leave the HLO collective-permute-only with the TOTAL permute bytes
        unchanged — re-blocking splits each stage's exchange, it never
        duplicates or re-routes bytes."""
        from repro.launch.hlo_analysis import sharded_stage_traffic
        from repro.parallel.spm_shard import pick_row_blocks
        cfg = SPMConfig(n=64, n_stages=8, schedule="two_level", n_shards=8,
                        backward="custom", use_kernel=False, overlap=True,
                        use_diag=False, use_bias=False)
        p = init_spm(KEY, cfg)
        rows = 16
        x = jax.random.normal(KEY, (rows, 64))
        assert len(pick_row_blocks(rows, 1)) > 1
        steps = spm_shard.plan_steps(64, cfg.pairing.strides(), 8)
        model = sharded_stage_traffic(64 // 8, rows, steps, dtype_bytes=4,
                                      overlap=True)
        with activation_sharding(_mesh(8), shard_feature=True):
            fwd = jax.jit(lambda p, x: spm_apply(p, x, cfg))
            hlo = fwd.lower(p, x).compile().as_text()
        assert_permute_only(hlo)
        cb = collective_bytes(hlo)
        assert cb["collective-permute"] == model["permute_bytes_per_chip"]
        # the model's books balance and the overlap split is non-trivial
        assert (model["exposed_permute_bytes_per_chip"]
                + model["hidden_permute_bytes_per_chip"]
                == model["permute_bytes_per_chip"])
        assert model["hidden_permute_bytes_per_chip"] > 0

    def test_permute_traffic_matches_model():
        """The HLO's collective-permute bytes equal the modeled per-stage
        slab exchanges (hlo_analysis.sharded_stage_traffic)."""
        from repro.launch.hlo_analysis import sharded_stage_traffic
        cfg = SPMConfig(n=64, n_stages=8, schedule="two_level", n_shards=8,
                        backward="custom", use_kernel=False,
                        use_diag=False, use_bias=False)
        p = init_spm(KEY, cfg)
        rows = 16
        x = jax.random.normal(KEY, (rows, 64))
        steps = spm_shard.plan_steps(64, cfg.pairing.strides(), 8)
        model = sharded_stage_traffic(64 // 8, rows, steps, dtype_bytes=4)
        with activation_sharding(_mesh(8), shard_feature=True):
            fwd = jax.jit(lambda p, x: spm_apply(p, x, cfg))
            cb = collective_bytes(fwd.lower(p, x).compile().as_text())
        assert cb["collective-permute"] == model["permute_bytes_per_chip"]

    # -- kernel-native boundary acceptance (ISSUE 4) ------------------------

    # eqn traversal lives in the shared analysis library now; the old
    # inline ``_walk_eqns`` helper became jaxpr_walk.split_shard_map.
    from repro.analysis.jaxpr_walk import (activation_pads,
                                           feature_axis_slices,
                                           split_shard_map)

    def test_shard_body_has_no_unfused_diag_bias_or_window_ops():
        """ISSUE 4 acceptance (fold + windowed reads): on an all-local
        schedule with diag + bias and rectangular widths, the shard body
        is kernel-native — no elementwise diag/bias mul/add on the slab,
        no pad/slice/gather of activations: every boundary op lives inside
        the Pallas kernel runs."""
        cfg = SPMConfig(n=64, n_stages=4, schedule="two_level", n_shards=4,
                        backward="custom", use_kernel=True)
        p = init_spm(KEY, cfg)
        rows = 8                       # multiple of block_rows: no row pad
        x = jax.random.normal(KEY, (rows, 50))
        with activation_sharding(_mesh(4), shard_feature=True):
            steps = spm_shard.plan_steps(64, cfg.pairing.strides(), 4)
            assert all(s[0] == "local" for s in steps)
            jx = jax.make_jaxpr(lambda p, x: spm_apply(
                p, x, cfg, in_width=50, out_width=40))(p, x)
        inside, outside = split_shard_map(jx.jaxpr)
        slab_rows = rows               # no DP axes: full rows per shard
        for e in inside:
            out_shapes = [v.aval.shape for v in e.outvars]
            slabby = any(len(s) == 2 and s[0] == slab_rows
                         for s in out_shapes)
            assert not (slabby and e.primitive.name in
                        ("mul", "add", "sub", "select_n", "pad", "gather",
                         "dynamic_slice")), \
                f"unfused slab op in shard body: {e.primitive.name}"
            if e.primitive.name == "slice":
                assert not any(len(s) == 2 and s[0] == slab_rows
                               for s in out_shapes), "slab slice in body"

    def test_cross_ending_schedule_folds_boundary_into_mix_epilogue():
        """PR 5 leftover closed: a schedule ENDING on cross stages folds
        d_out/bias onto the final mix epilogue's store instead of a
        separate post-walk pass.  The fold is scale-ON-STORE (d_out
        multiplies the mixed result AFTER the add) so it stays bitwise the
        unfolded op — elastic re-sharding classifies the same pinned
        stage local on a wider mesh and the two paths must agree.  Pinned
        structurally: the shard body's slab-shaped ops are EXACTLY the
        two-sided mix per cross stage (four muls, two adds, one role
        select — the order-preserving form _cross_mix documents) plus the
        ONE store-scale d_out mul and the single bias ride-along add on
        the last; no second d_out broadcast and no other elementwise op
        touches the slab."""
        from collections import Counter
        for use_bias in (True, False):
            cfg = SPMConfig(n=64, n_stages=6, schedule="two_level",
                            n_shards=4, backward="custom", use_kernel=True,
                            use_bias=use_bias)
            p = init_spm(KEY, cfg)
            rows = 8
            x = jax.random.normal(KEY, (rows, 64))
            steps = spm_shard.plan_steps(64, cfg.pairing.strides(), 4)
            assert steps[-1][0] == "cross"   # the premise of the test
            n_cross = sum(1 for s in steps if s[0] == "cross")
            with activation_sharding(_mesh(4), shard_feature=True):
                jx = jax.make_jaxpr(lambda p, x: spm_apply(p, x, cfg))(p, x)
            inside, _ = split_shard_map(jx.jaxpr)
            slab = Counter()
            for e in inside:
                if any(len(v.aval.shape) == 2 and v.aval.shape[0] == rows
                       for v in e.outvars):
                    slab[e.primitive.name] += 1
            assert slab["mul"] == 4 * n_cross + 1, dict(slab)
            assert slab["add"] == 2 * n_cross + int(use_bias), dict(slab)
            assert slab["select_n"] == n_cross, dict(slab)
            for prim in ("sub", "pad", "gather", "dynamic_slice"):
                assert slab[prim] == 0, dict(slab)

    def test_sharded_rect_no_pad_single_output_slice():
        """ISSUE 4 acceptance (rectangular widths): the sharded
        rectangular forward contains NO pad primitive and no
        activation-shaped gather; the only feature-axis slice is the final
        (rows, n) -> (rows, out_width) output extraction (one local
        per-shard op — shard_map outputs must be evenly sharded).  The
        backward's only activation-shaped pad is the even-slab cotangent
        transport (rows, out_width) -> (rows, n) — the slice's exact
        transpose, local and fused into the slab reshard (its other pads
        assemble the O(nL) coefficient tables)."""
        n, in_w, out_w, rows = 64, 50, 40, 8
        cfg = SPMConfig(n=n, n_stages=7, schedule="two_level", n_shards=4,
                        backward="custom", use_kernel=True)
        p = init_spm(KEY, cfg)
        x = jax.random.normal(KEY, (rows, in_w))
        kw = dict(in_width=in_w, out_width=out_w)
        with activation_sharding(_mesh(4), shard_feature=True):
            jxf = jax.make_jaxpr(lambda p, x: spm_apply(p, x, cfg, **kw))(
                p, x)
            jxb = jax.make_jaxpr(jax.grad(
                lambda p, x: jnp.sum(spm_apply(p, x, cfg, **kw) ** 2),
                argnums=(0, 1)))(p, x)
        inside, outside = split_shard_map(jxf.jaxpr)
        all_fwd = inside + outside
        assert not any(e.primitive.name == "pad" for e in all_fwd), \
            "XLA pad survived in the sharded rectangular forward"
        for e in all_fwd:
            if e.primitive.name == "gather":
                assert not (len(e.outvars[0].aval.shape) == 2
                            and e.outvars[0].aval.shape[0] == rows), \
                    "activation gather on the kernel path"
        feat_slices = feature_axis_slices(jxf.jaxpr, rows=rows)
        assert feat_slices == [((rows, n), (rows, out_w))], feat_slices
        act_pads = activation_pads(jxb.jaxpr, rows=rows)
        assert act_pads == [((rows, out_w), (rows, n))], act_pads

    def test_sharded_rect_hlo_collectives_bounded():
        """ISSUE 4 acceptance (HLO): the compiled rectangular sharded path
        communicates via collective-permute; no all-gather/all-reduce in
        the forward, and the backward's all-gather stays bounded by the
        O(nL) replicated-parameter grad assembly PLUS the one inherent
        jit-boundary replication of the indivisible-width g_x output.
        rows is chosen large enough that every activation buffer exceeds
        the parameter bound (same meaningfulness guard as the square HLO
        test), so a batch-scaled cotangent gather cannot hide under it —
        excluding exactly the regression a replicated windowed-gy read
        would introduce (the even-slab cotangent transport avoids it)."""
        n, in_w, out_w, rows = 64, 50, 40, 64
        cfg = SPMConfig(n=n, n_stages=7, schedule="two_level", n_shards=4,
                        backward="custom", use_kernel=True)
        p = init_spm(KEY, cfg)
        x = jax.random.normal(KEY, (rows, in_w))
        kw = dict(in_width=in_w, out_width=out_w)
        with activation_sharding(_mesh(4), shard_feature=True):
            fwd = jax.jit(lambda p, x: spm_apply(p, x, cfg, **kw))
            hlo_f = fwd.lower(p, x).compile().as_text()
            bwd = jax.jit(jax.grad(
                lambda p, x: jnp.sum(spm_apply(p, x, cfg, **kw) ** 2),
                argnums=(0, 1)))
            hlo_b = bwd.lower(p, x).compile().as_text()
        assert_permute_only(hlo_f)
        param_bytes = (cfg.n_stages * (cfg.n // 2) * 4 + 3 * cfg.n) * 4
        act_bytes = rows * out_w * 4   # the smallest activation buffer
        assert 2 * param_bytes < act_bytes   # the bound is meaningful
        # The one allowed activation-sized backward gather: replicating
        # the (rows, in_width) input cotangent at the jit boundary — a
        # width-50 array has no expressible even "model" sharding, so ANY
        # transport design pays it when g_x leaves the jit (shard width
        # rounds 50 up to 4*ceil(50/4) lanes).  The bound stays strictly
        # below what a windowed-gy replication would add on top
        # (+ rows*out_w*4), which is the regression this test excludes.
        gx_gather = rows * (-(-in_w // 4) * 4) * 4
        assert_bwd_gather_bounded(hlo_b, param_bytes=param_bytes,
                                  extra_gather_bytes=gx_gather)

    def test_psum_compressed_under_shard_map():
        """The int8 gradient all-reduce under a REAL shard_map pod axis
        (8 forced host devices): every member quantizes against the
        axis-max scale (pmax), the int8 payloads psum in int32, and each
        member dequantizes to the identical replicated result — matching
        the explicit host-side int8-sum reference."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.optim.compression import _amax_scale, psum_compressed

        mesh = Mesh(np.asarray(jax.devices()).reshape(N_DEV), ("pod",))
        # wildly different per-member magnitudes: local-scale quantization
        # would disagree on the dequant grid across members
        g = jnp.stack([(2.0 if i % 2 else 0.01) *
                       jax.random.normal(jax.random.fold_in(KEY, i), (64,))
                       for i in range(N_DEV)])
        f = jax.jit(shard_map(
            lambda gi: psum_compressed({"w": gi[0]}, "pod")["w"][None],
            mesh=mesh, in_specs=P("pod"), out_specs=P("pod")))
        out = np.asarray(f(g))
        s_max = float(max(_amax_scale(g[i]) for i in range(N_DEV)))
        q = np.clip(np.round(np.asarray(g, np.float64) / s_max), -127, 127)
        ref = q.sum(axis=0) * s_max
        for i in range(N_DEV):
            np.testing.assert_allclose(out[i], ref, rtol=1e-5, atol=1e-6)

    # -----------------------------------------------------------------
    # quantized parity (test-pyramid layer 3): int8 coefficient tables
    # under the sharded executor, serial and overlap, vs the f32
    # unsharded reference — tolerance derived from the per-stage scale
    # bound, not a magic constant
    # -----------------------------------------------------------------

    def _coeff_quant_bound_l2(x, p, cfg):
        """Worst-case L2 output perturbation from per-stage int8
        coefficient quantization — derived, and TIGHT enough to stay well
        below the signal (the elementwise row-sum bound is not: near-
        rotation stages cost ~sqrt(2) each there vs ~1 spectrally).

        A stage is block-diagonal 2x2s, so its spectral norm is the max
        pair singular value sigma_l (computed exactly); its quantization
        perturbs each entry by <= amax_l/254, a block-diagonal Delta with
        spectral norm <= 2*amax_l/254 = amax_l/127.  Routing stage l's
        perturbation through prefix amplitude and suffix gain:

            ||Delta y||_2 <= sum_l (G2 / sigma_l) * (amax_l/127) * ||x||_2

        with G2 = max|d_in| * max|d_out| * prod_l sigma_l, plus a factor
        2 of f32-accumulation headroom."""
        from repro.core.spm import stage_coeffs
        cf = stage_coeffs(p, cfg)
        a, b, c, d = cf[..., 0], cf[..., 1], cf[..., 2], cf[..., 3]
        e = a * a + b * b + c * c + d * d
        det = a * d - b * c
        sig = jnp.sqrt(
            (e + jnp.sqrt(jnp.maximum(e * e - 4 * det * det, 0.0))) / 2)
        sig_l = jnp.max(sig, axis=-1)                     # (L,)
        amax_l = jnp.max(jnp.abs(cf), axis=(1, 2))        # quant grids
        g2 = jnp.prod(sig_l)
        for diag in ("d_in", "d_out"):
            if diag in p:
                g2 = g2 * jnp.max(jnp.abs(p[diag]))
        per_stage = (g2 / sig_l) * (amax_l / 127.0)
        return 2.0 * float(jnp.sum(per_stage)) * \
            float(jnp.linalg.norm(x.astype(jnp.float32)))

    QUANT_SHARD_CASES = [
        # (shards, overlap)
        (2, False), (4, False), (8, False), (4, True), (8, True),
    ]

    @pytest.mark.parametrize(
        "shards,overlap", QUANT_SHARD_CASES,
        ids=[f"{s}way_{'overlap' if o else 'serial'}"
             for s, o in QUANT_SHARD_CASES])
    def test_sharded_quant_coeffs_parity(shards, overlap):
        """quant_coeffs=True through the sharded kernel executor (serial
        and row-block-overlapped) vs the unsharded f32 XLA reference,
        within the derived per-stage scale bound.  Note the sharded path
        quantizes each shard's LOCAL coefficient slab per stage (its own
        amax) while the fused single-device path uses the whole table's
        per-stage amax — so quantized paths are each compared against the
        f32 reference, never bitwise against each other.  Overlap vs
        serial WITHIN the sharded path is the sharp claim: identical
        tables, identical quantization grouping, re-blocked rows only —
        the forward must agree exactly."""
        L = 7
        cfg_q = SPMConfig(n=64, n_stages=L, schedule="two_level",
                          n_shards=shards, backward="custom",
                          use_kernel=True, overlap=overlap,
                          quant_coeffs=True)
        cfg_ser_q = SPMConfig(n=64, n_stages=L, schedule="two_level",
                              n_shards=shards, backward="custom",
                              use_kernel=True, overlap=False,
                              quant_coeffs=True)
        ref_cfg = SPMConfig(n=64, n_stages=L, schedule="two_level",
                            n_shards=shards, backward="custom",
                            use_kernel=False)
        p = init_spm(KEY, cfg_q)
        # rows sized so the overlap cases pipeline > 1 row block
        x = jax.random.normal(jax.random.PRNGKey(4), (4, 40, 64))

        def loss(cfg):
            return lambda p, x: jnp.sum(spm_apply(p, x, cfg) ** 2)

        y_ref = jax.jit(lambda p, x: spm_apply(p, x, ref_cfg))(p, x)
        g_ref = jax.jit(jax.grad(loss(ref_cfg), argnums=(0, 1)))(p, x)
        mesh = _mesh(shards)
        with activation_sharding(mesh, shard_feature=True):
            assert spm_shard.sharded_eligible(cfg_q)
            y_q = jax.jit(lambda p, x: spm_apply(p, x, cfg_q))(p, x)
            g_q = jax.jit(jax.grad(loss(cfg_q), argnums=(0, 1)))(p, x)
            if overlap:
                y_ser = jax.jit(
                    lambda p, x: spm_apply(p, x, cfg_ser_q))(p, x)

        bound = _coeff_quant_bound_l2(x, p, cfg_q)
        y_ref_l2 = float(jnp.linalg.norm(y_ref))
        err = float(jnp.linalg.norm(y_q - y_ref))
        assert err <= bound, (err, bound)
        # the bound must be meaningful: well below the signal itself, so
        # a wrong-scale / wrong-slab bug (error on the order of the
        # signal) trips the assertion above
        assert bound < 0.5 * y_ref_l2, (bound, y_ref_l2)
        if overlap:
            # same quantized tables, same quantization grouping: overlap
            # only re-blocks the rows, so it agrees with serial to a few
            # ulp of f32 reassociation — NOT within some quantization
            # bound (that would hide a grouping bug)
            np.testing.assert_allclose(np.asarray(y_q),
                                       np.asarray(y_ser),
                                       rtol=1e-5, atol=1e-6)
        # grads are STRAIGHT-THROUGH grads of the dequantized operator: a
        # multiplicatively ~eps_rel-perturbed J in g = 2 J^T y, so they
        # track the reference within the same relative bound (x8 headroom
        # for the two perturbed factors and sum-loss accumulation)
        eps_rel = bound / y_ref_l2
        for a, b in zip(jax.tree.leaves(g_q), jax.tree.leaves(g_ref)):
            atol = 8 * eps_rel * max(float(jnp.linalg.norm(b)), 1.0)
            assert float(jnp.linalg.norm(a - b)) <= atol

    def test_compressed_pod_convergence_char_lm():
        """ISSUE 9 acceptance: the char-LM training driver with
        ``compress_pod_grads=True`` on a real 8-device ("pod",) shard_map
        mesh converges within tolerance of the uncompressed pod run —
        int8 error-feedback gradient reduction changes bytes on the wire,
        not the training trajectory."""
        from repro.configs import get_smoke
        from repro.data.char_corpus import build_corpus
        from repro.launch.train import build_parser, make_batch_fn, train
        from repro.models import causal_lm as LM

        def run(compress):
            argv = ["--arch", "qwen3-1.7b", "--smoke", "--steps", "20",
                    "--batch", "8", "--seq", "32", "--pod-dp", "8",
                    "--log-every", "100"]
            if compress:
                argv.append("--compress-pod-grads")
            return train(build_parser().parse_args(argv))

        state_u = run(compress=False)
        state_c = run(compress=True)
        assert "ef" in state_c["opt"] and "ef" not in state_u["opt"]

        cfg = get_smoke("qwen3-1.7b")
        corpus = build_corpus(200_000, seed=0)
        batch = make_batch_fn(cfg, 32, corpus)(jax.random.PRNGKey(99), 16)
        loss_of = lambda st: float(LM.lm_loss(st["params"], batch,
                                              cfg)[0])
        init_p = __import__("repro.models.transformer",
                            fromlist=["init_model"]).init_model(
            jax.random.PRNGKey(0), cfg)
        l0 = float(LM.lm_loss(init_p, batch, cfg)[0])
        lu, lc = loss_of(state_u), loss_of(state_c)
        assert lu < l0 and lc < l0            # both actually trained
        # EF keeps the compressed trajectory tight to the uncompressed
        # one: same data, same init, only int8 grid noise on the reduce
        assert abs(lc - lu) <= 0.05 * lu, (lc, lu, l0)
