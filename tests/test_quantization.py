"""Quantization test pyramid, layer 1: the numeric primitives.

Property-based tests (hypothesis; the conftest shim sweeps deterministic
examples when it is absent) for the two quantizer families —
``optim/compression.py`` (per-tensor, gradient all-reduce) and
``kernels/quant.py`` (per-block activation / per-stage coefficient, kernel
I/O) — plus the error-feedback accumulation identity and the
``decompress_tree`` structural-2-tuple regression.  Layer 2 (kernel parity
matrices) lives in tests/test_kernels.py, layer 3 (sharded parity +
compressed-pod convergence) in tests/test_distributed.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.quant import (block_scale_bound, dequantize_blocks,
                                 dequantize_coeffs, quantize_blocks,
                                 quantize_coeffs)
from repro.optim.compression import (_amax_scale, compress, compress_tree,
                                     decompress, decompress_tree, ef_step,
                                     init_residual, psum_compressed_ef)

# ---------------------------------------------------------------------------
# compress / decompress properties (per-tensor, optim/compression.py)
# ---------------------------------------------------------------------------


def _tensor(seed: int, shape, scale: float) -> jax.Array:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


@settings(max_examples=24, deadline=None)
@given(seed=st.integers(0, 3),
       scale=st.floats(1e-3, 1e3),
       rows=st.sampled_from([1, 7, 64]))
def test_compress_roundtrip_error_bound(seed, scale, rows):
    """Elementwise |dequant(quant(x)) - x| <= scale/2: round-to-nearest
    against the amax grid never errs past half a quantization step."""
    x = _tensor(seed, (rows, 33), scale)
    q, s = compress(x)
    err = jnp.abs(decompress(q, s) - x)
    assert float(jnp.max(err)) <= float(s) / 2 + 1e-12


def test_compress_all_zero_is_exact():
    """An all-zero tensor survives the round trip exactly (scale is the
    epsilon floor, payload all zeros)."""
    x = jnp.zeros((5, 8), jnp.float32)
    q, s = compress(x)
    assert int(jnp.max(jnp.abs(q))) == 0
    np.testing.assert_array_equal(np.asarray(decompress(q, s)), 0.0)
    assert float(s) > 0.0 and np.isfinite(float(s))


@settings(max_examples=12, deadline=None)
@given(mag=st.sampled_from([1e-38, 1e-30, 1e30, 3e38]))
def test_compress_scale_finite_positive_extremes(mag):
    """Denormal-small and near-f32-max inputs produce a finite, strictly
    positive scale and an in-range payload."""
    x = jnp.asarray([[mag, -mag / 2, 0.0, mag / 3]], jnp.float32)
    q, s = compress(x)
    assert np.isfinite(float(s)) and float(s) > 0.0
    assert int(jnp.max(q)) <= 127 and int(jnp.min(q)) >= -127


@settings(max_examples=24, deadline=None)
@given(seed=st.integers(0, 5), scale=st.floats(1e-6, 1e6))
def test_compress_int8_range_never_exceeded(seed, scale):
    x = _tensor(seed, (17,), scale)
    q, _ = compress(x)
    assert q.dtype == jnp.int8
    assert int(jnp.max(q)) <= 127 and int(jnp.min(q)) >= -127


# ---------------------------------------------------------------------------
# error-feedback accumulation identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype,steps,tol_ulps", [
    (jnp.float32, 6, 4),        # identity is algebraically exact; f32
                                # rounding of the running sums remains
    (jnp.bfloat16, 6, None),    # output cast to bf16 adds per-step
                                # rounding ~2^-8 of the step magnitude
])
def test_ef_step_accumulation_identity(dtype, steps, tol_ulps):
    """Over K steps, sum(decompressed) + final residual == sum(true grads):
    EF recycles exactly what quantization dropped, so nothing is ever lost
    — the Karimireddy-style unbiasedness the train step relies on."""
    rng = np.random.default_rng(7)
    gs = [jnp.asarray(rng.standard_normal((4, 9)) * 0.3, dtype)
          for _ in range(steps)]
    g_tree = {"a": gs[0], "b": (gs[0] * 0,)}   # nested, incl. a 1-tuple
    r = init_residual(g_tree)
    acc = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), g_tree)
    true = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), g_tree)
    for k in range(steps):
        g_tree = {"a": gs[k], "b": (gs[(k * 2 + 1) % steps],)}
        dq, r = ef_step(g_tree, r)
        acc = jax.tree.map(lambda a, d: a + d.astype(jnp.float32), acc, dq)
        true = jax.tree.map(lambda t, g: t + g.astype(jnp.float32),
                            true, g_tree)
    total = jax.tree.map(lambda a, rr: a + rr, acc, r)
    err = jax.tree.reduce(
        jnp.maximum,
        jax.tree.map(lambda t, o: jnp.max(jnp.abs(t - o)), true, total))
    if tol_ulps is not None:
        tol = tol_ulps * np.finfo(np.float32).eps * steps
    else:
        # bf16 output rounding: each returned step is rounded to 8
        # mantissa bits before accumulation
        mx = max(float(jnp.max(jnp.abs(g.astype(jnp.float32))))
                 for g in gs)
        tol = steps * mx * 2.0 ** -8
    assert float(err) <= tol, (float(err), tol)


def test_ef_step_bf16_residual_stays_f32():
    g = {"w": jnp.ones((3, 3), jnp.bfloat16)}
    r = init_residual(g)
    dq, r2 = ef_step(g, r)
    assert dq["w"].dtype == jnp.bfloat16
    assert r2["w"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# decompress_tree structural-2-tuple regression
# ---------------------------------------------------------------------------


def test_decompress_tree_nested_two_tuple_state():
    """Regression: a structural 2-tuple (e.g. a (mu, nu) moment pair) must
    DESCEND, not be mistaken for a (int8, scale) compressed leaf."""
    state = {"moments": (jnp.ones((4, 4)) * 0.5, jnp.ones((4, 4)) * 2.0),
             "w": jnp.linspace(-1.0, 1.0, 16).reshape(4, 4)}
    ctree = compress_tree(state)
    # the compressed moments pair is a 2-tuple OF 2-tuples — the leaf
    # predicate must look at content to stop at the right depth
    out = decompress_tree(ctree, state)
    assert isinstance(out["moments"], tuple) and len(out["moments"]) == 2
    for got, want in ((out["moments"][0], state["moments"][0]),
                      (out["moments"][1], state["moments"][1]),
                      (out["w"], state["w"])):
        q_err = float(_amax_scale(want)) / 2 + 1e-12
        assert float(jnp.max(jnp.abs(got - want))) <= q_err
        assert got.dtype == want.dtype


# ---------------------------------------------------------------------------
# psum_compressed_ef semantics (vmap stands in for the named axis)
# ---------------------------------------------------------------------------


def test_psum_compressed_ef_mean_and_residual():
    """Under a 4-member axis: the output equals the mean of the shared-grid
    dequantized member grads, and each member's residual is exactly its own
    pre-quantization value minus its dequantized payload."""
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.standard_normal((4, 6, 5)), jnp.float32)
    r0 = jnp.asarray(rng.standard_normal((4, 6, 5)) * 1e-3, jnp.float32)

    out, r1 = jax.vmap(
        lambda gi, ri: psum_compressed_ef({"w": gi}, {"w": ri}, "i"),
        axis_name="i")(g, r0)

    gf = g + r0
    s = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12         # axis-max shared scale
    q = jnp.clip(jnp.round(gf / s), -127, 127)
    want_mean = jnp.mean(q * s, axis=0)
    for m in range(4):
        np.testing.assert_allclose(np.asarray(out["w"][m]),
                                   np.asarray(want_mean), rtol=0, atol=1e-6)
        np.testing.assert_allclose(np.asarray(r1["w"][m]),
                                   np.asarray(gf[m] - q[m] * s),
                                   rtol=0, atol=1e-7)


def test_psum_compressed_ef_sum_mode():
    g = jnp.asarray(np.random.default_rng(0).standard_normal((2, 8)),
                    jnp.float32)
    r0 = jnp.zeros_like(g)
    out_sum, _ = jax.vmap(
        lambda gi, ri: psum_compressed_ef({"w": gi}, {"w": ri}, "i",
                                          mean=False),
        axis_name="i")(g, r0)
    out_mean, _ = jax.vmap(
        lambda gi, ri: psum_compressed_ef({"w": gi}, {"w": ri}, "i"),
        axis_name="i")(g, r0)
    np.testing.assert_allclose(np.asarray(out_sum["w"]),
                               np.asarray(out_mean["w"]) * 2,
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# kernels/quant.py: per-block activation + per-stage coefficient quantizers
# ---------------------------------------------------------------------------


@settings(max_examples=24, deadline=None)
@given(rows=st.sampled_from([8, 24]),
       width=st.sampled_from([16, 48, 50]),
       block_rows=st.sampled_from([8]),
       n_tile=st.sampled_from([16, 32]))
def test_quantize_blocks_roundtrip_bound(rows, width, block_rows, n_tile):
    """Per-(row-block, feature-tile) round trip stays within half the
    block's own quantization step — ``block_scale_bound`` is the exact
    worst case the kernel parity tests derive their tolerance from."""
    rng = np.random.default_rng(rows * 1000 + width)
    x = jnp.asarray(rng.standard_normal((rows, width)), jnp.float32)
    q, scales = quantize_blocks(x, block_rows, n_tile)
    assert q.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(q))) <= 127
    assert scales.shape == (rows // block_rows, -(-width // n_tile))
    assert bool(jnp.all(scales > 0)) and bool(jnp.all(jnp.isfinite(scales)))
    back = dequantize_blocks(q, scales, block_rows, n_tile, jnp.float32)
    bound = block_scale_bound(x, block_rows, n_tile) / 2 + 1e-9
    assert float(jnp.max(jnp.abs(back - x))) <= bound


def test_quantize_blocks_zero_exact():
    x = jnp.zeros((16, 32), jnp.float32)
    q, s = quantize_blocks(x, 8, 16)
    back = dequantize_blocks(q, s, 8, 16, jnp.float32)
    np.testing.assert_array_equal(np.asarray(back), 0.0)


@settings(max_examples=12, deadline=None)
@given(L=st.sampled_from([1, 5]), half=st.sampled_from([8, 24]))
def test_quantize_coeffs_roundtrip_bound(L, half):
    rng = np.random.default_rng(L * 31 + half)
    cf = jnp.asarray(rng.standard_normal((L, half, 4)), jnp.float32)
    q, scales = quantize_coeffs(cf)
    assert q.dtype == jnp.int8 and scales.shape == (L, 1)
    back = dequantize_coeffs(q, scales, jnp.float32)
    per_stage_bound = scales.reshape(L, 1, 1) / 2 + 1e-9
    assert bool(jnp.all(jnp.abs(back - cf) <= per_stage_bound))


def test_quantize_coeffs_per_stage_scales_independent():
    """A huge stage must not destroy a tiny stage's precision: scales are
    per-stage, so stage 1's round-trip error is bounded by ITS amax."""
    cf = jnp.stack([jnp.full((4, 4), 1000.0), jnp.full((4, 4), 1e-3)])
    q, s = quantize_coeffs(cf)
    back = dequantize_coeffs(q, s, jnp.float32)
    assert float(jnp.max(jnp.abs(back[1] - cf[1]))) <= 1e-3 / 127 + 1e-9
