"""Chaos parity under the forced-8-device SHARDED executor, with a
shard-count change across the restart (the elastic-restart acceptance).

Like tests/test_distributed.py, the multi-device half runs out of
process: the parent test re-execs pytest on this file with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and a worker env
marker (conftest forbids forcing devices globally).

Worker scenario — one training job, three lives:

  1. 8-way sharded run hit by a 2-step NaN burst (fault-policy rollback
     to the last checkpoint), then a truncated newest checkpoint, then an
     injected preemption with a zero restart budget — the process "dies"
     (ChaosPreemption propagates, as a real preemption kills the binary).
  2. The re-launch resumes on a **4-way** mesh (the elastic restart:
     ``schedule_shards=8`` pins the two_level schedule, ``n_shards=4``
     re-executes it on half the devices).  The restore quarantines the
     truncated step and walks back to the newest valid one.
  3. A fault-free 8-way run of the same job in a separate directory.

Lives 1+2 must end BITWISE-identical to life 3.  This works at n=256
because every row-reduction the executor and the XLA fallback perform
has minor width >= 16 (n_local in {32, 64}, pair width in {16, 32}), the
regime where XLA CPU reductions are bitwise stage-order independent —
the same analysis behind the elastic-executor parity suite.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

WORKER_ENV = "SPM_CHAOS_WORKER"
N_DEV = 8
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _in_worker() -> bool:
    return os.environ.get(WORKER_ENV) == "1"


# ---------------------------------------------------------------------------
# device-free: the elastic schedule itself (both processes)
# ---------------------------------------------------------------------------

def test_schedule_shards_pins_the_operator_across_executor_widths():
    """``schedule_shards`` decouples WHAT the operator computes (the
    two_level schedule, built for S shards) from HOW it executes
    (``n_shards`` devices): every pow2 divisor executes the same stride
    sequence, so checkpoints restart onto any such mesh.  At pow2 ``n``
    the two_level cycle happens to coincide across shard counts; odd
    local factors (n=96) are where the pin is load-bearing."""
    import dataclasses

    from repro.core.spm import SPMConfig

    base = SPMConfig(n=96, n_stages=8, schedule="two_level", n_shards=8)
    strides = base.pairing.strides()
    for m in (4, 2, 1):
        elastic = dataclasses.replace(base, n_shards=m, schedule_shards=8)
        assert elastic.pairing.strides() == strides, m
    # without the pin, shard count changes the schedule (the old coupling)
    assert SPMConfig(n=96, n_stages=8, schedule="two_level",
                     n_shards=4).pairing.strides() != strides
    # the parity harness below rides the pow2 coincidence AND the pin
    p256 = SPMConfig(n=256, n_stages=12, schedule="two_level",
                     n_shards=8).pairing.strides()
    assert dataclasses.replace(
        SPMConfig(n=256, n_stages=12, schedule="two_level", n_shards=4),
        schedule_shards=8).pairing.strides() == p256


def test_elastic_schedule_stays_executor_eligible():
    import dataclasses

    from repro.core.spm import SPMConfig
    from repro.parallel.spm_shard import sharded_eligible

    base = SPMConfig(n=256, n_stages=12, schedule="two_level", n_shards=8,
                     backward="custom")
    for m in (8, 4, 2):
        cfg = dataclasses.replace(base, n_shards=m, schedule_shards=8)
        assert sharded_eligible(cfg), m


# ---------------------------------------------------------------------------
# parent: re-exec under forced device count
# ---------------------------------------------------------------------------

if not _in_worker():

    def test_chaos_distributed_suite_in_subprocess():
        env = dict(os.environ)
        env[WORKER_ENV] = "1"
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" --xla_force_host_platform_device_count="
                              f"{N_DEV}")
        env["PYTHONPATH"] = (os.path.join(REPO, "src") + os.pathsep
                             + env.get("PYTHONPATH", ""))
        r = subprocess.run(
            [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
             os.path.abspath(__file__)],
            capture_output=True, text=True, timeout=1500, cwd=REPO, env=env)
        assert r.returncode == 0, (
            f"chaos multi-device worker failed (rc={r.returncode}):\n"
            f"--- stdout ---\n{r.stdout[-6000:]}\n"
            f"--- stderr ---\n{r.stderr[-2000:]}")
        assert "passed" in r.stdout


# ---------------------------------------------------------------------------
# worker: sharded training with injected faults + elastic restart
# ---------------------------------------------------------------------------

else:
    import dataclasses

    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.core.spm import SPMConfig, init_spm, spm_apply
    from repro.optim import OptimizerConfig
    from repro.parallel.ctx import activation_sharding
    from repro.train import (FaultEventLog, FaultPolicy, latest_valid_step,
                             make_train_state, make_train_step,
                             restore_checkpoint, run_with_recovery,
                             save_checkpoint, verify_checkpoint)
    from repro.train.chaos import ChaosPreemption, ChaosSchedule

    KEY = jax.random.PRNGKey(0)
    N, L, BATCH, STEPS, CKPT_EVERY = 256, 12, 8, 12, 3

    def test_worker_sees_forced_devices():
        assert jax.device_count() == N_DEV

    def _mesh(shards: int) -> Mesh:
        return Mesh(np.asarray(jax.devices()[:shards]).reshape(shards),
                    ("model",))

    def _cfg(exec_shards: int) -> SPMConfig:
        # schedule pinned to 8 shards; executed on exec_shards devices
        return SPMConfig(n=N, n_stages=L, schedule="two_level",
                         n_shards=exec_shards, schedule_shards=8,
                         backward="custom", use_kernel=False)

    def _batch_at(step: int) -> dict:
        k = jax.random.fold_in(KEY, step)
        kx, ky = jax.random.split(k)
        return {"x": jax.random.normal(kx, (BATCH, N)),
                "y": jax.random.normal(ky, (BATCH, N))}

    def _run(ckpt_dir, exec_shards, chaos=None, event_log=None,
             max_restarts=0):
        """The training job: SPM regression under the sharded executor,
        with the same rollback / verified-restore / recovery wiring as
        launch/train.py (which owns the single-mesh case — the elastic
        re-shard across process death is what this loop adds)."""
        cfg = _cfg(exec_shards)
        mesh = _mesh(exec_shards)
        event_log = event_log or FaultEventLog()

        def loss_fn(p, batch):
            yp = spm_apply(p, batch["x"], cfg)
            # pull the prediction replicated BEFORE the reduction: the
            # loss/grad reductions then run at identical widths on every
            # mesh, keeping the math bitwise mesh-independent
            yp = jax.lax.with_sharding_constraint(
                yp, NamedSharding(mesh, P(None, None)))
            loss = jnp.mean((yp - batch["y"]) ** 2)
            return loss, {"loss": loss}

        step_fn = jax.jit(make_train_step(
            loss_fn, OptimizerConfig(lr=1e-2, total_steps=STEPS),
            chaos_guard=True))

        def try_restore():
            state = make_train_state(init_spm(KEY, _cfg(8)))
            step = latest_valid_step(ckpt_dir, event_log=event_log)
            if step is None:
                return state, 0
            state, extra = restore_checkpoint(ckpt_dir, state, step=step,
                                              event_log=event_log)
            return state, int(extra["cursor"]["step"])

        def loop(resume):
            state, s = try_restore()
            policy = FaultPolicy(max_consecutive_skips=2)
            with activation_sharding(mesh, shard_feature=True):
                while s < STEPS:
                    poison = chaos.poison(s) if chaos else 0.0
                    state, metrics = step_fn(state, _batch_at(s), poison)
                    metrics = jax.device_get(metrics)
                    if policy.on_metrics(metrics):
                        event_log.emit("rollback", step=s)
                        state, s = try_restore()
                        policy.reset()
                        continue
                    s += 1
                    if s % CKPT_EVERY == 0:
                        save_checkpoint(
                            ckpt_dir, s, state,
                            extra={"cursor": {"seed": 0, "step": s}})
                    if chaos:
                        chaos.post_step(s - 1, ckpt_dir,
                                        event_log=event_log)
            return state

        return run_with_recovery(loop, max_restarts=max_restarts,
                                 event_log=event_log,
                                 sleep=lambda _: None)

    def test_sharded_chaos_parity_with_elastic_restart(tmp_path):
        clean_dir, chaos_dir = str(tmp_path / "c0"), str(tmp_path / "c1")

        # life 3 first: the fault-free 8-way reference
        ref = _run(clean_dir, exec_shards=8)

        # life 1: 8-way, NaN burst at 4-5 (rollback to step_3), newest
        # checkpoint truncated after step 8 (= step_9 on disk), preempted
        # after step 9 with a zero restart budget -> the "process" dies
        log = FaultEventLog(os.path.join(chaos_dir, "events.jsonl"))
        chaos = ChaosSchedule.parse(
            "nan@4+2;corrupt@8:truncate;preempt@9")
        with pytest.raises(ChaosPreemption):
            _run(chaos_dir, exec_shards=8, chaos=chaos, event_log=log)
        assert chaos.remaining() == ()

        # life 2: elastic re-launch on a 4-WAY mesh resumes the same
        # schedule; the truncated step_9 is quarantined, the restore
        # walks back to step_6, and the job runs to completion
        state = _run(chaos_dir, exec_shards=4, event_log=log)

        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        names = os.listdir(chaos_dir)
        assert any(n.startswith("corrupt.9.") for n in names)
        assert verify_checkpoint(chaos_dir, STEPS) == []
        kinds = [json.loads(l)["kind"]
                 for l in open(os.path.join(chaos_dir, "events.jsonl"))]
        assert "rollback" in kinds
        assert "quarantine" in kinds
        assert "restart_budget_exhausted" in kinds

    def test_elastic_execution_is_bitwise_across_mesh_widths(tmp_path):
        """The foundation under the parity test, isolated: the SAME
        checkpointed state stepped once on an 8-way, 4-way, and 2-way
        mesh produces bitwise-identical updates (schedule pinned via
        ``schedule_shards=8``)."""
        d = str(tmp_path / "ck")
        state0 = make_train_state(init_spm(KEY, _cfg(8)))
        save_checkpoint(d, 0, state0, extra={"cursor": {"seed": 0,
                                                        "step": 0}})
        outs = []
        for shards in (8, 4, 2):
            cfg = _cfg(shards)
            mesh = _mesh(shards)

            def loss_fn(p, batch, cfg=cfg, mesh=mesh):
                yp = spm_apply(p, batch["x"], cfg)
                yp = jax.lax.with_sharding_constraint(
                    yp, NamedSharding(mesh, P(None, None)))
                loss = jnp.mean((yp - batch["y"]) ** 2)
                return loss, {"loss": loss}

            step_fn = jax.jit(make_train_step(
                loss_fn, OptimizerConfig(lr=1e-2, total_steps=STEPS)))
            state, _ = restore_checkpoint(d, state0, step=0)
            with activation_sharding(mesh, shard_feature=True):
                for s in range(2):
                    state, _ = step_fn(state, _batch_at(s))
            outs.append(jax.device_get(state))
        for other in outs[1:]:
            for a, b in zip(jax.tree.leaves(outs[0]),
                            jax.tree.leaves(other)):
                np.testing.assert_array_equal(a, b)
