"""Residual-block megakernel parity + grad sweeps (interpret mode on CPU).

Two comparison regimes, per the acceptance spec:

* the NON-fused layer path must equal the explicit norm/ffn/residual
  composition BITWISE (it is literally that composition), and
* the fused kernel path must land within a bound DERIVED from machine
  epsilon and the chain depth against the pure-jnp f32 oracle — no
  hand-tuned tolerances.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.linear import LinearConfig, init_linear, linear_apply
from repro.kernels.ops import spm_block_fused
from repro.kernels.ref import spm_full_ref
from repro.layers.ffn import FFNConfig, ffn_apply, ffn_block_apply, init_ffn
from repro.layers.norms import init_rms_norm, norm_linear_apply, rms_norm

KEY = jax.random.PRNGKey(0)

_ACTS = {"relu": jax.nn.relu, "silu": jax.nn.silu, "gelu": jax.nn.gelu}

N, L = 128, 7
STRIDES = (1, 2, 4, 8, 16, 32, 64)


def _operands(key, n, n_stages, scale=0.4):
    ks = jax.random.split(key, 4)
    cf = scale * jax.random.normal(ks[0], (n_stages, n // 2, 4))
    d_in = 1.0 + 0.1 * jax.random.normal(ks[1], (n,))
    d_out = 1.0 + 0.1 * jax.random.normal(ks[2], (n,))
    bias = 0.1 * jax.random.normal(ks[3], (n,))
    return cf, d_in, d_out, bias


def _tol(dtype, depth, ref):
    """Rounding bound derived from machine epsilon, not tuned: ``depth``
    dependent multiply-add levels each contribute O(eps_f32) relative
    error (Higham §3.1: the accumulated factor gamma_k ≈ k·eps for
    k·eps << 1) on top of one I/O-dtype store rounding, measured against
    the oracle's own magnitude scale.  The constant 8 covers the
    reassociation freedom between the VMEM chain and the oracle's
    op-by-op order (each reassociation is worth a small multiple of one
    rounding, never a new error class)."""
    eps_io = float(jnp.finfo(dtype).eps)
    eps_f32 = float(jnp.finfo(jnp.float32).eps)
    scale = float(np.max(np.abs(np.asarray(ref, np.float32)))) + 1.0
    return 8 * (depth * eps_f32 + eps_io) * scale


def _assert_close(got, ref, dtype, depth):
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32),
        atol=_tol(dtype, depth, ref), rtol=0)


def _block_ref(x, ops1, ops2, gamma, activation, residual,
               in_w, mid_w, out_w, eps=1e-6):
    """Pure-jnp f32 oracle of the whole block, masking dead lanes exactly
    where the kernel does: x to in_width before the row stats, the mid
    boundary to mid_width BEFORE the activation (act(0) = 0 keeps dead
    lanes dead), the store to out_width."""
    cf1, di1, do1, b1 = ops1
    cf2, di2, do2, b2 = ops2
    n = cf1.shape[1] * 2
    xf = x.astype(jnp.float32)
    h = xf
    if gamma is not None:
        var = jnp.mean(h * h, axis=-1, keepdims=True)
        h = h * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)
    h = jnp.pad(h, ((0, 0), (0, n - in_w)))
    h = spm_full_ref(h, cf1, STRIDES, d_in=di1, d_out=do1, bias=b1)
    h = jnp.where(jnp.arange(n) < mid_w, h, 0.0)
    if activation is not None:
        h = _ACTS[activation](h)
    h = spm_full_ref(h, cf2, STRIDES, d_in=di2, d_out=do2, bias=b2)
    y = h[:, :out_w]
    if residual:
        y = y + xf
    return y.astype(x.dtype)


@pytest.mark.parametrize("activation", [None, "relu", "silu", "gelu"])
@pytest.mark.parametrize("norm", [True, False], ids=["norm", "nonorm"])
@pytest.mark.parametrize("residual", [True, False], ids=["res", "nores"])
def test_block_fused_fwd_and_grads_match_oracle(activation, norm, residual):
    """Square full-width sweep: forward and every operand grad of the
    fused block against the f32 oracle, f32 I/O."""
    ops1 = _operands(jax.random.PRNGKey(1), N, L)
    ops2 = _operands(jax.random.PRNGKey(2), N, L)
    gamma = (1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(3), (N,))
             if norm else None)
    x = jax.random.normal(KEY, (8, N))

    def fused(x, gamma, ops1, ops2):
        return spm_block_fused(
            x, coeffs1=ops1[0], d_in1=ops1[1], d_out1=ops1[2],
            bias1=ops1[3], strides1=STRIDES, gamma=gamma,
            coeffs2=ops2[0], d_in2=ops2[1], d_out2=ops2[2],
            bias2=ops2[3], strides2=STRIDES, activation=activation,
            residual=residual)

    def oracle(x, gamma, ops1, ops2):
        return _block_ref(x, ops1, ops2, gamma, activation, residual,
                          N, N, N)

    y = fused(x, gamma, ops1, ops2)
    ref = oracle(x, gamma, ops1, ops2)
    depth = 2 * L + 8                  # stages + norm/diag/act/residual
    _assert_close(y, ref, jnp.float32, depth)

    def loss(fn):
        return lambda *a: jnp.sum(fn(*a).astype(jnp.float32) ** 2)

    args = (x, gamma, ops1, ops2) if norm else (x, ops1, ops2)
    arg_ix = tuple(range(len(args)))
    wrap = (lambda f: f) if norm else (
        lambda f: (lambda x, o1, o2: f(x, None, o1, o2)))
    g = jax.grad(loss(wrap(fused)), argnums=arg_ix)(*args)
    gr = jax.grad(loss(wrap(oracle)), argnums=arg_ix)(*args)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(gr)):
        _assert_close(a, b, jnp.float32, 2 * depth)


@pytest.mark.parametrize("activation", ["gelu", "relu"])
def test_block_fused_bf16_io(activation):
    """bf16 activation I/O, f32 interior: the derived bound collapses to
    one bf16 store rounding on top of the f32 chain."""
    ops1 = _operands(jax.random.PRNGKey(1), N, L)
    ops2 = _operands(jax.random.PRNGKey(2), N, L)
    gamma = 1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(3), (N,))
    x = jax.random.normal(KEY, (8, N)).astype(jnp.bfloat16)
    y = spm_block_fused(
        x, coeffs1=ops1[0], d_in1=ops1[1], d_out1=ops1[2], bias1=ops1[3],
        strides1=STRIDES, gamma=gamma, coeffs2=ops2[0], d_in2=ops2[1],
        d_out2=ops2[2], bias2=ops2[3], strides2=STRIDES,
        activation=activation, residual=True)
    assert y.dtype == jnp.bfloat16
    ref = _block_ref(x, ops1, ops2, gamma, activation, True, N, N, N)
    _assert_close(y, ref, jnp.bfloat16, 2 * L + 8)


def test_block_fused_rect_widths_and_dead_lane_grads():
    """Rectangular widths: norm stats over the true in_width lanes, mid
    masked before the activation, residual on the store — and every
    dead-lane operand grad comes back EXACTLY zero (never computed, not
    small)."""
    in_w, mid_w, out_w = 96, 100, 96   # residual requires out_w == in_w
    ops1 = _operands(jax.random.PRNGKey(1), N, L)
    ops2 = _operands(jax.random.PRNGKey(2), N, L)
    gamma = 1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(3), (in_w,))
    x = jax.random.normal(KEY, (8, in_w))

    def fused(x, gamma, ops1, ops2):
        return spm_block_fused(
            x, coeffs1=ops1[0], d_in1=ops1[1], d_out1=ops1[2],
            bias1=ops1[3], strides1=STRIDES, gamma=gamma,
            coeffs2=ops2[0], d_in2=ops2[1], d_out2=ops2[2],
            bias2=ops2[3], strides2=STRIDES, activation="gelu",
            residual=True, in_width=in_w, mid_width=mid_w,
            out_width=out_w)

    y = fused(x, gamma, ops1, ops2)
    assert y.shape == (8, out_w)
    ref = _block_ref(x, ops1, ops2, gamma, "gelu", True, in_w, mid_w, out_w)
    depth = 2 * L + 8
    _assert_close(y, ref, jnp.float32, depth)

    def loss(fn):
        return lambda *a: jnp.sum(fn(*a) ** 2)

    def oracle(x, gamma, ops1, ops2):
        return _block_ref(x, ops1, ops2, gamma, "gelu", True,
                          in_w, mid_w, out_w)

    g = jax.grad(loss(fused), argnums=(0, 1, 2, 3))(x, gamma, ops1, ops2)
    gr = jax.grad(loss(oracle), argnums=(0, 1, 2, 3))(x, gamma, ops1, ops2)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(gr)):
        _assert_close(a, b, jnp.float32, 2 * depth)
    _, _, g1, g2 = g
    assert np.all(np.asarray(g1[1][in_w:]) == 0)    # g_din1 past in_w
    assert np.all(np.asarray(g1[2][mid_w:]) == 0)   # g_dout1 past mid_w
    assert np.all(np.asarray(g1[3][mid_w:]) == 0)   # g_bias1 past mid_w
    assert np.all(np.asarray(g2[1][mid_w:]) == 0)   # g_din2 past mid_w
    assert np.all(np.asarray(g2[2][out_w:]) == 0)   # g_dout2 past out_w
    assert np.all(np.asarray(g2[3][out_w:]) == 0)   # g_bias2 past out_w


def _ffn_cfg(fuse, activation="gelu", d_model=64, d_ff=256):
    return FFNConfig(d_model=d_model, d_ff=d_ff, linear_impl="spm_general",
                     activation=activation, spm_backward="custom",
                     spm_use_kernel=True, spm_block_fuse=fuse)


def test_ffn_block_fallback_is_bitwise_the_composition():
    """spm_block_fuse=False IS the explicit composition — bitwise, both
    with and without the norm prologue."""
    cfg = _ffn_cfg(False)
    p = init_ffn(KEY, cfg)
    np_ = init_rms_norm(cfg.d_model)
    x = jax.random.normal(KEY, (4, 10, cfg.d_model))
    y = ffn_block_apply(p, np_, x, cfg)
    ref = x + ffn_apply(p, rms_norm(np_, x), cfg)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref))
    y2 = ffn_block_apply(p, None, x, cfg)
    np.testing.assert_array_equal(np.asarray(y2),
                                  np.asarray(x + ffn_apply(p, x, cfg)))


@pytest.mark.parametrize("activation", ["relu", "silu", "gelu"])
def test_ffn_block_fused_matches_fallback(activation):
    """Layer-level fused-vs-fallback parity, forward and parameter grads,
    within the derived bound (both interiors are f32; the fallback
    round-trips bf16-free f32 arrays between ops, so only reassociation
    separates them)."""
    cfg_f = _ffn_cfg(True, activation)
    cfg_o = _ffn_cfg(False, activation)
    p = init_ffn(KEY, cfg_f)
    np_ = init_rms_norm(cfg_f.d_model)
    x = jax.random.normal(KEY, (4, 10, cfg_f.d_model))
    y = ffn_block_apply(p, np_, x, cfg_f)
    ref = ffn_block_apply(p, np_, x, cfg_o)
    n = LinearConfig(d_in=cfg_f.d_model, d_out=cfg_f.d_ff,
                     impl="spm_general").n
    depth = 2 * len(LinearConfig(d_in=cfg_f.d_model, d_out=cfg_f.d_ff,
                                 impl="spm_general",
                                 use_kernel=True).spm_config()
                    .pairing.strides()) + 8
    assert n == 256
    _assert_close(y, ref, jnp.float32, depth)

    def loss(cfg):
        return lambda p, np_, x: jnp.sum(
            ffn_block_apply(p, np_, x, cfg) ** 2)

    g = jax.grad(loss(cfg_f), argnums=(0, 1, 2))(p, np_, x)
    gr = jax.grad(loss(cfg_o), argnums=(0, 1, 2))(p, np_, x)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(gr)):
        _assert_close(a, b, jnp.float32, 2 * depth)


def test_ffn_block_swiglu_never_fuses():
    """swiglu is structurally excluded (the gate is a second operator on
    the same input, not a chainable epilogue): even forced on, the layer
    takes the bitwise composition path."""
    cfg = _ffn_cfg(True, "swiglu")
    p = init_ffn(KEY, cfg)
    np_ = init_rms_norm(cfg.d_model)
    x = jax.random.normal(KEY, (4, 10, cfg.d_model))
    y = ffn_block_apply(p, np_, x, cfg)
    ref = x + ffn_apply(p, rms_norm(np_, x), cfg)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ref))
    jx = jax.make_jaxpr(lambda p, x: ffn_block_apply(p, np_, x, cfg))(p, x)
    from repro.analysis.jaxpr_walk import count_primitive
    assert count_primitive(jx, "pallas_call") > 1   # per-linear path


def test_ffn_block_fused_single_pallas_call():
    """The fused layer forward lowers the whole residual block as exactly
    ONE pallas_call — the megakernel acceptance shape, asserted at the
    layer entry (the contract checker proves it per zoo cell)."""
    from repro.analysis.jaxpr_walk import count_primitive, primitive_names
    cfg = _ffn_cfg(True)
    p = init_ffn(KEY, cfg)
    np_ = init_rms_norm(cfg.d_model)
    x = jax.random.normal(KEY, (8, cfg.d_model))
    jx = jax.make_jaxpr(lambda p, np_, x: ffn_block_apply(p, np_, x, cfg))(
        p, np_, x)
    assert count_primitive(jx, "pallas_call") == 1
    assert "pad" not in primitive_names(jx)


def test_norm_linear_apply_fused_and_fallback():
    """The single-stack face (norm prologue only): fused within the
    derived bound of the fallback, fallback bitwise the composition."""
    lc = LinearConfig(d_in=96, d_out=128, impl="spm_general",
                      backward="custom", use_kernel=True)
    p = init_linear(KEY, lc)
    np_ = init_rms_norm(96)
    x = jax.random.normal(KEY, (8, 96))
    y_off = norm_linear_apply(np_, p, x, lc, block_fuse=False)
    ref = linear_apply(p, rms_norm(np_, x), lc)
    np.testing.assert_array_equal(np.asarray(y_off), np.asarray(ref))
    y_on = norm_linear_apply(np_, p, x, lc, block_fuse=True)
    L1 = len(lc.spm_config().pairing.strides())
    _assert_close(y_on, ref, jnp.float32, L1 + 8)


