"""Static-analysis subsystem tests (compile contracts, spmlint, the
recompilation sentinel — ``src/repro/analysis/``).

The seeded-violation tests are the acceptance spine: each one plants the
exact hazard a tool exists to catch (an XLA pad smuggled onto the kernel
path, an inline eligibility predicate, a forced retrace) and asserts the
corresponding contract / lint rule / sentinel actually fires.  The
healthy-path twins prove the tools stay quiet on the real tree, so a
finding is always a signal.
"""

import ast
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts, driver, jaxpr_walk, lint
from repro.analysis.recompile import (CompileTracker, RetraceError,
                                      assert_compiles, assert_no_recompile)
from repro.core.linear import LinearConfig, init_linear, linear_apply
from repro.kernels.ops import plan_runs

KEY = jax.random.PRNGKey(0)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# jaxpr_walk units
# ---------------------------------------------------------------------------

def test_iter_eqns_descends_cond_branches():
    """The walk reaches primitives inside cond branches (list-valued
    sub-jaxpr params), not just direct .jaxpr params."""
    def f(x):
        return jax.lax.cond(x.sum() > 0, jnp.sin, jnp.cos, x)

    jx = jax.make_jaxpr(f)(jnp.ones(4))
    names = jaxpr_walk.primitive_names(jx.jaxpr)
    assert "cond" in names and "sin" in names and "cos" in names


def test_iter_eqns_does_not_descend_pallas_bodies():
    """pallas_call equations are leaves: the fused linear traces exactly
    len(plan_runs) pallas_calls and the walk must not multiply-count the
    kernel bodies' internal equations as outer pads/slices."""
    lc = LinearConfig(d_in=96, d_out=256, impl="spm_general",
                      backward="custom", use_kernel=True)
    p = init_linear(KEY, lc)
    x = jax.random.normal(KEY, (8, 96))
    jx = jax.make_jaxpr(lambda x: linear_apply(p, x, lc))(x)
    n = lc.n
    strides = lc.spm_config().pairing.strides()
    got = jaxpr_walk.count_primitive(jx.jaxpr, "pallas_call")
    assert got == len(plan_runs(n, tuple(strides)))
    # the kernel bodies mask in-VMEM with iota/broadcast compares; none of
    # that internal arithmetic may leak into the outer walk as pad
    assert "pad" not in jaxpr_walk.primitive_names(jx.jaxpr)


def test_feature_axis_slices_and_activation_pads():
    rows = 8

    def f(x):
        y = jax.lax.slice(x, (0, 0), (rows, 40))      # feature narrowing
        z = x[:4]                                     # row slice: ignored
        return y.sum() + z.sum()

    jx = jax.make_jaxpr(f)(jnp.ones((rows, 64)))
    assert jaxpr_walk.feature_axis_slices(jx.jaxpr) == [((rows, 64),
                                                         (rows, 40))]
    assert jaxpr_walk.feature_axis_slices(jx.jaxpr, rows=99) == []

    def g(x):
        return jnp.pad(x, ((0, 0), (0, 24)))

    jg = jax.make_jaxpr(g)(jnp.ones((rows, 40)))
    assert jaxpr_walk.activation_pads(jg.jaxpr, rows=rows) == [((rows, 40),
                                                                (rows, 64))]
    assert jaxpr_walk.activation_pads(jg.jaxpr, rows=7) == []


# ---------------------------------------------------------------------------
# compile contracts: healthy pass + seeded violations
# ---------------------------------------------------------------------------

def _fused_cell():
    return contracts.Cell(cell_id="96x256-butterfly/fused", d_in=96,
                          d_out=256, variant="fused")


def test_contracts_pass_on_healthy_fused_cell():
    cell = _fused_cell()
    results = contracts.run_cell(cell)
    assert results, "no contracts applied"
    bad = {k: v for k, v in results.items() if v != "pass"}
    assert not bad, bad


def test_contract_catches_injected_pad_on_kernel_path():
    """Seeded violation: a pad + feature slice smuggled around the fused
    forward must trip kernel-path-no-pad AND the single-output-slice
    contract."""
    cell = _fused_cell()
    art = contracts.Artifacts(cell)
    fwd = art._fwd_fn()

    def bad_fwd(p, x):
        x = jnp.pad(x, ((0, 0), (0, 4)))[:, :96]
        return fwd(p, x)

    # cached_property: planting the poisoned trace is one assignment
    art.jaxpr_fwd = jax.make_jaxpr(bad_fwd)(art.params, art.x)
    results = contracts.run_cell(cell, art)
    assert results["kernel-path-no-pad"].startswith("fail"), results
    assert results["kernel-path-single-output-slice"].startswith("fail")


def test_contract_catches_silent_kernel_fallback():
    """Seeded violation: a fused cell whose trace contains zero
    pallas_calls (the silent XLA fallback) must trip kernel-path-engaged
    and the pallas-count contract."""
    cell = _fused_cell()
    art = contracts.Artifacts(cell)
    lc_off = LinearConfig(d_in=cell.d_in, d_out=cell.d_out,
                          impl="spm_general", backward=cell.backward,
                          use_kernel=False)
    art.jaxpr_fwd = jax.make_jaxpr(
        lambda p, x: linear_apply(p, x, lc_off))(art.params, art.x)
    results = contracts.run_cell(cell, art)
    assert results["kernel-path-engaged"].startswith("fail"), results
    assert results["pallas-call-count-matches-plan"].startswith("fail")


def test_contract_catches_block_interop_roundtrip():
    """Seeded violation: a 'block' artifact assembled from per-linear
    pieces — norm and activation in XLA between two kernel calls — must
    trip block-no-interop-roundtrip on every prong (call count, batch-wide
    float intermediates outside the fused region)."""
    cell = contracts.Cell(cell_id="64x64/fused", d_in=64, d_out=64,
                          variant="fused")
    assert contracts.CONTRACTS["block-no-interop-roundtrip"].applies(cell)
    art = contracts.Artifacts(cell)
    lc = cell.linear_config()

    def bad(p, x):
        h = x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6)
        h = jax.nn.gelu(linear_apply(p, h, lc))
        return x + linear_apply(p, h, lc)   # spmlint: allow[SPM007]

    art.jaxpr_block = jax.make_jaxpr(bad)(art.params, art.x)
    results = contracts.run_cell(cell, art)
    verdict = results["block-no-interop-roundtrip"]
    assert verdict.startswith("fail"), results
    assert "pallas_call" in verdict and "intermediate" in verdict


def test_block_contract_passes_on_healthy_cells():
    """The real block artifact (one fused region) passes on a square and
    a rectangular cell."""
    for d_in, d_out in [(64, 64), (96, 256)]:
        cell = contracts.Cell(cell_id=f"{d_in}x{d_out}/fused", d_in=d_in,
                              d_out=d_out, variant="fused")
        results = contracts.run_cell(cell)
        assert results.get("block-no-interop-roundtrip") == "pass", results


def test_contract_reports_error_not_skip_on_broken_artifact():
    """An artifact that cannot build is a finding, not a silent skip."""
    cell = _fused_cell()
    art = contracts.Artifacts(cell)

    class Boom:
        def __getattr__(self, name):
            raise RuntimeError("artifact exploded")

    art.jaxpr_fwd = Boom()
    results = contracts.run_cell(cell, art)
    assert any(v.startswith("error:") for v in results.values()), results


# ---------------------------------------------------------------------------
# spmlint: seeded violations per rule + clean tree
# ---------------------------------------------------------------------------

def _lint_src(tmp_path, rel, source):
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(source)
    return lint.lint_file(p, root=tmp_path)


def test_spm001_inline_eligibility_predicate(tmp_path):
    src = '"""doc."""\ndef sharded_eligible(cfg):\n    return True\n'
    found = _lint_src(tmp_path, "src/repro/parallel/helper.py", src)
    assert [v.rule for v in found] == ["SPM001"]
    # the one legitimate home is exempt
    assert _lint_src(tmp_path, "src/repro/core/eligibility.py", src) == []


def test_spm002_pad_on_kernel_path(tmp_path):
    src = '"""doc."""\nimport jax.numpy as jnp\n\n\ndef f(x):\n' \
          '    return jnp.pad(x, ((0, 0), (0, 4)))\n'
    found = _lint_src(tmp_path, "src/repro/core/spm.py", src)
    assert [v.rule for v in found] == ["SPM002"]
    # outside the kernel path the same call is fine
    assert _lint_src(tmp_path, "src/repro/train/step.py", src) == []
    # and a pragma documents the sanctioned fallback site
    src_ok = src.replace("    return jnp.pad",
                         "    # spmlint: allow[SPM002] fallback\n"
                         "    return jnp.pad")
    assert _lint_src(tmp_path, "src/repro/core/spm.py", src_ok) == []


def test_spm003_pallas_outside_kernels(tmp_path):
    src = '"""doc."""\nfrom jax.experimental import pallas as pl\n'
    found = _lint_src(tmp_path, "src/repro/core/fancy.py", src)
    assert [v.rule for v in found] == ["SPM003"]
    assert _lint_src(tmp_path, "src/repro/kernels/fancy.py", src) == []


def test_spm004_branch_on_traced_value(tmp_path):
    src = '"""doc."""\nimport jax.numpy as jnp\n\n\ndef f(x):\n' \
          '    if jnp.any(x > 0):\n        return x\n    return -x\n'
    found = _lint_src(tmp_path, "src/repro/core/util.py", src)
    assert [v.rule for v in found] == ["SPM004"]
    # static trace-time attributes are safe branches
    src_ok = src.replace("jnp.any(x > 0)",
                         "jnp.issubdtype(x.dtype, jnp.floating)")
    assert _lint_src(tmp_path, "src/repro/core/util.py", src_ok) == []


def test_spm005_nondeterminism_in_bench_code(tmp_path):
    src = '"""doc."""\nimport time\nimport numpy as np\n\n\ndef f():\n' \
          '    return time.time() + np.random.rand()\n'
    found = _lint_src(tmp_path, "benchmarks/new_bench.py", src)
    assert sorted(v.rule for v in found) == ["SPM005", "SPM005"]
    src_ok = '"""doc."""\nimport time\nimport numpy as np\n\n\ndef f():\n' \
             '    rng = np.random.default_rng(0)\n' \
             '    return time.perf_counter() + rng.random()\n'
    assert _lint_src(tmp_path, "benchmarks/new_bench.py", src_ok) == []


def test_spm006_all_and_docstring_consistency(tmp_path):
    src = '"""doc."""\n__all__ = ["present", "ghost"]\n\n\ndef present():\n' \
          '    pass\n'
    found = _lint_src(tmp_path, "src/repro/core/mod.py", src)
    assert [v.rule for v in found] == ["SPM006"]
    assert "ghost" in found[0].msg
    nodoc = "x = 1\n"
    found = _lint_src(tmp_path, "src/repro/core/mod2.py", nodoc)
    assert [v.rule for v in found] == ["SPM006"]


def test_spm007_composition_outside_layers(tmp_path):
    wrapped = '"""doc."""\n\n\ndef f(p, x, cfg):\n' \
              '    return silu(spm_apply(p, x, cfg))\n'
    found = _lint_src(tmp_path, "src/repro/models/custom.py", wrapped)
    assert [v.rule for v in found] == ["SPM007"]
    fed = '"""doc."""\n\n\ndef f(p, np_, x, cfg):\n' \
          '    return linear_apply(p, rms_norm(np_, x), cfg)\n'
    found = _lint_src(tmp_path, "src/repro/models/custom.py", fed)
    assert [v.rule for v in found] == ["SPM007"]
    # layers/ owns the fused block entries; kernels/ hosts the fused
    # implementations and their fallback mirrors — both exempt
    assert _lint_src(tmp_path, "src/repro/layers/custom.py", wrapped) == []
    assert _lint_src(tmp_path, "src/repro/kernels/custom.py", wrapped) == []
    # pragma for spec-mandated compositions (the paper's teacher/student)
    ok = wrapped.replace("    return silu",
                         "    # spmlint: allow[SPM007] teacher spec\n"
                         "    return silu")
    assert _lint_src(tmp_path, "src/repro/models/custom.py", ok) == []


def test_spmlint_tree_is_clean():
    """The committed tree carries zero violations (sanctioned sites are
    pragma'd) — the CI lint job stays green by construction."""
    found = lint.lint_paths()
    assert found == [], "\n".join(str(v) for v in found)


# ---------------------------------------------------------------------------
# recompilation sentinel
# ---------------------------------------------------------------------------

def test_tracker_rejects_unjitted_fn():
    with pytest.raises(TypeError):
        with CompileTracker(f=lambda x: x):
            pass


def test_chaos_guard_train_step_compiles_once_across_poison():
    """The chaos port is a TRACED operand: healthy and poisoned steps ride
    one executable (the whole point of the in-graph injection)."""
    from repro.models import MLPConfig, init_mlp, mlp_loss
    from repro.optim import OptimizerConfig
    from repro.train import make_train_state, make_train_step

    cfg = MLPConfig(n_features=16, n_classes=4)
    step = jax.jit(make_train_step(
        lambda p, b: mlp_loss(p, b, cfg),
        OptimizerConfig(lr=1e-2, total_steps=4), chaos_guard=True))
    state = make_train_state(init_mlp(KEY, cfg))
    batch = {"x": jax.random.normal(KEY, (8, 16)),
             "y": jnp.zeros((8,), jnp.int32)}
    with assert_compiles(1, train_step=step):
        state, _ = step(state, batch, 0.0)
        state, _ = step(state, batch, 1.0)   # poisoned: same executable
    with assert_no_recompile(train_step=step):
        step(state, batch, 0.0)


def test_serve_decode_compiles_once_across_temperatures():
    """Per-request sampling params (temperature, key) are traced: a
    temperature sweep decodes on ONE compiled step."""
    from repro.configs import get_smoke
    from repro.models import transformer as T
    from repro.serve import ServeEngine

    cfg = get_smoke("qwen3-1.7b")
    eng = ServeEngine(cfg=cfg, params=T.init_model(KEY, cfg), max_len=16,
                      cache_dtype=jnp.float32)
    prompts = jax.random.randint(KEY, (2, 4), 0, cfg.vocab_size)
    with assert_compiles(1, decode_step=eng._step):
        eng.generate(prompts, max_new_tokens=3, temperature=0.7, key=KEY)
        eng.generate(prompts, max_new_tokens=3, temperature=1.3, key=KEY)


def test_sentinel_catches_forced_retrace():
    """Seeded violation: a shape change retraces the watched jit and the
    sentinel must raise (this is the regression it exists for)."""
    f = jax.jit(lambda x: x * 2)
    with pytest.raises(RetraceError, match="retracing"):
        with assert_compiles(1, f=f):
            f(jnp.ones(4))
            f(jnp.ones(8))           # new shape -> second executable


def test_sentinel_catches_never_ran():
    f = jax.jit(lambda x: x + 1)
    with pytest.raises(RetraceError, match="never ran"):
        with assert_compiles(1, f=f):
            pass


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def test_driver_smoke_single_arch():
    """In-process single-arch sweep: every contract passes, sharded
    variants are skipped with visible reasons on a 1-device pytest run
    (conftest forbids forcing devices in-process; the CLI forces 8)."""
    report = driver.run_check(["mamba2-370m"], scales=("smoke",),
                              include_bench_shapes=False, verbose=False)
    c = report["counts"]
    assert c["cells"] > 0 and c["contract_checks"] > 0
    assert c["failures"] == 0, report["failures"]
    if jax.device_count() < driver.N_SHARDS:
        assert report["skipped"], "expected shard variants skipped"
        assert all("devices" in s["reason"] or "divisible" in s["reason"]
                   or "shard" in s["reason"] for s in report["skipped"])
    # every fused/unfused cell reports its kernel-path verdict
    for cid, cell in report["cells"].items():
        assert cell["contracts"], cid
        assert cell["kernel_path"] == (cell["variant"] != "unfused")


def test_bench_rect_shapes_in_sync_with_kernel_bench():
    """driver.BENCH_RECT_SHAPES duplicates benchmarks/kernel_bench.py's
    RECT_SHAPES as data (benchmarks/ is not importable from src/): this
    test is the sync contract."""
    path = os.path.join(REPO, "benchmarks", "kernel_bench.py")
    tree = ast.parse(open(path).read())
    rect = None
    for node in tree.body:
        if (isinstance(node, ast.Assign)
                and isinstance(node.targets[0], ast.Name)
                and node.targets[0].id == "RECT_SHAPES"):
            rect = ast.literal_eval(node.value)
    assert rect is not None, "RECT_SHAPES not found in kernel_bench.py"
    assert [tuple(t) for t in rect] == \
        [tuple(t) for t in driver.BENCH_RECT_SHAPES]


def test_enumerate_operators_covers_all_archs():
    """Every registry arch contributes at least one operator at each
    scale, and dedupe keeps the arch attribution."""
    from repro.configs import registry
    ops = driver.enumerate_operators(include_bench_shapes=True)
    tagged = {a for rec in ops.values() for a in rec["archs"]}
    for arch in registry.ARCH_IDS:
        assert f"{arch}[smoke]" in tagged, arch
        assert f"{arch}[full]" in tagged, arch
    assert "kernel_bench" in tagged
