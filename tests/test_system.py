"""End-to-end system behaviour: training converges, serving generates,
paper's central claim holds at small scale (SPM student > dense student
on a compositional teacher at equal width)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data import (DeterministicLoader, TeacherConfig, build_corpus,
                        make_teacher, teacher_batch)
from repro.models import (GRULMConfig, MLPConfig, gru_lm_loss, init_gru_lm,
                          init_mlp, mlp_loss)
from repro.models import causal_lm as LM
from repro.models import transformer as T
from repro.optim import OptimizerConfig
from repro.serve import ServeEngine
from repro.train import make_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _train(cfg_mlp, loader, steps, lr=3e-3):
    state = make_train_state(init_mlp(KEY, cfg_mlp))
    step = jax.jit(make_train_step(
        lambda p, b: mlp_loss(p, b, cfg_mlp),
        OptimizerConfig(lr=lr, total_steps=steps)))
    for s in range(steps):
        state, m = step(state, loader.batch_at(s))
    # eval on fresh batches
    accs = []
    for s in range(1000, 1005):
        _, m = mlp_loss(state["params"], loader.batch_at(s), cfg_mlp)
        accs.append(float(m["acc"]))
    return float(np.mean(accs))


def test_spm_student_beats_dense_on_compositional_teacher():
    """Paper Table 1 claim, miniaturized: width 128, 300 steps."""
    width, steps = 128, 300
    tc = TeacherConfig(width=width)
    teacher = make_teacher(tc)
    loader = DeterministicLoader(
        lambda k, n: teacher_batch(teacher, tc, k, n), 128, seed=0)
    acc_spm = _train(MLPConfig(n_features=width, n_classes=10,
                               linear_impl="spm_general",
                               spm_backward="custom"), loader, steps)
    acc_dense = _train(MLPConfig(n_features=width, n_classes=10,
                                 linear_impl="dense"), loader, steps)
    assert acc_spm > acc_dense, (acc_spm, acc_dense)


def test_char_lm_loss_decreases():
    corpus = build_corpus(60_000)
    cfg = GRULMConfig(vocab_size=256, d_model=64,
                      linear_impl="spm_rotation", spm_backward="custom")
    params = init_gru_lm(KEY, cfg)
    state = make_train_state(params)
    step = jax.jit(make_train_step(
        lambda p, b: gru_lm_loss(p, b, cfg),
        OptimizerConfig(lr=3e-3, total_steps=60)))
    rng = np.random.default_rng(0)
    losses = []
    for s in range(60):
        starts = rng.integers(0, len(corpus) - 33, size=8)
        idx = starts[:, None] + np.arange(33)[None, :]
        chunk = corpus[idx]
        batch = {"tokens": jnp.asarray(chunk[:, :-1], jnp.int32),
                 "labels": jnp.asarray(chunk[:, 1:], jnp.int32)}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.5


def test_transformer_lm_trains_on_smoke_config():
    cfg = get_smoke("qwen3-1.7b")
    params = T.init_model(KEY, cfg)
    state = make_train_state(params)
    corpus = build_corpus(30_000)
    step = jax.jit(make_train_step(
        lambda p, b: LM.lm_loss(p, b, cfg),
        OptimizerConfig(lr=1e-3, total_steps=30)))
    rng = np.random.default_rng(0)
    losses = []
    for s in range(30):
        starts = rng.integers(0, len(corpus) - 33, size=4)
        idx = starts[:, None] + np.arange(33)[None, :]
        chunk = corpus[idx].astype(np.int64) % cfg.vocab_size
        batch = {"tokens": jnp.asarray(chunk[:, :-1], jnp.int32),
                 "labels": jnp.asarray(chunk[:, 1:], jnp.int32)}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_serve_engine_greedy_is_deterministic():
    cfg = get_smoke("qwen3-1.7b")
    params = T.init_model(KEY, cfg)
    eng = ServeEngine(cfg=cfg, params=params, max_len=24,
                      cache_dtype=jnp.float32)
    prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)
    out1 = eng.generate(prompts, max_new_tokens=8)
    out2 = eng.generate(prompts, max_new_tokens=8)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (2, 8)


def test_serve_engine_sampling_requires_key():
    """temperature > 0 without a key raises instead of silently decoding
    greedily (the old behaviour hid misconfigured samplers)."""
    cfg = get_smoke("qwen3-1.7b")
    params = T.init_model(KEY, cfg)
    eng = ServeEngine(cfg=cfg, params=params, max_len=16,
                      cache_dtype=jnp.float32)
    prompts = jax.random.randint(KEY, (1, 4), 0, cfg.vocab_size)
    with pytest.raises(ValueError, match="requires a PRNG key"):
        eng.generate(prompts, max_new_tokens=4, temperature=0.8)
    # sampled decode is reproducible under a fixed key
    out1 = eng.generate(prompts, max_new_tokens=4, temperature=0.8, key=KEY)
    out2 = eng.generate(prompts, max_new_tokens=4, temperature=0.8, key=KEY)
    np.testing.assert_array_equal(out1, out2)
    assert out1.shape == (1, 4)
