"""Layer-level tests: attention (chunked == naive, decode == full),
mamba2 (chunked SSD == sequential recurrence), MoE, GRU (paper §6)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.layers import (AttentionConfig, FFNConfig, GRUConfig,
                          Mamba2Config, MoEConfig, attention_apply,
                          chunked_causal_attention, ffn_apply, gru_apply,
                          gru_cell, init_attention, init_ffn, init_gru,
                          init_kv_cache, init_moe, init_mamba2,
                          init_ssm_cache, mamba2_apply, moe_apply)
from repro.layers.rope import apply_rope, mrope_angles, rope_angles

KEY = jax.random.PRNGKey(0)


def naive_attention(q, k, v, window=None):
    B, T, H, dh = q.shape
    G = H // k.shape[2]
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bthd,bshd->bhts", q, kk) / dh ** 0.5
    i = jnp.arange(T)[:, None]
    j = jnp.arange(T)[None, :]
    m = j <= i
    if window is not None:
        m &= j > i - window
    s = jnp.where(m[None, None], s, -1e30)
    return jnp.einsum("bhts,bshd->bthd", jax.nn.softmax(s, -1), vv)


@pytest.mark.parametrize("H,Hkv,window,qc,kc", [
    (8, 8, None, 16, 16),     # MHA
    (8, 4, None, 16, 8),      # GQA 2:1
    (8, 2, None, 13, 9),      # GQA 4:1, ragged chunks
    (8, 4, 8, 16, 16),        # sliding window
])
def test_chunked_attention_matches_naive(H, Hkv, window, qc, kc):
    B, T, dh = 2, 64, 16
    q = jax.random.normal(KEY, (B, T, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, Hkv, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, Hkv, dh))
    out = chunked_causal_attention(q, k, v, window=window, q_chunk=qc,
                                   k_chunk=kc)
    np.testing.assert_allclose(out, naive_attention(q, k, v, window),
                               atol=1e-4)


@pytest.mark.parametrize("T,qc,kc,window", [
    (61, 16, 16, None),   # prime T: edge chunks are padded + masked
    (61, 16, 16, 8),      # prime T, sliding window
    (37, 13, 11, None),   # odd T, odd ragged chunks
    (53, 64, 64, None),   # chunk larger than T
])
def test_chunked_attention_odd_lengths_match_naive(T, qc, kc, window):
    """Regression: prime/odd T used to degrade to chunk=1 (the largest
    chunk divisor of 61 is 1 — a length-61 scan of single-row chunks).
    The edge chunk is now padded and masked instead; padded keys must
    never leak into real queries nor padded queries into the output."""
    B, H, Hkv, dh = 2, 4, 2, 16
    q = jax.random.normal(KEY, (B, T, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, T, Hkv, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, T, Hkv, dh))
    out = chunked_causal_attention(q, k, v, window=window, q_chunk=qc,
                                   k_chunk=kc)
    np.testing.assert_allclose(out, naive_attention(q, k, v, window),
                               atol=1e-4)


@pytest.mark.parametrize("window", [None, 8])
def test_attention_decode_matches_full(window):
    B, T, d = 2, 32, 64
    cfg = AttentionConfig(d_model=d, n_heads=8, n_kv_heads=4, head_dim=8,
                          use_qk_norm=True, window=window, q_chunk=8,
                          k_chunk=8)
    p = init_attention(KEY, cfg)
    x = jax.random.normal(KEY, (B, T, d))
    pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    cos, sin = rope_angles(pos, cfg.head_dim)
    y_full, _ = attention_apply(p, x, cfg, cos=cos, sin=sin)
    cache = init_kv_cache(B, T, cfg, jnp.float32)
    outs = []
    for t in range(T):
        ct, st = rope_angles(jnp.full((B, 1), t), cfg.head_dim)
        yt, cache = attention_apply(p, x[:, t:t + 1], cfg, cos=ct, sin=st,
                                    cache=cache, cache_index=jnp.array(t))
        outs.append(yt)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), y_full, atol=2e-3)


def test_windowed_cache_is_ring_buffer():
    cfg = AttentionConfig(d_model=16, n_heads=2, n_kv_heads=2, head_dim=8,
                          window=4)
    cache = init_kv_cache(3, 1000, cfg)
    assert cache["k"].shape == (3, 4, 2, 8)     # window, not max_len


def test_windowed_ring_wraparound_decode():
    """Decode past the window (T=11 steps, window=4): once the ring wraps
    (t >= window) every step must still reproduce the full windowed
    forward — a wrong slot/age mask only shows up AFTER wraparound."""
    B, T, d, W = 2, 11, 16, 4
    cfg = AttentionConfig(d_model=d, n_heads=2, n_kv_heads=2, head_dim=8,
                          window=W)
    p = init_attention(KEY, cfg)
    x = jax.random.normal(KEY, (B, T, d))
    pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    cos, sin = rope_angles(pos, cfg.head_dim)
    y_full, _ = attention_apply(p, x, cfg, cos=cos, sin=sin)
    cache = init_kv_cache(B, T, cfg, jnp.float32)
    for t in range(T):
        ct, st = rope_angles(jnp.full((B, 1), t), cfg.head_dim)
        yt, cache = attention_apply(p, x[:, t:t + 1], cfg, cos=ct, sin=st,
                                    cache=cache, cache_index=jnp.array(t))
        np.testing.assert_allclose(yt[:, 0], y_full[:, t], atol=2e-3,
                                   err_msg=f"step {t} (wrapped: {t >= W})")


@pytest.mark.parametrize("window", [None, 4])
def test_decode_per_row_cache_index_matches_scalar(window):
    """A (B,) cache_index with every row at the same position is bitwise
    the scalar path: the continuous-batching scatter-write and the
    fixed-batch dynamic_update_slice must agree exactly."""
    B, T, d = 2, 6, 16
    cfg = AttentionConfig(d_model=d, n_heads=2, n_kv_heads=2, head_dim=8,
                          window=window)
    p = init_attention(KEY, cfg)
    x = jax.random.normal(KEY, (B, T, d))
    c_s = init_kv_cache(B, T, cfg, jnp.float32)
    c_r = init_kv_cache(B, T, cfg, jnp.float32)
    for t in range(T):
        ct, st = rope_angles(jnp.full((B, 1), t), cfg.head_dim)
        ys, c_s = attention_apply(p, x[:, t:t + 1], cfg, cos=ct, sin=st,
                                  cache=c_s, cache_index=jnp.array(t))
        yr, c_r = attention_apply(p, x[:, t:t + 1], cfg, cos=ct, sin=st,
                                  cache=c_r,
                                  cache_index=jnp.full((B,), t, jnp.int32))
        np.testing.assert_array_equal(np.asarray(ys), np.asarray(yr))
    for a, b in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("window", [None, 4])
def test_prefill_into_cache_matches_sequential_decode(window):
    """Block prefill of a right-padded batch (per-row fill_len), then one
    per-row decode step == each row prefilled token-by-token alone.  This
    is the continuous-batching admit path: padded cache slots must stay
    invisible and (windowed) padded keys must not evict real ones."""
    d, Tpad, max_len = 16, 12, 16
    lens = [8, 5]
    cfg = AttentionConfig(d_model=d, n_heads=2, n_kv_heads=2, head_dim=8,
                          window=window)
    p = init_attention(KEY, cfg)
    x = jax.random.normal(KEY, (2, Tpad, d))
    xt = jax.random.normal(jax.random.PRNGKey(3), (2, 1, d))

    # reference: each row alone, sequential decode over its true length
    refs = []
    for r, L in enumerate(lens):
        cache = init_kv_cache(1, max_len, cfg, jnp.float32)
        for t in range(L):
            ct, st = rope_angles(jnp.full((1, 1), t), cfg.head_dim)
            _, cache = attention_apply(p, x[r:r + 1, t:t + 1], cfg, cos=ct,
                                       sin=st, cache=cache,
                                       cache_index=jnp.array(t))
        ct, st = rope_angles(jnp.full((1, 1), L), cfg.head_dim)
        y, _ = attention_apply(p, xt[r:r + 1], cfg, cos=ct, sin=st,
                               cache=cache, cache_index=jnp.array(L))
        refs.append(y[0, 0])

    # batched: ONE chunked prefill over the padded prompts, then a
    # per-row-index decode step at each row's own length
    cache = init_kv_cache(2, max_len, cfg, jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(Tpad), (2, Tpad))
    cos, sin = rope_angles(pos, cfg.head_dim)
    _, cache = attention_apply(p, x, cfg, cos=cos, sin=sin, cache=cache,
                               cache_index=jnp.array(0),
                               fill_len=jnp.asarray(lens, jnp.int32))
    ci = jnp.asarray(lens, jnp.int32)
    ct, st = rope_angles(ci[:, None], cfg.head_dim)
    y, _ = attention_apply(p, xt, cfg, cos=ct, sin=st, cache=cache,
                           cache_index=ci)
    np.testing.assert_allclose(y[0, 0], refs[0], atol=2e-3)
    np.testing.assert_allclose(y[1, 0], refs[1], atol=2e-3)


def test_mrope_reduces_to_rope_for_text():
    """When (t, h, w) ids coincide, M-RoPE == 1-D RoPE (paper-of-record
    behaviour for text tokens)."""
    T, dh = 16, 16
    pos1 = jnp.broadcast_to(jnp.arange(T), (2, T))
    pos3 = jnp.broadcast_to(pos1, (3, 2, T))
    c1, s1 = rope_angles(pos1, dh)
    c3, s3 = mrope_angles(pos3, dh, (2, 3, 3))
    x = jax.random.normal(KEY, (2, T, 4, dh))
    np.testing.assert_allclose(apply_rope(x, c1, s1), apply_rope(x, c3, s3),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# mamba2 / SSD
# ---------------------------------------------------------------------------

def test_ssd_chunked_matches_sequential():
    """Chunked SSD (train path) == step-by-step recurrence (decode path)."""
    cfg = Mamba2Config(d_model=32, d_state=16, d_head=8, chunk=8)
    p = init_mamba2(KEY, cfg)
    x = 0.5 * jax.random.normal(KEY, (2, 32, 32))
    y_train, _ = mamba2_apply(p, x, cfg)
    cache = init_ssm_cache(2, cfg)
    outs = []
    for t in range(32):
        yt, cache = mamba2_apply(p, x[:, t:t + 1], cfg, cache=cache)
        outs.append(yt)
    np.testing.assert_allclose(y_train, jnp.concatenate(outs, 1), atol=2e-3)


def test_ssd_chunk_size_invariance():
    cfg8 = Mamba2Config(d_model=32, d_state=16, d_head=8, chunk=8)
    cfg16 = Mamba2Config(d_model=32, d_state=16, d_head=8, chunk=16)
    p = init_mamba2(KEY, cfg8)
    x = 0.5 * jax.random.normal(KEY, (2, 32, 32))
    y8, _ = mamba2_apply(p, x, cfg8)
    y16, _ = mamba2_apply(p, x, cfg16)
    np.testing.assert_allclose(y8, y16, atol=1e-3)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def test_moe_routes_and_balances():
    cfg = MoEConfig(d_model=16, d_ff=32, n_experts=4, top_k=2,
                    group_size=32)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 32, 16))
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(aux)) and float(aux) > 0.5   # ~1 when balanced
    g = jax.grad(lambda p: jnp.sum(moe_apply(p, x, cfg)[0] ** 2))(p)
    assert all(bool(jnp.all(jnp.isfinite(t))) for t in jax.tree.leaves(g))


def test_moe_capacity_drops_overflow():
    """With capacity_factor ~0, (almost) all tokens are dropped -> y ~ 0
    (plus shared expert if any)."""
    cfg = MoEConfig(d_model=16, d_ff=32, n_experts=4, top_k=1,
                    capacity_factor=1e-9, group_size=32)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (1, 32, 16))
    y, _ = moe_apply(p, x, cfg)
    # capacity floor is top_k=1 token per (group, expert): at most 4 of 32
    # token slots are routed; the rest contribute exactly zero.
    nonzero_rows = jnp.sum(jnp.any(jnp.abs(y[0]) > 1e-6, axis=-1))
    assert int(nonzero_rows) <= 4


def test_moe_shared_expert_always_on():
    cfg = MoEConfig(d_model=16, d_ff=32, n_experts=4, top_k=1,
                    capacity_factor=1e-9, shared_d_ff=32, group_size=32)
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (1, 32, 16))
    y, _ = moe_apply(p, x, cfg)
    # routed path dead, shared path alive => most rows nonzero
    nonzero_rows = jnp.sum(jnp.any(jnp.abs(y[0]) > 1e-6, axis=-1))
    assert int(nonzero_rows) >= 28


# ---------------------------------------------------------------------------
# GRU (paper §6)
# ---------------------------------------------------------------------------

def test_gru_cell_matches_paper_equations():
    """Dense GRU cell == explicit eqs. 20–23."""
    cfg = GRUConfig(d_in=8, d_hidden=8, linear_impl="dense")
    p = init_gru(KEY, cfg)
    x = jax.random.normal(KEY, (3, 8))
    h = jax.random.normal(jax.random.PRNGKey(1), (3, 8))
    got = gru_cell(p, x, h, cfg)
    z = jax.nn.sigmoid(x @ p["wz"]["w"] + p["wz"]["b"] + h @ p["uz"]["w"])
    r = jax.nn.sigmoid(x @ p["wr"]["w"] + p["wr"]["b"] + h @ p["ur"]["w"])
    ht = jnp.tanh(x @ p["wh"]["w"] + p["wh"]["b"] + (r * h) @ p["uh"]["w"])
    want = (1 - z) * h + z * ht
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_spm_gru_preserves_semantics_and_trains():
    cfg = GRUConfig(d_in=16, d_hidden=16, linear_impl="spm_rotation")
    p = init_gru(KEY, cfg)
    x = jax.random.normal(KEY, (2, 12, 16))
    hs, hT = gru_apply(p, x, cfg)
    assert hs.shape == (2, 12, 16) and hT.shape == (2, 16)
    g = jax.grad(lambda p: jnp.sum(gru_apply(p, x, cfg)[0] ** 2))(p)
    leaves = jax.tree.leaves(g)
    assert all(bool(jnp.all(jnp.isfinite(t))) for t in leaves)
    assert any(float(jnp.max(jnp.abs(t))) > 0 for t in leaves)
