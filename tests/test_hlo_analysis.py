"""Unit tests for the raw HLO-text parsers (``repro.launch.hlo_analysis``)
and the structured matchers layered on them (``repro.analysis.hlo_match``),
on ADVERSARIAL hand-written HLO: async -start/-done twins that must count
once, tuple result shapes, unknown dtypes that must be skipped, and the
``memory_analysis`` degradation path (warn + empty, never crash)."""

import warnings

import pytest

from repro.analysis.hlo_match import (assert_bwd_gather_bounded,
                                      assert_permute_only, list_collectives,
                                      permute_only_violations)
from repro.launch.hlo_analysis import (collective_bytes,
                                       memory_analysis_terms,
                                       parse_shape_bytes)


# ---------------------------------------------------------------------------
# parse_shape_bytes
# ---------------------------------------------------------------------------

def test_parse_shape_bytes_simple_and_rank0():
    assert parse_shape_bytes("f32[8,64]") == 8 * 64 * 4
    assert parse_shape_bytes("bf16[16]") == 32
    assert parse_shape_bytes("f32[]") == 4          # rank-0: one element
    assert parse_shape_bytes("pred[4]") == 4


def test_parse_shape_bytes_tuple_shapes_sum():
    # async collectives carry tuple-typed results: every member counts
    s = "(f32[8,16], u32[], s32[2,2])"
    assert parse_shape_bytes(s) == 8 * 16 * 4 + 4 + 4 * 4


def test_parse_shape_bytes_skips_unknown_dtypes():
    # a token dtype the table doesn't know contributes nothing (instead of
    # crashing or guessing) — layout/opaque annotations stay inert
    assert parse_shape_bytes("token[]") == 0
    assert parse_shape_bytes("(token[], f32[4])") == 16
    assert parse_shape_bytes("opaque123[8]") == 0


# ---------------------------------------------------------------------------
# collective_bytes on adversarial HLO text
# ---------------------------------------------------------------------------

_ASYNC_HLO = """\
HloModule adversarial

ENTRY main {
  p0 = f32[8,16] parameter(0)
  cps = (f32[8,16], f32[8,16]) collective-permute-start(p0), channel_id=1
  cpd = f32[8,16] collective-permute-done(cps)
  ag = f32[8,64] all-gather(cpd), dimensions={1}
  ars = f32[8,16] all-reduce-start(cpd), to_apply=add
  ard = f32[8,16] all-reduce-done(ars)
  ROOT t = tuple(ag, ard)
}
"""


def test_collective_bytes_counts_async_pairs_once():
    cb = collective_bytes(_ASYNC_HLO)
    # the -start line carries a (operand, result) tuple: both members are
    # parsed, but the -done twin adds nothing
    assert cb["collective-permute"] == 2 * 8 * 16 * 4
    assert cb["all-reduce"] == 8 * 16 * 4
    assert cb["all-gather"] == 8 * 64 * 4
    assert cb["total"] == (cb["collective-permute"] + cb["all-reduce"]
                           + cb["all-gather"])


def test_collective_bytes_ignores_non_collective_lines():
    hlo = "x = f32[1024,1024] dot(a, b)\ny = f32[4] add(c, d)\n"
    cb = collective_bytes(hlo)
    assert cb["total"] == 0


# ---------------------------------------------------------------------------
# hlo_match structured matchers
# ---------------------------------------------------------------------------

def test_list_collectives_orders_and_flags_async():
    ops = list_collectives(_ASYNC_HLO)
    kinds = [o.kind for o in ops]
    assert kinds == ["collective-permute", "all-gather", "all-reduce"]
    assert [o.is_async for o in ops] == [True, False, True]
    assert ops[0].line_no < ops[1].line_no < ops[2].line_no


def test_permute_only_violations_and_budgets():
    bad = permute_only_violations(_ASYNC_HLO)
    assert any("all-gather" in b for b in bad)
    assert any("all-reduce" in b for b in bad)
    # generous budgets absorb both; the permute requirement is satisfied
    assert permute_only_violations(
        _ASYNC_HLO, allow={"all-gather": 10**6, "all-reduce": 10**6}) == []
    # an empty module with require_permute flags the vacuous pass
    assert permute_only_violations("ENTRY e { ROOT c = f32[] constant(0) }")


def test_assert_permute_only_raises_with_detail():
    with pytest.raises(AssertionError, match="all-gather"):
        assert_permute_only(_ASYNC_HLO)
    clean = "cp = f32[8,8] collective-permute(p0), channel_id=1\n"
    assert_permute_only(clean)          # no raise


def test_bwd_gather_bound():
    hlo = "ag = f32[256] all-gather(x), dimensions={0}\n"
    assert_bwd_gather_bounded(hlo, param_bytes=512)       # 1024 budget
    with pytest.raises(AssertionError, match="all-gather"):
        assert_bwd_gather_bounded(hlo, param_bytes=100)
    with pytest.raises(AssertionError, match="all-reduce"):
        assert_bwd_gather_bounded(
            "ar = f32[4] all-reduce(x), to_apply=add\n", param_bytes=10**6)


# ---------------------------------------------------------------------------
# memory_analysis_terms degradation (the un-silenced except)
# ---------------------------------------------------------------------------

class _NoAnalysis:
    def memory_analysis(self):
        raise NotImplementedError("backend has no memory analysis")


class _RuntimeFail:
    def memory_analysis(self):
        raise RuntimeError("UNIMPLEMENTED: memory analysis")


class _Bug:
    def memory_analysis(self):
        raise ValueError("a genuine bug, not a backend gap")


class _Ok:
    class _MA:
        argument_size_in_bytes = 128
        output_size_in_bytes = 64
        temp_size_in_bytes = 32

    def memory_analysis(self):
        return self._MA()


def test_memory_analysis_degrades_with_warning_not_silently():
    for compiled in (_NoAnalysis(), _RuntimeFail()):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            assert memory_analysis_terms(compiled) == {}
        assert len(w) == 1
        assert issubclass(w[0].category, RuntimeWarning)
        assert "memory_analysis unavailable" in str(w[0].message)


def test_memory_analysis_reraises_genuine_bugs():
    with pytest.raises(ValueError, match="genuine bug"):
        memory_analysis_terms(_Bug())


def test_memory_analysis_extracts_known_terms():
    terms = memory_analysis_terms(_Ok())
    assert terms == {"argument_size_in_bytes": 128,
                     "output_size_in_bytes": 64,
                     "temp_size_in_bytes": 32}
