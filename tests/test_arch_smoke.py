"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, asserting shapes + no NaNs (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, arch_shapes, get_config, get_smoke
from repro.models import causal_lm as LM
from repro.models import transformer as T
from repro.optim.adamw import OptimizerConfig
from repro.train import make_train_state, make_train_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg):
    b = {"labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if cfg.input_kind == "tokens":
        b["tokens"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    else:
        b["embeds"] = jax.random.normal(KEY, (B, S, cfg.d_model))
        if cfg.rope_kind == "mrope":
            pos = jnp.broadcast_to(jnp.arange(S), (B, S))
            b["positions"] = jnp.broadcast_to(pos, (3, B, S))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    params = T.init_model(KEY, cfg)
    batch = _batch(cfg)
    kw = ({"tokens": batch["tokens"]} if cfg.input_kind == "tokens"
          else {"embeds": batch["embeds"],
                "positions": batch.get("positions")})
    logits, _, aux = T.forward(params, cfg, **kw)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))

    step = make_train_step(lambda p, b: LM.lm_loss(p, b, cfg),
                           OptimizerConfig(lr=1e-3, total_steps=10))
    state = make_train_state(params)
    state2, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["skipped"]) == 0.0
    # params actually moved
    d0 = jax.tree.leaves(state["params"])[0]
    d1 = jax.tree.leaves(state2["params"])[0]
    assert not np.allclose(d0, d1)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters (they are
    exercised via the dry-run only — ShapeDtypeStruct, no allocation)."""
    cfg = get_config(arch)
    expected = {
        "zamba2-1.2b": (38, 2048, 32, 32, 0, 32000),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 0, 151936),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 0, 202048),
        "mamba2-370m": (48, 1024, 16, 16, 0, 50280),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (got, expected)
    if arch == "qwen3-moe-30b-a3b":
        assert (cfg.n_experts, cfg.top_k, cfg.moe_d_ff) == (128, 8, 768)
    if arch == "llama4-scout-17b-a16e":
        assert (cfg.n_experts, cfg.top_k, cfg.moe_d_ff) == (16, 1, 8192)
    if arch == "zamba2-1.2b":
        assert cfg.ssm_state == 64 and cfg.shared_attn_d_ff == 8192
    if arch == "mamba2-370m":
        assert cfg.ssm_state == 128
    if arch == "gemma3-12b":
        # 5:1 local:global
        w = [s.window for s in cfg.layers[:6]]
        assert w == [1024] * 5 + [None]


def test_shape_assignment_gating():
    """long_500k runs only for sub-quadratic archs (DESIGN §4)."""
    for arch in ARCH_IDS:
        names = [s.name for s in arch_shapes(arch)]
        if arch in ("zamba2-1.2b", "gemma3-12b", "mamba2-370m"):
            assert "long_500k" in names
        else:
            assert "long_500k" not in names
        for required in ("train_4k", "prefill_32k", "decode_32k"):
            assert required in names


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-370m",
                                  "zamba2-1.2b"])
def test_smoke_decode_matches_forward(arch):
    """Prefill+decode path == cache-free forward on the smoke config."""
    cfg = get_smoke(arch)
    if cfg.input_kind != "tokens":
        pytest.skip("token archs only")
    params = T.init_model(KEY, cfg)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    full, _, _ = T.forward(params, cfg, tokens=toks)
    last, cache = LM.prefill(params, cfg, max_len=S, tokens=toks,
                             cache_dtype=jnp.float32)
    np.testing.assert_allclose(last, full[:, -1], atol=3e-2, rtol=3e-2)
