"""Continuous-batching engine acceptance (serve/engine.py).

The two contracts the tentpole rests on:

1. BITWISE churn parity — a request decodes the exact same tokens
   whether it shares the slot pool with churning neighbours (mixed
   prompt lengths, temperatures, top-k/top-p, staggered arrivals) or is
   served alone on a single-slot engine.  Per-request PRNG keys
   (``fold_in(base_key, rid)`` folded with the per-request step counter)
   and the per-row-only sampling math make this exact, not approximate.

2. SINGLE-COMPILE decode tick — after one warmup request, serving an
   arbitrary mix of requests adds ZERO executable-cache entries to the
   jitted tick (``analysis/recompile.assert_compiles``): every
   per-request quantity is a traced per-row operand.

Plus the non-finite-logits flag propagation through both engines.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.recompile import assert_compiles
from repro.configs import get_smoke
from repro.models import transformer as T
from repro.serve import ContinuousBatchingEngine, Request, ServeEngine

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_smoke("qwen3-1.7b")
    params = T.init_model(KEY, cfg)
    return cfg, params


def _requests(vocab):
    """A churn mix: every bucket, greedy + sampled, k/p filters on/off."""
    specs = [
        # (prompt_len, max_new, temperature, top_k, top_p)
        (8, 5, 0.0, 0, 1.0),     # greedy, exact-bucket prompt
        (5, 6, 0.8, 0, 1.0),     # plain temperature sampling
        (12, 4, 1.2, 5, 1.0),    # top-k
        (24, 6, 0.7, 0, 0.9),    # top-p
        (7, 3, 1.0, 50, 0.95),   # top-k AND top-p
        (16, 2, 0.0, 0, 1.0),    # greedy again, different bucket
    ]
    reqs = []
    for i, (plen, mnew, temp, k, p) in enumerate(specs):
        prompt = jax.random.randint(jax.random.fold_in(KEY, i), (plen,),
                                    0, vocab)
        reqs.append(Request(prompt=prompt, max_new_tokens=mnew,
                            temperature=temp, top_k=k, top_p=p, rid=i))
    return reqs


def test_continuous_matches_serve_engine_greedy(smoke_model):
    """Greedy decode through the continuous engine == ServeEngine.generate
    on the same prompt (the pre-existing engine is the reference)."""
    cfg, params = smoke_model
    prompts = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    ref = ServeEngine(cfg=cfg, params=params, max_len=24,
                      cache_dtype=jnp.float32)
    out = ref.generate(prompts, max_new_tokens=6)
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_len=24,
                                   cache_dtype=jnp.float32)
    results, stats = eng.serve([Request(prompt=prompts[0],
                                        max_new_tokens=6, rid=0)])
    assert results[0]["tokens"] == [int(t) for t in np.asarray(out[0])]
    assert not results[0]["flagged"]
    assert stats["tokens"] == 6


def test_churn_bitwise_parity_and_single_compile(smoke_model):
    """The acceptance gate: a churning pool (staggered arrivals into 2
    slots, all sampling modes mixed) emits bitwise the same tokens per
    request as a single-slot engine serving each request alone — and the
    whole churn adds zero compiles to the warmed decode tick."""
    cfg, params = smoke_model
    base = jax.random.PRNGKey(7)
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_len=48,
                                   base_key=base)
    # warm the tick (and one prefill bucket); rid outside the churn range
    eng.serve([Request(prompt=jnp.zeros((4,), jnp.int32),
                       max_new_tokens=2, rid=999)])
    reqs = _requests(cfg.vocab_size)
    arrivals = [0, 0, 1, 3, 3, 6]
    with assert_compiles(0, tick=eng._tick):
        results, stats = eng.serve(reqs, arrival_ticks=arrivals)

    alone = ContinuousBatchingEngine(cfg, params, slots=1, max_len=48,
                                     base_key=base)
    for r in _requests(cfg.vocab_size):
        solo, _ = alone.serve([r])
        assert solo[r.rid]["tokens"] == results[r.rid]["tokens"], \
            f"request {r.rid} diverged under churn"
        assert len(results[r.rid]["tokens"]) == r.max_new_tokens

    # schedule accounting: admits respect arrivals and slot capacity
    for i, r in enumerate(reqs):
        res = results[r.rid]
        assert res["admitted_tick"] >= arrivals[i]
        assert res["finished_tick"] >= res["admitted_tick"]
    assert stats["occupied_slot_ticks"] <= stats["ticks"] * eng.slots


def test_sampled_tokens_in_range_and_reproducible(smoke_model):
    """Two serves of the same sampled request reproduce exactly (PRNG is
    keyed on rid + step, not on pool state or wall time)."""
    cfg, params = smoke_model
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_len=32)
    req = lambda: Request(prompt=jnp.arange(6, dtype=jnp.int32),
                          max_new_tokens=8, temperature=1.1, top_k=20,
                          rid=0)
    r1, _ = eng.serve([req()])
    r2, _ = eng.serve([req()])
    assert r1[0]["tokens"] == r2[0]["tokens"]
    assert all(0 <= t < cfg.vocab_size for t in r1[0]["tokens"])


def test_immediate_finish_single_token_request(smoke_model):
    """max_new_tokens=1 finishes at its admit tick: the first token comes
    from the prefill sample, no decode tick is owed."""
    cfg, params = smoke_model
    eng = ContinuousBatchingEngine(cfg, params, slots=2, max_len=16)
    results, stats = eng.serve([Request(prompt=jnp.arange(4, dtype=jnp.int32),
                                        max_new_tokens=1, rid=0)])
    res = results[0]
    assert len(res["tokens"]) == 1
    assert res["finished_tick"] == res["admitted_tick"]
    assert stats["occupied_slot_ticks"] == 0


def test_request_validation(smoke_model):
    cfg, params = smoke_model
    eng = ContinuousBatchingEngine(cfg, params, slots=1, max_len=16)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.serve([Request(prompt=jnp.arange(4, dtype=jnp.int32),
                           max_new_tokens=0)])
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.serve([Request(prompt=jnp.arange(12, dtype=jnp.int32),
                           max_new_tokens=8)])


def test_continuous_engine_rejects_ssm_stacks():
    cfg = get_smoke("mamba2-370m")
    params = T.init_model(KEY, cfg)
    with pytest.raises(ValueError, match="attention-only"):
        ContinuousBatchingEngine(cfg, params, slots=1, max_len=16)


# ---------------------------------------------------------------------------
# non-finite flag propagation
# ---------------------------------------------------------------------------

def test_flags_isolate_poisoned_request(smoke_model):
    """A NaN embedding row poisons ONLY the requests whose prompt uses
    that token: their rows are flagged (every decode step re-raises via
    the NaN KV cache) and degrade to the in-range fallback, while a clean
    request in the same batch stays unflagged.  Untied output projection
    so the poisoned table row cannot leak into every logit column."""
    cfg, _ = smoke_model
    cfg = dataclasses.replace(cfg, tie_embeddings=False)
    params = T.init_model(KEY, cfg)
    poisoned = jax.tree.map(lambda x: x, params)
    poisoned["embed"] = dict(params["embed"])
    poisoned["embed"]["table"] = \
        params["embed"]["table"].at[3].set(jnp.nan)

    # ServeEngine: flags are the union over prefill + every decode step
    eng = ServeEngine(cfg=cfg, params=poisoned, max_len=16,
                      cache_dtype=jnp.float32)
    prompts = jnp.stack([jnp.asarray([1, 2, 3, 4], jnp.int32),   # has 3
                         jnp.asarray([1, 2, 4, 5], jnp.int32)])  # clean
    out, flags = eng.generate(prompts, max_new_tokens=4,
                              return_flags=True)
    assert bool(flags[0]) and not bool(flags[1])
    np.testing.assert_array_equal(np.asarray(out[0]), 0)  # fallback row
    assert bool(((out >= 0) & (out < cfg.vocab_size)).all())

    # continuous engine: per-request ``flagged`` carries the same union
    ceng = ContinuousBatchingEngine(cfg, poisoned, slots=2, max_len=16)
    results, _ = ceng.serve([
        Request(prompt=prompts[0], max_new_tokens=4, rid=0),
        Request(prompt=prompts[1], max_new_tokens=4, rid=1)])
    assert results[0]["flagged"] and not results[1]["flagged"]
    assert results[0]["tokens"] == [0, 0, 0, 0]
    assert all(0 <= t < cfg.vocab_size for t in results[1]["tokens"])
