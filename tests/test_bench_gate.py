"""Unit tests for the CI bench-regression gate
(``benchmarks/check_regression.py``): the comparator that fails a PR when
the freshly generated ``BENCH_kernel.json`` grows a modeled HBM or
exposed-communication metric past the committed baseline.
"""

import copy
import importlib.util
import json
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

spec = importlib.util.spec_from_file_location(
    "check_regression", os.path.join(REPO, "benchmarks",
                                     "check_regression.py"))
cr = importlib.util.module_from_spec(spec)
spec.loader.exec_module(cr)


def _payload():
    return {
        "batch": 64, "linear_batch": 16,
        "results": [{"n": 256, "traffic": {"fused_bytes": 1000,
                                           "fused_roundtrips": 2}}],
        "rect_results": [{"shape": "ffn_up", "d_in": 128, "d_out": 512,
                          "traffic": {"fused_bytes": 500}}],
        "sharded_results": [{
            "n": 256, "L": 8, "n_shards": 8,
            "in_width": None, "out_width": None,
            "modeled": {"hbm_bytes_per_chip": 2000,
                        "permute_bytes_per_chip": 300,
                        "exposed_permute_bytes_per_chip": 300},
            "modeled_overlap": {"exposed_permute_bytes_per_chip": 100},
        }],
    }


def test_identical_payloads_pass():
    regs, dropped, new = cr.compare(_payload(), _payload())
    assert regs == [] and dropped == [] and new == []


def test_growth_past_tolerance_fails_and_names_the_metric():
    fresh = _payload()
    fresh["sharded_results"][0]["modeled_overlap"][
        "exposed_permute_bytes_per_chip"] = 160      # +60% > 2%
    regs, _, _ = cr.compare(_payload(), fresh, tol=0.02)
    assert len(regs) == 1
    key, base, val = regs[0]
    assert "exposed_overlap" in key and (base, val) == (100, 160)


def test_growth_within_tolerance_passes():
    fresh = _payload()
    fresh["results"][0]["traffic"]["fused_bytes"] = 1009   # +0.9%
    regs, _, _ = cr.compare(_payload(), fresh, tol=0.02)
    assert regs == []


def test_improvements_and_new_rows_are_free_dropped_rows_are_not():
    fresh = _payload()
    fresh["results"][0]["traffic"]["fused_bytes"] = 900    # improvement
    fresh["rect_results"].append({"shape": "new", "d_in": 1, "d_out": 2,
                                  "traffic": {"fused_bytes": 7}})
    del fresh["sharded_results"][0]["modeled_overlap"]     # dropped metric
    regs, dropped, new = cr.compare(_payload(), fresh)
    assert regs == []
    assert len(new) == 1 and len(dropped) == 1


def test_cli_end_to_end(tmp_path):
    base_p, fresh_p = tmp_path / "base.json", tmp_path / "fresh.json"
    base_p.write_text(json.dumps(_payload()))
    fresh = _payload()
    fresh_p.write_text(json.dumps(fresh))
    assert cr.main(["--baseline", str(base_p), "--fresh",
                    str(fresh_p)]) == 0
    fresh["sharded_results"][0]["modeled"]["hbm_bytes_per_chip"] = 9999
    fresh_p.write_text(json.dumps(fresh))
    assert cr.main(["--baseline", str(base_p), "--fresh",
                    str(fresh_p)]) == 1
    # scale mismatch is an error, never a vacuous pass
    mism = copy.deepcopy(_payload())
    mism["batch"] = 256
    fresh_p.write_text(json.dumps(mism))
    assert cr.main(["--baseline", str(base_p), "--fresh",
                    str(fresh_p)]) == 2


def test_gate_accepts_the_committed_baseline_against_itself():
    with open(os.path.join(REPO, "BENCH_kernel.json")) as f:
        bench = json.load(f)
    regs, dropped, new = cr.compare(bench, bench)
    assert regs == [] and dropped == [] and new == []
    assert len(cr.gated_metrics(bench)) >= 10


def test_quant_bytes_is_gated_and_growth_fails():
    base = _payload()
    base["rect_results"][0]["traffic"]["quant_bytes"] = 150
    fresh = copy.deepcopy(base)
    fresh["rect_results"][0]["traffic"]["quant_bytes"] = 200
    regs, dropped, new = cr.compare(base, fresh)
    assert len(regs) == 1 and regs[0][0][-1] == "quant_bytes"
    # a payload without the key (pre-quant baseline) simply has no row
    regs, dropped, new = cr.compare(_payload(), base)
    assert regs == [] and dropped == []
    assert [k for k in new if k[-1] == "quant_bytes"]


def test_committed_rect_hot_shapes_meet_quant_reduction_floor():
    """ISSUE 9 acceptance: every rect hot shape in the committed bench is
    int8-eligible with >= 1.8x modeled HBM-byte reduction vs the f32
    fused plan, and the gate actually carries those rows."""
    with open(os.path.join(REPO, "BENCH_kernel.json")) as f:
        bench = json.load(f)
    assert bench["rect_results"], "baseline has no rect rows"
    for r in bench["rect_results"]:
        t = r["traffic"]
        assert t["quant_eligible"], f"{r['shape']}: quant-ineligible plan"
        assert t["quant_reduction"] >= 1.8, \
            f"{r['shape']}: {t['quant_reduction']:.2f}x < 1.8x"
    gated = cr.gated_metrics(bench)
    assert [k for k in gated if k[-1] == "quant_bytes"]


# ---------------------------------------------------------------------------
# compile-contract report gating (repro.analysis driver output)
# ---------------------------------------------------------------------------

def _contract_report(failures=(), cells=None):
    default_cells = {
        "96x256-butterfly/fused": {"kernel_path": True,
                                   "contracts": {"kernel-path-no-pad":
                                                 "pass"}},
        "96x256-butterfly/unfused": {"kernel_path": False,
                                     "contracts": {}},
    }
    return {"schema": 1, "counts": {"contract_checks": 2},
            "failures": list(failures),
            "cells": default_cells if cells is None else cells}


def test_contract_gate_passes_clean_report():
    fails, dropped = cr.compare_contracts(_contract_report(),
                                          _contract_report())
    assert fails == [] and dropped == []


def test_contract_gate_fails_on_contract_failure():
    fresh = _contract_report(
        failures=["96x256-butterfly/fused/kernel-path-no-pad: fail: pad"])
    fails, _ = cr.compare_contracts(fresh, _contract_report())
    assert len(fails) == 1 and "kernel-path-no-pad" in fails[0]


def test_contract_gate_fails_on_dropped_cell_and_lost_kernel_path():
    base = _contract_report()
    # fresh lost one cell entirely and the other fell off the kernel path
    fresh = _contract_report(cells={
        "96x256-butterfly/fused": {"kernel_path": False, "contracts": {}},
    })
    fails, dropped = cr.compare_contracts(fresh, base)
    assert fails == []
    assert len(dropped) == 2
    assert any("missing" in d for d in dropped)
    assert any("fell off the kernel path" in d for d in dropped)


def test_contract_gate_cli(tmp_path):
    base_p, fresh_p = tmp_path / "base.json", tmp_path / "fresh.json"
    base_p.write_text(json.dumps(_payload()))
    fresh_p.write_text(json.dumps(_payload()))
    cb_p, cf_p = tmp_path / "cbase.json", tmp_path / "cfresh.json"
    cb_p.write_text(json.dumps(_contract_report()))
    cf_p.write_text(json.dumps(_contract_report()))
    argv = ["--baseline", str(base_p), "--fresh", str(fresh_p),
            "--contract-report", str(cf_p),
            "--contract-baseline", str(cb_p)]
    assert cr.main(argv) == 0
    cf_p.write_text(json.dumps(_contract_report(
        failures=["x/contract: fail"])))
    assert cr.main(argv) == 1


# ---------------------------------------------------------------------------
# serve-bench gating (BENCH_serve.json payloads)
# ---------------------------------------------------------------------------

def _serve_payload():
    return {
        "schema": "serve_bench/v1", "arch": "qwen3-1.7b", "slots": 4,
        "requests": 12, "max_new": 6, "tick_compiles": 0,
        "loads": [
            {"offered_load": 0.5, "ticks": 40, "tokens": 72,
             "occupancy_milli": 450, "p50_latency_ticks": 4,
             "p99_latency_ticks": 6, "wall_s": 1.0, "tokens_per_s": 72.0},
            {"offered_load": 2.0, "ticks": 16, "tokens": 72,
             "occupancy_milli": 940, "p50_latency_ticks": 8,
             "p99_latency_ticks": 10, "wall_s": 0.5,
             "tokens_per_s": 144.0},
        ],
    }


def test_serve_gate_identical_payloads_pass_and_wall_clock_ignored():
    fresh = _serve_payload()
    fresh["loads"][0]["wall_s"] = 99.0          # wall-clock never gated
    fresh["loads"][0]["tokens_per_s"] = 0.1
    regs, dropped, new = cr.compare(_serve_payload(), fresh,
                                    metrics_fn=cr.gated_serve_metrics)
    assert regs == [] and dropped == [] and new == []


def test_serve_gate_fails_on_latency_occupancy_or_compile_regression():
    for field, worse in [("p99_latency_ticks", 14), ("ticks", 60)]:
        fresh = _serve_payload()
        fresh["loads"][0][field] = worse
        regs, _, _ = cr.compare(_serve_payload(), fresh,
                                metrics_fn=cr.gated_serve_metrics)
        assert len(regs) == 1 and field in regs[0][0]
    # occupancy drop gates as idle growth
    fresh = _serve_payload()
    fresh["loads"][1]["occupancy_milli"] = 500   # idle 60 -> 500
    regs, _, _ = cr.compare(_serve_payload(), fresh,
                            metrics_fn=cr.gated_serve_metrics)
    assert len(regs) == 1 and "idle_milli" in regs[0][0]
    # a retracing decode tick is a hard failure
    fresh = _serve_payload()
    fresh["tick_compiles"] = 3
    regs, _, _ = cr.compare(_serve_payload(), fresh,
                            metrics_fn=cr.gated_serve_metrics)
    assert len(regs) == 1 and "tick_compiles" in regs[0][0]


def test_serve_gate_cli(tmp_path):
    base_p, fresh_p = tmp_path / "base.json", tmp_path / "fresh.json"
    base_p.write_text(json.dumps(_payload()))
    fresh_p.write_text(json.dumps(_payload()))
    sb_p, sf_p = tmp_path / "sbase.json", tmp_path / "sfresh.json"
    sb_p.write_text(json.dumps(_serve_payload()))
    sf_p.write_text(json.dumps(_serve_payload()))
    argv = ["--baseline", str(base_p), "--fresh", str(fresh_p),
            "--serve-baseline", str(sb_p), "--serve-fresh", str(sf_p)]
    assert cr.main(argv) == 0
    bad = _serve_payload()
    bad["loads"][1]["p50_latency_ticks"] = 12
    sf_p.write_text(json.dumps(bad))
    assert cr.main(argv) == 1
    # serve scale mismatch is an error, never a vacuous pass
    mism = _serve_payload()
    mism["slots"] = 8
    sf_p.write_text(json.dumps(mism))
    assert cr.main(argv) == 2
    # --serve-fresh without a baseline is an error
    assert cr.main(["--baseline", str(base_p), "--fresh", str(fresh_p),
                    "--serve-fresh", str(sf_p)]) == 2


def test_serve_gate_accepts_the_committed_baseline_against_itself():
    with open(os.path.join(REPO, "BENCH_serve.json")) as f:
        bench = json.load(f)
    regs, dropped, new = cr.compare(bench, bench,
                                    metrics_fn=cr.gated_serve_metrics)
    assert regs == [] and dropped == [] and new == []
    assert bench["tick_compiles"] == 0      # the single-compile contract
    assert len(cr.gated_serve_metrics(bench)) >= 10
