"""Direct unit tests for the consolidated eligibility/fallback matrix
(``core/eligibility.py``) — the single module the kernel path, the
distributed executor, and the overlap schedule all resolve through — plus
the back-compat re-export surface the older call sites still import.
"""

import pytest

from repro.core.eligibility import (kernel_eligible, overlap_segments,
                                    plan_steps, resolve_overlap,
                                    resolve_rdma, resolve_shard_kernel,
                                    sharded_eligible, use_fused_kernel)
from repro.core.spm import SPMConfig


def _cfg(**kw):
    base = dict(n=64, n_stages=6, schedule="two_level", n_shards=4,
                backward="custom")
    base.update(kw)
    return SPMConfig(**base)


# ---------------------------------------------------------------------------
# single-device kernel predicates
# ---------------------------------------------------------------------------

def test_kernel_eligible_matrix():
    assert kernel_eligible(_cfg())
    assert not kernel_eligible(_cfg(n=63, n_shards=1))          # odd n
    assert not kernel_eligible(_cfg(schedule="random",
                                    n_shards=1))        # permutation pairs
    assert not kernel_eligible(_cfg(variant="rotation",
                                    backward="custom_inverse"))
    # n_shards > 1 alone is NOT an exclusion (routing happens upstream)
    assert kernel_eligible(_cfg(n_shards=8, n_stages=8))


def test_use_fused_kernel_tri_state(monkeypatch):
    import jax
    assert use_fused_kernel(_cfg(use_kernel=True))      # force: on anywhere
    assert not use_fused_kernel(_cfg(use_kernel=False))
    assert not use_fused_kernel(_cfg(use_kernel=True, n=63, n_shards=1))
    # auto follows the backend
    auto = _cfg(use_kernel=None)
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert not use_fused_kernel(auto)
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert use_fused_kernel(auto)


# ---------------------------------------------------------------------------
# distributed-executor predicates
# ---------------------------------------------------------------------------

def test_sharded_eligible_matrix():
    assert sharded_eligible(_cfg())
    assert not sharded_eligible(_cfg(n_shards=1))
    assert not sharded_eligible(_cfg(n=24, n_stages=4, n_shards=8))
    assert not sharded_eligible(_cfg(variant="rotation",
                                     backward="custom_inverse"))
    assert not sharded_eligible(_cfg(schedule="random", n_stages=4))


def test_resolve_shard_kernel():
    steps = plan_steps(64, _cfg().pairing.strides(), 4)
    assert resolve_shard_kernel(_cfg(use_kernel=True), steps, False)
    assert not resolve_shard_kernel(_cfg(use_kernel=False), steps, True)
    assert resolve_shard_kernel(_cfg(use_kernel=None), steps, True)
    assert not resolve_shard_kernel(_cfg(use_kernel=None), steps, False)
    # a schedule with no local steps has nothing to fuse
    no_local = (("cross", 0, 1), ("cross", 1, 2))
    assert not resolve_shard_kernel(_cfg(use_kernel=True), no_local, True)


# ---------------------------------------------------------------------------
# overlap schedule
# ---------------------------------------------------------------------------

def test_overlap_segments_pairs_local_with_following_cross():
    local_a = ("local", 0, (1, 2, 4, 8))
    cross_1 = ("cross", 4, 1)
    cross_2 = ("cross", 5, 2)
    local_b = ("local", 6, (1,))
    segs = overlap_segments((local_a, cross_1, cross_2, local_b))
    assert segs == (("pair", local_a, cross_1), ("one", cross_2),
                    ("one", local_b))
    # trailing local after a pair; consecutive pairs chain greedily
    segs = overlap_segments((local_a, cross_1, local_b, cross_2))
    assert segs == (("pair", local_a, cross_1), ("pair", local_b, cross_2))
    assert overlap_segments((local_a,)) == (("one", local_a),)
    assert overlap_segments(()) == ()


def test_resolve_overlap_tri_state():
    cfg = _cfg()
    steps = plan_steps(64, cfg.pairing.strides(), 4)
    assert any(s[0] == "cross" for s in steps)
    # explicit off wins everywhere
    assert not resolve_overlap(_cfg(overlap=False), steps, True)
    # force engages off-TPU (the ppermute-transport proof path)
    assert resolve_overlap(_cfg(overlap=True), steps, False)
    # auto is TPU-only
    assert resolve_overlap(_cfg(overlap=None), steps, True)
    assert not resolve_overlap(_cfg(overlap=None), steps, False)
    # a communication-free schedule has nothing to overlap, even forced
    all_local = (("local", 0, (1, 2)),)
    assert not resolve_overlap(_cfg(overlap=True), all_local, True)


def test_resolve_rdma_requires_compiled_tpu_kernels():
    assert resolve_rdma(True, True, False)
    assert not resolve_rdma(False, True, False)   # no kernel path
    assert not resolve_rdma(True, False, False)   # no TPU backend
    assert not resolve_rdma(True, True, True)     # interpret mode


# ---------------------------------------------------------------------------
# back-compat re-exports
# ---------------------------------------------------------------------------

def test_reexports_are_the_same_objects():
    from repro.core import spm as spm_mod
    from repro.parallel import spm_shard
    assert spm_mod.kernel_eligible is kernel_eligible
    assert spm_mod.use_fused_kernel is use_fused_kernel
    assert spm_shard.sharded_eligible is sharded_eligible
    assert spm_shard.plan_steps is plan_steps


def test_plan_steps_still_rejects_non_shardable_strides():
    with pytest.raises(ValueError):
        plan_steps(64, (3,), 4)
    with pytest.raises(ValueError):
        plan_steps(48, (8,), 8)
