"""Pairing-schedule unit tests (paper §2.1, §5; DESIGN.md §3.1/§3.4)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import pairings as P


def test_valid_strides():
    assert P.valid_strides(8) == [1, 2, 4]
    assert P.valid_strides(12) == [1, 2, 3, 6]
    assert P.valid_strides(6) == [1, 3]


@settings(max_examples=25, deadline=None)
@given(n=st.sampled_from([4, 8, 16, 48, 96, 256, 768]),
       L=st.integers(1, 16))
def test_butterfly_stages_are_valid(n, L):
    sched = P.butterfly_schedule(n, L)
    assert sched.n_stages == L
    for st_ in sched.stages:
        assert st_.structured and n % (2 * st_.stride) == 0


def test_butterfly_connects_non_power_of_two():
    # n = 96 = 2^5 * 3: cross strides must connect the three 32-blocks
    sched = P.butterfly_schedule(96, 7)
    assert P.connectivity_components(sched) == 1


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([8, 9, 17, 33]), L=st.integers(1, 6),
       seed=st.integers(0, 5))
def test_random_schedule_is_disjoint_pairing(n, L, seed):
    sched = P.random_schedule(n, L, seed=seed)
    for st_ in sched.stages:
        perm = st_.perm
        assert sorted(perm) == list(range(n))   # a permutation => disjoint


def test_two_level_orders_local_before_cross():
    """DESIGN §3.4: shard-local strides first, then cross-shard strides."""
    n, shards = 256, 8
    n_local = n // shards
    sched = P.two_level_schedule(n, 8, shards)
    strides = sched.strides()
    seen_cross = False
    for s in strides:
        if s >= n_local:
            seen_cross = True
        else:
            assert not seen_cross, strides
    assert any(s >= n_local for s in strides)      # has cross-shard stages
    assert P.connectivity_components(sched) == 1


def test_two_level_cross_strides_are_shard_aligned():
    n, shards = 128, 4
    n_local = n // shards
    sched = P.two_level_schedule(n, 10, shards)
    for s in sched.strides():
        if s >= n_local:
            assert s % n_local == 0    # partner = shard j XOR k


def test_default_n_stages_matches_paper():
    # paper: L = log2 n, capped (paper uses fixed L=12 at n=2048/4096)
    assert P.default_n_stages(2048) == 11
    assert P.default_n_stages(4096) == 12
    assert P.default_n_stages(1 << 20) == 12   # cap
    assert P.default_n_stages(8) == 3


def test_make_schedule_dispatch():
    for kind in ("butterfly", "brick", "random"):
        s = P.make_schedule(kind, 16, 4)
        assert s.n_stages == 4
    s = P.make_schedule("two_level", 64, 6, n_shards=4)
    assert s.n_stages == 6
    with pytest.raises(ValueError):
        P.make_schedule("nope", 16, 4)
