"""Pairing-schedule unit tests (paper §2.1, §5; DESIGN.md §3.1/§3.4)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import pairings as P


def test_valid_strides():
    assert P.valid_strides(8) == [1, 2, 4]
    assert P.valid_strides(12) == [1, 2, 3, 6]
    assert P.valid_strides(6) == [1, 3]


@settings(max_examples=25, deadline=None)
@given(n=st.sampled_from([4, 8, 16, 48, 96, 256, 768]),
       L=st.integers(1, 16))
def test_butterfly_stages_are_valid(n, L):
    sched = P.butterfly_schedule(n, L)
    assert sched.n_stages == L
    for st_ in sched.stages:
        assert st_.structured and n % (2 * st_.stride) == 0


def test_butterfly_connects_non_power_of_two():
    # n = 96 = 2^5 * 3: cross strides must connect the three 32-blocks
    sched = P.butterfly_schedule(96, 7)
    assert P.connectivity_components(sched) == 1


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([8, 9, 17, 33]), L=st.integers(1, 6),
       seed=st.integers(0, 5))
def test_random_schedule_is_disjoint_pairing(n, L, seed):
    sched = P.random_schedule(n, L, seed=seed)
    for st_ in sched.stages:
        perm = st_.perm
        assert sorted(perm) == list(range(n))   # a permutation => disjoint


def test_two_level_orders_local_before_cross():
    """DESIGN §3.4: shard-local strides first, then cross-shard strides."""
    n, shards = 256, 8
    n_local = n // shards
    sched = P.two_level_schedule(n, 8, shards)
    strides = sched.strides()
    seen_cross = False
    for s in strides:
        if s >= n_local:
            seen_cross = True
        else:
            assert not seen_cross, strides
    assert any(s >= n_local for s in strides)      # has cross-shard stages
    assert P.connectivity_components(sched) == 1


def test_two_level_cross_strides_are_shard_aligned():
    n, shards = 128, 4
    n_local = n // shards
    sched = P.two_level_schedule(n, 10, shards)
    for s in sched.strides():
        if s >= n_local:
            assert s % n_local == 0    # partner = shard j XOR k


# ---------------------------------------------------------------------------
# two_level invariants the distributed executor (parallel/spm_shard.py)
# relies on — property-tested under real hypothesis AND the conftest shim.
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(n=st.sampled_from([16, 64, 96, 256, 768]),
       shards=st.sampled_from([2, 4, 8]),
       L=st.integers(1, 12))
def test_two_level_locals_precede_crosses_each_cycle(n, shards, L):
    """Every stage is valid for n, and within each repetition of the stride
    cycle all shard-local strides come before all cross-shard strides."""
    sched = P.two_level_schedule(n, L, shards)
    strides = sched.strides()
    n_local = n // shards
    for s in strides:
        assert n % (2 * s) == 0
    local = sorted({s for s in strides if s < n_local})
    cross = sorted({s for s in strides if s >= n_local})
    cycle = local + cross
    assert list(strides) == [cycle[i % len(cycle)] for i in range(L)]


@settings(max_examples=40, deadline=None)
@given(n=st.sampled_from([16, 48, 64, 96, 256]),
       shards=st.sampled_from([2, 4, 8]))
def test_two_level_cross_partner_is_j_xor_k(n, shards):
    """Every cross stride is k * n_local with power-of-two k, and its pairs
    connect shard j to shard j XOR k at the same local lane offset — the
    collective_permute partner-exchange contract.  (The old builder emitted
    e.g. stride 8 for n=48, 8 shards — straddling n_local=6 blocks.)"""
    n_local = n // shards
    sched = P.two_level_schedule(n, 16, shards)
    crosses = [s for s in sched.strides() if s >= n_local]
    for stage in sched.stages:
        s = stage.stride
        if s < n_local:
            assert n_local % (2 * s) == 0      # shard-local stage
            continue
        k, rem = divmod(s, n_local)
        assert rem == 0 and (k & (k - 1)) == 0 and shards % (2 * k) == 0
        pairs = P._stage_pairs(stage, n)
        shard_of, lane_of = pairs // n_local, pairs % n_local
        assert np.all((shard_of[:, 0] ^ shard_of[:, 1]) == k)
        assert np.all(lane_of[:, 0] == lane_of[:, 1])
    if shards in (2, 4, 8):
        assert crosses, "two_level must mix across shards"


@settings(max_examples=30, deadline=None)
@given(n=st.sampled_from([16, 48, 64, 96, 240]),
       shards=st.sampled_from([2, 3, 4, 6, 8, 12]))
def test_two_level_connects_all_coordinates(n, shards):
    """A full cycle of the schedule couples every coordinate with every
    other — including NON-power-of-two shard counts, where the cross list
    needs the odd-factor shard-graph strides (a pure-XOR cross set would
    leave disconnected shard groups, e.g. 48/6)."""
    if n % shards:
        return
    sched = P.two_level_schedule(n, 16, shards)
    assert P.connectivity_components(sched) == 1


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([16, 24, 48]), L=st.integers(1, 8))
def test_two_level_no_local_stride_fallback(n, L):
    """n_local == 1 (or odd n_local) leaves no valid shard-local stride:
    the builder falls back to local = [1], which is still a valid stage for
    the unsharded executor (such schedules simply stay off the distributed
    path)."""
    shards = n        # n_local == 1: stride 1 cannot be shard-local
    sched = P.two_level_schedule(n, L, shards)
    strides = sched.strides()
    assert 1 in set(strides) or L < 1
    for s in strides:
        assert n % (2 * s) == 0
    assert sched.n_stages == L


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([10, 50, 100]), shards=st.sampled_from([3, 7, 8]))
def test_two_level_indivisible_raises(n, shards):
    if n % shards == 0:
        return   # divisible combos are the other tests' domain
    with pytest.raises(ValueError):
        P.two_level_schedule(n, 4, shards)


def test_default_n_stages_matches_paper():
    # paper: L = log2 n, capped (paper uses fixed L=12 at n=2048/4096)
    assert P.default_n_stages(2048) == 11
    assert P.default_n_stages(4096) == 12
    assert P.default_n_stages(1 << 20) == 12   # cap
    assert P.default_n_stages(8) == 3


def test_make_schedule_dispatch():
    for kind in ("butterfly", "brick", "random"):
        s = P.make_schedule(kind, 16, 4)
        assert s.n_stages == 4
    s = P.make_schedule("two_level", 64, 6, n_shards=4)
    assert s.n_stages == 6
    with pytest.raises(ValueError):
        P.make_schedule("nope", 16, 4)
