"""Training substrate: optimizer, accumulation, NaN guard, checkpoints,
deterministic data, fault policy, compression."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import (DeterministicLoader, TeacherConfig, build_corpus,
                        hashed_text_batch, make_teacher, teacher_batch)
from repro.data.hashed_text import HashedTextConfig
from repro.models import MLPConfig, init_mlp, mlp_loss
from repro.optim import (OptimizerConfig, adamw_update, clip_by_global_norm,
                         cosine_schedule, ef_step, global_norm,
                         init_opt_state, init_residual)
from repro.optim.compression import compress, decompress
from repro.train import (FaultPolicy, latest_step, list_checkpoints,
                         make_train_state, make_train_step,
                         restore_checkpoint, save_checkpoint)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_cosine_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.array(s))) for s in
           (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 0.06          # mid-warmup
    assert lrs[2] == pytest.approx(1.0, abs=0.02)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=0.02)


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(10.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_adamw_decreases_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_opt_state(params)
    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=1000,
                          weight_decay=0.0)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


# ---------------------------------------------------------------------------
# train step: convergence, accumulation, NaN guard
# ---------------------------------------------------------------------------

def _mlp_setup(impl="spm_general", width=64):
    cfg = MLPConfig(n_features=width, n_classes=10, linear_impl=impl)
    tc = TeacherConfig(width=width)
    teacher = make_teacher(tc)
    loader = DeterministicLoader(
        lambda k, n: teacher_batch(teacher, tc, k, n), 64, seed=1)
    return cfg, loader


def test_train_step_learns_teacher():
    cfg, loader = _mlp_setup()
    state = make_train_state(init_mlp(KEY, cfg))
    step = jax.jit(make_train_step(lambda p, b: mlp_loss(p, b, cfg),
                                   OptimizerConfig(lr=3e-3,
                                                   total_steps=150)))
    accs = []
    for s in range(150):
        state, m = step(state, loader.batch_at(s))
        accs.append(float(m["acc"]))
    assert np.mean(accs[-10:]) > np.mean(accs[:10]) + 0.2


def test_grad_accumulation_matches_full_batch():
    cfg, loader = _mlp_setup(width=32)
    params = init_mlp(KEY, cfg)
    batch = loader.batch_at(0)
    s1 = make_train_state(params)
    s2 = make_train_state(params)
    ocfg = OptimizerConfig(lr=1e-2, total_steps=10)
    st1 = jax.jit(make_train_step(lambda p, b: mlp_loss(p, b, cfg), ocfg))
    st4 = jax.jit(make_train_step(lambda p, b: mlp_loss(p, b, cfg), ocfg,
                                  accum_steps=4))
    s1, m1 = st1(s1, batch)
    s2, m2 = st4(s2, batch)
    # same data, same params: accumulated grads == full-batch grads
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_nan_guard_skips_update():
    cfg, loader = _mlp_setup(width=32)
    state = make_train_state(init_mlp(KEY, cfg))
    step = jax.jit(make_train_step(lambda p, b: mlp_loss(p, b, cfg),
                                   OptimizerConfig(total_steps=10)))
    bad = {"x": jnp.full((8, 32), jnp.nan),
           "y": jnp.zeros((8,), jnp.int32)}
    state2, m = step(state, bad)
    assert float(m["skipped"]) == 1.0
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(state2["params"])):
        np.testing.assert_allclose(a, b)
    assert int(state2["step"]) == 1   # step counter still advances


def test_fault_policy_rollback_threshold():
    pol = FaultPolicy(max_consecutive_skips=3)
    assert not pol.on_metrics({"skipped": 1.0})
    assert not pol.on_metrics({"skipped": 1.0})
    assert pol.on_metrics({"skipped": 1.0})       # third in a row
    pol.reset()
    assert not pol.on_metrics({"skipped": 0.0})
    assert pol.total_skips == 3


def test_fault_policy_counts_consecutive_not_total():
    """A clean step resets the consecutive counter: sporadic skips never
    trip the rollback, only an unbroken run of them does — and a recovery
    reset() clears the streak while keeping lifetime accounting."""
    pol = FaultPolicy(max_consecutive_skips=3)
    for _ in range(5):                            # alternating skip/clean
        assert not pol.on_metrics({"skipped": 1.0})
        assert not pol.on_metrics({"skipped": 0.0})
    assert pol.total_skips == 5 and pol.consecutive_skips == 0
    assert not pol.on_metrics({"skipped": 1.0})
    assert not pol.on_metrics({"skipped": 1.0})
    pol.reset()                                   # recovery mid-streak
    assert not pol.on_metrics({"skipped": 1.0})   # streak restarts at 1
    assert pol.total_skips == 8


def test_nan_guard_skips_under_accumulation():
    """One poisoned microbatch inside an accumulated step must skip the
    WHOLE update (the non-finite term contaminates the summed grads) —
    the skipped metric and pass-through hold at accum_steps > 1."""
    cfg, loader = _mlp_setup(width=32)
    state = make_train_state(init_mlp(KEY, cfg))
    step = jax.jit(make_train_step(lambda p, b: mlp_loss(p, b, cfg),
                                   OptimizerConfig(total_steps=10),
                                   accum_steps=4))
    good = loader.batch_at(0)
    x = np.asarray(good["x"]).copy()
    x[2] = np.nan                    # one row -> one bad microbatch
    bad = {"x": jnp.asarray(x), "y": good["y"]}
    state2, m = step(state, bad)
    assert float(m["skipped"]) == 1.0
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(state2["params"])):
        np.testing.assert_array_equal(a, b)
    assert int(state2["opt"]["count"]) == 0       # schedule did not advance
    state3, m = step(state, good)
    assert float(m["skipped"]) == 0.0
    assert int(state3["opt"]["count"]) == 1


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_atomic_keepN_resume():
    cfg, loader = _mlp_setup(width=32)
    state = make_train_state(init_mlp(KEY, cfg))
    with tempfile.TemporaryDirectory() as d:
        for s in (10, 20, 30, 40, 50):
            save_checkpoint(d, s, state,
                            extra={"cursor": {"seed": 1, "step": s}},
                            keep=3)
        assert list_checkpoints(d) == [30, 40, 50]
        assert latest_step(d) == 50
        restored, extra = restore_checkpoint(d, state)
        assert extra["cursor"]["step"] == 50
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_allclose(a, b)
        # no stale tmp dirs (atomicity)
        assert not [f for f in os.listdir(d) if f.startswith("tmp.")]


def test_checkpoint_crash_leftovers_are_gcd_and_publish_is_nondestructive():
    """A crashed save leaves a tmp.* staging dir; the next save must GC it.
    Re-saving an existing step must republish without ever having deleted
    the published payload before the new one landed."""
    cfg, loader = _mlp_setup(width=32)
    state = make_train_state(init_mlp(KEY, cfg))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 10, state, extra={"v": 1})
        # simulate a crash mid-save: stale staging dir with partial payload
        stale = os.path.join(d, "tmp.20.deadbeef")
        os.makedirs(stale)
        with open(os.path.join(stale, "arrays.npz"), "w") as f:
            f.write("partial")
        # overwrite step 10 with new extra; stale dir must be collected
        save_checkpoint(d, 10, state, extra={"v": 2})
        assert not [f for f in os.listdir(d) if f.startswith("tmp.")]
        assert list_checkpoints(d) == [10]
        _, extra = restore_checkpoint(d, state)
        assert extra["v"] == 2


def test_checkpoint_crash_mid_republish_is_recovered():
    """A crash between the two renames of a same-step re-save leaves the
    step unpublished, with complete payloads stranded in staging (the new
    one at tmp.<s>.<nonce>, the old at tmp.<s>.<nonce>.displaced).  The
    next save must REPUBLISH (preferring the fresh payload) instead of
    sweeping the only copies of the step."""
    cfg, loader = _mlp_setup(width=32)
    state = make_train_state(init_mlp(KEY, cfg))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 10, state, extra={"v": "old"})
        save_checkpoint(d, 11, state, extra={"v": "new"})
        # simulate the crash window: step 10's published copy was moved
        # aside and the re-save's fresh payload never landed on step_10
        os.rename(os.path.join(d, "step_10"),
                  os.path.join(d, "tmp.10.aaaa1111.displaced"))
        os.rename(os.path.join(d, "step_11"),
                  os.path.join(d, "tmp.10.aaaa1111"))
        assert list_checkpoints(d) == []
        # the RESUME path (latest_step / restore_checkpoint) must recover
        # on its own — a restarting trainer reads before it ever saves
        assert latest_step(d) == 10
        save_checkpoint(d, 20, state, extra={"v": 3})
        assert list_checkpoints(d) == [10, 20]
        assert not [f for f in os.listdir(d) if f.startswith("tmp.")]
        _, extra = restore_checkpoint(d, state, step=10)
        assert extra["v"] == "new"   # fresh payload won over the displaced


def test_resume_is_bitwise_reproducible():
    """Train 10 steps straight == train 5, checkpoint, restore, train 5."""
    cfg, loader = _mlp_setup(width=32)
    ocfg = OptimizerConfig(lr=1e-2, total_steps=20)
    step = jax.jit(make_train_step(lambda p, b: mlp_loss(p, b, cfg), ocfg))

    sA = make_train_state(init_mlp(KEY, cfg))
    for s in range(10):
        sA, _ = step(sA, loader.batch_at(s))

    sB = make_train_state(init_mlp(KEY, cfg))
    for s in range(5):
        sB, _ = step(sB, loader.batch_at(s))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 5, sB, extra={"cursor": {"seed": 1, "step": 5}})
        sB, extra = restore_checkpoint(d, sB)
    for s in range(int(extra["cursor"]["step"]), 10):
        sB, _ = step(sB, loader.batch_at(s))

    for a, b in zip(jax.tree.leaves(sA["params"]),
                    jax.tree.leaves(sB["params"])):
        np.testing.assert_allclose(a, b, atol=1e-6)


# ---------------------------------------------------------------------------
# data determinism + compression
# ---------------------------------------------------------------------------

def test_loader_determinism_and_host_sharding():
    tc = TeacherConfig(width=16)
    teacher = make_teacher(tc)
    fn = lambda k, n: teacher_batch(teacher, tc, k, n)
    full = DeterministicLoader(fn, 32, seed=3)
    h0 = DeterministicLoader(fn, 32, seed=3, n_hosts=4, host_id=0)
    h3 = DeterministicLoader(fn, 32, seed=3, n_hosts=4, host_id=3)
    b = full.batch_at(7)
    np.testing.assert_allclose(h0.batch_at(7)["x"], b["x"][:8])
    np.testing.assert_allclose(h3.batch_at(7)["x"], b["x"][24:])


def test_corpus_is_deterministic_and_textlike():
    c1 = build_corpus(30_000, seed=2)
    c2 = build_corpus(30_000, seed=2)
    np.testing.assert_array_equal(c1, c2)
    # mostly printable ASCII
    printable = np.mean((c1 >= 32) & (c1 < 127) | (c1 == 10))
    assert printable > 0.95


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(0.01, 100.0))
def test_int8_roundtrip_error_bound(scale):
    x = scale * jax.random.normal(KEY, (256,))
    q, s = compress(x)
    err = jnp.max(jnp.abs(decompress(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-6   # half-ULP of the quantizer


def test_error_feedback_accumulates_residual():
    g = {"w": 0.01 * jax.random.normal(KEY, (64,))}
    r = init_residual(g)
    # two EF steps: residual carries quantization error forward
    gq1, r1 = ef_step(g, r)
    gq2, r2 = ef_step(g, r1)
    # sum of transmitted approximates sum of true grads better than 2x solo
    true_sum = 2 * g["w"]
    ef_sum = gq1["w"] + gq2["w"]
    solo_err = jnp.linalg.norm(2 * gq1["w"] - true_sum)
    ef_err = jnp.linalg.norm(ef_sum - true_sum)
    assert float(ef_err) <= float(solo_err) + 1e-6


# ---------------------------------------------------------------------------
# accumulation metrics (regression: last-microbatch reporting)
# ---------------------------------------------------------------------------

def test_accum_metrics_cover_whole_batch_not_last_micro():
    """Regression: the accum path used to report ONLY the last
    microbatch's metrics (``tree.map(lambda m: m[-1], metrics)``).  With
    an uneven mask across microbatches, accum=4 must log the same
    mask-weighted ce as accum=1 on the identical batch — and emphatically
    not the last micro's ce."""
    w0 = jnp.zeros((4,))
    x = jax.random.normal(KEY, (8, 4))
    # micro 0 fully masked out; micros 1-3 carry 1, 4, 8 live tokens:
    # last-micro ce, plain-mean ce, and weighted ce all differ
    mask = jnp.zeros((8, 4)).at[2, 0].set(1.0).at[4:6, :2].set(1.0) \
        .at[6:8, :].set(1.0)

    def loss_fn(p, b):
        per_tok = (b["x"] - p["w"]) ** 2
        wsum = jnp.sum(b["mask"])
        ce = jnp.sum(per_tok * b["mask"]) / jnp.maximum(wsum, 1.0)
        return ce, {"ce": ce, "ce_weight": wsum,
                    "ppl_proxy": jnp.exp(jnp.clip(ce, max=20.0)),
                    "aux": jnp.mean(per_tok)}

    ocfg = OptimizerConfig(lr=1e-2, total_steps=10)
    st1 = jax.jit(make_train_step(loss_fn, ocfg))
    st4 = jax.jit(make_train_step(loss_fn, ocfg, accum_steps=4))
    batch = {"x": x, "mask": mask}
    _, m1 = st1(make_train_state({"w": w0}), batch)
    _, m4 = st4(make_train_state({"w": w0}), batch)
    np.testing.assert_allclose(float(m4["ce"]), float(m1["ce"]), rtol=1e-6)
    np.testing.assert_allclose(float(m4["ce_weight"]),
                               float(m1["ce_weight"]), rtol=1e-6)
    np.testing.assert_allclose(float(m4["ppl_proxy"]),
                               float(m1["ppl_proxy"]), rtol=1e-6)
    # the buggy value (last micro alone) is measurably different
    last_ce, _ = loss_fn({"w": w0}, {"x": x[6:], "mask": mask[6:]})
    assert abs(float(last_ce) - float(m1["ce"])) > 1e-3
    # unweighted metrics take the plain mean over microbatches
    aux_mean = np.mean([float(loss_fn({"w": w0},
                                      {"x": x[i:i + 2],
                                       "mask": mask[i:i + 2]})[1]["aux"])
                        for i in range(0, 8, 2)])
    np.testing.assert_allclose(float(m4["aux"]), aux_mean, rtol=1e-6)


def test_psum_compressed_uses_axis_max_scale():
    """``psum_compressed`` under a named axis (vmap stands in for
    shard_map): every member quantizes against the axis-MAX scale —
    members agree on the dequant grid — and the result matches the
    explicit int8-sum reference.  Also pins the dead-work fix: the scale
    comes straight from absmax/127, not from a discarded local
    compress()."""
    from repro.optim.compression import _amax_scale, psum_compressed
    g = jnp.stack([0.01 * jax.random.normal(KEY, (64,)),
                   3.0 * jax.random.normal(jax.random.PRNGKey(1), (64,))])
    out = jax.vmap(lambda gi: psum_compressed({"w": gi}, "i"),
                   axis_name="i")(g)["w"]
    s_max = float(jnp.maximum(_amax_scale(g[0]), _amax_scale(g[1])))
    q = np.clip(np.round(np.asarray(g, np.float64) / s_max), -127, 127)
    ref = q.sum(axis=0) * s_max
    np.testing.assert_allclose(np.asarray(out[0]), ref, rtol=1e-5,
                               atol=1e-7)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(out[1]))
    # quantization error is bounded by half an ULP of the shared grid
    assert float(np.max(np.abs(ref - np.asarray(g.sum(0))))) <= s_max + 1e-9
