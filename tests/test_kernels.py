"""Pallas kernel allclose sweeps vs the pure-jnp oracle (kernels/ref.py).

Shape x dtype sweep per instructions; interpret mode on CPU.  Covers the
bare stage stack AND the full folded operator (diag + bias) forward and
backward, plus the knob plumbing through spm_apply / linear_apply."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SPMConfig, init_spm, kernel_eligible, spm_apply,
                        use_fused_kernel)
from repro.core.eligibility import quant_acts_eligible
from repro.core.linear import LinearConfig, init_linear, linear_apply
from repro.core.spm import stage_coeffs
from repro.kernels import quant as Q
from repro.kernels.ops import (plan_runs, spm_stack_fused, spm_stack_fused_q8,
                               tile_cap_for_rows)
from repro.kernels.ref import (spm_full_ref, spm_stack_grads_ref,
                               spm_stack_ref)
from repro.kernels.spm_stack import (pick_block_rows, spm_stack_bwd_kernel_call,
                                     spm_stack_kernel_call, vmem_bytes)

KEY = jax.random.PRNGKey(0)

SWEEP = [
    # (B, n, strides, dtype, block_rows, n_tile)
    (8, 128, (1, 2, 4, 8), jnp.float32, 8, 128),
    (16, 256, (1, 2, 4, 8, 16, 32, 64, 128), jnp.float32, 8, 256),
    (32, 512, (1, 4, 16, 64), jnp.float32, 16, 128),
    (8, 128, (1, 2, 4, 8), jnp.bfloat16, 8, 128),
    (16, 1024, (1, 2, 4, 8, 16), jnp.bfloat16, 8, 512),
    (8, 96, (1, 2, 4, 48), jnp.float32, 8, 96),    # non-power-of-two n
]


@pytest.mark.parametrize("B,n,strides,dtype,br,nt", SWEEP)
def test_fwd_kernel_matches_ref(B, n, strides, dtype, br, nt):
    x = jax.random.normal(KEY, (B, n)).astype(dtype)
    cf = (0.4 * jax.random.normal(jax.random.PRNGKey(1),
                                  (len(strides), n // 2, 4)))
    y = spm_stack_kernel_call(x, cf, strides=strides, block_rows=br,
                              n_tile=nt, interpret=True)
    ref = spm_stack_ref(x.astype(jnp.float32), cf, strides).astype(dtype)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("B,n,strides,dtype,br,nt", SWEEP[:4])
def test_bwd_kernel_matches_ref(B, n, strides, dtype, br, nt):
    x = jax.random.normal(KEY, (B, n)).astype(dtype)
    gy = jax.random.normal(jax.random.PRNGKey(2), (B, n)).astype(dtype)
    cf = (0.4 * jax.random.normal(jax.random.PRNGKey(1),
                                  (len(strides), n // 2, 4)))
    gx, gcf = spm_stack_bwd_kernel_call(x, cf, gy, strides=strides,
                                        block_rows=br, n_tile=nt,
                                        interpret=True)
    rgx, rgcf = spm_stack_grads_ref(x.astype(jnp.float32), cf, strides,
                                    gy.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(gx, np.float32),
                               np.asarray(rgx, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(gcf), np.asarray(rgcf),
                               atol=tol * 10, rtol=tol * 10)


def test_fused_wrapper_odd_batch_and_3d():
    n, strides = 256, (1, 2, 4, 8, 16, 32, 64, 128)
    x = jax.random.normal(KEY, (3, 7, n))       # odd rows, 3-D
    cf = 0.4 * jax.random.normal(KEY, (8, n // 2, 4))
    y = spm_stack_fused(x, cf, strides)
    np.testing.assert_allclose(y, spm_stack_ref(x, cf, strides), atol=1e-5)


def test_fused_wrapper_grads():
    n, strides = 128, (1, 2, 4, 8, 16, 32, 64)
    x = jax.random.normal(KEY, (5, n))
    cf = 0.4 * jax.random.normal(KEY, (7, n // 2, 4))
    f = lambda x, cf: jnp.sum(spm_stack_fused(x, cf, strides) ** 2)
    r = lambda x, cf: jnp.sum(spm_stack_ref(x, cf, strides) ** 2)
    g = jax.grad(f, argnums=(0, 1))(x, cf)
    gr = jax.grad(r, argnums=(0, 1))(x, cf)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_kernel_path_in_spm_apply():
    cfg0 = SPMConfig(n=64, n_stages=6, variant="general", use_kernel=False)
    cfg1 = SPMConfig(n=64, n_stages=6, variant="general", use_kernel=True)
    p = init_spm(KEY, cfg0)
    x = jax.random.normal(KEY, (5, 64))
    np.testing.assert_allclose(spm_apply(p, x, cfg0),
                               spm_apply(p, x, cfg1), atol=1e-5)


# ---------------------------------------------------------------------------
# full folded operator: y = D_out (B_L...B_1) D_in x + bias
# ---------------------------------------------------------------------------

def _full_operands(n, L, dkey=7):
    cf = 0.4 * jax.random.normal(jax.random.PRNGKey(1), (L, n // 2, 4))
    d_in = 1.0 + 0.2 * jax.random.normal(jax.random.PRNGKey(dkey), (n,))
    d_out = 1.0 + 0.2 * jax.random.normal(jax.random.PRNGKey(dkey + 1), (n,))
    bias = 0.3 * jax.random.normal(jax.random.PRNGKey(dkey + 2), (n,))
    return cf, d_in, d_out, bias


FULL_SWEEP = [
    # (B, n, strides, dtype).  The n=4096 case plans to TWO runs (stride
    # 2048 has pair span 4096 > MAX_TILE): d_in folds into run 0 and
    # d_out/bias into run 1, exercising the boundary split.
    (8, 128, (1, 2, 4, 8, 16, 64), jnp.float32),
    (5, 256, (1, 2, 4, 8, 16, 32, 64, 128), jnp.float32),
    (8, 128, (1, 2, 4, 8, 16, 64), jnp.bfloat16),
    (4, 4096, (1, 2, 4, 8, 1024, 2048), jnp.float32),
]


def test_full_sweep_has_multi_run_case():
    """Guard: the sweep's big case really is a multi-run plan (so the
    boundary folding and the per-run backward routing stay covered)."""
    assert len(plan_runs(4096, (1, 2, 4, 8, 1024, 2048))) == 2


@pytest.mark.parametrize("B,n,strides,dtype", FULL_SWEEP)
def test_fused_full_operator_matches_ref(B, n, strides, dtype):
    cf, d_in, d_out, bias = _full_operands(n, len(strides))
    x = jax.random.normal(KEY, (B, n)).astype(dtype)
    y = spm_stack_fused(x, cf, strides, d_in=d_in, d_out=d_out, bias=bias)
    assert y.dtype == dtype
    ref = spm_full_ref(x.astype(jnp.float32), cf, tuple(strides),
                       d_in=d_in, d_out=d_out, bias=bias)
    tol = 1e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("B,n,strides,dtype", FULL_SWEEP)
def test_fused_full_operator_grads_match_autodiff(B, n, strides, dtype):
    """custom_vjp of the FULL fused operator == autodiff on the unfused
    reference, in every operand: x, coeffs, d_in, d_out, bias — incl. the
    bf16-activation backward (grads vs a bf16-quantized-forward oracle;
    param grads stay f32 in-kernel)."""
    cf, d_in, d_out, bias = _full_operands(n, len(strides))
    x = jax.random.normal(KEY, (B, n)).astype(dtype)

    def f(x, cf, d_in, d_out, bias):
        y = spm_stack_fused(x, cf, strides, d_in=d_in, d_out=d_out,
                            bias=bias)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    def r(x, cf, d_in, d_out, bias):
        y = spm_full_ref(x.astype(jnp.float32), cf, tuple(strides),
                         d_in=d_in, d_out=d_out, bias=bias)
        return jnp.sum(y ** 2)

    g = jax.grad(f, argnums=(0, 1, 2, 3, 4))(x, cf, d_in, d_out, bias)
    gr = jax.grad(r, argnums=(0, 1, 2, 3, 4))(x, cf, d_in, d_out, bias)
    # bf16: the fused path quantizes the activation I/O the f32 oracle
    # doesn't; grads agree to bf16 resolution
    tol = 1e-4 if dtype == jnp.float32 else 6e-2
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=tol, rtol=tol)


@pytest.mark.parametrize("variant", ["general", "rotation"])
def test_spm_apply_full_fused_parity(variant):
    """spm_apply(use_kernel=True) == unfused path: outputs AND grads (the
    rotation variant exercises the theta -> coeffs chain outside the
    kernel)."""
    cfg0 = SPMConfig(n=64, n_stages=6, variant=variant, backward="custom",
                     use_kernel=False)
    cfg1 = SPMConfig(n=64, n_stages=6, variant=variant, backward="custom",
                     use_kernel=True)
    p = init_spm(KEY, cfg0)
    p["d_in"] = 1 + 0.2 * jax.random.normal(jax.random.PRNGKey(11), (64,))
    p["d_out"] = 1 + 0.2 * jax.random.normal(jax.random.PRNGKey(12), (64,))
    p["bias"] = 0.3 * jax.random.normal(jax.random.PRNGKey(13), (64,))
    x = jax.random.normal(KEY, (5, 64))
    np.testing.assert_allclose(spm_apply(p, x, cfg0), spm_apply(p, x, cfg1),
                               atol=1e-5)
    loss = lambda cfg: (lambda p, x: jnp.sum(spm_apply(p, x, cfg) ** 2))
    g0 = jax.grad(loss(cfg0), argnums=(0, 1))(p, x)
    g1 = jax.grad(loss(cfg1), argnums=(0, 1))(p, x)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_spm_apply_fused_bf16_activations():
    """bf16 activation I/O with f32 in-VMEM compute (serve engine path)."""
    cfg0 = SPMConfig(n=128, n_stages=7, variant="general", use_kernel=False)
    cfg1 = SPMConfig(n=128, n_stages=7, variant="general", use_kernel=True)
    p = init_spm(KEY, cfg0)
    p["bias"] = 0.3 * jax.random.normal(jax.random.PRNGKey(14), (128,))
    x = jax.random.normal(KEY, (9, 128)).astype(jnp.bfloat16)
    y0 = spm_apply(p, x, cfg0)
    y1 = spm_apply(p, x, cfg1)
    assert y1.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(y0, np.float32),
                               np.asarray(y1, np.float32),
                               atol=4e-2, rtol=4e-2)


# ---------------------------------------------------------------------------
# rectangular-native fused linears: the kernel reads (…, d_in), zero-fills
# to n in VMEM, and stores only the d_out output columns
# ---------------------------------------------------------------------------

RECT_CASES = [
    # (d_in, d_out, dtype)
    (48, 32, jnp.float32),     # d_in == n, narrow output only
    (48, 128, jnp.float32),    # d_in < d_out (FFN-up-like)
    (128, 48, jnp.float32),    # d_in > d_out (FFN-down-like)
    (47, 33, jnp.float32),     # odd dims (n = 48, both widths partial)
    (96, 256, jnp.bfloat16),   # bf16 I/O on the rectangular path
]


@pytest.mark.parametrize("d_in,d_out,dtype", RECT_CASES)
def test_linear_apply_fused_parity_rectangular(d_in, d_out, dtype):
    """Fused rectangular path == unfused XLA pad/compose/slice: outputs AND
    grads in every operand, with the input cotangent coming back
    (…, d_in).  bf16 compares at bf16 resolution with an absolute floor
    (the unfused path computes the stages in bf16; the kernel is f32 in
    VMEM)."""
    mk = lambda uk: LinearConfig(d_in=d_in, d_out=d_out, impl="spm_general",
                                 backward="custom", use_kernel=uk)
    lc0, lc1 = mk(False), mk(True)
    p = init_linear(KEY, lc0)
    p["bias"] = 0.1 * jax.random.normal(jax.random.PRNGKey(15), (lc0.n,))
    x = jax.random.normal(KEY, (6, d_in)).astype(dtype)
    y0, y1 = linear_apply(p, x, lc0), linear_apply(p, x, lc1)
    assert y1.shape == (6, d_out) and y1.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(y0, np.float32),
                               np.asarray(y1, np.float32),
                               atol=tol, rtol=tol)
    loss = lambda lc: (lambda p, x: jnp.sum(
        linear_apply(p, x, lc).astype(jnp.float32) ** 2))
    g0 = jax.grad(loss(lc0), argnums=(0, 1))(p, x)
    g1 = jax.grad(loss(lc1), argnums=(0, 1))(p, x)
    assert g1[1].shape == (6, d_in) and g1[1].dtype == dtype
    atol, rtol = (1e-4, 1e-4) if dtype == jnp.float32 else (0.25, 6e-2)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=atol, rtol=rtol)


def test_fused_rectangular_no_xla_pad_or_slice():
    """Acceptance: the fused rectangular linear_apply lowers with NO
    XLA-level jnp.pad and no feature-axis output slice — the zero-fill and
    the partial store live inside the kernel boundary runs.  (Uses the
    shared repro.analysis.jaxpr_walk walker, which visits every inner
    jaxpr except kernel bodies; the batch is a multiple of the row block
    so the only legitimate pad — row padding — is absent too.)"""
    from repro.analysis.jaxpr_walk import feature_axis_slices, primitive_names

    lc = LinearConfig(d_in=96, d_out=256, impl="spm_general",
                      backward="custom", use_kernel=True)
    p = init_linear(KEY, lc)
    x = jax.random.normal(KEY, (8, 96))
    jx = jax.make_jaxpr(lambda x: linear_apply(p, x, lc))(x)
    names = primitive_names(jx.jaxpr)
    assert "pad" not in names, f"XLA pad survived: {sorted(set(names))}"
    slices = feature_axis_slices(jx.jaxpr)
    assert slices == [], f"feature-axis output slice survived: {slices}"


def test_bwd_dead_tile_skip_zero_blocks():
    """ISSUE 4 acceptance: with ``out_width`` the backward grid visits only
    ceil(out_width / n_tile) feature tiles, the unvisited parameter-grad
    (and g_x) blocks come back EXACTLY zero (aliased zero-init, not
    computed), and the visited region matches the full-grid oracle.
    ``dead_from`` produces the same pruning for an interior run whose
    cotangent is already zero past the downstream run's skip point."""
    B, n, nt, strides = 8, 256, 64, (1, 2, 4)
    out_w = 100                         # vis = ceil(100/64) = 2 of 4 tiles
    x = jax.random.normal(KEY, (B, n))
    gy = jax.random.normal(jax.random.PRNGKey(2), (B, out_w))
    cf = 0.4 * jax.random.normal(jax.random.PRNGKey(1),
                                 (len(strides), n // 2, 4))
    d_in = 1 + 0.1 * jax.random.normal(jax.random.PRNGKey(3), (n,))
    d_out = 1 + 0.1 * jax.random.normal(jax.random.PRNGKey(4), (n,))
    out = spm_stack_bwd_kernel_call(x, cf, gy, d_in, d_out, strides=strides,
                                    block_rows=8, n_tile=nt, has_bias=True,
                                    out_width=out_w, interpret=True)
    gx, gcf, gdin, gdout, gbias = out
    # oracle: full-width gy with an explicit zero tail, full grid
    gy_full = jnp.pad(gy, ((0, 0), (0, n - out_w)))

    def ref(x, cf, d_in, d_out):
        z = spm_stack_ref(x * d_in, cf, strides)
        return jnp.sum(z * d_out * gy_full)

    rgx, rgcf, rgdin, rgdout = jax.grad(ref, argnums=(0, 1, 2, 3))(
        x, cf, d_in, d_out)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rgx),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gcf), np.asarray(rgcf),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(gdin), np.asarray(rgdin),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(gdout), np.asarray(rgdout),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(gbias),
                               np.asarray(jnp.sum(gy_full, axis=0)),
                               atol=1e-4, rtol=1e-4)
    # unvisited blocks (tiles 2..3: pair rows >= 64, columns >= 128) are
    # exact zeros — not small numbers: they were never computed
    assert np.all(np.asarray(gcf[:, 2 * (nt // 2):]) == 0)
    assert np.all(np.asarray(gx[:, 2 * nt:]) == 0)
    for v in (gdin, gdout, gbias):
        assert np.all(np.asarray(v[2 * nt:]) == 0)
    # dead_from: interior-run shape — full-width gy whose tail is already
    # exactly zero; the pruned grid must reproduce the full-grid grads
    gx2, gcf2 = spm_stack_bwd_kernel_call(x, cf, gy_full, strides=strides,
                                          block_rows=8, n_tile=nt,
                                          dead_from=out_w, interpret=True)
    rgx2, rgcf2 = spm_stack_grads_ref(x, cf, strides, gy_full)
    np.testing.assert_allclose(np.asarray(gx2), np.asarray(rgx2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gcf2), np.asarray(rgcf2),
                               atol=1e-3, rtol=1e-3)
    assert np.all(np.asarray(gcf2[:, 2 * (nt // 2):]) == 0)


@pytest.mark.parametrize("in_w,out_w", [
    (3000, 2500),   # both widths partial in their edge tiles
    (1500, 2500),   # in_w <= n - first-run n_tile: whole input feature
                    # tiles past the edge (the g_x width-vs-grid aliasing
                    # regime — the backward must widen g_x internally)
    (1500, 1800),   # both widths below the first/last run tile — here the
                    # plan's last run is a single 4096-wide tile, so the
                    # backward skip does NOT engage (dead-chain coverage
                    # lives in test_fused_dead_chain_non_monotone_tiles)
])
def test_fused_rectangular_multi_run_boundaries(in_w, out_w):
    """Rectangular widths on a MULTI-run plan (n=4096 splits in two):
    in_width masks only the first run, out_width only the last, the
    intermediate stays n-wide, and padded lanes get exactly-zero
    diag/bias grads."""
    n, strides = 4096, (1, 2, 4, 8, 1024, 2048)
    assert len(plan_runs(n, strides)) == 2
    cf, d_in, d_out, bias = _full_operands(n, len(strides))
    # 16 rows: above TINY_ROW_THRESHOLD, so the multi-run default plan
    # engages (tiny batches collapse to a single wide run by design)
    x = jax.random.normal(KEY, (16, in_w))

    def f(x, cf, d_in, d_out, bias):
        y = spm_stack_fused(x, cf, strides, d_in=d_in, d_out=d_out,
                            bias=bias, in_width=in_w, out_width=out_w)
        return jnp.sum(y ** 2)

    def r(x, cf, d_in, d_out, bias):
        xp = jnp.pad(x, ((0, 0), (0, n - in_w)))
        y = spm_full_ref(xp, cf, tuple(strides), d_in=d_in, d_out=d_out,
                         bias=bias)
        return jnp.sum(y[:, :out_w] ** 2)

    y = spm_stack_fused(x, cf, strides, d_in=d_in, d_out=d_out, bias=bias,
                        in_width=in_w, out_width=out_w)
    assert y.shape == (16, out_w)
    xp = jnp.pad(x, ((0, 0), (0, n - in_w)))
    ref = spm_full_ref(xp, cf, tuple(strides), d_in=d_in, d_out=d_out,
                       bias=bias)[:, :out_w]
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    g = jax.grad(f, argnums=(0, 1, 2, 3, 4))(x, cf, d_in, d_out, bias)
    gr = jax.grad(r, argnums=(0, 1, 2, 3, 4))(x, cf, d_in, d_out, bias)
    assert g[0].shape == (16, in_w)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3)
    assert np.all(np.asarray(g[2][in_w:]) == 0)    # g_din past d_in
    assert np.all(np.asarray(g[3][out_w:]) == 0)   # g_dout past d_out
    assert np.all(np.asarray(g[4][out_w:]) == 0)   # g_bias past d_out


@pytest.mark.parametrize("in_w,out_w", [
    (None, 1800),   # square input, narrow output: every dead column holds
                    # real remat data, so a wrong skip corrupts grads
    (3000, 1200),   # narrowing with both widths partial
])
def test_fused_dead_chain_non_monotone_tiles(in_w, out_w):
    """Regression for the dead_from chain on a plan whose run tiles are
    NOT monotone (2048 -> 4096 -> 8): a larger-tile middle run spreads
    live cotangent across its whole edge tile, so the upstream run's dead
    boundary must be re-derived from EACH run's tile width — propagating
    the last run's boundary verbatim zeroed real gradients here."""
    n, strides = 4096, (1, 2, 4, 8, 1024, 2048, 1, 2)
    tiles = [t for _, t in plan_runs(n, strides)]
    assert len(tiles) == 3 and tiles[1] > tiles[0] > tiles[2], tiles
    cf = 0.4 * jax.random.normal(jax.random.PRNGKey(1),
                                 (len(strides), n // 2, 4))
    xw = in_w if in_w is not None else n
    # 16 rows keep the non-monotone 3-run plan (tiny rows collapse it)
    x = jax.random.normal(KEY, (16, xw))

    def f(x, cf):
        y = spm_stack_fused(x, cf, strides, in_width=in_w, out_width=out_w)
        return jnp.sum(y ** 2)

    def r(x, cf):
        xp = jnp.pad(x, ((0, 0), (0, n - xw)))
        return jnp.sum(spm_stack_ref(xp, cf, strides)[:, :out_w] ** 2)

    g = jax.grad(f, argnums=(0, 1))(x, cf)
    gr = jax.grad(r, argnums=(0, 1))(x, cf)
    assert g[0].shape == (16, xw)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3)


def test_windowed_col_base_kernel_mode():
    """The sharded windowed (col_base) kernel mode, driven directly as the
    distributed executor drives it per shard: the forward/backward read
    each shard's n_local-wide window straight out of the feature-complete
    operands, masking against GLOBAL widths in VMEM.  (The executor uses
    the x window; the symmetric gy window is exercised here to keep the
    kernel contract covered.)"""
    n, S, n_local, in_w, out_w = 64, 4, 16, 50, 40
    B, nt, strides = 8, 16, (1, 2, 4)
    x = jax.random.normal(KEY, (B, in_w))
    gy = jax.random.normal(jax.random.PRNGKey(2), (B, out_w))
    cf_l = 0.4 * jax.random.normal(jax.random.PRNGKey(1),
                                   (len(strides), n_local // 2, 4))
    d_in = 1 + 0.1 * jax.random.normal(jax.random.PRNGKey(3), (n,))
    xp = jnp.pad(x, ((0, 0), (0, n - in_w)))
    gyp = jnp.pad(gy, ((0, 0), (0, n - out_w)))
    for j in range(S):
        base = jnp.asarray([j * (n_local // nt)], jnp.int32)
        d_loc = d_in[j * n_local:(j + 1) * n_local]
        slab = xp[:, j * n_local:(j + 1) * n_local]
        gy_slab = gyp[:, j * n_local:(j + 1) * n_local]
        y = spm_stack_kernel_call(x, cf_l, d_loc, None, None, base,
                                  strides=strides, block_rows=8, n_tile=nt,
                                  in_width=in_w, interpret=True)
        ref = spm_stack_ref(slab * d_loc, cf_l, strides)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)
        gx, gcf, gdin, gbias = spm_stack_bwd_kernel_call(
            x, cf_l, gy, d_loc, None, base, strides=strides, block_rows=8,
            n_tile=nt, has_bias=True, in_width=in_w, out_width=out_w,
            interpret=True)

        def f(slab, cf, d):
            return jnp.sum(spm_stack_ref(slab * d, cf, strides) * gy_slab)

        rgx, rgcf, rgd = jax.grad(f, argnums=(0, 1, 2))(slab, cf_l, d_loc)
        for a, b in ((gx, rgx), (gcf, rgcf), (gdin, rgd),
                     (gbias, jnp.sum(gy_slab, axis=0))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-3, rtol=1e-3)


def test_use_kernel_fallback_rules():
    """Tri-state resolution: forced-on still falls back for odd n,
    permutation pairings, and custom_inverse; auto is off on CPU."""
    assert not use_fused_kernel(
        SPMConfig(n=9, n_stages=3, schedule="random", use_kernel=True))
    assert not use_fused_kernel(
        SPMConfig(n=16, n_stages=4, schedule="random", use_kernel=True))
    assert not use_fused_kernel(
        SPMConfig(n=16, n_stages=4, variant="rotation",
                  backward="custom_inverse", use_kernel=True))
    # sharded two_level WITHOUT a mesh context: just a stride schedule —
    # the fused kernel runs it unpartitioned.  (With a feature-sharding
    # mesh active, spm_apply routes to the distributed executor BEFORE
    # this check — parallel/spm_shard.py, tests/test_distributed.py.)
    assert use_fused_kernel(
        SPMConfig(n=64, n_stages=6, schedule="two_level", n_shards=4,
                  use_kernel=True))
    assert use_fused_kernel(
        SPMConfig(n=64, n_stages=6, schedule="two_level", n_shards=1,
                  use_kernel=True))
    assert kernel_eligible(SPMConfig(n=16, n_stages=4))
    auto = SPMConfig(n=16, n_stages=4)
    if jax.default_backend() != "tpu":
        assert not use_fused_kernel(auto)
    assert not use_fused_kernel(
        SPMConfig(n=16, n_stages=4, use_kernel=False))
    # odd-n fallback still computes correctly end to end
    cfg = SPMConfig(n=9, n_stages=3, schedule="random", use_kernel=True)
    p = init_spm(KEY, cfg)
    y = spm_apply(p, jax.random.normal(KEY, (4, 9)), cfg)
    assert y.shape == (4, 9) and bool(jnp.all(jnp.isfinite(y)))


def test_plan_runs_covers_schedule():
    runs = plan_runs(2048, (1, 2, 4, 8, 1024, 1, 2))
    flat = [s for r, _ in runs for s in r]
    assert flat == [1, 2, 4, 8, 1024, 1, 2]
    for strides, tile in runs:
        assert 2048 % tile == 0
        for s in strides:
            assert tile % (2 * s) == 0


def test_vmem_budget_respected():
    for nt in (128, 512, 2048):
        br = pick_block_rows(nt, 12)
        assert vmem_bytes(br, nt, 12) <= 12 * 2 ** 20 * 2  # within 2x budget
        assert br >= 8


# ---------------------------------------------------------------------------
# tiny-row (decode) plans
# ---------------------------------------------------------------------------

def test_plan_runs_for_rows_tiny_vs_training():
    """Decode-sized calls (rows <= TINY_ROW_THRESHOLD) re-plan under the
    widened VMEM tile cap — fewer, wider runs (fewer HBM round-trips per
    token) — while training-sized calls keep the default plan exactly."""
    from repro.core.eligibility import TINY_ROW_THRESHOLD, tiny_row_call
    from repro.kernels.ops import (MAX_TILE, plan_runs_for_rows,
                                   tile_cap_for_rows)
    from repro.kernels.spm_stack import pick_max_tile

    assert not tiny_row_call(0)
    assert all(tiny_row_call(r) for r in range(1, TINY_ROW_THRESHOLD + 1))
    assert not tiny_row_call(TINY_ROW_THRESHOLD + 1)

    n, strides = 4096, (1, 2, 4, 8, 1024, 2048)
    assert len(plan_runs(n, strides)) == 2        # default: 2 runs @ 2048
    assert tile_cap_for_rows(n, strides, 64) == MAX_TILE
    assert plan_runs_for_rows(n, strides, 64) == plan_runs(n, strides)

    assert pick_max_tile(n, len(strides)) >= n    # one 8-row block fits
    assert tile_cap_for_rows(n, strides, 4) >= n
    tiny = plan_runs_for_rows(n, strides, 4)
    assert len(tiny) == 1 and tiny[0][1] == n     # single full-width run
    # the runs cover the same stage sequence either way
    assert sum((list(r[0]) for r in tiny), []) == \
        sum((list(r[0]) for r in plan_runs(n, strides)), [])


def test_tiny_row_fused_matches_ref_and_grads():
    """A decode-shaped call (4 rows) through spm_stack_fused takes the
    single-run tiny plan and still matches the jnp oracle bitwise-close,
    forward and backward — the re-plan changes traffic, not math."""
    from repro.kernels.ops import plan_runs_for_rows

    n, strides = 4096, (1, 2, 2048)
    assert len(plan_runs_for_rows(n, strides, 4)) == 1   # tiny plan
    assert len(plan_runs(n, strides)) == 2               # training plan
    x = jax.random.normal(KEY, (4, n))
    cf = 0.4 * jax.random.normal(jax.random.PRNGKey(1),
                                 (len(strides), n // 2, 4))
    y = spm_stack_fused(x, cf, strides)
    ref = spm_stack_ref(x, cf, strides)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    g = jax.grad(lambda x, cf:
                 jnp.sum(spm_stack_fused(x, cf, strides) ** 2),
                 argnums=(0, 1))(x, cf)
    gr = jax.grad(lambda x, cf:
                  jnp.sum(spm_stack_ref(x, cf, strides) ** 2),
                  argnums=(0, 1))(x, cf)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3)

# ---------------------------------------------------------------------------
# quantized fused path (test-pyramid layer 2): int8 activation I/O and
# per-stage int8 coefficient tables vs the f32 XLA reference.  Layer 1
# (quantizer primitives) is tests/test_quantization.py; layer 3 (sharded
# parity + compressed-pod convergence) is tests/test_distributed.py.
# ---------------------------------------------------------------------------


def _operator_gain(coeffs, d_in=None, d_out=None):
    """Row-sum-norm bound on the operator's amplification: every stage's
    2x2 mix amplifies an elementwise bound by at most
    max(|a|+|b|, |c|+|d|) over its pairs, the diagonals by their absmax.
    An upper bound on |y|_inf / |x|_inf, and on the gain from any
    internal point to the output."""
    a, b, c, d = (jnp.abs(coeffs[..., i]) for i in range(4))
    per_stage = jnp.max(jnp.maximum(a + b, c + d), axis=-1)   # (L,)
    g = jnp.prod(per_stage)
    for diag in (d_in, d_out):
        if diag is not None:
            g = g * jnp.max(jnp.abs(diag))
    return float(g)


def _quant_tol(x, coeffs, d_in=None, d_out=None):
    """Derived worst-case output bound for the quantized fused path — no
    magic constants, everything comes from the scale convention and the
    operands themselves.

    Each quantization event rounds to nearest on a grid with step
    absmax/127 at that point, so it injects at most absmax/254
    elementwise.  The magnitude anywhere in the chain is at most
    G * max|x| (G = ``_operator_gain``), and the downstream gain on any
    injected error is also at most G, so one event contributes at most
    G * (G * max|x|) / 254 ... except G bounds the WHOLE chain, so
    amplitude-at-event x gain-after-event is itself bounded by
    G * max|x|.  Events: activation I/O quantizes the input plus every
    run-boundary store (<= L + 1 of them, runs <= stages), coefficient
    quantization perturbs each of the L stages' two row entries.  Total:

        tol = 2 * (3 L + 2) * G * max|x| / 254

    with a final factor 2 of headroom for f32 accumulation ordering.
    Observed error sits ~20x below this bound while the bound stays well
    below the output scale, so a wrong-scale / wrong-tile bug trips it.
    """
    L = coeffs.shape[0]
    g = _operator_gain(coeffs, d_in, d_out)
    return 2.0 * (3 * L + 2) * g * float(jnp.max(jnp.abs(x))) / 254.0


QUANT_RECT = [
    # (d_in, d_out): FFN-up-like, FFN-down-like, odd dims, square
    (48, 128),
    (128, 48),
    (47, 33),
    (64, 64),
]


@pytest.mark.parametrize("d_in,d_out", QUANT_RECT)
@pytest.mark.parametrize("mode", ["acts", "coeffs", "both"])
def test_linear_apply_quantized_parity(d_in, d_out, mode):
    """Quantized fused linear vs the f32 XLA reference (use_kernel=False)
    across rectangular widths, within the tolerance DERIVED from the
    per-stage scale bound (``_quant_tol``) — not a magic constant.  Grads
    through the quantized path stay finite (straight-through for coeffs,
    dequantized cotangents for acts)."""
    qa, qc = mode in ("acts", "both"), mode in ("coeffs", "both")
    mk = lambda uk: LinearConfig(d_in=d_in, d_out=d_out, impl="spm_general",
                                 backward="custom", use_kernel=uk,
                                 quant_acts=uk and qa,
                                 quant_coeffs=uk and qc)
    lc_ref, lc_q = mk(False), mk(True)
    p = init_linear(KEY, lc_ref)
    p["bias"] = 0.1 * jax.random.normal(jax.random.PRNGKey(15), (lc_ref.n,))
    x = jax.random.normal(jax.random.PRNGKey(2), (8, d_in))
    y_ref = linear_apply(p, x, lc_ref)
    y_q = linear_apply(p, x, lc_q)
    assert y_q.shape == y_ref.shape and y_q.dtype == y_ref.dtype
    cf = stage_coeffs(p, lc_ref.spm_config())
    tol = _quant_tol(x, cf, p.get("d_in"), p.get("d_out"))
    err = float(jnp.max(jnp.abs(y_q - y_ref)))
    assert err <= tol, (err, tol)
    g = jax.grad(lambda p, x: jnp.sum(linear_apply(p, x, lc_q) ** 2),
                 argnums=(0, 1))(p, x)
    assert all(bool(jnp.all(jnp.isfinite(leaf)))
               for leaf in jax.tree.leaves(g))


def test_quant_coeffs_grads_match_predequantized_table():
    """quant_coeffs=True is numerically the f32 operator over the
    DEQUANTIZED table: outputs and grads (straight-through in coeffs)
    match running the plain fused kernel on ``dequantize_coeffs(
    quantize_coeffs(cf))`` to within a few ulp of f32 reassociation —
    single-stage is bitwise, multi-stage XLA:CPU FMA ordering costs ~1
    ulp per stage."""
    B, n, strides = 8, 128, (1, 2, 4, 8)
    x = jax.random.normal(KEY, (B, n))
    cf = 0.4 * jax.random.normal(jax.random.PRNGKey(3),
                                 (len(strides), n // 2, 4))
    dq = Q.dequantize_coeffs(*Q.quantize_coeffs(cf), jnp.float32)
    y_q = spm_stack_fused(x, cf, strides, quant_coeffs=True)
    y_d = spm_stack_fused(x, dq, strides)
    np.testing.assert_allclose(np.asarray(y_q), np.asarray(y_d),
                               rtol=2e-6, atol=1e-6)
    g_q = jax.grad(lambda x, cf: jnp.sum(
        spm_stack_fused(x, cf, strides, quant_coeffs=True) ** 2),
        argnums=(0, 1))(x, cf)
    g_d = jax.grad(lambda x, cf: jnp.sum(
        spm_stack_fused(x, cf, strides) ** 2),
        argnums=(0, 1))(x, dq)
    for a, b in zip(g_q, g_d):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_quant_acts_ineligible_plan_falls_back_bitwise():
    """A non-uniform-tile training plan cannot chain int8 across runs:
    quant_acts must silently fall back to f32 I/O — BITWISE equal to the
    unquantized kernel path, not merely close."""
    B, n, strides = 64, 4096, (1, 2048)
    cap = tile_cap_for_rows(n, strides, B, dtype_bytes=4)
    runs = plan_runs(n, strides, cap)
    assert not quant_acts_eligible(runs), runs   # the premise of the test
    x = jax.random.normal(KEY, (B, n))
    cf = 0.4 * jax.random.normal(jax.random.PRNGKey(5),
                                 (len(strides), n // 2, 4))
    y_f32 = spm_stack_fused(x, cf, strides)
    y_q = spm_stack_fused(x, cf, strides, quant_acts=True)
    np.testing.assert_array_equal(np.asarray(y_f32), np.asarray(y_q))


def test_spm_stack_fused_q8_int8_end_to_end():
    """The inference entry: int8 rows in, int8 rows out, per-block scales
    riding alongside — dequantizing the result lands within the derived
    quantization bound of the f32 fused operator (which itself matches
    the XLA reference elsewhere in this file)."""
    B, n, strides = 16, 128, (1, 2, 4, 8, 16, 32, 64)
    br = 8
    x = jax.random.normal(KEY, (B, n))
    cf = 0.4 * jax.random.normal(jax.random.PRNGKey(7),
                                 (len(strides), n // 2, 4))
    di = 1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(8), (n,))
    do = 1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(9), (n,))
    bias = 0.1 * jax.random.normal(jax.random.PRNGKey(10), (n,))
    cap = tile_cap_for_rows(n, strides, B, dtype_bytes=1)
    (run,) = plan_runs(n, strides, cap)      # single uniform-tile run
    qx, xs = Q.quantize_blocks(x, br, run[1])
    qy, ys = spm_stack_fused_q8(qx, xs, cf, strides,
                                d_in=di, d_out=do, bias=bias)
    assert qy.dtype == jnp.int8 and qy.shape == (B, n)
    assert ys.shape == (B // br, n // run[1])
    y = Q.dequantize_blocks(qy, ys, br, run[1], jnp.float32)
    y_ref = spm_stack_fused(x, cf, strides, d_in=di, d_out=do, bias=bias)
    tol = _quant_tol(x, cf, di, do)
    err = float(jnp.max(jnp.abs(y - y_ref)))
    assert err <= tol, (err, tol)


def test_spm_stack_fused_q8_rejects_ineligible_plan():
    """Unlike the training entry (graceful f32 fallback), the int8-native
    entry has no f32 path to fall back to: a non-uniform-tile plan is a
    loud ValueError, not silent garbage."""
    B, n, strides = 64, 4096, (1, 2048)
    qx = jnp.zeros((B, n), jnp.int8)
    xs = jnp.ones((B // 8, 1), jnp.float32)
    cf = jnp.zeros((len(strides), n // 2, 4), jnp.float32)
    with pytest.raises(ValueError, match="uniform-tile"):
        spm_stack_fused_q8(qx, xs, cf, strides)
