"""Pallas kernel allclose sweeps vs the pure-jnp oracle (kernels/ref.py).

Shape x dtype sweep per instructions; interpret mode on CPU.  Covers the
bare stage stack AND the full folded operator (diag + bias) forward and
backward, plus the knob plumbing through spm_apply / linear_apply."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SPMConfig, init_spm, kernel_eligible, spm_apply,
                        use_fused_kernel)
from repro.core.linear import LinearConfig, init_linear, linear_apply
from repro.kernels.ops import plan_runs, spm_stack_fused
from repro.kernels.ref import (spm_full_ref, spm_stack_grads_ref,
                               spm_stack_ref)
from repro.kernels.spm_stack import (pick_block_rows, spm_stack_bwd_kernel_call,
                                     spm_stack_kernel_call, vmem_bytes)

KEY = jax.random.PRNGKey(0)

SWEEP = [
    # (B, n, strides, dtype, block_rows, n_tile)
    (8, 128, (1, 2, 4, 8), jnp.float32, 8, 128),
    (16, 256, (1, 2, 4, 8, 16, 32, 64, 128), jnp.float32, 8, 256),
    (32, 512, (1, 4, 16, 64), jnp.float32, 16, 128),
    (8, 128, (1, 2, 4, 8), jnp.bfloat16, 8, 128),
    (16, 1024, (1, 2, 4, 8, 16), jnp.bfloat16, 8, 512),
    (8, 96, (1, 2, 4, 48), jnp.float32, 8, 96),    # non-power-of-two n
]


@pytest.mark.parametrize("B,n,strides,dtype,br,nt", SWEEP)
def test_fwd_kernel_matches_ref(B, n, strides, dtype, br, nt):
    x = jax.random.normal(KEY, (B, n)).astype(dtype)
    cf = (0.4 * jax.random.normal(jax.random.PRNGKey(1),
                                  (len(strides), n // 2, 4)))
    y = spm_stack_kernel_call(x, cf, strides=strides, block_rows=br,
                              n_tile=nt, interpret=True)
    ref = spm_stack_ref(x.astype(jnp.float32), cf, strides).astype(dtype)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("B,n,strides,dtype,br,nt", SWEEP[:4])
def test_bwd_kernel_matches_ref(B, n, strides, dtype, br, nt):
    x = jax.random.normal(KEY, (B, n)).astype(dtype)
    gy = jax.random.normal(jax.random.PRNGKey(2), (B, n)).astype(dtype)
    cf = (0.4 * jax.random.normal(jax.random.PRNGKey(1),
                                  (len(strides), n // 2, 4)))
    gx, gcf = spm_stack_bwd_kernel_call(x, cf, gy, strides=strides,
                                        block_rows=br, n_tile=nt,
                                        interpret=True)
    rgx, rgcf = spm_stack_grads_ref(x.astype(jnp.float32), cf, strides,
                                    gy.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(gx, np.float32),
                               np.asarray(rgx, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(gcf), np.asarray(rgcf),
                               atol=tol * 10, rtol=tol * 10)


def test_fused_wrapper_odd_batch_and_3d():
    n, strides = 256, (1, 2, 4, 8, 16, 32, 64, 128)
    x = jax.random.normal(KEY, (3, 7, n))       # odd rows, 3-D
    cf = 0.4 * jax.random.normal(KEY, (8, n // 2, 4))
    y = spm_stack_fused(x, cf, strides)
    np.testing.assert_allclose(y, spm_stack_ref(x, cf, strides), atol=1e-5)


def test_fused_wrapper_grads():
    n, strides = 128, (1, 2, 4, 8, 16, 32, 64)
    x = jax.random.normal(KEY, (5, n))
    cf = 0.4 * jax.random.normal(KEY, (7, n // 2, 4))
    f = lambda x, cf: jnp.sum(spm_stack_fused(x, cf, strides) ** 2)
    r = lambda x, cf: jnp.sum(spm_stack_ref(x, cf, strides) ** 2)
    g = jax.grad(f, argnums=(0, 1))(x, cf)
    gr = jax.grad(r, argnums=(0, 1))(x, cf)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_kernel_path_in_spm_apply():
    cfg0 = SPMConfig(n=64, n_stages=6, variant="general", use_kernel=False)
    cfg1 = SPMConfig(n=64, n_stages=6, variant="general", use_kernel=True)
    p = init_spm(KEY, cfg0)
    x = jax.random.normal(KEY, (5, 64))
    np.testing.assert_allclose(spm_apply(p, x, cfg0),
                               spm_apply(p, x, cfg1), atol=1e-5)


# ---------------------------------------------------------------------------
# full folded operator: y = D_out (B_L...B_1) D_in x + bias
# ---------------------------------------------------------------------------

def _full_operands(n, L, dkey=7):
    cf = 0.4 * jax.random.normal(jax.random.PRNGKey(1), (L, n // 2, 4))
    d_in = 1.0 + 0.2 * jax.random.normal(jax.random.PRNGKey(dkey), (n,))
    d_out = 1.0 + 0.2 * jax.random.normal(jax.random.PRNGKey(dkey + 1), (n,))
    bias = 0.3 * jax.random.normal(jax.random.PRNGKey(dkey + 2), (n,))
    return cf, d_in, d_out, bias


FULL_SWEEP = [
    # (B, n, strides, dtype).  The n=4096 case plans to TWO runs (stride
    # 2048 has pair span 4096 > MAX_TILE): d_in folds into run 0 and
    # d_out/bias into run 1, exercising the boundary split.
    (8, 128, (1, 2, 4, 8, 16, 64), jnp.float32),
    (5, 256, (1, 2, 4, 8, 16, 32, 64, 128), jnp.float32),
    (8, 128, (1, 2, 4, 8, 16, 64), jnp.bfloat16),
    (4, 4096, (1, 2, 4, 8, 1024, 2048), jnp.float32),
]


def test_full_sweep_has_multi_run_case():
    """Guard: the sweep's big case really is a multi-run plan (so the
    boundary folding and the per-run backward routing stay covered)."""
    assert len(plan_runs(4096, (1, 2, 4, 8, 1024, 2048))) == 2


@pytest.mark.parametrize("B,n,strides,dtype", FULL_SWEEP)
def test_fused_full_operator_matches_ref(B, n, strides, dtype):
    cf, d_in, d_out, bias = _full_operands(n, len(strides))
    x = jax.random.normal(KEY, (B, n)).astype(dtype)
    y = spm_stack_fused(x, cf, strides, d_in=d_in, d_out=d_out, bias=bias)
    assert y.dtype == dtype
    ref = spm_full_ref(x.astype(jnp.float32), cf, tuple(strides),
                       d_in=d_in, d_out=d_out, bias=bias)
    tol = 1e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("B,n,strides,dtype", FULL_SWEEP)
def test_fused_full_operator_grads_match_autodiff(B, n, strides, dtype):
    """custom_vjp of the FULL fused operator == autodiff on the unfused
    reference, in every operand: x, coeffs, d_in, d_out, bias — incl. the
    bf16-activation backward (grads vs a bf16-quantized-forward oracle;
    param grads stay f32 in-kernel)."""
    cf, d_in, d_out, bias = _full_operands(n, len(strides))
    x = jax.random.normal(KEY, (B, n)).astype(dtype)

    def f(x, cf, d_in, d_out, bias):
        y = spm_stack_fused(x, cf, strides, d_in=d_in, d_out=d_out,
                            bias=bias)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    def r(x, cf, d_in, d_out, bias):
        y = spm_full_ref(x.astype(jnp.float32), cf, tuple(strides),
                         d_in=d_in, d_out=d_out, bias=bias)
        return jnp.sum(y ** 2)

    g = jax.grad(f, argnums=(0, 1, 2, 3, 4))(x, cf, d_in, d_out, bias)
    gr = jax.grad(r, argnums=(0, 1, 2, 3, 4))(x, cf, d_in, d_out, bias)
    # bf16: the fused path quantizes the activation I/O the f32 oracle
    # doesn't; grads agree to bf16 resolution
    tol = 1e-4 if dtype == jnp.float32 else 6e-2
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=tol, rtol=tol)


@pytest.mark.parametrize("variant", ["general", "rotation"])
def test_spm_apply_full_fused_parity(variant):
    """spm_apply(use_kernel=True) == unfused path: outputs AND grads (the
    rotation variant exercises the theta -> coeffs chain outside the
    kernel)."""
    cfg0 = SPMConfig(n=64, n_stages=6, variant=variant, backward="custom",
                     use_kernel=False)
    cfg1 = SPMConfig(n=64, n_stages=6, variant=variant, backward="custom",
                     use_kernel=True)
    p = init_spm(KEY, cfg0)
    p["d_in"] = 1 + 0.2 * jax.random.normal(jax.random.PRNGKey(11), (64,))
    p["d_out"] = 1 + 0.2 * jax.random.normal(jax.random.PRNGKey(12), (64,))
    p["bias"] = 0.3 * jax.random.normal(jax.random.PRNGKey(13), (64,))
    x = jax.random.normal(KEY, (5, 64))
    np.testing.assert_allclose(spm_apply(p, x, cfg0), spm_apply(p, x, cfg1),
                               atol=1e-5)
    loss = lambda cfg: (lambda p, x: jnp.sum(spm_apply(p, x, cfg) ** 2))
    g0 = jax.grad(loss(cfg0), argnums=(0, 1))(p, x)
    g1 = jax.grad(loss(cfg1), argnums=(0, 1))(p, x)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_spm_apply_fused_bf16_activations():
    """bf16 activation I/O with f32 in-VMEM compute (serve engine path)."""
    cfg0 = SPMConfig(n=128, n_stages=7, variant="general", use_kernel=False)
    cfg1 = SPMConfig(n=128, n_stages=7, variant="general", use_kernel=True)
    p = init_spm(KEY, cfg0)
    p["bias"] = 0.3 * jax.random.normal(jax.random.PRNGKey(14), (128,))
    x = jax.random.normal(KEY, (9, 128)).astype(jnp.bfloat16)
    y0 = spm_apply(p, x, cfg0)
    y1 = spm_apply(p, x, cfg1)
    assert y1.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(y0, np.float32),
                               np.asarray(y1, np.float32),
                               atol=4e-2, rtol=4e-2)


def test_linear_apply_fused_parity_rectangular():
    """Fused knob through LinearConfig, incl. the pad/slice rectangular
    path: outputs and parameter grads match the unfused composition."""
    mk = lambda uk: LinearConfig(d_in=48, d_out=32, impl="spm_general",
                                 backward="custom", use_kernel=uk)
    lc0, lc1 = mk(False), mk(True)
    p = init_linear(KEY, lc0)
    p["bias"] = 0.1 * jax.random.normal(jax.random.PRNGKey(15), (lc0.n,))
    x = jax.random.normal(KEY, (6, 48))
    np.testing.assert_allclose(linear_apply(p, x, lc0),
                               linear_apply(p, x, lc1), atol=1e-5)
    g0 = jax.grad(lambda p: jnp.sum(linear_apply(p, x, lc0) ** 2))(p)
    g1 = jax.grad(lambda p: jnp.sum(linear_apply(p, x, lc1) ** 2))(p)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_use_kernel_fallback_rules():
    """Tri-state resolution: forced-on still falls back for odd n,
    permutation pairings, and custom_inverse; auto is off on CPU."""
    assert not use_fused_kernel(
        SPMConfig(n=9, n_stages=3, schedule="random", use_kernel=True))
    assert not use_fused_kernel(
        SPMConfig(n=16, n_stages=4, schedule="random", use_kernel=True))
    assert not use_fused_kernel(
        SPMConfig(n=16, n_stages=4, variant="rotation",
                  backward="custom_inverse", use_kernel=True))
    # sharded two_level: stays on the partitionable XLA path until the
    # kernel supports cross-shard collective stages
    assert not use_fused_kernel(
        SPMConfig(n=64, n_stages=6, schedule="two_level", n_shards=4,
                  use_kernel=True))
    assert use_fused_kernel(
        SPMConfig(n=64, n_stages=6, schedule="two_level", n_shards=1,
                  use_kernel=True))
    assert kernel_eligible(SPMConfig(n=16, n_stages=4))
    auto = SPMConfig(n=16, n_stages=4)
    if jax.default_backend() != "tpu":
        assert not use_fused_kernel(auto)
    assert not use_fused_kernel(
        SPMConfig(n=16, n_stages=4, use_kernel=False))
    # odd-n fallback still computes correctly end to end
    cfg = SPMConfig(n=9, n_stages=3, schedule="random", use_kernel=True)
    p = init_spm(KEY, cfg)
    y = spm_apply(p, jax.random.normal(KEY, (4, 9)), cfg)
    assert y.shape == (4, 9) and bool(jnp.all(jnp.isfinite(y)))


def test_plan_runs_covers_schedule():
    runs = plan_runs(2048, (1, 2, 4, 8, 1024, 1, 2))
    flat = [s for r, _ in runs for s in r]
    assert flat == [1, 2, 4, 8, 1024, 1, 2]
    for strides, tile in runs:
        assert 2048 % tile == 0
        for s in strides:
            assert tile % (2 * s) == 0


def test_vmem_budget_respected():
    for nt in (128, 512, 2048):
        br = pick_block_rows(nt, 12)
        assert vmem_bytes(br, nt, 12) <= 12 * 2 ** 20 * 2  # within 2x budget
        assert br >= 8
