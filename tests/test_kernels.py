"""Pallas kernel allclose sweeps vs the pure-jnp oracle (kernels/ref.py).

Shape x dtype sweep per instructions; interpret mode on CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SPMConfig, init_spm, spm_apply
from repro.kernels.ops import plan_runs, spm_stack_fused
from repro.kernels.ref import spm_stack_grads_ref, spm_stack_ref
from repro.kernels.spm_stack import (pick_block_rows, spm_stack_bwd_kernel_call,
                                     spm_stack_kernel_call, vmem_bytes)

KEY = jax.random.PRNGKey(0)

SWEEP = [
    # (B, n, strides, dtype, block_rows, n_tile)
    (8, 128, (1, 2, 4, 8), jnp.float32, 8, 128),
    (16, 256, (1, 2, 4, 8, 16, 32, 64, 128), jnp.float32, 8, 256),
    (32, 512, (1, 4, 16, 64), jnp.float32, 16, 128),
    (8, 128, (1, 2, 4, 8), jnp.bfloat16, 8, 128),
    (16, 1024, (1, 2, 4, 8, 16), jnp.bfloat16, 8, 512),
    (8, 96, (1, 2, 4, 48), jnp.float32, 8, 96),    # non-power-of-two n
]


@pytest.mark.parametrize("B,n,strides,dtype,br,nt", SWEEP)
def test_fwd_kernel_matches_ref(B, n, strides, dtype, br, nt):
    x = jax.random.normal(KEY, (B, n)).astype(dtype)
    cf = (0.4 * jax.random.normal(jax.random.PRNGKey(1),
                                  (len(strides), n // 2, 4)))
    y = spm_stack_kernel_call(x, cf, strides=strides, block_rows=br,
                              n_tile=nt, interpret=True)
    ref = spm_stack_ref(x.astype(jnp.float32), cf, strides).astype(dtype)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("B,n,strides,dtype,br,nt", SWEEP[:4])
def test_bwd_kernel_matches_ref(B, n, strides, dtype, br, nt):
    x = jax.random.normal(KEY, (B, n)).astype(dtype)
    gy = jax.random.normal(jax.random.PRNGKey(2), (B, n)).astype(dtype)
    cf = (0.4 * jax.random.normal(jax.random.PRNGKey(1),
                                  (len(strides), n // 2, 4)))
    gx, gcf = spm_stack_bwd_kernel_call(x, cf, gy, strides=strides,
                                        block_rows=br, n_tile=nt,
                                        interpret=True)
    rgx, rgcf = spm_stack_grads_ref(x.astype(jnp.float32), cf, strides,
                                    gy.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(gx, np.float32),
                               np.asarray(rgx, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(gcf), np.asarray(rgcf),
                               atol=tol * 10, rtol=tol * 10)


def test_fused_wrapper_odd_batch_and_3d():
    n, strides = 256, (1, 2, 4, 8, 16, 32, 64, 128)
    x = jax.random.normal(KEY, (3, 7, n))       # odd rows, 3-D
    cf = 0.4 * jax.random.normal(KEY, (8, n // 2, 4))
    y = spm_stack_fused(x, cf, strides)
    np.testing.assert_allclose(y, spm_stack_ref(x, cf, strides), atol=1e-5)


def test_fused_wrapper_grads():
    n, strides = 128, (1, 2, 4, 8, 16, 32, 64)
    x = jax.random.normal(KEY, (5, n))
    cf = 0.4 * jax.random.normal(KEY, (7, n // 2, 4))
    f = lambda x, cf: jnp.sum(spm_stack_fused(x, cf, strides) ** 2)
    r = lambda x, cf: jnp.sum(spm_stack_ref(x, cf, strides) ** 2)
    g = jax.grad(f, argnums=(0, 1))(x, cf)
    gr = jax.grad(r, argnums=(0, 1))(x, cf)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_kernel_path_in_spm_apply():
    cfg0 = SPMConfig(n=64, n_stages=6, variant="general")
    cfg1 = SPMConfig(n=64, n_stages=6, variant="general", use_kernel=True)
    p = init_spm(KEY, cfg0)
    x = jax.random.normal(KEY, (5, 64))
    np.testing.assert_allclose(spm_apply(p, x, cfg0),
                               spm_apply(p, x, cfg1), atol=1e-5)


def test_plan_runs_covers_schedule():
    runs = plan_runs(2048, (1, 2, 4, 8, 1024, 1, 2))
    flat = [s for r, _ in runs for s in r]
    assert flat == [1, 2, 4, 8, 1024, 1, 2]
    for strides, tile in runs:
        assert 2048 % tile == 0
        for s in strides:
            assert tile % (2 * s) == 0


def test_vmem_budget_respected():
    for nt in (128, 512, 2048):
        br = pick_block_rows(nt, 12)
        assert vmem_bytes(br, nt, 12) <= 12 * 2 ** 20 * 2  # within 2x budget
        assert br >= 8
