# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke
# tests and benches must see exactly 1 device; only launch/dryrun.py uses
# 512 placeholder devices.
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
