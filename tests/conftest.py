# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke
# tests and benches must see exactly 1 device; only launch/dryrun.py uses
# 512 placeholder devices.
import itertools
import sys
import types

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# hypothesis fallback shim
# ---------------------------------------------------------------------------
#
# The property-based tests (test_pairings / test_spm_core /
# test_train_substrate) use hypothesis when available; this container does
# not ship it and nothing may be pip-installed.  Degrade gracefully: install
# a minimal stand-in into sys.modules BEFORE test modules import it, turning
# each @given test into a fixed-example sweep over a small deterministic
# cross-product of the declared strategies.  Real hypothesis, when present
# (e.g. the CI with-hypothesis job), takes priority.

try:
    import hypothesis  # noqa: F401
except ImportError:
    _MAX_EXAMPLES = 24

    class _Strategy:
        """A strategy degraded to an explicit example list."""

        def __init__(self, examples):
            self.examples = list(examples)

    def _sampled_from(seq):
        return _Strategy(seq)

    def _integers(min_value=0, max_value=100):
        vals = {min_value, max_value, (min_value + max_value) // 2}
        return _Strategy(sorted(vals))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        lo, hi = float(min_value), float(max_value)
        return _Strategy([lo, (lo + hi) / 2, hi])

    def _booleans():
        return _Strategy([False, True])

    def _settings(**_kw):  # max_examples / deadline are no-ops here
        def deco(fn):
            return fn
        return deco

    def _given(*s_args, **s_kw):
        if s_args:
            raise TypeError("shim @given supports keyword strategies only")

        def deco(fn):
            names = list(s_kw)
            combos = list(
                itertools.product(*(s_kw[k].examples for k in names)))
            if len(combos) > _MAX_EXAMPLES:
                # evenly-strided subsample: product() varies the FIRST
                # strategy slowest, so a head-truncation would silently
                # drop its trailing values; striding keeps every strategy
                # covered across its range.
                step = len(combos) / _MAX_EXAMPLES
                combos = [combos[int(i * step)]
                          for i in range(_MAX_EXAMPLES)]

            def wrapper(*args, **kwargs):
                for combo in combos:
                    example = dict(zip(names, combo))
                    try:
                        fn(*args, **example, **kwargs)
                    except BaseException:
                        print(f"\n[hypothesis-shim] failing example: "
                              f"{example}")
                        raise
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    _st = types.ModuleType("hypothesis.strategies")
    _st.sampled_from = _sampled_from
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__is_shim__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
