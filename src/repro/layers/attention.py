"""GQA attention: chunked (flash-style) training path + KV-cache decode.

Projections go through the ``linear_impl`` factory so the paper's SPM
operator can replace every dense Q/K/V/O map (paper §7).  The score
computation ``Q K^T`` is untouched (paper §7.2: "attention score
computation remains unchanged").

The training/prefill path is an online-softmax over key chunks written
with ``jax.lax`` control flow: memory is O(T * chunk) instead of O(T^2),
which is what lets the 32k-prefill dry-run cells fit HBM.  Sliding-window
(Gemma3 local layers) is a mask refinement of the same loop.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.eligibility import resolve_block_fuse
from repro.core.linear import (LinearConfig, init_linear, linear_apply,
                               spm_block_operands)
from repro.layers.norms import qk_norm, rms_norm
from repro.layers.rope import apply_rope
from repro.parallel.ctx import constrain

__all__ = ["AttentionConfig", "init_attention", "attention_apply",
           "init_kv_cache", "chunked_causal_attention"]

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    use_qk_norm: bool = False
    window: Optional[int] = None        # sliding window (None = global)
    linear_impl: str = "dense"
    spm_stages: Optional[int] = None
    spm_backward: str = "autodiff"
    spm_use_kernel: Optional[bool] = None
    spm_schedule: str = "butterfly"
    spm_n_shards: int = 1
    spm_overlap: Optional[bool] = None
    spm_quant_acts: bool = False
    spm_quant_coeffs: bool = False
    # Fused-qkv norm prologue: when ``attention_apply`` receives
    # ``norm_params`` and ALL THREE q/k/v projections are block-fusible
    # SPM stacks, each projection lowers as one norm -> SPM Pallas region
    # (kernels/ops.spm_block_fused, no second stack).  Tri-state like
    # spm_use_kernel; ineligible layers fall back to one explicit
    # rms_norm + the per-linear path (bitwise).
    spm_block_fuse: Optional[bool] = None
    q_chunk: int = 1024
    k_chunk: int = 1024
    param_dtype: Any = jnp.float32

    def _lin(self, d_in: int, d_out: int) -> LinearConfig:
        return LinearConfig(
            d_in=d_in, d_out=d_out, impl=self.linear_impl, use_bias=False,
            n_stages=self.spm_stages, backward=self.spm_backward,
            use_kernel=self.spm_use_kernel, schedule=self.spm_schedule,
            n_shards=self.spm_n_shards, overlap=self.spm_overlap,
            quant_acts=self.spm_quant_acts,
            quant_coeffs=self.spm_quant_coeffs,
            param_dtype=self.param_dtype)

    @property
    def q_proj(self) -> LinearConfig:
        return self._lin(self.d_model, self.n_heads * self.head_dim)

    @property
    def kv_proj(self) -> LinearConfig:
        return self._lin(self.d_model, self.n_kv_heads * self.head_dim)

    @property
    def o_proj(self) -> LinearConfig:
        return self._lin(self.n_heads * self.head_dim, self.d_model)


def init_attention(key: jax.Array, cfg: AttentionConfig) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "q": init_linear(kq, cfg.q_proj),
        "k": init_linear(kk, cfg.kv_proj),
        "v": init_linear(kv, cfg.kv_proj),
        "o": init_linear(ko, cfg.o_proj),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), cfg.param_dtype)
        p["k_norm"] = jnp.ones((cfg.head_dim,), cfg.param_dtype)
    return p


def init_kv_cache(batch: int, max_len: int, cfg: AttentionConfig,
                  dtype=jnp.bfloat16) -> dict:
    """Decode-time cache.  ``window`` layers allocate only the window."""
    s = max_len if cfg.window is None else min(max_len, cfg.window)
    shape = (batch, s, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# chunked online-softmax attention
# ---------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q: (B, Tq, Hkv, G, dh); k: (B, Tk, Hkv, dh) -> (B, Hkv, G, Tq, Tk).

    Standard GQA convention: q head h shares kv head h // G (consecutive
    q heads share one kv head)."""
    return jnp.einsum("bthgd,bshd->bhgts", q, k)


def chunked_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                             window: Optional[int] = None,
                             q_offset: int = 0,
                             q_chunk: int = 1024,
                             k_chunk: int = 1024) -> jax.Array:
    """Causal GQA attention with online softmax over key chunks.

    q: (B, Tq, H, dh); k, v: (B, Tk, Hkv, dh) with H % Hkv == 0.
    q position i attends to k positions j <= i + q_offset (and
    j > i + q_offset - window when windowed).  Returns (B, Tq, H, dh).
    """
    B, Tq, H, dh = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = dh ** -0.5

    # Pad the EDGE chunk (masked) instead of shrinking the chunk to a
    # divisor: the old largest-divisor search degraded to chunk=1 on
    # prime/odd lengths (a T=1021 prefill became a length-1021 scan of
    # single-row chunks).  Padded key positions land past every real
    # position, so the causal mask would admit them for padded queries —
    # the explicit ``kp < Tk`` refinement keeps them out everywhere; padded
    # query rows are sliced off the output.
    q_chunk = min(q_chunk, Tq)
    k_chunk = min(k_chunk, Tk)
    Tq_pad = -(-Tq // q_chunk) * q_chunk
    Tk_pad = -(-Tk // k_chunk) * k_chunk
    if Tq_pad != Tq:
        q = jnp.pad(q, ((0, 0), (0, Tq_pad - Tq), (0, 0), (0, 0)))
    if Tk_pad != Tk:
        k = jnp.pad(k, ((0, 0), (0, Tk_pad - Tk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tk_pad - Tk), (0, 0), (0, 0)))
    nq, nk = Tq_pad // q_chunk, Tk_pad // k_chunk

    qg = (q.reshape(B, nq, q_chunk, Hkv, G, dh).astype(jnp.float32) * scale)
    kg = k.reshape(B, nk, k_chunk, Hkv, dh).astype(jnp.float32)
    vg = v.reshape(B, nk, k_chunk, Hkv, dh).astype(jnp.float32)

    q_pos = q_offset + jnp.arange(Tq_pad).reshape(nq, q_chunk)
    k_pos = jnp.arange(Tk_pad).reshape(nk, k_chunk)

    def per_q_chunk(qi, qc):
        # qc: (B, q_chunk, Hkv, G, dh)
        qp = q_pos[qi]  # (q_chunk,)

        def body(carry, inputs):
            m, l, acc = carry
            kc, vc, kp = inputs   # (B,k_chunk,Hkv,dh) x2, (k_chunk,)
            s = _gqa_scores(qc, kc)                       # (B,Hkv,G,qc,kc)
            mask = kp[None, :] <= qp[:, None]             # causal
            if window is not None:
                mask &= kp[None, :] > qp[:, None] - window
            if Tk_pad != Tk:
                mask &= kp[None, :] < Tk                  # padded keys out
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgts,bshd->bhgtd", p, vc)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (jnp.moveaxis(kg, 1, 0), jnp.moveaxis(vg, 1, 0), k_pos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # (B,Hkv,G,qc,dh)
        return jnp.transpose(out, (0, 3, 1, 2, 4))        # (B,qc,Hkv,G,dh)

    outs = jax.lax.map(lambda i: per_q_chunk(i, qg[:, i]), jnp.arange(nq))
    # outs: (nq, B, q_chunk, G, Hkv, dh)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Tq_pad, H, dh)
    if Tq_pad != Tq:
        out = out[:, :Tq]
    return out


# ---------------------------------------------------------------------------
# full layer apply
# ---------------------------------------------------------------------------

def attention_apply(params: dict, x: jax.Array, cfg: AttentionConfig, *,
                    cos: jax.Array, sin: jax.Array,
                    cache: Optional[dict] = None,
                    cache_index: Optional[jax.Array] = None,
                    fill_len: Optional[jax.Array] = None,
                    norm_params: Optional[dict] = None
                    ) -> Tuple[jax.Array, Optional[dict]]:
    """x: (B, T, d).  ``norm_params`` (the pre-attention RMSNorm scale)
    moves the input norm INSIDE this layer: when ``cfg.spm_block_fuse``
    resolves on and all three q/k/v projections are block-fusible SPM
    stacks, each projection runs as one fused norm -> SPM Pallas region
    (the norm never round-trips HBM); otherwise one explicit ``rms_norm``
    is applied up front — bitwise the caller-side composition.  Three
    modes:

    * **training** — ``cache is None``: chunked causal attention, no cache.
    * **prefill-into-cache** — cache given with ``T > 1``: the fresh
      prompt runs the SAME chunked attention path and its K/V are
      block-written into the (assumed empty) cache in one pass — no
      per-token scan.  ``cache_index`` is the scalar start position
      (serving prefills at 0); ``fill_len`` (scalar or per-row ``(B,)``)
      gives the TRUE prompt length of a right-padded batch: windowed
      layers ring-fill only the last ``window`` REAL positions (padded
      keys never evict real ones), and full layers rely on the decode
      valid mask to hide padded slots until decode overwrites them.
    * **decode** — cache given with ``T == 1``: append K/V at
      ``cache_index`` and attend over the cache.  ``cache_index`` may be
      a scalar (whole batch at one position — the fixed-batch engine) or
      per-row ``(B,)`` (continuous batching: every slot at its own
      length, scatter-written).  Windowed layers treat the cache as a
      ring buffer (slot = index % window, age-based valid mask).
    """
    B, T, _ = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    bundles = None
    if norm_params is not None:
        bq = spm_block_operands(params["q"], cfg.q_proj)
        bk = spm_block_operands(params["k"], cfg.kv_proj)
        bv = spm_block_operands(params["v"], cfg.kv_proj)
        if bq is not None and bk is not None and bv is not None:
            bundles = (bq, bk, bv)
    fuse = (norm_params is not None
            and resolve_block_fuse(cfg.spm_block_fuse, bundles is not None,
                                   jax.default_backend() == "tpu"))
    if fuse:
        from repro.kernels import ops as kernel_ops  # lazy: keeps layers light
        gamma = norm_params["scale"]

        def _norm_proj(b, lcfg):
            return kernel_ops.spm_block_fused(
                x, coeffs1=b["coeffs"], d_in1=b["d_in"], d_out1=b["d_out"],
                bias1=b["bias"], strides1=b["strides"], gamma=gamma,
                out_width=lcfg.d_out)

        q = constrain(_norm_proj(bq, cfg.q_proj)
                      .reshape(B, T, H, dh), "heads")
        k = constrain(_norm_proj(bk, cfg.kv_proj)
                      .reshape(B, T, Hkv, dh), "kv_heads")
        v = constrain(_norm_proj(bv, cfg.kv_proj)
                      .reshape(B, T, Hkv, dh), "kv_heads")
    else:
        if norm_params is not None:
            x = rms_norm(norm_params, x)
        q = constrain(linear_apply(params["q"], x, cfg.q_proj)
                      .reshape(B, T, H, dh), "heads")
        k = constrain(linear_apply(params["k"], x, cfg.kv_proj)
                      .reshape(B, T, Hkv, dh), "kv_heads")
        v = constrain(linear_apply(params["v"], x, cfg.kv_proj)
                      .reshape(B, T, Hkv, dh), "kv_heads")

    if cfg.use_qk_norm:
        q = qk_norm(params["q_norm"], q)
        k = qk_norm(params["k_norm"], k)

    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if cache is None:
        out = chunked_causal_attention(
            q, k, v, window=cfg.window,
            q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk)
        new_cache = None
    elif T > 1:
        # prefill-into-cache: attention over the fresh prompt runs the
        # chunked training path (cache assumed empty), then K/V are
        # block-written in one pass.
        out = chunked_causal_attention(
            q, k, v, window=cfg.window,
            q_chunk=cfg.q_chunk, k_chunk=cfg.k_chunk)
        kc = k.astype(cache["k"].dtype)
        vc = v.astype(cache["v"].dtype)
        s_cache = cache["k"].shape[1]
        start = jnp.asarray(0 if cache_index is None else cache_index)
        if cfg.window is None:
            ck = jax.lax.dynamic_update_slice(cache["k"], kc, (0, start, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], vc, (0, start, 0, 0))
        else:
            # ring fill: slot j holds the newest position p with
            # p % window == j among the REAL positions start..last; with a
            # right-padded prompt, ``fill_len`` keeps padded keys out of
            # the ring so they can never evict real recent positions.
            lens = jnp.broadcast_to(
                jnp.asarray(T if fill_len is None else fill_len), (B,))
            last = start + lens - 1                          # (B,) global
            j = jnp.arange(s_cache)[None, :]                 # (1, W)
            p = last[:, None] - ((last[:, None] - j) % s_cache)
            src = jnp.clip(p - start, 0, T - 1)              # (B, W)
            ck = jnp.take_along_axis(kc, src[:, :, None, None], axis=1)
            cv = jnp.take_along_axis(vc, src[:, :, None, None], axis=1)
        new_cache = {"k": ck, "v": cv}
    else:
        # decode: append k/v at cache_index (ring-buffer for windowed
        # layers); per-row (B,) cache_index scatter-writes each row at its
        # own slot — the continuous-batching path.
        ci = jnp.asarray(cache_index)
        s_cache = cache["k"].shape[1]
        slot = (ci % s_cache) if cfg.window is not None else ci
        if ci.ndim == 1:
            rows = jnp.arange(B)
            ck = cache["k"].at[rows, slot].set(
                k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[rows, slot].set(
                v[:, 0].astype(cache["v"].dtype))
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        new_cache = {"k": ck, "v": cv}
        scale = dh ** -0.5
        qf = q.astype(jnp.float32) * scale                 # (B,1,H,dh)
        kf = ck.astype(jnp.float32)
        vf = cv.astype(jnp.float32)
        qg = qf.reshape(B, 1, Hkv, H // Hkv, dh)
        s = jnp.einsum("bthgd,bshd->bhgts", qg, kf)        # (B,Hkv,G,1,S)
        pos = jnp.arange(s_cache)[None, :]                 # (1, S)
        ci_b = jnp.broadcast_to(ci, (B,))[:, None]         # (B, 1)
        if cfg.window is None:
            valid = pos <= ci_b                            # (B, S)
        else:
            # ring buffer: valid slots are the last min(index+1, window)
            n_valid = jnp.minimum(ci_b + 1, s_cache)
            slot_b = jnp.broadcast_to(slot, (B,))[:, None]
            age = (slot_b - pos) % s_cache                 # 0 = newest
            valid = age < n_valid
        s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgts,bshd->bthgd", p, vf).reshape(B, 1, H, dh)

    out = out.astype(x.dtype).reshape(B, T, H * dh)
    y = linear_apply(params["o"], out, cfg.o_proj)
    return y, new_cache
