"""GRU with SPM-substituted dense maps (paper §6).

Every one of the six affine maps (W_z, U_z, W_r, U_r, W_h, U_h) is an
independent instance of the linear factory, so ``linear_impl`` switches
the whole recurrence between the paper's dense baseline and SPM.  The
recurrence itself (gates, convex update) is untouched — paper §6.2:
"preserves the algebraic structure of the GRU".
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.linear import LinearConfig, init_linear, linear_apply

__all__ = ["GRUConfig", "init_gru", "gru_apply", "gru_cell"]


@dataclasses.dataclass(frozen=True)
class GRUConfig:
    d_in: int
    d_hidden: int
    linear_impl: str = "dense"
    spm_stages: Optional[int] = None
    spm_backward: str = "autodiff"
    spm_use_kernel: Optional[bool] = None
    spm_schedule: str = "butterfly"
    spm_n_shards: int = 1
    spm_overlap: Optional[bool] = None
    spm_quant_acts: bool = False
    spm_quant_coeffs: bool = False
    param_dtype: Any = jnp.float32

    def _lin(self, d_in: int, d_out: int, bias: bool) -> LinearConfig:
        return LinearConfig(
            d_in=d_in, d_out=d_out, impl=self.linear_impl, use_bias=bias,
            n_stages=self.spm_stages, backward=self.spm_backward,
            use_kernel=self.spm_use_kernel, schedule=self.spm_schedule,
            n_shards=self.spm_n_shards, overlap=self.spm_overlap,
            quant_acts=self.spm_quant_acts,
            quant_coeffs=self.spm_quant_coeffs,
            param_dtype=self.param_dtype)

    @property
    def w(self) -> LinearConfig:    # input maps W_. (with bias b_.)
        return self._lin(self.d_in, self.d_hidden, True)

    @property
    def u(self) -> LinearConfig:    # recurrent maps U_. (no bias)
        return self._lin(self.d_hidden, self.d_hidden, False)


def init_gru(key: jax.Array, cfg: GRUConfig) -> dict:
    ks = jax.random.split(key, 6)
    return {
        "wz": init_linear(ks[0], cfg.w), "uz": init_linear(ks[1], cfg.u),
        "wr": init_linear(ks[2], cfg.w), "ur": init_linear(ks[3], cfg.u),
        "wh": init_linear(ks[4], cfg.w), "uh": init_linear(ks[5], cfg.u),
    }


def gru_cell(params: dict, x_t: jax.Array, h_prev: jax.Array,
             cfg: GRUConfig) -> jax.Array:
    """One step (paper eqs. 20–23).  x_t: (B, d_in); h_prev: (B, d_h)."""
    z = jax.nn.sigmoid(linear_apply(params["wz"], x_t, cfg.w)
                       + linear_apply(params["uz"], h_prev, cfg.u))
    r = jax.nn.sigmoid(linear_apply(params["wr"], x_t, cfg.w)
                       + linear_apply(params["ur"], h_prev, cfg.u))
    h_tilde = jnp.tanh(linear_apply(params["wh"], x_t, cfg.w)
                       + linear_apply(params["uh"], r * h_prev, cfg.u))
    return (1.0 - z) * h_prev + z * h_tilde


def gru_apply(params: dict, x: jax.Array, cfg: GRUConfig,
              h0: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, d_in) -> (hs (B, T, d_h), h_T)."""
    B = x.shape[0]
    if h0 is None:
        h0 = jnp.zeros((B, cfg.d_hidden), x.dtype)

    def step(h, x_t):
        h_new = gru_cell(params, x_t, h, cfg)
        return h_new, h_new

    h_final, hs = jax.lax.scan(step, h0, jnp.moveaxis(x, 1, 0))
    return jnp.moveaxis(hs, 0, 1), h_final
