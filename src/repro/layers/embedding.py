"""Token embedding and output head.

Both stay DENSE regardless of ``linear_impl``: vocab tables are lookup /
classification maps over a categorical axis, not square feature mixers —
SPM's pairwise-mixing inductive bias does not apply (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["EmbeddingConfig", "init_embedding", "embed", "unembed"]


@dataclasses.dataclass(frozen=True)
class EmbeddingConfig:
    vocab_size: int
    d_model: int
    tie_output: bool = True
    param_dtype: Any = jnp.float32


def init_embedding(key: jax.Array, cfg: EmbeddingConfig) -> dict:
    ke, ko = jax.random.split(key)
    p = {"table": 0.02 * jax.random.normal(
        ke, (cfg.vocab_size, cfg.d_model), cfg.param_dtype)}
    if not cfg.tie_output:
        p["out"] = 0.02 * jax.random.normal(
            ko, (cfg.d_model, cfg.vocab_size), cfg.param_dtype)
    return p


def embed(params: dict, tokens: jax.Array, cfg: EmbeddingConfig,
          dtype=jnp.float32, onehot: bool = False) -> jax.Array:
    """Token lookup.  ``onehot=True`` lowers as a matmul: with the table
    vocab-sharded over "model" this becomes a sharded contraction + one
    small all-reduce of (tokens, d) partial sums — instead of the
    replicate-the-table gather XLA's SPMD falls back to (EXPERIMENTS
    §Perf iteration 1)."""
    if onehot:
        oh = jax.nn.one_hot(tokens, cfg.vocab_size, dtype=dtype)
        return oh @ params["table"].astype(dtype)
    return params["table"].astype(dtype)[tokens]


def unembed(params: dict, h: jax.Array, cfg: EmbeddingConfig) -> jax.Array:
    if cfg.tie_output:
        return h @ params["table"].astype(h.dtype).T
    return h @ params["out"].astype(h.dtype)
