"""SwiGLU feed-forward block (projections via the linear factory)."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.linear import LinearConfig, init_linear, linear_apply

__all__ = ["FFNConfig", "init_ffn", "ffn_apply"]


@dataclasses.dataclass(frozen=True)
class FFNConfig:
    d_model: int
    d_ff: int
    linear_impl: str = "dense"
    spm_stages: Optional[int] = None
    spm_backward: str = "autodiff"
    spm_use_kernel: Optional[bool] = None
    spm_schedule: str = "butterfly"
    spm_n_shards: int = 1
    spm_overlap: Optional[bool] = None
    spm_quant_acts: bool = False
    spm_quant_coeffs: bool = False
    param_dtype: Any = jnp.float32

    def _lin(self, d_in: int, d_out: int) -> LinearConfig:
        return LinearConfig(
            d_in=d_in, d_out=d_out, impl=self.linear_impl, use_bias=False,
            n_stages=self.spm_stages, backward=self.spm_backward,
            use_kernel=self.spm_use_kernel, schedule=self.spm_schedule,
            n_shards=self.spm_n_shards, overlap=self.spm_overlap,
            quant_acts=self.spm_quant_acts,
            quant_coeffs=self.spm_quant_coeffs,
            param_dtype=self.param_dtype)

    @property
    def up(self) -> LinearConfig:
        return self._lin(self.d_model, self.d_ff)

    @property
    def gate(self) -> LinearConfig:
        return self._lin(self.d_model, self.d_ff)

    @property
    def down(self) -> LinearConfig:
        return self._lin(self.d_ff, self.d_model)


def init_ffn(key: jax.Array, cfg: FFNConfig) -> dict:
    ku, kg, kd = jax.random.split(key, 3)
    return {"up": init_linear(ku, cfg.up),
            "gate": init_linear(kg, cfg.gate),
            "down": init_linear(kd, cfg.down)}


def ffn_apply(params: dict, x: jax.Array, cfg: FFNConfig) -> jax.Array:
    u = linear_apply(params["up"], x, cfg.up)
    g = linear_apply(params["gate"], x, cfg.gate)
    h = jax.nn.silu(g) * u
    return linear_apply(params["down"], h, cfg.down)
