"""Feed-forward block (projections via the linear factory).

Two shapes, selected by ``FFNConfig.activation``:

  * ``"swiglu"`` (default) — gated: ``down(silu(gate(x)) * up(x))``.
  * ``"relu" | "silu" | "gelu"`` — ungated: ``down(act(up(x)))``, no gate
    parameters.  These are the shapes the residual-block megakernel can
    lower as ONE fused Pallas region (``ffn_block_apply``): the gate of
    swiglu is a second independent SPM over the same input, not a
    chainable elementwise epilogue, so swiglu always takes the per-linear
    path.

``ffn_block_apply`` is the fused residual-block entry used by the
transformer: ``x + ffn(rms_norm(x))`` with norm prologue, activation
epilogue, and residual store inside the kernel chain when
``core/eligibility.resolve_block_fuse`` engages, and the bitwise XLA /
per-linear-kernel composition otherwise.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.eligibility import block_fusion_eligible, resolve_block_fuse
from repro.core.linear import (LinearConfig, init_linear, linear_apply,
                               spm_block_operands)
from repro.layers.norms import rms_norm

__all__ = ["FFNConfig", "init_ffn", "ffn_apply", "ffn_block_apply"]

_ACTS = {"relu": jax.nn.relu, "silu": jax.nn.silu, "gelu": jax.nn.gelu}


@dataclasses.dataclass(frozen=True)
class FFNConfig:
    d_model: int
    d_ff: int
    linear_impl: str = "dense"
    activation: str = "swiglu"           # "swiglu" | "relu" | "silu" | "gelu"
    spm_stages: Optional[int] = None
    spm_backward: str = "autodiff"
    spm_use_kernel: Optional[bool] = None
    spm_schedule: str = "butterfly"
    spm_n_shards: int = 1
    spm_overlap: Optional[bool] = None
    spm_quant_acts: bool = False
    spm_quant_coeffs: bool = False
    # Residual-block megakernel (norm -> up -> act -> down -> residual in
    # one Pallas chain): tri-state like spm_use_kernel.  None = auto
    # (on-TPU), True = force (interpret off-TPU), False = per-linear path.
    # Only engages for ungated activations on block-fusible SPM linears
    # (core/eligibility.block_fusion_eligible); falls back gracefully.
    spm_block_fuse: Optional[bool] = None
    param_dtype: Any = jnp.float32

    def __post_init__(self):
        if self.activation != "swiglu" and self.activation not in _ACTS:
            raise ValueError(f"unknown ffn activation {self.activation!r}")

    def _lin(self, d_in: int, d_out: int) -> LinearConfig:
        return LinearConfig(
            d_in=d_in, d_out=d_out, impl=self.linear_impl, use_bias=False,
            n_stages=self.spm_stages, backward=self.spm_backward,
            use_kernel=self.spm_use_kernel, schedule=self.spm_schedule,
            n_shards=self.spm_n_shards, overlap=self.spm_overlap,
            quant_acts=self.spm_quant_acts,
            quant_coeffs=self.spm_quant_coeffs,
            param_dtype=self.param_dtype)

    @property
    def up(self) -> LinearConfig:
        return self._lin(self.d_model, self.d_ff)

    @property
    def gate(self) -> LinearConfig:
        return self._lin(self.d_model, self.d_ff)

    @property
    def down(self) -> LinearConfig:
        return self._lin(self.d_ff, self.d_model)


def init_ffn(key: jax.Array, cfg: FFNConfig) -> dict:
    """Init the block's linears (no gate for ungated activations)."""
    ku, kg, kd = jax.random.split(key, 3)
    p = {"up": init_linear(ku, cfg.up), "down": init_linear(kd, cfg.down)}
    if cfg.activation == "swiglu":
        p["gate"] = init_linear(kg, cfg.gate)
    return p


def ffn_apply(params: dict, x: jax.Array, cfg: FFNConfig) -> jax.Array:
    """The FFN body alone (no norm, no residual): gated swiglu or
    ``down(act(up(x)))`` per ``cfg.activation``."""
    u = linear_apply(params["up"], x, cfg.up)
    if cfg.activation == "swiglu":
        g = linear_apply(params["gate"], x, cfg.gate)
        h = jax.nn.silu(g) * u
    else:
        h = _ACTS[cfg.activation](u)
    return linear_apply(params["down"], h, cfg.down)


def _block_bundles(params: dict, cfg: FFNConfig):
    """The (up, down) kernel-operand bundles when this FFN is structurally
    block-fusible, else None: ungated activation, both linears
    block-fusible SPM stacks sharing one operator width."""
    if cfg.activation == "swiglu":
        return None
    up = spm_block_operands(params["up"], cfg.up)
    if up is None:
        return None
    down = spm_block_operands(params["down"], cfg.down)
    if down is None or down["n"] != up["n"]:
        return None
    if not block_fusion_eligible(up["n"], up["strides"], down["strides"],
                                 cfg.activation):
        return None
    return up, down


def ffn_block_apply(params: dict, norm_params: Optional[dict], x: jax.Array,
                    cfg: FFNConfig) -> jax.Array:
    """The whole residual block: ``x + ffn(rms_norm(x))``.

    When ``resolve_block_fuse`` engages (tri-state ``cfg.spm_block_fuse``
    over structural eligibility), the block lowers as ONE fused Pallas
    region — RMS prologue, up-stack, activation epilogue, down-stack, and
    residual-add on the store, with the closed-form block custom_vjp
    (``kernels/ops.spm_block_fused``).  Otherwise the composition below is
    literally the pre-existing per-linear path (bitwise fallback).
    ``norm_params=None`` skips the norm (block without prologue)."""
    bundles = _block_bundles(params, cfg)
    fuse = resolve_block_fuse(cfg.spm_block_fuse, bundles is not None,
                              jax.default_backend() == "tpu")
    if fuse:
        from repro.kernels import ops as kernel_ops  # lazy: keeps layers light
        up, down = bundles
        gamma = (norm_params["scale"] if norm_params is not None else None)
        return kernel_ops.spm_block_fused(
            x, coeffs1=up["coeffs"], d_in1=up["d_in"], d_out1=up["d_out"],
            bias1=up["bias"], strides1=up["strides"], gamma=gamma,
            coeffs2=down["coeffs"], d_in2=down["d_in"],
            d_out2=down["d_out"], bias2=down["bias"],
            strides2=down["strides"], activation=cfg.activation,
            residual=True, mid_width=cfg.d_ff, out_width=cfg.d_model)
    h = rms_norm(norm_params, x) if norm_params is not None else x
    return x + ffn_apply(params, h, cfg)
