"""Rotary position embeddings: standard RoPE and multi-axis M-RoPE.

M-RoPE (Qwen2-VL, arXiv:2409.12191) splits the head dimension into
sections rotated by separate (temporal, height, width) position ids.  For
the text-only backbone path all three ids coincide, which reduces M-RoPE
exactly to 1-D RoPE; the section machinery is exercised by the VLM config
through ``input_specs`` patch-grid positions.
"""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = ["rope_angles", "apply_rope", "mrope_angles"]


def rope_angles(positions: jax.Array, head_dim: int,
                theta: float = 10000.0) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables.  positions: (..., T) int -> (..., T, head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(positions: jax.Array, head_dim: int,
                 sections: Sequence[int],
                 theta: float = 10000.0) -> Tuple[jax.Array, jax.Array]:
    """M-RoPE tables.  positions: (3, ..., T) for (t, h, w) ids; sections are
    half-dim section sizes summing to head_dim // 2 (e.g. (16, 24, 24) for
    head_dim 128)."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, head_dim)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang_all = positions.astype(jnp.float32)[..., None] * freqs  # (3,...,T,half)
    parts = []
    off = 0
    for axis, sec in enumerate(sections):
        parts.append(ang_all[axis][..., off: off + sec])
        off += sec
    ang = jnp.concatenate(parts, axis=-1)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate pairs (x[..2i], x[..2i+1]).  x: (..., T, H, head_dim);
    cos/sin: (..., T, head_dim//2) broadcast over the head axis."""
    half = x.shape[-1] // 2
    xf = x.astype(jnp.float32)
    x1 = xf[..., :half]
    x2 = xf[..., half:]
    c = cos[..., None, :]   # add head axis
    s = sin[..., None, :]
    y1 = x1 * c - x2 * s
    y2 = x2 * c + x1 * s
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
