"""Mixture-of-Experts FFN with GShard-style capacity dispatch.

The dispatch/combine einsums are the expert-parallel (EP) communication
pattern: with experts sharded over the ``model`` mesh axis and tokens over
``data``, XLA's SPMD partitioner lowers them to all-to-alls.  The router
stays a dense ``d -> E`` map — it is a tiny classifier head, not a square
feature mixer, so SPM is inapplicable by design (DESIGN.md §4).

Per-expert FFN weights DO route through the linear factory, so SPM applies
inside each expert (``vmap`` over the expert axis).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.layers.ffn import FFNConfig, init_ffn, ffn_apply

__all__ = ["MoEConfig", "init_moe", "moe_apply"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                 # per-expert hidden dim
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    group_size: int = 512     # GShard "S": dispatch is computed per token
                              # group, so the one-hot tensor is
                              # (G, S, E, C) with C ~ k*S/E — total memory
                              # O(N * k * S), NOT O(N * E * C_global).
    shared_d_ff: int = 0      # Llama4-style always-on shared expert (0 = off)
    linear_impl: str = "dense"
    spm_stages: Optional[int] = None
    spm_backward: str = "autodiff"
    spm_use_kernel: Optional[bool] = None
    spm_schedule: str = "butterfly"
    spm_n_shards: int = 1
    spm_overlap: Optional[bool] = None
    spm_quant_acts: bool = False
    spm_quant_coeffs: bool = False
    param_dtype: Any = jnp.float32

    @property
    def expert_ffn(self) -> FFNConfig:
        return FFNConfig(d_model=self.d_model, d_ff=self.d_ff,
                         linear_impl=self.linear_impl,
                         spm_stages=self.spm_stages,
                         spm_backward=self.spm_backward,
                         spm_use_kernel=self.spm_use_kernel,
                         spm_schedule=self.spm_schedule,
                         spm_n_shards=self.spm_n_shards,
                         spm_overlap=self.spm_overlap,
                         spm_quant_acts=self.spm_quant_acts,
                         spm_quant_coeffs=self.spm_quant_coeffs,
                         param_dtype=self.param_dtype)

    @property
    def shared_ffn(self) -> FFNConfig:
        return FFNConfig(d_model=self.d_model, d_ff=self.shared_d_ff,
                         linear_impl=self.linear_impl,
                         spm_stages=self.spm_stages,
                         spm_backward=self.spm_backward,
                         spm_use_kernel=self.spm_use_kernel,
                         spm_schedule=self.spm_schedule,
                         spm_n_shards=self.spm_n_shards,
                         spm_overlap=self.spm_overlap,
                         spm_quant_acts=self.spm_quant_acts,
                         spm_quant_coeffs=self.spm_quant_coeffs,
                         param_dtype=self.param_dtype)

    def capacity(self, group_tokens: int) -> int:
        c = int(self.capacity_factor * self.top_k * group_tokens
                / self.n_experts)
        return max(c, self.top_k)


def init_moe(key: jax.Array, cfg: MoEConfig) -> dict:
    kr, ke, ks = jax.random.split(key, 3)
    p = {
        "router": 0.02 * jax.random.normal(
            kr, (cfg.d_model, cfg.n_experts), cfg.param_dtype),
        "experts": jax.vmap(lambda k: init_ffn(k, cfg.expert_ffn))(
            jax.random.split(ke, cfg.n_experts)),
    }
    if cfg.shared_d_ff:
        p["shared"] = init_ffn(ks, cfg.shared_ffn)
    return p


def _top_k_gating(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """logits (..., E) -> (gates (..., E) renormalized over chosen, mask)."""
    topv, topi = jax.lax.top_k(logits, k)
    probs = jax.nn.softmax(topv, axis=-1)                 # renorm over top-k
    onehot = jax.nn.one_hot(topi, logits.shape[-1],
                            dtype=logits.dtype)           # (..., k, E)
    gates = jnp.einsum("...k,...ke->...e", probs, onehot)
    mask = jnp.sum(onehot, axis=-2) > 0
    return gates, mask


def moe_apply(params: dict, x: jax.Array, cfg: MoEConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, d) -> (y, aux_loss).  aux is the load-balancing loss
    (Switch-style mean(gate_frac * token_frac) * E).

    GShard grouped dispatch: tokens are split into G groups of S; routing
    capacity is per (group, expert), so the dispatch one-hot is
    (G, S, E, C) with C = ceil(cf * k * S / E).  With G sharded over
    ``data`` and experts over ``model``, the two einsums below lower to
    the canonical EP all-to-all pair.
    """
    B, T, d = x.shape
    n_tok = B * T
    S = min(cfg.group_size, n_tok)
    while n_tok % S:
        S -= 1
    G = n_tok // S
    cap = cfg.capacity(S)

    xg = x.reshape(G, S, d)
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    gates, mask = _top_k_gating(logits, cfg.top_k)        # (G, S, E)

    # load-balancing aux loss (global means)
    me = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=(0, 1))
    ce = jnp.mean(mask.astype(jnp.float32),
                  axis=(0, 1)) * cfg.n_experts / cfg.top_k
    aux = jnp.sum(me * ce)

    # capacity-limited positions: rank within (group, expert)
    maskf = mask.astype(jnp.int32)
    pos = jnp.cumsum(maskf, axis=1) - 1                   # (G, S, E)
    keep = mask & (pos < cap)
    gates = jnp.where(keep, gates, 0.0)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, -1), cap,
                            dtype=x.dtype)                # (G, S, E, C)

    dispatch = pos_oh
    combine = gates.astype(x.dtype)[..., None] * pos_oh

    xe = jnp.einsum("gsec,gsd->egcd", dispatch, xg)       # EP all-to-all
    E = cfg.n_experts
    ye = jax.vmap(lambda p, h: ffn_apply(p, h, cfg.expert_ffn)
                  )(params["experts"], xe.reshape(E, G * cap, d))
    ye = ye.reshape(E, G, cap, d)
    yg = jnp.einsum("gsec,egcd->gsd", combine, ye)        # EP all-to-all

    y = yg.reshape(B, T, d)
    if cfg.shared_d_ff:
        y = y + ffn_apply(params["shared"], x, cfg.shared_ffn)
    return y.astype(x.dtype), aux
