"""Mamba2 / SSD (state-space duality) mixer, chunked-scan formulation.

Implements the SSD algorithm of arXiv:2405.21060: the sequence is split
into chunks; within a chunk the output is the quadratic "attention-like"
form masked by the cumulative decay matrix L; across chunks an O(T/Q)
``lax.scan`` carries the (H, P, N) recurrent state.  Decode is the O(1)
recurrence ``h <- a h + dt B x``.

TPU adaptation: chunk length defaults to 128 so the intra-chunk einsums
are MXU-shaped (128-aligned); the inter-chunk scan is sequential but tiny.
in/out projections route through the linear factory (SPM-able — the SSD
scan itself is already sub-quadratic and is left untouched, DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.linear import LinearConfig, init_linear, linear_apply
from repro.layers.norms import init_rms_norm, rms_norm

__all__ = ["Mamba2Config", "init_mamba2", "mamba2_apply", "init_ssm_cache"]


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    d_head: int = 64               # P
    expand: int = 2
    d_conv: int = 4
    chunk: int = 128
    linear_impl: str = "dense"
    spm_stages: Optional[int] = None
    spm_backward: str = "autodiff"
    spm_use_kernel: Optional[bool] = None
    spm_schedule: str = "butterfly"
    spm_n_shards: int = 1
    spm_overlap: Optional[bool] = None
    spm_quant_acts: bool = False
    spm_quant_coeffs: bool = False
    param_dtype: Any = jnp.float32

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.d_head

    @property
    def d_in_proj(self) -> int:
        # [z, x, B, C, dt]  (single SSM group)
        return 2 * self.d_inner + 2 * self.d_state + self.n_heads

    def _lin(self, d_in: int, d_out: int) -> LinearConfig:
        return LinearConfig(
            d_in=d_in, d_out=d_out, impl=self.linear_impl, use_bias=False,
            n_stages=self.spm_stages, backward=self.spm_backward,
            use_kernel=self.spm_use_kernel, schedule=self.spm_schedule,
            n_shards=self.spm_n_shards, overlap=self.spm_overlap,
            quant_acts=self.spm_quant_acts,
            quant_coeffs=self.spm_quant_coeffs,
            param_dtype=self.param_dtype)

    @property
    def in_proj(self) -> LinearConfig:
        return self._lin(self.d_model, self.d_in_proj)

    @property
    def out_proj(self) -> LinearConfig:
        return self._lin(self.d_inner, self.d_model)


def init_mamba2(key: jax.Array, cfg: Mamba2Config) -> dict:
    ki, ko, kc, kd = jax.random.split(key, 4)
    H = cfg.n_heads
    conv_dim = cfg.d_inner + 2 * cfg.d_state
    dt = jnp.exp(jax.random.uniform(kd, (H,), cfg.param_dtype)
                 * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
    return {
        "in_proj": init_linear(ki, cfg.in_proj),
        "out_proj": init_linear(ko, cfg.out_proj),
        "conv_w": 0.1 * jax.random.normal(
            kc, (cfg.d_conv, conv_dim), cfg.param_dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.param_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(cfg.param_dtype)),
        "D": jnp.ones((H,), cfg.param_dtype),
        "dt_bias": jnp.log(jnp.expm1(dt)),   # softplus^-1(dt)
        "norm": init_rms_norm(cfg.d_inner, cfg.param_dtype),
    }


def init_ssm_cache(batch: int, cfg: Mamba2Config, dtype=jnp.float32) -> dict:
    conv_dim = cfg.d_inner + 2 * cfg.d_state
    return {
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.d_head, cfg.d_state), dtype),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype),
    }


def _segsum(a: jax.Array) -> jax.Array:
    """Stable segment-sum: a (..., Q) -> (..., Q, Q) lower-tri cumulative
    sums  out[i, j] = sum_{k=j+1..i} a[k]  (−inf above the diagonal)."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def _ssd_chunked(x, dt, A, B, C, D, chunk: int):
    """SSD scan.  x: (b, T, H, P); dt: (b, T, H); A: (H,);
    B, C: (b, T, N).  Returns y (b, T, H, P), final state (b, H, P, N)."""
    b, T, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, T)
    while T % Q:
        Q -= 1
    nc = T // Q

    xd = x * dt[..., None]                     # fold dt into inputs
    a = dt * (-jnp.exp(A))                     # log-decay per step (b,T,H)

    xc = xd.reshape(b, nc, Q, H, P)
    ac = a.reshape(b, nc, Q, H)
    Bc = B.reshape(b, nc, Q, N)
    Cc = C.reshape(b, nc, Q, N)

    acs = jnp.cumsum(ac, axis=2)               # (b,nc,Q,H)
    L = jnp.exp(_segsum(jnp.moveaxis(ac, -1, -2)))   # (b,nc,H,Q,Q)

    # intra-chunk (diagonal block): y = (C B^T ⊙ L) x
    cb = jnp.einsum("bcqn,bcsn->bcqs", Cc, Bc)       # (b,nc,Q,Q)
    yd = jnp.einsum("bcqs,bchqs,bcshp->bcqhp", cb, L, xc)

    # chunk-final states: h_c = sum_s exp(acs_Q - acs_s) B_s x_s
    decay_to_end = jnp.exp(acs[:, :, -1:, :] - acs)  # (b,nc,Q,H)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn",
                        Bc, decay_to_end, xc)        # (b,nc,H,P,N)

    # inter-chunk recurrence over nc (sequential, tiny)
    chunk_decay = jnp.exp(acs[:, :, -1, :])          # (b,nc,H)

    def body(h, inp):
        st, dec = inp                                # (b,H,P,N), (b,H)
        h_new = h * dec[..., None, None] + st
        return h_new, h                              # emit state BEFORE chunk

    h0 = jnp.zeros((b, H, P, N), x.dtype)
    h_final, h_prev = jax.lax.scan(
        body, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)              # (b,nc,H,P,N)

    # inter-chunk contribution: y += C_t exp(acs_t) h_prev
    in_decay = jnp.exp(acs)                          # (b,nc,Q,H)
    yi = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, in_decay, h_prev)

    y = (yd + yi).reshape(b, T, H, P) + x * D[None, None, :, None]
    return y, h_final


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv.  u: (B, T, C); w: (K, C)."""
    K = w.shape[0]
    up = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(K):
        out = out + up[:, i: i + u.shape[1], :] * w[i]
    return out + b


def mamba2_apply(params: dict, x: jax.Array, cfg: Mamba2Config, *,
                 cache: Optional[dict] = None
                 ) -> Tuple[jax.Array, Optional[dict]]:
    """x: (B, T, d).  Returns (y, new_cache).  cache given => T == 1 decode."""
    Bsz, T, _ = x.shape
    H, P, N = cfg.n_heads, cfg.d_head, cfg.d_state
    zxbcdt = linear_apply(params["in_proj"], x, cfg.in_proj)
    z, xin, Bv, Cv, dt = jnp.split(
        zxbcdt, [cfg.d_inner, 2 * cfg.d_inner,
                 2 * cfg.d_inner + N, 2 * cfg.d_inner + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xin, Bv, Cv], axis=-1)
    w, bconv = params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype)

    if cache is None:
        conv = jax.nn.silu(_causal_conv(conv_in, w, bconv))
        new_cache = None
    else:
        hist = jnp.concatenate(
            [cache["conv"].astype(x.dtype), conv_in], axis=1)
        acc = bconv + jnp.einsum("kc,bkc->bc", w, hist)[:, None, :]
        conv = jax.nn.silu(acc)
        new_conv = hist[:, 1:, :]
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype)}

    xc, Bc, Cc = jnp.split(conv, [cfg.d_inner, cfg.d_inner + N], axis=-1)
    xh = xc.reshape(Bsz, T, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = params["A_log"].astype(jnp.float32)
    D = params["D"].astype(jnp.float32)

    if cache is None:
        y, _ = _ssd_chunked(xh.astype(jnp.float32), dt, A,
                            Bc.astype(jnp.float32), Cc.astype(jnp.float32),
                            D, cfg.chunk)
    else:
        # O(1) recurrent step:  h <- exp(-exp(A) dt) h + dt B x
        a = jnp.exp(dt[:, 0, :] * (-jnp.exp(A)))          # (B,H)
        h = cache["ssm"].astype(jnp.float32)
        upd = jnp.einsum("bh,bhp,bn->bhpn",
                         dt[:, 0, :], xh[:, 0].astype(jnp.float32),
                         Bc[:, 0].astype(jnp.float32))
        h = h * a[..., None, None] + upd
        yv = jnp.einsum("bhpn,bn->bhp", h, Cc[:, 0].astype(jnp.float32))
        y = (yv + xh[:, 0].astype(jnp.float32)
             * D[None, :, None])[:, None]
        new_cache["ssm"] = h.astype(cache["ssm"].dtype)

    y = y.reshape(Bsz, T, cfg.d_inner).astype(x.dtype)
    y = rms_norm(params["norm"], y * jax.nn.silu(z))
    return linear_apply(params["out_proj"], y, cfg.out_proj), new_cache
