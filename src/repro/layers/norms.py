"""Normalization layers (RMSNorm, per-head qk-norm) and the fused
norm -> linear entry.

``norm_linear_apply`` is the single-stack face of the residual-block
megakernel: RMSNorm prologue computed in VMEM feeding one SPM operator in
the same Pallas region (``kernels/ops.spm_block_fused`` with no second
stack, no residual) — used wherever a norm directly feeds a projection
(the fused-qkv entry in ``layers/attention``, a final norm -> head).  The
fallback is literally ``linear_apply(params, rms_norm(x))`` (bitwise)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.eligibility import resolve_block_fuse
from repro.core.linear import LinearConfig, linear_apply, spm_block_operands

__all__ = ["rms_norm", "init_rms_norm", "qk_norm", "norm_linear_apply"]


def init_rms_norm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm over the last axis; stats in f32 for stability."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def norm_linear_apply(norm_params: dict, params: dict, x: jax.Array,
                      cfg: LinearConfig,
                      block_fuse: Optional[bool] = None,
                      eps: float = 1e-6) -> jax.Array:
    """``linear_apply(params, rms_norm(norm_params, x))`` with the norm
    fused into the SPM kernel's prologue when the tri-state ``block_fuse``
    knob resolves on (``core/eligibility.resolve_block_fuse``): row stats
    and scale computed in VMEM feeding the operator's first run, so the
    normalized activation never round-trips HBM.  Falls back bitwise to
    the explicit composition for dense/sharded/quantized/ineligible
    linears."""
    bundle = spm_block_operands(params, cfg)
    fuse = resolve_block_fuse(block_fuse, bundle is not None,
                              jax.default_backend() == "tpu")
    if fuse:
        from repro.kernels import ops as kernel_ops  # lazy: keeps layers light
        return kernel_ops.spm_block_fused(
            x, coeffs1=bundle["coeffs"], d_in1=bundle["d_in"],
            d_out1=bundle["d_out"], bias1=bundle["bias"],
            strides1=bundle["strides"], gamma=norm_params["scale"],
            out_width=cfg.d_out, eps=eps)
    return linear_apply(params, rms_norm(norm_params, x, eps=eps), cfg)


def qk_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMS norm over head_dim (Qwen3 / Gemma3 style).

    x: (..., head_dim); scale: (head_dim,).
    """
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)
