"""Normalization layers (RMSNorm and per-head qk-norm)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "init_rms_norm", "qk_norm"]


def init_rms_norm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm over the last axis; stats in f32 for stability."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def qk_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMS norm over head_dim (Qwen3 / Gemma3 style).

    x: (..., head_dim); scale: (head_dim,).
    """
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)
