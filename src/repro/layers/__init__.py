"""Neural-net building blocks; every projection routes through the
``linear_impl`` factory so SPM can replace any dense map (paper §6–7)."""

from repro.layers.norms import (  # noqa: F401
    rms_norm, init_rms_norm, qk_norm, norm_linear_apply,
)
from repro.layers.rope import rope_angles, mrope_angles, apply_rope  # noqa: F401
from repro.layers.attention import (  # noqa: F401
    AttentionConfig, init_attention, attention_apply, init_kv_cache,
    chunked_causal_attention,
)
from repro.layers.ffn import (  # noqa: F401
    FFNConfig, init_ffn, ffn_apply, ffn_block_apply,
)
from repro.layers.moe import MoEConfig, init_moe, moe_apply  # noqa: F401
from repro.layers.mamba2 import (  # noqa: F401
    Mamba2Config, init_mamba2, mamba2_apply, init_ssm_cache,
)
from repro.layers.gru import GRUConfig, init_gru, gru_apply, gru_cell  # noqa: F401
from repro.layers.embedding import (  # noqa: F401
    EmbeddingConfig, init_embedding, embed, unembed,
)
