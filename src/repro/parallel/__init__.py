"""Mesh + PartitionSpec machinery (DP / FSDP / TP / EP / SP + pod axis),
plus the distributed two_level SPM executor (feature axis over "model")."""

from repro.parallel.sharding import (  # noqa: F401
    param_spec, param_shardings, batch_spec, cache_specs, data_axes,
    tree_path_str,
)
from repro.parallel.spm_shard import (  # noqa: F401
    spm_apply_sharded, sharded_eligible, plan_steps,
)
