"""Mesh + PartitionSpec machinery (DP / FSDP / TP / EP / SP + pod axis)."""

from repro.parallel.sharding import (  # noqa: F401
    param_spec, param_shardings, batch_spec, cache_specs, data_axes,
    tree_path_str,
)
