"""PartitionSpec rule table: parameters, activations, caches.

Strategy (DESIGN.md §6): FSDP x TP inside a pod over mesh axes
``("data", "model")``; the optional ``"pod"`` axis is an outer pure-DP
axis (params replicated across pods, gradients all-reduced — the only
cross-pod collective, matching ICI-vs-DCN bandwidth).

Rules are written against the TRAILING dims of each parameter so they are
insensitive to leading stacking axes (scan groups, vmapped experts): a
rule returning k trailing axis names is left-padded with ``None``.
Experts are the exception — the expert axis (just before the trailing
dims) is sharded over ``model`` (expert parallelism), and inner dims fall
back to ``data``-only sharding to avoid axis reuse.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_spec", "param_shardings", "batch_spec", "cache_specs",
           "data_axes", "tree_path_str"]


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """All pure-DP axes present in the mesh (pod + data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tree_path_str(path) -> str:
    """Render a jax.tree_util key path as the dotted/indexed string the
    ``param_spec`` profile rules match against (e.g. "layers.3.ffn.w")."""
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# SPM parameters: pair / feature axes split over "model" in the SAME
# contiguous blocks the distributed two_level executor
# (parallel/spm_shard.py) reads — stage coeffs by trailing pair axis,
# diagonals/bias by the feature axis.  Shared by the "tp" rule table below
# and the "spm_feat" profile.
_SPM_PARAM_RULES = (
    # stage coeffs: (L, n_pairs, 4) / (L, n_pairs) — pairs over model
    (lambda p: p.endswith("/mix"), (None, "model", None)),
    (lambda p: p.endswith("/theta"), (None, "model")),
    # diagonals / bias: (n,) over model, matching the pair sharding
    (lambda p: any(p.endswith(s) for s in
                   ("/d_in", "/d_out", "/bias", "/res_scale")),
     ("model",)),
)

# trailing-dim rule table: (predicate on path, trailing spec)
# order matters — first match wins.
_RULES = (
    # embeddings: (vocab, d) — vocab-parallel TP, FSDP over d
    (lambda p: p.endswith("embed/table") or p.endswith("embed/out"),
     ("model", "data")),
    # routers are tiny classifiers: replicate
    (lambda p: p.endswith("router"), (None, None)),
    # output-expanding dense mats: (d_in, d_out) col-parallel + FSDP rows
    (lambda p: any(p.endswith(s) for s in
                   ("/q/w", "/k/w", "/v/w", "/up/w", "/gate/w", "/wz/w",
                    "/wr/w", "/wh/w", "/uz/w", "/ur/w", "/uh/w", "/mix/w",
                    "in_proj/w")),
     ("data", "model")),
    # input-contracting dense mats: (d_in, d_out) row-parallel + FSDP cols
    (lambda p: any(p.endswith(s) for s in
                   ("/o/w", "/down/w", "out_proj/w", "/head/w")),
     ("model", "data")),
    *_SPM_PARAM_RULES,
    # mamba conv: (K, conv_dim) — conv_dim over model
    (lambda p: p.endswith("conv_w"), (None, "model")),
)


PROFILES = ("tp", "spm_dp", "spm_dp_g", "spm_dp_g2", "spm_feat")


def param_spec(path_str: str, ndim: int, mesh: Mesh,
               profile: str = "tp") -> P:
    """PartitionSpec for one parameter.

    profile="tp":      classic Megatron-style rule table (the naive
                       baseline for SPM models — XLA then has to guess
                       how elementwise SPM stages interact with TP).
    profile="spm_dp":  SPM-aware: SPM/norm/small params REPLICATED (they
                       are O(nL)); the model axis is reserved for what
                       actually scales — vocab-parallel embeddings and
                       expert parallelism.  Activations stay batch-sharded
                       over the data axes; heads are sharded via explicit
                       activation constraints (parallel/ctx.py).
    profile="spm_feat": spm_dp + SPM stage coeffs/diagonals SHARD-SPLIT
                       over "model" in the blocks the two_level distributed
                       executor reads (pair axis for mix/theta, feature
                       axis for d_in/d_out/bias) — feature parallelism via
                       collective_permute instead of replication.
    """
    have_model = "model" in mesh.axis_names
    have_data = "data" in mesh.axis_names

    if profile.startswith("spm_dp") or profile == "spm_feat":
        is_expert = "/experts/" in path_str
        if path_str.endswith("embed/table") or path_str.endswith("embed/out"):
            return P(*([None] * (ndim - 2)), "model", None)
        if is_expert and ndim >= 2 and have_model:
            # expert axis over model (EP); inner dims replicated.
            expert_axis = (1 if path_str.startswith("layers/")
                           and "/mlp/" in path_str else 0)
            spec = [None] * ndim
            spec[expert_axis] = "model"
            return P(*spec)
        if profile == "spm_feat" and have_model:
            for pred, trailing in _SPM_PARAM_RULES:
                if pred(path_str):
                    k = len(trailing)
                    if ndim < k:
                        return P(*([None] * ndim))
                    return P(*([None] * (ndim - k)), *trailing)
        return P(*([None] * ndim))

    def mesh_ok(ax):
        return (ax is None or (ax == "model" and have_model)
                or (ax == "data" and have_data))

    is_expert = "/experts/" in path_str or path_str.endswith("/experts")

    for pred, trailing in _RULES:
        if pred(path_str):
            if is_expert:
                # expert axis takes "model" (EP); free inner dims of the
                # rule from "model" to avoid reuse within one spec.
                trailing = tuple("data" if ax == "data" else None
                                 for ax in trailing)
                k = len(trailing)
                if ndim < k + 1:   # scalar-ish expert param
                    return P(*([None] * ndim))
                lead = [None] * (ndim - k - 1) + ["model"]
                return P(*lead, *trailing)
            k = len(trailing)
            if ndim < k:
                return P(*([None] * ndim))
            trailing = tuple(ax if mesh_ok(ax) else None for ax in trailing)
            return P(*([None] * (ndim - k)), *trailing)
    if is_expert and ndim >= 2 and have_model:
        # unmatched expert param (SPM coeffs, norms inside experts): shard
        # the expert axis, which sits right after any scan-group axis.  We
        # cannot see stacking depth here, so shard the FIRST axis — correct
        # for unscanned experts, and for scanned models the group axis is
        # folded before experts only in "layers/<i>/mlp/experts/..." paths,
        # where axis 0 is the group: fall back to axis 1.
        expert_axis = 1 if path_str.startswith("layers/") and "/mlp/" in path_str else 0
        spec = [None] * ndim
        if expert_axis < ndim:
            spec[expert_axis] = "model"
        return P(*spec)
    # norms, biases, small vectors: replicate
    return P(*([None] * ndim))


def _drop_indivisible(spec: P, shape, mesh: Mesh) -> P:
    """jit in_shardings demands exact divisibility: drop any axis
    assignment the dim size cannot honor (e.g. vocab 50280 on 16-way
    model)."""
    if shape is None:
        return spec
    out = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            out.append(None if i >= len(shape) else ax)
            continue
        szs = [mesh.shape[a] for a in (ax if isinstance(ax, tuple)
                                       else (ax,))]
        out.append(ax if shape[i] % int(np.prod(szs)) == 0 else None)
    return P(*out)


def param_shardings(mesh: Mesh, params: Any, profile: str = "tp") -> Any:
    """Pytree of NamedShardings matching ``params`` (arrays or
    ShapeDtypeStructs)."""
    def one(path, x):
        ndim = np.ndim(x) if not hasattr(x, "ndim") else x.ndim
        shape = getattr(x, "shape", None)
        spec = param_spec(tree_path_str(path), ndim, mesh, profile)
        return NamedSharding(mesh, _drop_indivisible(spec, shape, mesh))
    return jax.tree_util.tree_map_with_path(one, params)


def batch_spec(mesh: Mesh, *, seq_sharded: bool = False) -> P:
    """(B, T, ...) batch arrays: batch over all DP axes; optionally shard
    the sequence axis over "data" (sequence parallelism for the 500k
    decode cells where B == 1)."""
    dp = data_axes(mesh)
    if seq_sharded:
        non_data = tuple(a for a in dp if a != "data")
        return P(non_data if non_data else None, "data")
    return P(dp)


def cache_specs(mesh: Mesh, cache: Any, *, seq_sharded: bool = False) -> Any:
    """KV / SSM cache shardings for decode.

    Default: batch over DP axes, kv-heads over model.  When seq_sharded
    (long-context, B=1): KV sequence axis over "data" instead.
    KV caches are (B, S, Hkv, dh); SSM states (B, H, P, N); conv states
    (B, K, C).
    """
    dp = data_axes(mesh)
    n_model = mesh.shape.get("model", 1)
    n_dp = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def pad(nd: int, trailing) -> P:
        """Left-pad with None so scan-group stacking axes stay replicated."""
        k = len(trailing)
        if nd < k:
            return P(*([None] * nd))
        return P(*([None] * (nd - k)), *trailing)

    def fit(shape, trailing):
        """Drop axis assignments the dims cannot honor (jit in_shardings
        demands exact divisibility); for KV caches fall back from the
        head axis to head_dim when n_kv_heads < model size."""
        nd = len(shape)
        spec = list(trailing)
        off = nd - len(spec)
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            size = n_model if ax == "model" else n_dp
            if ax == "model" and shape[off + i] % size:
                # try the next dim to the right (e.g. Hkv -> head_dim)
                spec[i] = None
                if (i + 1 < len(spec) and spec[i + 1] is None
                        and shape[off + i + 1] % size == 0):
                    spec[i + 1] = "model"
            elif ax != "model":
                szs = ([mesh.shape[a] for a in ax]
                       if isinstance(ax, tuple) else [mesh.shape[ax]])
                if shape[off + i] % int(np.prod(szs)):
                    spec[i] = None
        return tuple(spec)

    def one(path, x):
        p = tree_path_str(path)
        nd = x.ndim
        if p.endswith("/k") or p.endswith("/v"):      # (B, S, Hkv, dh)
            tr = ((None, "data", "model", None) if seq_sharded
                  else (dp, None, "model", None))
        elif p.endswith("/ssm"):                      # (B, H, P, N)
            tr = ((None, "model", None, None) if seq_sharded
                  else (dp, "model", None, None))
        elif p.endswith("/conv"):                     # (B, K, C)
            tr = ((None, None, "model") if seq_sharded
                  else (dp, None, "model"))
        else:
            return NamedSharding(mesh, P(*([None] * nd)))
        k = len(tr)
        shape_trail = x.shape[-k:] if nd >= k else x.shape
        return NamedSharding(mesh, pad(nd, fit(shape_trail, tr)))

    return jax.tree_util.tree_map_with_path(one, cache)
