"""Activation-sharding context: explicit constraints inside model code.

SPM models have no large matmuls, so XLA's sharding propagation cannot
discover head/feature parallelism on its own (DESIGN.md §3.4, EXPERIMENTS
§Perf).  Layers call ``constrain(x, kind)`` at strategic points; outside
any context this is the identity, so CPU smoke paths and the naive
baseline are untouched.

Kinds:
  "heads":      (B, T, H, dh)   -> heads over "model", batch over DP axes
  "kv_heads":   (B, T, Hkv, dh) -> same on the KV head axis
  "btd":        (B, T, D)       -> batch over DP axes, feature replicated
  "batch_full": (B, ...)        -> batch over DP axes + "model" (full-mesh
                                   DP — the spm_dp training layout)
  "feature":    (..., n)        -> feature over "model" (two-level SPM)
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["activation_sharding", "constrain", "feature_mesh"]

_STATE = threading.local()


def _current() -> Optional[dict]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, *, shard_heads: bool = True,
                        shard_feature: bool = False,
                        full_batch: bool = False):
    """Enable explicit activation constraints within the block."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    prev = _current()
    _STATE.ctx = {"mesh": mesh, "dp": dp, "shard_heads": shard_heads,
                  "shard_feature": shard_feature, "full_batch": full_batch}
    try:
        yield
    finally:
        _STATE.ctx = prev


def feature_mesh(n_shards: Optional[int] = None) -> Optional[Mesh]:
    """The active mesh when feature sharding is enabled, else None.

    ``core/spm.spm_apply`` calls this to decide whether to route a
    two_level operator through the distributed executor
    (``parallel/spm_shard.py``): it needs an ``activation_sharding`` block
    with ``shard_feature=True``, a ``"model"`` mesh axis, and (when
    ``n_shards`` is given) an axis size matching the operator's shard
    count — otherwise the unsharded composition runs and XLA partitions it.
    """
    ctx = _current()
    if ctx is None or not ctx.get("shard_feature"):
        return None
    mesh = ctx["mesh"]
    if "model" not in mesh.axis_names:
        return None
    if n_shards is not None and mesh.shape["model"] != n_shards:
        return None
    return mesh


def constrain(x: jax.Array, kind: str) -> jax.Array:
    """Apply the activation-sharding constraint of ``kind`` (see the
    module docstring) under the active ``activation_sharding`` context;
    the identity when no context is active."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, dp = ctx["mesh"], ctx["dp"]
    if kind in ("heads", "kv_heads"):
        if not ctx["shard_heads"]:
            return x
        spec = P(dp, None, "model", None)
    elif kind == "btd":
        spec = P(dp, *([None] * (x.ndim - 1)))
    elif kind == "batch_full":
        if not ctx.get("full_batch"):
            return x
        spec = P(dp + ("model",), *([None] * (x.ndim - 1)))
    elif kind == "feature":
        if not ctx["shard_feature"]:
            return x
        spec = P(*([None] * (x.ndim - 1)), "model")
    else:
        raise ValueError(kind)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
