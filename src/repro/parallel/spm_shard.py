"""Distributed two_level SPM: the feature axis sharded over ``"model"``.

The paper's two_level schedule was designed for exactly this executor
(core/pairings.py): with ``n = n_shards * n_local`` features block-sharded
over the mesh's ``"model"`` axis, every stage is one of two shapes:

* **shard-local run** (``n_local % (2*s) == 0``) — pairs stay inside one
  shard block.  Maximal consecutive runs of local stages execute on the
  shard-resident ``(rows, n_local)`` slab through the existing fused Pallas
  kernel (``kernels/spm_stack.py``; interpret mode off-TPU) or the XLA 2x2
  composition — zero communication.
* **cross-shard stage** (``s = k * n_local``, ``k`` a power of two) — pairs
  lane ``r`` of shard ``j`` with lane ``r`` of shard ``j XOR k``.  Realized
  as one ``jax.lax.ppermute`` partner exchange (an involution: the XOR
  permutation is its own inverse) plus a local 2x2 mix: the "low" partner
  (``j & k == 0``) holds the x0 role and computes ``y0 = a*x0 + b*x1``, the
  "high" partner computes ``y1 = c*x0 + d*x1``.

The whole sharded operator — D_in fold, stages, D_out/bias fold — runs
inside one ``shard_map`` with a closed-form ``custom_vjp``:

* the transpose of a partner exchange is the same exchange, so the backward
  walks the schedule in reverse issuing the SAME ppermutes (plus one for
  the saved stage input, needed by the coefficient grads);
* each shard computes only the coefficient-grad components its role owns
  (low: g_a, g_b; high: g_c, g_d) — the gather that built its coefficient
  table transposes to a scatter-add that merges the two partners' partials
  into the full (a, b, c, d) rows, so no all-reduce of parameter grads over
  the feature axis is ever issued;
* diag/bias grads are per-shard slices of (n,) vectors (out-sharded over
  ``"model"``), again collective-free.

Per-stage coefficient slabs are gathered OUTSIDE the shard_map
(``_step_tables`` — pure O(nL) indexing, differentiable) and passed in
pre-sharded with ``P("model")`` leading specs, so each device reads exactly
the rows its lanes need: for a local stage the contiguous pair block
``[j*n_local/2, (j+1)*n_local/2)``, for a cross stage the shared partner
rows ``[Q(j)*n_local, (Q(j)+1)*n_local)`` with
``Q(j) = ((j & ~k) // 2k)*k + ((j & ~k) % 2k)``.

The operator boundaries are kernel-native inside the shard (this PR):

* **diag/bias folding** — ``D_in`` folds into the first kernel run of the
  first shard-local step, ``D_out``/bias into the last kernel run of the
  last, exactly as the single-device plan folds them into its boundary
  runs — the shard body issues NO elementwise diag/bias ops (they only
  reappear on the XLA fallback path or when a boundary step is a
  cross-shard stage).  The boundary runs' backward kernels emit the
  closed-form g_din/g_dout/g_bias per-shard slices collective-free.
* **windowed rectangular boundaries** — for a rectangular operator the
  ``(rows, in_width)`` input enters the shard_map feature-REPLICATED and
  the first shard-local kernel run reads this shard's n_local-wide window
  straight out of it: a scalar-prefetch base tile offsets the x block
  index and an in-VMEM iota mask zero-fills lanes at or past the GLOBAL
  ``in_width`` (``kernels/spm_stack.py`` ``col_base``).  The zero-padded
  square input is never materialized in HBM and interior shards' masks
  are no-ops by construction.  The backward remats through the same
  windowed read (the replicated x is the residual) and the custom_vjp
  returns the input cotangent as ``(rows, in_width)`` with exact-zero
  padded-lane parameter grads.  The COTANGENT travels the other way: it
  enters the backward as an even-width slab (zero-padded to n — a local
  op fused into the slab reshard) rather than a windowed read, because
  replicating a feature-sharded cotangent would cost a
  batch-proportional all-gather.  Two further SPMD constraints remain by
  design: the assembled (rows, n) output is cut to ``out_width`` by one
  local per-shard slice (shard_map outputs must be evenly sharded), and
  the backward grid stays uniform across shards (a shard cannot skip its
  dead edge tiles — which costs no wall-clock, since the fully-live
  interior shards bound the step anyway).

The lowered HLO of this path contains ``collective-permute`` only — no
all-gather or all-reduce of the feature axis (asserted by
tests/test_distributed.py via ``hlo_analysis.collective_bytes``; the
backward's two bounded exceptions are the O(nL) replicated
coefficient-grad assembly and, for rectangular operators only, the
jit-boundary replication of the indivisible-width g_x output — inherent
to any transport design).

**Overlap schedule** (this PR): with ``SPMConfig.overlap`` resolved on
(``core/eligibility.resolve_overlap`` — auto on TPU, forceable
everywhere), the walk above restructures into a row-block pipeline: the
slab splits into ``ShardPlan.row_blocks`` and every step processes
per block, so block i's partner exchange flies while block i+1 computes.
On compiled TPU backends each {local run -> cross stage} pair fuses into
ONE pallas_call (``kernels/spm_stack.spm_overlap_kernel_call`` — the
remote copy is an in-kernel ``pltpu.make_async_remote_copy`` started per
row block, the 2x2 mix its receiving epilogue, and the backward remats
the sent activation in VMEM; those cross steps save placeholder
residuals, ``ShardPlan.rdma_crosses``).  Everywhere else the SAME
schedule transports blocks via per-block ``jax.lax.ppermute`` — the
interpret-mode proof path — and the custom_vjp replays the overlapped
walk in reverse using the same exchange-is-its-own-transpose property.
``launch/hlo_analysis.sharded_stage_traffic(..., overlap=True)`` models
the exposed-vs-hidden permute-byte split; docs/sharding.md "The overlap
executor" is the design reference.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import spm as spm_mod
from repro.core.eligibility import (OVERLAP_ROW_BLOCKS, overlap_segments,
                                    plan_steps, resolve_overlap,
                                    resolve_rdma, resolve_shard_kernel,
                                    sharded_eligible)
from repro.core.pairings import Stage
from repro.kernels import quant as Q
from repro.kernels import spm_stack as K
from repro.kernels.ops import (default_interpret, pick_block_rows_for_plan,
                               plan_runs)

__all__ = ["spm_apply_sharded", "sharded_eligible", "plan_steps",
           "cross_partner_perm", "pick_row_blocks"]

AXIS = "model"
_F32 = jnp.float32

# plan_steps / sharded_eligible / OVERLAP_ROW_BLOCKS moved to
# core/eligibility.py (the single
# fallback matrix shared with the single-device kernel path); re-exported
# here unchanged for back-compat.


def cross_partner_perm(n_shards: int, k: int) -> Tuple[Tuple[int, int], ...]:
    """The ppermute permutation of a cross stage: shard j <-> j XOR k.
    An involution — forward and backward issue the identical exchange."""
    return tuple((j, j ^ k) for j in range(n_shards))


@functools.lru_cache(maxsize=None)
def _cross_coeff_rows(n_shards: int, n_local: int, k: int) -> np.ndarray:
    """(n_shards, n_local) pair-row indices for a cross stage: lane r of
    shard j (and of its partner j XOR k — the rows are shared) uses pair
    Q(j)*n_local + r with Q(j) = ((j & ~k) // 2k)*k + ((j & ~k) % 2k)."""
    j = np.arange(n_shards)
    jl = j & ~k                       # the pair's low-partner shard id
    q = (jl // (2 * k)) * k + (jl % (2 * k))
    return q[:, None] * n_local + np.arange(n_local)[None, :]


# ---------------------------------------------------------------------------
# static plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Hashable static description closed over by the custom_vjp.

    ``in_width`` / ``out_width`` are the GLOBAL rectangular widths (None =
    square).  The derived ``win_in`` flag says whether the first
    shard-local kernel run reads the input through a windowed
    (scalar-prefetch offset) kernel call; ``fold_din`` / ``fold_dout`` /
    ``fold_bias`` say whether the diag/bias operands fold into the
    boundary kernel runs instead of running as elementwise ops in the
    shard body.
    """

    mesh: Mesh
    n: int
    n_local: int
    n_shards: int
    steps: Tuple[tuple, ...]
    has_din: bool
    has_dout: bool
    has_bias: bool
    use_kernel: bool
    block_rows: int
    interpret: bool
    dp: Tuple[str, ...] = ()     # pure-DP mesh axes: rows shard over these
    in_width: Optional[int] = None
    out_width: Optional[int] = None
    # -- overlap schedule (this PR) ----------------------------------------
    # row_blocks: static per-shard row-block sizes of the pipelined walk
    # (empty = step-serial full-slab schedule).  rdma_crosses: indices of
    # cross steps executed as the epilogue of a fused RDMA pair kernel
    # (TPU only — see core/eligibility.resolve_rdma); their saved stage
    # input is a placeholder, rematerialized in VMEM by the backward
    # kernel.
    row_blocks: Tuple[int, ...] = ()
    rdma_crosses: Tuple[int, ...] = ()
    # quant_cf: shard-local kernel runs read int8 per-stage-scaled
    # coefficient tables, dequantized in VMEM (SPMConfig.quant_coeffs).
    # The quantization is recomputed deterministically from the f32 slab
    # in forward AND backward, so both see identical dequantized values
    # and the closed-form grads are grads of the dequantized operator
    # (straight-through in the table params).  Cross-stage 2x2 mixes are
    # O(n) elementwise XLA ops and stay f32.
    quant_cf: bool = False

    @property
    def overlap(self) -> bool:
        """Whether the row-block pipelined (overlap) walk is engaged."""
        return bool(self.row_blocks)

    @property
    def segments(self) -> Tuple[tuple, ...]:
        """The overlap segmentation of ``steps`` (``("pair", local,
        cross)`` / ``("one", step)`` — core/eligibility.overlap_segments)."""
        return overlap_segments(self.steps)

    # -- boundary-step structure -------------------------------------------
    @property
    def first_local(self) -> bool:
        return self.steps[0][0] == "local"

    @property
    def last_local(self) -> bool:
        return self.steps[-1][0] == "local"

    @property
    def fold_din(self) -> bool:
        """D_in folds into the first kernel run of the first local step."""
        return self.has_din and self.use_kernel and self.first_local

    @property
    def fold_dout(self) -> bool:
        """D_out folds into the schedule's last step: into the last kernel
        run when the schedule ends on a local step, or — when it ends on a
        CROSS stage — into the mix epilogue itself, scaling the mixed
        result ON THE STORE (after the mix add, bitwise the unfolded
        post-stack op — elastic re-sharding depends on that order; on the
        RDMA path the kernel's receive-mix applies it as one extra vector
        operand, so the slab never round-trips HBM for the boundary).
        Only a kernel-off local ending still applies d_out as an explicit
        batch-wide elementwise op."""
        return self.has_dout and (self.use_kernel if self.last_local
                                  else True)

    @property
    def fold_bias(self) -> bool:
        """Bias folds exactly like ``fold_dout`` (one fused add in the mix
        epilogue on a cross ending)."""
        return self.has_bias and (self.use_kernel if self.last_local
                                  else True)

    @property
    def win_in(self) -> bool:
        """The first kernel run reads the (rows, in_width) global input
        through a windowed (col_base) call — the padded square input is
        never materialized in HBM."""
        return (self.in_width is not None and self.use_kernel
                and self.first_local)

    # NOTE deliberately no ``win_out``: the backward cotangent is
    # transported as an even-width slab (zero-padded to n in
    # ``_sharded_core_bwd`` — a local op fused into the slab reshard)
    # rather than window-read from a replicated (rows, out_width) array.
    # The windowed read would force replicating the cotangent, and when it
    # arrives feature-sharded (the common case: it flows back from the
    # sharded forward output) that replication is a batch-proportional
    # all-gather over ICI — strictly worse than the fused local pad.

    # -- residual layout ----------------------------------------------------
    @property
    def saves_x_res(self) -> bool:
        """Whether a stage-0 input residual rides next to step_ins: the
        replicated x itself under win_in (the backward's windowed remat
        source), else the pre-D_in slab when g_din is computed explicitly."""
        return self.win_in or (self.has_din and not self.fold_din)

    @property
    def saves_z_last(self) -> bool:
        """z_L (pre-D_out) is a residual only when g_dout is explicit; a
        folded boundary run remats it in VMEM."""
        return self.has_dout and not self.fold_dout

    # -- shard_map specs ----------------------------------------------------
    def table_specs(self) -> Tuple[P, ...]:
        return tuple(P(AXIS) for _ in self.steps)

    def vec_spec(self, present: bool) -> P:
        return P(AXIS) if present else P()   # (1,) placeholders replicated

    def act_spec(self) -> P:
        # (rows, n): rows over the DP axes (kept replicated when there are
        # none), features over "model" — entering with batch-sharded
        # activations must NOT all-gather them.
        return P(self.dp if self.dp else None, AXIS)

    def rep_spec(self) -> P:
        # (rows, width) with the feature axis replicated over "model" —
        # the natural sharding of a rectangular boundary operand, whose
        # width is not divisible by the shard count.
        return P(self.dp if self.dp else None, None)

    def x_spec(self) -> P:
        return self.rep_spec() if self.in_width is not None \
            else self.act_spec()

    def res_specs(self):
        """Shard_map specs of the residual tuple ``(x_res, step_ins,
        z_last)``: placeholders ride replicated ``P(None)``, slabs the act
        spec, the windowed x residual the replicated rep spec.  An RDMA
        pair's cross step saves a placeholder — its stage input (the local
        run's output) never reaches HBM and the backward kernel remats it
        from the local run's own input."""
        act = self.act_spec()
        x_res = (self.rep_spec() if self.win_in
                 else (act if self.saves_x_res else P(None)))
        step_ins = tuple(P(None) if ((i == 0 and self.win_in)
                                     or i in self.rdma_crosses) else act
                         for i in range(len(self.steps)))
        z_last = act if self.saves_z_last else P(None)
        return (x_res, step_ins, z_last)


def _step_tables(coeffs: jax.Array, steps, n_shards: int,
                 n_local: int) -> Tuple[jax.Array, ...]:
    """Per-step coefficient tables with a leading shard axis (sharded into
    the shard_map with P("model")).  Differentiable: the local case is a
    reshape/transpose, the cross case a gather whose transpose scatter-adds
    the two partners' grad partials into the shared rows."""
    nl2 = n_local // 2
    tabs = []
    for step in steps:
        if step[0] == "local":
            _, start, run = step
            blk = coeffs[start: start + len(run)]          # (Lr, n/2, 4)
            tabs.append(blk.reshape(len(run), n_shards, nl2, 4)
                        .transpose(1, 0, 2, 3))            # (S, Lr, nl2, 4)
        else:
            _, ell, k = step
            rows = _cross_coeff_rows(n_shards, n_local, k)
            tabs.append(coeffs[ell][rows])                 # (S, n_local, 4)
    return tuple(tabs)


def _window_slab(x_full: jax.Array, base_cols: jax.Array, n_local: int,
                 width: int) -> jax.Array:
    """XLA fallback for the windowed boundary read: this shard's
    (rows, n_local) slab of a feature-complete (rows, width) operand,
    zero-filled past ``width``.  A clipped static-length gather + mask —
    local, collective-free, but it does materialize the slab in HBM,
    which the windowed KERNEL read (``win_in``) avoids."""
    col = base_cols + jnp.arange(n_local)
    idx = jnp.clip(col, 0, width - 1)
    slab = jnp.take(x_full, idx, axis=-1)
    return jnp.where(col < width, slab, jnp.zeros_like(slab))


# ---------------------------------------------------------------------------
# shard-local stage math
# ---------------------------------------------------------------------------

def _cross_mix(z, zp, cf, k: int, d_out=None, bias=None):
    """The local 2x2 half of a cross stage, once the partner slab ``zp``
    is in hand: the low partner (``j & k == 0``) holds the x0 role and
    computes ``y0 = a*z + b*zp``, the high partner ``y1 = c*zp + d*z``.
    The OPERAND ORDER of each two-term form is load-bearing: XLA
    contracts ``p*q + r`` into an fma whose rounding depends on which
    product stays exact, and an elastic execution classifies this same
    pinned stage LOCAL on a wider-``n_local`` mesh — where the pair math
    computes exactly these forms — so any re-association here breaks
    bitwise re-shard parity.  When the schedule ENDS on this stage the
    operator boundary folds in ON THE STORE: ``d_out`` scales the mixed
    result AFTER the add (never pre-scaled into the mix coefficients, for
    the same bitwise reason) and ``bias`` rides the same fused region.
    Factored out of ``_cross_fwd`` so the overlap schedule can apply it
    per row block (and the RDMA kernel as its in-VMEM epilogue, with the
    same scale-on-store order)."""
    low = (jax.lax.axis_index(AXIS) & k) == 0
    a, b, c, d = (cf[:, i].astype(z.dtype) for i in range(4))
    y = jnp.where(low, a * z + b * zp, c * zp + d * z)
    if d_out is not None:
        y = y * d_out.astype(z.dtype)
    if bias is not None:
        y = y + bias.astype(z.dtype)
    return y


def _cross_fwd(z, cf, k: int, plan: ShardPlan, d_out=None, bias=None):
    """One partner exchange + local 2x2 mix.  z: (rows, n_local);
    cf: (n_local, 4) rows shared with the partner shard.  ``d_out`` /
    ``bias`` fold the operator boundary into the mix (schedule-ending
    cross stage — see ``_cross_mix``)."""
    zp = jax.lax.ppermute(z, AXIS, cross_partner_perm(plan.n_shards, k))
    return _cross_mix(z, zp, cf, k, d_out=d_out, bias=bias)


def _cross_bwd(z_in, delta, cf, k: int, plan: ShardPlan,
               d_out=None, has_bias: bool = False):
    """Transpose of the partner exchange is the same exchange.  Each shard
    emits only the coefficient-grad components its role owns (low: a, b;
    high: c, d); the table gather's scatter-add merges the partners.

    With ``d_out`` (folded boundary — this cross stage ended the
    schedule), ``delta`` arrives RAW (the output cotangent): ``g_bias``
    sums it as-is, ``g_dout`` contracts it against the rematerialized mix
    output ``u*z + v*zp`` (no stored pre-d_out activation needed), and the
    mix cotangent is ``d_out * delta`` — scaled by the shard's OWN d_out
    slice BEFORE the partner exchange, so the partner's arrives pre-scaled
    by ITS slice.  Returns ``(g_in, g_cf, extras)`` with extras ordered
    [g_dout?, g_bias?]."""
    perm = cross_partner_perm(plan.n_shards, k)
    zp = jax.lax.ppermute(z_in, AXIS, perm)
    low = (jax.lax.axis_index(AXIS) & k) == 0
    extras = []
    if d_out is not None or has_bias:
        if has_bias:
            g_bias = jnp.sum(delta.astype(_F32), axis=0)
        if d_out is not None:
            # remat the mix output in the forward's exact operand order
            # (see _cross_mix — the two-sided form is the bitwise anchor)
            af, bf, cf_, df = (cf[:, i].astype(_F32) for i in range(4))
            zf, zpf = z_in.astype(_F32), zp.astype(_F32)
            m = jnp.where(low, af * zf + bf * zpf, cf_ * zpf + df * zf)
            extras.append(jnp.sum(delta.astype(_F32) * m, axis=0))
            delta = delta * d_out.astype(delta.dtype)
        if has_bias:
            extras.append(g_bias)
    dp = jax.lax.ppermute(delta, AXIS, perm)
    a, b, c, d = (cf[:, i].astype(delta.dtype) for i in range(4))
    # g_x0 = a d0 + c d1 on the low shard; g_x1 = b d0 + d d1 on the high.
    g_in = jnp.where(low, a * delta + c * dp, b * dp + d * delta)
    # low holds (d0, x0) and receives x1=zp: g_a = sum d0 x0, g_b = sum d0 x1
    # high holds (d1, x1) and receives x0=zp: g_c = sum d1 x0, g_d = sum d1 x1
    s_own = jnp.sum(delta.astype(_F32) * z_in.astype(_F32), axis=0)
    s_swp = jnp.sum(delta.astype(_F32) * zp.astype(_F32), axis=0)
    zero = jnp.zeros_like(s_own)
    g_cf = jnp.where(low,
                     jnp.stack([s_own, s_swp, zero, zero], axis=-1),
                     jnp.stack([zero, zero, s_swp, s_own], axis=-1))
    return g_in, g_cf.astype(cf.dtype), extras


def _base_tiles(col_base, n_tile: int):
    """Convert a traced base-column scalar to the (1,) base-feature-tile
    operand of a windowed kernel call."""
    return jnp.reshape(col_base // n_tile, (1,))


def _segment_fwd(z, cf, run: Tuple[int, ...], plan: ShardPlan, *,
                 d_in=None, d_out=None, bias=None,
                 col_base=None, in_width: Optional[int] = None):
    """A maximal run of shard-local stages on the resident slab: the fused
    Pallas kernel when enabled (interpret off-TPU), else the XLA 2x2
    composition.  On the kernel path the BOUNDARY sub-runs absorb the
    operator boundaries: ``d_in`` folds into the first sub-run (applied in
    VMEM before its first stage), ``d_out``/``bias`` into the last, and
    with ``col_base``/``in_width`` the first sub-run is a windowed call
    that reads this shard's n_local-wide window straight out of the
    feature-complete (rows, in_width) operand ``z``."""
    if plan.use_kernel:
        runs = plan_runs(plan.n_local, run)
        kcf, scf = (Q.quantize_coeffs(cf) if plan.quant_cf
                    else (cf, None))
        off = 0
        for r, (run_strides, n_tile) in enumerate(runs):
            first, last = r == 0, r == len(runs) - 1
            z = K.spm_stack_kernel_call(
                z, kcf[off: off + len(run_strides)],
                d_in if first else None,
                d_out if last else None,
                bias if last else None,
                _base_tiles(col_base, n_tile)
                if (first and col_base is not None) else None,
                coeff_scale=(scf[off: off + len(run_strides)]
                             if plan.quant_cf else None),
                strides=run_strides, block_rows=plan.block_rows,
                n_tile=n_tile,
                in_width=in_width if first else None,
                interpret=plan.interpret)
            off += len(run_strides)
        return z
    for i, s in enumerate(run):
        z = spm_mod.apply_stage(z, cf[i].astype(z.dtype), Stage(stride=s))
    return z


def _segment_bwd(z_in, delta, cf, run: Tuple[int, ...], plan: ShardPlan, *,
                 d_in=None, d_out=None, has_bias: bool = False,
                 col_base=None, in_width: Optional[int] = None):
    """Closed-form backward of a local run from its saved input: the fused
    backward kernel per planned sub-run (stage inputs remat in VMEM), else
    forward-recompute + per-stage eq. 12-14 grads.

    Kernel path boundary handling mirrors ``_segment_fwd``: the first
    sub-run consumes ``d_in`` (and with ``in_width``/``col_base`` remats
    from the feature-complete replicated x through a windowed read,
    emitting exact-zero padded-lane grads), the last sub-run consumes
    ``d_out``/``has_bias``.  ``delta`` is always the slab cotangent (a
    rectangular out_width arrives pre-zero-padded — see _shard_bwd).
    Returns ``(delta_slab, g_coeffs, vec_grads)`` with ``vec_grads``
    ordered [g_din?, g_dout?, g_bias?].
    """
    if plan.use_kernel:
        runs = plan_runs(plan.n_local, run)
        # recompute the SAME deterministic quantization as the forward so
        # the remat and the grads see identical dequantized tables
        kcf, scf = (Q.quantize_coeffs(cf) if plan.quant_cf
                    else (cf, None))
        zs, z, off = [], z_in, 0
        for r, (run_strides, n_tile) in enumerate(runs):
            zs.append(z)
            if r < len(runs) - 1:    # the last output is never needed
                z = K.spm_stack_kernel_call(
                    z, kcf[off: off + len(run_strides)],
                    d_in if r == 0 else None, None, None,
                    _base_tiles(col_base, n_tile)
                    if (r == 0 and in_width is not None
                        and col_base is not None) else None,
                    coeff_scale=(scf[off: off + len(run_strides)]
                                 if plan.quant_cf else None),
                    strides=run_strides, block_rows=plan.block_rows,
                    n_tile=n_tile,
                    in_width=in_width if r == 0 else None,
                    interpret=plan.interpret)
            off += len(run_strides)
        offs = np.cumsum([0] + [len(rs) for rs, _ in runs])
        g_parts = [None] * len(runs)
        g_din = g_dout = g_bias = None
        for r in range(len(runs) - 1, -1, -1):
            run_strides, n_tile = runs[r]
            first, last = r == 0, r == len(runs) - 1
            win_x = first and in_width is not None and col_base is not None
            out = K.spm_stack_bwd_kernel_call(
                zs[r], kcf[offs[r]: offs[r + 1]], delta,
                d_in if first else None,
                d_out if last else None,
                _base_tiles(col_base, n_tile) if win_x else None,
                coeff_scale=(scf[offs[r]: offs[r + 1]]
                             if plan.quant_cf else None),
                strides=run_strides, block_rows=plan.block_rows,
                n_tile=n_tile, has_bias=last and has_bias,
                in_width=in_width if first else None,
                interpret=plan.interpret)
            delta, g_parts[r] = out[0], out[1]
            vecs = list(out[2:])
            if first and d_in is not None:
                g_din = vecs.pop(0)
            if last and d_out is not None:
                g_dout = vecs.pop(0)
            if last and has_bias:
                g_bias = vecs.pop(0)
        vec_grads = [g for g in (g_din, g_dout, g_bias) if g is not None]
        return (delta, jnp.concatenate(g_parts, axis=0).astype(cf.dtype),
                vec_grads)
    zs, z = [], z_in
    for i, s in enumerate(run):
        zs.append(z)
        if i < len(run) - 1:
            z = spm_mod.apply_stage(z, cf[i].astype(z.dtype),
                                    Stage(stride=s))
    g_cf = []
    for i in range(len(run) - 1, -1, -1):
        delta, gc, _ = spm_mod._stage_grads(
            zs[i], delta, cf[i].astype(delta.dtype), Stage(stride=run[i]),
            None)
        g_cf.append(gc)
    return delta, jnp.stack(g_cf[::-1], axis=0).astype(cf.dtype), []


# ---------------------------------------------------------------------------
# overlap schedule: row-block pipelined walk
# ---------------------------------------------------------------------------

def pick_row_blocks(rows: int, block_rows: int,
                    target: int = OVERLAP_ROW_BLOCKS) -> Tuple[int, ...]:
    """Static per-shard row-block sizes of the overlap pipeline.

    Splits ``rows`` (the per-DP-shard slab rows, already padded to a
    ``block_rows`` multiple) into at most ``target`` contiguous blocks,
    each a ``block_rows`` multiple so every block is a whole number of
    kernel row-blocks.  Degenerate inputs (fewer kernel row-blocks than
    ``target``) get fewer, down to the single-block tuple — the overlap
    walk then reduces to the step-serial schedule on the same code path.
    """
    if rows <= 0:
        return (max(rows, 0),) if rows else ()
    units = max(1, rows // block_rows)        # whole kernel row-blocks
    nb = max(1, min(target, units))
    base, extra = divmod(units, nb)
    sizes = []
    used = 0
    for b in range(nb):
        u = base + (1 if b < extra else 0)
        sizes.append(u * block_rows)
        used += u * block_rows
    sizes[-1] += rows - used                  # fold any sub-block remainder
    return tuple(s for s in sizes if s > 0)


def _overlap_split(z, row_blocks: Tuple[int, ...]):
    """Slice the slab's row axis into the plan's static row blocks."""
    offs = np.cumsum((0,) + row_blocks)
    return [jax.lax.slice_in_dim(z, int(offs[b]), int(offs[b + 1]), axis=0)
            for b in range(len(row_blocks))]


def _partner_coords(plan: ShardPlan, k: int):
    """(mesh.ndim,) int32 logical mesh coordinates of this shard's XOR-k
    partner — every axis keeps this device's index except ``"model"``,
    which flips to ``j XOR k``.  Consumed by the RDMA kernels' remote-copy
    ``device_id`` (scalar prefetch)."""
    coords = []
    for a in plan.mesh.axis_names:
        idx = jax.lax.axis_index(a)
        if a == AXIS:
            idx = idx ^ k
        coords.append(idx)
    return jnp.stack([c.astype(jnp.int32) for c in coords])


def _cross_role_vecs(cf, k: int, low):
    """Role-resolved forward mix vectors: the epilogue computes
    ``y = mix_a * z + mix_b * zp`` where (mix_a, mix_b) is (a, b) on the
    low partner and (d, c) on the high — O(n_local) elementwise, computed
    in the shard body so the kernel itself is role-free."""
    return (jnp.where(low, cf[:, 0], cf[:, 3]),
            jnp.where(low, cf[:, 1], cf[:, 2]))


def _pair_rdma_fwd(z, li: int, ci: int, plan: ShardPlan, tabs,
                   d_in, d_out, bias, base_cols):
    """One fused {local run -> cross exchange -> mix epilogue} pallas_call
    over the whole slab: the kernel row-block-pipelines internally, a
    block's partner-half remote copy starting as soon as its local mix
    finishes (kernels/spm_stack.spm_overlap_kernel_call).  When this pair's
    cross stage ENDS the schedule, the operator boundary folds into the
    receive-mix epilogue as two extra vector operands: ``d_out`` scales
    the mixed result AFTER the add (scale-on-store — bitwise the unfolded
    post-stack op, which elastic re-sharding depends on) and ``bias``
    rides the same store."""
    local_step, cross_step = plan.steps[li], plan.steps[ci]
    k = cross_step[2]
    low = (jax.lax.axis_index(AXIS) & k) == 0
    mix_a, mix_b = _cross_role_vecs(tabs[ci][0], k, low)
    last = ci == len(plan.steps) - 1
    (run_strides, n_tile), = plan_runs(plan.n_local, local_step[2])
    first = li == 0
    kcf, scf = (Q.quantize_coeffs(tabs[li][0]) if plan.quant_cf
                else (tabs[li][0], None))
    return K.spm_overlap_kernel_call(
        z, kcf, mix_a, mix_b, _partner_coords(plan, k),
        d_in=d_in if (first and plan.fold_din) else None,
        d_out=d_out if (last and plan.fold_dout) else None,
        bias=bias if (last and plan.fold_bias) else None,
        col_base=(_base_tiles(base_cols, n_tile)
                  if (first and plan.win_in) else None),
        coeff_scale=scf,
        strides=run_strides, block_rows=plan.block_rows, n_tile=n_tile,
        in_width=plan.in_width if (first and plan.win_in) else None,
        collective_id=2 * ci)       # distinct per pair; bwd takes 2*ci+1


def _pair_rdma_bwd(z_in, delta, li: int, ci: int, plan: ShardPlan, tabs,
                   d_in, d_out, base_cols):
    """Backward of an RDMA pair from the LOCAL step's saved input: the
    kernel remats the local run's output in VMEM (the forward sent it
    without ever writing HBM), exchanges (delta, z_out) blocks with the
    partner — the partner exchange is its own transpose — applies the
    cross-backward mix as its prologue and walks the local stages in
    reverse.  Returns (delta, g_local_coeffs, g_cross_coeffs, vec_grads)
    with the cross grads placed into the role-owned (a,b)/(c,d) slots
    exactly as ``_cross_bwd`` does and ``vec_grads`` ordered
    [g_din?, g_dout?, g_bias?].

    When this pair's cross stage ENDED the schedule with a folded
    boundary, ``delta`` arrives RAW: ``g_bias`` sums it in the shard body,
    the kernel pre-scales each SENT block by the shard's own d_out slice
    and returns the raw-cotangent sums (t_own, t_swp), and
    ``g_dout = mix_a * t_own + mix_b * t_swp`` with the UNSCALED forward
    role vectors — exact, no division remat."""
    local_step, cross_step = plan.steps[li], plan.steps[ci]
    k = cross_step[2]
    low = (jax.lax.axis_index(AXIS) & k) == 0
    cfc = tabs[ci][0]
    # transpose mix: g_mid = u * delta + v * delta_p with (u, v) = (a, c)
    # on the low partner and (d, b) on the high (see _cross_bwd)
    u = jnp.where(low, cfc[:, 0], cfc[:, 3])
    v = jnp.where(low, cfc[:, 2], cfc[:, 1])
    (run_strides, n_tile), = plan_runs(plan.n_local, local_step[2])
    first = li == 0
    last = ci == len(plan.steps) - 1
    fold_dout = last and plan.fold_dout
    kcf, scf = (Q.quantize_coeffs(tabs[li][0]) if plan.quant_cf
                else (tabs[li][0], None))
    out = K.spm_overlap_bwd_kernel_call(
        z_in, kcf, delta, u, v, _partner_coords(plan, k),
        d_in=d_in if (first and plan.fold_din) else None,
        d_out=d_out if fold_dout else None,
        col_base=(_base_tiles(base_cols, n_tile)
                  if (first and plan.win_in) else None),
        coeff_scale=scf,
        strides=run_strides, block_rows=plan.block_rows, n_tile=n_tile,
        in_width=plan.in_width if (first and plan.win_in) else None,
        collective_id=2 * ci + 1)
    gx, g_local, s_own, s_swp = out[:4]
    vecs = list(out[4:])           # [g_din?] + [t_own, t_swp]?
    if fold_dout:
        t_swp = vecs.pop()
        t_own = vecs.pop()
        mix_a, mix_b = _cross_role_vecs(cfc, k, low)
        vecs.append(mix_a.astype(_F32) * t_own
                    + mix_b.astype(_F32) * t_swp)
    if last and plan.fold_bias:
        vecs.append(jnp.sum(delta.astype(_F32), axis=0))
    delta = gx
    zero = jnp.zeros_like(s_own)
    g_cross = jnp.where(low,
                        jnp.stack([s_own, s_swp, zero, zero], axis=-1),
                        jnp.stack([zero, zero, s_swp, s_own], axis=-1))
    return (delta, g_local.astype(tabs[li][0].dtype),
            g_cross.astype(cfc.dtype), vecs)


def _overlap_steps_fwd(plan: ShardPlan, tabs, d_in, d_out, bias, z,
                       base_cols, collect: bool):
    """Row-block pipelined forward walk of the schedule.

    Blocks are independent, so issuing block b's partner exchange right
    after its local mix lets it fly while block b+1 computes — on TPU the
    pair segments fuse this into one RDMA kernel
    (``plan.rdma_crosses``); everywhere else the per-block
    ``jax.lax.ppermute`` transport realizes the IDENTICAL schedule (the
    interpret-mode proof path), with XLA's async collectives free to
    overlap the in-flight permutes with the next block's kernel.
    Residual layout matches the serial walk except RDMA cross steps,
    whose stage input is a placeholder (rematerialized by the backward
    kernel)."""
    fdt = z.dtype
    ph = jnp.zeros((1,), fdt)
    n_steps = len(plan.steps)
    step_ins = [ph] * n_steps
    i = 0
    for seg in plan.segments:
        if seg[0] == "pair" and (i + 1) in plan.rdma_crosses:
            li, ci = i, i + 1
            if collect and not (li == 0 and plan.win_in):
                step_ins[li] = z
            z = _pair_rdma_fwd(z, li, ci, plan, tabs, d_in, d_out, bias,
                               base_cols)
            i += 2
            continue
        for step in (seg[1:] if seg[0] == "pair" else (seg[1],)):
            first, last = i == 0, i == n_steps - 1
            if collect and not (first and plan.win_in):
                step_ins[i] = z
            cf = tabs[i][0]
            blocks = _overlap_split(z, plan.row_blocks)
            if step[0] == "cross":
                perm = cross_partner_perm(plan.n_shards, step[2])
                zps = [jax.lax.ppermute(b, AXIS, perm) for b in blocks]
                outs = [_cross_mix(
                    b, p, cf, step[2],
                    d_out=d_out if (last and plan.fold_dout) else None,
                    bias=bias if (last and plan.fold_bias) else None)
                    for b, p in zip(blocks, zps)]
            else:
                outs = [_segment_fwd(
                    b, cf, step[2], plan,
                    d_in=d_in if (first and plan.fold_din) else None,
                    d_out=d_out if (last and plan.fold_dout) else None,
                    bias=bias if (last and plan.fold_bias) else None,
                    col_base=base_cols if (first and plan.win_in) else None,
                    in_width=plan.in_width
                    if (first and plan.win_in) else None) for b in blocks]
            z = jnp.concatenate(outs, axis=0)
            i += 1
    return z, step_ins


def _sum_vec_lists(parts):
    """Elementwise-sum the per-block ``vec_grads`` lists of a local step
    (each ordered [g_din?, g_dout?, g_bias?])."""
    if not parts or not parts[0]:
        return []
    return [functools.reduce(jnp.add, [p[j] for p in parts])
            for j in range(len(parts[0]))]


def _overlap_steps_bwd(plan: ShardPlan, tabs, d_in, d_out, res, delta,
                       base_cols):
    """Reverse of ``_overlap_steps_fwd``: walks the segments backwards,
    per row block, replaying the same exchanges (the XOR permutation is
    its own transpose); RDMA pairs run their fused backward kernel on the
    whole slab.  Returns (delta, g_tabs in schedule order, vec_grads dict
    keyed 'din'/'dout'/'bias' for the folded boundary grads)."""
    x_res, step_ins, _ = res
    n_steps = len(plan.steps)
    g_tabs = [None] * n_steps
    folded = {}
    spans = []
    i = 0
    for seg in plan.segments:
        spans.append((seg, i))
        i += 2 if seg[0] == "pair" else 1
    for seg, i0 in reversed(spans):
        if seg[0] == "pair" and (i0 + 1) in plan.rdma_crosses:
            li, ci = i0, i0 + 1
            z_in = x_res if (li == 0 and plan.win_in) else step_ins[li]
            delta, g_l, g_c, vecs = _pair_rdma_bwd(
                z_in, delta, li, ci, plan, tabs, d_in, d_out, base_cols)
            g_tabs[li], g_tabs[ci] = g_l, g_c
            if li == 0 and plan.fold_din:
                folded["din"] = vecs.pop(0)
            if ci == n_steps - 1 and plan.fold_dout:
                folded["dout"] = vecs.pop(0)
            if ci == n_steps - 1 and plan.fold_bias:
                folded["bias"] = vecs.pop(0)
            continue
        steps_here = seg[1:] if seg[0] == "pair" else (seg[1],)
        for off in range(len(steps_here) - 1, -1, -1):
            i = i0 + off
            step = steps_here[off]
            first, last = i == 0, i == n_steps - 1
            cf = tabs[i][0]
            d_blocks = _overlap_split(delta, plan.row_blocks)
            if step[0] == "cross":
                z_blocks = _overlap_split(step_ins[i], plan.row_blocks)
                outs = [_cross_bwd(
                    zb, db, cf, step[2], plan,
                    d_out=d_out if (last and plan.fold_dout) else None,
                    has_bias=last and plan.fold_bias)
                    for zb, db in zip(z_blocks, d_blocks)]
                delta = jnp.concatenate([o[0] for o in outs], axis=0)
                g_tabs[i] = functools.reduce(jnp.add, [o[1] for o in outs])
                extras = _sum_vec_lists([o[2] for o in outs])
                if last and plan.fold_dout:
                    folded["dout"] = extras.pop(0)
                if last and plan.fold_bias:
                    folded["bias"] = extras.pop(0)
            else:
                z_in = x_res if (first and plan.win_in) else step_ins[i]
                z_blocks = _overlap_split(z_in, plan.row_blocks)
                outs = [_segment_bwd(
                    zb, db, cf, step[2], plan,
                    d_in=d_in if (first and plan.fold_din) else None,
                    d_out=d_out if (last and plan.fold_dout) else None,
                    has_bias=last and plan.fold_bias,
                    col_base=base_cols if (first and plan.win_in) else None,
                    in_width=plan.in_width
                    if (first and plan.win_in) else None)
                    for zb, db in zip(z_blocks, d_blocks)]
                delta = jnp.concatenate([o[0] for o in outs], axis=0)
                g_tabs[i] = functools.reduce(jnp.add, [o[1] for o in outs])
                vecs = _sum_vec_lists([o[2] for o in outs])
                if first and plan.fold_din:
                    folded["din"] = vecs.pop(0)
                if last and plan.fold_dout:
                    folded["dout"] = vecs.pop(0)
                if last and plan.fold_bias:
                    folded["bias"] = vecs.pop(0)
    return delta, g_tabs, folded


# ---------------------------------------------------------------------------
# per-shard operator body
# ---------------------------------------------------------------------------

def _shard_fwd(plan: ShardPlan, tabs, d_in, d_out, bias, x2, collect: bool):
    fdt = x2.dtype
    ph = jnp.zeros((1,), fdt)
    base_cols = jax.lax.axis_index(AXIS) * plan.n_local
    if plan.in_width is None:
        z = x2                                 # the shard-resident slab
    elif plan.win_in:
        z = x2      # feature-complete: the first kernel run windows it
    else:
        z = _window_slab(x2, base_cols, plan.n_local, plan.in_width)
    x_res = x2 if plan.win_in else (z if plan.saves_x_res else ph)
    if plan.has_din and not plan.fold_din:
        z = z * d_in.astype(fdt)
    n_steps = len(plan.steps)
    if plan.overlap:
        z, step_ins = _overlap_steps_fwd(plan, tabs, d_in, d_out, bias, z,
                                         base_cols, collect)
    else:
        step_ins = []
        for i, (step, tab) in enumerate(zip(plan.steps, tabs)):
            first, last = i == 0, i == n_steps - 1
            if collect:
                step_ins.append(ph if (first and plan.win_in) else z)
            cf = tab[0]                  # drop the (1,) local shard axis
            if step[0] == "cross":
                z = _cross_fwd(
                    z, cf, step[2], plan,
                    d_out=d_out if (last and plan.fold_dout) else None,
                    bias=bias if (last and plan.fold_bias) else None)
            else:
                z = _segment_fwd(
                    z, cf, step[2], plan,
                    d_in=d_in if (first and plan.fold_din) else None,
                    d_out=d_out if (last and plan.fold_dout) else None,
                    bias=bias if (last and plan.fold_bias) else None,
                    col_base=base_cols
                    if (first and plan.win_in) else None,
                    in_width=plan.in_width
                    if (first and plan.win_in) else None)
    z_last = z
    if plan.has_dout and not plan.fold_dout:
        z = z * d_out.astype(fdt)
    if plan.has_bias and not plan.fold_bias:
        z = z + bias.astype(fdt)
    if collect:
        return z, (x_res, tuple(step_ins),
                   z_last if plan.saves_z_last else ph)
    return z


def _shard_bwd(plan: ShardPlan, tabs, d_in, d_out, bias, res, gy):
    x_res, step_ins, z_last = res
    fdt = gy.dtype
    ph = jnp.zeros((1,), _F32)
    base_cols = jax.lax.axis_index(AXIS) * plan.n_local
    # gy is always the (rows, n_local) slab cotangent: a rectangular
    # out_width arrives zero-padded to n by _sharded_core_bwd (see the
    # ShardPlan note on why the cotangent is not window-read), so the
    # padded lanes contribute exact zeros to every grad below with no
    # masking needed.
    gys = gy
    g_din = g_dout = g_bias = None
    if plan.has_bias and not plan.fold_bias:
        g_bias = jnp.sum(gys.astype(_F32), axis=0)
    if plan.has_dout and not plan.fold_dout:
        g_dout = jnp.sum(gys.astype(_F32) * z_last.astype(_F32), axis=0)
        delta = gys * d_out.astype(fdt)
    else:
        delta = gys
    n_steps = len(plan.steps)
    if plan.overlap:
        delta, g_list, folded = _overlap_steps_bwd(
            plan, tabs, d_in, d_out, res, delta, base_cols)
        # restore the (1,) local shard axis; reversed so the shared
        # epilogue's final [::-1] yields schedule order
        g_tabs = [g[None] for g in reversed(g_list)]
        g_din = folded.get("din", g_din)
        g_dout = folded.get("dout", g_dout)
        g_bias = folded.get("bias", g_bias)
    else:
        g_tabs = []
        for i in range(n_steps - 1, -1, -1):
            step = plan.steps[i]
            cf = tabs[i][0]
            first, last = i == 0, i == n_steps - 1
            if step[0] == "cross":
                delta, g, extras = _cross_bwd(
                    step_ins[i], delta, cf, step[2], plan,
                    d_out=d_out if (last and plan.fold_dout) else None,
                    has_bias=last and plan.fold_bias)
                if last and plan.fold_dout:
                    g_dout = extras.pop(0)
                if last and plan.fold_bias:
                    g_bias = extras.pop(0)
            else:
                z_in = x_res if (first and plan.win_in) else step_ins[i]
                delta, g, vecs = _segment_bwd(
                    z_in, delta, cf, step[2], plan,
                    d_in=d_in if (first and plan.fold_din) else None,
                    d_out=d_out if (last and plan.fold_dout) else None,
                    has_bias=last and plan.fold_bias,
                    col_base=base_cols
                    if (first and plan.win_in) else None,
                    in_width=plan.in_width
                    if (first and plan.win_in) else None)
                if first and plan.fold_din:
                    g_din = vecs.pop(0)
                if last and plan.fold_dout:
                    g_dout = vecs.pop(0)
                if last and plan.fold_bias:
                    g_bias = vecs.pop(0)
            g_tabs.append(g[None])       # restore the (1,) local shard axis
    if plan.has_din and not plan.fold_din:
        g_din = jnp.sum(delta.astype(_F32) * x_res.astype(_F32), axis=0)
        delta = delta * d_in.astype(fdt)
    g_din = ph if g_din is None else g_din
    g_dout = ph if g_dout is None else g_dout
    g_bias = ph if g_bias is None else g_bias
    if plan.dp:
        # rows shard over the DP axes, so every batch-summed parameter grad
        # above is a per-DP-shard partial: reduce over dp (standard data-
        # parallel grad sync, parameter-sized — the feature axis itself is
        # never reduced).
        g_tabs = [jax.lax.psum(g, plan.dp) for g in g_tabs]
        if plan.has_din:
            g_din = jax.lax.psum(g_din, plan.dp)
        if plan.has_dout:
            g_dout = jax.lax.psum(g_dout, plan.dp)
        if plan.has_bias:
            g_bias = jax.lax.psum(g_bias, plan.dp)
    return delta, tuple(g_tabs[::-1]), g_din, g_dout, g_bias


# ---------------------------------------------------------------------------
# custom_vjp over the whole sharded operator
# ---------------------------------------------------------------------------

def _fwd_specs(plan: ShardPlan):
    in_specs = (plan.table_specs(), plan.vec_spec(plan.has_din),
                plan.vec_spec(plan.has_dout), plan.vec_spec(plan.has_bias),
                plan.x_spec())
    return in_specs, plan.act_spec(), plan.res_specs()


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sharded_core(plan: ShardPlan, tables, d_in, d_out, bias, x2):
    """x2: (rows, in_width or n) row-major, rows pre-padded to block_rows
    when the kernel path is on.  Returns (rows, out_width or n)."""
    in_specs, y_spec, _ = _fwd_specs(plan)
    f = shard_map(
        functools.partial(_shard_fwd, plan, collect=False),
        mesh=plan.mesh, in_specs=in_specs, out_specs=y_spec,
        check_rep=False)
    y2 = f(tables, d_in, d_out, bias, x2)
    if plan.out_width is not None:
        y2 = y2[:, :plan.out_width]
    return y2


def _sharded_core_fwd(plan, tables, d_in, d_out, bias, x2):
    in_specs, y_spec, res_specs = _fwd_specs(plan)
    f = shard_map(
        functools.partial(_shard_fwd, plan, collect=True),
        mesh=plan.mesh, in_specs=in_specs, out_specs=(y_spec, res_specs),
        check_rep=False)
    y2, res = f(tables, d_in, d_out, bias, x2)
    if plan.out_width is not None:
        y2 = y2[:, :plan.out_width]
    return y2, (tables, d_in, d_out, bias, res)


def _sharded_core_bwd(plan, saved, gy2):
    tables, d_in, d_out, bias, res = saved
    in_specs, y_spec, res_specs = _fwd_specs(plan)
    if plan.out_width is not None:
        # Transport the cotangent as an even-width slab: the zero-pad is a
        # local op that fuses into the slab reshard, and the padded lanes
        # carry exact-zero cotangent (the transpose of the forward's
        # output slice).  Window-reading the (rows, out_width) cotangent
        # instead would force replicating it — a batch-proportional
        # all-gather whenever it flows back feature-sharded.
        # spmlint: allow[SPM002] — even-slab cotangent transport
        gy2 = jnp.pad(gy2, ((0, 0), (0, plan.n - plan.out_width)))
    out_specs = (y_spec, plan.table_specs(), plan.vec_spec(plan.has_din),
                 plan.vec_spec(plan.has_dout), plan.vec_spec(plan.has_bias))
    f = shard_map(
        functools.partial(_shard_bwd, plan),
        mesh=plan.mesh,
        in_specs=in_specs[:4] + (res_specs, y_spec),
        out_specs=out_specs, check_rep=False)
    g_x2, g_tabs, g_din, g_dout, g_bias = f(tables, d_in, d_out, bias,
                                            res, gy2)
    if plan.in_width is not None:
        # the shard_map assembles the (rows, n) sharded delta; the primal
        # contract is (rows, in_width) — a local per-shard slice, and the
        # dropped lanes are the padded ones whose cotangent is discarded
        g_x2 = g_x2[:, :plan.in_width]

    def _vg(g, like, present):
        return g.astype(like.dtype) if present else jnp.zeros_like(like)

    g_tabs = tuple(g.astype(t.dtype) for g, t in zip(g_tabs, tables))
    return (g_tabs, _vg(g_din, d_in, plan.has_din),
            _vg(g_dout, d_out, plan.has_dout),
            _vg(g_bias, bias, plan.has_bias), g_x2)


_sharded_core.defvjp(_sharded_core_fwd, _sharded_core_bwd)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

# _resolve_kernel moved to core/eligibility.resolve_shard_kernel (the
# single fallback matrix), next to resolve_overlap / resolve_rdma.


def _rdma_cross_indices(steps, n_local: int) -> Tuple[int, ...]:
    """Cross-step indices executable as fused RDMA pair kernels: the pair's
    local run must plan to ONE kernel run (its stages' pair spans all fit
    one n_local-wide tile — true for every two_level cycle with
    n_local <= MAX_TILE).  The kernel pipelines at its own ``block_rows``
    granularity (one grid step per row block), independent of the coarser
    ``row_blocks`` the ppermute transport uses."""
    out = []
    i = 0
    for seg in overlap_segments(steps):
        if seg[0] == "pair":
            if len(plan_runs(n_local, seg[1][2])) == 1:
                out.append(i + 1)
            i += 2
        else:
            i += 1
    return tuple(out)


def spm_apply_sharded(params: dict, x: jax.Array, cfg, mesh: Mesh, *,
                      in_width: Optional[int] = None,
                      out_width: Optional[int] = None) -> jax.Array:
    """Feature-sharded SPM forward (+ closed-form grads via custom_vjp).

    Semantically identical to the unsharded ``spm_apply`` on the same
    params/config; the mesh's ``"model"`` axis size must equal
    ``cfg.n_shards``.  Rows co-shard over any pure-DP mesh axes
    ("pod"/"data") so batch-sharded activations enter without an
    all-gather.  Collectives issued: one collective-permute per cross-shard
    stage (two in the backward) — plus, only when DP axes exist, the
    standard parameter-sized grad psum over those axes in the backward.
    Under the overlap schedule (``cfg.overlap`` — see the module
    docstring) each of those permutes splits into one per row block with
    IDENTICAL total bytes, pipelined so a block's exchange hides under
    the other blocks' compute (in-kernel ``make_async_remote_copy`` on
    compiled TPU backends, per-block ppermute everywhere else).

    Rectangular widths: ``x`` stays ``(..., in_width)`` — it enters the
    shard_map feature-replicated and the FIRST shard-local kernel run reads
    this shard's n_local-wide window straight out of it (scalar-prefetch
    offset + in-VMEM iota mask against the global width), so no
    zero-padded square array is ever materialized in HBM; the backward
    remats through the same windowed read and the custom_vjp returns the
    input cotangent as ``(..., in_width)`` with exact-zero padded-lane
    parameter grads.  (Off the kernel path the window falls back to a
    local gather + mask in the shard body.)  The output leaves the
    shard_map as the assembled (rows, n) sharded array and is cut to
    ``out_width`` by one local per-shard slice, and the backward's
    cotangent enters as an even-width slab (local zero-pad fused into the
    reshard — see the ShardPlan note) — the two boundary XLA ops a
    rectangular operator still costs; under SPMD the edge shard's
    dead-tile compute is wall-clock-free (fully-live interior shards
    bound the step).
    """
    n = cfg.n
    if mesh.shape[AXIS] != cfg.n_shards:
        raise ValueError(
            f"mesh axis {AXIS!r} has size {mesh.shape[AXIS]}, operator has "
            f"n_shards={cfg.n_shards}")
    if in_width == n:
        in_width = None
    if out_width == n:
        out_width = None
    sched = cfg.pairing
    steps = plan_steps(n, sched.strides(), cfg.n_shards)
    n_local = n // cfg.n_shards

    in_w = in_width if in_width is not None else n
    lead = x.shape[:-1]
    rows = int(np.prod(lead, dtype=np.int64)) if lead else 1
    x2 = x.reshape(rows, in_w)

    from repro.parallel.sharding import data_axes
    dp = data_axes(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= int(mesh.shape[a])

    backend_tpu = jax.default_backend() == "tpu"
    interpret = default_interpret()
    use_kernel = resolve_shard_kernel(cfg, steps, backend_tpu)
    overlap = resolve_overlap(cfg, steps, backend_tpu)
    rdma = overlap and resolve_rdma(use_kernel, backend_tpu, interpret)
    block_rows = 1
    if use_kernel:
        rows_per_dp = -(-rows // dp_total)
        block_rows = min(
            pick_block_rows_for_plan(plan_runs(n_local, step[2]),
                                     rows_per_dp,
                                     dtype_bytes=x.dtype.itemsize,
                                     overlap_bufs=rdma)
            for step in steps if step[0] == "local")
        if overlap:
            # the pipeline needs >= OVERLAP_ROW_BLOCKS kernel row blocks to
            # hide anything: trade block size down (never below the 8-row
            # VREG floor) until the slab yields that many — the per-block
            # VMEM working set only shrinks with it
            while (block_rows > 8
                   and rows_per_dp // block_rows < OVERLAP_ROW_BLOCKS):
                block_rows //= 2
    # rows must split evenly over the DP axes AND (kernel path) each
    # DP-local slab must be a block_rows multiple; padded rows are zeros,
    # contributing exact zeros to every batch-summed parameter grad.
    quantum = dp_total * block_rows
    padded = -(-rows // quantum) * quantum
    if padded != rows:
        # spmlint: allow[SPM002] row padding to the DP x row-block quantum
        x2 = jnp.pad(x2, ((0, padded - rows), (0, 0)))

    row_blocks = pick_row_blocks(padded // dp_total,
                                 block_rows) if overlap else ()
    rdma_crosses = (_rdma_cross_indices(steps, n_local)
                    if rdma else ())
    plan = ShardPlan(
        mesh=mesh, n=n, n_local=n_local, n_shards=cfg.n_shards,
        steps=steps, has_din=cfg.use_diag, has_dout=cfg.use_diag,
        has_bias=cfg.use_bias, use_kernel=use_kernel,
        block_rows=block_rows, interpret=interpret, dp=dp,
        in_width=in_width, out_width=out_width,
        row_blocks=row_blocks, rdma_crosses=rdma_crosses,
        quant_cf=use_kernel and bool(getattr(cfg, "quant_coeffs", False)))

    coeffs = spm_mod.stage_coeffs(params, cfg)
    tables = _step_tables(coeffs, steps, cfg.n_shards, n_local)
    # Pin the O(nL) tables replicated: without this, XLA back-propagates the
    # shard_map's P("model") spec into the gather above and then re-gathers
    # the result — a (tiny but) spurious all-gather in the forward HLO.  The
    # reshard at the shard_map boundary is then a local slice.  (The
    # BACKWARD still pays one parameter-sized all-gather assembling the
    # replicated coefficient grad from per-shard partials — inherent to
    # replicated params, and O(nL), never activation-sized.)
    rep = jax.sharding.NamedSharding(mesh, P())
    tables = tuple(jax.lax.with_sharding_constraint(t, rep) for t in tables)
    ph = jnp.zeros((1,), _F32)
    y2 = _sharded_core(
        plan, tables,
        params["d_in"] if cfg.use_diag else ph,
        params["d_out"] if cfg.use_diag else ph,
        params["bias"] if cfg.use_bias else ph,
        x2)
    if y2.shape[0] != rows:
        y2 = y2[:rows]
    out_w = out_width if out_width is not None else n
    return y2.reshape(lead + (out_w,))
