"""Fused L-stage SPM kernel (Pallas / TPU) — full-operator edition.

Why a kernel (DESIGN.md §3.2): SPM has arithmetic intensity ~O(L) FLOP/byte
(vs ~n/2 for a dense matmul), far below the TPU v5e balance point
(~240 FLOP/byte @ 197 TFLOP/s bf16 / 819 GB/s HBM), so SPM is memory-bound by
construction.  Lowering each stage separately costs L+1 HBM round-trips of
the full activation; this kernel keeps an activation tile resident in VMEM
and applies ALL stages before writing back — one read + one write.

Full-operator folding (this PR): the paper's complete operator is

    y = D_out * (B_L ... B_1) * D_in * x + bias

and with only the stage stack fused, the two diagonal multiplies and the
bias add each cost one more full-activation HBM round-trip around the
kernel.  Both kernels therefore take OPTIONAL ``d_in`` / ``d_out`` / ``bias``
tile refs ((1, n_tile) slabs riding the lane dimension): ``d_in`` is applied
in VMEM before the first stage of the FIRST run, ``d_out``/``bias`` after
the last stage of the LAST run (ops.py folds them into the boundary runs of
the run plan).  The backward kernel emits their closed-form grads next to
the eq. 12-14 coefficient grads:

    g_bias  = sum_batch gy                       (accumulated across row tiles)
    g_dout  = sum_batch gy * z_L                 (z_L recomputed in VMEM)
    g_din   = sum_batch delta_0 * x              (delta_0 = backprop through stages)
    g_x     = delta_0 * d_in

Activation I/O may be bf16; all in-VMEM compute is f32 (inputs are upcast on
load, outputs downcast on the final store), so the serve engine's bf16 path
gets the fused kernel without precision loss in the accumulations
(coefficient/diag/bias grads are always written f32).

Rectangular-native boundaries (this PR): SPM is defined on a square n-wide
operator, but the projection linears it replaces are rectangular
(d_in -> d_out with n = even_ceil(max)).  Instead of the caller zero-padding
the input and slicing the output in XLA (two extra full-activation HBM
round-trips + up to n - d_out dead columns of compute), both kernels take
static ``in_width`` / ``out_width``:

  * ``in_width``  — the input operand is (B, in_width); the kernel reads
    whatever the (block_rows, n_tile) BlockSpec delivers (blocks past the
    array edge are padding) and zero-fills lanes with virtual column index
    >= in_width via an iota mask IN VMEM, before the d_in fold.
  * ``out_width`` — the output operand is (B, out_width); the final store
    relies on Pallas' masked out-of-bounds store semantics for the partial
    edge tile, and the FORWARD grid visits only ceil(out_width / n_tile)
    feature tiles (columns past out_width are dead by construction: stages
    in one run pair lanes tile-locally, so discarded output tiles depend
    only on discarded input tiles).
  * The backward keeps the FULL feature grid: every gcf / diag / bias
    output block must be written (unvisited blocks would be garbage), and
    masked x / gy loads make padded lanes contribute exact zeros to the
    coefficient, diag, and bias grads while g_x comes back (B, in_width).

ops.py sets the widths only on the boundary runs of a multi-run plan; the
interior intermediates stay n-wide.

Dead-tile-free backward (this PR): a feature tile whose columns all sit at
or past ``out_width`` receives an all-zero gy after the in-VMEM mask, and
because stages inside one run pair lanes tile-locally, EVERY gradient the
tile produces (gcf, g_din, g_dout, g_bias, g_x) is exactly zero.  The
backward grid therefore visits only ``ceil(out_width / n_tile)`` feature
tiles; the parameter-grad (and, when wider than the visited region, g_x)
blocks of the skipped tiles are zero-initialized by aliasing pre-zeroed
operands onto the outputs (``input_output_aliases`` — unvisited blocks
keep their input value).  ``dead_from`` extends the same skip to the
earlier runs of a multi-run plan: the last run's cotangent is exactly zero
from its first skipped column on, so upstream runs prune the same tail.

Sharded windowed boundaries (this PR): inside the distributed executor
(``parallel/spm_shard.py``) shard ``j`` owns global columns
``[j*n_local, (j+1)*n_local)`` of a rectangular operator whose input is a
feature-complete ``(rows, in_width)`` array.  Both kernels take an optional
``col_base`` — a TRACED (1,) int32 scalar holding the shard's base feature
tile — delivered via Pallas scalar prefetch: the x (forward / backward) and
gy (backward) BlockSpec index maps offset their feature-block index by it,
so each shard reads its own window straight out of the replicated operand
(the padded square array is never materialized in HBM), and the iota masks
compare against the GLOBAL column ``(col_base + j) * n_tile + lane``.  With
``col_base`` the widths are global widths, the output stays the shard-local
``(rows, n_local)`` slab, and the backward keeps the full local grid (the
grid is SPMD-uniform across shards; a shard's dead edge tiles are hidden by
the fully-live interior shards that bound the step wall-clock anyway).

Layout notes (TPU-native adaptation of the paper's CPU loop):
  * The feature axis rides the 128-wide lane dimension; batch rides sublanes.
  * A stride-s stage is the relayout (bb, n) -> (bb, g, 2, s) + vectorized
    2x2 FMA on the VPU (the MXU would be >97% idle at k=2, so we stay off it).
  * Stages with s >= 128 are lane-aligned relayouts (free-ish).  Stages with
    s < 128 induce intra-lane shuffles; the benchmark harness quantifies the
    residual cost and the two_level schedule orders them first so they fuse
    while the tile is hot.
  * Grid tiles: (batch_tile, feature_tile).  A feature tile of width n_t can
    fuse every stage with n_t % (2 s) == 0 (pair stays inside the tile);
    ops.py splits the schedule into maximal tile-local runs and composes.

Validated in interpret mode on CPU against kernels/ref.py (this container
has no TPU); the BlockSpec tiling is sized for v5e VMEM (~16 MiB budget).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["spm_stack_kernel_call", "spm_stack_bwd_kernel_call",
           "spm_overlap_kernel_call", "spm_overlap_bwd_kernel_call",
           "spm_block_kernel_call", "spm_block_bwd_kernel_call",
           "pick_block_rows", "vmem_bytes", "overlap_vmem_bytes",
           "block_vmem_bytes"]

_F32 = jnp.float32


def _mask_cols(z, tile_idx, width: int):
    """Zero lanes whose VIRTUAL column index (feature-tile offset + lane)
    is >= width — the in-VMEM realization of zero-padding a (B, width)
    operand up to the square operator width n."""
    nt = z.shape[-1]
    col = tile_idx * nt + jax.lax.broadcasted_iota(jnp.int32, z.shape,
                                                   z.ndim - 1)
    return jnp.where(col < width, z, 0.0)


def _apply_stages_fwd(z, cf_ref, strides, collect: bool = False,
                      scf_ref=None):
    """Run all stages on a resident f32 tile; optionally collect inputs.
    With ``scf_ref`` ((L, 1) per-stage scales) the coefficient slab is an
    int8 table dequantized here, in VMEM, one stage at a time."""
    bb, nt = z.shape
    zs = []
    for ell, s in enumerate(strides):
        if collect:
            zs.append(z)
        g = nt // (2 * s)
        zr = z.reshape(bb, g, 2, s)
        cf = cf_ref[ell].astype(_F32)          # (nt//2, 4)
        if scf_ref is not None:
            cf = cf * scf_ref[ell, 0]
        a = cf[:, 0].reshape(g, 1, s)
        b = cf[:, 1].reshape(g, 1, s)
        c = cf[:, 2].reshape(g, 1, s)
        d = cf[:, 3].reshape(g, 1, s)
        x0 = zr[:, :, 0, :].reshape(bb, g, 1, s)
        x1 = zr[:, :, 1, :].reshape(bb, g, 1, s)
        y0 = a * x0 + b * x1
        y1 = c * x0 + d * x1
        z = jnp.concatenate([y0, y1], axis=2).reshape(bb, nt)
    return (z, zs) if collect else z


def _kernel(*refs,
            strides: Tuple[int, ...],
            has_din: bool, has_dout: bool, has_bias: bool,
            in_width: Optional[int], has_base: bool = False,
            quant_in: bool = False, quant_out: bool = False,
            quant_cf: bool = False):
    """Kernel body: x_ref (bb, nt), cf_ref (L, nt//2, 4), o_ref (bb, nt).

    Optional refs (in order, present when the matching flag is set):
    ``quant_in`` inserts an sx_ref ((1, 1) per-block scale) after x_ref —
    x is int8, dequantized to f32 on load in VMEM; ``quant_cf`` inserts an
    scf_ref ((L, 1) per-stage scales) after cf_ref — the coefficient slab
    is int8, dequantized per stage in VMEM; din_ref / dout_ref / bias_ref,
    each (1, nt).  ``quant_out`` adds a second output sy_ref ((1, 1)): the
    epilogue computes the block's absmax/127 scale, stores it, and stores
    the int8 requantized block to o_ref — HBM sees no f32 activation
    bytes on a fully quantized run.  All compute is f32 in VMEM
    regardless of the I/O dtype.  ``in_width`` (rectangular first run)
    zero-fills the lanes past the true input width before anything else
    touches them; a narrow OUTPUT needs no in-kernel handling — the
    partial edge tile is masked by the out-of-bounds store.  With
    ``has_base`` the first ref is the scalar-prefetch ``(1,)`` base
    feature tile (sharded windowed read) and the mask compares against
    the GLOBAL column index.
    """
    refs = list(refs)
    base = refs.pop(0)[0] if has_base else 0
    x_ref = refs.pop(0)
    sx_ref = refs.pop(0) if quant_in else None
    cf_ref = refs.pop(0)
    scf_ref = refs.pop(0) if quant_cf else None
    din_ref = refs.pop(0) if has_din else None
    dout_ref = refs.pop(0) if has_dout else None
    bias_ref = refs.pop(0) if has_bias else None
    if quant_out:
        o_ref, sy_ref = refs
    else:
        (o_ref,) = refs

    z = x_ref[...].astype(_F32)
    if quant_in:
        z = z * sx_ref[0, 0]                    # dequantize-on-load (VMEM)
    if in_width is not None:
        z = _mask_cols(z, base + pl.program_id(1), in_width)
    if has_din:
        z = z * din_ref[...].astype(_F32)       # (1, nt) broadcast over rows
    z = _apply_stages_fwd(z, cf_ref, strides, scf_ref=scf_ref)
    if has_dout:
        z = z * dout_ref[...].astype(_F32)
    if has_bias:
        z = z + bias_ref[...].astype(_F32)
    if quant_out:
        # requantize-on-store: per-block absmax scale, int8 payload.  The
        # scale convention matches kernels/quant.py (always positive).
        sy = jnp.max(jnp.abs(z)) / 127.0 + 1e-12
        sy_ref[...] = sy.reshape(1, 1)
        o_ref[...] = jnp.clip(jnp.round(z / sy), -127, 127).astype(jnp.int8)
    else:
        o_ref[...] = z.astype(o_ref.dtype)


def vmem_bytes(block_rows: int, n_tile: int, n_stages: int,
               dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of the BACKWARD kernel — the binding one,
    since forward and backward share ``block_rows``: the in-VMEM remat
    keeps all L+1 stage-input tiles PLUS the delta tile resident in f32
    until the reverse walk consumes them, on top of the x/gy/gx I/O tiles
    and two coefficient slabs (coeffs in, gcf out).  The forward needs
    strictly less (2 activation copies).  Diag/bias slabs are O(n_tile),
    negligible.

    The model keys on ONE run's (n_tile, n_stages): ops.py budgets each run
    of a plan against its own tile width and stage count (not a uniform
    n-wide worst case — see ``ops.pick_block_rows_for_plan``).  Rectangular
    boundary runs change nothing here: a masked-fill input tile occupies
    the full (block_rows, n_tile) buffer in VMEM even when the HBM operand
    is narrower."""
    act = (n_stages + 2) * block_rows * n_tile * 4   # zs (L+1) + delta, f32
    io = 3 * block_rows * n_tile * dtype_bytes       # x, gy, gx tiles
    cf = 2 * n_stages * (n_tile // 2) * 4 * 4        # coeffs + gcf
    return act + io + cf


def overlap_vmem_bytes(block_rows: int, n_tile: int, n_stages: int,
                       dtype_bytes: int = 4) -> int:
    """VMEM working set of the overlap (RDMA) kernels — the binding one is
    again the backward: the ``vmem_bytes`` stage-remat working set PLUS the
    per-block send/recv communication buffers.  The backward exchanges a
    ``(2, block_rows, n_tile)`` package per row block — the (delta, z_out)
    pair — double-buffered on BOTH ends (2 slots x send + recv), i.e.

        comm = 2 slots * 2 tensors * 2 ends * block_rows * n_tile * io_bytes

    in the activation I/O dtype (blocks travel the wire as sent), plus ONE
    extra I/O tile: the overlap backward streams x through two BlockSpec
    windows (the send-side remat reads block i while the walk-side remat
    reads block i-1), one more activation window than the three
    ``vmem_bytes`` models.  The forward ships only z_out (half the
    package) and needs strictly less; budgeting the backward keeps
    ``block_rows`` shared, exactly as ``vmem_bytes`` does for the
    non-overlap pair."""
    comm = 8 * block_rows * n_tile * dtype_bytes
    x_walk = block_rows * n_tile * dtype_bytes   # second x window (bwd)
    return vmem_bytes(block_rows, n_tile, n_stages, dtype_bytes) \
        + comm + x_walk


def block_vmem_bytes(block_rows: int, n_tile: int, n_stages: int,
                     dtype_bytes: int = 4) -> int:
    """VMEM working set of the residual-BLOCK kernels (norm prologue ->
    stack 1 -> activation -> stack 2 -> residual store) — the binding one
    is again the backward, which remats the whole chain in VMEM:
    ``vmem_bytes`` with ``n_stages = L1 + L2`` covers the two stacks'
    stage-input tiles, and on top of that the block keeps THREE more f32
    activation tiles live across the chain — the normalized x-hat tile
    (the norm backward re-reads it after both stage walks), and the
    mid-boundary pre-activation u / post-activation h pair (u feeds the
    activation derivative, h feeds the second stack's d_in grad) — plus
    the (block_rows, 1) row statistics.  Per-linear budgeting
    (``ops.pick_block_rows_for_plan`` without ``block_bufs``) misses
    these and would overcommit VMEM by ~3 tiles."""
    extra = 3 * block_rows * n_tile * 4 + block_rows * 4
    return vmem_bytes(block_rows, n_tile, n_stages, dtype_bytes) + extra


def pick_block_rows(n_tile: int, n_stages: int, dtype_bytes: int = 4,
                    budget: int = 12 * 2**20, *,
                    overlap: bool = False, block: bool = False) -> int:
    """Largest power-of-two row-block (>=8) within the VMEM budget;
    ``overlap`` budgets against ``overlap_vmem_bytes`` (the RDMA kernels'
    send/recv double buffers ride the same VMEM), ``block`` against
    ``block_vmem_bytes`` (the residual-block kernels' norm/activation/
    residual live buffers)."""
    if block:
        cost = block_vmem_bytes
    else:
        cost = overlap_vmem_bytes if overlap else vmem_bytes
    bb = 8
    while bb < 1024 and cost(bb * 2, n_tile, n_stages,
                             dtype_bytes) <= budget:
        bb *= 2
    return bb


def pick_max_tile(n: int, n_stages: int, dtype_bytes: int = 4,
                  budget: int = 12 * 2**20) -> int:
    """Feature-tile cap for tiny-row (decode) calls: the widest
    power-of-two multiple of the default 2048 cap whose backward working
    set still fits the VMEM budget at the MINIMUM row block (8).

    Decode ticks call the operator with rows = active batch slots — a
    single row block.  The default ``ops.MAX_TILE`` cap is sized for
    training row counts, where many row blocks stream through VMEM
    concurrently with wide tiles; with one 8-row block resident the same
    budget affords much wider tiles, so a schedule that plans to several
    runs at 2048 (several HBM round-trips per token) re-plans to fewer,
    wider runs — often one."""
    cap = 2048
    while cap < n and vmem_bytes(8, cap * 2, n_stages,
                                 dtype_bytes) <= budget:
        cap *= 2
    return cap


def _vec_spec(n_tile: int) -> pl.BlockSpec:
    """(1, n_tile) slab of an (1, n) vector, indexed by the feature tile."""
    return pl.BlockSpec((1, n_tile), lambda i, j: (0, j))


def _lift_spec(spec: pl.BlockSpec) -> pl.BlockSpec:
    """Adapt a plain BlockSpec to a scalar-prefetch grid: index maps gain
    a trailing scalar ref, which non-windowed operands ignore.  Works for
    either grid-axis order (it just drops the last argument)."""
    return pl.BlockSpec(spec.block_shape,
                        lambda *a, f=spec.index_map: f(*a[:-1]))


@functools.partial(jax.jit, static_argnames=("strides", "block_rows",
                                             "n_tile", "in_width",
                                             "out_width", "quant_out",
                                             "interpret"))
def spm_stack_kernel_call(x: jax.Array, coeffs: jax.Array,
                          d_in: Optional[jax.Array] = None,
                          d_out: Optional[jax.Array] = None,
                          bias: Optional[jax.Array] = None,
                          col_base: Optional[jax.Array] = None,
                          x_scale: Optional[jax.Array] = None,
                          coeff_scale: Optional[jax.Array] = None, *,
                          strides: Tuple[int, ...],
                          block_rows: int,
                          n_tile: int,
                          in_width: Optional[int] = None,
                          out_width: Optional[int] = None,
                          quant_out: bool = False,
                          interpret: bool = False):
    """pallas_call wrapper.  x: (B, in_width or n); coeffs: (L, n//2, 4);
    optional d_in/d_out/bias: (n,) — folded into the kernel (applied before
    the first / after the last stage, in VMEM).  ``in_width`` /
    ``out_width`` make the boundary runs rectangular-native: the input is
    zero-filled to n in VMEM (iota mask) and only the first ``out_width``
    output columns are computed (grid shrinks to ceil(out_width / n_tile)
    tiles — tile-local pairing makes the rest dead) and stored (masked
    partial edge tile).  Returns (B, out_width or n).

    Quantized I/O (kernels/quant.py conventions):

    * ``x_scale`` — x is int8 with per-(row-block, feature-tile) scales
      ``(B // block_rows, ceil(in_width / n_tile))``; each block is
      dequantized to f32 on load, in VMEM.
    * ``quant_out=True`` — the epilogue requantizes the finished block and
      returns ``(y int8, y_scale f32)`` with ``y_scale`` shaped
      ``(B // block_rows, grid feature tiles)``; chained runs feed it
      straight back as the next run's ``x_scale`` (tiles must match).
    * ``coeff_scale`` — coeffs is int8 with per-stage ``(L, 1)`` scales,
      dequantized one stage at a time in VMEM.

    ``col_base`` (sharded windowed read — requires ``in_width``, excludes
    ``out_width`` and quantized ACTIVATIONS; quantized coeffs are fine):
    a TRACED (1,) int32 base feature tile.  x is the feature-COMPLETE
    (B, in_width) operand shared by all shards; the x index map offsets
    its feature block by the base (scalar prefetch) so this shard
    reads/zero-fills exactly its n-wide window of the global operator,
    and the output is the full (B, n) shard-local slab.

    Requires: B % block_rows == 0, n % n_tile == 0, and every stride s
    satisfies n_tile % (2*s) == 0 (pairs tile-local).  ops.py guarantees
    these by padding/splitting; this function is the raw kernel entry.
    """
    B = x.shape[0]
    L, n = coeffs.shape[0], 2 * coeffs.shape[1]
    assert x.shape[-1] == (in_width if in_width is not None else n)
    assert B % block_rows == 0 and n % n_tile == 0
    for s in strides:
        assert n_tile % (2 * s) == 0, (s, n_tile)
    quant_in = x_scale is not None
    assert quant_in == (x.dtype == jnp.int8)
    has_base = col_base is not None
    assert not has_base or (in_width is not None and out_width is None)
    assert not has_base or (not quant_in and not quant_out)
    out_w = out_width if out_width is not None else n
    grid = (B // block_rows, n // n_tile if has_base
            else -(-out_w // n_tile))

    # Pair indices for feature tile j are the contiguous slab
    # [j * n_tile/2, (j+1) * n_tile/2): groups are sequential in the flat
    # pair index, and each tile covers whole groups for every fused stride.
    x_spec = pl.BlockSpec((block_rows, n_tile), lambda i, j: (i, j))
    cf_spec = pl.BlockSpec((L, n_tile // 2, 4), lambda i, j: (0, j, 0))
    o_spec = pl.BlockSpec((block_rows, n_tile), lambda i, j: (i, j))
    sc_spec = pl.BlockSpec((1, 1), lambda i, j: (i, j))
    scf_spec = pl.BlockSpec((L, 1), lambda i, j: (0, 0))

    operands = [x]
    in_specs = [x_spec]
    if quant_in:
        operands.append(x_scale.astype(_F32))
        in_specs.append(sc_spec)
    operands.append(coeffs)
    in_specs.append(cf_spec)
    if coeff_scale is not None:
        operands.append(coeff_scale.astype(_F32).reshape(L, 1))
        in_specs.append(scf_spec)
    for vec in (d_in, d_out, bias):
        if vec is not None:
            operands.append(vec.reshape(1, n))
            in_specs.append(_vec_spec(n_tile))

    out_specs = o_spec
    out_shape = jax.ShapeDtypeStruct(
        (B, out_w), jnp.int8 if quant_out else x.dtype)
    if quant_out:
        out_specs = [o_spec, sc_spec]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((B // block_rows, grid[1]),
                                          jnp.float32)]

    kernel = functools.partial(_kernel, strides=strides,
                               has_din=d_in is not None,
                               has_dout=d_out is not None,
                               has_bias=bias is not None,
                               in_width=in_width, has_base=has_base,
                               quant_in=quant_in, quant_out=quant_out,
                               quant_cf=coeff_scale is not None)
    if has_base:
        # Scalar prefetch: every index map gains a trailing base ref; only
        # the x map consumes it (blocks past the operand edge clamp; the
        # in-VMEM mask against the global column zero-fills them).
        in_specs = [_lift_spec(s) for s in in_specs]
        in_specs[0] = pl.BlockSpec(x_spec.block_shape,
                                   lambda i, j, b: (i, b[0] + j))
        return pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1, grid=grid,
                in_specs=in_specs, out_specs=_lift_spec(o_spec)),
            out_shape=jax.ShapeDtypeStruct((B, n), x.dtype),
            interpret=interpret,
        )(col_base.astype(jnp.int32), *operands)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# fused backward kernel
# ---------------------------------------------------------------------------
#
# Training is 2/3 backward; without a fused backward the forward fusion win
# is capped at 1.5x end-to-end.  The backward kernel recomputes the stage
# inputs IN VMEM from the x tile (no HBM traffic for intermediates — the
# Pallas analogue of remat), then walks the stages in reverse applying the
# paper's closed forms: delta <- B_l^T delta (eqs. 12-13) and the rank-1 pair
# accumulations for (a, b, c, d) grads (eq. 14).  The folded diag/bias grads
# ride the same pass: g_bias/g_dout fall out of gy (and the recomputed z_L)
# before the stage walk, g_din out of delta_0 after it.  All parameter-
# gradient partials are accumulated across batch tiles in their output
# blocks; the grid is therefore (feature, batch) with batch as the MINOR
# axis, so for a fixed feature tile every batch step maps to the SAME
# output block on consecutive grid iterations — the documented Pallas
# reduction pattern (the block stays resident in VMEM between consecutive
# revisits; accumulating across a non-minor axis would read back a flushed
# buffer on real TPU): init at batch step 0, accumulate after.

def _stage_walk_bwd(zs, delta, cf_ref, strides: Tuple[int, ...],
                    scf_ref=None):
    """Reverse walk over one run's stages from the collected stage-input
    tiles ``zs``: the eq. 14 pair grads (reduced over the batch-tile rows)
    and delta <- B^T delta (eqs. 12-13).  Returns ``(delta_0,
    gcf (L, nt//2, 4))`` — shared by the plain and overlap backward
    kernels.  ``scf_ref`` dequantizes an int8 coefficient slab in VMEM
    (the gcf output stays f32 in DEQUANTIZED units — the grads of the
    values the forward actually used)."""
    bb, nt = delta.shape
    gcf_parts = []
    for ell in range(len(strides) - 1, -1, -1):
        s = strides[ell]
        g = nt // (2 * s)
        cf = cf_ref[ell].astype(_F32)
        if scf_ref is not None:
            cf = cf * scf_ref[ell, 0]
        a = cf[:, 0].reshape(g, 1, s)
        b = cf[:, 1].reshape(g, 1, s)
        c = cf[:, 2].reshape(g, 1, s)
        d = cf[:, 3].reshape(g, 1, s)
        zr = zs[ell].reshape(bb, g, 2, s)
        dr = delta.reshape(bb, g, 2, s)
        x0 = zr[:, :, 0, :].reshape(bb, g, 1, s)
        x1 = zr[:, :, 1, :].reshape(bb, g, 1, s)
        d0 = dr[:, :, 0, :].reshape(bb, g, 1, s)
        d1 = dr[:, :, 1, :].reshape(bb, g, 1, s)
        ga = jnp.sum(d0 * x0, axis=0).reshape(g * s)
        gb = jnp.sum(d0 * x1, axis=0).reshape(g * s)
        gc = jnp.sum(d1 * x0, axis=0).reshape(g * s)
        gd = jnp.sum(d1 * x1, axis=0).reshape(g * s)
        gcf_parts.append(jnp.stack([ga, gb, gc, gd], axis=-1))
        delta = jnp.concatenate([a * d0 + c * d1, b * d0 + d * d1],
                                axis=2).reshape(bb, nt)
    return delta, jnp.stack(gcf_parts[::-1], axis=0)


def _bwd_kernel(*refs,
                strides: Tuple[int, ...],
                has_din: bool, has_dout: bool, has_bias: bool,
                in_width: Optional[int], out_width: Optional[int],
                has_base: bool = False, n_zero_init: int = 0,
                quant_in: bool = False, quant_cf: bool = False):
    refs = list(refs)
    base = refs.pop(0)[0] if has_base else 0
    x_ref = refs.pop(0)
    sx_ref = refs.pop(0) if quant_in else None
    cf_ref = refs.pop(0)
    scf_ref = refs.pop(0) if quant_cf else None
    gy_ref = refs.pop(0)
    din_ref = refs.pop(0) if has_din else None
    dout_ref = refs.pop(0) if has_dout else None
    if n_zero_init:
        del refs[:n_zero_init]       # aliased zero-init operands, unread
    gx_ref = refs.pop(0)
    gcf_ref = refs.pop(0)
    gdin_ref = refs.pop(0) if has_din else None
    gdout_ref = refs.pop(0) if has_dout else None
    gbias_ref = refs.pop(0) if has_bias else None

    bb, nt = x_ref.shape
    L = len(strides)
    # feature tile: major grid axis.  ``base`` shifts it to the GLOBAL
    # feature tile in the sharded windowed mode (0 otherwise), so the
    # in_width/out_width masks below always compare global columns.
    j = base + pl.program_id(0)

    # recompute stage inputs in VMEM (forward remat), incl. the d_in fold.
    # Rectangular first run: lanes past in_width are zero-filled exactly as
    # the forward saw them, so the remat AND every grad that multiplies by
    # x (g_din, the eq. 14 coefficient grads) see zeros on padded lanes.
    # A quantized saved-x (int8 + per-block scale) dequantizes on load, so
    # the remat replays EXACTLY the activations the quantized forward
    # produced — the backward is the true gradient of the quantized net.
    x_raw = x_ref[...].astype(_F32)
    if quant_in:
        x_raw = x_raw * sx_ref[0, 0]
    if in_width is not None:
        x_raw = _mask_cols(x_raw, j, in_width)
    z0 = x_raw * din_ref[...].astype(_F32) if has_din else x_raw
    z_last, zs = _apply_stages_fwd(z0, cf_ref, strides, collect=True,
                                   scf_ref=scf_ref)

    # Rectangular last run: the sliced-away output columns carry no
    # cotangent, so masking gy to out_width zeroes their contribution to
    # g_bias / g_dout and to the stage walk below.
    gy = gy_ref[...].astype(_F32)
    if out_width is not None:
        gy = _mask_cols(gy, j, out_width)
    i = pl.program_id(1)  # batch step: minor grid axis (see note above)

    def _acc(ref, tile):
        @pl.when(i == 0)
        def _init():
            ref[...] = tile

        @pl.when(i > 0)
        def _add():
            ref[...] += tile

    if has_bias:
        _acc(gbias_ref, jnp.sum(gy, axis=0).reshape(1, nt))
    if has_dout:
        _acc(gdout_ref, jnp.sum(gy * z_last, axis=0).reshape(1, nt))
        delta = gy * dout_ref[...].astype(_F32)
    else:
        delta = gy

    delta, gcf = _stage_walk_bwd(zs, delta, cf_ref, strides,
                                 scf_ref=scf_ref)

    if has_din:
        _acc(gdin_ref, jnp.sum(delta * x_raw, axis=0).reshape(1, nt))
        delta = delta * din_ref[...].astype(_F32)
    gx_ref[...] = delta.astype(gx_ref.dtype)
    _acc(gcf_ref, gcf)                                 # (L, nt//2, 4)


@functools.partial(jax.jit, static_argnames=("strides", "block_rows",
                                             "n_tile", "has_bias",
                                             "in_width", "out_width",
                                             "dead_from", "interpret"))
def spm_stack_bwd_kernel_call(x: jax.Array, coeffs: jax.Array,
                              gy: jax.Array,
                              d_in: Optional[jax.Array] = None,
                              d_out: Optional[jax.Array] = None,
                              col_base: Optional[jax.Array] = None,
                              x_scale: Optional[jax.Array] = None,
                              coeff_scale: Optional[jax.Array] = None, *,
                              strides: Tuple[int, ...],
                              block_rows: int,
                              n_tile: int,
                              has_bias: bool = False,
                              in_width: Optional[int] = None,
                              out_width: Optional[int] = None,
                              dead_from: Optional[int] = None,
                              interpret: bool = False):
    """Fused backward for (optionally) the full operator.

    Always returns ``(g_x (B, in_width or n), g_coeffs (L, n//2, 4) f32)``
    followed by ``g_din (n,)`` if ``d_in`` was given, ``g_dout (n,)`` if
    ``d_out`` was given, and ``g_bias (n,)`` if ``has_bias`` (the bias value
    itself is not needed for its grad).  All parameter grads are f32.

    Quantized operands (kernels/quant.py conventions): ``x_scale`` marks a
    saved-x that is int8 with per-(row-block, feature-tile) scales —
    dequantized on load, so the in-VMEM remat replays exactly the
    activations the quantized forward produced (g_x then comes back in
    the GY dtype, never int8 — cotangents are not quantized).
    ``coeff_scale`` marks an int8 coefficient table with per-stage
    ``(L, 1)`` scales dequantized in VMEM; the f32 gcf output is the grad
    of the DEQUANTIZED values, bitwise what a pre-dequantized f32 table
    would produce.

    Rectangular boundaries: ``x`` is (B, in_width) and ``gy`` is
    (B, out_width) when set; both are masked to exact zeros past their
    width in VMEM, so padded lanes contribute exact zeros to the
    coefficient, diag, and bias grads.

    Dead-tile skip: a feature tile whose columns all sit at or past
    ``out_width`` carries an all-zero masked gy, and tile-local pairing
    makes EVERY grad it produces an exact zero — the grid visits only
    ``ceil(out_width / n_tile)`` feature tiles, and the skipped tiles'
    parameter-grad / g_x blocks are zero-initialized by aliasing pre-zeroed
    operands onto the outputs (``input_output_aliases``: an unvisited
    block keeps its input value).  ``dead_from`` declares the same
    all-zero-cotangent property for an interior run of a multi-run plan
    (its gy is the downstream run's g_x, exactly zero from the first
    column that run skipped) without implying a narrow gy operand.

    ``g_x`` comes back (B, in_width) only when ceil(in_width / n_tile)
    covers at least the visited tiles; when ``in_width`` leaves whole
    VISITED feature tiles past the array edge it comes back widened to the
    visited width and the CALLER slices — a fully out-of-bounds output
    block is not masked but CLAMPED onto the last valid block (both
    interpret mode and Mosaic clamp block indices), which would corrupt
    valid g_x columns.

    ``col_base`` (sharded windowed mode — see the forward kernel): a
    TRACED (1,) int32 base feature tile.  ``in_width``/``out_width``
    become GLOBAL widths; the matching operand (x / gy) is the
    feature-complete global array read through an offset index map, masks
    compare global columns, g_x is the full (B, n) shard-local slab, and
    the grid keeps every local tile (it must be SPMD-uniform across
    shards, so the skip is single-device only).
    """
    B = x.shape[0]
    L, n = coeffs.shape[0], 2 * coeffs.shape[1]
    has_base = col_base is not None
    assert not (has_base and dead_from is not None)
    quant_in = x_scale is not None
    assert quant_in == (x.dtype == jnp.int8)
    assert not (has_base and quant_in)
    x_windowed = has_base and in_width is not None
    gy_windowed = has_base and out_width is not None
    in_w = in_width if in_width is not None else n
    assert x.shape[-1] == in_w
    assert gy.shape[-1] == (out_width if out_width is not None else n)
    assert B % block_rows == 0 and n % n_tile == 0
    n_tiles = n // n_tile

    # Visited feature tiles: every tile from the first all-dead column on
    # is skipped (single-device only: the sharded grid is SPMD-uniform).
    live = n
    if out_width is not None:
        live = min(live, out_width)
    if dead_from is not None:
        live = min(live, dead_from)
    vis = n_tiles if has_base else min(n_tiles, -(-live // n_tile))

    gx_w = n if x_windowed else in_w
    if not x_windowed and -(-gx_w // n_tile) < vis:
        gx_w = vis * n_tile  # see docstring: narrow g_x would alias
        #                      clamped stores; the caller slices
    # batch is the MINOR grid axis: parameter-grad blocks (indexed by the
    # feature tile only) are revisited on consecutive iterations, which is
    # required for the in-block accumulation to be valid on real TPU.
    grid = (vis, B // block_rows)
    act_spec = pl.BlockSpec((block_rows, n_tile), lambda j, i: (i, j))
    cf_spec = pl.BlockSpec((L, n_tile // 2, 4), lambda j, i: (0, j, 0))
    vec_spec = pl.BlockSpec((1, n_tile), lambda j, i: (0, j))
    sc_spec = pl.BlockSpec((1, 1), lambda j, i: (i, j))
    scf_spec = pl.BlockSpec((L, 1), lambda j, i: (0, 0))

    operands = [x]
    in_specs = [act_spec]
    if quant_in:
        operands.append(x_scale.astype(jnp.float32))
        in_specs.append(sc_spec)
    operands.append(coeffs)
    in_specs.append(cf_spec)
    if coeff_scale is not None:
        operands.append(coeff_scale.astype(jnp.float32).reshape(L, 1))
        in_specs.append(scf_spec)
    operands.append(gy)
    in_specs.append(act_spec)
    for vec in (d_in, d_out):
        if vec is not None:
            operands.append(vec.reshape(1, n))
            in_specs.append(vec_spec)

    gx_dt = gy.dtype if quant_in else x.dtype
    out_specs = [act_spec, cf_spec]
    out_shape = [jax.ShapeDtypeStruct((B, gx_w), gx_dt),
                 jax.ShapeDtypeStruct((L, n // 2, 4), jnp.float32)]
    for present in (d_in is not None, d_out is not None, has_bias):
        if present:
            out_specs.append(vec_spec)
            out_shape.append(jax.ShapeDtypeStruct((1, n), jnp.float32))

    # Zero-init every output owning blocks the shrunk grid never visits by
    # aliasing a zeros operand onto it: g_x only when it is wider than the
    # visited region, parameter grads whenever any tile is skipped.  The
    # zeros operands sit at the END of the input list (the kernel body
    # skips ``n_zero_init`` refs there).
    aliases = {}
    n_zero_init = 0
    if vis < n_tiles:
        for o, (spec, sh) in enumerate(zip(out_specs, out_shape)):
            if o == 0 and -(-gx_w // n_tile) <= vis:
                continue
            aliases[len(operands)] = o
            operands.append(jnp.zeros(sh.shape, sh.dtype))
            in_specs.append(spec)
            n_zero_init += 1

    kernel = functools.partial(_bwd_kernel, strides=strides,
                               has_din=d_in is not None,
                               has_dout=d_out is not None,
                               has_bias=has_bias,
                               in_width=in_width, out_width=out_width,
                               has_base=has_base, n_zero_init=n_zero_init,
                               quant_in=quant_in,
                               quant_cf=coeff_scale is not None)
    if has_base:
        # Scalar prefetch: every index map gains a trailing base ref; only
        # the windowed operands consume it (offset feature block).
        win_spec = pl.BlockSpec((block_rows, n_tile),
                                lambda j, i, b: (i, b[0] + j))
        in_specs = [_lift_spec(s) for s in in_specs]
        gy_idx = 2 + (1 if coeff_scale is not None else 0)
        if x_windowed:
            in_specs[0] = win_spec
        if gy_windowed:
            in_specs[gy_idx] = win_spec
        out = pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1, grid=grid,
                in_specs=in_specs,
                out_specs=[_lift_spec(s) for s in out_specs]),
            out_shape=out_shape,
            interpret=interpret,
        )(col_base.astype(jnp.int32), *operands)
    else:
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=in_specs,
            out_specs=out_specs,
            out_shape=out_shape,
            input_output_aliases=aliases,
            interpret=interpret,
        )(*operands)
    gx, gcf = out[0], out[1]
    vec_grads = tuple(v.reshape(n) for v in out[2:])
    return (gx, gcf) + vec_grads


# ---------------------------------------------------------------------------
# residual-block (megakernel) pair: norm -> SPM -> act -> SPM -> residual
# ---------------------------------------------------------------------------
#
# The per-linear fused operator still pays an HBM round-trip at every
# block boundary: norm reads+writes the activation before the up
# projection, the activation reads+writes between the two linears, and
# the residual add reads+writes after the down projection — >=2 extra
# full-activation round-trips per transformer block that the O(nL)
# operator itself no longer needs.  These kernels lower the WHOLE
# residual block as one fused region:
#
#   prologue   RMS row statistics + gamma scale, in VMEM
#   stack 1    d_in -> stages -> d_out (+bias): the up projection
#   epilogue   activation (relu / silu / gelu, closed form both ways)
#   stack 2    the down projection, fed without leaving VMEM
#   store      + residual, masked to out_width
#
# Eligibility (core/eligibility.block_fusion_eligible) guarantees both
# stacks plan to a SINGLE full-width run (every stride s of either stack
# has n % (2s) == 0 and n <= BLOCK_MAX_TILE), so the grid is row blocks
# only — the feature axis never re-tiles between the stacks and the mid
# activation never touches HBM.
#
# Backward remats from row statistics: the forward saves ONLY the raw x
# and the (rows, 1) rstd — the normalized input, both stacks' stage
# inputs, and the mid activation are all recomputed in VMEM (the Pallas
# remat idiom of the per-linear backward, extended over the whole
# chain), then one reverse walk produces every grad closed-form:
# bias2/dout2 from gy, the eq. 12-14 walk of stack 2, the activation
# derivative at the rematted u, bias1/dout1/stack 1, gamma from the
# rematted x-hat, and the RMS-norm input grad
#
#   g_x = rstd * (g_xhat - xhat * mean(g_xhat * xhat))  (+ gy residual)
#
# Dead-lane discipline: x is masked to in_width before the row
# statistics (the mean divides by in_width, not n), the mid boundary is
# masked to mid_width before the activation (act(0) = 0 for every
# BLOCK_ACTIVATIONS member, so dead lanes enter stack 2 as exact zeros —
# bitwise what the unfused rectangular composition feeds it), and gy is
# masked to out_width; every parameter grad is therefore exactly zero on
# padded lanes.  The grid is 1-D over row blocks, so the parameter-grad
# outputs (indexed to block 0) are revisited on consecutive iterations —
# the same documented TPU reduction pattern as the per-linear backward,
# with no zero-init aliasing needed (block 0 is always visited at i=0).

def _act_fwd(u, activation: Optional[str]):
    """Closed-form block-epilogue activation on a resident f32 tile.
    ``None`` is the identity (norm-prologue-only entries, e.g. fused
    qkv).  Every member maps 0 -> 0, which the dead-lane masking relies
    on."""
    if activation == "relu":
        return jnp.maximum(u, 0.0)
    if activation == "silu":
        return u * jax.nn.sigmoid(u)
    if activation == "gelu":
        return jax.nn.gelu(u)       # tanh approximation (jax default)
    return u


def _act_grad(u, activation: Optional[str]):
    """Closed-form derivative of ``_act_fwd`` at the rematted
    pre-activation ``u`` — the backward never stores the activation."""
    if activation == "relu":
        return jnp.where(u > 0, 1.0, 0.0)
    if activation == "silu":
        sg = jax.nn.sigmoid(u)
        return sg * (1.0 + u * (1.0 - sg))
    if activation == "gelu":
        # d/du of the tanh-approx gelu 0.5*u*(1 + tanh(k*(u + 0.044715 u^3)))
        k = 0.7978845608028654      # sqrt(2/pi)
        t = jnp.tanh(k * (u + 0.044715 * u * u * u))
        return (0.5 * (1.0 + t)
                + 0.5 * u * (1.0 - t * t) * k
                * (1.0 + 3 * 0.044715 * u * u))
    return jnp.ones_like(u)


def _block_kernel(*refs,
                  strides1: Tuple[int, ...],
                  strides2: Optional[Tuple[int, ...]],
                  activation: Optional[str],
                  has_norm: bool, has_bias1: bool, has_bias2: bool,
                  residual: bool, in_width: int, mid_width: int,
                  out_width: int, eps: float):
    refs = list(refs)
    x_ref = refs.pop(0)
    g_ref = refs.pop(0) if has_norm else None
    cf1_ref = refs.pop(0)
    din1_ref, dout1_ref = refs.pop(0), refs.pop(0)
    bias1_ref = refs.pop(0) if has_bias1 else None
    if strides2 is not None:
        cf2_ref = refs.pop(0)
        din2_ref, dout2_ref = refs.pop(0), refs.pop(0)
        bias2_ref = refs.pop(0) if has_bias2 else None
    if has_norm:
        o_ref, rstd_ref = refs
    else:
        (o_ref,) = refs

    x_raw = _mask_cols(x_ref[...].astype(_F32), 0, in_width)
    if has_norm:
        # row statistics over the TRUE input width (padded lanes are 0)
        var = jnp.sum(x_raw * x_raw, axis=1, keepdims=True) / in_width
        rstd = jax.lax.rsqrt(var + eps)
        rstd_ref[...] = rstd
        z = x_raw * rstd * g_ref[...].astype(_F32)
    else:
        z = x_raw
    z = z * din1_ref[...].astype(_F32)
    z = _apply_stages_fwd(z, cf1_ref, strides1)
    z = z * dout1_ref[...].astype(_F32)
    if has_bias1:
        z = z + bias1_ref[...].astype(_F32)
    if strides2 is not None:
        # mask BEFORE the activation: bias1 contaminates lanes past
        # mid_width, and act(0) = 0 keeps them exact zeros into stack 2
        z = _act_fwd(_mask_cols(z, 0, mid_width), activation)
        z = z * din2_ref[...].astype(_F32)
        z = _apply_stages_fwd(z, cf2_ref, strides2)
        z = z * dout2_ref[...].astype(_F32)
        if has_bias2:
            z = z + bias2_ref[...].astype(_F32)
    elif activation is not None:
        z = _act_fwd(_mask_cols(z, 0, mid_width), activation)
    if residual:
        z = z + x_raw
    o_ref[...] = z.astype(o_ref.dtype)


def _block_bwd_kernel(*refs,
                      strides1: Tuple[int, ...],
                      strides2: Optional[Tuple[int, ...]],
                      activation: Optional[str],
                      has_norm: bool, has_bias1: bool, has_bias2: bool,
                      residual: bool, in_width: int, mid_width: int,
                      out_width: int):
    refs = list(refs)
    x_ref = refs.pop(0)
    g_ref = refs.pop(0) if has_norm else None
    rstd_ref = refs.pop(0) if has_norm else None
    cf1_ref = refs.pop(0)
    din1_ref, dout1_ref = refs.pop(0), refs.pop(0)
    bias1_ref = refs.pop(0) if has_bias1 else None
    if strides2 is not None:
        cf2_ref = refs.pop(0)
        din2_ref, dout2_ref = refs.pop(0), refs.pop(0)
        bias2_ref = refs.pop(0) if has_bias2 else None
    gy_ref = refs.pop(0)
    gx_ref = refs.pop(0)
    ggam_ref = refs.pop(0) if has_norm else None
    gcf1_ref, gdin1_ref, gdout1_ref = (refs.pop(0), refs.pop(0),
                                       refs.pop(0))
    gbias1_ref = refs.pop(0) if has_bias1 else None
    if strides2 is not None:
        gcf2_ref, gdin2_ref, gdout2_ref = (refs.pop(0), refs.pop(0),
                                           refs.pop(0))
        gbias2_ref = refs.pop(0) if has_bias2 else None

    i = pl.program_id(0)
    bb, nt = x_ref.shape

    def _acc(ref, tile):
        @pl.when(i == 0)
        def _init():
            ref[...] = tile

        @pl.when(i > 0)
        def _add():
            ref[...] += tile

    # ---- remat the whole block forward in VMEM (norm from saved rstd) ----
    x_raw = _mask_cols(x_ref[...].astype(_F32), 0, in_width)
    if has_norm:
        rstd = rstd_ref[...]                       # (bb, 1) f32, saved
        xh = x_raw * rstd
        z0 = xh * g_ref[...].astype(_F32)
    else:
        z0 = x_raw
    t1 = z0 * din1_ref[...].astype(_F32)
    z1_last, zs1 = _apply_stages_fwd(t1, cf1_ref, strides1, collect=True)
    u = z1_last * dout1_ref[...].astype(_F32)
    if has_bias1:
        u = u + bias1_ref[...].astype(_F32)
    if strides2 is not None:
        u = _mask_cols(u, 0, mid_width)
        h = _act_fwd(u, activation)
        t2 = h * din2_ref[...].astype(_F32)
        z2_last, zs2 = _apply_stages_fwd(t2, cf2_ref, strides2,
                                         collect=True)
    elif activation is not None:
        u = _mask_cols(u, 0, mid_width)

    gy = _mask_cols(gy_ref[...].astype(_F32), 0, out_width)

    # ---- reverse walk ----
    if strides2 is not None:
        if has_bias2:
            _acc(gbias2_ref, jnp.sum(gy, axis=0).reshape(1, nt))
        _acc(gdout2_ref, jnp.sum(gy * z2_last, axis=0).reshape(1, nt))
        delta = gy * dout2_ref[...].astype(_F32)
        delta, gcf2 = _stage_walk_bwd(zs2, delta, cf2_ref, strides2)
        _acc(gcf2_ref, gcf2)
        _acc(gdin2_ref, jnp.sum(delta * h, axis=0).reshape(1, nt))
        dh = _mask_cols(delta * din2_ref[...].astype(_F32), 0, mid_width)
        du = dh * _act_grad(u, activation)
    elif activation is not None:
        du = gy * _act_grad(u, activation)
    else:
        du = gy
    if has_bias1:
        _acc(gbias1_ref, jnp.sum(du, axis=0).reshape(1, nt))
    _acc(gdout1_ref, jnp.sum(du * z1_last, axis=0).reshape(1, nt))
    delta = du * dout1_ref[...].astype(_F32)
    delta, gcf1 = _stage_walk_bwd(zs1, delta, cf1_ref, strides1)
    _acc(gcf1_ref, gcf1)
    _acc(gdin1_ref, jnp.sum(delta * z0, axis=0).reshape(1, nt))
    dz0 = _mask_cols(delta * din1_ref[...].astype(_F32), 0, in_width)
    if has_norm:
        _acc(ggam_ref, jnp.sum(dz0 * xh, axis=0).reshape(1, nt))
        gxh = dz0 * g_ref[...].astype(_F32)
        mean = jnp.sum(gxh * xh, axis=1, keepdims=True) / in_width
        gx = rstd * (gxh - xh * mean)
    else:
        gx = dz0
    if residual:
        gx = gx + gy
    gx_ref[...] = gx.astype(gx_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "strides1", "strides2", "activation", "block_rows", "residual",
    "in_width", "mid_width", "out_width", "eps", "interpret"))
def spm_block_kernel_call(x: jax.Array, coeffs1: jax.Array,
                          d_in1: jax.Array, d_out1: jax.Array,
                          bias1: Optional[jax.Array] = None,
                          gamma: Optional[jax.Array] = None,
                          coeffs2: Optional[jax.Array] = None,
                          d_in2: Optional[jax.Array] = None,
                          d_out2: Optional[jax.Array] = None,
                          bias2: Optional[jax.Array] = None, *,
                          strides1: Tuple[int, ...],
                          strides2: Optional[Tuple[int, ...]] = None,
                          activation: Optional[str] = None,
                          block_rows: int,
                          residual: bool = False,
                          in_width: int, mid_width: int, out_width: int,
                          eps: float = 1e-6,
                          interpret: bool = False):
    """Residual-block megakernel forward: ONE pallas_call lowering
    norm -> stack 1 -> activation -> stack 2 -> (+residual) store.

    x: (B, in_width); gamma: (n,) RMS scale zero-padded past ``in_width``
    (None skips the norm prologue); coeffs1/coeffs2: (L, n//2, 4) stage
    slabs of the up / down projections, with their (n,) d_in / d_out /
    optional bias; ``strides2=None`` ends the chain after stack 1 (the
    norm-prologue-only fused-qkv entry).  Both stacks must satisfy
    ``block_fusion_eligible`` (single full-width run each) — asserted
    here.  Returns ``y (B, out_width)`` or ``(y, rstd (B, 1) f32)`` with
    the norm prologue; rstd is the ONLY extra forward residual the
    backward needs (remat-from-row-stats).
    """
    B = x.shape[0]
    L1, n = coeffs1.shape[0], 2 * coeffs1.shape[1]
    assert x.shape[-1] == in_width and B % block_rows == 0
    for s in strides1 + (strides2 or ()):
        assert n % (2 * s) == 0, (s, n)
    if strides2 is not None:
        assert 2 * coeffs2.shape[1] == n
    if residual:
        assert out_width == in_width, (out_width, in_width)
    has_norm = gamma is not None
    grid = (B // block_rows,)

    row_spec = pl.BlockSpec((block_rows, n), lambda i: (i, 0))
    vec_spec = pl.BlockSpec((1, n), lambda i: (0, 0))

    def _cf_spec(L):
        return pl.BlockSpec((L, n // 2, 4), lambda i: (0, 0, 0))

    operands, in_specs = [x], [row_spec]
    if has_norm:
        operands.append(gamma.reshape(1, n))
        in_specs.append(vec_spec)
    operands += [coeffs1, d_in1.reshape(1, n), d_out1.reshape(1, n)]
    in_specs += [_cf_spec(L1), vec_spec, vec_spec]
    if bias1 is not None:
        operands.append(bias1.reshape(1, n))
        in_specs.append(vec_spec)
    if strides2 is not None:
        operands += [coeffs2, d_in2.reshape(1, n), d_out2.reshape(1, n)]
        in_specs += [_cf_spec(coeffs2.shape[0]), vec_spec, vec_spec]
        if bias2 is not None:
            operands.append(bias2.reshape(1, n))
            in_specs.append(vec_spec)

    out_specs = [row_spec]
    out_shape = [jax.ShapeDtypeStruct((B, out_width), x.dtype)]
    if has_norm:
        out_specs.append(pl.BlockSpec((block_rows, 1), lambda i: (i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((B, 1), jnp.float32))

    kernel = functools.partial(
        _block_kernel, strides1=strides1, strides2=strides2,
        activation=activation, has_norm=has_norm,
        has_bias1=bias1 is not None, has_bias2=bias2 is not None,
        residual=residual, in_width=in_width, mid_width=mid_width,
        out_width=out_width, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs if has_norm else out_specs[0],
        out_shape=out_shape if has_norm else out_shape[0],
        interpret=interpret,
    )(*operands)
    return out if has_norm else (out,)


@functools.partial(jax.jit, static_argnames=(
    "strides1", "strides2", "activation", "block_rows", "residual",
    "in_width", "mid_width", "out_width", "interpret"))
def spm_block_bwd_kernel_call(x: jax.Array, gy: jax.Array,
                              coeffs1: jax.Array,
                              d_in1: jax.Array, d_out1: jax.Array,
                              bias1: Optional[jax.Array] = None,
                              gamma: Optional[jax.Array] = None,
                              rstd: Optional[jax.Array] = None,
                              coeffs2: Optional[jax.Array] = None,
                              d_in2: Optional[jax.Array] = None,
                              d_out2: Optional[jax.Array] = None,
                              bias2: Optional[jax.Array] = None, *,
                              strides1: Tuple[int, ...],
                              strides2: Optional[Tuple[int, ...]] = None,
                              activation: Optional[str] = None,
                              block_rows: int,
                              residual: bool = False,
                              in_width: int, mid_width: int,
                              out_width: int,
                              interpret: bool = False):
    """Residual-block megakernel backward: ONE pallas_call from the raw
    saved x and the (B, 1) row statistics — the normalized input, both
    stacks' stage inputs, and the mid activation are all rematted in
    VMEM (never stored by the forward), then one reverse walk emits
    every grad closed-form.  ``bias1``/``bias2`` are needed as INPUTS
    (the rematted pre-activation includes them); ``rstd`` is required
    iff ``gamma`` is given.

    Returns ``(g_x (B, in_width), [g_gamma (n,)], g_coeffs1, g_din1,
    g_dout1, [g_bias1], [g_coeffs2, g_din2, g_dout2, [g_bias2]])`` —
    bracketed entries present when the matching operand was.  All
    parameter grads are f32, exactly zero on padded lanes.
    """
    B = x.shape[0]
    L1, n = coeffs1.shape[0], 2 * coeffs1.shape[1]
    assert x.shape[-1] == in_width and gy.shape[-1] == out_width
    assert B % block_rows == 0
    has_norm = gamma is not None
    assert has_norm == (rstd is not None)
    grid = (B // block_rows,)

    row_spec = pl.BlockSpec((block_rows, n), lambda i: (i, 0))
    vec_spec = pl.BlockSpec((1, n), lambda i: (0, 0))
    rs_spec = pl.BlockSpec((block_rows, 1), lambda i: (i, 0))

    def _cf_spec(L):
        return pl.BlockSpec((L, n // 2, 4), lambda i: (0, 0, 0))

    operands, in_specs = [x], [row_spec]
    if has_norm:
        operands += [gamma.reshape(1, n), rstd.astype(jnp.float32)]
        in_specs += [vec_spec, rs_spec]
    operands += [coeffs1, d_in1.reshape(1, n), d_out1.reshape(1, n)]
    in_specs += [_cf_spec(L1), vec_spec, vec_spec]
    if bias1 is not None:
        operands.append(bias1.reshape(1, n))
        in_specs.append(vec_spec)
    if strides2 is not None:
        operands += [coeffs2, d_in2.reshape(1, n), d_out2.reshape(1, n)]
        in_specs += [_cf_spec(coeffs2.shape[0]), vec_spec, vec_spec]
        if bias2 is not None:
            operands.append(bias2.reshape(1, n))
            in_specs.append(vec_spec)
    operands.append(gy)
    in_specs.append(row_spec)

    # g_x first, then parameter grads (all indexed to block 0 — the 1-D
    # row grid revisits them every iteration, accumulation-safe)
    out_specs = [row_spec]
    out_shape = [jax.ShapeDtypeStruct((B, in_width), x.dtype)]

    def _vec_out():
        out_specs.append(vec_spec)
        out_shape.append(jax.ShapeDtypeStruct((1, n), jnp.float32))

    if has_norm:
        _vec_out()                                 # g_gamma
    out_specs.append(_cf_spec(L1))
    out_shape.append(jax.ShapeDtypeStruct((L1, n // 2, 4), jnp.float32))
    _vec_out()                                     # g_din1
    _vec_out()                                     # g_dout1
    if bias1 is not None:
        _vec_out()
    if strides2 is not None:
        L2 = coeffs2.shape[0]
        out_specs.append(_cf_spec(L2))
        out_shape.append(jax.ShapeDtypeStruct((L2, n // 2, 4),
                                              jnp.float32))
        _vec_out()                                 # g_din2
        _vec_out()                                 # g_dout2
        if bias2 is not None:
            _vec_out()

    kernel = functools.partial(
        _block_bwd_kernel, strides1=strides1, strides2=strides2,
        activation=activation, has_norm=has_norm,
        has_bias1=bias1 is not None, has_bias2=bias2 is not None,
        residual=residual, in_width=in_width, mid_width=mid_width,
        out_width=out_width)
    out = list(pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands))
    # flatten the (1, n) vector grads to (n,); cf grads (ndim 3) stay
    return (out[0],) + tuple(v.reshape(n) if v.ndim == 2 else v
                             for v in out[1:])


# ---------------------------------------------------------------------------
# overlap (RDMA) kernels: fused {local run -> cross exchange -> 2x2 mix}
# ---------------------------------------------------------------------------
#
# The distributed executor's cross stages were one full-slab ppermute each:
# the whole (rows, n_local) slab had to finish its local kernel run before
# a single byte moved, so the ICI time was fully exposed.  These kernels
# restructure one {shard-local run -> cross stage} pair into a row-block
# pipeline INSIDE one pallas_call: the grid walks row blocks, block i's
# partner-half remote copy (pltpu.make_async_remote_copy over the mesh)
# starts the moment its local mix finishes, and the cross 2x2 mix is the
# receiving epilogue of iteration i+1 — so block i's exchange flies while
# block i+1 computes, double-buffered through two VMEM send/recv slots.
#
# Roles are resolved OUTSIDE the kernel: the shard body passes
# (mix_a, mix_b) with y = mix_a * z + mix_b * z_partner — (a, b) on the
# low partner, (d, c) on the high — so the kernel is role-free and the
# same program runs SPMD on every shard.  The partner's mesh coordinates
# arrive via scalar prefetch (they depend on jax.lax.axis_index, traced
# inside shard_map).
#
# Flow control (per slot s = i % 2):
#   * send side: before reusing slot s at iteration i >= 2, wait for our
#     own send from s to drain (wait_send) AND for one CREDIT — the
#     partner signals our capacity semaphore after consuming the block we
#     previously landed in ITS recv slot s, so a fast sender can never
#     overwrite an unconsumed remote buffer;
#   * recv side: iteration i consumes block i-1 (wait_recv on slot
#     (i-1) % 2), applies the mix epilogue, stores, and signals the credit.
#   * epilogue (iteration n_blocks): drain the last two sends and the two
#     unconsumed credits so every semaphore ends at zero.
#
# The BACKWARD kernel replays the same pipeline in reverse roles: the
# partner exchange is its own transpose, so each block SENDS the
# (delta, z_out) package — z_out rematerialized in VMEM from the local
# run's saved input (the forward never wrote it to HBM) — and the
# receiving iteration applies the transpose mix
# delta_mid = u * delta + v * delta_partner as its PROLOGUE, accumulates
# the role-owned cross-coefficient sums (s_own = sum delta*z_out,
# s_swp = sum delta*z_partner), then walks the local stages in reverse
# (shared _stage_walk_bwd).  The local forward runs twice per block (once
# for the send-side remat, once collecting stage inputs for the walk) —
# deliberate: the recompute is exactly the VPU work the in-flight
# exchange hides under, and it keeps the VMEM working set at one block.
#
# There is NO interpret realization of make_async_remote_copy, so these
# kernels are TPU-compile-only (core/eligibility.resolve_rdma); the
# per-block ppermute transport in parallel/spm_shard.py runs the identical
# schedule everywhere else and is what the parity tests exercise.

def _partner_device_id(partner_ref, mesh_ndim: int):
    """The partner's mesh-coordinate ``device_id`` tuple, read from the
    scalar-prefetch ref — the ONE encoding shared by the remote-copy
    descriptors and the credit-semaphore signals."""
    return tuple(partner_ref[a] for a in range(mesh_ndim))


def _rdma_descriptor(send_buf, recv_buf, send_sem, recv_sem, slot,
                     partner_ref, mesh_ndim: int):
    """The slot's remote-copy descriptor (reconstructed each iteration —
    start/wait are semaphore ops on the same (src, dst, sems, size)
    tuple)."""
    return pltpu.make_async_remote_copy(
        send_buf.at[slot], recv_buf.at[slot],
        send_sem.at[slot], recv_sem.at[slot],
        device_id=_partner_device_id(partner_ref, mesh_ndim),
        device_id_type=pltpu.DeviceIdType.MESH)


def _slot_reuse_guard(rdma, cap_sem, slot, i):
    """Flow control before reusing slot ``i % 2`` at iteration ``i >= 2``:
    our own send from this slot must have drained AND the partner must
    have consumed the block we previously landed in ITS recv slot (one
    credit).  Shared by the forward and backward overlap kernels — the
    protocol must never desynchronize between them."""
    @pl.when(i >= 2)
    def _():
        rdma(slot).wait_send()
        pltpu.semaphore_wait(cap_sem, 1)


def _drain_epilogue(rdma, cap_sem, n_blocks: int):
    """Final-iteration drain: the last two sends were never waited on and
    the partner's last (up to two) credits never consumed — retire them
    so every semaphore ends the kernel at zero.  Shared by both overlap
    kernels."""
    rdma(jax.lax.rem(n_blocks - 1, 2)).wait_send()
    if n_blocks >= 2:
        rdma(jax.lax.rem(n_blocks - 2, 2)).wait_send()
    pltpu.semaphore_wait(cap_sem, min(2, n_blocks))


def _overlap_kernel(partner_ref, base_ref, *refs,
                    strides: Tuple[int, ...], n_blocks: int,
                    mesh_ndim: int, has_din: bool, has_dout: bool,
                    has_bias: bool, in_width: Optional[int],
                    quant_cf: bool = False):
    refs = list(refs)
    x_ref, cf_ref = refs.pop(0), refs.pop(0)
    scf_ref = refs.pop(0) if quant_cf else None
    ma_ref, mb_ref = refs.pop(0), refs.pop(0)
    din_ref = refs.pop(0) if has_din else None
    dout_ref = refs.pop(0) if has_dout else None
    bias_ref = refs.pop(0) if has_bias else None
    o_ref, send_buf, recv_buf, send_sem, recv_sem, cap_sem = refs

    i = pl.program_id(0)

    def _rdma(slot):
        return _rdma_descriptor(send_buf, recv_buf, send_sem, recv_sem,
                                slot, partner_ref, mesh_ndim)

    @pl.when(i < n_blocks)
    def _compute_send():
        slot = jax.lax.rem(i, 2)
        _slot_reuse_guard(_rdma, cap_sem, slot, i)

        z = x_ref[...].astype(_F32)
        if in_width is not None:
            z = _mask_cols(z, base_ref[0], in_width)
        if has_din:
            z = z * din_ref[...].astype(_F32)
        z = _apply_stages_fwd(z, cf_ref, strides, scf_ref=scf_ref)
        send_buf[slot] = z.astype(send_buf.dtype)
        _rdma(slot).start()

    @pl.when(i > 0)
    def _recv_mix():
        slot = jax.lax.rem(i - 1, 2)
        _rdma(slot).wait_recv()
        zm = send_buf[slot].astype(_F32)
        zp = recv_buf[slot].astype(_F32)
        y = ma_ref[...].astype(_F32) * zm + mb_ref[...].astype(_F32) * zp
        if has_dout:
            # operator-boundary fold, scale-ON-STORE: d_out multiplies
            # the mixed result AFTER the add — bitwise the unfolded
            # post-stack elementwise op, which elastic re-sharding
            # depends on (see parallel/spm_shard._cross_mix)
            y = y * dout_ref[...].astype(_F32)
        if has_bias:
            y = y + bias_ref[...].astype(_F32)
        o_ref[...] = y.astype(o_ref.dtype)
        pltpu.semaphore_signal(cap_sem, inc=1,
                               device_id=_partner_device_id(partner_ref,
                                                            mesh_ndim),
                               device_id_type=pltpu.DeviceIdType.MESH)

    @pl.when(i == n_blocks)
    def _drain():
        _drain_epilogue(_rdma, cap_sem, n_blocks)


@functools.partial(jax.jit, static_argnames=("strides", "block_rows",
                                             "n_tile", "in_width",
                                             "collective_id", "interpret"))
def spm_overlap_kernel_call(x: jax.Array, coeffs: jax.Array,
                            mix_a: jax.Array, mix_b: jax.Array,
                            partner: jax.Array,
                            d_in: Optional[jax.Array] = None,
                            d_out: Optional[jax.Array] = None,
                            bias: Optional[jax.Array] = None,
                            col_base: Optional[jax.Array] = None,
                            coeff_scale: Optional[jax.Array] = None, *,
                            strides: Tuple[int, ...],
                            block_rows: int,
                            n_tile: int,
                            in_width: Optional[int] = None,
                            collective_id: int = 0,
                            interpret: bool = False) -> jax.Array:
    """Fused {local run -> cross exchange -> mix epilogue} forward.

    x: (B, n_tile) shard slab — or, windowed (``col_base`` + ``in_width``,
    both GLOBAL as in ``spm_stack_kernel_call``), the feature-complete
    (B, in_width) operand.  coeffs: (L, n_tile//2, 4) local-run stages;
    mix_a / mix_b: (n_tile,) role-resolved cross coefficients
    (y = mix_a * z + mix_b * z_partner); partner: (mesh_ndim,) int32
    logical mesh coordinates of the XOR partner (scalar prefetch);
    optional d_in: (n_tile,) this shard's diagonal slice, folded before
    the first stage; optional d_out / bias: (n_tile,) this shard's
    output-boundary slices, applied by the mix epilogue when the schedule
    ENDS on this cross stage — d_out scales the mixed result AFTER the
    add (scale-on-store, bitwise the unfolded post-stack op) and bias
    follows.  Pipelines ``B // block_rows`` row blocks with
    double-buffered VMEM send/recv slots (budgeted by
    ``overlap_vmem_bytes``); returns the mixed (B, n_tile) slab.

    TPU-compile-only: ``make_async_remote_copy`` has no interpret
    realization (``core/eligibility.resolve_rdma`` gates engagement).
    """
    assert not interpret, "RDMA overlap kernel has no interpret mode"
    B = x.shape[0]
    L = coeffs.shape[0]
    assert 2 * coeffs.shape[1] == n_tile
    assert B % block_rows == 0
    nb = B // block_rows
    mesh_ndim = partner.shape[0]
    io_dt = x.dtype
    base = (col_base.astype(jnp.int32) if col_base is not None
            else jnp.zeros((1,), jnp.int32))

    nbm1 = nb - 1
    x_spec = pl.BlockSpec(
        (block_rows, n_tile),
        lambda i, p, b: (jnp.minimum(i, nbm1),
                         b[0] if in_width is not None else 0))
    cf_spec = pl.BlockSpec((L, n_tile // 2, 4), lambda i, p, b: (0, 0, 0))
    vec_spec = pl.BlockSpec((1, n_tile), lambda i, p, b: (0, 0))
    o_spec = pl.BlockSpec((block_rows, n_tile),
                          lambda i, p, b: (jnp.maximum(i - 1, 0), 0))

    operands = [x, coeffs]
    in_specs = [x_spec, cf_spec]
    if coeff_scale is not None:
        operands.append(coeff_scale.astype(jnp.float32).reshape(L, 1))
        in_specs.append(pl.BlockSpec((L, 1), lambda i, p, b: (0, 0)))
    operands += [mix_a.reshape(1, n_tile), mix_b.reshape(1, n_tile)]
    in_specs += [vec_spec, vec_spec]
    if d_in is not None:
        operands.append(d_in.reshape(1, n_tile))
        in_specs.append(vec_spec)
    if d_out is not None:
        operands.append(d_out.reshape(1, n_tile))
        in_specs.append(vec_spec)
    if bias is not None:
        operands.append(bias.reshape(1, n_tile))
        in_specs.append(vec_spec)

    kernel = functools.partial(_overlap_kernel, strides=strides,
                               n_blocks=nb, mesh_ndim=mesh_ndim,
                               has_din=d_in is not None,
                               has_dout=d_out is not None,
                               has_bias=bias is not None,
                               in_width=in_width,
                               quant_cf=coeff_scale is not None)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=(nb + 1,),
            in_specs=in_specs, out_specs=o_spec,
            scratch_shapes=[
                pltpu.VMEM((2, block_rows, n_tile), io_dt),   # send slots
                pltpu.VMEM((2, block_rows, n_tile), io_dt),   # recv slots
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SemaphoreType.REGULAR,                  # credits
            ]),
        out_shape=jax.ShapeDtypeStruct((B, n_tile), io_dt),
        compiler_params=pltpu.TPUCompilerParams(
            collective_id=collective_id),
    )(partner.astype(jnp.int32), base, *operands)


def _overlap_bwd_kernel(partner_ref, base_ref, *refs,
                        strides: Tuple[int, ...], n_blocks: int,
                        mesh_ndim: int, has_din: bool, has_dout: bool,
                        in_width: Optional[int], quant_cf: bool = False):
    refs = list(refs)
    x_ref, xw_ref, cf_ref = refs.pop(0), refs.pop(0), refs.pop(0)
    scf_ref = refs.pop(0) if quant_cf else None
    gy_ref = refs.pop(0)
    u_ref, v_ref = refs.pop(0), refs.pop(0)
    din_ref = refs.pop(0) if has_din else None
    # folded-boundary mode (schedule ends on this cross stage): the raw
    # gy streams through a SECOND walk-side window (block i-1, like x),
    # and this shard's d_out slab pre-scales the delta it SENDS
    gyw_ref = refs.pop(0) if has_dout else None
    dout_ref = refs.pop(0) if has_dout else None
    gx_ref, gcf_ref, gso_ref, gsw_ref = (refs.pop(0), refs.pop(0),
                                         refs.pop(0), refs.pop(0))
    gto_ref = refs.pop(0) if has_dout else None
    gtw_ref = refs.pop(0) if has_dout else None
    gdin_ref = refs.pop(0) if has_din else None
    send_buf, recv_buf, send_sem, recv_sem, cap_sem = refs

    i = pl.program_id(0)
    bb, nt = gy_ref.shape

    def _rdma(slot):
        return _rdma_descriptor(send_buf, recv_buf, send_sem, recv_sem,
                                slot, partner_ref, mesh_ndim)

    def _masked(xr):
        z = xr[...].astype(_F32)
        if in_width is not None:
            z = _mask_cols(z, base_ref[0], in_width)
        return z

    @pl.when(i < n_blocks)
    def _remat_send():
        slot = jax.lax.rem(i, 2)
        _slot_reuse_guard(_rdma, cap_sem, slot, i)

        z = _masked(x_ref)
        if has_din:
            z = z * din_ref[...].astype(_F32)
        z_out = _apply_stages_fwd(z, cf_ref, strides, scf_ref=scf_ref)
        if has_dout:
            # scale-before-exchange: each shard scales its OWN cotangent
            # by its OWN d_out slab, so the partner's delta arrives
            # correctly scaled without ever shipping the remote slab
            g = gy_ref[...].astype(_F32) * dout_ref[...].astype(_F32)
            send_buf[slot, 0] = g.astype(send_buf.dtype)
        else:
            send_buf[slot, 0] = gy_ref[...].astype(send_buf.dtype)
        send_buf[slot, 1] = z_out.astype(send_buf.dtype)
        _rdma(slot).start()

    @pl.when(i > 0)
    def _consume():
        slot = jax.lax.rem(i - 1, 2)
        _rdma(slot).wait_recv()
        delta = send_buf[slot, 0].astype(_F32)     # own block i-1 cotangent
        z_out = send_buf[slot, 1].astype(_F32)     # own remat z_out
        delta_p = recv_buf[slot, 0].astype(_F32)
        zp = recv_buf[slot, 1].astype(_F32)

        def _acc(ref, tile):
            @pl.when(i == 1)
            def _init():
                ref[...] = tile

            @pl.when(i > 1)
            def _add():
                ref[...] += tile

        # role-owned cross-coefficient sums (slot placement by the caller)
        _acc(gso_ref, jnp.sum(delta * z_out, axis=0).reshape(1, nt))
        _acc(gsw_ref, jnp.sum(delta * zp, axis=0).reshape(1, nt))
        if has_dout:
            # raw-cotangent sums for the folded d_out grad: g_dout =
            # mix_a*t_own + mix_b*t_swp outside the kernel (exact — no
            # division remat).  The packaged delta is pre-scaled, so the
            # raw gy comes from its own walk-side window.
            gy_raw = gyw_ref[...].astype(_F32)
            _acc(gto_ref, jnp.sum(gy_raw * z_out, axis=0).reshape(1, nt))
            _acc(gtw_ref, jnp.sum(gy_raw * zp, axis=0).reshape(1, nt))
        # transpose-mix prologue, then the local stage walk (collect remat)
        dmid = (u_ref[...].astype(_F32) * delta
                + v_ref[...].astype(_F32) * delta_p)
        x_raw = _masked(xw_ref)
        z0 = x_raw * din_ref[...].astype(_F32) if has_din else x_raw
        _, zs = _apply_stages_fwd(z0, cf_ref, strides, collect=True,
                                  scf_ref=scf_ref)
        delta0, gcf = _stage_walk_bwd(zs, dmid, cf_ref, strides,
                                      scf_ref=scf_ref)
        _acc(gcf_ref, gcf)
        if has_din:
            _acc(gdin_ref, jnp.sum(delta0 * x_raw, axis=0).reshape(1, nt))
            delta0 = delta0 * din_ref[...].astype(_F32)
        gx_ref[...] = delta0.astype(gx_ref.dtype)
        pltpu.semaphore_signal(cap_sem, inc=1,
                               device_id=_partner_device_id(partner_ref,
                                                            mesh_ndim),
                               device_id_type=pltpu.DeviceIdType.MESH)

    @pl.when(i == n_blocks)
    def _drain():
        _drain_epilogue(_rdma, cap_sem, n_blocks)


@functools.partial(jax.jit, static_argnames=("strides", "block_rows",
                                             "n_tile", "in_width",
                                             "collective_id", "interpret"))
def spm_overlap_bwd_kernel_call(x: jax.Array, coeffs: jax.Array,
                                gy: jax.Array,
                                u: jax.Array, v: jax.Array,
                                partner: jax.Array,
                                d_in: Optional[jax.Array] = None,
                                d_out: Optional[jax.Array] = None,
                                col_base: Optional[jax.Array] = None,
                                coeff_scale: Optional[jax.Array] = None, *,
                                strides: Tuple[int, ...],
                                block_rows: int,
                                n_tile: int,
                                in_width: Optional[int] = None,
                                collective_id: int = 1,
                                interpret: bool = False):
    """Fused backward of one {local run -> cross stage} pair from the
    LOCAL step's saved input.

    x: the local run's input — the (B, n_tile) slab, or the windowed
    feature-complete (B, in_width) operand (``col_base``); gy: (B, n_tile)
    post-cross cotangent slab; u / v: (n_tile,) role-resolved transpose
    mix (delta_mid = u * delta + v * delta_partner — (a, c) low,
    (d, b) high); partner: (mesh_ndim,) int32 mesh coordinates.  Each row
    block SENDS its (delta, remat z_out) package — the partner exchange
    is its own transpose — and the receiving iteration applies the
    transpose mix, accumulates the role-owned cross sums, and walks the
    local stages in reverse.

    Returns ``(g_x (B, n_tile), g_coeffs (L, n_tile//2, 4) f32,
    s_own (n_tile,), s_swp (n_tile,)[, g_din (n_tile,)]
    [, t_own (n_tile,), t_swp (n_tile,)])`` with
    s_own = sum_B delta * z_out and s_swp = sum_B delta * z_partner — the
    caller places them into the (a, b) / (c, d) slots by role.

    ``d_out`` engages the folded-boundary mode (the schedule ENDS on
    this cross stage — _pair_rdma_fwd folded d_out/bias into the mix
    epilogue): each block's SENT delta is pre-scaled by the shard's own
    d_out slab in VMEM (u/v stay the raw transpose-mix vectors), and two
    extra raw-cotangent sums t_own = sum_B gy * z_out / t_swp =
    sum_B gy * z_partner come back for the caller's exact
    ``g_dout = mix_a * t_own + mix_b * t_swp``.  TPU-only, like the
    forward."""
    assert not interpret, "RDMA overlap kernel has no interpret mode"
    B = gy.shape[0]
    L = coeffs.shape[0]
    assert 2 * coeffs.shape[1] == n_tile
    assert B % block_rows == 0
    nb = B // block_rows
    mesh_ndim = partner.shape[0]
    io_dt = gy.dtype
    base = (col_base.astype(jnp.int32) if col_base is not None
            else jnp.zeros((1,), jnp.int32))

    nbm1 = nb - 1
    x_col = (lambda b: b[0]) if in_width is not None else (lambda b: 0)
    x_send_spec = pl.BlockSpec(
        (block_rows, n_tile),
        lambda i, p, b: (jnp.minimum(i, nbm1), x_col(b)))
    x_walk_spec = pl.BlockSpec(
        (block_rows, n_tile),
        lambda i, p, b: (jnp.maximum(i - 1, 0), x_col(b)))
    gy_spec = pl.BlockSpec((block_rows, n_tile),
                           lambda i, p, b: (jnp.minimum(i, nbm1), 0))
    cf_spec = pl.BlockSpec((L, n_tile // 2, 4), lambda i, p, b: (0, 0, 0))
    vec_spec = pl.BlockSpec((1, n_tile), lambda i, p, b: (0, 0))
    gx_spec = pl.BlockSpec((block_rows, n_tile),
                           lambda i, p, b: (jnp.maximum(i - 1, 0), 0))

    operands = [x, x, coeffs]
    in_specs = [x_send_spec, x_walk_spec, cf_spec]
    if coeff_scale is not None:
        operands.append(coeff_scale.astype(jnp.float32).reshape(L, 1))
        in_specs.append(pl.BlockSpec((L, 1), lambda i, p, b: (0, 0)))
    operands += [gy, u.reshape(1, n_tile), v.reshape(1, n_tile)]
    in_specs += [gy_spec, vec_spec, vec_spec]
    if d_in is not None:
        operands.append(d_in.reshape(1, n_tile))
        in_specs.append(vec_spec)
    if d_out is not None:
        # folded-boundary mode: raw gy through a walk-side window
        # (block i-1, like x_walk_spec) + this shard's d_out slab
        gyw_spec = pl.BlockSpec((block_rows, n_tile),
                                lambda i, p, b: (jnp.maximum(i - 1, 0), 0))
        operands += [gy, d_out.reshape(1, n_tile)]
        in_specs += [gyw_spec, vec_spec]

    out_specs = [gx_spec, cf_spec, vec_spec, vec_spec]
    out_shape = [jax.ShapeDtypeStruct((B, n_tile), io_dt),
                 jax.ShapeDtypeStruct((L, n_tile // 2, 4), jnp.float32),
                 jax.ShapeDtypeStruct((1, n_tile), jnp.float32),
                 jax.ShapeDtypeStruct((1, n_tile), jnp.float32)]
    if d_out is not None:
        out_specs += [vec_spec, vec_spec]          # t_own, t_swp
        out_shape += [jax.ShapeDtypeStruct((1, n_tile), jnp.float32),
                      jax.ShapeDtypeStruct((1, n_tile), jnp.float32)]
    if d_in is not None:
        out_specs.append(vec_spec)
        out_shape.append(jax.ShapeDtypeStruct((1, n_tile), jnp.float32))

    kernel = functools.partial(_overlap_bwd_kernel, strides=strides,
                               n_blocks=nb, mesh_ndim=mesh_ndim,
                               has_din=d_in is not None,
                               has_dout=d_out is not None,
                               in_width=in_width,
                               quant_cf=coeff_scale is not None)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=(nb + 1,),
            in_specs=in_specs, out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((2, 2, block_rows, n_tile), io_dt),  # send slots
                pltpu.VMEM((2, 2, block_rows, n_tile), io_dt),  # recv slots
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SemaphoreType.DMA((2,)),
                pltpu.SemaphoreType.REGULAR,                    # credits
            ]),
        out_shape=out_shape,
        compiler_params=pltpu.TPUCompilerParams(
            collective_id=collective_id),
    )(partner.astype(jnp.int32), base, *operands)
    gx, gcf, s_own, s_swp = out[0], out[1], out[2], out[3]
    res = (gx, gcf, s_own.reshape(n_tile), s_swp.reshape(n_tile))
    rest = list(out[4:])
    t_pair = ()
    if d_out is not None:
        t_pair = (rest.pop(0).reshape(n_tile), rest.pop(0).reshape(n_tile))
    if d_in is not None:
        res = res + (rest.pop(0).reshape(n_tile),)
    return res + t_pair
