"""Fused L-stage SPM kernel (Pallas / TPU).

Why a kernel (DESIGN.md §3.2): SPM has arithmetic intensity ~O(L) FLOP/byte
(vs ~n/2 for a dense matmul), far below the TPU v5e balance point
(~240 FLOP/byte @ 197 TFLOP/s bf16 / 819 GB/s HBM), so SPM is memory-bound by
construction.  Lowering each stage separately costs L+1 HBM round-trips of
the full activation; this kernel keeps an activation tile resident in VMEM
and applies ALL stages before writing back — one read + one write, an
(L+1)/2x reduction of the memory-roofline term.

Layout notes (TPU-native adaptation of the paper's CPU loop):
  * The feature axis rides the 128-wide lane dimension; batch rides sublanes.
  * A stride-s stage is the relayout (bb, n) -> (bb, g, 2, s) + vectorized
    2x2 FMA on the VPU (the MXU would be >97% idle at k=2, so we stay off it).
  * Stages with s >= 128 are lane-aligned relayouts (free-ish).  Stages with
    s < 128 induce intra-lane shuffles; the benchmark harness quantifies the
    residual cost and the two_level schedule orders them first so they fuse
    while the tile is hot.
  * Grid tiles: (batch_tile, feature_tile).  A feature tile of width n_t can
    fuse every stage with n_t % (2 s) == 0 (pair stays inside the tile);
    ops.py splits the schedule into maximal tile-local runs and composes.

Validated in interpret mode on CPU against kernels/ref.py (this container
has no TPU); the BlockSpec tiling is sized for v5e VMEM (~16 MiB budget).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["spm_stack_kernel_call", "spm_stack_bwd_kernel_call",
           "pick_block_rows", "vmem_bytes"]

_F32 = jnp.float32


def _kernel(x_ref, cf_ref, o_ref, *, strides: Tuple[int, ...]):
    """Kernel body: x_ref (bb, nt), cf_ref (L, nt//2, 4), o_ref (bb, nt)."""
    z = x_ref[...].astype(_F32)
    bb, nt = z.shape
    for ell, s in enumerate(strides):
        g = nt // (2 * s)
        zr = z.reshape(bb, g, 2, s)
        cf = cf_ref[ell].astype(_F32)          # (nt//2, 4)
        a = cf[:, 0].reshape(g, 1, s)
        b = cf[:, 1].reshape(g, 1, s)
        c = cf[:, 2].reshape(g, 1, s)
        d = cf[:, 3].reshape(g, 1, s)
        x0 = zr[:, :, 0, :].reshape(bb, g, 1, s)
        x1 = zr[:, :, 1, :].reshape(bb, g, 1, s)
        y0 = a * x0 + b * x1
        y1 = c * x0 + d * x1
        z = jnp.concatenate([y0, y1], axis=2).reshape(bb, nt)
    o_ref[...] = z.astype(o_ref.dtype)


def vmem_bytes(block_rows: int, n_tile: int, n_stages: int,
               dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set: in + out tiles (f32 compute copy) + coeffs."""
    act = 2 * block_rows * n_tile * 4          # f32 compute copies
    io = 2 * block_rows * n_tile * dtype_bytes
    cf = n_stages * (n_tile // 2) * 4 * 4
    return act + io + cf


def pick_block_rows(n_tile: int, n_stages: int, dtype_bytes: int = 4,
                    budget: int = 12 * 2**20) -> int:
    """Largest power-of-two row-block (>=8) within the VMEM budget."""
    bb = 8
    while bb < 1024 and vmem_bytes(bb * 2, n_tile, n_stages,
                                   dtype_bytes) <= budget:
        bb *= 2
    return bb


@functools.partial(jax.jit, static_argnames=("strides", "block_rows",
                                             "n_tile", "interpret"))
def spm_stack_kernel_call(x: jax.Array, coeffs: jax.Array, *,
                          strides: Tuple[int, ...],
                          block_rows: int,
                          n_tile: int,
                          interpret: bool = False) -> jax.Array:
    """pallas_call wrapper.  x: (B, n); coeffs: (L, n//2, 4).

    Requires: B % block_rows == 0, n % n_tile == 0, and every stride s
    satisfies n_tile % (2*s) == 0 (pairs tile-local).  ops.py guarantees
    these by padding/splitting; this function is the raw kernel entry.
    """
    B, n = x.shape
    L = coeffs.shape[0]
    assert B % block_rows == 0 and n % n_tile == 0
    for s in strides:
        assert n_tile % (2 * s) == 0, (s, n_tile)
    grid = (B // block_rows, n // n_tile)

    # Pair indices for feature tile j are the contiguous slab
    # [j * n_tile/2, (j+1) * n_tile/2): groups are sequential in the flat
    # pair index, and each tile covers whole groups for every fused stride.
    x_spec = pl.BlockSpec((block_rows, n_tile), lambda i, j: (i, j))
    cf_spec = pl.BlockSpec((L, n_tile // 2, 4), lambda i, j: (0, j, 0))
    o_spec = pl.BlockSpec((block_rows, n_tile), lambda i, j: (i, j))

    return pl.pallas_call(
        functools.partial(_kernel, strides=strides),
        grid=grid,
        in_specs=[x_spec, cf_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B, n), x.dtype),
        interpret=interpret,
    )(x, coeffs)


# ---------------------------------------------------------------------------
# fused backward kernel
# ---------------------------------------------------------------------------
#
# Training is 2/3 backward; without a fused backward the forward fusion win
# is capped at 1.5x end-to-end.  The backward kernel recomputes the stage
# inputs IN VMEM from the x tile (no HBM traffic for intermediates — the
# Pallas analogue of remat), then walks the stages in reverse applying the
# paper's closed forms: delta <- B_l^T delta (eqs. 12-13) and the rank-1 pair
# accumulations for (a, b, c, d) grads (eq. 14).  Coefficient-gradient
# partials are accumulated across batch tiles in the output block itself
# (grid iterates feature-minor, so for a fixed feature tile the batch index
# is the slow axis: init at i == 0, accumulate after).

def _bwd_kernel(x_ref, cf_ref, gy_ref, gx_ref, gcf_ref, *,
                strides: Tuple[int, ...]):
    bb, nt = x_ref.shape
    L = len(strides)

    # recompute stage inputs in VMEM (forward remat)
    zs = []
    z = x_ref[...].astype(_F32)
    for ell, s in enumerate(strides):
        zs.append(z)
        g = nt // (2 * s)
        zr = z.reshape(bb, g, 2, s)
        cf = cf_ref[ell].astype(_F32)
        a = cf[:, 0].reshape(g, 1, s)
        b = cf[:, 1].reshape(g, 1, s)
        c = cf[:, 2].reshape(g, 1, s)
        d = cf[:, 3].reshape(g, 1, s)
        x0 = zr[:, :, 0, :].reshape(bb, g, 1, s)
        x1 = zr[:, :, 1, :].reshape(bb, g, 1, s)
        z = jnp.concatenate([a * x0 + b * x1, c * x0 + d * x1],
                            axis=2).reshape(bb, nt)

    delta = gy_ref[...].astype(_F32)
    gcf_parts = []
    for ell in range(L - 1, -1, -1):
        s = strides[ell]
        g = nt // (2 * s)
        cf = cf_ref[ell].astype(_F32)
        a = cf[:, 0].reshape(g, 1, s)
        b = cf[:, 1].reshape(g, 1, s)
        c = cf[:, 2].reshape(g, 1, s)
        d = cf[:, 3].reshape(g, 1, s)
        zr = zs[ell].reshape(bb, g, 2, s)
        dr = delta.reshape(bb, g, 2, s)
        x0 = zr[:, :, 0, :].reshape(bb, g, 1, s)
        x1 = zr[:, :, 1, :].reshape(bb, g, 1, s)
        d0 = dr[:, :, 0, :].reshape(bb, g, 1, s)
        d1 = dr[:, :, 1, :].reshape(bb, g, 1, s)
        # eq. 14 pair grads, reduced over the batch-tile rows
        ga = jnp.sum(d0 * x0, axis=0).reshape(g * s)
        gb = jnp.sum(d0 * x1, axis=0).reshape(g * s)
        gc = jnp.sum(d1 * x0, axis=0).reshape(g * s)
        gd = jnp.sum(d1 * x1, axis=0).reshape(g * s)
        gcf_parts.append(jnp.stack([ga, gb, gc, gd], axis=-1))
        # eqs. 12-13: delta <- B^T delta
        delta = jnp.concatenate([a * d0 + c * d1, b * d0 + d * d1],
                                axis=2).reshape(bb, nt)

    gx_ref[...] = delta.astype(gx_ref.dtype)
    gcf_tile = jnp.stack(gcf_parts[::-1], axis=0)  # (L, nt//2, 4)

    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        gcf_ref[...] = gcf_tile

    @pl.when(i > 0)
    def _acc():
        gcf_ref[...] += gcf_tile


@functools.partial(jax.jit, static_argnames=("strides", "block_rows",
                                             "n_tile", "interpret"))
def spm_stack_bwd_kernel_call(x: jax.Array, coeffs: jax.Array,
                              gy: jax.Array, *,
                              strides: Tuple[int, ...],
                              block_rows: int,
                              n_tile: int,
                              interpret: bool = False):
    """Fused backward.  Returns (g_x (B, n), g_coeffs (L, n//2, 4) f32)."""
    B, n = x.shape
    L = coeffs.shape[0]
    assert B % block_rows == 0 and n % n_tile == 0
    grid = (B // block_rows, n // n_tile)
    x_spec = pl.BlockSpec((block_rows, n_tile), lambda i, j: (i, j))
    cf_spec = pl.BlockSpec((L, n_tile // 2, 4), lambda i, j: (0, j, 0))
    gy_spec = pl.BlockSpec((block_rows, n_tile), lambda i, j: (i, j))
    gx_spec = pl.BlockSpec((block_rows, n_tile), lambda i, j: (i, j))
    gcf_spec = pl.BlockSpec((L, n_tile // 2, 4), lambda i, j: (0, j, 0))
    return pl.pallas_call(
        functools.partial(_bwd_kernel, strides=strides),
        grid=grid,
        in_specs=[x_spec, cf_spec, gy_spec],
        out_specs=[gx_spec, gcf_spec],
        out_shape=[jax.ShapeDtypeStruct((B, n), x.dtype),
                   jax.ShapeDtypeStruct((L, n // 2, 4), jnp.float32)],
        interpret=interpret,
    )(x, coeffs, gy)
