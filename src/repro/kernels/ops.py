"""Public entry for the fused SPM operator kernel.

``spm_stack_fused(x, coeffs, strides, d_in=..., d_out=..., bias=...)``
applies the paper's COMPLETE operator

    y = D_out * (B_L ... B_1) * D_in * x + bias

to the last axis of ``x`` with:

  * **run planning** — the stride schedule is split into maximal consecutive
    *runs* such that every stride in a run keeps its pairs inside one feature
    tile (``n_tile % (2*s) == 0``).  Each run is one ``pallas_call`` that
    fuses all its stages in VMEM (DESIGN.md §3.2); run boundaries are the
    only HBM round-trips.
  * **boundary folding** — ``d_in`` is folded into the FIRST run and
    ``d_out``/``bias`` into the LAST run of the plan, so the diagonal
    multiplies and the bias add cost zero extra HBM round-trips: the full
    operator is 1 read + 1 write of the activation per run (a single
    round-trip total for schedules that plan to one run) instead of the
    L+4 round-trips of the per-stage composition with unfused diag/bias.
  * **custom_vjp over the full operator** — backward uses the fused backward
    kernel per run (paper §4 closed forms, recomputing stage inputs in
    VMEM); the boundary runs additionally emit the closed-form diag/bias
    grads (g_dout = sum gy*z_L, g_bias = sum gy, g_din = sum delta_0*x), so
    training gets the same one-read-one-write property as the forward.
    The rotation variant's ``theta -> (a, b, c, d)`` chain stays OUTSIDE the
    kernel: it is O(nL), not activation-sized, and plain autodiff composes
    with the coefficient cotangent this VJP returns.
  * **batch/tile padding** — leading dims are flattened; rows are padded to
    the row-block so arbitrary batch sizes work (padded rows carry zero
    cotangents, so the batch-summed parameter grads are unaffected).
  * **rectangular-native boundaries** — ``in_width`` / ``out_width`` declare
    the true I/O widths of a rectangular linear (d_in -> d_out around the
    square n-wide operator).  The FIRST run of the plan reads only the
    (…, in_width) input and zero-fills to n in VMEM (iota mask, no XLA
    ``jnp.pad``); the LAST run computes and stores only the ``out_width``
    output columns (shrunk forward grid + masked partial-tile store).  The
    custom_vjp hands the input cotangent back as (…, in_width), and the
    masked loads make padded lanes contribute exact zeros to the
    coefficient/diag/bias grads.  Interior intermediates stay n-wide.
  * **dead-tile-free backward** — the backward grid of the last run visits
    only ``ceil(out_width / n_tile)`` feature tiles (tiles fully past
    ``out_width`` have an all-zero masked cotangent, so every grad they
    produce is an exact zero); skipped parameter-grad / g_x blocks are
    zero-initialized via ``input_output_aliases``, and the resulting
    exactly-zero g_x tail lets every upstream run of a multi-run plan
    prune the same dead tiles (``dead_from``).
  * **bf16 I/O** — activations may be bf16; in-VMEM compute is f32 and all
    parameter grads are returned f32 (cast back to the param dtype here).

On CPU (this container) kernels run with ``interpret=True``; on TPU the
same BlockSpecs compile natively.  ``kernels/ref.py`` is the oracle.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.eligibility import (block_fusion_eligible,
                                    quant_acts_eligible, tiny_row_call)
from repro.kernels import spm_stack as K
from repro.kernels import quant as Q

__all__ = ["spm_stack_fused", "spm_stack_fused_q8", "spm_block_fused",
           "plan_runs", "plan_runs_for_rows", "tile_cap_for_rows",
           "pick_block_rows_for_plan", "default_interpret"]

MAX_TILE = 2048  # lane-dim tile cap: 16 VREG lanes x 128; VMEM-comfortable


def default_interpret() -> bool:
    """Whether pallas_call should run in interpret mode: True off-TPU
    (CPU/GPU validation), False on TPU (Mosaic compile)."""
    return jax.default_backend() != "tpu"


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


@functools.lru_cache(maxsize=None)
def plan_runs(n: int, strides: Tuple[int, ...],
              max_tile: int = MAX_TILE) -> Tuple[Tuple[Tuple[int, ...], int], ...]:
    """Split ``strides`` into runs of (strides, n_tile).

    Every stride s in a run satisfies ``n_tile % (2*s) == 0`` and
    ``n % n_tile == 0``.  Greedy: extend the current run while the lcm of
    pair spans stays within ``max_tile``; the tile is the largest multiple
    of that lcm that divides n and is <= max_tile (>= lcm always exists
    because the lcm of divisors of n divides n).
    """
    for s in strides:
        if n % (2 * s) != 0:
            raise ValueError(f"stride {s} invalid for n={n}")
    runs = []
    cur: list = []
    cur_lcm = 1

    def close():
        nonlocal cur, cur_lcm
        if not cur:
            return
        # largest multiple of cur_lcm dividing n, capped at max_tile
        tile = cur_lcm
        k = 1
        while True:
            cand = cur_lcm * (k + 1)
            if cand > max_tile or n % cand != 0:
                break
            k += 1
            tile = cand
        runs.append((tuple(cur), tile))
        cur, cur_lcm = [], 1

    for s in strides:
        span = 2 * s
        new_lcm = _lcm(cur_lcm, span)
        if cur and new_lcm > max_tile:
            close()
            new_lcm = span
        cur.append(s)
        cur_lcm = new_lcm
    close()
    return tuple(runs)


def tile_cap_for_rows(n: int, strides: Tuple[int, ...], n_rows: int,
                      dtype_bytes: int = 4) -> int:
    """Feature-tile cap for a call with ``n_rows`` flattened batch rows:
    the default ``MAX_TILE`` for training-sized calls, the widened
    ``spm_stack.pick_max_tile`` cap for tiny-row (decode) calls — see
    ``core/eligibility.tiny_row_call``."""
    if tiny_row_call(n_rows):
        return max(MAX_TILE, K.pick_max_tile(n, len(strides), dtype_bytes))
    return MAX_TILE


def plan_runs_for_rows(n: int, strides: Tuple[int, ...], n_rows: int,
                       dtype_bytes: int = 4
                       ) -> Tuple[Tuple[Tuple[int, ...], int], ...]:
    """Row-count-aware run plan: ``plan_runs`` under the tile cap
    ``tile_cap_for_rows`` picks for ``n_rows``.  The ONE planner both the
    executor (``spm_stack_fused``) and the compile-contract checker
    (``analysis/contracts.Artifacts.runs``) call, so the proven
    pallas-call count can never drift from the executed plan."""
    strides = tuple(int(s) for s in strides)
    return plan_runs(n, strides,
                     tile_cap_for_rows(n, strides, n_rows, dtype_bytes))


def _flatten_rows(x: jax.Array) -> Tuple[jax.Array, Tuple[int, ...]]:
    lead = x.shape[:-1]
    rows = 1
    for d in lead:
        rows *= d
    return x.reshape(rows, x.shape[-1]), lead


def _pad_rows(x2: jax.Array, block_rows: int) -> Tuple[jax.Array, int]:
    rows = x2.shape[0]
    padded = -(-rows // block_rows) * block_rows
    if padded != rows:
        # spmlint: allow[SPM002] row padding to the kernel row block
        x2 = jnp.pad(x2, ((0, padded - rows), (0, 0)))
    return x2, rows


def pick_block_rows_for_plan(runs, n_rows: int, dtype_bytes: int, *,
                             overlap_bufs: bool = False,
                             block_bufs: bool = False) -> int:
    """One uniform row-block for every run of a plan (uniform row padding),
    budgeted per run: run r only keeps its OWN L_r + 2 tiles of its OWN
    width resident, so the binding constraint is the min over runs — not
    the old uniform (max_tile, total L) worst case, which under-sized the
    row block for every multi-run plan.  ``overlap_bufs`` additionally
    reserves the overlap (RDMA) kernels' per-block send/recv double
    buffers in the same budget (``spm_stack.overlap_vmem_bytes``) — set by
    the sharded executor whenever the in-kernel transport may engage, so
    a row block never outgrows VMEM once the comm slots move in.
    ``block_bufs`` budgets for the residual-BLOCK kernels instead
    (``spm_stack.block_vmem_bytes``): the norm-stat, activation, and
    residual buffers the block kernel keeps live on top of the per-run
    working set.  For the block entry, pass ONE pseudo-run holding both
    stacks' strides at the full width n — the block kernel never re-tiles
    between the stacks, so its binding run is the whole chain."""
    br = min(K.pick_block_rows(n_tile, len(run_strides),
                               dtype_bytes=dtype_bytes,
                               overlap=overlap_bufs, block=block_bufs)
             for run_strides, n_tile in runs)
    return min(br, max(8, 1 << (n_rows - 1).bit_length()))


# ---------------------------------------------------------------------------
# full-operator custom_vjp core
# ---------------------------------------------------------------------------
#
# Diff args: (x2, coeffs, d_in, d_out, bias).  The diag/bias operands are
# ALWAYS arrays (size-1 placeholders when absent) so the vjp signature is
# uniform; the static ``flags = (has_din, has_dout, has_bias, quant_acts,
# quant_coeffs)`` tuple decides which are real and whether the run chain
# moves int8 activations / coefficient tables (kernels/quant.py scale
# conventions).  Placeholders never reach a kernel and get zero grads.
#
# Quantized-activation chain (``quant_acts``; requires a uniform-tile plan,
# ``core/eligibility.quant_acts_eligible``): the input is quantized ONCE in
# XLA at entry, every run reads int8 + per-block scales and requantizes on
# its epilogue store (the scale array chains straight into the next run's
# x_scale), and the final int8 output is dequantized at exit.  The saved
# residuals are the int8 stage inputs + scales, so the backward's in-VMEM
# remat replays exactly the activations the quantized forward produced —
# the VJP is the true gradient of the quantized network (straight-through
# w.r.t. the entry quantization).
#
# Quantized coefficients (``quant_coeffs``): the f32 table is quantized
# per-stage here (O(nL), not activation-sized) and the kernels dequantize
# one stage at a time in VMEM.  The backward recomputes the SAME
# deterministic quantization from the saved f32 table, so its coefficient
# grads are bitwise what a pre-dequantized f32 table would produce, and
# the cotangent flows to the original f32 coeffs straight-through.

def _run_offsets(runs):
    offs, off = [], 0
    for run_strides, _ in runs:
        offs.append(off)
        off += len(run_strides)
    return offs


def _boundary_kw(r: int, n_runs: int, flags, d_in, d_out, bias) -> dict:
    """Kernel operands folded into run r: d_in on the first, d_out/bias on
    the last (both on a single-run plan)."""
    has_din, has_dout, has_bias = flags[:3]
    kw = {}
    if r == 0 and has_din:
        kw["d_in"] = d_in
    if r == n_runs - 1:
        if has_dout:
            kw["d_out"] = d_out
        if has_bias:
            kw["bias"] = bias
    return kw


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10, 11))
def _fused_core(x2, coeffs, d_in, d_out, bias,
                strides, flags, block_rows, interpret, in_width, out_width,
                max_tile=MAX_TILE):
    """x2: (B, in_width or n) row-major; coeffs: (L, n//2, 4);
    d_in/d_out/bias: (n,).  Returns (B, out_width or n).  ``max_tile`` is
    the static feature-tile cap the run plan was made under (widened for
    tiny-row decode calls)."""
    return _fused_fwd(x2, coeffs, d_in, d_out, bias,
                      strides, flags, block_rows, interpret,
                      in_width, out_width, max_tile)[0]


def _fused_fwd(x2, coeffs, d_in, d_out, bias,
               strides, flags, block_rows, interpret, in_width, out_width,
               max_tile=MAX_TILE):
    n = 2 * coeffs.shape[1]
    runs = plan_runs(n, strides, max_tile)
    quant_acts = len(flags) > 3 and flags[3]
    quant_cf = len(flags) > 4 and flags[4]
    kcf, scf = (Q.quantize_coeffs(coeffs) if quant_cf else (coeffs, None))
    zs = []
    z, zscale = x2, None
    if quant_acts:
        z, zscale = Q.quantize_blocks(x2, block_rows, runs[0][1])
    off = 0
    for r, (run_strides, n_tile) in enumerate(runs):
        zs.append((z, zscale) if quant_acts else z)
        nL = len(run_strides)
        out = K.spm_stack_kernel_call(
            z, kcf[off: off + nL], strides=run_strides,
            block_rows=block_rows, n_tile=n_tile, interpret=interpret,
            in_width=in_width if r == 0 else None,
            out_width=out_width if r == len(runs) - 1 else None,
            x_scale=zscale,
            coeff_scale=scf[off: off + nL] if quant_cf else None,
            quant_out=quant_acts,
            **_boundary_kw(r, len(runs), flags, d_in, d_out, bias))
        z, zscale = out if quant_acts else (out, None)
        off += nL
    if quant_acts:
        # dequantize the final int8 output at exit — callers that want the
        # int8 payload itself use the forward-only spm_stack_fused_q8
        z = Q.dequantize_blocks(z, zscale, block_rows, runs[-1][1],
                                dtype=x2.dtype)
    return z, (tuple(zs), coeffs, d_in, d_out, bias)


def _fused_bwd(strides, flags, block_rows, interpret, in_width, out_width,
               max_tile, res, gy):
    zs, coeffs, d_in, d_out, bias = res
    has_din, has_dout, has_bias = flags[:3]
    quant_acts = len(flags) > 3 and flags[3]
    quant_cf = len(flags) > 4 and flags[4]
    # requantize the saved f32 table — deterministic, so the kernels see
    # bitwise the same dequantized values the forward used
    kcf, scf = (Q.quantize_coeffs(coeffs) if quant_cf else (coeffs, None))
    n = 2 * coeffs.shape[1]
    runs = plan_runs(n, strides, max_tile)
    offsets = _run_offsets(runs)
    delta = gy
    g_cf_parts = [None] * len(runs)
    g_din = g_dout = g_bias = None
    # Dead-tile chain: each run's backward visits only the feature tiles
    # holding live cotangent columns and returns a g_x that is EXACTLY
    # zero from its first skipped column on (zero-initialized unvisited
    # blocks), so the upstream run can prune its own grid to match (its
    # dead tiles' grads are all exact zeros for the same
    # tile-local-pairing reason).  The boundary must be re-derived from
    # EACH run's tile width: a run re-tiles the dead region to its own
    # n_tile, and a larger-tile run spreads live cotangent across its
    # whole edge tile (run tiles are not monotone across a plan).
    dead = None     # first all-zero column of the downstream run's g_x
    for r in range(len(runs) - 1, -1, -1):
        run_strides, n_tile = runs[r]
        lo = offsets[r]
        cf = kcf[lo: lo + len(run_strides)]
        z_r, zscale_r = zs[r] if quant_acts else (zs[r], None)
        last = r == len(runs) - 1
        out = K.spm_stack_bwd_kernel_call(
            z_r, cf, delta,
            d_in=d_in if (r == 0 and has_din) else None,
            d_out=d_out if (last and has_dout) else None,
            x_scale=zscale_r,
            coeff_scale=scf[lo: lo + len(run_strides)] if quant_cf
            else None,
            strides=run_strides, block_rows=block_rows, n_tile=n_tile,
            has_bias=last and has_bias,
            in_width=in_width if r == 0 else None,
            out_width=out_width if last else None,
            dead_from=None if last else dead,
            interpret=interpret)
        live = out_width if last else dead
        if live is not None and -(-live // n_tile) * n_tile < n:
            dead = -(-live // n_tile) * n_tile
        else:
            dead = None
        delta, gcf = out[0], out[1]
        vec = list(out[2:])
        if r == 0 and has_din:
            g_din = vec.pop(0)
        if last and has_dout:
            g_dout = vec.pop(0)
        if last and has_bias:
            g_bias = vec.pop(0)
        g_cf_parts[r] = gcf
    g_coeffs = jnp.concatenate(g_cf_parts, axis=0).astype(coeffs.dtype)
    if in_width is not None and delta.shape[-1] != in_width:
        # the kernel widened g_x to n (narrow output blocks would alias
        # clamped out-of-bounds stores — see spm_stack_bwd_kernel_call);
        # hand the custom_vjp its contract shape back
        delta = delta[:, :in_width]

    def _vg(g, like):
        if g is None:
            return jnp.zeros_like(like)
        return g.astype(like.dtype)

    return (delta, g_coeffs, _vg(g_din, d_in), _vg(g_dout, d_out),
            _vg(g_bias, bias))


_fused_core.defvjp(_fused_fwd, _fused_bwd)


def spm_stack_fused(x: jax.Array, coeffs: jax.Array,
                    strides: Sequence[int], *,
                    d_in: Optional[jax.Array] = None,
                    d_out: Optional[jax.Array] = None,
                    bias: Optional[jax.Array] = None,
                    in_width: Optional[int] = None,
                    out_width: Optional[int] = None,
                    block_rows: int | None = None,
                    quant_acts: bool = False,
                    quant_coeffs: bool = False,
                    interpret: bool | None = None) -> jax.Array:
    """Fused SPM operator over the last axis of ``x``.

    x: (..., in_width or n) with n = 2 * coeffs.shape[1] divisible by 2*s
    for every stride; coeffs (L, n//2, 4); optional d_in/d_out/bias: (n,)
    folded into the boundary runs.  ``in_width`` / ``out_width`` (each
    <= n) make the operator rectangular-native: the input is zero-filled
    to n inside the first run and only ``out_width`` output columns are
    computed/stored by the last, with the input cotangent returned as
    (..., in_width).  Differentiable in x, coeffs, and the diag/bias
    operands (closed-form VJP); with everything optional omitted this is
    exactly the bare square stage stack (back-compat entry).

    ``quant_acts`` moves the run chain's HBM activation traffic at int8
    with per-(row-block, feature-tile) scales (quantize at entry,
    dequantize-in-VMEM / requantize-on-store per run, dequantize at
    exit); requires a uniform-tile run plan
    (``core/eligibility.quant_acts_eligible`` — falls back to f32 I/O
    gracefully otherwise).  ``quant_coeffs`` moves the coefficient table
    at int8 with per-stage scales dequantized in VMEM; coefficient grads
    stay f32 and bitwise-comparable to a pre-dequantized f32 table.  Both
    knobs change only BYTES MOVED, never the in-VMEM f32 compute.
    """
    strides = tuple(int(s) for s in strides)
    n = 2 * coeffs.shape[1]
    if in_width == n:
        in_width = None
    if out_width == n:
        out_width = None
    for w, name in ((in_width, "in_width"), (out_width, "out_width")):
        if w is not None and not 0 < w <= n:
            raise ValueError(f"{name}={w} outside (0, {n}]")
    expect = in_width if in_width is not None else n
    if x.shape[-1] != expect:
        raise ValueError(f"expected (..., {expect}), got {x.shape}")
    if interpret is None:
        interpret = default_interpret()
    x2, lead = _flatten_rows(x)
    max_tile = tile_cap_for_rows(n, strides, x2.shape[0],
                                 dtype_bytes=x.dtype.itemsize)
    runs = plan_runs(n, strides, max_tile)
    if block_rows is None:
        block_rows = pick_block_rows_for_plan(
            runs, x2.shape[0], dtype_bytes=x.dtype.itemsize)
    x2p, rows = _pad_rows(x2, block_rows)
    flags = (d_in is not None, d_out is not None, bias is not None,
             quant_acts and quant_acts_eligible(runs), bool(quant_coeffs))
    placeholder = jnp.zeros((1,), x.dtype)
    y2 = _fused_core(
        x2p, coeffs,
        d_in if d_in is not None else placeholder,
        d_out if d_out is not None else placeholder,
        bias if bias is not None else placeholder,
        strides, flags, block_rows, interpret, in_width, out_width,
        max_tile)
    if y2.shape[0] != rows:       # row padding only; never a feature slice
        y2 = y2[:rows]
    out_w = out_width if out_width is not None else n
    return y2.reshape(lead + (out_w,))


def spm_stack_fused_q8(qx: jax.Array, x_scale: jax.Array,
                       coeffs: jax.Array, strides: Sequence[int], *,
                       d_in: Optional[jax.Array] = None,
                       d_out: Optional[jax.Array] = None,
                       bias: Optional[jax.Array] = None,
                       in_width: Optional[int] = None,
                       out_width: Optional[int] = None,
                       quant_coeffs: bool = True,
                       interpret: bool | None = None):
    """Int8-native fused forward: int8 in, int8 out (inference entry).

    ``qx``: (B, in_width or n) int8 rows already quantized per
    (row-block, feature-tile) (``kernels/quant.quantize_blocks``);
    ``x_scale``: its (B // block_rows, tiles) f32 scale array —
    ``block_rows`` is derived from it, so the two must come from the same
    quantization.  Runs the whole run chain with int8 activation I/O
    (and, by default, an int8 per-stage-scaled coefficient table) and
    returns ``(qy int8 (B, out_width or n), y_scale)`` WITHOUT
    dequantizing: end to end, HBM sees no f32 activation bytes — the
    property the quant compile contract checks on this entry.  Forward
    only (no custom_vjp); training uses ``spm_stack_fused(...,
    quant_acts=True)``, which shares the same run chain but
    quantizes/dequantizes at the jit boundary.  Raises when the run plan
    is not uniform-tile (``core/eligibility.quant_acts_eligible``).
    """
    strides = tuple(int(s) for s in strides)
    n = 2 * coeffs.shape[1]
    if in_width == n:
        in_width = None
    if out_width == n:
        out_width = None
    assert qx.dtype == jnp.int8, qx.dtype
    B = qx.shape[0]
    if B % x_scale.shape[0]:
        raise ValueError(f"rows {B} not a multiple of scale rows "
                         f"{x_scale.shape[0]}")
    block_rows = B // x_scale.shape[0]
    max_tile = tile_cap_for_rows(n, strides, B, dtype_bytes=1)
    runs = plan_runs(n, strides, max_tile)
    if not quant_acts_eligible(runs):
        raise ValueError(f"run plan {runs} is not uniform-tile; int8 "
                         "activation I/O cannot chain across its runs")
    if interpret is None:
        interpret = default_interpret()
    kcf, scf = (Q.quantize_coeffs(coeffs) if quant_coeffs
                else (coeffs, None))
    flags = (d_in is not None, d_out is not None, bias is not None)
    z, zscale = qx, x_scale
    off = 0
    for r, (run_strides, n_tile) in enumerate(runs):
        nL = len(run_strides)
        z, zscale = K.spm_stack_kernel_call(
            z, kcf[off: off + nL], strides=run_strides,
            block_rows=block_rows, n_tile=n_tile, interpret=interpret,
            in_width=in_width if r == 0 else None,
            out_width=out_width if r == len(runs) - 1 else None,
            x_scale=zscale,
            coeff_scale=scf[off: off + nL] if quant_coeffs else None,
            quant_out=True,
            **_boundary_kw(r, len(runs), flags, d_in, d_out, bias))
        off += nL
    return z, zscale


# ---------------------------------------------------------------------------
# residual-block (megakernel) custom_vjp core + public entry
# ---------------------------------------------------------------------------
#
# Diff args: (x2, gamma, cf1, din1, dout1, bias1, cf2, din2, dout2,
# bias2) — size-1 placeholders when absent, exactly the _fused_core
# convention.  The static tuple rides one nondiff slot: (strides1,
# strides2, activation, flags, block_rows, residual, widths, eps,
# interpret) with flags = (has_norm, has_bias1, has_stack2, has_bias2).
# The ONLY forward residuals beyond the operands are the (B, 1) row
# statistics — the backward kernel remats the normalized input, both
# stacks' stage inputs, and the mid activation in VMEM from (x, rstd).

def _block_args(gamma, cf1, din1, dout1, bias1, cf2, din2, dout2, bias2,
                statics):
    """Expand the placeholder convention into the kernel-call kwargs
    shared by the block forward and backward wrappers."""
    (strides1, strides2, activation, flags, block_rows, residual,
     in_width, mid_width, out_width, eps, interpret) = statics
    has_norm, has_bias1, has_stack2, has_bias2 = flags
    return dict(
        bias1=bias1 if has_bias1 else None,
        gamma=gamma if has_norm else None,
        coeffs2=cf2 if has_stack2 else None,
        d_in2=din2 if has_stack2 else None,
        d_out2=dout2 if has_stack2 else None,
        bias2=bias2 if (has_stack2 and has_bias2) else None,
        strides1=strides1,
        strides2=strides2 if has_stack2 else None,
        activation=activation, block_rows=block_rows, residual=residual,
        in_width=in_width, mid_width=mid_width, out_width=out_width,
        interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(10,))
def _block_core(x2, gamma, cf1, din1, dout1, bias1,
                cf2, din2, dout2, bias2, statics):
    """x2: (B, in_width) row-major; gamma/diag/bias: (n,) (placeholders
    when the matching flag is off); cf1/cf2: (L, n//2, 4).  Returns
    (B, out_width)."""
    return _block_fwd(x2, gamma, cf1, din1, dout1, bias1,
                      cf2, din2, dout2, bias2, statics)[0]


def _block_fwd(x2, gamma, cf1, din1, dout1, bias1,
               cf2, din2, dout2, bias2, statics):
    kw = _block_args(gamma, cf1, din1, dout1, bias1,
                     cf2, din2, dout2, bias2, statics)
    out = K.spm_block_kernel_call(x2, cf1, din1, dout1, eps=statics[9],
                                  **kw)
    rstd = out[1] if kw["gamma"] is not None else None
    return out[0], (x2, rstd, gamma, cf1, din1, dout1, bias1,
                    cf2, din2, dout2, bias2)


def _block_bwd(statics, res, gy):
    (x2, rstd, gamma, cf1, din1, dout1, bias1,
     cf2, din2, dout2, bias2) = res
    flags = statics[3]
    has_norm, has_bias1, has_stack2, has_bias2 = flags
    kw = _block_args(gamma, cf1, din1, dout1, bias1,
                     cf2, din2, dout2, bias2, statics)
    kw.pop("interpret")
    out = list(K.spm_block_bwd_kernel_call(
        x2, gy, cf1, din1, dout1, rstd=rstd, interpret=statics[10], **kw))
    gx = out.pop(0)
    g_gamma = out.pop(0) if has_norm else None
    g_cf1, g_din1, g_dout1 = out.pop(0), out.pop(0), out.pop(0)
    g_bias1 = out.pop(0) if has_bias1 else None
    g_cf2 = g_din2 = g_dout2 = g_bias2 = None
    if has_stack2:
        g_cf2, g_din2, g_dout2 = out.pop(0), out.pop(0), out.pop(0)
        if has_bias2:
            g_bias2 = out.pop(0)

    def _g(g, like):
        if g is None:
            return jnp.zeros_like(like)
        return g.astype(like.dtype)

    return (gx, _g(g_gamma, gamma), g_cf1.astype(cf1.dtype),
            _g(g_din1, din1), _g(g_dout1, dout1), _g(g_bias1, bias1),
            _g(g_cf2, cf2), _g(g_din2, din2), _g(g_dout2, dout2),
            _g(g_bias2, bias2))


_block_core.defvjp(_block_fwd, _block_bwd)


def spm_block_fused(x: jax.Array, *,
                    coeffs1: jax.Array, d_in1: jax.Array,
                    d_out1: jax.Array, strides1: Sequence[int],
                    bias1: Optional[jax.Array] = None,
                    gamma: Optional[jax.Array] = None,
                    coeffs2: Optional[jax.Array] = None,
                    d_in2: Optional[jax.Array] = None,
                    d_out2: Optional[jax.Array] = None,
                    bias2: Optional[jax.Array] = None,
                    strides2: Optional[Sequence[int]] = None,
                    activation: Optional[str] = None,
                    residual: bool = False,
                    in_width: Optional[int] = None,
                    mid_width: Optional[int] = None,
                    out_width: Optional[int] = None,
                    eps: float = 1e-6,
                    block_rows: Optional[int] = None,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Residual-block megakernel over the last axis of ``x``: ONE fused
    Pallas region lowering

        y = [x +] stack2(act(stack1(rms_norm(x))))

    where each stack is a complete SPM operator (d_in -> stages ->
    d_out [+ bias]) and every piece is optional — ``gamma=None`` skips
    the norm prologue, ``strides2=None`` ends after stack 1 (the
    norm-prologue-only fused-qkv entry), ``activation=None`` is the
    identity, ``residual`` adds x on the store (requires out_width ==
    in_width).

    ``gamma`` is the (in_width,) RMS scale (``eps`` matching
    ``layers/norms.rms_norm``); widths default to ``in_width =
    x.shape[-1]``, ``out_width = n``, and ``mid_width`` (the true width
    between the stacks — d_ff for an FFN) to ``n`` with a second stack,
    ``out_width`` without.  Both stacks must satisfy
    ``core/eligibility.block_fusion_eligible`` — single full-width run
    each, so the mid activation never leaves VMEM (raises otherwise; the
    layer entries resolve eligibility BEFORE calling this).
    Differentiable in every array operand: the closed-form custom_vjp
    saves only x and the (rows, 1) row statistics and remats the rest in
    VMEM (remat-from-row-stats).
    """
    strides1 = tuple(int(s) for s in strides1)
    strides2 = (tuple(int(s) for s in strides2)
                if strides2 is not None else None)
    n = 2 * coeffs1.shape[1]
    if not block_fusion_eligible(n, strides1, strides2, activation):
        raise ValueError(
            f"block fusion ineligible: n={n}, strides1={strides1}, "
            f"strides2={strides2}, activation={activation!r}")
    if in_width is None:
        in_width = x.shape[-1]
    if out_width is None:
        out_width = n
    if mid_width is None:
        mid_width = n if strides2 is not None else out_width
    for w, name in ((in_width, "in_width"), (mid_width, "mid_width"),
                    (out_width, "out_width")):
        if not 0 < w <= n:
            raise ValueError(f"{name}={w} outside (0, {n}]")
    if x.shape[-1] != in_width:
        raise ValueError(f"expected (..., {in_width}), got {x.shape}")
    if residual and out_width != in_width:
        raise ValueError(f"residual needs out_width == in_width, got "
                         f"{out_width} != {in_width}")
    if (strides2 is not None) != (coeffs2 is not None):
        raise ValueError("strides2 and coeffs2 must be given together")
    if interpret is None:
        interpret = default_interpret()
    if gamma is not None and gamma.shape[-1] != n:
        # zero-fill the RMS scale to operator width in O(n) (dead lanes
        # multiply exact zeros either way)
        gamma = jnp.zeros((n,), gamma.dtype).at[:in_width].set(gamma)
    x2, lead = _flatten_rows(x)
    if block_rows is None:
        # ONE pseudo-run with both stacks' strides at full width: the
        # block kernel never re-tiles, and block_bufs reserves the
        # norm/activation/residual buffers it keeps live
        runs = ((strides1 + (strides2 or ()), n),)
        block_rows = pick_block_rows_for_plan(
            runs, x2.shape[0], dtype_bytes=x.dtype.itemsize,
            block_bufs=True)
    x2p, rows = _pad_rows(x2, block_rows)
    flags = (gamma is not None, bias1 is not None, strides2 is not None,
             bias2 is not None)
    statics = (strides1, strides2, activation, flags, block_rows,
               residual, in_width, mid_width, out_width, eps,
               bool(interpret))
    ph = jnp.zeros((1,), x.dtype)
    y2 = _block_core(
        x2p,
        gamma if gamma is not None else ph,
        coeffs1, d_in1, d_out1,
        bias1 if bias1 is not None else ph,
        coeffs2 if coeffs2 is not None else ph,
        d_in2 if d_in2 is not None else ph,
        d_out2 if d_out2 is not None else ph,
        bias2 if bias2 is not None else ph,
        statics)
    if y2.shape[0] != rows:       # row padding only; never a feature slice
        y2 = y2[:rows]
    return y2.reshape(lead + (out_width,))
