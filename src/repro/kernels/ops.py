"""Public entry for the fused SPM stage-stack kernel.

``spm_stack_fused(x, coeffs, strides)`` applies the L structured mixing
stages to the last axis of ``x`` with:

  * **run planning** — the stride schedule is split into maximal consecutive
    *runs* such that every stride in a run keeps its pairs inside one feature
    tile (``n_tile % (2*s) == 0``).  Each run is one ``pallas_call`` that
    fuses all its stages in VMEM (DESIGN.md §3.2); run boundaries are the
    only HBM round-trips.
  * **custom_vjp** — backward uses the fused backward kernel per run
    (paper §4 closed forms, recomputing stage inputs in VMEM), so training
    gets the same one-read-one-write property as the forward.
  * **batch/tile padding** — leading dims are flattened; rows are padded to
    the row-block so arbitrary batch sizes work.

On CPU (this container) kernels run with ``interpret=True``; on TPU the
same BlockSpecs compile natively.  ``kernels/ref.py`` is the oracle.
"""

from __future__ import annotations

import functools
import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import spm_stack as K

__all__ = ["spm_stack_fused", "plan_runs", "default_interpret"]

MAX_TILE = 2048  # lane-dim tile cap: 16 VREG lanes x 128; VMEM-comfortable


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


@functools.lru_cache(maxsize=None)
def plan_runs(n: int, strides: Tuple[int, ...],
              max_tile: int = MAX_TILE) -> Tuple[Tuple[Tuple[int, ...], int], ...]:
    """Split ``strides`` into runs of (strides, n_tile).

    Every stride s in a run satisfies ``n_tile % (2*s) == 0`` and
    ``n % n_tile == 0``.  Greedy: extend the current run while the lcm of
    pair spans stays within ``max_tile``; the tile is the largest multiple
    of that lcm that divides n and is <= max_tile (>= lcm always exists
    because the lcm of divisors of n divides n).
    """
    for s in strides:
        if n % (2 * s) != 0:
            raise ValueError(f"stride {s} invalid for n={n}")
    runs = []
    cur: list = []
    cur_lcm = 1

    def close():
        nonlocal cur, cur_lcm
        if not cur:
            return
        # largest multiple of cur_lcm dividing n, capped at max_tile
        tile = cur_lcm
        k = 1
        while True:
            cand = cur_lcm * (k + 1)
            if cand > max_tile or n % cand != 0:
                break
            k += 1
            tile = cand
        runs.append((tuple(cur), tile))
        cur, cur_lcm = [], 1

    for s in strides:
        span = 2 * s
        new_lcm = _lcm(cur_lcm, span)
        if cur and new_lcm > max_tile:
            close()
            new_lcm = span
        cur.append(s)
        cur_lcm = new_lcm
    close()
    return tuple(runs)


def _flatten_rows(x: jax.Array) -> Tuple[jax.Array, Tuple[int, ...]]:
    lead = x.shape[:-1]
    rows = 1
    for d in lead:
        rows *= d
    return x.reshape(rows, x.shape[-1]), lead


def _pad_rows(x2: jax.Array, block_rows: int) -> Tuple[jax.Array, int]:
    rows = x2.shape[0]
    padded = -(-rows // block_rows) * block_rows
    if padded != rows:
        x2 = jnp.pad(x2, ((0, padded - rows), (0, 0)))
    return x2, rows


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _fused_core(x2, coeffs, strides, block_rows, interpret):
    """x2: (B, n) row-major; coeffs: (L, n//2, 4)."""
    z = x2
    off = 0
    for run_strides, n_tile in plan_runs(x2.shape[-1], strides):
        cf = coeffs[off: off + len(run_strides)]
        z = K.spm_stack_kernel_call(
            z, cf, strides=run_strides, block_rows=block_rows,
            n_tile=n_tile, interpret=interpret)
        off += len(run_strides)
    return z


def _fused_fwd(x2, coeffs, strides, block_rows, interpret):
    zs = []
    z = x2
    off = 0
    for run_strides, n_tile in plan_runs(x2.shape[-1], strides):
        zs.append(z)
        cf = coeffs[off: off + len(run_strides)]
        z = K.spm_stack_kernel_call(
            z, cf, strides=run_strides, block_rows=block_rows,
            n_tile=n_tile, interpret=interpret)
        off += len(run_strides)
    return z, (tuple(zs), coeffs)


def _fused_bwd(strides, block_rows, interpret, res, gy):
    zs, coeffs = res
    runs = plan_runs(gy.shape[-1], strides)
    offsets = []
    off = 0
    for run_strides, _ in runs:
        offsets.append(off)
        off += len(run_strides)
    delta = gy
    g_cf_parts = [None] * len(runs)
    for r in range(len(runs) - 1, -1, -1):
        run_strides, n_tile = runs[r]
        cf = coeffs[offsets[r]: offsets[r] + len(run_strides)]
        delta, gcf = K.spm_stack_bwd_kernel_call(
            zs[r], cf, delta, strides=run_strides, block_rows=block_rows,
            n_tile=n_tile, interpret=interpret)
        g_cf_parts[r] = gcf
    g_coeffs = jnp.concatenate(g_cf_parts, axis=0).astype(coeffs.dtype)
    return delta, g_coeffs


_fused_core.defvjp(_fused_fwd, _fused_bwd)


def spm_stack_fused(x: jax.Array, coeffs: jax.Array,
                    strides: Sequence[int], *,
                    block_rows: int | None = None,
                    interpret: bool | None = None) -> jax.Array:
    """Fused L-stage SPM over the last axis of ``x``.

    x: (..., n) with n divisible by 2*s for every stride; coeffs
    (L, n//2, 4).  Differentiable in x and coeffs (closed-form VJP).
    """
    strides = tuple(int(s) for s in strides)
    n = x.shape[-1]
    if interpret is None:
        interpret = default_interpret()
    x2, lead = _flatten_rows(x)
    if block_rows is None:
        min_tile = min(t for _, t in plan_runs(n, strides))
        block_rows = K.pick_block_rows(min_tile, len(strides),
                                       dtype_bytes=x.dtype.itemsize)
        block_rows = min(block_rows, max(8, 1 << (x2.shape[0] - 1).bit_length()))
    x2p, rows = _pad_rows(x2, block_rows)
    y2 = _fused_core(x2p, coeffs, strides, block_rows, interpret)
    return y2[:rows].reshape(lead + (n,))
