"""Block-scale int8 quantization helpers for the fused SPM kernels.

The fused kernels tile activations into self-contained
``(block_rows, n_tile)`` blocks — stages inside one run pair lanes
tile-locally, so block (i, j) of the output depends ONLY on block (i, j)
of the input.  That makes per-(row-block, feature-tile) scales the
natural quantization granularity: one f32 scale per VMEM-resident block,
delivered to the kernel through a ``(1, 1)`` BlockSpec riding the same
grid indices as the activation block it scales.  Dequantize-on-load and
requantize-on-store then happen entirely in VMEM; HBM only ever sees the
int8 payload plus the O(B * n / (block_rows * n_tile)) scale array.

Coefficient tables quantize per STAGE (one scale per ``(n_pairs, 4)``
slab): the table is O(nL) — tiny next to activations — and a per-stage
scale keeps the dequantized values bitwise-identical whether the multiply
happens in VMEM (kernel) or in XLA (the reference / the closed-form
backward), which is what keeps coefficient grads bitwise-comparable
between the quantized and pre-dequantized runs.

The scale convention matches ``optim/compression``: ``absmax / 127 +
1e-12`` — always finite and strictly positive (denormal and all-zero
inputs quantize to exact zeros; the round-trip error is bounded by
``scale / 2`` elementwise).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_blocks", "dequantize_blocks", "quantize_coeffs",
           "dequantize_coeffs", "block_scale_bound"]

_EPS = 1e-12


def quantize_blocks(x2: jax.Array, block_rows: int, n_tile: int
                    ) -> Tuple[jax.Array, jax.Array]:
    """Quantize a (B, W) activation to int8 with per-block scales.

    ``B`` must be a multiple of ``block_rows`` (the caller row-pads, as
    for the kernels); ``W`` may be a partial multiple of ``n_tile`` (a
    rectangular boundary operand) — the trailing partial tile is scaled
    over its real columns only.  Returns ``(q, scales)`` with ``q`` int8
    of shape (B, W) and ``scales`` f32 of shape
    ``(B // block_rows, ceil(W / n_tile))``, laid out so the kernels'
    ``(1, 1)`` scale BlockSpec indexed by the activation grid ``(i, j)``
    picks the matching block's scale.
    """
    B, W = x2.shape
    assert B % block_rows == 0, (B, block_rows)
    nb = B // block_rows
    ncol = -(-W // n_tile)
    wp = ncol * n_tile
    xf = x2.astype(jnp.float32)
    if wp != W:
        # spmlint: allow[SPM002] scale-grid padding (host-side, pre-kernel)
        xf = jnp.pad(xf, ((0, 0), (0, wp - W)))
    xr = xf.reshape(nb, block_rows, ncol, n_tile)
    scales = jnp.max(jnp.abs(xr), axis=(1, 3)) / 127.0 + _EPS  # (nb, ncol)
    q = jnp.clip(jnp.round(xr / scales[:, None, :, None]), -127, 127)
    q = q.astype(jnp.int8).reshape(B, wp)[:, :W]
    return q, scales


def dequantize_blocks(q: jax.Array, scales: jax.Array, block_rows: int,
                      n_tile: int, dtype=jnp.float32) -> jax.Array:
    """Inverse of ``quantize_blocks`` (up to the <= scale/2 rounding)."""
    B, W = q.shape
    nb = B // block_rows
    ncol = -(-W // n_tile)
    wp = ncol * n_tile
    qf = q.astype(jnp.float32)
    if wp != W:
        # spmlint: allow[SPM002] scale-grid padding (host-side, pre-kernel)
        qf = jnp.pad(qf, ((0, 0), (0, wp - W)))
    xr = qf.reshape(nb, block_rows, ncol, n_tile) * scales[:, None, :, None]
    return xr.reshape(B, wp)[:, :W].astype(dtype)


def quantize_coeffs(coeffs: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Quantize an (L, n_pairs, 4) coefficient table to int8 with one f32
    scale per stage.  Returns ``(q, scales)`` with ``scales`` shaped
    ``(L, 1)`` — the 2D layout the kernels' stage-scale ref expects."""
    cf = coeffs.astype(jnp.float32)
    scales = (jnp.max(jnp.abs(cf), axis=(1, 2), keepdims=False)
              / 127.0 + _EPS)                                  # (L,)
    q = jnp.clip(jnp.round(cf / scales[:, None, None]), -127, 127)
    return q.astype(jnp.int8), scales.reshape(-1, 1)


def dequantize_coeffs(q: jax.Array, scales: jax.Array,
                      dtype=jnp.float32) -> jax.Array:
    """Dequantize a per-stage-scaled int8 coefficient table — the exact
    multiply the kernels perform in VMEM, so a reference computed on this
    table matches the kernel's quantized-coeff output bitwise (modulo the
    shared f32 arithmetic)."""
    return (q.astype(jnp.float32)
            * scales.reshape(-1, 1, 1)).astype(dtype)


def block_scale_bound(x2: jax.Array, block_rows: int, n_tile: int) -> float:
    """Worst-case per-element quantization step of ``quantize_blocks`` on
    this input: the MAX block scale.  Parity tests derive their tolerance
    from this (error <= scale / 2 per quantization point) instead of a
    magic constant."""
    _, scales = quantize_blocks(x2, block_rows, n_tile)
    return float(jnp.max(scales))
