"""Pure-jnp oracle for the fused SPM stage-stack kernel.

Semantics shared with ``kernels/spm_stack.py``: apply L structured
(stride-pairing) mixing stages to the last axis of ``x``.

    z_0 = x;   z_l = B_l z_{l-1};   return z_L

``coeffs`` is (L, n//2, 4) holding (a, b, c, d) per pair; ``strides`` is a
static tuple of per-stage strides with ``n % (2*s) == 0``.

This module is the correctness reference: tests assert the Pallas kernel
(interpret mode on CPU) matches ``spm_stack_ref`` across shape/dtype sweeps.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

__all__ = ["spm_stack_ref", "spm_stack_grads_ref", "spm_full_ref"]


def _stage(z, cf, s):
    """One stride-s stage.  z: (..., n); cf: (n//2, 4)."""
    n = z.shape[-1]
    lead = z.shape[:-1]
    g = n // (2 * s)
    zr = z.reshape(lead + (g, 2, s))
    x0, x1 = zr[..., 0, :], zr[..., 1, :]
    a, b, c, d = (cf[:, i].reshape(g, s) for i in range(4))
    y0 = a * x0 + b * x1
    y1 = c * x0 + d * x1
    return jnp.stack([y0, y1], axis=-2).reshape(lead + (n,))


def spm_stack_ref(x: jnp.ndarray, coeffs: jnp.ndarray,
                  strides: Tuple[int, ...]) -> jnp.ndarray:
    z = x
    for ell, s in enumerate(strides):
        z = _stage(z, coeffs[ell].astype(z.dtype), s)
    return z


def spm_full_ref(x: jnp.ndarray, coeffs: jnp.ndarray,
                 strides: Tuple[int, ...],
                 d_in: Optional[jnp.ndarray] = None,
                 d_out: Optional[jnp.ndarray] = None,
                 bias: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Oracle for the FULL operator y = D_out (B_L...B_1) D_in x + bias,
    matching the diag/bias folding of the fused kernel path."""
    z = x if d_in is None else x * d_in.astype(x.dtype)
    z = spm_stack_ref(z, coeffs, strides)
    if d_out is not None:
        z = z * d_out.astype(z.dtype)
    if bias is not None:
        z = z + bias.astype(z.dtype)
    return z


def spm_stack_grads_ref(x, coeffs, strides, gy):
    """Closed-form (paper §4.2) backward for the stage stack.

    Returns (g_x, g_coeffs).  Used to validate the kernel-wrapped custom_vjp.
    """
    # forward, collecting stage inputs
    zs = []
    z = x
    for ell, s in enumerate(strides):
        zs.append(z)
        z = _stage(z, coeffs[ell].astype(z.dtype), s)
    g_coeffs = []
    delta = gy
    n = x.shape[-1]
    lead = x.shape[:-1]
    bdims = tuple(range(len(lead)))
    for ell in range(len(strides) - 1, -1, -1):
        s = strides[ell]
        g = n // (2 * s)
        cf = coeffs[ell].astype(delta.dtype)
        a, b, c, d = (cf[:, i].reshape(g, s) for i in range(4))
        zr = zs[ell].reshape(lead + (g, 2, s))
        dr = delta.reshape(lead + (g, 2, s))
        x0, x1 = zr[..., 0, :], zr[..., 1, :]
        d0, d1 = dr[..., 0, :], dr[..., 1, :]
        ga = jnp.sum(d0 * x0, axis=bdims).reshape(-1)
        gb = jnp.sum(d0 * x1, axis=bdims).reshape(-1)
        gc = jnp.sum(d1 * x0, axis=bdims).reshape(-1)
        gd = jnp.sum(d1 * x1, axis=bdims).reshape(-1)
        g_coeffs.append(jnp.stack([ga, gb, gc, gd], axis=-1))
        gx0 = a * d0 + c * d1
        gx1 = b * d0 + d * d1
        delta = jnp.stack([gx0, gx1], axis=-2).reshape(lead + (n,))
    return delta, jnp.stack(g_coeffs[::-1], axis=0)
