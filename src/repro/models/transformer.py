"""Block-pattern transformer composer.

One ``ModelConfig`` describes any of the assigned architectures: a tuple of
``LayerSpec`` (mixer = attention / mamba / +shared block, mlp = dense /
moe / none), GQA geometry, RoPE flavor, MoE and SSM hyperparameters, and —
central to this repo — the ``linear_impl`` knob that swaps every projection
between dense and SPM (paper §7).

Layers are scanned over repeating pattern groups (``scan_group``) so HLO
size stays O(1) in depth; heterogeneous stacks (zamba2's shared-attention
interleave) unroll.  The same ``forward`` serves training (cache=None),
prefill, and single-token decode (cache + cache_index).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.layers.attention import (AttentionConfig, attention_apply,
                                    init_attention, init_kv_cache)
from repro.layers.embedding import (EmbeddingConfig, embed, init_embedding,
                                    unembed)
from repro.layers.ffn import FFNConfig, ffn_block_apply, init_ffn
from repro.layers.mamba2 import (Mamba2Config, init_mamba2, init_ssm_cache,
                                 mamba2_apply)
from repro.layers.moe import MoEConfig, init_moe, moe_apply
from repro.layers.norms import init_rms_norm, rms_norm
from repro.layers.rope import mrope_angles, rope_angles
from repro.parallel.ctx import constrain

__all__ = ["LayerSpec", "ModelConfig", "init_model", "forward",
           "init_cache", "model_param_count"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"              # "attn" | "mamba"
    mlp: str = "dense"               # "dense" | "moe" | "none"
    window: Optional[int] = None     # sliding window for attn mixers
    rope: str = "default"            # rope table key: "default" | "local"
    shared_block: bool = False       # apply the shared attn+ffn block first


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    layers: Tuple[LayerSpec, ...]
    scan_group: int = 1              # 0 = unrolled; else pattern period
    # attention
    qk_norm: bool = False
    rope_theta: float = 1e4
    rope_local_theta: float = 1e4
    rope_kind: str = "default"       # "default" | "mrope"
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    q_chunk: int = 512
    k_chunk: int = 1024
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    # ssm
    ssm_state: int = 0
    ssm_head: int = 64
    ssm_chunk: int = 128
    # shared block (zamba2)
    shared_attn_d_ff: int = 0
    # paper knob
    linear_impl: str = "dense"
    spm_stages: Optional[int] = None
    spm_backward: str = "custom"
    spm_use_kernel: Optional[bool] = None  # fused Pallas operator (tri-state:
                                           # None=auto/on-TPU, True, False)
    spm_schedule: str = "butterfly"        # "two_level" + spm_n_shards > 1:
    spm_n_shards: int = 1                  # feature axis distributable over
                                           # the "model" mesh axis via
                                           # parallel/spm_shard.py
    spm_overlap: Optional[bool] = None     # overlap-scheduled sharded
                                           # executor (row-block pipelined
                                           # exchanges): None=auto/on-TPU,
                                           # True=force, False=off
    spm_quant_acts: bool = False           # int8 activation I/O on the fused
                                           # kernel path (per-block scales)
    spm_quant_coeffs: bool = False         # int8 per-stage-scaled coefficient
                                           # tables dequantized in VMEM
    ffn_activation: str = "swiglu"         # "swiglu" (gated) or an ungated
                                           # "relu"/"silu"/"gelu" — the
                                           # shapes the residual-block
                                           # megakernel can fuse
    spm_block_fuse: Optional[bool] = None  # residual-block megakernel
                                           # (norm -> SPM -> act -> SPM ->
                                           # residual in one Pallas chain):
                                           # None=auto/on-TPU, True=force
                                           # (interpret off-TPU), False=off
    compress_pod_grads: bool = False       # int8 error-feedback pod-DP grad
                                           # reduction (train/step.py
                                           # make_pod_train_step)
    # io
    input_kind: str = "tokens"       # "tokens" | "embeddings"
    tie_embeddings: bool = True
    embed_scale: float = 1.0
    embed_onehot: bool = False       # matmul-lowered lookup (sharded vocab)
    logits_dtype: Any = "float32"    # bf16 halves LM-head HBM traffic
                                     # (softmax stats still f32 in-regs)
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: bool = True

    # ---- derived sub-configs -------------------------------------------
    def attn_cfg(self, spec: LayerSpec) -> AttentionConfig:
        return AttentionConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
            use_qk_norm=self.qk_norm, window=spec.window,
            linear_impl=self.linear_impl, spm_stages=self.spm_stages,
            spm_backward=self.spm_backward,
            spm_use_kernel=self.spm_use_kernel,
            spm_schedule=self.spm_schedule, spm_n_shards=self.spm_n_shards,
            spm_overlap=self.spm_overlap,
            spm_quant_acts=self.spm_quant_acts,
            spm_quant_coeffs=self.spm_quant_coeffs,
            spm_block_fuse=self.spm_block_fuse,
            q_chunk=self.q_chunk,
            k_chunk=self.k_chunk, param_dtype=self.param_dtype)

    def ffn_cfg(self) -> FFNConfig:
        return FFNConfig(
            d_model=self.d_model, d_ff=self.d_ff,
            linear_impl=self.linear_impl,
            activation=self.ffn_activation, spm_stages=self.spm_stages,
            spm_backward=self.spm_backward,
            spm_use_kernel=self.spm_use_kernel,
            spm_schedule=self.spm_schedule, spm_n_shards=self.spm_n_shards,
            spm_overlap=self.spm_overlap,
            spm_quant_acts=self.spm_quant_acts,
            spm_quant_coeffs=self.spm_quant_coeffs,
            spm_block_fuse=self.spm_block_fuse,
            param_dtype=self.param_dtype)

    def moe_cfg(self) -> MoEConfig:
        return MoEConfig(
            d_model=self.d_model, d_ff=self.moe_d_ff,
            n_experts=self.n_experts, top_k=self.top_k,
            capacity_factor=self.capacity_factor,
            shared_d_ff=self.shared_d_ff, linear_impl=self.linear_impl,
            spm_stages=self.spm_stages, spm_backward=self.spm_backward,
            spm_use_kernel=self.spm_use_kernel,
            spm_schedule=self.spm_schedule, spm_n_shards=self.spm_n_shards,
            spm_overlap=self.spm_overlap,
            spm_quant_acts=self.spm_quant_acts,
            spm_quant_coeffs=self.spm_quant_coeffs,
            param_dtype=self.param_dtype)

    def mamba_cfg(self) -> Mamba2Config:
        return Mamba2Config(
            d_model=self.d_model, d_state=self.ssm_state,
            d_head=self.ssm_head, chunk=self.ssm_chunk,
            linear_impl=self.linear_impl, spm_stages=self.spm_stages,
            spm_backward=self.spm_backward,
            spm_use_kernel=self.spm_use_kernel,
            spm_schedule=self.spm_schedule, spm_n_shards=self.spm_n_shards,
            spm_overlap=self.spm_overlap,
            spm_quant_acts=self.spm_quant_acts,
            spm_quant_coeffs=self.spm_quant_coeffs,
            param_dtype=self.param_dtype)

    def shared_attn_cfg(self) -> AttentionConfig:
        return self.attn_cfg(LayerSpec(mixer="attn"))

    def shared_ffn_cfg(self) -> FFNConfig:
        return FFNConfig(
            d_model=self.d_model, d_ff=self.shared_attn_d_ff,
            linear_impl=self.linear_impl,
            activation=self.ffn_activation, spm_stages=self.spm_stages,
            spm_backward=self.spm_backward,
            spm_use_kernel=self.spm_use_kernel,
            spm_schedule=self.spm_schedule, spm_n_shards=self.spm_n_shards,
            spm_overlap=self.spm_overlap,
            spm_quant_acts=self.spm_quant_acts,
            spm_quant_coeffs=self.spm_quant_coeffs,
            spm_block_fuse=self.spm_block_fuse,
            param_dtype=self.param_dtype)

    def embed_cfg(self) -> EmbeddingConfig:
        return EmbeddingConfig(
            vocab_size=self.vocab_size, d_model=self.d_model,
            tie_output=self.tie_embeddings, param_dtype=self.param_dtype)

    # ---- scan layout ----------------------------------------------------
    @property
    def scanned(self) -> bool:
        g = self.scan_group
        if g <= 0 or self.n_layers % g:
            return False
        return all(self.layers[i] == self.layers[i % g]
                   for i in range(self.n_layers))

    @property
    def uniform_ignoring_shared(self) -> bool:
        """Layers identical except for the shared-block flag (zamba2)."""
        base = dataclasses.replace(self.layers[0], shared_block=False)
        return all(dataclasses.replace(s, shared_block=False) == base
                   for s in self.layers)

    @property
    def stacked_params(self) -> bool:
        """Layer params stored stacked (scan-compatible).  Hybrid stacks
        too: shared-block application is a ``lax.cond`` inside the scan
        body (HLO stays O(1) in depth), decode unrolls by slicing."""
        return self.scanned or self.uniform_ignoring_shared

    @property
    def group_specs(self) -> Tuple[LayerSpec, ...]:
        if self.scanned:
            return self.layers[: self.scan_group]
        if self.uniform_ignoring_shared:
            return (dataclasses.replace(self.layers[0], shared_block=False),)
        return self.layers

    @property
    def n_groups(self) -> int:
        if self.scanned:
            return self.n_layers // self.scan_group
        if self.uniform_ignoring_shared:
            return self.n_layers
        return 1

    @property
    def has_shared_block(self) -> bool:
        return any(s.shared_block for s in self.layers)

    @property
    def shared_flags(self) -> Tuple[bool, ...]:
        """Per-group shared-block application flags (hybrid scan path)."""
        return tuple(s.shared_block for s in self.layers)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(key: jax.Array, spec: LayerSpec, cfg: ModelConfig) -> dict:
    km, kf, kn1, kn2 = jax.random.split(key, 4)
    p: dict = {"norm1": init_rms_norm(cfg.d_model, cfg.param_dtype)}
    if spec.mixer == "attn":
        p["mixer"] = init_attention(km, cfg.attn_cfg(spec))
    elif spec.mixer == "mamba":
        p["mixer"] = init_mamba2(km, cfg.mamba_cfg())
    else:
        raise ValueError(spec.mixer)
    if spec.mlp != "none":
        p["norm2"] = init_rms_norm(cfg.d_model, cfg.param_dtype)
        if spec.mlp == "dense":
            p["mlp"] = init_ffn(kf, cfg.ffn_cfg())
        elif spec.mlp == "moe":
            p["mlp"] = init_moe(kf, cfg.moe_cfg())
        else:
            raise ValueError(spec.mlp)
    return p


def _init_group(key: jax.Array, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, len(cfg.group_specs))
    return {f"l{i}": _init_layer(keys[i], spec, cfg)
            for i, spec in enumerate(cfg.group_specs)}


def init_model(key: jax.Array, cfg: ModelConfig) -> dict:
    ke, kl, ks = jax.random.split(key, 3)
    p: dict = {"final_norm": init_rms_norm(cfg.d_model, cfg.param_dtype)}
    # embeddings-input archs (modality frontend stub) still need the vocab
    # table for the output head.
    p["embed"] = init_embedding(ke, cfg.embed_cfg())
    if cfg.stacked_params:
        gkeys = jax.random.split(kl, cfg.n_groups)
        groups = [_init_group(k, cfg) for k in gkeys]
        p["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
    else:
        lkeys = jax.random.split(kl, cfg.n_layers)
        p["layers"] = [_init_layer(lkeys[i], cfg.layers[i], cfg)
                       for i in range(cfg.n_layers)]
    if cfg.has_shared_block:
        k1, k2 = jax.random.split(ks)
        p["shared"] = {
            "norm1": init_rms_norm(cfg.d_model, cfg.param_dtype),
            "attn": init_attention(k1, cfg.shared_attn_cfg()),
            "norm2": init_rms_norm(cfg.d_model, cfg.param_dtype),
            "ffn": init_ffn(k2, cfg.shared_ffn_cfg()),
        }
    return p


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _init_layer_cache(batch: int, max_len: int, spec: LayerSpec,
                      cfg: ModelConfig, dtype) -> dict:
    c: dict = {}
    if spec.mixer == "attn":
        c["mixer"] = init_kv_cache(batch, max_len, cfg.attn_cfg(spec), dtype)
    else:
        c["mixer"] = init_ssm_cache(batch, cfg.mamba_cfg(), jnp.float32)
    if spec.shared_block:
        c["shared"] = init_kv_cache(batch, max_len, cfg.shared_attn_cfg(),
                                    dtype)
    return c


def init_cache(batch: int, max_len: int, cfg: ModelConfig,
               dtype=jnp.bfloat16):
    """Decode cache matching the layer layout (stacked when scanned)."""
    if cfg.scanned:
        group = {f"l{i}": _init_layer_cache(batch, max_len, spec, cfg, dtype)
                 for i, spec in enumerate(cfg.group_specs)}
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_groups,) + x.shape).copy(),
            group)
    return [_init_layer_cache(batch, max_len, spec, cfg, dtype)
            for spec in cfg.layers]


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def _rope_tables(cfg: ModelConfig, positions: jax.Array) -> dict:
    """positions: (B, T) or (3, B, T) for mrope."""
    if cfg.rope_kind == "mrope":
        cos, sin = mrope_angles(positions, cfg.head_dim,
                                cfg.mrope_sections, cfg.rope_theta)
        return {"default": (cos, sin), "local": (cos, sin)}
    cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
    tables = {"default": (cos, sin)}
    if cfg.rope_local_theta != cfg.rope_theta:
        cl, sl = rope_angles(positions, cfg.head_dim, cfg.rope_local_theta)
        tables["local"] = (cl, sl)
    else:
        tables["local"] = (cos, sin)
    return tables


def _apply_shared(shared_params: dict, h: jax.Array, cfg: ModelConfig,
                  rope: dict, cache, cache_index, fill_len=None):
    cos, sin = rope["default"]
    a, new_cache = attention_apply(
        shared_params["attn"], h,
        cfg.shared_attn_cfg(), cos=cos, sin=sin,
        cache=cache, cache_index=cache_index, fill_len=fill_len,
        norm_params=shared_params["norm1"])
    h = h + a
    return ffn_block_apply(shared_params["ffn"], shared_params["norm2"], h,
                           cfg.shared_ffn_cfg()), new_cache


def _apply_layer(lp: dict, spec: LayerSpec, cfg: ModelConfig, h: jax.Array,
                 rope: dict, shared_params: Optional[dict],
                 cache: Optional[dict], cache_index, fill_len=None):
    new_cache: dict = {}
    aux = jnp.zeros((), jnp.float32)
    if spec.shared_block:
        sc = None if cache is None else cache.get("shared")
        h, nsc = _apply_shared(shared_params, h, cfg, rope, sc, cache_index,
                               fill_len)
        if cache is not None:
            new_cache["shared"] = nsc
    mc = None if cache is None else cache["mixer"]
    if spec.mixer == "attn":
        # pre-attention norm applied INSIDE the layer (norm_params): the
        # fused-qkv path folds it into the projection kernels' prologue,
        # the fallback is bitwise the old rms_norm-then-apply composition.
        cos, sin = rope[spec.rope]
        y, nmc = attention_apply(lp["mixer"], h, cfg.attn_cfg(spec),
                                 cos=cos, sin=sin, cache=mc,
                                 cache_index=cache_index, fill_len=fill_len,
                                 norm_params=lp["norm1"])
    else:
        y, nmc = mamba2_apply(lp["mixer"], rms_norm(lp["norm1"], h),
                              cfg.mamba_cfg(), cache=mc)
    if cache is not None:
        new_cache["mixer"] = nmc
    h = h + y
    if spec.mlp == "dense":
        h = ffn_block_apply(lp["mlp"], lp["norm2"], h, cfg.ffn_cfg())
    elif spec.mlp == "moe":
        y, aux = moe_apply(lp["mlp"], rms_norm(lp["norm2"], h), cfg.moe_cfg())
        h = h + y
    return h, (new_cache if cache is not None else None), aux


def forward(params: dict, cfg: ModelConfig, *,
            tokens: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            positions: Optional[jax.Array] = None,
            cache=None, cache_index=None, fill_len=None):
    """Returns (logits, new_cache, aux_loss).

    Three modes:

    * training — ``cache=None``: plain causal forward over the full batch.
    * chunked prefill — ``cache`` given with ``T > 1`` tokens: one causal
      forward whose attention layers also write K/V into the cache starting
      at ``cache_index`` (attention-only stacks; SSM caches are strictly
      single-token).  ``fill_len`` (scalar or per-row ``(B,)``) gives true
      prompt lengths when the batch is right-padded to a bucket length.
    * decode — ``T == 1`` with ``cache`` + ``cache_index`` (scalar, or
      per-row ``(B,)`` for continuous batching).
    """
    if tokens is not None:
        h = embed(params["embed"], tokens, cfg.embed_cfg(), cfg.dtype,
                  onehot=cfg.embed_onehot)
        B, T = tokens.shape
    else:
        h = embeds.astype(cfg.dtype)
        B, T = embeds.shape[:2]
    if cfg.embed_scale != 1.0:
        h = h * jnp.asarray(cfg.embed_scale, h.dtype)
    # under an activation_sharding(full_batch=True) context: tokens enter
    # replicated over "model" (cheap — int32); pinning the gather OUTPUT
    # model-replicated first makes the vocab-sharded gather lower as
    # mask+all-reduce, and the follow-up full-mesh-DP reshard is a free
    # local slice (EXPERIMENTS §Perf I6).
    h = constrain(h, "btd")
    h = constrain(h, "batch_full")

    if positions is None:
        if cache_index is None:
            base = jnp.arange(T)
        else:
            ci = jnp.asarray(cache_index)
            off = ci[:, None] if ci.ndim == 1 else ci
            base = off + jnp.arange(T)
        positions = jnp.broadcast_to(base, (B, T))
        if cfg.rope_kind == "mrope":
            positions = jnp.broadcast_to(positions, (3, B, T))
    rope = _rope_tables(cfg, positions)

    shared_params = params.get("shared")
    aux_total = jnp.zeros((), jnp.float32)

    use_scan = cfg.scanned or (cfg.uniform_ignoring_shared
                               and cache is None)
    if use_scan:
        specs = cfg.group_specs
        hybrid = cfg.has_shared_block and not cfg.scanned

        def group_body(carry, xs):
            h, aux = carry
            if hybrid:
                if cache is None:
                    gp, flag = xs
                    gc = {f"l{i}": None for i in range(len(specs))}
                else:
                    gp, gc, flag = xs
                # shared attn+ffn applied only at flagged groups; lax.cond
                # keeps the shared block compiled ONCE for all depths.
                h = jax.lax.cond(
                    flag,
                    lambda hh: _apply_shared(shared_params, hh, cfg, rope,
                                             None, cache_index)[0],
                    lambda hh: hh, h)
            else:
                if cache is None:
                    gp = xs
                    gc = {f"l{i}": None for i in range(len(specs))}
                else:
                    gp, gc = xs
            new_gc = {}
            for i, spec in enumerate(specs):
                h, nc, a = _apply_layer(gp[f"l{i}"], spec, cfg, h, rope,
                                        shared_params, gc[f"l{i}"],
                                        cache_index, fill_len)
                new_gc[f"l{i}"] = nc
                aux = aux + a
            out = None if cache is None else new_gc
            return (h, aux), out

        body = group_body
        if cfg.remat and cache is None:
            body = jax.checkpoint(group_body, prevent_cse=False)
        xs = [params["layers"]]
        if cache is not None:
            xs.append(cache)
        if hybrid:
            xs.append(jnp.asarray(cfg.shared_flags))
        xs = tuple(xs) if len(xs) > 1 else xs[0]
        (h, aux_total), new_cache = jax.lax.scan(body, (h, aux_total), xs)
    else:
        new_cache = [] if cache is not None else None
        stacked = cfg.stacked_params
        for i, spec in enumerate(cfg.layers):
            if stacked:
                lp = jax.tree.map(lambda x: x[i], params["layers"])["l0"]
            else:
                lp = params["layers"][i]
            lc = None if cache is None else cache[i]
            step = _apply_layer
            if cfg.remat and cache is None:
                step = jax.checkpoint(_apply_layer,
                                      static_argnums=(1, 2), prevent_cse=False)
            h, nc, a = step(lp, spec, cfg, h, rope, shared_params, lc,
                            cache_index, fill_len)
            aux_total = aux_total + a
            if cache is not None:
                new_cache.append(nc)

    h = rms_norm(params["final_norm"], h)
    logits = unembed(params["embed"], h.astype(cfg.logits_dtype),
                     cfg.embed_cfg())
    return logits, new_cache, aux_total


def model_param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
