"""Causal-LM heads over the transformer composer: loss, prefill, decode."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T

__all__ = ["lm_loss", "train_metrics", "prefill", "decode_step"]

MOE_AUX_COEF = 0.01


def lm_loss(params: dict, batch: dict, cfg: T.ModelConfig
            ) -> Tuple[jax.Array, dict]:
    """Next-token cross-entropy.  batch: {tokens|embeds, labels, [mask],
    [positions]}.  labels align with inputs (already shifted by the data
    pipeline).  Returns (loss, metrics)."""
    kw = {}
    if cfg.input_kind == "tokens":
        kw["tokens"] = batch["tokens"]
    else:
        kw["embeds"] = batch["embeds"]
    logits, _, aux = T.forward(params, cfg, positions=batch.get("positions"),
                               **kw)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll * mask) / denom
    loss = ce + MOE_AUX_COEF * aux
    metrics = {"loss": loss, "ce": ce, "aux": aux,
               "ppl_proxy": jnp.exp(jnp.clip(ce, max=20.0))}
    return loss, metrics


def train_metrics(metrics: dict) -> dict:
    return {k: float(v) for k, v in metrics.items()}


def prefill(params: dict, cfg: T.ModelConfig, *, max_len: int,
            tokens: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            cache_dtype=jnp.bfloat16):
    """Run the prompt through the model and build a decode-ready cache.

    Implementation: token-parallel forward for the logits (cheap, chunked
    attention), then the cache is filled by replaying K/V projections —
    here we simply run the forward in cache-filling mode token-block-wise
    is avoided: we recompute K/V per layer via a cache-free forward and
    scatter.  For simplicity and exactness we fill the cache by running
    decode over the prompt with ``lax.scan`` (state-carried); logits of the
    last position are returned.  O(T) steps but each is O(1) — acceptable
    for the CPU validation path; the dry-run lowers the fused variant.
    """
    if tokens is not None:
        B, T_len = tokens.shape
    else:
        B, T_len = embeds.shape[:2]
    cache = T.init_cache(B, max_len, cfg, cache_dtype)

    def step(carry, t):
        cache = carry
        if tokens is not None:
            tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)
            logits, cache, _ = T.forward(params, cfg, tokens=tok,
                                         cache=cache, cache_index=t)
        else:
            emb = jax.lax.dynamic_slice_in_dim(embeds, t, 1, axis=1)
            logits, cache, _ = T.forward(params, cfg, embeds=emb,
                                         cache=cache, cache_index=t)
        return cache, logits[:, 0]

    cache, logits_all = jax.lax.scan(step, cache, jnp.arange(T_len))
    return logits_all[-1], cache


def decode_step(params: dict, cfg: T.ModelConfig, token: jax.Array,
                cache, cache_index: jax.Array):
    """One-token decode.  token: (B,) int32 -> (logits (B, V), new cache)."""
    logits, cache, _ = T.forward(params, cfg, tokens=token[:, None],
                                 cache=cache, cache_index=cache_index)
    return logits[:, 0], cache
