"""Causal-LM heads over the transformer composer: loss, prefill, decode."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T

__all__ = ["lm_loss", "train_metrics", "prefill", "decode_step"]

MOE_AUX_COEF = 0.01


def lm_loss(params: dict, batch: dict, cfg: T.ModelConfig
            ) -> Tuple[jax.Array, dict]:
    """Next-token cross-entropy.  batch: {tokens|embeds, labels, [mask],
    [positions]}.  labels align with inputs (already shifted by the data
    pipeline).  Returns (loss, metrics)."""
    kw = {}
    if cfg.input_kind == "tokens":
        kw["tokens"] = batch["tokens"]
    else:
        kw["embeds"] = batch["embeds"]
    logits, _, aux = T.forward(params, cfg, positions=batch.get("positions"),
                               **kw)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll * mask) / denom
    loss = ce + MOE_AUX_COEF * aux
    metrics = {"loss": loss, "ce": ce, "aux": aux,
               "ppl_proxy": jnp.exp(jnp.clip(ce, max=20.0)),
               # mask weight of this batch: lets gradient accumulation
               # recover the global masked mean from per-microbatch means
               # (train/step.py averages ce weighted by ce_weight).
               "ce_weight": denom}
    return loss, metrics


def train_metrics(metrics: dict) -> dict:
    return {k: float(v) for k, v in metrics.items()}


def prefill(params: dict, cfg: T.ModelConfig, *, max_len: int,
            tokens: Optional[jax.Array] = None,
            embeds: Optional[jax.Array] = None,
            cache_dtype=jnp.bfloat16,
            length: Optional[jax.Array] = None):
    """Run the prompt through the model and build a decode-ready cache.

    Attention-only stacks take the chunked path: ONE token-parallel forward
    (chunked causal attention) that also writes K/V into the cache —
    O(T^2/chunk) attention work instead of the O(T)-sequential
    decode-replay scan.  ``length`` (scalar or per-row ``(B,)``) gives true
    prompt lengths when the batch is right-padded to a common bucket
    length; last-position logits are gathered at ``length - 1`` per row and
    windowed ring caches only fill real positions.

    Stacks with SSM mixers (mamba2/zamba2 hybrids) keep the exact
    decode-replay ``lax.scan`` (SSM caches are strictly single-token);
    ``length`` is unsupported there.
    """
    if tokens is not None:
        B, T_len = tokens.shape
    else:
        B, T_len = embeds.shape[:2]
    cache = T.init_cache(B, max_len, cfg, cache_dtype)
    attn_only = all(s.mixer == "attn" for s in cfg.layers)

    if attn_only:
        kw = {"tokens": tokens} if tokens is not None else {"embeds": embeds}
        logits, cache, _ = T.forward(
            params, cfg, cache=cache,
            cache_index=jnp.asarray(0, jnp.int32),
            fill_len=length, **kw)
        if length is None:
            return logits[:, -1], cache
        last = jnp.broadcast_to(jnp.asarray(length), (B,)) - 1
        out = jnp.take_along_axis(logits, last[:, None, None], axis=1)
        return out[:, 0], cache

    if length is not None:
        raise NotImplementedError(
            "per-row prompt lengths need an attention-only stack "
            "(SSM caches prefill via the sequential scan)")

    def step(carry, t):
        cache = carry
        if tokens is not None:
            tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)
            logits, cache, _ = T.forward(params, cfg, tokens=tok,
                                         cache=cache, cache_index=t)
        else:
            emb = jax.lax.dynamic_slice_in_dim(embeds, t, 1, axis=1)
            logits, cache, _ = T.forward(params, cfg, embeds=emb,
                                         cache=cache, cache_index=t)
        return cache, logits[:, 0]

    cache, logits_all = jax.lax.scan(step, cache, jnp.arange(T_len))
    return logits_all[-1], cache


def decode_step(params: dict, cfg: T.ModelConfig, token: jax.Array,
                cache, cache_index: jax.Array):
    """One-token decode.  token: (B,) int32 -> (logits (B, V), new cache)."""
    logits, cache, _ = T.forward(params, cfg, tokens=token[:, None],
                                 cache=cache, cache_index=cache_index)
    return logits[:, 0], cache
