"""GRU language model (paper §6 host architecture).

embed -> N stacked GRU layers (dense or SPM recurrent/input maps) -> head.
Used by the char-LM reproduction and the §6 gradient-flow tests.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.layers.embedding import EmbeddingConfig, embed, init_embedding, unembed
from repro.layers.gru import GRUConfig, gru_apply, init_gru

__all__ = ["GRULMConfig", "init_gru_lm", "gru_lm_forward", "gru_lm_loss"]


@dataclasses.dataclass(frozen=True)
class GRULMConfig:
    vocab_size: int
    d_model: int
    n_layers: int = 1
    linear_impl: str = "dense"
    spm_stages: Optional[int] = None
    spm_backward: str = "custom"
    spm_use_kernel: Optional[bool] = None
    param_dtype: Any = jnp.float32

    def gru_cfg(self) -> GRUConfig:
        return GRUConfig(d_in=self.d_model, d_hidden=self.d_model,
                         linear_impl=self.linear_impl,
                         spm_stages=self.spm_stages,
                         spm_backward=self.spm_backward,
                         spm_use_kernel=self.spm_use_kernel,
                         param_dtype=self.param_dtype)

    def embed_cfg(self) -> EmbeddingConfig:
        return EmbeddingConfig(vocab_size=self.vocab_size,
                               d_model=self.d_model, tie_output=True,
                               param_dtype=self.param_dtype)


def init_gru_lm(key: jax.Array, cfg: GRULMConfig) -> dict:
    ke, *kls = jax.random.split(key, 1 + cfg.n_layers)
    return {"embed": init_embedding(ke, cfg.embed_cfg()),
            "grus": [init_gru(k, cfg.gru_cfg()) for k in kls]}


def gru_lm_forward(params: dict, tokens: jax.Array, cfg: GRULMConfig
                   ) -> jax.Array:
    h = embed(params["embed"], tokens, cfg.embed_cfg())
    for gp in params["grus"]:
        h = h + gru_apply(gp, h, cfg.gru_cfg())[0]
    return unembed(params["embed"], h, cfg.embed_cfg())


def gru_lm_loss(params: dict, batch: dict, cfg: GRULMConfig
                ) -> Tuple[jax.Array, dict]:
    logits = gru_lm_forward(params, batch["tokens"], cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], -1)[..., 0]
    loss = jnp.mean(nll)
    return loss, {"loss": loss, "bpc": loss / jnp.log(2.0)}
