"""MLP classifier + compositional teacher (paper §9.1–§9.2).

Student: ``logits = W2 · φ(mix(x))`` where ``mix`` is dense or SPM via the
linear factory — exactly the two students compared in Table 1.  The
teacher is an SPM → ReLU → dense map whose argmax produces hard labels.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.linear import LinearConfig, init_linear, linear_apply

__all__ = ["MLPConfig", "init_mlp", "mlp_apply", "mlp_loss"]


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    n_features: int
    n_classes: int
    width: Optional[int] = None        # None -> square (width = n_features)
    linear_impl: str = "dense"         # the swept knob
    spm_stages: Optional[int] = None
    spm_backward: str = "custom"
    spm_use_kernel: Optional[bool] = None
    param_dtype: Any = jnp.float32

    @property
    def d_hidden(self) -> int:
        return self.width or self.n_features

    @property
    def mix(self) -> LinearConfig:
        return LinearConfig(
            d_in=self.n_features, d_out=self.d_hidden,
            impl=self.linear_impl, n_stages=self.spm_stages,
            backward=self.spm_backward, use_kernel=self.spm_use_kernel,
            param_dtype=self.param_dtype)

    @property
    def head(self) -> LinearConfig:
        # classification head stays dense in BOTH students (paper teacher is
        # SPM -> ReLU -> Dense; the head is not a square mixer).
        return LinearConfig(d_in=self.d_hidden, d_out=self.n_classes,
                            impl="dense", param_dtype=self.param_dtype)


def init_mlp(key: jax.Array, cfg: MLPConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {"mix": init_linear(k1, cfg.mix),
            "head": init_linear(k2, cfg.head)}


def mlp_apply(params: dict, x: jax.Array, cfg: MLPConfig) -> jax.Array:
    # spmlint: allow[SPM007] paper's §9.1 student spec, not a fusible block
    h = jax.nn.relu(linear_apply(params["mix"], x, cfg.mix))
    return linear_apply(params["head"], h, cfg.head)


def mlp_loss(params: dict, batch: dict, cfg: MLPConfig
             ) -> Tuple[jax.Array, dict]:
    logits = mlp_apply(params, batch["x"], cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1)[:, 0]
    loss = jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
    return loss, {"loss": loss, "acc": acc}
