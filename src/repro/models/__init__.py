"""Model compositions: block-pattern transformer, causal-LM heads, the
paper's MLP students/teacher, and the §6 GRU-LM."""

from repro.models.transformer import (  # noqa: F401
    LayerSpec, ModelConfig, init_model, forward, init_cache,
    model_param_count,
)
from repro.models.causal_lm import (  # noqa: F401
    lm_loss, prefill, decode_step,
)
from repro.models.mlp import MLPConfig, init_mlp, mlp_apply, mlp_loss  # noqa: F401
from repro.models.gru_lm import (  # noqa: F401
    GRULMConfig, init_gru_lm, gru_lm_forward, gru_lm_loss,
)
