"""Shared jaxpr traversal for compile contracts and tests.

Every kernel-path invariant the repo proves at the jaxpr level — no XLA
pad on the fused path, a single output slice on the sharded rectangular
path, pallas_call counts matching the run plan — needs the same
traversal: walk every equation of every inner jaxpr **except** the
bodies of ``pallas_call`` equations (the whole point of the kernels is
that masking/padding lives inside them), and remember whether an
equation sits inside a ``shard_map`` body (per-shard ops) or outside it
(replicated glue).

Before this module, that traversal existed as ad-hoc closures in
``tests/test_kernels.py`` and ``tests/test_distributed.py``; both now
import from here, as do the declarative contracts in
``repro.analysis.contracts``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator, List, Optional, Sequence, Tuple

__all__ = [
    "WalkedEqn",
    "iter_eqns",
    "collect_eqns",
    "split_shard_map",
    "primitive_names",
    "count_primitive",
    "feature_axis_slices",
    "activation_pads",
]


@dataclasses.dataclass(frozen=True)
class WalkedEqn:
    """One equation plus where the walk found it.

    ``in_shard_map`` is True for equations inside a ``shard_map`` body
    (i.e. per-shard program), False for the outer replicated program.
    ``depth`` counts enclosing sub-jaxprs (0 = top level).
    """

    eqn: Any
    in_shard_map: bool
    depth: int

    @property
    def name(self) -> str:
        return self.eqn.primitive.name


def _sub_jaxprs(eqn: Any) -> Iterator[Any]:
    """Yield the inner jaxprs referenced by an equation's params.

    Handles both ClosedJaxpr-valued params (``v.jaxpr.eqns``) and raw
    Jaxpr-valued params (``v.eqns``), plus lists/tuples of either (e.g.
    ``cond``'s ``branches``).
    """
    for v in eqn.params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for u in vs:
            if hasattr(u, "jaxpr") and hasattr(u.jaxpr, "eqns"):
                yield u.jaxpr
            elif hasattr(u, "eqns"):
                yield u


def iter_eqns(jaxpr: Any, *, in_shard_map: bool = False,
              depth: int = 0) -> Iterator[WalkedEqn]:
    """Depth-first walk over every equation of ``jaxpr`` and its inner
    jaxprs, **never descending into pallas_call bodies** (in-kernel ops
    are exactly what the contracts must not see).

    Accepts a Jaxpr or ClosedJaxpr.
    """
    if hasattr(jaxpr, "jaxpr"):        # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield WalkedEqn(eqn, in_shard_map, depth)
        if eqn.primitive.name == "pallas_call":
            continue
        sub_shard = in_shard_map or eqn.primitive.name == "shard_map"
        for sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, in_shard_map=sub_shard,
                                 depth=depth + 1)


def collect_eqns(jaxpr: Any) -> List[WalkedEqn]:
    """List form of :func:`iter_eqns`."""
    return list(iter_eqns(jaxpr))


def split_shard_map(jaxpr: Any) -> Tuple[List[Any], List[Any]]:
    """(inside, outside): raw equations inside shard_map bodies vs not.

    Drop-in replacement for the old ``_walk_eqns`` helper in
    ``tests/test_distributed.py``.
    """
    inside: List[Any] = []
    outside: List[Any] = []
    for we in iter_eqns(jaxpr):
        (inside if we.in_shard_map else outside).append(we.eqn)
    return inside, outside


def primitive_names(jaxpr: Any) -> List[str]:
    """All primitive names reached by the walk (duplicates kept)."""
    return [we.name for we in iter_eqns(jaxpr)]


def count_primitive(jaxpr: Any, name: str,
                    pred: Optional[Callable[[WalkedEqn], bool]] = None) -> int:
    """Number of equations named ``name`` (optionally filtered)."""
    return sum(1 for we in iter_eqns(jaxpr)
               if we.name == name and (pred is None or pred(we)))


def feature_axis_slices(jaxpr: Any, *,
                        rows: Optional[int] = None) -> List[Tuple[tuple, tuple]]:
    """(in_shape, out_shape) of every ``slice`` narrowing the last axis
    of a rank-2 array.  With ``rows``, only activation-shaped slices
    (leading dim == rows) are reported.

    The rectangular kernel path is allowed exactly ONE of these (the
    sharded (rows, n) -> (rows, out_width) output extraction) and the
    unsharded path none at all.
    """
    out = []
    for we in iter_eqns(jaxpr):
        if we.name != "slice":
            continue
        iv = we.eqn.invars[0].aval
        ov = we.eqn.outvars[0].aval
        if len(iv.shape) != 2 or iv.shape[-1] == ov.shape[-1]:
            continue
        if rows is not None and iv.shape[0] != rows:
            continue
        out.append((tuple(iv.shape), tuple(ov.shape)))
    return out


def activation_pads(jaxpr: Any, *, rows: int) -> List[Tuple[tuple, tuple]]:
    """(in_shape, out_shape) of every ``pad`` whose output is an
    activation-shaped rank-2 array (leading dim == rows).

    The sharded backward is allowed exactly one — the even-slab
    cotangent transport (rows, out_width) -> (rows, n)."""
    out = []
    for we in iter_eqns(jaxpr):
        if we.name != "pad":
            continue
        ov = we.eqn.outvars[0].aval
        if len(ov.shape) == 2 and ov.shape[0] == rows:
            out.append((tuple(we.eqn.invars[0].aval.shape),
                        tuple(ov.shape)))
    return out
