"""``python -m repro.analysis check`` — contracts over the whole config zoo.

Enumerates every registry architecture's SPM linear operators (attention
q/kv/o, FFN up/gate/down, MoE expert/shared FFNs, Mamba2 in/out
projections, the zamba2 shared block) at BOTH scales (full ``CONFIG`` and
``SMOKE``), plus the kernel-bench rectangular hot shapes; dedupes them
into operator cells; and runs the full contract registry
(``repro.analysis.contracts``) on each cell x executor variant:

* ``unfused`` / ``fused``          — jaxpr-level contracts (trace only,
  cheap even at full registry widths),
* ``shard_serial`` / ``shard_overlap`` — the distributed executor over a
  4-way "model" mesh of forced host devices; cells up to ``--hlo-cap``
  also compile and run the HLO contracts (permute-only, bounded backward
  gather).

Emits a machine-readable JSON report; ``benchmarks/check_regression.py
--contract-report`` gates CI on it (a config dropping off the kernel path
is a regression even when modeled bytes look fine).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Iterator, List, Optional, Tuple

import jax

from repro.analysis.contracts import Artifacts, Cell, run_cell
from repro.core import eligibility
from repro.core.linear import LinearConfig

__all__ = ["enumerate_operators", "build_cells", "run_check", "main"]

N_SHARDS = 4          # mesh width for sharded variants (8 forced devices
                      # leave headroom; matches tests/test_distributed.py)
HLO_N_CAP = 512       # compile sharded HLO only for n <= cap: XLA compile
                      # time scales hard with width, and the invariant is
                      # schedule-shaped, not width-shaped


def _model_linears(mc) -> Iterator[Tuple[str, LinearConfig]]:
    """(role, LinearConfig) for every distinct projection of one model."""
    seen = []
    for spec in mc.group_specs:
        if spec in seen:
            continue
        seen.append(spec)
        if spec.mixer == "attn":
            ac = mc.attn_cfg(spec)
            yield "attn_q", ac.q_proj
            yield "attn_kv", ac.kv_proj
            yield "attn_o", ac.o_proj
        elif spec.mixer == "mamba":
            sc = mc.mamba_cfg()
            yield "mamba_in", sc.in_proj
            yield "mamba_out", sc.out_proj
        if spec.mlp == "dense":
            fc = mc.ffn_cfg()
            yield "ffn_up", fc.up
            yield "ffn_gate", fc.gate
            yield "ffn_down", fc.down
        elif spec.mlp == "moe":
            moe = mc.moe_cfg()
            ec = moe.expert_ffn
            yield "moe_expert_up", ec.up
            yield "moe_expert_down", ec.down
            if mc.shared_d_ff:
                sc = moe.shared_ffn
                yield "moe_shared_up", sc.up
                yield "moe_shared_down", sc.down
    if mc.has_shared_block:
        ac = mc.shared_attn_cfg()
        yield "shared_attn_q", ac.q_proj
        yield "shared_attn_o", ac.o_proj
        if mc.shared_attn_d_ff:
            fc = mc.shared_ffn_cfg()
            yield "shared_ffn_up", fc.up
            yield "shared_ffn_down", fc.down


def enumerate_operators(archs: Optional[List[str]] = None, *,
                        scales: Tuple[str, ...] = ("smoke", "full"),
                        include_bench_shapes: bool = True) -> Dict:
    """Dedupe the zoo into operator specs.

    Returns {op_key: {"d_in", "d_out", "n_stages", "schedule", "backward",
    "archs": set, "roles": set}} where op_key is the shape/schedule tuple.
    """
    from repro.configs import registry
    archs = list(archs) if archs else list(registry.ARCH_IDS)
    ops: Dict[tuple, dict] = {}

    def add(arch: str, role: str, lc: LinearConfig):
        if not lc.is_spm:
            return
        key = (lc.d_in, lc.d_out, lc.n_stages, lc.schedule, lc.backward)
        rec = ops.setdefault(key, {
            "d_in": lc.d_in, "d_out": lc.d_out, "n_stages": lc.n_stages,
            "schedule": lc.schedule, "backward": lc.backward,
            "archs": set(), "roles": set()})
        rec["archs"].add(arch)
        rec["roles"].add(role)

    for arch in archs:
        for scale in scales:
            mc = (registry.get_smoke(arch) if scale == "smoke"
                  else registry.get_config(arch))
            for role, lc in _model_linears(mc):
                add(f"{arch}[{scale}]", role, lc)
    if include_bench_shapes:
        _add_bench_shapes(ops)
    return ops


# The kernel-bench rectangular hot shapes, duplicated here as data (the
# benchmarks/ tree is not an importable package from src/): kept in sync
# by tests/test_analysis.py::test_bench_rect_shapes_in_driver.
BENCH_RECT_SHAPES = [
    ("qkv_fused", 256, 768),
    ("ffn_up", 256, 1024),
    ("ffn_down", 1024, 256),
    ("lm_head", 384, 2048),
]


def _add_bench_shapes(ops: Dict) -> None:
    for tag, d_in, d_out in BENCH_RECT_SHAPES:
        lc = LinearConfig(d_in=d_in, d_out=d_out, impl="spm_general",
                          backward="custom")
        key = (lc.d_in, lc.d_out, lc.n_stages, lc.schedule, lc.backward)
        rec = ops.setdefault(key, {
            "d_in": lc.d_in, "d_out": lc.d_out, "n_stages": lc.n_stages,
            "schedule": lc.schedule, "backward": lc.backward,
            "archs": set(), "roles": set()})
        rec["archs"].add("kernel_bench")
        rec["roles"].add(f"rect_{tag}")


def build_cells(ops: Dict, *, n_shards: int = N_SHARDS,
                hlo_cap: int = HLO_N_CAP,
                device_count: Optional[int] = None
                ) -> Tuple[List[Cell], List[dict]]:
    """Expand operator specs into per-variant cells + skip records."""
    device_count = (jax.device_count() if device_count is None
                    else device_count)
    cells: List[Cell] = []
    skipped: List[dict] = []
    for key in sorted(ops):
        rec = ops[key]
        d_in, d_out = rec["d_in"], rec["d_out"]
        base = dict(d_in=d_in, d_out=d_out, n_stages=rec["n_stages"],
                    schedule=rec["schedule"], backward=rec["backward"],
                    archs=tuple(sorted(rec["archs"])),
                    roles=tuple(sorted(rec["roles"])))
        lc = LinearConfig(d_in=d_in, d_out=d_out, impl="spm_general",
                          n_stages=rec["n_stages"], schedule=rec["schedule"],
                          backward=rec["backward"])
        n = lc.n
        stem = (f"{d_in}x{d_out}"
                + (f"-L{rec['n_stages']}" if rec["n_stages"] else "")
                + f"-{rec['schedule']}")
        for variant in ("unfused", "fused"):
            cells.append(Cell(cell_id=f"{stem}/{variant}", variant=variant,
                              **base))
        # sharded variants: structural eligibility first, devices second
        scfg = LinearConfig(**{**base_kwargs(base), "n_shards": n_shards,
                               "use_kernel": True}).spm_config()
        if not eligibility.sharded_eligible(scfg):
            reason = (f"n={n} not divisible by {n_shards}"
                      if n % n_shards else "schedule not shard-executable")
            skipped.append({"op": stem, "variants": "shard_*",
                            "reason": reason})
        elif device_count < n_shards:
            skipped.append({"op": stem, "variants": "shard_*",
                            "reason": f"{device_count} devices < {n_shards}"})
        else:
            hlo = n <= hlo_cap
            for variant in ("shard_serial", "shard_overlap"):
                cells.append(Cell(cell_id=f"{stem}/{variant}",
                                  variant=variant, n_shards=n_shards,
                                  compile_hlo=hlo, **base))
            if not hlo:
                skipped.append({"op": stem, "variants": "shard_* hlo",
                                "reason": f"n={n} > hlo_cap={hlo_cap} "
                                          "(jaxpr contracts only)"})
    return cells, skipped


def base_kwargs(base: dict) -> dict:
    return dict(d_in=base["d_in"], d_out=base["d_out"], impl="spm_general",
                n_stages=base["n_stages"], schedule=base["schedule"],
                backward=base["backward"])


def run_check(archs: Optional[List[str]] = None, *,
              scales: Tuple[str, ...] = ("smoke", "full"),
              n_shards: int = N_SHARDS, hlo_cap: int = HLO_N_CAP,
              include_bench_shapes: bool = True,
              verbose: bool = True) -> Dict:
    """Run the full contract matrix; return the report dict."""
    ops = enumerate_operators(archs, scales=scales,
                              include_bench_shapes=include_bench_shapes)
    cells, skipped = build_cells(ops, n_shards=n_shards, hlo_cap=hlo_cap)
    report_cells: Dict[str, dict] = {}
    failures: List[str] = []
    for cell in cells:
        art = Artifacts(cell)
        results = run_cell(cell, art)
        ok = all(v == "pass" for v in results.values())
        for cname, v in results.items():
            if v != "pass":
                failures.append(f"{cell.cell_id}/{cname}: {v}")
        engaged = results.get("kernel-path-engaged", "n/a")
        report_cells[cell.cell_id] = {
            "archs": list(cell.archs), "roles": list(cell.roles),
            "d_in": cell.d_in, "d_out": cell.d_out, "n": art.n,
            "n_stages": art.scfg.n_stages, "schedule": cell.schedule,
            "variant": cell.variant, "n_shards": cell.n_shards,
            "rows": cell.rows, "hlo": cell.compile_hlo,
            "kernel_path": (cell.variant != "unfused"
                            and engaged == "pass"),
            "contracts": results,
        }
        if verbose:
            status = "ok " if ok else "FAIL"
            print(f"[{status}] {cell.cell_id}  "
                  f"({len(results)} contracts)", flush=True)
    report = {
        "schema": 1,
        "generated_by": "repro.analysis.driver",
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "n_shards": n_shards,
        "hlo_cap": hlo_cap,
        "counts": {
            "operators": len(ops),
            "cells": len(cells),
            "contract_checks": sum(len(c["contracts"])
                                   for c in report_cells.values()),
            "failures": len(failures),
            "skipped_variants": len(skipped),
        },
        "cells": report_cells,
        "skipped": skipped,
        "failures": failures,
    }
    return report


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis check",
        description="lower every registry config x executor variant on "
                    "CPU and check the compile-contract registry")
    ap.add_argument("--archs", default=None,
                    help="comma-separated arch ids (default: all)")
    ap.add_argument("--scales", default="smoke,full",
                    help="config scales to enumerate (smoke,full)")
    ap.add_argument("--n-shards", type=int, default=N_SHARDS)
    ap.add_argument("--hlo-cap", type=int, default=HLO_N_CAP,
                    help="compile sharded HLO only for n <= cap")
    ap.add_argument("--no-bench-shapes", action="store_true",
                    help="skip the kernel-bench rectangular hot shapes")
    ap.add_argument("--report", default="ANALYSIS_contracts.json",
                    help="JSON report path ('' to skip)")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    archs = args.archs.split(",") if args.archs else None
    scales = tuple(s for s in args.scales.split(",") if s)
    report = run_check(archs, scales=scales, n_shards=args.n_shards,
                       hlo_cap=args.hlo_cap,
                       include_bench_shapes=not args.no_bench_shapes,
                       verbose=not args.quiet)
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"wrote {args.report}")
    c = report["counts"]
    print(f"contract check: {c['cells']} cells / {c['operators']} operators, "
          f"{c['contract_checks']} checks, {c['failures']} failures, "
          f"{c['skipped_variants']} skipped variant groups "
          f"(devices={report['device_count']})")
    for f_ in report["failures"]:
        print(f"  FAIL {f_}", file=sys.stderr)
    return 1 if report["failures"] else 0


if __name__ == "__main__":
    sys.exit(main())
