"""spmlint — AST-level rules for repo-specific hazards.

Generic style is ruff's job (pyproject.toml); these rules encode things
that have already bitten or regressed once in THIS codebase and that no
generic linter knows about:

=======  ==================================================================
rule     invariant
=======  ==================================================================
SPM001   eligibility predicates are DEFINED only in ``core/eligibility.py``
         (the PR 5 consolidation must not silently re-grow inline copies
         in ``spm.py`` / ``spm_shard.py``); importing them is fine.
SPM002   no ``jnp.pad`` / ``lax.dynamic_slice`` in kernel-path modules
         (``core/spm.py``, ``kernels/``, ``parallel/spm_shard.py``) — the
         rectangular story is in-VMEM masking, not XLA ops.  The four
         legitimate sites (the XLA fallback, row padding, the cotangent
         transport) carry ``# spmlint: allow[SPM002]`` pragmas.
SPM003   no pallas / pltpu imports or usage outside ``kernels/`` — the
         kernel boundary is an API boundary.
SPM004   no Python ``if``/``while`` on a traced ``jnp.``/``lax.`` call
         result inside ``src/repro`` — that's a retrace (or a
         ConcretizationError) waiting to happen; use ``jnp.where`` /
         ``lax.cond``.
SPM005   no wall-clock or unseeded-global-RNG nondeterminism in chaos /
         bench code (``train/chaos.py``, ``benchmarks/``): chaos schedules
         and modeled bench numbers must be bit-reproducible.
         (``time.perf_counter`` timing and ``np.random.default_rng(seed)``
         are fine; ``time.time`` / ``datetime.now`` / bare ``random.*`` /
         ``np.random.*`` module-state calls are not.)
SPM006   every ``__all__`` name is actually bound at module top level, and
         every public module has a docstring.
SPM007   no norm/activation composed directly around an SPM entry point
         (``rms_norm(...)`` / ``silu|gelu|relu`` wrapping ``spm_apply`` /
         ``linear_apply`` / ``ffn_apply``, or fed into one) outside
         ``layers/`` and ``kernels/`` — those compositions belong to the
         fused block entries (``ffn_block_apply``, the fused-qkv path),
         where ``resolve_block_fuse`` can lower them as ONE Pallas
         region; inlining them elsewhere silently forfeits the fusion.
=======  ==================================================================

Suppress a finding with a line pragma: ``# spmlint: allow[SPM002]``
(comma-separate several rule ids; add a reason after the bracket).
"""

from __future__ import annotations

import argparse
import ast
import dataclasses
import re
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

__all__ = ["Violation", "RULES", "lint_file", "lint_paths", "main"]

RULES = {
    "SPM001": "eligibility predicate defined outside core/eligibility.py",
    "SPM002": "XLA pad/dynamic_slice in a kernel-path module",
    "SPM003": "pallas/pltpu usage outside kernels/",
    "SPM004": "Python branch on a traced jnp/lax expression",
    "SPM005": "wall-clock / global-RNG nondeterminism in chaos or bench code",
    "SPM006": "__all__ name unbound at module top level, or missing docstring",
    "SPM007": "norm/activation composed around an SPM entry outside layers/",
}

# names whose definitions must live in core/eligibility.py only
ELIGIBILITY_NAMES = frozenset({
    "kernel_eligible", "use_fused_kernel", "sharded_eligible",
    "resolve_shard_kernel", "resolve_overlap", "resolve_rdma",
    "plan_steps", "overlap_segments",
    "block_fusion_eligible", "resolve_block_fuse",
})

# SPM007: SPM operator entry points and the norm/activation wrappers the
# block megakernel fuses around them
_SPM_ENTRY_CALLS = frozenset({"spm_apply", "linear_apply", "ffn_apply"})
_SPM_WRAPPER_CALLS = frozenset({"rms_norm", "silu", "gelu", "relu"})

# SPM002 scope: the modules whose perf story is "no XLA pad/slice"
_KERNEL_PATH_PARTS = ("core/spm.py", "parallel/spm_shard.py")
_KERNEL_PATH_DIRS = ("kernels/",)

# SPM002 forbidden dotted-call suffixes
_PAD_SLICE_CALLS = ("jnp.pad", "np.pad", "numpy.pad", "jax.numpy.pad",
                    "lax.dynamic_slice", "lax.dynamic_slice_in_dim",
                    "jax.lax.dynamic_slice", "jax.lax.dynamic_slice_in_dim")

# SPM004: static (trace-time) jnp/lax attributes that are safe in a branch
_STATIC_SAFE_ATTRS = frozenset({"issubdtype", "dtype", "result_type",
                                "iinfo", "finfo", "ndim", "shape"})

# SPM005 scope + verdicts
_NONDET_CALLS = ("time.time", "datetime.now", "datetime.utcnow",
                 "datetime.datetime.now", "datetime.datetime.utcnow")
_ALLOWED_RANDOM = ("np.random.default_rng", "numpy.random.default_rng",
                   "np.random.Generator", "numpy.random.Generator",
                   "random.Random")

_PRAGMA_RE = re.compile(r"#\s*spmlint:\s*allow\[([A-Z0-9,\s]+)\]")


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str
    line: int
    rule: str
    msg: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.msg}"


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for an Attribute/Name chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _pragmas(source: str) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(line)
        if m:
            out[i] = {r.strip() for r in m.group(1).split(",") if r.strip()}
    return out


def _posix(path: Path) -> str:
    return path.as_posix()


def _in_kernel_path(rel: str) -> bool:
    if any(rel.endswith(p) for p in _KERNEL_PATH_PARTS):
        return True
    return any(f"/{d}" in rel or rel.startswith(d)
               for d in _KERNEL_PATH_DIRS)


def _in_kernels_dir(rel: str) -> bool:
    return "/kernels/" in rel or rel.startswith("kernels/")


def _in_block_entry_scope(rel: str) -> bool:
    """Paths allowed to compose norm/activation around SPM entries: the
    layer modules that own the fused block entries, and kernels/ itself
    (the fused implementations + their fallback mirrors)."""
    return ("/layers/" in rel or rel.startswith("layers/")
            or _in_kernels_dir(rel))


def _in_chaos_or_bench(rel: str) -> bool:
    return rel.endswith("train/chaos.py") or "benchmarks/" in rel \
        or rel.startswith("benchmarks")


def _in_src_repro(rel: str) -> bool:
    return "src/repro/" in rel or rel.startswith("repro/")


class _Checker(ast.NodeVisitor):
    def __init__(self, rel: str, pragmas: Dict[int, Set[str]]):
        self.rel = rel
        self.pragmas = pragmas
        self.found: List[Violation] = []

    def _emit(self, rule: str, node: ast.AST, msg: str) -> None:
        # a pragma suppresses findings on its own line or the line below
        # (comment-above style for statements that don't fit one line)
        line = getattr(node, "lineno", 0)
        if rule in self.pragmas.get(line, ()) \
                or rule in self.pragmas.get(line - 1, ()):
            return
        self.found.append(Violation(self.rel, line, rule, msg))

    # -- SPM001: inline eligibility predicate definitions ----------------

    def _check_def_name(self, node: ast.AST, name: str) -> None:
        if (name in ELIGIBILITY_NAMES
                and _in_src_repro(self.rel)
                and not self.rel.endswith("core/eligibility.py")):
            self._emit("SPM001", node,
                       f"definition of eligibility predicate {name!r} "
                       "outside core/eligibility.py (import it instead)")

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._check_def_name(node, node.name)
        self.generic_visit(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._check_def_name(node, node.name)
        self.generic_visit(node)

    def visit_Assign(self, node: ast.Assign) -> None:
        if isinstance(node.value, ast.Lambda):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    self._check_def_name(node, t.id)
        self.generic_visit(node)

    # -- SPM002 / SPM005: forbidden dotted calls -------------------------

    def visit_Call(self, node: ast.Call) -> None:
        dotted = _dotted(node.func)
        if dotted:
            if _in_kernel_path(self.rel) and any(
                    dotted == c or dotted.endswith("." + c)
                    for c in _PAD_SLICE_CALLS):
                self._emit("SPM002", node,
                           f"{dotted}(...) on the kernel path (in-VMEM "
                           "masking, not XLA pad/slice; pragma if this is "
                           "a documented fallback site)")
            if _in_chaos_or_bench(self.rel):
                if any(dotted == c or dotted.endswith("." + c)
                       for c in _NONDET_CALLS):
                    self._emit("SPM005", node,
                               f"{dotted}(...) is wall-clock state in "
                               "chaos/bench logic (use a seeded schedule "
                               "or time.perf_counter for pure timing)")
                elif (dotted.startswith(("random.", "np.random.",
                                         "numpy.random."))
                      and dotted not in _ALLOWED_RANDOM):
                    self._emit("SPM005", node,
                               f"{dotted}(...) uses global RNG state in "
                               "chaos/bench logic (use "
                               "np.random.default_rng(seed))")
            if (_in_src_repro(self.rel)
                    and not _in_block_entry_scope(self.rel)):
                self._check_block_composition(node, dotted)
        self.generic_visit(node)

    # -- SPM007: norm/activation around SPM entries outside layers/ ------

    def _check_block_composition(self, node: ast.Call, dotted: str) -> None:
        leaf = dotted.rsplit(".", 1)[-1]
        if leaf not in _SPM_ENTRY_CALLS | _SPM_WRAPPER_CALLS:
            return
        inner = set()
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            for sub in ast.walk(arg):
                if isinstance(sub, ast.Call):
                    d = _dotted(sub.func)
                    if d:
                        inner.add(d.rsplit(".", 1)[-1])
        if leaf in _SPM_WRAPPER_CALLS and inner & _SPM_ENTRY_CALLS:
            self._emit("SPM007", node,
                       f"{leaf}() wraps {sorted(inner & _SPM_ENTRY_CALLS)} "
                       "outside layers/ — this composition belongs to a "
                       "fused block entry (ffn_block_apply / fused-qkv) so "
                       "block fusion can engage")
        elif leaf in _SPM_ENTRY_CALLS and inner & _SPM_WRAPPER_CALLS:
            self._emit("SPM007", node,
                       f"{leaf}() consumes "
                       f"{sorted(inner & _SPM_WRAPPER_CALLS)} output "
                       "outside layers/ — this composition belongs to a "
                       "fused block entry (ffn_block_apply / fused-qkv) so "
                       "block fusion can engage")

    # -- SPM003: pallas outside kernels/ ---------------------------------

    def visit_Import(self, node: ast.Import) -> None:
        if not _in_kernels_dir(self.rel) and _in_src_repro(self.rel):
            for alias in node.names:
                if ".pallas" in alias.name:
                    self._emit("SPM003", node,
                               f"import {alias.name} outside kernels/")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        if not _in_kernels_dir(self.rel) and _in_src_repro(self.rel):
            if ".pallas" in mod or mod.endswith("pallas"):
                self._emit("SPM003", node,
                           f"from {mod} import ... outside kernels/")
            else:
                for alias in node.names:
                    if alias.name == "pallas" or alias.name == "pltpu":
                        self._emit("SPM003", node,
                                   f"from {mod} import {alias.name} "
                                   "outside kernels/")
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if (not _in_kernels_dir(self.rel) and _in_src_repro(self.rel)
                and isinstance(node.value, ast.Name)
                and node.value.id == "pltpu"):
            self._emit("SPM003", node,
                       f"pltpu.{node.attr} outside kernels/")
        self.generic_visit(node)

    # -- SPM004: Python branch on traced expressions ---------------------

    def _check_branch(self, node) -> None:
        if not _in_src_repro(self.rel):
            return
        for sub in ast.walk(node.test):
            if isinstance(sub, ast.Call):
                dotted = _dotted(sub.func) or ""
                root, _, attr = dotted.partition(".")
                if root in ("jnp", "lax") or dotted.startswith(
                        ("jax.numpy.", "jax.lax.")):
                    leaf = dotted.rsplit(".", 1)[-1]
                    if leaf not in _STATIC_SAFE_ATTRS:
                        self._emit("SPM004", node,
                                   f"branching on {dotted}(...): a traced "
                                   "value in Python control flow retraces "
                                   "or raises under jit (use jnp.where / "
                                   "lax.cond)")

    def visit_If(self, node: ast.If) -> None:
        self._check_branch(node)
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        self._check_branch(node)
        self.generic_visit(node)


def _check_all_consistency(rel: str, tree: ast.Module,
                           pragmas: Dict[int, Set[str]]) -> List[Violation]:
    """SPM006 over one parsed module."""
    out: List[Violation] = []
    if not _in_src_repro(rel):
        return out
    if (ast.get_docstring(tree) is None
            and Path(rel).name != "__init__.py"):
        v = Violation(rel, 1, "SPM006", "module has no docstring")
        if "SPM006" not in pragmas.get(1, ()):
            out.append(v)
    bound: Set[str] = set()
    all_node = None
    all_names: List[str] = []
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            bound.add(node.name)
        elif isinstance(node, ast.Import):
            for alias in node.names:
                bound.add((alias.asname or alias.name).split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            for alias in node.names:
                bound.add(alias.asname or alias.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                for sub in ast.walk(t):
                    if isinstance(sub, ast.Name):
                        bound.add(sub.id)
            if (len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id == "__all__"
                    and isinstance(node.value, (ast.List, ast.Tuple))):
                all_node = node
                for elt in node.value.elts:
                    if isinstance(elt, ast.Constant) \
                            and isinstance(elt.value, str):
                        all_names.append(elt.value)
        elif isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name):
            bound.add(node.target.id)
        elif isinstance(node, (ast.If, ast.Try)):
            # conditional defs (TYPE_CHECKING / fallback imports) bind too
            for sub in ast.walk(node):
                if isinstance(sub, (ast.FunctionDef, ast.ClassDef)):
                    bound.add(sub.name)
                elif isinstance(sub, ast.Import):
                    for alias in sub.names:
                        bound.add((alias.asname or alias.name).split(".")[0])
                elif isinstance(sub, ast.ImportFrom):
                    for alias in sub.names:
                        bound.add(alias.asname or alias.name)
                elif isinstance(sub, ast.Assign):
                    for t in sub.targets:
                        if isinstance(t, ast.Name):
                            bound.add(t.id)
    if all_node is not None:
        line = all_node.lineno
        for name in all_names:
            if name not in bound and "SPM006" not in pragmas.get(line, ()):
                out.append(Violation(rel, line, "SPM006",
                                     f"__all__ lists unbound name {name!r}"))
    return out


def lint_file(path: Path, root: Optional[Path] = None) -> List[Violation]:
    """Run every rule over one file."""
    rel = _posix(path if root is None else path.relative_to(root))
    source = path.read_text()
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Violation(rel, e.lineno or 0, "SPM000",
                          f"syntax error: {e.msg}")]
    pragmas = _pragmas(source)
    checker = _Checker(rel, pragmas)
    checker.visit(tree)
    return checker.found + _check_all_consistency(rel, tree, pragmas)


def _repo_root() -> Path:
    # src/repro/analysis/lint.py -> repo root three levels above src/
    return Path(__file__).resolve().parents[3]


def lint_paths(paths: Optional[Sequence[str]] = None) -> List[Violation]:
    """Lint the given files/dirs (default: src/repro + benchmarks)."""
    root = _repo_root()
    if not paths:
        paths = [p for p in (root / "src" / "repro", root / "benchmarks")
                 if Path(p).exists()]
    found: List[Violation] = []
    for p in paths:
        p = Path(p)
        files: Iterable[Path] = (sorted(p.rglob("*.py")) if p.is_dir()
                                 else [p])
        for f in files:
            try:
                rel_root = root if f.resolve().is_relative_to(root) else None
            except AttributeError:            # py<3.9 — not our floor
                rel_root = None
            found.extend(lint_file(f.resolve(), rel_root))
    return found


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis lint",
        description="spmlint: repo-specific AST rules "
                    "(SPM001..SPM007; see repro/analysis/lint.py)")
    ap.add_argument("paths", nargs="*",
                    help="files or directories (default: src/repro, "
                         "benchmarks)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)
    if args.list_rules:
        for rule, desc in RULES.items():
            print(f"{rule}  {desc}")
        return 0
    violations = lint_paths(args.paths)
    for v in violations:
        print(v)
    n = len(violations)
    print(f"spmlint: {n} violation(s)" if n else "spmlint: clean")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
