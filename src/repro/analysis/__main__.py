"""CLI dispatch: ``python -m repro.analysis {check,lint}``.

``check`` forces 8 virtual host devices via XLA_FLAGS **before** jax
initializes (this entry point is a fresh process, so the flag is safe to
set here — unlike inside pytest, where conftest forbids it), then runs
the contract driver.  ``lint`` runs spmlint and never imports jax.
"""

from __future__ import annotations

import os
import sys

_USAGE = """usage: python -m repro.analysis <command> [args]

commands:
  check   lower every registry config x executor variant on CPU and run
          the compile-contract registry (repro.analysis.driver)
  lint    spmlint: repo-specific AST rules (repro.analysis.lint)

run a command with --help for its options."""

_DEVICE_FLAG = "--xla_force_host_platform_device_count"


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print(_USAGE)
        return 0
    cmd, rest = argv[0], argv[1:]
    if cmd == "lint":
        from repro.analysis.lint import main as lint_main
        return lint_main(rest)
    if cmd == "check":
        if "jax" not in sys.modules and _DEVICE_FLAG not in os.environ.get(
                "XLA_FLAGS", ""):
            os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                       + f" {_DEVICE_FLAG}=8").strip()
        from repro.analysis.driver import main as check_main
        return check_main(rest)
    print(f"unknown command {cmd!r}\n\n{_USAGE}", file=sys.stderr)
    return 2


if __name__ == "__main__":
    sys.exit(main())
