"""Static-analysis subsystem: compile contracts, spmlint, retrace sentinel.

Three tools that prove the repo's kernel-path invariants hold over the
WHOLE config zoo instead of the handful of shapes the tests happen to
build (docs/analysis.md):

* ``repro.analysis.contracts`` + ``driver`` — declarative compile
  contracts checked against the jaxpr/HLO lowering of every registry
  config x executor variant (``python -m repro.analysis check``), built
  on the shared walker libraries ``jaxpr_walk`` / ``hlo_match``.
* ``repro.analysis.lint`` — spmlint, AST rules for repo-specific hazards
  (``python -m repro.analysis lint``).
* ``repro.analysis.recompile`` — the jit-cache-miss sentinel
  (``assert_compiles``), wired into tests and the kernel bench.

Submodules are imported lazily: ``lint`` stays importable (and fast)
without initializing jax.
"""

from __future__ import annotations

import importlib

_SUBMODULES = ("jaxpr_walk", "hlo_match", "contracts", "driver", "lint",
               "recompile")

__all__ = list(_SUBMODULES)


def __getattr__(name: str):
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.analysis.{name}")
    raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_SUBMODULES))
