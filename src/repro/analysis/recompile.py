"""Recompilation sentinel: jit-cache-miss tracking as a hard assertion.

A silent retrace is the repo's most expensive invisible bug class: the
train step, the decode step, and the bench timing loops are all designed
so their variants (chaos poison on/off, per-request sampling params,
elastic restarts) ride TRACED operands of one compiled function — if a
refactor turns one of those into a Python-level branch or an unstable
static argument, everything still returns the right numbers, just 10-100x
slower and with a compile stall in the serving tick.

``CompileTracker`` watches the executable caches of specific
``jax.jit``-wrapped callables (their ``_cache_size()``), so the count is
exact and per-function — unlike global backend-compile event counts,
which include XLA-internal jits.  ``assert_compiles(n, name=fn)`` is the
assertion form wired into tests and ``benchmarks/kernel_bench.py``.
"""

from __future__ import annotations

import contextlib
from typing import Callable, Dict, Iterator

__all__ = ["RetraceError", "CompileTracker", "assert_compiles",
           "assert_no_recompile"]


class RetraceError(AssertionError):
    """A watched jitted callable compiled a different number of times than
    the sentinel's contract allows."""


def _cache_size(fn: Callable) -> int:
    size = getattr(fn, "_cache_size", None)
    if size is None:
        raise TypeError(
            f"{fn!r} exposes no _cache_size(); pass the jax.jit-wrapped "
            "callable itself (not a plain function or its __wrapped__)")
    return size()


class CompileTracker:
    """Track new executable-cache entries of named jitted callables.

    >>> step = jax.jit(f)
    >>> with CompileTracker(step=step) as t:
    ...     step(a); step(b)
    >>> t.new_compiles()          # {"step": 1} if b hit a's executable
    """

    def __init__(self, **fns: Callable):
        if not fns:
            raise ValueError("CompileTracker needs at least one fn to watch")
        self._fns: Dict[str, Callable] = dict(fns)
        self._start: Dict[str, int] = {}

    def __enter__(self) -> "CompileTracker":
        self._start = {k: _cache_size(f) for k, f in self._fns.items()}
        return self

    def __exit__(self, *exc) -> None:
        return None

    def new_compiles(self) -> Dict[str, int]:
        """Cache entries added per watched fn since ``__enter__``."""
        if not self._start:
            raise RuntimeError("tracker not entered")
        return {k: _cache_size(f) - self._start[k]
                for k, f in self._fns.items()}


@contextlib.contextmanager
def assert_compiles(expected: int, **fns: Callable) -> Iterator[CompileTracker]:
    """Assert each watched jitted callable adds EXACTLY ``expected`` cache
    entries inside the block (0 compile errors tolerated: fewer means the
    call never ran or was already cached when the contract said fresh,
    more means a retrace).

    >>> with assert_compiles(1, train=jstep):
    ...     jstep(state, batch, poison=0.0)
    ...     jstep(state, batch, poison=1.0)   # traced operand: same exe
    """
    tracker = CompileTracker(**fns)
    with tracker:
        yield tracker
    got = tracker.new_compiles()
    bad = {k: v for k, v in got.items() if v != expected}
    if bad:
        detail = ", ".join(f"{k}={v}" for k, v in sorted(bad.items()))
        hint = (" — a traced-operand variant is retracing (unstable static "
                "argument / Python branch on a traced value?)"
                if any(v > expected for v in bad.values()) else
                " — the call never ran, or was already cached when the "
                "contract said fresh")
        raise RetraceError(
            f"expected exactly {expected} compile(s) per watched fn, "
            f"got {detail}{hint}")


@contextlib.contextmanager
def assert_no_recompile(**fns: Callable) -> Iterator[CompileTracker]:
    """Assert the block adds ZERO cache entries — the steady-state form
    (everything already warmed up before entering)."""
    with assert_compiles(0, **fns) as tracker:
        yield tracker
