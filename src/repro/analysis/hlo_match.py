"""Structured matchers over post-optimization HLO text.

``launch.hlo_analysis`` owns the low-level regexes (shape-bytes parsing,
per-kind collective byte totals); this module layers the *assertions*
the sharded executor's acceptance story is made of — "communication is
collective-permute only", "backward gathers stay bounded by the O(nL)
parameter bytes" — so tests and the contract driver state the invariant
once instead of re-deriving it from raw byte dicts.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

from repro.launch.hlo_analysis import (_COLL_OPS, _LINE_RE, collective_bytes,
                                       parse_shape_bytes)

__all__ = [
    "CollectiveOp",
    "list_collectives",
    "permute_only_violations",
    "assert_permute_only",
    "bwd_gather_bound_violations",
    "assert_bwd_gather_bounded",
]


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One collective instruction (async -start/-done pairs collapse to a
    single entry at the -start line)."""

    kind: str          # e.g. "collective-permute"
    bytes: int         # result-shape bytes
    line_no: int       # 1-based line in the HLO text
    is_async: bool     # written as <kind>-start(...)
    text: str          # the stripped instruction line


_ASYNC_START_RE = re.compile(
    r"(" + "|".join(_COLL_OPS) + r")-start\(")


def list_collectives(hlo_text: str) -> List[CollectiveOp]:
    """Every collective in the module, counted once, in program order."""
    out: List[CollectiveOp] = []
    for i, line in enumerate(hlo_text.splitlines(), start=1):
        m = _LINE_RE.search(line)
        if not m or "-done(" in line:
            continue
        out.append(CollectiveOp(kind=m.group(2),
                                bytes=parse_shape_bytes(m.group(1)),
                                line_no=i,
                                is_async=bool(_ASYNC_START_RE.search(line)),
                                text=line.strip()))
    return out


def permute_only_violations(hlo_text: str, *,
                            require_permute: bool = True,
                            allow: Optional[Dict[str, int]] = None
                            ) -> List[str]:
    """Check the "collective-permute-only" invariant; return violations.

    Every non-permute collective kind must move zero bytes, except kinds
    listed in ``allow`` (kind -> byte budget, e.g. the backward's bounded
    all-gather).  With ``require_permute`` the module must actually
    contain a permute (guards against the vacuous pass where the whole
    sharded path was constant-folded or never engaged).
    """
    cb = collective_bytes(hlo_text)
    allow = allow or {}
    bad: List[str] = []
    if require_permute and cb["collective-permute"] == 0:
        bad.append("no collective-permute found (sharded path not engaged?)")
    for kind in _COLL_OPS:
        if kind == "collective-permute":
            continue
        budget = allow.get(kind, 0)
        if cb[kind] > budget:
            bad.append(f"{kind} moves {cb[kind]} bytes "
                       f"(budget {budget})")
    return bad


def assert_permute_only(hlo_text: str, *, require_permute: bool = True,
                        allow: Optional[Dict[str, int]] = None) -> None:
    """AssertionError form of :func:`permute_only_violations`."""
    bad = permute_only_violations(hlo_text, require_permute=require_permute,
                                  allow=allow)
    assert not bad, "; ".join(bad)


def bwd_gather_bound_violations(hlo_text: str, *, param_bytes: int,
                                extra_gather_bytes: int = 0) -> List[str]:
    """Check the backward-pass collective budget; return violations.

    The sharded custom_vjp assembles replicated O(nL) parameter grads, so
    its all-gather may move up to ``2 * param_bytes`` plus the inherent
    jit-boundary replication allowances in ``extra_gather_bytes`` (e.g.
    an indivisible-width g_x output).  all-reduce must be absent: a
    feature-axis all-reduce is exactly the dense-transport regression the
    executor exists to avoid.
    """
    cb = collective_bytes(hlo_text)
    bad: List[str] = []
    if cb["all-reduce"] != 0:
        bad.append(f"all-reduce moves {cb['all-reduce']} bytes "
                   "(feature-axis reduction on the backward path)")
    budget = 2 * param_bytes + extra_gather_bytes
    if cb["all-gather"] > budget:
        bad.append(f"all-gather moves {cb['all-gather']} bytes "
                   f"> bound {budget} (2*param_bytes={2 * param_bytes} "
                   f"+ allowed {extra_gather_bytes})")
    return bad


def assert_bwd_gather_bounded(hlo_text: str, *, param_bytes: int,
                              extra_gather_bytes: int = 0) -> None:
    """AssertionError form of :func:`bwd_gather_bound_violations`."""
    bad = bwd_gather_bound_violations(hlo_text, param_bytes=param_bytes,
                                      extra_gather_bytes=extra_gather_bytes)
    assert not bad, "; ".join(bad)
