"""Declarative compile contracts over the SPM kernel path.

Each contract states one lowering invariant the repo's perf story rests
on — "the fused rectangular path emits no XLA pad", "sharded
communication is collective-permute only", "the pallas_call count equals
the run plan" — as a named, registered check over the jaxpr/HLO
artifacts of one operator *cell* (a ``(d_in, d_out, schedule, variant)``
point of the config zoo).  ``python -m repro.analysis check``
(``repro.analysis.driver``) enumerates every registry architecture's
linear operators, builds the artifacts once per cell, and runs every
applicable contract, so an invariant proven today on the handful of
shapes a test happens to build is proven on the WHOLE zoo tomorrow.

The walkers live in ``repro.analysis.jaxpr_walk`` / ``hlo_match`` — the
same libraries ``tests/test_kernels.py`` and ``tests/test_distributed.py``
assert with, so a contract failure here and a test failure there are the
same fact observed twice.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import jaxpr_walk
from repro.analysis.hlo_match import (bwd_gather_bound_violations,
                                      permute_only_violations)
from repro.core import eligibility
from repro.core.linear import LinearConfig, init_linear, linear_apply
from repro.kernels.ops import plan_runs, plan_runs_for_rows

__all__ = ["Cell", "Artifacts", "Contract", "CONTRACTS", "contract",
           "run_cell", "VARIANTS"]

VARIANTS = ("unfused", "fused", "shard_serial", "shard_overlap")

_KEY = jax.random.PRNGKey(0)


@dataclasses.dataclass(frozen=True)
class Cell:
    """One operator x executor-variant point of the config zoo."""

    cell_id: str
    d_in: int
    d_out: int
    variant: str                      # one of VARIANTS
    n_stages: Optional[int] = None    # None -> default_n_stages(n)
    schedule: str = "butterfly"
    backward: str = "custom"
    rows: int = 8
    n_shards: int = 1                 # > 1 for shard_* variants
    compile_hlo: bool = False         # build compiled-HLO artifacts too
    archs: Tuple[str, ...] = ()       # registry archs using this operator
    roles: Tuple[str, ...] = ()       # e.g. ("attn_q", "ffn_up")

    @property
    def sharded(self) -> bool:
        return self.variant in ("shard_serial", "shard_overlap")

    def linear_config(self) -> LinearConfig:
        return LinearConfig(
            d_in=self.d_in, d_out=self.d_out, impl="spm_general",
            n_stages=self.n_stages, schedule=self.schedule,
            backward=self.backward,
            n_shards=self.n_shards if self.sharded else 1,
            use_kernel=(self.variant != "unfused"),
            overlap=(self.variant == "shard_overlap"))


class Artifacts:
    """Lazily-built lowering artifacts of one cell.

    jaxpr artifacts are traces (``jax.make_jaxpr``, cheap even at full
    registry widths); HLO artifacts actually compile the cell
    (``jax.jit(...).lower(...).compile()``) and are only built for cells
    flagged ``compile_hlo``.  Sharded cells build under an
    ``activation_sharding`` mesh context over the first ``n_shards`` host
    devices — the driver process forces 8 via XLA_FLAGS.
    """

    def __init__(self, cell: Cell):
        self.cell = cell
        self.lc = cell.linear_config()
        self.scfg = self.lc.spm_config()

    # -- inputs ----------------------------------------------------------

    @functools.cached_property
    def params(self):
        return init_linear(_KEY, self.lc)

    @functools.cached_property
    def x(self):
        return jax.random.normal(_KEY, (self.cell.rows, self.cell.d_in),
                                 jnp.float32)

    def _fwd_fn(self) -> Callable:
        lc = self.lc
        return lambda p, x: linear_apply(p, x, lc)

    def _loss_fn(self) -> Callable:
        fwd = self._fwd_fn()
        return jax.grad(lambda p, x: jnp.sum(fwd(p, x) ** 2),
                        argnums=(0, 1))

    def _mesh_ctx(self):
        if not self.cell.sharded:
            return contextlib.nullcontext()
        import numpy as np
        from jax.sharding import Mesh

        from repro.parallel.ctx import activation_sharding
        k = self.cell.n_shards
        devs = jax.devices()
        if len(devs) < k:
            raise RuntimeError(
                f"cell {self.cell.cell_id} needs {k} devices, have "
                f"{len(devs)} (run via `python -m repro.analysis check`, "
                "which forces 8 host devices)")
        mesh = Mesh(np.asarray(devs[:k]).reshape(k), ("model",))
        return activation_sharding(mesh, shard_feature=True)

    # -- plan facts ------------------------------------------------------

    @property
    def n(self) -> int:
        return self.lc.n

    @functools.cached_property
    def strides(self) -> Tuple[int, ...]:
        return tuple(self.scfg.pairing.strides())

    @functools.cached_property
    def runs(self):
        """Unsharded fused-kernel run plan — row-count-aware, matching
        what ``spm_stack_fused`` executes for this cell's ``rows`` (f32
        activations): tiny-row cells plan under the widened decode tile
        cap."""
        return plan_runs_for_rows(self.n, self.strides, self.cell.rows, 4)

    @functools.cached_property
    def steps(self):
        """Sharded schedule steps (raises ValueError if not shardable)."""
        return eligibility.plan_steps(self.n, self.strides,
                                      self.cell.n_shards)

    @functools.cached_property
    def param_bytes(self) -> int:
        """Replicated O(nL) parameter bytes (f32 coeffs + diag/bias)."""
        return (self.scfg.n_stages * (self.n // 2) * 4 + 3 * self.n) * 4

    # -- jaxpr artifacts -------------------------------------------------

    @functools.cached_property
    def jaxpr_fwd(self):
        with self._mesh_ctx():
            return jax.make_jaxpr(self._fwd_fn())(self.params, self.x)

    @functools.cached_property
    def jaxpr_bwd(self):
        with self._mesh_ctx():
            return jax.make_jaxpr(self._loss_fn())(self.params, self.x)

    @functools.cached_property
    def jaxpr_q8(self):
        """Trace of the int8-native inference entry
        (``spm_stack_fused_q8``) over this cell's square operator core:
        int8 rows + per-block scales in, int8 rows + scales out.  Only
        built for cells the quant eligibility rule admits (uniform-tile
        run plan under int8 byte width)."""
        from repro.core.spm import stage_coeffs
        from repro.kernels import quant as Q
        from repro.kernels.ops import spm_stack_fused_q8
        cf = stage_coeffs(self.params, self.scfg)
        rows = self.cell.rows
        runs = plan_runs_for_rows(self.n, self.strides, rows, 1)
        qx, xs = Q.quantize_blocks(
            jax.random.normal(_KEY, (rows, self.n), jnp.float32),
            rows, runs[0][1])
        p = self.params
        fn = lambda qx, xs, cf, di, do, b: spm_stack_fused_q8(
            qx, xs, cf, self.strides, d_in=di, d_out=do, bias=b)
        return jax.make_jaxpr(fn)(qx, xs, cf, p["d_in"], p["d_out"],
                                  p["bias"])

    @functools.cached_property
    def jaxpr_block(self):
        """Trace of the fused residual block built around this cell's
        operator (``kernels/ops.spm_block_fused``): RMS-norm prologue ->
        this operator as the up stack -> gelu epilogue -> the mirror
        operator (d_out x d_in) as the down stack -> residual-add on the
        store.  Only built for cells the block-fusion eligibility rule
        admits for BOTH stacks (single full-width run each, see
        ``core/eligibility.block_fusion_eligible``)."""
        from repro.core.linear import spm_block_operands
        from repro.kernels.ops import spm_block_fused
        cell = self.cell
        lc2 = _mirror_config(cell)
        up = spm_block_operands(self.params, self.lc)
        down = spm_block_operands(init_linear(jax.random.PRNGKey(1), lc2),
                                  lc2)
        s1, s2 = up["strides"], down["strides"]
        mid, out = cell.d_out, cell.d_in
        fn = lambda x, g, c1, di1, do1, b1, c2, di2, do2, b2: \
            spm_block_fused(
                x, coeffs1=c1, d_in1=di1, d_out1=do1, bias1=b1,
                strides1=s1, gamma=g, coeffs2=c2, d_in2=di2, d_out2=do2,
                bias2=b2, strides2=s2, activation="gelu", residual=True,
                mid_width=mid, out_width=out)
        gamma = jnp.ones((cell.d_in,), jnp.float32)
        return jax.make_jaxpr(fn)(
            self.x, gamma, up["coeffs"], up["d_in"], up["d_out"],
            up["bias"], down["coeffs"], down["d_in"], down["d_out"],
            down["bias"])

    # -- HLO artifacts (compiled; compile_hlo cells only) ----------------

    @functools.cached_property
    def hlo_fwd(self) -> str:
        with self._mesh_ctx():
            return jax.jit(self._fwd_fn()).lower(
                self.params, self.x).compile().as_text()

    @functools.cached_property
    def hlo_bwd(self) -> str:
        with self._mesh_ctx():
            return jax.jit(self._loss_fn()).lower(
                self.params, self.x).compile().as_text()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Contract:
    name: str
    doc: str
    applies: Callable[[Cell], bool]
    check: Callable[[Cell, Artifacts], List[str]]


CONTRACTS: Dict[str, Contract] = {}


def contract(name: str, *, applies: Callable[[Cell], bool]):
    """Register a contract: ``check(cell, artifacts) -> [violation, ...]``
    (empty list = pass).  ``applies`` gates which cells it runs on."""
    def deco(fn):
        CONTRACTS[name] = Contract(name=name, doc=(fn.__doc__ or "").strip(),
                                   applies=applies, check=fn)
        return fn
    return deco


def run_cell(cell: Cell, art: Optional[Artifacts] = None) -> Dict[str, str]:
    """Run every applicable contract; return {name: "pass" | "fail: ..."}.

    A contract that raises is reported as ``error:`` — an artifact that
    cannot even build is itself a finding, not a skip.
    """
    art = art or Artifacts(cell)
    out: Dict[str, str] = {}
    for name, c in CONTRACTS.items():
        if not c.applies(cell):
            continue
        try:
            bad = c.check(cell, art)
        except Exception as e:  # noqa: BLE001 — reported, never swallowed
            out[name] = f"error: {type(e).__name__}: {e}"
            continue
        out[name] = "pass" if not bad else "fail: " + "; ".join(bad)
    return out


def _kernel_variant(cell: Cell) -> bool:
    return cell.variant != "unfused"


def _mirror_config(cell: Cell) -> LinearConfig:
    """The down-stack operator of the block built around ``cell``: the
    same schedule family transposed to (d_out -> d_in)."""
    return LinearConfig(
        d_in=cell.d_out, d_out=cell.d_in, impl="spm_general",
        n_stages=cell.n_stages, schedule=cell.schedule,
        backward=cell.backward)


def _block_cell(cell: Cell) -> bool:
    """Cells whose operator can anchor a fused residual block: the fused
    unsharded variant, with both the operator and its mirror structurally
    block-fusible at the same kernel width."""
    if cell.variant != "fused":
        return False
    lc1 = cell.linear_config()
    lc2 = _mirror_config(cell)
    if lc1.n != lc2.n:
        return False
    s1, s2 = lc1.spm_config(), lc2.spm_config()
    if not (eligibility.kernel_eligible(s1, s1.pairing)
            and eligibility.kernel_eligible(s2, s2.pairing)):
        return False
    return eligibility.block_fusion_eligible(
        lc1.n, s1.pairing.strides(), s2.pairing.strides(), "gelu")


def _hlo_sharded(cell: Cell) -> bool:
    return cell.sharded and cell.compile_hlo


# ---------------------------------------------------------------------------
# contracts
# ---------------------------------------------------------------------------

@contract("kernel-path-no-pad", applies=_kernel_variant)
def _c_no_pad(cell: Cell, art: Artifacts) -> List[str]:
    """The kernel-path forward lowers with NO XLA ``pad`` and no
    activation gather: rectangular zero-fill happens in VMEM inside the
    boundary runs (tests/test_kernels.py proves it for one shape; this
    proves it per zoo cell)."""
    bad = []
    pads = [we for we in jaxpr_walk.iter_eqns(art.jaxpr_fwd)
            if we.name == "pad"]
    if pads:
        shapes = [tuple(we.eqn.outvars[0].aval.shape) for we in pads]
        bad.append(f"XLA pad survived on the forward path: {shapes}")
    rows = cell.rows
    for we in jaxpr_walk.iter_eqns(art.jaxpr_fwd):
        if we.name == "gather":
            shape = we.eqn.outvars[0].aval.shape
            if len(shape) == 2 and shape[0] == rows:
                bad.append(f"activation gather on the kernel path: {shape}")
    return bad


@contract("kernel-path-single-output-slice", applies=_kernel_variant)
def _c_single_slice(cell: Cell, art: Artifacts) -> List[str]:
    """Feature-axis activation slices on the forward path: none for the
    unsharded fused kernel (the last run stores only d_out columns); for
    the sharded executor exactly ONE — the local (rows, n) ->
    (rows, d_out) output extraction — and only when d_out < n."""
    slices = jaxpr_walk.feature_axis_slices(art.jaxpr_fwd, rows=cell.rows)
    rect_out = cell.d_out < art.n
    if cell.sharded:
        expect = [((cell.rows, art.n), (cell.rows, cell.d_out))] \
            if rect_out else []
    else:
        expect = []
    if slices != expect:
        return [f"feature-axis slices {slices} != expected {expect}"]
    return []


@contract("bwd-single-cotangent-pad", applies=_kernel_variant)
def _c_bwd_pad(cell: Cell, art: Artifacts) -> List[str]:
    """Activation-shaped pads on the backward path: none unsharded; for
    the sharded rectangular executor exactly one — the even-slab
    cotangent transport (rows, d_out) -> (rows, n), the output slice's
    transpose (fused into the slab reshard)."""
    pads = jaxpr_walk.activation_pads(art.jaxpr_bwd, rows=cell.rows)
    rect_out = cell.d_out < art.n
    if cell.sharded and rect_out:
        expect = [((cell.rows, cell.d_out), (cell.rows, art.n))]
    else:
        expect = []
    if pads != expect:
        return [f"activation pads {pads} != expected {expect}"]
    return []


@contract("kernel-path-engaged", applies=lambda cell: True)
def _c_engaged(cell: Cell, art: Artifacts) -> List[str]:
    """The eligibility resolution and the lowered jaxpr agree: a cell
    declared on the kernel path actually contains pallas_call equations
    (inside the shard_map body for sharded variants), an unfused cell
    contains none, and a sharded cell's cross stages lower to ppermute.
    This is THE "silently fell off the fast path" detector ("Compute
    Better Spent": structured wins evaporate off the fast path)."""
    bad = []
    inside, outside = jaxpr_walk.split_shard_map(art.jaxpr_fwd)
    n_pallas_in = sum(1 for e in inside if e.primitive.name == "pallas_call")
    n_pallas_out = sum(1 for e in outside
                       if e.primitive.name == "pallas_call")
    if cell.variant == "unfused":
        if n_pallas_in or n_pallas_out:
            bad.append("unfused cell lowered pallas_call equations")
        return bad
    if cell.variant == "fused":
        if not eligibility.use_fused_kernel(art.scfg):
            bad.append("use_fused_kernel resolved False for a fused cell")
        elif n_pallas_out + n_pallas_in == 0:
            bad.append("fused cell lowered ZERO pallas_call equations "
                       "(silent XLA fallback)")
        return bad
    # sharded variants
    if not eligibility.sharded_eligible(art.scfg):
        bad.append("sharded_eligible resolved False for a sharded cell")
        return bad
    if n_pallas_in == 0:
        bad.append("sharded cell lowered ZERO pallas_call equations inside "
                   "shard_map (silent fallback)")
    n_cross = sum(1 for s in art.steps if s[0] == "cross")
    n_ppermute = sum(1 for e in inside
                     if e.primitive.name == "ppermute")
    if n_cross and not n_ppermute:
        bad.append(f"{n_cross} cross stages planned but no ppermute lowered")
    if not n_cross and n_ppermute:
        bad.append("ppermute lowered on an all-local schedule")
    return bad


@contract("no-collectives-unsharded",
          applies=lambda cell: not cell.sharded)
def _c_no_coll(cell: Cell, art: Artifacts) -> List[str]:
    """An unsharded cell traces no collective primitives at all — the
    single-device operator must not silently grow mesh dependencies."""
    colls = [we.name for we in jaxpr_walk.iter_eqns(art.jaxpr_fwd)
             if we.name in ("ppermute", "psum", "all_gather",
                            "all_to_all", "reduce_scatter")]
    return [f"collective primitives in unsharded cell: {colls}"] \
        if colls else []


@contract("pallas-call-count-matches-plan",
          applies=lambda cell: cell.variant == "fused")
def _c_pallas_count(cell: Cell, art: Artifacts) -> List[str]:
    """The fused forward lowers exactly ``len(plan_runs(n, strides))``
    pallas_call equations — one per kernel run, the 1-HBM-round-trip-per-
    run property stated structurally (an extra call is an extra activation
    round-trip; a missing one means a run fell back)."""
    got = sum(1 for we in jaxpr_walk.iter_eqns(art.jaxpr_fwd)
              if we.name == "pallas_call")
    want = len(art.runs)
    if got != want:
        return [f"forward pallas_call count {got} != plan runs {want}"]
    return []


@contract("shard-pallas-calls-match-schedule", applies=Cell.sharded.fget)
def _c_shard_pallas_count(cell: Cell, art: Artifacts) -> List[str]:
    """The sharded forward's pallas_call count matches the planned
    schedule: one call per shard-local kernel run for the step-serial
    executor, times the row-block pipeline depth under overlap (each
    block walks every segment once — the overlap executor's
    one-pallas_call-per-(segment, block) shape, checked on the CPU
    lowering where the per-block transport is ppermute)."""
    from repro.parallel.spm_shard import pick_row_blocks
    n_local = art.n // cell.n_shards
    local_calls = sum(len(plan_runs(n_local, rs))
                      for kind, _, rs in [s for s in art.steps
                                          if s[0] == "local"])
    if cell.variant == "shard_overlap" and any(
            s[0] == "cross" for s in art.steps):
        from repro.kernels.ops import pick_block_rows_for_plan
        runs = [(rs, tile) for kind, _, rs in art.steps if kind == "local"
                for rs, tile in plan_runs(n_local, rs)]
        br = pick_block_rows_for_plan(runs, cell.rows, 4,
                                      overlap_bufs=False) if runs else 8
        padded = -(-cell.rows // br) * br
        n_blocks = len(pick_row_blocks(padded, br))
        want = local_calls * n_blocks
    else:
        want = local_calls
    inside, _ = jaxpr_walk.split_shard_map(art.jaxpr_fwd)
    got = sum(1 for e in inside if e.primitive.name == "pallas_call")
    if got != want:
        return [f"shard-body pallas_call count {got} != planned {want}"]
    return []


@contract("dead-tile-grid-matches-plan",
          applies=lambda cell: cell.variant == "fused"
          and cell.d_out < LinearConfig(d_in=cell.d_in, d_out=cell.d_out,
                                        impl="spm_general").n)
def _c_dead_tile(cell: Cell, art: Artifacts) -> List[str]:
    """The rectangular backward grid visits only ceil(d_out / n_tile)
    feature tiles of the last run — dead output tiles are never launched
    (the dead-tile-free grid of the PR 4 backward).  Checked via the
    lowered pallas_call grids: when the plan leaves dead tiles
    (vis < full), some backward grid must carry the pruned tile count and
    none may carry the full count for that run width."""
    nt_last = art.runs[-1][1]
    full = -(-art.n // nt_last)
    vis = -(-cell.d_out // nt_last)
    if vis == full:
        return []                      # no dead tiles to prune at this shape
    grids = []
    for we in jaxpr_walk.iter_eqns(art.jaxpr_bwd):
        if we.name == "pallas_call":
            gm = we.eqn.params.get("grid_mapping")
            if gm is not None:
                grids.append(tuple(gm.grid))
    if not any(vis in g for g in grids):
        return [f"no backward pallas grid shows the pruned feature-tile "
                f"count {vis} (grids: {grids})"]
    return []


def _quant_cell(cell: Cell) -> bool:
    if cell.variant != "fused":
        return False
    lc = cell.linear_config()
    strides = tuple(lc.spm_config().pairing.strides())
    runs = plan_runs_for_rows(lc.n, strides, cell.rows, 1)
    return eligibility.quant_acts_eligible(runs)


@contract("quant-no-f32-activation-io", applies=_quant_cell)
def _c_quant_no_f32(cell: Cell, art: Artifacts) -> List[str]:
    """The int8-native entry (``spm_stack_fused_q8``) moves NO f32
    activation arrays between kernels: every activation-shaped
    (rows, features) array outside the pallas bodies is int8 — the only
    f32 riding the path are the narrow per-(row-block, feature-tile) /
    per-stage scale arrays.  Non-vacuous: the trace must contain
    pallas_call equations and return an int8 payload.  This is the
    quantization perf story stated structurally — byte width IS
    wall-clock on a memory-bound operator, so one stray f32 round trip
    erases the win."""
    bad = []
    rows = cell.rows
    n_pallas = 0
    for we in jaxpr_walk.iter_eqns(art.jaxpr_q8):
        if we.name == "pallas_call":
            n_pallas += 1
        for v in we.eqn.outvars:
            aval = getattr(v, "aval", None)
            shape = tuple(getattr(aval, "shape", ()))
            # scale arrays are (rows/block_rows, tiles) with tiles a
            # small run count — an activation is rows x a feature width
            if (len(shape) == 2 and shape[0] == rows and shape[1] >= 8
                    and str(aval.dtype) == "float32"):
                bad.append(f"f32 activation-shaped array {shape} "
                           f"from '{we.name}'")
    if n_pallas == 0:
        bad.append("q8 trace lowered ZERO pallas_call equations")
    out0 = art.jaxpr_q8.jaxpr.outvars[0]
    if str(out0.aval.dtype) != "int8":
        bad.append(f"q8 payload dtype {out0.aval.dtype} != int8")
    return bad


def _result_var_ids(jaxpr) -> set:
    """ids of every var that is a result of some (sub-)jaxpr on the walk
    — the block contract excludes these from the intermediate check (the
    final (rows, out_width) extraction IS the block's return value, not
    an inter-op round trip)."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    out = set(map(id, jaxpr.outvars))
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            continue
        for sub in jaxpr_walk._sub_jaxprs(eqn):
            out |= _result_var_ids(sub)
    return out


@contract("block-no-interop-roundtrip", applies=_block_cell)
def _c_block_roundtrip(cell: Cell, art: Artifacts) -> List[str]:
    """The fused residual block (norm -> SPM -> activation -> mirror SPM
    -> residual-add) lowers as ONE Pallas region with no inter-op HBM
    round trips: exactly one pallas_call equation; no batch-wide
    ``(rows, k>1)`` float array produced by any other equation (the
    ``(rows, 1)`` row-statistic the backward remats from is the only
    per-row array allowed to leave the kernel, and the block's own
    return value doesn't count); and — at the zoo's row count, a
    multiple of every block tile — no XLA ``pad`` anywhere on the path."""
    bad = []
    jx = art.jaxpr_block
    rows = cell.rows
    n_pallas = sum(1 for we in jaxpr_walk.iter_eqns(jx)
                   if we.name == "pallas_call")
    if n_pallas != 1:
        bad.append(f"block trace lowered {n_pallas} pallas_call "
                   "equations != 1")
    results = _result_var_ids(jx)
    for we in jaxpr_walk.iter_eqns(jx):
        if we.name == "pad":
            bad.append("XLA pad on the block path: "
                       f"{tuple(we.eqn.outvars[0].aval.shape)}")
        if we.name == "pallas_call":
            continue
        for v in we.eqn.outvars:
            aval = getattr(v, "aval", None)
            shape = tuple(getattr(aval, "shape", ()))
            if (len(shape) == 2 and shape[0] == rows and shape[1] > 1
                    and aval is not None
                    and jnp.issubdtype(aval.dtype, jnp.floating)
                    and id(v) not in results):
                bad.append(f"batch-wide float intermediate {shape} from "
                           f"'{we.name}' outside the fused region")
    return bad


@contract("sharded-permute-only", applies=_hlo_sharded)
def _c_permute_only(cell: Cell, art: Artifacts) -> List[str]:
    """The compiled sharded forward communicates via collective-permute
    ONLY: zero all-gather / all-reduce / reduce-scatter / all-to-all
    bytes, and a permute actually present whenever the schedule has cross
    stages (no vacuous pass)."""
    has_cross = any(s[0] == "cross" for s in art.steps)
    return permute_only_violations(art.hlo_fwd, require_permute=has_cross)


@contract("bwd-gather-bounded-by-param-bytes", applies=_hlo_sharded)
def _c_bwd_gather(cell: Cell, art: Artifacts) -> List[str]:
    """The compiled sharded backward has NO all-reduce and its all-gather
    stays bounded by the replicated O(nL) parameter-grad assembly plus the
    inherent jit-boundary replication of the g_x output."""
    gx_gather = cell.rows * (-(-cell.d_in // cell.n_shards)
                             * cell.n_shards) * 4
    return bwd_gather_bound_violations(art.hlo_bwd,
                                       param_bytes=art.param_bytes,
                                       extra_gather_bytes=gx_gather)
