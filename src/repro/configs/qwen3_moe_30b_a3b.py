"""qwen3-moe-30b-a3b [moe]: 48L d=2048 32H (GQA kv=4) per-expert d_ff=768
vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""

from repro.configs.base import moe_layers
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", d_model=2048, n_layers=48, n_heads=32,
    n_kv_heads=4, head_dim=128, d_ff=0, vocab_size=151936,
    layers=moe_layers(48), scan_group=1, qk_norm=True,
    n_experts=128, top_k=8, moe_d_ff=768,
    rope_theta=1e6, linear_impl="spm_general", spm_backward="custom")

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", d_model=64, n_layers=2, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=0, vocab_size=256,
    layers=moe_layers(2), scan_group=1, qk_norm=True,
    n_experts=8, top_k=2, moe_d_ff=32,
    rope_theta=1e6, linear_impl="spm_general", spm_backward="custom",
    dtype="float32", q_chunk=16, k_chunk=16)

SUBQUADRATIC = False
