"""Assigned input-shape registry (LM-family shape set).

``train_*`` lowers ``train_step``; ``prefill_*`` lowers a cache-free
forward over the prompt; ``decode_*`` / ``long_*`` lower ``serve_step``
(one new token against a KV cache of ``seq_len``).  ``long_500k`` is only
applicable to sub-quadratic archs (registry gates it).
"""

from __future__ import annotations

import dataclasses

__all__ = ["ShapeSpec", "SHAPES", "LM_SHAPES"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"
    seq_sharded: bool = False  # sequence-parallel KV (B too small to DP)


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode",
                           seq_sharded=True),
}

LM_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
