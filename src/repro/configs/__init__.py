"""Architecture + shape registry (one module per assigned arch)."""

from repro.configs.shapes import SHAPES, ShapeSpec, LM_SHAPES  # noqa: F401
from repro.configs.registry import (  # noqa: F401
    ARCH_IDS, get_config, get_smoke, arch_shapes, is_subquadratic, all_cells,
)
from repro.configs.base import (  # noqa: F401
    with_overrides, with_fused_linears, with_feature_sharding,
    with_overlap_executor, with_quantized_io, with_compressed_pod_grads,
)
