"""Config construction helpers shared by the per-arch files."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.models.transformer import LayerSpec, ModelConfig

__all__ = ["dense_layers", "local_global_layers", "moe_layers",
           "mamba_layers", "hybrid_layers", "with_overrides",
           "with_fused_linears", "with_feature_sharding",
           "with_overlap_executor", "with_quantized_io",
           "with_compressed_pod_grads"]


def dense_layers(n: int) -> Tuple[LayerSpec, ...]:
    """``n`` identical full-attention + dense-FFN layers (the default
    transformer stack)."""
    return tuple([LayerSpec()] * n)


def local_global_layers(n: int, local_per_global: int,
                        window: int) -> Tuple[LayerSpec, ...]:
    """Gemma3 pattern: ``local_per_global`` sliding-window layers then one
    global layer, repeated."""
    group = ([LayerSpec(window=window, rope="local")] * local_per_global
             + [LayerSpec()])
    reps = n // len(group)
    assert reps * len(group) == n, (n, len(group))
    return tuple(group * reps)


def moe_layers(n: int) -> Tuple[LayerSpec, ...]:
    """``n`` layers with mixture-of-experts FFNs (Qwen3-MoE / Llama4
    pattern)."""
    return tuple([LayerSpec(mlp="moe")] * n)


def mamba_layers(n: int) -> Tuple[LayerSpec, ...]:
    """``n`` pure Mamba2 mixer layers, no FFN (Mamba2 backbone pattern)."""
    return tuple([LayerSpec(mixer="mamba", mlp="none")] * n)


def hybrid_layers(n: int, attn_every: int) -> Tuple[LayerSpec, ...]:
    """Zamba2 pattern: all-mamba backbone with the SHARED attention+FFN
    block applied before every ``attn_every``-th mamba layer."""
    return tuple(LayerSpec(mixer="mamba", mlp="none",
                           shared_block=(i % attn_every == 0))
                 for i in range(n))


def with_overrides(cfg: ModelConfig, **kw) -> ModelConfig:
    """Frozen-dataclass field override (``dataclasses.replace`` spelled as
    a config verb: registry entries compose these)."""
    return dataclasses.replace(cfg, **kw)


def with_fused_linears(cfg: ModelConfig,
                       on: Optional[bool] = True) -> ModelConfig:
    """Set the fused-Pallas-operator knob on every SPM linear in the model
    (``spm_use_kernel``: None = auto/on-TPU, True = force, False = off).
    Ineligible operators (odd n, permutation pairings, custom_inverse)
    fall back to the XLA composition regardless — see core/spm.py."""
    return dataclasses.replace(cfg, spm_use_kernel=on)


def with_feature_sharding(cfg: ModelConfig, n_shards: int) -> ModelConfig:
    """Switch every SPM linear to the two_level schedule with its feature
    axis distributable over ``n_shards`` "model"-axis devices.  The
    distributed executor (``parallel/spm_shard.py``: shard-local fused
    kernel runs + collective_permute cross stages) engages when an
    ``activation_sharding(mesh, shard_feature=True)`` context is active and
    the mesh's model axis matches; otherwise the schedule still runs
    unsharded (it is just a reordered butterfly)."""
    return dataclasses.replace(cfg, spm_schedule="two_level",
                               spm_n_shards=n_shards)


def with_overlap_executor(cfg: ModelConfig,
                          on: Optional[bool] = True) -> ModelConfig:
    """Set the overlap-scheduled sharded executor knob on every SPM linear
    (``spm_overlap``: None = auto/on-TPU, True = force the row-block
    pipelined schedule everywhere — off-TPU it runs with the per-block
    collective_permute transport, the interpret-mode proof path — False =
    keep the step-serial schedule).  Only consulted when the distributed
    executor engages (``with_feature_sharding`` + a matching
    ``activation_sharding`` context); see core/eligibility.resolve_overlap
    for the resolution rules."""
    return dataclasses.replace(cfg, spm_overlap=on)


def with_quantized_io(cfg: ModelConfig, acts: bool = True,
                      coeffs: bool = True) -> ModelConfig:
    """Set the int8 quantization knobs on every SPM linear in the model.

    ``acts`` — int8 ACTIVATION I/O on the fused kernel path
    (``spm_quant_acts``): inputs/outputs move through HBM as int8 with
    per-(row-block, feature-tile) scales, dequantized to f32 in VMEM;
    engages only when the kernel run plan has one uniform feature tile
    (core/eligibility.quant_acts_eligible), else falls back to f32 I/O.
    ``coeffs`` — int8 per-stage-scaled COEFFICIENT tables
    (``spm_quant_coeffs``), honored by both the fused single-device path
    and the distributed shard-local runs.  Both knobs are inert on dense
    baselines and on the XLA composition fallback.  See
    docs/quantization.md for the full eligibility/fallback matrix."""
    return dataclasses.replace(cfg, spm_quant_acts=acts,
                               spm_quant_coeffs=coeffs)


def with_compressed_pod_grads(cfg: ModelConfig, on: bool = True) -> ModelConfig:
    """Enable int8 error-feedback compressed data-parallel gradient
    reduction (``compress_pod_grads``).  Consumed by the TRAIN layer, not
    the operator: ``train/step.make_pod_train_step`` reads it to route the
    pod all-reduce through ``optim.compression.psum_compressed_ef`` with
    the per-member residual carried in ``state["opt"]["ef"]`` (see
    ``train/step.pod_residual``)."""
    return dataclasses.replace(cfg, compress_pod_grads=on)
