"""llama4-scout-17b-a16e [moe]: 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + always-on shared expert, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""

from repro.configs.base import moe_layers
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", d_model=5120, n_layers=48, n_heads=40,
    n_kv_heads=8, head_dim=128, d_ff=0, vocab_size=202048,
    layers=moe_layers(48), scan_group=1,
    n_experts=16, top_k=1, moe_d_ff=8192, shared_d_ff=8192,
    rope_theta=5e5, linear_impl="spm_general", spm_backward="custom")

SMOKE = ModelConfig(
    name="llama4-scout-smoke", d_model=64, n_layers=2, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=0, vocab_size=256,
    layers=moe_layers(2), scan_group=1,
    n_experts=4, top_k=1, moe_d_ff=64, shared_d_ff=64,
    rope_theta=5e5, linear_impl="spm_general", spm_backward="custom",
    dtype="float32", q_chunk=16, k_chunk=16)

SUBQUADRATIC = False
