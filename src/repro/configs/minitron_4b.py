"""minitron-4b [dense]: 32L d=3072 24H (GQA kv=8) d_ff=9216 vocab=256000 —
pruned nemotron [arXiv:2407.14679; hf]."""

from repro.configs.base import dense_layers
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", d_model=3072, n_layers=32, n_heads=24, n_kv_heads=8,
    head_dim=128, d_ff=9216, vocab_size=256000,
    layers=dense_layers(32), scan_group=1,
    rope_theta=1e4, linear_impl="spm_general", spm_backward="custom")

SMOKE = ModelConfig(
    name="minitron-4b-smoke", d_model=48, n_layers=2, n_heads=6, n_kv_heads=2,
    head_dim=8, d_ff=144, vocab_size=250,
    layers=dense_layers(2), scan_group=1,
    rope_theta=1e4, linear_impl="spm_general", spm_backward="custom",
    dtype="float32", q_chunk=16, k_chunk=16)

SUBQUADRATIC = False
