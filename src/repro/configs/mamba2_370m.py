"""mamba2-370m [ssm]: 48L d=1024 attn-free vocab=50280 ssm_state=128 —
SSD state-space duality [arXiv:2405.21060; unverified].

Attention-free: the paper's SPM applies to in/out projections; the SSD
scan itself is already sub-quadratic and left untouched (complementary,
not inapplicable — DESIGN.md §4).  long_500k RUNS (O(1) decode state).
"""

from repro.configs.base import mamba_layers
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", d_model=1024, n_layers=48, n_heads=16,
    n_kv_heads=16, head_dim=64, d_ff=0, vocab_size=50280,
    layers=mamba_layers(48), scan_group=1,
    ssm_state=128, ssm_head=64,
    linear_impl="spm_general", spm_backward="custom")

SMOKE = ModelConfig(
    name="mamba2-370m-smoke", d_model=64, n_layers=2, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=0, vocab_size=256,
    layers=mamba_layers(2), scan_group=1,
    ssm_state=16, ssm_head=16, ssm_chunk=8,
    linear_impl="spm_general", spm_backward="custom",
    dtype="float32")

SUBQUADRATIC = True
