"""musicgen-medium [audio]: 48L d=1536 24H (MHA kv=24) d_ff=6144 vocab=2048
— decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

BACKBONE only: the EnCodec frontend is a stub — ``input_specs`` feeds
precomputed frame embeddings (B, T, d) for train/prefill; decode
autoregresses over the model's own 2048-token codebook embedding.
"""

from repro.configs.base import dense_layers
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", d_model=1536, n_layers=48, n_heads=24,
    n_kv_heads=24, head_dim=64, d_ff=6144, vocab_size=2048,
    layers=dense_layers(48), scan_group=1, input_kind="embeddings",
    rope_theta=1e4, linear_impl="spm_general", spm_backward="custom")

SMOKE = ModelConfig(
    name="musicgen-medium-smoke", d_model=64, n_layers=2, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=128,
    layers=dense_layers(2), scan_group=1, input_kind="embeddings",
    rope_theta=1e4, linear_impl="spm_general", spm_backward="custom",
    dtype="float32", q_chunk=16, k_chunk=16)

SUBQUADRATIC = False
