"""Architecture registry: ``--arch <id>`` resolution, shape applicability.

Every assigned architecture is selectable; ``long_500k`` is gated on
SUBQUADRATIC (pure full-attention archs skip it — noted in DESIGN.md §4).
"""

from __future__ import annotations

import importlib
from typing import Any, Tuple

from repro.configs.base import with_fused_linears, with_overlap_executor
from repro.configs.shapes import SHAPES, ShapeSpec
from repro.models.transformer import ModelConfig

__all__ = ["ARCH_IDS", "get_config", "get_smoke", "arch_shapes",
           "is_subquadratic", "all_cells"]

_MODULES = {
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
    "qwen3-32b": "repro.configs.qwen3_32b",
    "qwen3-1.7b": "repro.configs.qwen3_1_7b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "minitron-4b": "repro.configs.minitron_4b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "mamba2-370m": "repro.configs.mamba2_370m",
}

ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    return importlib.import_module(_MODULES[arch])


_UNSET = object()  # distinct from None: None is itself a valid tri-state
                   # value ("auto"), so absence needs its own sentinel


def get_config(arch: str,
               use_kernel: Any = _UNSET,
               overlap: Any = _UNSET) -> ModelConfig:
    """Resolve an arch id; ``use_kernel`` (when passed) overrides the
    fused-Pallas-linear knob and ``overlap`` the overlap-scheduled
    sharded-executor knob (each tri-state: None = auto/on-TPU, True =
    force, False = off).  Omit either to keep the arch config's own
    setting."""
    cfg = _mod(arch).CONFIG
    if use_kernel is not _UNSET:
        cfg = with_fused_linears(cfg, use_kernel)
    if overlap is not _UNSET:
        cfg = with_overlap_executor(cfg, overlap)
    return cfg


def get_smoke(arch: str, use_kernel: Any = _UNSET,
              overlap: Any = _UNSET) -> ModelConfig:
    """Smoke-scale variant of ``get_config`` (same knob overrides)."""
    cfg = _mod(arch).SMOKE
    if use_kernel is not _UNSET:
        cfg = with_fused_linears(cfg, use_kernel)
    if overlap is not _UNSET:
        cfg = with_overlap_executor(cfg, overlap)
    return cfg


def is_subquadratic(arch: str) -> bool:
    return bool(_mod(arch).SUBQUADRATIC)


def arch_shapes(arch: str) -> Tuple[ShapeSpec, ...]:
    """All 4 LM shapes; long_500k only for sub-quadratic archs.  Every
    assigned (arch x shape) pair is a dry-run cell; skipped long_500k
    cells are recorded as skipped, not silently dropped."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if is_subquadratic(arch):
        names.append("long_500k")
    return tuple(SHAPES[n] for n in names)


def all_cells():
    """Every (arch, shape) cell, including inapplicable long_500k marked
    with applicable=False."""
    cells = []
    for arch in ARCH_IDS:
        sub = is_subquadratic(arch)
        for name, spec in SHAPES.items():
            applicable = (name != "long_500k") or sub
            cells.append((arch, spec, applicable))
    return cells
