"""gemma3-12b [dense]: 48L d=3840 16H (GQA kv=8) d_ff=15360 vocab=262144,
5:1 local:global sliding-window, 128k-class context
[hf:google/gemma-3-1b-pt family; unverified].

long_500k RUNS for this arch: decode cost is dominated by the 1024-token
sliding-window layers; only the 1-in-6 global layers hold a 500k KV cache
(B=1, sharded over "data" — sequence parallel).  See DESIGN.md §4.
"""

from repro.configs.base import local_global_layers
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", d_model=3840, n_layers=48, n_heads=16, n_kv_heads=8,
    head_dim=256, d_ff=15360, vocab_size=262144,
    layers=local_global_layers(48, 5, 1024), scan_group=6, qk_norm=True,
    rope_theta=1e6, rope_local_theta=1e4, embed_scale=3840 ** 0.5,
    linear_impl="spm_general", spm_backward="custom")

SMOKE = ModelConfig(
    name="gemma3-12b-smoke", d_model=64, n_layers=6, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, vocab_size=512,
    layers=local_global_layers(6, 5, 8), scan_group=6, qk_norm=True,
    rope_theta=1e6, rope_local_theta=1e4, embed_scale=8.0,
    linear_impl="spm_general", spm_backward="custom",
    dtype="float32", q_chunk=16, k_chunk=16)

SUBQUADRATIC = True    # 5:1 local:global — 500k decode is window-dominated
