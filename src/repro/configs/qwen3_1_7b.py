"""qwen3-1.7b [dense]: 28L d=2048 16H (GQA kv=8) d_ff=6144 vocab=151936,
qk_norm [hf:Qwen/Qwen3-8B family; hf]."""

from repro.configs.base import dense_layers
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", d_model=2048, n_layers=28, n_heads=16, n_kv_heads=8,
    head_dim=128, d_ff=6144, vocab_size=151936,
    layers=dense_layers(28), scan_group=1, qk_norm=True,
    rope_theta=1e6, linear_impl="spm_general", spm_backward="custom")

SMOKE = ModelConfig(
    name="qwen3-1.7b-smoke", d_model=64, n_layers=2, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=96, vocab_size=256,
    layers=dense_layers(2), scan_group=1, qk_norm=True,
    rope_theta=1e6, linear_impl="spm_general", spm_backward="custom",
    dtype="float32", q_chunk=16, k_chunk=16)

SUBQUADRATIC = False
