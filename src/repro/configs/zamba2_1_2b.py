"""zamba2-1.2b [hybrid]: 38L d=2048 32H (MHA kv=32) d_ff=8192 vocab=32000,
ssm_state=64 — Mamba2 backbone + SHARED attention+FFN block applied every
6th layer [arXiv:2411.15242; hf].

Heterogeneous interleave => unrolled (scan_group=0).  long_500k RUNS:
hybrid — shared-attn KV at 500k is B=1 and sequence-sharded.
"""

from repro.configs.base import hybrid_layers
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", d_model=2048, n_layers=38, n_heads=32,
    n_kv_heads=32, head_dim=64, d_ff=0, vocab_size=32000,
    layers=hybrid_layers(38, 6), scan_group=0,
    ssm_state=64, ssm_head=64, shared_attn_d_ff=8192,
    linear_impl="spm_general", spm_backward="custom")

SMOKE = ModelConfig(
    name="zamba2-1.2b-smoke", d_model=64, n_layers=4, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=0, vocab_size=256,
    layers=hybrid_layers(4, 2), scan_group=0,
    ssm_state=16, ssm_head=16, ssm_chunk=8, shared_attn_d_ff=128,
    linear_impl="spm_general", spm_backward="custom",
    dtype="float32", q_chunk=16, k_chunk=16)

SUBQUADRATIC = True
