"""qwen3-32b [dense]: 64L d=5120 64H (GQA kv=8) d_ff=25600 vocab=151936,
qk_norm [hf:Qwen/Qwen3-8B family; hf]."""

from repro.configs.base import dense_layers
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", d_model=5120, n_layers=64, n_heads=64, n_kv_heads=8,
    head_dim=128, d_ff=25600, vocab_size=151936,
    layers=dense_layers(64), scan_group=1, qk_norm=True,
    rope_theta=1e6, linear_impl="spm_general", spm_backward="custom")

SMOKE = ModelConfig(
    name="qwen3-32b-smoke", d_model=64, n_layers=2, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, vocab_size=256,
    layers=dense_layers(2), scan_group=1, qk_norm=True,
    rope_theta=1e6, linear_impl="spm_general", spm_backward="custom",
    dtype="float32", q_chunk=16, k_chunk=16)

SUBQUADRATIC = False   # pure full-attention: long_500k skipped (DESIGN §4)
