"""The paper's own experiment configurations (§9).

Table 1 — synthetic compositional teacher, widths {256,512,1024,2048},
steps=1200, batch=256, classes=10.
Table 2 — AG News proxy (hashed sparse features), widths {2048,4096}, L=12.
Tables 3–4 — char-LM, d=4096, T=128, B=32, lr=1e-3, L=12 butterfly.
"""

from __future__ import annotations

import dataclasses

from repro.models.mlp import MLPConfig

__all__ = ["TEACHER_WIDTHS", "T1_STEPS", "T1_BATCH", "T1_CLASSES",
           "AGNEWS_WIDTHS", "AGNEWS_L", "CHARLM_D", "CHARLM_T", "CHARLM_B",
           "CHARLM_LR", "CHARLM_L", "student_cfg"]

TEACHER_WIDTHS = (256, 512, 1024, 2048)
T1_STEPS = 1200
T1_BATCH = 256
T1_CLASSES = 10

AGNEWS_WIDTHS = (2048, 4096)
AGNEWS_L = 12          # paper: ceil((log2 2048 + log2 4096)/2) = 12
AGNEWS_CLASSES = 4

CHARLM_D = 4096
CHARLM_T = 128
CHARLM_B = 32
CHARLM_LR = 1e-3
CHARLM_L = 12          # butterfly-style schedule, paper §9.3


def student_cfg(width: int, n_classes: int, impl: str,
                n_stages: int | None = None) -> MLPConfig:
    return MLPConfig(n_features=width, n_classes=n_classes,
                     linear_impl=impl, spm_stages=n_stages,
                     spm_backward="custom")
