"""qwen2-vl-7b [vlm]: 28L d=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 —
M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

BACKBONE only: the ViT frontend is a stub — ``input_specs`` provides
precomputed patch embeddings plus (3, B, T) M-RoPE position ids
(temporal/height/width); decode generates text tokens.
"""

from repro.configs.base import dense_layers
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", d_model=3584, n_layers=28, n_heads=28, n_kv_heads=4,
    head_dim=128, d_ff=18944, vocab_size=152064,
    layers=dense_layers(28), scan_group=1, input_kind="embeddings",
    rope_kind="mrope", mrope_sections=(16, 24, 24), rope_theta=1e6,
    linear_impl="spm_general", spm_backward="custom")

SMOKE = ModelConfig(
    name="qwen2-vl-7b-smoke", d_model=64, n_layers=2, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, vocab_size=256,
    layers=dense_layers(2), scan_group=1, input_kind="embeddings",
    rope_kind="mrope", mrope_sections=(2, 3, 3), rope_theta=1e6,
    linear_impl="spm_general", spm_backward="custom",
    dtype="float32", q_chunk=16, k_chunk=16)

SUBQUADRATIC = False
