"""Stagewise Pairwise Mixers (SPM) — the paper's core operator.

Implements (paper §2):

    SPM(x) = D_out * (B_L ... B_1) * D_in * x + b

with each stage B_l made of n//2 independent 2x2 blocks on disjoint pairs.

Two parameterizations (paper §3):
  * variant="rotation":  one angle per pair, orthogonal by construction.
  * variant="general":   four scalars (a, b, c, d) per pair.

Both are normalized internally to a per-stage coefficient tensor
``coeffs[l] : (n_pairs, 4)`` holding (a, b, c, d); the rotation variant
derives (cos t, -sin t, sin t, cos t) from theta so the closed-form theta
gradient (paper eq. 9) emerges from chaining eq. 14 through the trig map.

Backward modes:
  * "autodiff"       — JAX reverse-mode through the factorized forward.
  * "custom"         — paper §4 closed-form VJP (custom_vjp, saves stage
                       inputs exactly as eqs. 12–14/15–19 require).
  * "custom_inverse" — rotation only: REVERSIBLE backward.  Stage inputs are
                       reconstructed from outputs via B_l^T = B_l^{-1}, so no
                       intermediate activations are stored (O(n) residuals
                       instead of O(nL)).  Beyond-paper memory optimization.

Fused kernel path: ``use_kernel`` (tri-state, see SPMConfig) routes the
WHOLE operator — diag, stages, and bias — through the Pallas kernel pair in
``kernels/ops.py`` with its own closed-form custom_vjp; the selectable
backward modes above only apply to the XLA composition fallback.

All apply functions act on the last axis of arbitrarily-batched inputs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pairings
from repro.core.eligibility import kernel_eligible, use_fused_kernel
from repro.core.pairings import Schedule, Stage

__all__ = ["SPMConfig", "init_spm", "spm_apply", "spm_matrix", "stage_coeffs",
           "kernel_eligible", "use_fused_kernel"]


@dataclasses.dataclass(frozen=True)
class SPMConfig:
    """Static configuration of one SPM operator (hashable; safe to close over
    in jitted functions)."""

    n: int
    n_stages: int
    variant: str = "general"          # "general" | "rotation"
    schedule: str = "butterfly"       # pairings.make_schedule kinds
    use_diag: bool = True
    use_bias: bool = True
    backward: str = "autodiff"        # "autodiff" | "custom" | "custom_inverse"
    init_mode: str = "orthogonal"     # "orthogonal" | "identity"
    init_scale: float = 0.05
    n_shards: int = 1                 # for schedule="two_level"
    # Schedule granularity for "two_level", decoupled from the EXECUTION
    # shard count.  The stride sequence (the operator's math) is built for
    # ``schedule_shards`` blocks (default: ``n_shards``); ``n_shards`` only
    # says how many shards EXECUTE it.  A schedule built for S shards is
    # executable on any power-of-two divisor m of S (strides below n/m
    # become shard-local runs, the rest stay k*(n/m) partner exchanges), so
    # an elastic restart onto fewer chips keeps the SAME operator:
    # ``dataclasses.replace(cfg, n_shards=m, schedule_shards=S)`` restores
    # a checkpoint bit-for-bit onto the smaller mesh (train/checkpoint.py's
    # topology-independent restore; proven by the chaos parity harness).
    schedule_shards: Optional[int] = None
    seed: int = 0
    param_dtype: Any = jnp.float32
    # Fused full-operator Pallas kernel (kernels/ops.py): tri-state.
    #   None  — auto: ON whenever the schedule is eligible AND we are on a
    #           TPU backend (on CPU the kernel runs in interpret mode, which
    #           is only useful for validation, so auto stays on XLA).
    #   True  — force the fused path when eligible (interpret mode off-TPU;
    #           used by tests/benchmarks).
    #   False — never.
    # Eligibility (graceful fallback otherwise): all stages structured
    # (stride pairings), even n, and backward != "custom_inverse" (the
    # reversible backward stores outputs, incompatible with the in-VMEM
    # remat the kernel backward performs).
    use_kernel: Optional[bool] = None
    # Overlap-scheduled sharded executor (parallel/spm_shard.py): tri-state.
    #   None  — auto: row-block pipelined cross-shard exchanges on TPU
    #           backends only (where the ICI latency is real); off-TPU the
    #           step-serial full-slab schedule remains the fallback.
    #   True  — force the overlap SCHEDULE everywhere; off-TPU / interpret
    #           it runs with the per-block collective_permute transport
    #           (the parity-test proof path), on TPU pair segments use the
    #           in-kernel RDMA transport (make_async_remote_copy).
    #   False — keep the step-serial schedule.
    # Resolution lives in core/eligibility.resolve_overlap; only consulted
    # when the distributed executor engages (n_shards > 1 + mesh context).
    overlap: Optional[bool] = None
    # Int8 quantization knobs (kernels/quant.py scale conventions).  Both
    # change only BYTES MOVED — in-VMEM compute stays f32:
    #   quant_acts   — int8 activation I/O for the fused kernel runs
    #                  (per-(row-block, feature-tile) scales; requires a
    #                  uniform-tile run plan, falls back to f32 I/O
    #                  gracefully — core/eligibility.quant_acts_eligible).
    #                  Fused single-device path only; the XLA composition
    #                  and the distributed executor ignore it.
    #   quant_coeffs — int8 per-stage-scaled coefficient tables,
    #                  dequantized in VMEM; honored by the fused path AND
    #                  the distributed executor's shard-local runs.
    #                  Coefficient grads stay f32, computed from the same
    #                  dequantized values the forward used.
    quant_acts: bool = False
    quant_coeffs: bool = False
    # Int8 error-feedback compression of the cross-pod gradient all-reduce
    # (optim/compression.psum_compressed_ef).  Consumed by the TRAIN layer
    # (train/step.make_pod_train_step), not by the operator itself: the
    # knob rides here so one config object carries the whole quantization
    # posture of a run.
    compress_pod_grads: bool = False

    def __post_init__(self):
        if self.variant not in ("general", "rotation"):
            raise ValueError(f"bad variant {self.variant!r}")
        if self.backward == "custom_inverse" and self.variant != "rotation":
            raise ValueError("custom_inverse backward requires the rotation "
                             "variant (blocks must be orthogonal)")

    @functools.cached_property
    def pairing(self) -> Schedule:
        """The operator's pairing schedule (built once; the two_level kind
        uses ``schedule_shards`` — see that field — as its block split)."""
        return pairings.make_schedule(
            self.schedule, self.n, self.n_stages,
            n_shards=self.schedule_shards or self.n_shards, seed=self.seed)

    @property
    def n_pairs(self) -> int:
        """Pairs per stage (n // 2; the odd coordinate, if any, rides a
        residual 1x1 scale instead)."""
        return self.n // 2

    @property
    def odd(self) -> bool:
        """Odd operator width: each stage leaves one coordinate unpaired
        (scaled by ``res_scale``) and the fused kernel path is ineligible."""
        return self.n % 2 == 1

    def param_count(self) -> int:
        """Total learnable parameters of the operator: O(nL) stage
        coefficients (1 angle or 4 scalars per pair) plus the odd-n
        residual scales and the optional diagonals/bias — the paper's
        headline count vs the dense layer's n^2."""
        per_stage = self.n_pairs * (1 if self.variant == "rotation" else 4)
        total = self.n_stages * per_stage
        if self.odd:
            total += self.n_stages  # residual 1x1 scales
        if self.use_diag:
            total += 2 * self.n
        if self.use_bias:
            total += self.n
        return total


# ---------------------------------------------------------------------------
# initialization
# ---------------------------------------------------------------------------

def init_spm(key: jax.Array, cfg: SPMConfig) -> dict:
    """Near-identity / random-orthogonal init.  The paper does not prescribe
    an init; we default to random per-pair rotations (norm-preserving at
    init for BOTH variants) plus small noise, which keeps the composed
    operator well-conditioned at L=12 depth."""
    kt, km, kd = jax.random.split(key, 3)
    dt = cfg.param_dtype
    p: dict = {}
    if cfg.variant == "rotation":
        if cfg.init_mode == "identity":
            theta = cfg.init_scale * jax.random.normal(
                kt, (cfg.n_stages, cfg.n_pairs), dt)
        else:
            theta = jax.random.uniform(
                kt, (cfg.n_stages, cfg.n_pairs), dt,
                minval=-np.pi, maxval=np.pi)
        p["theta"] = theta
    else:
        if cfg.init_mode == "identity":
            eye = jnp.asarray([1.0, 0.0, 0.0, 1.0], dt)
            mix = (jnp.broadcast_to(eye, (cfg.n_stages, cfg.n_pairs, 4))
                   + cfg.init_scale * jax.random.normal(
                       km, (cfg.n_stages, cfg.n_pairs, 4), dt))
        else:
            th = jax.random.uniform(kt, (cfg.n_stages, cfg.n_pairs), dt,
                                    minval=-np.pi, maxval=np.pi)
            c, s = jnp.cos(th), jnp.sin(th)
            mix = (jnp.stack([c, -s, s, c], axis=-1)
                   + cfg.init_scale * jax.random.normal(
                       km, (cfg.n_stages, cfg.n_pairs, 4), dt))
        p["mix"] = mix
    if cfg.odd:
        p["res_scale"] = jnp.ones((cfg.n_stages,), dt)
    if cfg.use_diag:
        p["d_in"] = jnp.ones((cfg.n,), dt)
        p["d_out"] = jnp.ones((cfg.n,), dt)
    if cfg.use_bias:
        p["bias"] = jnp.zeros((cfg.n,), dt)
    return p


def stage_coeffs(params: dict, cfg: SPMConfig) -> jax.Array:
    """Normalize either parameterization to (L, n_pairs, 4) = (a, b, c, d)."""
    if cfg.variant == "rotation":
        th = params["theta"]
        c, s = jnp.cos(th), jnp.sin(th)
        return jnp.stack([c, -s, s, c], axis=-1)
    return params["mix"]


# ---------------------------------------------------------------------------
# single-stage application
# ---------------------------------------------------------------------------

def _mix_pairs(x0, x1, a, b, c, d):
    y0 = a * x0 + b * x1
    y1 = c * x0 + d * x1
    return y0, y1


def apply_stage(x: jax.Array, coeffs: jax.Array, stage: Stage,
                res_scale: Optional[jax.Array] = None,
                transpose: bool = False) -> jax.Array:
    """Apply one stage B_l (or B_l^T) to the last axis of x.

    coeffs: (n_pairs, 4).  transpose=True applies the transposed blocks
    [[a, c], [b, d]] on the same pairing — used by the closed-form backward
    (paper §4.2: g_{z-1} = B^T g_z).
    """
    a, b, c, d = (coeffs[:, 0], coeffs[:, 1], coeffs[:, 2], coeffs[:, 3])
    if transpose:
        b, c = c, b
    n = x.shape[-1]
    lead = x.shape[:-1]
    if stage.structured:
        s = stage.stride
        g = n // (2 * s)
        xr = x.reshape(lead + (g, 2, s))
        x0, x1 = xr[..., 0, :], xr[..., 1, :]
        ar, br, cr, dr = (v.reshape(g, s) for v in (a, b, c, d))
        y0, y1 = _mix_pairs(x0, x1, ar, br, cr, dr)
        return jnp.stack([y0, y1], axis=-2).reshape(lead + (n,))
    # general permutation pairing
    perm = stage.perm
    inv = np.argsort(perm)
    n_pairs = n // 2
    xg = x[..., perm]
    xp = xg[..., : 2 * n_pairs].reshape(lead + (n_pairs, 2))
    y0, y1 = _mix_pairs(xp[..., 0], xp[..., 1], a, b, c, d)
    yp = jnp.stack([y0, y1], axis=-1).reshape(lead + (2 * n_pairs,))
    if n % 2:
        rs = res_scale if res_scale is not None else jnp.ones((), x.dtype)
        resid = (xg[..., -1] * rs)[..., None]
        yp = jnp.concatenate([yp, resid], axis=-1)
    return yp[..., inv]


def apply_stage_inverse(y: jax.Array, coeffs: jax.Array, stage: Stage,
                        res_scale: Optional[jax.Array] = None) -> jax.Array:
    """Invert one stage.  For orthogonal (rotation) blocks this equals the
    transpose; implemented generally via the 2x2 inverse for robustness."""
    a, b, c, d = (coeffs[:, 0], coeffs[:, 1], coeffs[:, 2], coeffs[:, 3])
    det = a * d - b * c
    inv_coeffs = jnp.stack([d / det, -b / det, -c / det, a / det], axis=-1)
    inv_res = None if res_scale is None else 1.0 / res_scale
    return apply_stage(y, inv_coeffs, stage, res_scale=inv_res)


# ---------------------------------------------------------------------------
# core L-stage composition with selectable backward
# ---------------------------------------------------------------------------

def _forward_stages(coeffs: jax.Array, res_scales: Optional[jax.Array],
                    x: jax.Array, sched: Schedule,
                    collect: bool = False):
    """Run all stages; optionally return the list of stage inputs."""
    zs = []
    z = x
    for ell, stage in enumerate(sched.stages):
        if collect:
            zs.append(z)
        rs = None if res_scales is None else res_scales[ell]
        z = apply_stage(z, coeffs[ell], stage, res_scale=rs)
    return (z, zs) if collect else z


def _stage_grads(z_in: jax.Array, delta: jax.Array, coeffs: jax.Array,
                 stage: Stage, res_scale: Optional[jax.Array]):
    """Closed-form per-stage grads (paper eqs. 12–14 applied pairwise).

    Returns (g_input, g_coeffs, g_res_scale).  Batch dims of z_in/delta are
    summed into the parameter grads (paper §4 'Batch Setting').
    """
    n = z_in.shape[-1]
    lead = z_in.shape[:-1]
    bdims = tuple(range(len(lead)))

    if stage.structured:
        s = stage.stride
        g = n // (2 * s)
        zr = z_in.reshape(lead + (g, 2, s))
        dr = delta.reshape(lead + (g, 2, s))
        x0, x1 = zr[..., 0, :], zr[..., 1, :]
        d0, d1 = dr[..., 0, :], dr[..., 1, :]
        a, b, c, d = (coeffs[:, i].reshape(g, s) for i in range(4))
        # input grads: B^T delta  (eqs. 12–13)
        gx0 = a * d0 + c * d1
        gx1 = b * d0 + d * d1
        g_in = jnp.stack([gx0, gx1], axis=-2).reshape(lead + (n,))
        # parameter grads (eq. 14), summed over batch
        ga = jnp.sum(d0 * x0, axis=bdims).reshape(-1)
        gb = jnp.sum(d0 * x1, axis=bdims).reshape(-1)
        gc = jnp.sum(d1 * x0, axis=bdims).reshape(-1)
        gd = jnp.sum(d1 * x1, axis=bdims).reshape(-1)
        return g_in, jnp.stack([ga, gb, gc, gd], axis=-1), None

    perm = stage.perm
    inv = np.argsort(perm)
    n_pairs = n // 2
    zg = z_in[..., perm]
    dg = delta[..., perm]
    zp = zg[..., : 2 * n_pairs].reshape(lead + (n_pairs, 2))
    dp = dg[..., : 2 * n_pairs].reshape(lead + (n_pairs, 2))
    x0, x1 = zp[..., 0], zp[..., 1]
    d0, d1 = dp[..., 0], dp[..., 1]
    a, b, c, d = (coeffs[:, i] for i in range(4))
    gx0 = a * d0 + c * d1
    gx1 = b * d0 + d * d1
    gp = jnp.stack([gx0, gx1], axis=-1).reshape(lead + (2 * n_pairs,))
    g_rs = None
    if n % 2:
        rs = res_scale if res_scale is not None else jnp.ones((), z_in.dtype)
        g_res_lane = dg[..., -1] * rs
        g_rs = jnp.sum(dg[..., -1] * zg[..., -1])
        gp = jnp.concatenate([gp, g_res_lane[..., None]], axis=-1)
    g_in = gp[..., inv]
    ga = jnp.sum(d0 * x0, axis=bdims)
    gb = jnp.sum(d0 * x1, axis=bdims)
    gc = jnp.sum(d1 * x0, axis=bdims)
    gd = jnp.sum(d1 * x1, axis=bdims)
    return g_in, jnp.stack([ga, gb, gc, gd], axis=-1), g_rs


def _make_core(sched: Schedule, mode: str):
    """Build the L-stage composition with the requested backward mode.

    Signature: core(coeffs (L, n_pairs, 4), res_scales (L,)|None, x) -> y.
    res_scales is passed as an array always (ones when unused) to keep the
    custom_vjp signature uniform.
    """

    if mode == "autodiff":
        def core(coeffs, res_scales, x):
            return _forward_stages(coeffs, res_scales, x, sched)
        return core

    if mode == "custom":
        @jax.custom_vjp
        def core(coeffs, res_scales, x):
            return _forward_stages(coeffs, res_scales, x, sched)

        def fwd(coeffs, res_scales, x):
            y, zs = _forward_stages(coeffs, res_scales, x, sched,
                                    collect=True)
            return y, (coeffs, res_scales, tuple(zs))

        def bwd(res, gy):
            coeffs, res_scales, zs = res
            g_coeffs = []
            g_rs = []
            delta = gy
            for ell in range(len(sched.stages) - 1, -1, -1):
                stage = sched.stages[ell]
                rs = res_scales[ell]
                delta, gc, grs = _stage_grads(zs[ell], delta, coeffs[ell],
                                              stage, rs)
                g_coeffs.append(gc)
                g_rs.append(grs if grs is not None
                            else jnp.zeros((), delta.dtype))
            g_coeffs = jnp.stack(g_coeffs[::-1], axis=0)
            g_rs = jnp.stack(g_rs[::-1], axis=0)
            return g_coeffs, g_rs, delta

        core.defvjp(fwd, bwd)
        return core

    if mode == "custom_inverse":
        @jax.custom_vjp
        def core(coeffs, res_scales, x):
            return _forward_stages(coeffs, res_scales, x, sched)

        def fwd(coeffs, res_scales, x):
            y = _forward_stages(coeffs, res_scales, x, sched)
            return y, (coeffs, res_scales, y)  # O(n) residuals: outputs only

        def bwd(res, gy):
            coeffs, res_scales, y = res
            g_coeffs = []
            g_rs = []
            delta = gy
            z = y
            for ell in range(len(sched.stages) - 1, -1, -1):
                stage = sched.stages[ell]
                rs = res_scales[ell]
                # reconstruct the stage INPUT from its output (reversibility)
                z = apply_stage_inverse(z, coeffs[ell], stage, res_scale=rs)
                delta, gc, grs = _stage_grads(z, delta, coeffs[ell], stage, rs)
                g_coeffs.append(gc)
                g_rs.append(grs if grs is not None
                            else jnp.zeros((), delta.dtype))
            g_coeffs = jnp.stack(g_coeffs[::-1], axis=0)
            g_rs = jnp.stack(g_rs[::-1], axis=0)
            return g_coeffs, g_rs, delta

        core.defvjp(fwd, bwd)
        return core

    raise ValueError(f"unknown backward mode {mode!r}")


@functools.lru_cache(maxsize=None)
def _cached_core(sched: Schedule, mode: str):
    return _make_core(sched, mode)


# ---------------------------------------------------------------------------
# public apply
# ---------------------------------------------------------------------------

# kernel_eligible / use_fused_kernel moved to core/eligibility.py (the
# single fallback matrix shared with the distributed executor); re-exported
# here unchanged for back-compat.


def spm_apply(params: dict, x: jax.Array, cfg: SPMConfig, *,
              in_width: Optional[int] = None,
              out_width: Optional[int] = None) -> jax.Array:
    """Full SPM forward: y = D_out * (B_L ... B_1) * D_in * x + bias.

    ``in_width`` / ``out_width`` embed a rectangular map (d_in -> d_out,
    each <= n) in the square operator: x is (..., in_width), treated as
    zero-padded to n, and only the first ``out_width`` output columns are
    returned.  On the fused kernel path the padding/slicing happens inside
    the kernel boundary runs (no XLA pad/slice, no dead output columns);
    the distributed executor window-reads the boundary operands per shard
    (docs/sharding.md); the XLA composition fallback realizes the same
    semantics with an explicit pad + slice around the square operator.
    """
    n = cfg.n
    if in_width == n:
        in_width = None
    if out_width == n:
        out_width = None
    expect = in_width if in_width is not None else n
    if x.shape[-1] != expect:
        raise ValueError(f"expected (..., {expect}), got {x.shape}")
    sched = cfg.pairing
    if cfg.n_shards > 1:
        # Distributed two_level path: with a feature-sharding mesh context
        # active (parallel/ctx.activation_sharding(shard_feature=True))
        # whose "model" axis matches n_shards, shard-local runs execute on
        # the shard-resident slab and cross-shard stages lower to
        # collective_permute partner exchanges (parallel/spm_shard.py).
        from repro.parallel import ctx as par_ctx        # lazy: keeps core
        from repro.parallel import spm_shard             # import-light
        mesh = par_ctx.feature_mesh(cfg.n_shards)
        if mesh is not None and spm_shard.sharded_eligible(cfg, sched):
            return spm_shard.spm_apply_sharded(
                params, x, cfg, mesh, in_width=in_width, out_width=out_width)
    if use_fused_kernel(cfg, sched):
        # Fused full-operator path: the diag multiplies and bias add are
        # folded into the boundary runs of the kernel plan (zero extra HBM
        # round-trips), and the custom_vjp covers the whole operator.
        # Coefficients stay in their param dtype (f32): the kernel computes
        # f32 in VMEM regardless of the activation I/O dtype, and the
        # rotation variant's theta -> (a, b, c, d) chain differentiates
        # outside the kernel through the coefficient cotangent.
        from repro.kernels import ops as kernel_ops  # lazy: keeps core light
        return kernel_ops.spm_stack_fused(
            x, stage_coeffs(params, cfg), sched.strides(),
            d_in=params["d_in"] if cfg.use_diag else None,
            d_out=params["d_out"] if cfg.use_diag else None,
            bias=params["bias"] if cfg.use_bias else None,
            in_width=in_width, out_width=out_width,
            quant_acts=cfg.quant_acts, quant_coeffs=cfg.quant_coeffs)
    if in_width is not None:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, n - in_width)]
        x = jnp.pad(x, pad)  # spmlint: allow[SPM002] XLA fallback path
    coeffs = stage_coeffs(params, cfg).astype(x.dtype)
    res_scales = params.get("res_scale")
    if res_scales is None:
        res_scales = jnp.ones((cfg.n_stages,), x.dtype)
    else:
        res_scales = res_scales.astype(x.dtype)
    z = x
    if cfg.use_diag:
        z = z * params["d_in"].astype(x.dtype)
    core = _cached_core(sched, cfg.backward)
    z = core(coeffs, res_scales, z)
    if cfg.use_diag:
        z = z * params["d_out"].astype(x.dtype)
    if cfg.use_bias:
        z = z + params["bias"].astype(x.dtype)
    if out_width is not None:
        z = z[..., :out_width]
    return z


def spm_matrix(params: dict, cfg: SPMConfig) -> jax.Array:
    """Materialize the full n x n operator (tests/analysis only, O(n^2 L)).

    Returns W such that spm_apply(params, x) == W @ x + bias.
    """
    eye = jnp.eye(cfg.n, dtype=jnp.float32)
    p = dict(params)
    bias = p.pop("bias", None)
    cols = spm_apply({**p, "bias": jnp.zeros((cfg.n,))} if cfg.use_bias else p,
                     eye, cfg)
    return cols.T  # rows of output per basis vector -> transpose
