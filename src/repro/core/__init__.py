"""Core SPM operator (the paper's contribution) and the linear factory."""

from repro.core.pairings import (  # noqa: F401
    Schedule, Stage, butterfly_schedule, brick_schedule, random_schedule,
    two_level_schedule, make_schedule, default_n_stages,
    connectivity_components,
)
from repro.core.spm import (  # noqa: F401
    SPMConfig, init_spm, spm_apply, spm_matrix, stage_coeffs,
    kernel_eligible, use_fused_kernel,
)
from repro.core.linear import (  # noqa: F401
    LinearConfig, init_linear, linear_apply, linear_param_count,
    LINEAR_IMPLS, SPM_IMPLS,
)
