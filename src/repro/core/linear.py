"""Drop-in linear layer factory: dense baseline or SPM (paper's technique).

``linear_impl`` is the framework-wide knob (every architecture config carries
it) selecting how projection linears are parameterized:

  * "dense"        — y = x W + b, W (d_in, d_out).  The paper's baseline.
  * "spm_general"  — SPM with unconstrained 2x2 blocks (paper §3.2).
  * "spm_rotation" — SPM with orthogonal rotation blocks (paper §3.1).

Rectangular handling (DESIGN.md §5 — beyond the paper, which defines SPM for
square maps only): the SPM operates over ``n = even_ceil(max(d_in, d_out))``
and ``spm_apply`` is told the true I/O widths (``in_width=d_in``,
``out_width=d_out``).  On the fused Pallas path the zero-fill to n happens
IN VMEM inside the first kernel run (iota mask, no XLA ``jnp.pad``) and the
last run computes/stores only the d_out output columns (no dead columns, no
output slice) — the rectangular hot shapes (q/k/v, the d -> 4d FFN
up-projection, the LM head) keep the kernel's one-HBM-round-trip-per-run
property, and the input cotangent comes back ``(..., d_in)``.  The XLA
composition fallback realizes the same semantics with an explicit pad +
slice around the square operator.  For ``d_in == d_out`` (even) both paths
reduce exactly to the paper's operator.

``use_kernel`` selects the fused Pallas full-operator path (tri-state:
None = auto/on-TPU, True = force, False = off; see core/spm.py for the
eligibility + fallback rules).

Distributed feature axis: ``schedule="two_level"`` with ``n_shards > 1``
makes the operator distributable — inside an
``activation_sharding(mesh, shard_feature=True)`` block whose "model" axis
matches ``n_shards``, ``spm_apply`` routes through
``parallel/spm_shard.py`` (shard-local fused-kernel runs + one
collective_permute partner exchange per cross-shard stage); outside any
mesh context the same config runs unsharded (two_level is just a reordered
butterfly).  Model configs plumb these as ``spm_schedule`` /
``spm_n_shards`` (``configs.base.with_feature_sharding``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import spm as spm_mod
from repro.core.pairings import default_n_stages
from repro.core.spm import SPMConfig

__all__ = ["LinearConfig", "init_linear", "linear_apply",
           "linear_param_count", "spm_block_operands"]

SPM_IMPLS = ("spm_general", "spm_rotation")
LINEAR_IMPLS = ("dense",) + SPM_IMPLS


@dataclasses.dataclass(frozen=True)
class LinearConfig:
    d_in: int
    d_out: int
    impl: str = "dense"
    use_bias: bool = True
    n_stages: Optional[int] = None       # None -> min(ceil(log2 n), 12)
    schedule: str = "butterfly"
    backward: str = "autodiff"
    init_scale: float = 0.05
    n_shards: int = 1
    param_dtype: Any = jnp.float32
    use_kernel: Optional[bool] = None    # fused Pallas operator: None=auto
                                         # (on-TPU), True=force, False=off
    overlap: Optional[bool] = None       # overlap-scheduled sharded executor
                                         # (row-block pipelined cross-shard
                                         # exchanges): None=auto (on-TPU),
                                         # True=force the schedule (ppermute
                                         # transport off-TPU), False=off
    quant_acts: bool = False             # int8 activation I/O on the fused
                                         # kernel path (per-block scales;
                                         # see SPMConfig.quant_acts)
    quant_coeffs: bool = False           # int8 per-stage-scaled coefficient
                                         # tables dequantized in VMEM

    def __post_init__(self):
        if self.impl not in LINEAR_IMPLS:
            raise ValueError(f"unknown linear impl {self.impl!r}")

    @property
    def is_spm(self) -> bool:
        """Whether this linear is SPM-parameterized (vs the dense
        baseline)."""
        return self.impl in SPM_IMPLS

    @property
    def n(self) -> int:
        """Internal SPM operator width."""
        m = max(self.d_in, self.d_out)
        return m + (m % 2)

    def spm_config(self) -> SPMConfig:
        """The SPMConfig realizing this linear: square width ``self.n``,
        diag always on (the rectangular embedding needs the output scale),
        and ``custom_inverse`` silently downgraded to ``custom`` for the
        general variant (its blocks need not be orthogonal)."""
        variant = "rotation" if self.impl == "spm_rotation" else "general"
        n_stages = (self.n_stages if self.n_stages is not None
                    else default_n_stages(self.n))
        backward = self.backward
        if backward == "custom_inverse" and variant != "rotation":
            backward = "custom"
        return SPMConfig(
            n=self.n, n_stages=n_stages, variant=variant,
            schedule=self.schedule, use_diag=True, use_bias=self.use_bias,
            backward=backward, init_scale=self.init_scale,
            n_shards=self.n_shards, param_dtype=self.param_dtype,
            use_kernel=self.use_kernel, overlap=self.overlap,
            quant_acts=self.quant_acts, quant_coeffs=self.quant_coeffs)


def init_linear(key: jax.Array, cfg: LinearConfig) -> dict:
    """Initialize one linear's params: 1/sqrt(d_in) normal W (+ zero bias)
    for dense, else ``init_spm`` of the embedded square operator."""
    if cfg.impl == "dense":
        kw, _ = jax.random.split(key)
        std = cfg.d_in ** -0.5
        p = {"w": std * jax.random.normal(
            kw, (cfg.d_in, cfg.d_out), cfg.param_dtype)}
        if cfg.use_bias:
            p["b"] = jnp.zeros((cfg.d_out,), cfg.param_dtype)
        return p
    return spm_mod.init_spm(key, cfg.spm_config())


def linear_apply(params: dict, x: jax.Array, cfg: LinearConfig) -> jax.Array:
    """Apply to the last axis of x: (..., d_in) -> (..., d_out)."""
    if cfg.impl == "dense":
        y = x @ params["w"].astype(x.dtype)
        if cfg.use_bias:
            y = y + params["b"].astype(x.dtype)
        return y
    if x.shape[-1] != cfg.d_in:
        raise ValueError(f"expected (..., {cfg.d_in}), got {x.shape}")
    return spm_mod.spm_apply(params, x, cfg.spm_config(),
                             in_width=cfg.d_in, out_width=cfg.d_out)


def spm_block_operands(params: dict, cfg: LinearConfig) -> Optional[dict]:
    """Kernel operands for routing this linear through the residual-block
    megakernel (``kernels/ops.spm_block_fused``), or ``None`` when this
    linear cannot be one stack of a fused block.

    A linear qualifies when it is SPM-parameterized, unsharded, unquantized
    (the block kernel moves f32 tiles), kernel-expressible (all-structured
    stride stages, even n, no ``custom_inverse``), and structurally
    block-fusible (``core/eligibility.block_fusion_eligible`` — single
    full-width run, so its output never leaves VMEM).  The returned dict
    carries everything the block entry needs for ONE stack: ``coeffs``
    (L, n//2, 4), ``d_in``/``d_out``/``bias`` vectors (bias ``None`` when
    unused), ``strides``, and ``n``.  Layer entries
    (``layers/ffn.ffn_block_apply``, the fused-qkv path) combine two
    bundles (or one, for norm-prologue-only fusion) and resolve the
    tri-state ``spm_block_fuse`` knob before calling the kernel."""
    if not cfg.is_spm or cfg.n_shards > 1:
        return None
    if cfg.quant_acts or cfg.quant_coeffs:
        return None
    scfg = cfg.spm_config()
    sched = scfg.pairing
    from repro.core.eligibility import (block_fusion_eligible,
                                        kernel_eligible)
    if not kernel_eligible(scfg, sched):
        return None
    strides = sched.strides()
    if not block_fusion_eligible(scfg.n, strides):
        return None
    return {
        "coeffs": spm_mod.stage_coeffs(params, scfg),
        "d_in": params["d_in"],
        "d_out": params["d_out"],
        "bias": params["bias"] if scfg.use_bias else None,
        "strides": strides,
        "n": scfg.n,
    }


def linear_param_count(cfg: LinearConfig) -> int:
    """Learnable-parameter count of this linear (the paper's O(nL) vs
    O(d_in * d_out) comparison, Tables 1-4)."""
    if cfg.impl == "dense":
        return cfg.d_in * cfg.d_out + (cfg.d_out if cfg.use_bias else 0)
    return cfg.spm_config().param_count()
