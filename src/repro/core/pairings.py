"""Pairing schedules for Stagewise Pairwise Mixers (paper §2.1, §5).

A *pairing* for one stage partitions the ``n`` coordinates into ``n//2``
disjoint pairs (plus one optional unpaired residual lane when ``n`` is odd).
The paper allows arbitrary pairings per stage; on TPU arbitrary pairings
lower to dynamic gathers, so we distinguish two representations:

* **Structured (stride) pairings** — pair ``(i, i + s)`` inside contiguous
  groups of ``2s``.  These lower to a reshape ``(n,) -> (n/2s, 2, s)`` plus a
  vectorized 2x2 mix: a pure layout transform, VPU-friendly, no gather.
  Valid whenever ``n % (2*s) == 0``.
* **General (permutation) pairings** — an explicit index permutation; pairs
  are ``(perm[2i], perm[2i+1])``.  Paper-faithful fully-general path.

``Schedule`` holds one entry per stage.  ``two_level_schedule`` produces the
sharding-aware ordering used by the distributed fast path (DESIGN.md §3.4):
all shard-local strides first, then the cross-shard strides, so the latter
map onto ``collective_permute`` partner exchanges.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

__all__ = [
    "Stage",
    "Schedule",
    "butterfly_schedule",
    "brick_schedule",
    "random_schedule",
    "two_level_schedule",
    "valid_strides",
    "connectivity_components",
]


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: perm arrays
class Stage:
    """One mixing stage: either a stride (structured) or a permutation."""

    stride: Optional[int] = None          # structured pairing if not None
    perm: Optional[np.ndarray] = None     # general pairing if not None

    def __post_init__(self):
        if (self.stride is None) == (self.perm is None):
            raise ValueError("exactly one of stride/perm must be set")

    @property
    def structured(self) -> bool:
        return self.stride is not None


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash (see Stage)
class Schedule:
    """L pairing stages over an n-dimensional feature space."""

    n: int
    stages: tuple  # tuple[Stage, ...]

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def n_pairs(self) -> int:
        return self.n // 2

    @property
    def all_structured(self) -> bool:
        return all(s.structured for s in self.stages)

    def strides(self) -> tuple:
        """The per-stage stride tuple of an all-structured schedule (the
        form the fused kernels and the distributed executor consume)."""
        if not self.all_structured:
            raise ValueError("schedule contains general (perm) stages")
        return tuple(s.stride for s in self.stages)


def valid_strides(n: int) -> list:
    """All strides ``s`` with ``n % (2*s) == 0``, ascending."""
    return [s for s in range(1, n // 2 + 1) if n % (2 * s) == 0]


def _pow2_strides(n: int) -> list:
    """Power-of-two strides valid for n, ascending: 1, 2, 4, ..."""
    out, s = [], 1
    while n % (2 * s) == 0:
        out.append(s)
        s *= 2
    return out


def _butterfly_strides(n: int) -> list:
    """The butterfly stride recipe for width n: power-of-two strides
    ascending, then (for n = 2^k * m with odd m > 1) the odd-factor
    super-strides m * 2^j largest first.  Shared by butterfly_schedule and
    every level of two_level_schedule (the recipe applies alike to the
    full width, the shard-local block, and the shard index)."""
    base = _pow2_strides(n)
    k = len(base)
    m = n >> k
    cross = []
    if m > 1 and k:
        for j in range(k - 1, -1, -1):
            s = m << j
            if n % (2 * s) == 0:
                cross.append(s)
    return base + cross


def butterfly_schedule(n: int, n_stages: int) -> Schedule:
    """Default TPU-native schedule: power-of-two strides, ascending, plus
    "super-strides" that cross the odd-factor blocks of non-power-of-two n.

    For ``n = 2^k * m`` (m odd), strides ``1..2^(k-1)`` fully mix each
    ``2^k`` block; appended strides ``m*2^j`` (largest first) connect the m
    blocks.  The result is cycled/truncated to ``n_stages``.  Connectivity of
    the union of chosen strides is guaranteed (tested via
    ``connectivity_components``).
    """
    if n < 2 or n % 2:
        raise ValueError(f"butterfly_schedule requires even n >= 2, got {n}")
    cycle = _butterfly_strides(n)
    strides = [cycle[i % len(cycle)] for i in range(n_stages)]
    return Schedule(n=n, stages=tuple(Stage(stride=s) for s in strides))


def brick_schedule(n: int, n_stages: int) -> Schedule:
    """Adjacent pairing with alternating half-offset (brick-wall pattern).

    Stage 2t pairs (2i, 2i+1); stage 2t+1 pairs (2i+1, 2i+2) cyclically.
    Mixing radius grows linearly — included for ablations (paper permits any
    schedule); butterfly mixes exponentially faster.
    """
    if n < 2 or n % 2:
        raise ValueError("brick_schedule requires even n >= 2")
    stages = []
    for ell in range(n_stages):
        if ell % 2 == 0:
            stages.append(Stage(stride=1))
        else:
            perm = np.roll(np.arange(n), -1)  # pairs (2i+1, 2i+2)
            stages.append(Stage(perm=perm))
    return Schedule(n=n, stages=tuple(stages))


def random_schedule(n: int, n_stages: int, seed: int = 0) -> Schedule:
    """Fully general pairings: an independent random perfect matching per
    stage (paper §5: pairings 'may be chosen arbitrarily and independently').
    Odd n leaves the last permuted coordinate unpaired (residual lane)."""
    rng = np.random.default_rng(seed)
    stages = []
    for _ in range(n_stages):
        stages.append(Stage(perm=rng.permutation(n)))
    return Schedule(n=n, stages=tuple(stages))


def two_level_schedule(n: int, n_stages: int, n_shards: int) -> Schedule:
    """Sharding-aware butterfly: all shard-local strides first (stride <
    n_local), then cross-shard strides (multiples of n_local, ascending).

    With the feature axis sharded ``n = n_shards * n_local``, every stage is
    one of exactly two shapes the distributed executor
    (``parallel/spm_shard.py``) can realize:

    * **local** — ``n_local % (2*s) == 0``: pairs stay inside one shard
      block, so the stage runs on the shard-resident slab (fused Pallas
      kernel on TPU) with no communication.  Local strides follow the
      butterfly recipe applied WITHIN the block (power-of-two strides of
      ``n_local`` plus its odd-factor super-strides).
    * **cross** — ``s = k * n_local`` with ``k`` a power of two and
      ``n_shards % (2*k) == 0``: the stage pairs shard ``j`` with shard
      ``j XOR k`` — a partner exchange implementable as
      ``collective_permute`` plus a local 2x2 mix.

    The previous builder reused the GLOBAL power-of-two strides for the
    cross list, which for odd-factor ``n_local`` (e.g. n=48, 8 shards ->
    n_local=6) could emit strides straddling shard blocks without being a
    multiple of ``n_local``; crosses are now derived from the shard index
    butterfly directly, so the XOR-partner invariant holds by construction.
    When no valid local stride exists (e.g. ``n_local == 1`` or odd
    ``n_local``) the schedule falls back to ``local = [1]`` — still a valid
    stage for the unsharded executor (``n`` even), though such a stage pairs
    across shard boundaries and keeps the operator off the distributed path.
    """
    if n % n_shards:
        raise ValueError(f"n={n} not divisible by n_shards={n_shards}")
    n_local = n // n_shards
    local = _butterfly_strides(n_local)
    # Cross multipliers k follow the butterfly recipe ON THE SHARD INDEX:
    # power-of-two k give XOR partner exchanges; for even non-power-of-two
    # n_shards the odd-factor super-strides connect the remaining shard
    # blocks — valid global strides, but NOT partner exchanges, so such
    # schedules stay off the distributed executor (it is restricted to
    # power-of-two shard counts) while keeping the operator fully
    # connected.
    ks = _butterfly_strides(n_shards)
    cross = [k * n_local for k in ks]
    if not ks and n_shards > 1:
        # odd n_shards: no block-aligned cross stride exists at all (any
        # k*n_local needs n_shards % 2k == 0).  Fall back to the global
        # butterfly strides >= n_local so connectivity is preserved.
        cross = [s for s in _butterfly_strides(n) if s >= n_local]
    if not local:
        local = [1]
    cycle = sorted(set(local)) + sorted(set(cross))
    strides = [cycle[i % len(cycle)] for i in range(n_stages)]
    return Schedule(n=n, stages=tuple(Stage(stride=s) for s in strides))


def make_schedule(kind: str, n: int, n_stages: int, *, n_shards: int = 1,
                  seed: int = 0) -> Schedule:
    """Build a pairing schedule by kind: "butterfly" (default TPU-native),
    "brick" (ablation), "random" (fully general pairings), or "two_level"
    (sharding-aware; ``n_shards`` selects the block split)."""
    if kind == "butterfly":
        return butterfly_schedule(n, n_stages)
    if kind == "brick":
        return brick_schedule(n, n_stages)
    if kind == "random":
        return random_schedule(n, n_stages, seed=seed)
    if kind == "two_level":
        return two_level_schedule(n, n_stages, n_shards)
    raise ValueError(f"unknown schedule kind: {kind!r}")


def default_n_stages(n: int, cap: int = 12) -> int:
    """Paper §2.2 / §9.2: L <= log2 n for small n, log2 n for large n; the
    paper's own large-width runs fix L=12.  We use min(ceil(log2 n), cap)."""
    return max(1, min(int(np.ceil(np.log2(max(n, 2)))), cap))


# ---------------------------------------------------------------------------
# analysis helpers (test/benchmark only)
# ---------------------------------------------------------------------------

def _stage_pairs(stage: Stage, n: int) -> np.ndarray:
    """Return (n//2, 2) int array of paired coordinate indices."""
    if stage.structured:
        s = stage.stride
        g = n // (2 * s)
        idx = np.arange(n).reshape(g, 2, s)
        return np.stack([idx[:, 0, :].ravel(), idx[:, 1, :].ravel()], axis=1)
    perm = stage.perm
    npairs = len(perm) // 2
    return perm[: 2 * npairs].reshape(npairs, 2)


def connectivity_components(schedule: Schedule) -> int:
    """Number of connected components of the union pairing graph.  1 means
    the composed operator can couple every coordinate with every other."""
    parent = list(range(schedule.n))

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    for st in schedule.stages:
        for a, b in _stage_pairs(st, schedule.n):
            ra, rb = find(int(a)), find(int(b))
            if ra != rb:
                parent[ra] = rb
    return len({find(i) for i in range(schedule.n)})
