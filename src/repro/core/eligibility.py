"""One fallback matrix for every SPM execution path.

Before this module, "can this operator take the fast path?" was answered
in three places that had to agree by convention: ``core/spm.py`` decided
kernel eligibility (``kernel_eligible`` / ``use_fused_kernel``),
``parallel/spm_shard.py`` decided distributed eligibility
(``sharded_eligible``) plus its own private kernel re-resolution
(``_resolve_kernel``), and the overlap executor would have added a fourth.
This module is now the single home of those predicates; ``core/spm`` and
``parallel/spm_shard`` re-export them unchanged for back-compat.

The matrix (rows are operator properties, columns the three executors):

===========================  ==========  ===========  ================
property                     XLA compose fused kernel sharded executor
===========================  ==========  ===========  ================
permutation pairings         yes         no           no
odd n                        yes         no           no
backward=custom_inverse      yes         no           no
n % n_shards != 0            yes         yes          no
odd n_local (stride-1 list)  yes         yes          no
non-XOR cross stride         yes         yes          no
===========================  ==========  ===========  ================

and the two tri-state engagement knobs resolved here:

* ``use_kernel`` — fused Pallas operator.  ``None`` = auto (on-TPU only:
  off-TPU the kernels run in interpret mode, a validation tool), ``True``
  = force (interpret off-TPU), ``False`` = never.
* ``overlap`` — the overlap-scheduled sharded executor (row-block
  pipelined cross-shard exchanges, ``parallel/spm_shard.py``).  Same
  tri-state: ``None`` = auto (on-TPU only), ``True`` = force the overlap
  SCHEDULE everywhere (off-TPU it runs with the per-block
  collective_permute transport — the interpret-mode proof of
  correctness), ``False`` = keep the step-serial full-slab schedule.
  The in-kernel RDMA transport (``resolve_rdma``) additionally requires
  a real TPU backend: ``pltpu.make_async_remote_copy`` has no interpret
  realization, so off-TPU the overlap schedule always transports blocks
  via ``jax.lax.ppermute``.
* ``spm_block_fuse`` — the residual-block megakernel (norm prologue ->
  SPM -> activation -> SPM -> residual store in one Pallas chain,
  ``kernels/ops.spm_block_fused``).  Same tri-state: ``None`` = auto
  (on-TPU only), ``True`` = force (interpret off-TPU — how the parity
  tests run it), ``False`` = keep the per-linear fused composition.
  Resolved by ``resolve_block_fuse`` over ``block_fusion_eligible``.

All predicates take the ``SPMConfig`` duck-typed (attributes ``n``,
``odd``, ``n_shards``, ``backward``, ``pairing``, ``use_kernel``,
``overlap``) so this module depends only on ``core/pairings``.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from repro.core.pairings import Schedule

__all__ = ["plan_steps", "kernel_eligible", "use_fused_kernel",
           "sharded_eligible", "resolve_shard_kernel", "resolve_overlap",
           "resolve_rdma", "overlap_segments", "OVERLAP_ROW_BLOCKS",
           "TINY_ROW_THRESHOLD", "tiny_row_call", "quant_acts_eligible",
           "BLOCK_MAX_TILE", "BLOCK_ACTIVATIONS", "block_fusion_eligible",
           "resolve_block_fuse"]

# Row blocks per shard slab under the overlap schedule: block i's partner
# exchange hides under block i+1's compute, so >= 2 blocks are needed for
# any overlap and the marginal win shrinks past a handful (each block adds
# kernel-call overhead and, on the RDMA path, a VMEM send/recv slot pair
# amortized over fewer rows).  Lives here — the ONE module both the
# executor (parallel/spm_shard.pick_row_blocks) and the traffic model
# (launch/hlo_analysis.sharded_stage_traffic's overlap default) import —
# so the modeled pipeline depth can never drift from the executed one.
OVERLAP_ROW_BLOCKS = 4

# Decode-tick calls hit the fused kernel with rows = active batch slots —
# often 1-8, far below the training row counts the default feature-tiling
# assumes.  At or under this row count the kernel planner widens feature
# tiles instead (kernels/ops.plan_runs_for_rows): with a single 8-row
# block resident, VMEM affords much wider tiles, turning a many-run grid
# of dead rows into few wide runs.  Contract cells lower at rows=8, so
# the committed ANALYSIS baselines pin exactly this boundary.
TINY_ROW_THRESHOLD = 8


def tiny_row_call(n_rows: int) -> bool:
    """Whether a call with ``n_rows`` flattened batch rows should take the
    decode-specialized tiny-row kernel plan (wider feature tiles — see
    ``kernels/ops.plan_runs_for_rows``)."""
    return 0 < n_rows <= TINY_ROW_THRESHOLD


def quant_acts_eligible(runs) -> bool:
    """Whether a kernel run plan (``kernels/ops.plan_runs`` output:
    ``((strides, n_tile), ...)``) supports int8 ACTIVATION I/O.

    Activation scales are per (row-block, feature-tile), so a run's int8
    output chains into the next run as its int8 input only when BOTH runs
    tile the feature axis identically — the scale array produced by run r
    is indexed by run r+1's grid.  The predicate is therefore: one uniform
    feature tile across every run of the plan (single-run plans — the
    common case for butterfly schedules under the default tile cap — are
    trivially uniform).  Ineligible plans fall back to f32 activation I/O
    gracefully; quantized COEFFICIENT tables are per-stage-scaled and have
    no such constraint.  Lives here with the rest of the fallback matrix
    (single home for every SPM fast-path predicate)."""
    tiles = {n_tile for _, n_tile in runs}
    return len(tiles) == 1


def _is_pow2(k: int) -> bool:
    return k > 0 and (k & (k - 1)) == 0


# ---------------------------------------------------------------------------
# shard-schedule planning (pure stride arithmetic — no jax, no kernels)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def plan_steps(n: int, strides: Tuple[int, ...],
               n_shards: int) -> Tuple[tuple, ...]:
    """Split a stride schedule into shard-executable steps.

    Returns a tuple of ``("local", stage_offset, run_strides)`` /
    ``("cross", stage_index, k)`` entries covering the schedule in order;
    consecutive local stages are grouped into one run (one fused kernel
    call).  Raises ValueError when any stage is neither shard-local nor an
    XOR partner exchange — callers treat that as "not sharded-eligible".
    """
    if n % n_shards:
        raise ValueError(f"n={n} not divisible by n_shards={n_shards}")
    n_local = n // n_shards
    steps = []
    run: list = []
    run_start = 0
    for ell, s in enumerate(strides):
        if n % (2 * s):
            raise ValueError(f"stride {s} invalid for n={n}")
        if s < n_local and n_local % (2 * s) == 0:
            if not run:
                run_start = ell
            run.append(s)
            continue
        if run:
            steps.append(("local", run_start, tuple(run)))
            run = []
        k, rem = divmod(s, n_local)
        if rem or not _is_pow2(k) or n_shards % (2 * k):
            raise ValueError(
                f"stride {s} is neither local to n_local={n_local} nor a "
                f"power-of-two multiple partner exchange over "
                f"{n_shards} shards")
        steps.append(("cross", ell, k))
    if run:
        steps.append(("local", run_start, tuple(run)))
    return tuple(steps)


@functools.lru_cache(maxsize=None)
def overlap_segments(steps: Tuple[tuple, ...]) -> Tuple[tuple, ...]:
    """Group ``plan_steps`` output into overlap segments.

    Each segment is ``("pair", local_step, cross_step)`` — a shard-local
    run immediately followed by a cross stage, the shape the fused RDMA
    kernel executes as one ``pallas_call`` (the local mix of row block
    ``i+1`` hides block ``i``'s partner exchange) — or ``("one", step)``
    for an unpaired step (a trailing local run, or the 2nd+ of
    consecutive cross stages, whose exchange overlaps OTHER blocks' work
    in the row-block pipeline rather than a dedicated local run).
    """
    segs = []
    i = 0
    while i < len(steps):
        if (steps[i][0] == "local" and i + 1 < len(steps)
                and steps[i + 1][0] == "cross"):
            segs.append(("pair", steps[i], steps[i + 1]))
            i += 2
        else:
            segs.append(("one", steps[i]))
            i += 1
    return tuple(segs)


# ---------------------------------------------------------------------------
# fused-kernel eligibility (single device)
# ---------------------------------------------------------------------------

def kernel_eligible(cfg, sched: Optional[Schedule] = None) -> bool:
    """Whether the fused Pallas kernel can express this operator exactly:
    all-structured (stride) stages, even n, and a backward mode whose
    residual contract the kernel honors (custom_inverse stores outputs
    instead of inputs, so it falls back to the XLA composition).

    ``n_shards > 1`` is no longer an exclusion: when a feature-sharding
    mesh context is active, ``spm_apply`` routes the operator through the
    distributed executor (``parallel/spm_shard.py`` — shard-local runs
    through this same kernel, cross-shard stages as collective_permute
    partner exchanges) BEFORE this check; without a mesh context a
    two_level schedule is just a stride schedule and runs through the
    single-device fused kernel directly.  Remaining exclusions: permutation
    pairings, odd n, and ``custom_inverse``."""
    sched = cfg.pairing if sched is None else sched
    return (sched.all_structured and not cfg.odd
            and cfg.backward != "custom_inverse")


def use_fused_kernel(cfg, sched: Optional[Schedule] = None) -> bool:
    """Resolve the tri-state ``use_kernel`` knob (see SPMConfig)."""
    if cfg.use_kernel is False:
        return False
    if not kernel_eligible(cfg, sched):
        return False  # graceful fallback, even when forced on
    if cfg.use_kernel:
        return True
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# distributed-executor eligibility
# ---------------------------------------------------------------------------

def sharded_eligible(cfg, sched: Optional[Schedule] = None) -> bool:
    """Whether the distributed executor can express this operator exactly:
    even n divisible by n_shards, all-structured stages each either
    shard-local or an XOR partner exchange, and a backward mode whose
    residual contract the custom_vjp honors (custom_inverse stores outputs;
    this path stores step inputs)."""
    if cfg.n_shards <= 1 or cfg.odd or cfg.n % cfg.n_shards:
        return False
    if cfg.backward == "custom_inverse":
        return False
    sched = cfg.pairing if sched is None else sched
    if not sched.all_structured:
        return False
    try:
        plan_steps(cfg.n, sched.strides(), cfg.n_shards)
    except ValueError:
        return False
    return True


def resolve_shard_kernel(cfg, steps, backend_tpu: bool) -> bool:
    """Resolve the tri-state ``use_kernel`` knob for the shard-local runs
    (None = auto/on-TPU, True = force/interpret off-TPU, False = never);
    a schedule with no local steps has nothing to fuse."""
    if cfg.use_kernel is False:
        return False
    if not any(step[0] == "local" for step in steps):
        return False
    return True if cfg.use_kernel else backend_tpu


def resolve_overlap(cfg, steps, backend_tpu: bool) -> bool:
    """Resolve the tri-state ``overlap`` knob for the sharded executor.

    ``False`` — never.  ``True`` — force the overlap schedule (row-block
    pipelined exchanges; off-TPU the per-block transport is
    ``jax.lax.ppermute``, which is how the interpret-mode parity tests
    exercise the exact schedule the TPU path runs).  ``None`` — auto:
    engage only on a TPU backend, where the exchange actually has ICI
    latency to hide; off-TPU the step-serial PR 3/4 schedule remains the
    proof-of-correctness fallback.  Structurally the overlap schedule
    needs at least one cross stage (a communication-free schedule has
    nothing to overlap — re-blocking rows would only add kernel-call
    overhead)."""
    if getattr(cfg, "overlap", None) is False:
        return False
    if not any(step[0] == "cross" for step in steps):
        return False
    if getattr(cfg, "overlap", None):
        return True
    return backend_tpu


# ---------------------------------------------------------------------------
# residual-block fusion (megakernel) eligibility
# ---------------------------------------------------------------------------

# Mirrors kernels/ops.MAX_TILE without importing the kernels package (this
# module must stay import-light: core/pairings only).  Block fusion keeps a
# whole residual block's working set in VMEM, so the feature axis must fit
# ONE tile — the block kernel never re-tiles between the two stacks.
BLOCK_MAX_TILE = 2048

# Activations the block kernel's epilogue expresses closed-form (forward
# AND derivative, for the remat backward).  ``None`` is the norm-prologue
# -only entry (fused qkv).  swiglu is structurally excluded: its gate is a
# SECOND independent SPM operator over the same input, not a chainable
# elementwise epilogue.
BLOCK_ACTIVATIONS = (None, "relu", "silu", "gelu")


def block_fusion_eligible(n: int, strides1, strides2=None,
                          activation=None) -> bool:
    """Whether a residual block around SPM can lower as ONE fused Pallas
    kernel (norm prologue -> stack 1 -> activation -> stack 2 -> residual
    store).

    The structural condition is that both stacks run as a SINGLE full-width
    kernel run: every stride ``s`` of either stack must satisfy
    ``n % (2s) == 0`` (so the greedy run planner's lcm tile equals ``n``)
    and ``n`` must fit one VMEM tile (``BLOCK_MAX_TILE``).  With those, the
    mid-activation never leaves VMEM between the stacks.  The activation
    must be one the epilogue expresses closed-form both ways
    (``BLOCK_ACTIVATIONS``)."""
    if n <= 0 or n % 2 or n > BLOCK_MAX_TILE:
        return False
    for s in tuple(strides1) + tuple(strides2 if strides2 else ()):
        if n % (2 * int(s)):
            return False
    return activation in BLOCK_ACTIVATIONS


def resolve_block_fuse(block_fuse, eligible: bool,
                       backend_tpu: bool) -> bool:
    """Resolve the tri-state ``spm_block_fuse`` knob (layer configs).

    ``False`` — never fuse the block.  ``True`` — force (off-TPU the block
    kernel runs in interpret mode, the parity-test configuration).
    ``None`` — auto: fuse only on a TPU backend, where the saved HBM
    round-trips are real.  Ineligible blocks fall back gracefully even
    when forced on, mirroring ``use_fused_kernel``."""
    if not eligible:
        return False
    if block_fuse is None:
        return bool(backend_tpu)
    return bool(block_fuse)


def resolve_rdma(use_kernel: bool, backend_tpu: bool,
                 interpret: bool) -> bool:
    """Whether the overlap schedule's pair segments may use the in-kernel
    RDMA transport (``pltpu.make_async_remote_copy`` double-buffered over
    row blocks).  Requires the fused kernel path, a real TPU backend, and
    a compiled (non-interpret) kernel: interpret mode has no remote-DMA
    realization, so it keeps the per-block ppermute transport — by design
    the two transports realize the identical schedule."""
    return use_kernel and backend_tpu and not interpret
