"""Optimizer substrate: AdamW/cosine/clip + int8 error-feedback gradient
compression for the cross-pod axis."""

from repro.optim.adamw import (  # noqa: F401
    OptimizerConfig, init_opt_state, adamw_update, cosine_schedule,
    global_norm, clip_by_global_norm,
)
from repro.optim.compression import (  # noqa: F401
    compress, decompress, ef_step, psum_compressed, init_residual,
)
