"""Int8 error-feedback gradient compression for the cross-pod all-reduce.

At 512+ chips the pod axis crosses DCN (slow links): compressing the
gradient all-reduce over ``pod`` by 4x (f32 -> int8 with per-tensor
scale) cuts the dominant cross-pod collective term.  Error feedback keeps
the quantization residual locally and adds it to the next step's gradient,
preserving convergence (Karimireddy et al.-style EF-SGD argument).

``compress_tree``/``decompress_tree`` are pure functions usable inside a
jitted train step; ``psum_compressed`` wires them around
``jax.lax.psum`` for use under ``shard_map`` on the pod axis.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress", "decompress", "compress_tree", "decompress_tree",
           "ef_step", "psum_compressed"]


def _amax_scale(x: jax.Array) -> jax.Array:
    """Per-tensor int8 quantization scale: absmax / 127 (+eps)."""
    return jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-12


def compress(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """f32 -> (int8 values, f32 scale)."""
    xf = x.astype(jnp.float32)
    scale = _amax_scale(xf)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jax.Array, scale: jax.Array, dtype=jnp.float32
               ) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(tree: Any) -> Any:
    return jax.tree.map(lambda x: compress(x), tree,
                        is_leaf=lambda x: isinstance(x, jax.Array))


def decompress_tree(ctree: Any, like: Any) -> Any:
    return jax.tree.map(
        lambda c, x: decompress(c[0], c[1], x.dtype), ctree, like,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)


def ef_step(grads: Any, residual: Any) -> Tuple[Any, Any]:
    """Error-feedback: g' = g + residual; r' = g' - dequant(quant(g')).

    Returns (compressed-then-decompressed grads, new residual)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = compress(gf)
        deq = decompress(q, s)
        return deq.astype(g.dtype), gf - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def psum_compressed(grads: Any, axis_name: str) -> Any:
    """All-reduce int8-compressed gradients over ``axis_name`` (shard_map
    collective).  Sum of int8 payloads in int32, then rescale — exact for
    the quantized values; per-member scales are all-gathered (tiny)."""
    def one(g):
        # each member may have a different scale; reduce in scaled space:
        # sum_i q_i * s_i = psum(q * s) — but that defeats compression.
        # Standard trick: use the axis-max scale so payload stays int8.
        # Only the scale is needed here — quantizing against the LOCAL
        # scale first would be dead work (the payload is re-quantized
        # against s_max below).
        s_max = jax.lax.pmax(_amax_scale(g), axis_name)
        q2 = jnp.clip(jnp.round(g.astype(jnp.float32) / s_max),
                      -127, 127).astype(jnp.int8)
        total = jax.lax.psum(q2.astype(jnp.int32), axis_name)
        return (total.astype(jnp.float32) * s_max).astype(g.dtype)
    return jax.tree.map(one, grads)


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
