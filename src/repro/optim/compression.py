"""Int8 error-feedback gradient compression for the cross-pod all-reduce.

At 512+ chips the pod axis crosses DCN (slow links): compressing the
gradient all-reduce over ``pod`` by 4x (f32 -> int8 with per-tensor
scale) cuts the dominant cross-pod collective term.  Error feedback keeps
the quantization residual locally and adds it to the next step's gradient,
preserving convergence (Karimireddy et al.-style EF-SGD argument).

``compress_tree``/``decompress_tree`` are pure functions usable inside a
jitted train step; ``psum_compressed`` wires them around
``jax.lax.psum`` for use under ``shard_map`` on the pod axis.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress", "decompress", "compress_tree", "decompress_tree",
           "ef_step", "psum_compressed", "psum_compressed_ef",
           "init_residual"]


def _amax_scale(x: jax.Array) -> jax.Array:
    """Per-tensor int8 quantization scale: absmax / 127 (+eps)."""
    return jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0 + 1e-12


def _is_compressed_leaf(x: Any) -> bool:
    """Whether ``x`` is a ``compress`` result: a 2-tuple of (int8 array,
    scalar scale).  Keying off the CONTENT (dtype + rank) instead of
    "any 2-tuple" keeps legitimate 2-tuple pytree structure (e.g. a
    ``(mu, nu)`` state pair) traversable."""
    if not (isinstance(x, tuple) and len(x) == 2):
        return False
    q, s = x
    return (hasattr(q, "dtype") and q.dtype == jnp.int8
            and hasattr(s, "ndim") and jnp.ndim(s) == 0)


def compress(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """f32 -> (int8 values, f32 scale)."""
    xf = x.astype(jnp.float32)
    scale = _amax_scale(xf)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jax.Array, scale: jax.Array, dtype=jnp.float32
               ) -> jax.Array:
    """(int8 values, f32 scale) -> ``dtype`` (default f32)."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(tree: Any) -> Any:
    """``compress`` every array leaf: pytree of (int8 values, scale)."""
    return jax.tree.map(lambda x: compress(x), tree,
                        is_leaf=lambda x: isinstance(x, jax.Array))


def decompress_tree(ctree: Any, like: Any) -> Any:
    """Inverse of ``compress_tree``: dequantize every compressed leaf back
    to the dtype of the matching leaf of ``like``.  Compressed leaves are
    recognized by content — (int8 array, scalar scale) — so 2-tuples that
    are genuine pytree structure descend normally."""
    return jax.tree.map(
        lambda c, x: decompress(c[0], c[1], x.dtype), ctree, like,
        is_leaf=_is_compressed_leaf)


def ef_step(grads: Any, residual: Any) -> Tuple[Any, Any]:
    """Error-feedback: g' = g + residual; r' = g' - dequant(quant(g')).

    Returns (compressed-then-decompressed grads, new residual)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = compress(gf)
        deq = decompress(q, s)
        return deq.astype(g.dtype), gf - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def psum_compressed(grads: Any, axis_name: str) -> Any:
    """All-reduce int8-compressed gradients over ``axis_name`` (shard_map
    collective).  Sum of int8 payloads in int32, then rescale — exact for
    the quantized values; per-member scales are all-gathered (tiny)."""
    def one(g):
        # each member may have a different scale; reduce in scaled space:
        # sum_i q_i * s_i = psum(q * s) — but that defeats compression.
        # Standard trick: use the axis-max scale so payload stays int8.
        # Only the scale is needed here — quantizing against the LOCAL
        # scale first would be dead work (the payload is re-quantized
        # against s_max below).
        s_max = jax.lax.pmax(_amax_scale(g), axis_name)
        q2 = jnp.clip(jnp.round(g.astype(jnp.float32) / s_max),
                      -127, 127).astype(jnp.int8)
        total = jax.lax.psum(q2.astype(jnp.int32), axis_name)
        return (total.astype(jnp.float32) * s_max).astype(g.dtype)
    return jax.tree.map(one, grads)


def psum_compressed_ef(grads: Any, residual: Any, axis_name: str, *,
                       mean: bool = True) -> Tuple[Any, Any]:
    """Error-feedback int8 gradient all-reduce over ``axis_name``.

    Each member folds its LOCAL residual into the gradient BEFORE
    quantizing (g' = g + r), quantizes g' against the axis-max scale
    (pmax, so every member shares one dequant grid), psums the int8
    payload in int32, and keeps the local quantization error as the next
    step's residual (r' = g' - q * s).  Over steps the residual recycles
    what quantization dropped, making the compressed update unbiased in
    the EF-SGD sense.  Returns ``(total_grads, new_residual)``; with
    ``mean=True`` the total is divided by the axis size (gradient mean,
    matching an uncompressed ``pmean``) — the residual is kept in SUM
    space either way, since it is local error, not a reduced quantity."""
    inv_size = 1.0 / jax.lax.psum(1.0, axis_name)

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        s = jax.lax.pmax(_amax_scale(gf), axis_name)
        q = jnp.clip(jnp.round(gf / s), -127, 127)
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        out = total.astype(jnp.float32) * s
        if mean:
            out = out * inv_size
        return out.astype(g.dtype), gf - q * s

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def init_residual(params: Any) -> Any:
    """Zero error-feedback residual matching ``params`` (always f32 — the
    residual accumulates sub-quantum error smaller than one bf16 ulp)."""
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
