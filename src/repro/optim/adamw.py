"""AdamW + cosine schedule + global-norm clipping (pure JAX, no optax)."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptimizerConfig", "init_opt_state", "adamw_update",
           "cosine_schedule", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Any, max_norm: float
                        ) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), norm


def init_opt_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(params: Any, grads: Any, state: dict,
                 cfg: OptimizerConfig,
                 lr: Optional[jax.Array] = None) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_state, info)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state["count"] + 1
    if lr is None:
        lr = cosine_schedule(cfg, count)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        step = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    # extra optimizer-state keys (e.g. the error-feedback residual "ef"
    # carried by the compressed-psum train step) pass through untouched —
    # their owner updates them, AdamW only owns mu/nu/count
    new_state = {**state, "mu": new_mu, "nu": new_nu, "count": count}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
