"""Synthetic Bard corpus: byte-level char-LM data (paper §9.3 proxy).

The real Shakespeare file is unavailable offline, so we synthesize ~1MB of
byte text from a 3-gram Markov chain seeded with an embedded public-domain
passage.  The corpus has realistic char-LM statistics (entropy ~2 bits/char
of structure above uniform) — enough to test the paper's claim that SPM
matches dense NLL trajectories at ~4x lower step cost at d=4096.
"""

from __future__ import annotations

import numpy as np

__all__ = ["build_corpus", "corpus_batches", "VOCAB"]

VOCAB = 256

_SEED_TEXT = b"""
Shall I compare thee to a summer's day? Thou art more lovely and more
temperate: rough winds do shake the darling buds of May, and summer's
lease hath all too short a date. Sometime too hot the eye of heaven
shines, and often is his gold complexion dimm'd; and every fair from
fair sometime declines, by chance or nature's changing course untrimm'd.
But thy eternal summer shall not fade nor lose possession of that fair
thou ow'st; nor shall Death brag thou wander'st in his shade, when in
eternal lines to time thou grow'st: so long as men can breathe or eyes
can see, so long lives this, and this gives life to thee.
To be, or not to be, that is the question: whether 'tis nobler in the
mind to suffer the slings and arrows of outrageous fortune, or to take
arms against a sea of troubles and by opposing end them. To die - to
sleep, no more; and by a sleep to say we end the heart-ache and the
thousand natural shocks that flesh is heir to: 'tis a consummation
devoutly to be wish'd. To die, to sleep; to sleep, perchance to dream -
ay, there's the rub: for in that sleep of death what dreams may come,
when we have shuffled off this mortal coil, must give us pause - there's
the respect that makes calamity of so long life.
All the world's a stage, and all the men and women merely players; they
have their exits and their entrances, and one man in his time plays many
parts, his acts being seven ages. At first the infant, mewling and
puking in the nurse's arms. Then the whining schoolboy, with his satchel
and shining morning face, creeping like snail unwillingly to school.
"""


def build_corpus(n_bytes: int = 1_100_000, order: int = 3,
                 seed: int = 0) -> np.ndarray:
    """Markov-chain extension of the seed passage to ``n_bytes`` bytes."""
    rng = np.random.default_rng(seed)
    seedb = np.frombuffer(_SEED_TEXT, dtype=np.uint8)
    # transition table: context (order bytes) -> list of next bytes
    table: dict = {}
    for i in range(len(seedb) - order):
        ctx = bytes(seedb[i: i + order])
        table.setdefault(ctx, []).append(seedb[i + order])
    ctxs = list(table.keys())
    out = np.empty(n_bytes, np.uint8)
    out[: len(seedb)] = seedb
    pos = len(seedb)
    ctx = bytes(seedb[-order:])
    while pos < n_bytes:
        nexts = table.get(ctx)
        if not nexts:
            ctx = ctxs[rng.integers(len(ctxs))]
            continue
        b = nexts[rng.integers(len(nexts))]
        out[pos] = b
        pos += 1
        ctx = ctx[1:] + bytes([b])
    return out


def corpus_batches(corpus: np.ndarray, batch: int, seq_len: int,
                   rng: np.random.Generator):
    """Yield {tokens, labels} windows forever (deterministic given rng)."""
    n = len(corpus) - seq_len - 1
    while True:
        starts = rng.integers(0, n, size=batch)
        idx = starts[:, None] + np.arange(seq_len + 1)[None, :]
        chunk = corpus[idx]
        yield {"tokens": chunk[:, :-1].astype(np.int32),
               "labels": chunk[:, 1:].astype(np.int32)}
