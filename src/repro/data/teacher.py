"""Synthetic compositional teacher (paper §9.1).

Labels are produced by a frozen teacher ``argmax(W2 · ReLU(SPM(x)))`` —
the data-generating process IS a structured mixing stage followed by a
nonlinearity, which is the regime where the paper predicts SPM students
dominate dense students at equal width.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.pairings import default_n_stages
from repro.core.spm import SPMConfig, init_spm, spm_apply

__all__ = ["TeacherConfig", "make_teacher", "teacher_batch"]


@dataclasses.dataclass(frozen=True)
class TeacherConfig:
    width: int
    n_classes: int = 10
    n_stages: int | None = None
    seed: int = 0

    def spm_cfg(self) -> SPMConfig:
        L = self.n_stages or default_n_stages(self.width)
        return SPMConfig(n=self.width, n_stages=L, variant="general",
                         schedule="butterfly", init_mode="orthogonal",
                         init_scale=0.3)


def make_teacher(cfg: TeacherConfig) -> dict:
    key = jax.random.PRNGKey(cfg.seed)
    k1, k2 = jax.random.split(key)
    spm_params = init_spm(k1, cfg.spm_cfg())
    w2 = jax.random.normal(k2, (cfg.width, cfg.n_classes)) / cfg.width ** 0.5
    return {"spm": spm_params, "w2": w2}


def teacher_batch(teacher: dict, cfg: TeacherConfig, key: jax.Array,
                  batch: int) -> dict:
    """Draw x ~ N(0, I), label = argmax(W2 ReLU(SPM(x)))."""
    x = jax.random.normal(key, (batch, cfg.width))
    # spmlint: allow[SPM007] paper's teacher spec, not a fusible block
    h = jax.nn.relu(spm_apply(teacher["spm"], x, cfg.spm_cfg()))
    y = jnp.argmax(h @ teacher["w2"], axis=-1).astype(jnp.int32)
    return {"x": x, "y": y}
