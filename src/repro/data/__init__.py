"""Data pipeline: paper-experiment generators + deterministic loader."""

from repro.data.teacher import TeacherConfig, make_teacher, teacher_batch  # noqa: F401
from repro.data.hashed_text import HashedTextConfig, hashed_text_batch  # noqa: F401
from repro.data.char_corpus import build_corpus, corpus_batches, VOCAB  # noqa: F401
from repro.data.loader import DataCursor, DeterministicLoader  # noqa: F401
