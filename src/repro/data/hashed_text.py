"""AG News proxy: class-conditional hashed sparse features (paper §9.2).

No internet in this container, so the real AG News corpus is SIMULATED:
each of 4 classes owns a sparse set of "topic" hash buckets; a document
activates ``nnz`` buckets drawn from a mixture of its class distribution
and a shared background, with tf-style magnitudes.  This matches the
regime of the paper's experiment (hashed sparse features, 4 classes,
120k train / 7.6k test) without reproducing its exact numbers.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["HashedTextConfig", "hashed_text_batch"]


@dataclasses.dataclass(frozen=True)
class HashedTextConfig:
    width: int                  # hash-feature dimension (n in the paper)
    n_classes: int = 4
    nnz: int = 64               # active buckets per document
    class_frac: float = 0.35    # fraction of buckets drawn class-specific
    topics_per_class: int = 200
    seed: int = 0


def _class_tables(cfg: HashedTextConfig) -> jax.Array:
    key = jax.random.PRNGKey(cfg.seed)
    return jax.random.randint(
        key, (cfg.n_classes, cfg.topics_per_class), 0, cfg.width)


def hashed_text_batch(cfg: HashedTextConfig, key: jax.Array,
                      batch: int) -> dict:
    """Returns {x: (B, width) float32 sparse-ish, y: (B,) int32}."""
    tables = _class_tables(cfg)
    ky, kc, kb, km, kv = jax.random.split(key, 5)
    y = jax.random.randint(ky, (batch,), 0, cfg.n_classes)
    n_class = int(cfg.nnz * cfg.class_frac)
    n_bg = cfg.nnz - n_class
    # class-specific buckets
    tidx = jax.random.randint(kc, (batch, n_class), 0, cfg.topics_per_class)
    cls_buckets = tables[y[:, None], tidx]                   # (B, n_class)
    # background buckets
    bg_buckets = jax.random.randint(kb, (batch, n_bg), 0, cfg.width)
    buckets = jnp.concatenate([cls_buckets, bg_buckets], axis=1)
    mags = 0.5 + jax.random.exponential(kv, buckets.shape)
    x = jnp.zeros((batch, cfg.width)).at[
        jnp.arange(batch)[:, None], buckets].add(mags)
    x = x / jnp.maximum(jnp.linalg.norm(x, axis=1, keepdims=True), 1e-6)
    return {"x": x, "y": y.astype(jnp.int32)}
