"""Deterministic sharded loader with resume cursors.

The global batch at step ``s`` is a pure function of (seed, s): each
restart resumes bitwise-identically from the checkpointed step counter —
no iterator state needs saving.  Per-host sharding slices the global
batch by ``process_index`` so 1000-node runs read disjoint shards.

``resume`` is defensive: a checkpoint written by an older trainer (no
cursor extra, or a partial one) degrades to a fresh cursor with a logged
warning instead of killing the restore — losing data-order continuity is
recoverable, crashing the resume path is not.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Callable, Optional

import jax
import numpy as np

log = logging.getLogger("repro.data")

__all__ = ["DataCursor", "DeterministicLoader"]


@dataclasses.dataclass
class DataCursor:
    """Position in the deterministic stream: (seed, step) is the whole
    state — the batch at any step is recomputable from it."""

    seed: int
    step: int = 0

    def state_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_state(cls, d: dict) -> "DataCursor":
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class DeterministicLoader:
    """Wraps a ``batch_fn(key, global_batch) -> pytree`` generator."""

    def __init__(self, batch_fn: Callable, global_batch: int, seed: int = 0,
                 n_hosts: int = 1, host_id: int = 0):
        assert global_batch % n_hosts == 0
        self.batch_fn = batch_fn
        self.global_batch = global_batch
        self.cursor = DataCursor(seed=seed)
        self.n_hosts = n_hosts
        self.host_id = host_id

    def batch_at(self, step: int):
        """The (host-sharded) batch for ``step`` — pure in (seed, step)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.cursor.seed), step)
        batch = self.batch_fn(key, self.global_batch)
        if self.n_hosts > 1:
            per = self.global_batch // self.n_hosts
            lo = self.host_id * per
            batch = jax.tree.map(lambda x: x[lo: lo + per], batch)
        return batch

    def __next__(self):
        b = self.batch_at(self.cursor.step)
        self.cursor.step += 1
        return b

    def __iter__(self):
        return self

    def state_dict(self) -> dict:
        """Checkpointable cursor state (pass as the ``cursor`` extra)."""
        return self.cursor.state_dict()

    def resume(self, cursor_state: Optional[dict]) -> bool:
        """Restore the cursor from checkpointed state.

        Returns True on success.  ``None`` or a dict missing
        ``seed``/``step`` (older checkpoint formats) keeps the current
        fresh cursor and logs a warning — the restore path must not
        crash over a missing data cursor."""
        if cursor_state is None:
            log.warning("no data cursor in checkpoint; keeping fresh "
                        "cursor (seed=%d, step=%d)",
                        self.cursor.seed, self.cursor.step)
            return False
        try:
            self.cursor = DataCursor.from_state(cursor_state)
        except (KeyError, TypeError, ValueError) as e:
            log.warning("unusable data cursor %r in checkpoint (%s); "
                        "keeping fresh cursor", cursor_state, e)
            return False
        return True
