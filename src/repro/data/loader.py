"""Deterministic sharded loader with resume cursors.

The global batch at step ``s`` is a pure function of (seed, s): each
restart resumes bitwise-identically from the checkpointed step counter —
no iterator state needs saving.  Per-host sharding slices the global
batch by ``process_index`` so 1000-node runs read disjoint shards.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import numpy as np

__all__ = ["DataCursor", "DeterministicLoader"]


@dataclasses.dataclass
class DataCursor:
    seed: int
    step: int = 0

    def state_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_state(cls, d: dict) -> "DataCursor":
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class DeterministicLoader:
    """Wraps a ``batch_fn(key, global_batch) -> pytree`` generator."""

    def __init__(self, batch_fn: Callable, global_batch: int, seed: int = 0,
                 n_hosts: int = 1, host_id: int = 0):
        assert global_batch % n_hosts == 0
        self.batch_fn = batch_fn
        self.global_batch = global_batch
        self.cursor = DataCursor(seed=seed)
        self.n_hosts = n_hosts
        self.host_id = host_id

    def batch_at(self, step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.cursor.seed), step)
        batch = self.batch_fn(key, self.global_batch)
        if self.n_hosts > 1:
            per = self.global_batch // self.n_hosts
            lo = self.host_id * per
            batch = jax.tree.map(lambda x: x[lo: lo + per], batch)
        return batch

    def __next__(self):
        b = self.batch_at(self.cursor.step)
        self.cursor.step += 1
        return b

    def __iter__(self):
        return self

    def resume(self, cursor_state: dict) -> None:
        self.cursor = DataCursor.from_state(cursor_state)
