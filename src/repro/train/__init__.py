"""Training substrate: state, step factories, checkpointing, fault policy."""

from repro.train.state import make_train_state, param_count  # noqa: F401
from repro.train.step import make_train_step, make_eval_step  # noqa: F401
from repro.train.checkpoint import (  # noqa: F401
    save_checkpoint, restore_checkpoint, latest_step, list_checkpoints,
)
from repro.train.fault import FaultPolicy, run_with_recovery  # noqa: F401
