"""Training substrate: state, step factories, verified-integrity
checkpointing, fault policy / recovery orchestration, chaos injection."""

from repro.train.state import (  # noqa: F401
    make_train_state, param_count, tree_signature,
)
from repro.train.step import (  # noqa: F401
    make_eval_step, make_pod_train_step, make_train_step, pod_residual,
)
from repro.train.checkpoint import (  # noqa: F401
    CheckpointCorruptError, latest_step, latest_valid_step,
    list_checkpoints, quarantine_checkpoint, restore_checkpoint,
    save_checkpoint, verify_checkpoint,
)
from repro.train.fault import (  # noqa: F401
    RESUME_LATEST, FaultEventLog, FaultPolicy, StragglerDetector,
    run_with_recovery,
)
from repro.train.chaos import (  # noqa: F401
    ChaosPreemption, ChaosSchedule, corrupt_checkpoint,
)
