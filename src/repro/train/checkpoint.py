"""Topology-independent checkpointing: atomic npz + treedef JSON.

* **Atomic**: write to a uniquely-named ``<dir>/tmp.<step>.<nonce>`` then
  ``os.replace`` into ``step_<step>`` — nothing already published is
  deleted before the new data is in place, so a crash at ANY point leaves
  every previously visible checkpoint intact (the only non-atomic case is
  re-saving an already-published step, where the old copy is moved aside —
  not deleted — for the instant of the publish).  The next save first
  REPUBLISHES any complete payload a crash left unpublished in staging,
  then garbage-collects the remaining stale ``tmp.*`` dirs.  One writer
  per ``ckpt_dir`` is assumed (as everywhere in this trainer).
* **Keep-N**: old checkpoints garbage-collected.
* **Topology-independent**: arrays are saved as host numpy (fully
  addressable gather); on restore the caller re-applies whatever
  shardings the CURRENT mesh dictates — a run checkpointed on 256 chips
  restarts on 512 or 64 (elastic re-shard), because nothing about the
  mesh is serialized.
* The data-loader cursor and the step counter ride along, so restarts
  are bitwise-reproducible.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import uuid
from typing import Any, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "list_checkpoints"]

_STEP_RE = re.compile(r"^step_(\d+)$")
_TMP_RE = re.compile(r"^tmp\.(\d+)\.[0-9a-f]+(\.displaced)?$")


def _recover_staging(ckpt_dir: str) -> None:
    """Republish complete staging dirs orphaned by a crash mid-publish.

    A crash between the two renames of a same-step re-save leaves
    ``step_<s>`` missing while ``tmp.<s>.<nonce>`` (new payload) and/or
    ``tmp.<s>.<nonce>.displaced`` (the previously published copy) hold a
    complete checkpoint.  Promote one of them — preferring the fresh
    payload over the displaced one — so the keep-N sweep that follows
    never deletes the only copy of a step."""
    by_step: dict = {}
    for name in os.listdir(ckpt_dir):
        m = _TMP_RE.match(name)
        if not m:
            continue
        path = os.path.join(ckpt_dir, name)
        complete = (os.path.exists(os.path.join(path, "meta.json"))
                    and os.path.exists(os.path.join(path, "arrays.npz")))
        if complete:
            # displaced (old) copies sort after fresh ones
            by_step.setdefault(int(m.group(1)), []).append(
                (bool(m.group(2)), path))
    for step, candidates in by_step.items():
        final = os.path.join(ckpt_dir, f"step_{step}")
        if os.path.exists(final):
            continue
        try:
            os.replace(sorted(candidates)[0][1], final)
        except OSError:
            # read paths also recover (a resuming process reads before it
            # saves) and must stay usable on read-only mounts or when the
            # single writer republishes concurrently — fall back to
            # whatever is published rather than raise
            pass


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save_checkpoint(ckpt_dir: str, step: int, state: Any,
                    extra: Optional[dict] = None, keep: int = 3) -> str:
    """Save pytree ``state`` (+ JSON-serializable ``extra``) at ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    # First, promote any complete-but-unpublished payload a crashed save
    # left behind — the sweep at the end deletes whatever staging remains.
    _recover_staging(ckpt_dir)
    # Unique staging name: a crashed save's leftover can never collide with
    # (and must never be deleted by) the current one before it publishes.
    tmp = os.path.join(ckpt_dir, f"tmp.{step}.{uuid.uuid4().hex[:8]}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp)

    flat, treedef = _flatten_with_names(state)
    arrays = {f"a{i}": np.asarray(jax.device_get(x))
              for i, x in enumerate(flat)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"n_arrays": len(flat),
            "treedef": str(treedef),
            "step": step,
            "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)

    # Publish WITHOUT deleting anything first.  ``os.replace`` cannot land
    # a directory on a non-empty target, so re-saving an existing step
    # moves the old copy aside (rename, still recoverable) for the instant
    # of the swap instead of rmtree-ing it beforehand — a crash between the
    # two renames leaves both the old and new payloads on disk as tmp-like
    # dirs and every OTHER published step untouched.
    if os.path.exists(final):
        displaced = tmp + ".displaced"
        os.replace(final, displaced)
        os.replace(tmp, final)
        shutil.rmtree(displaced, ignore_errors=True)
    else:
        os.replace(tmp, final)                  # atomic publish

    # keep-N garbage collection + stale staging dirs from crashed saves
    # (ours was renamed away above, so every remaining tmp.* is stale).
    steps = sorted(list_checkpoints(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
    for name in os.listdir(ckpt_dir):
        if name.startswith("tmp."):
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
    return final


def list_checkpoints(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    # a resuming process must see a step whose publish was interrupted,
    # not silently fall back to an older one
    if os.path.isdir(ckpt_dir):
        _recover_staging(ckpt_dir)
    steps = list_checkpoints(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, like: Any,
                       step: Optional[int] = None,
                       shardings: Any = None) -> Tuple[Any, dict]:
    """Restore into the structure of ``like``.  If ``shardings`` (a pytree
    of NamedSharding matching ``like``) is given, arrays are placed
    sharded — this is the elastic re-shard path."""
    if os.path.isdir(ckpt_dir):
        _recover_staging(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    npz = np.load(os.path.join(d, "arrays.npz"))
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    assert meta["n_arrays"] == len(flat_like), "structure mismatch"
    flat = [npz[f"a{i}"] for i in range(len(flat_like))]
    if shardings is not None:
        flat_sh = jax.tree_util.tree_flatten(shardings)[0]
        flat = [jax.device_put(x, s) for x, s in zip(flat, flat_sh)]
    else:
        flat = [jax.numpy.asarray(x) for x in flat]
    state = treedef.unflatten(flat)
    return state, meta["extra"]
