"""Verified-integrity, topology-independent checkpointing.

* **Atomic**: write to a uniquely-named ``<dir>/tmp.<step>.<nonce>`` then
  ``os.replace`` into ``step_<step>`` — nothing already published is
  deleted before the new data is in place, so a crash at ANY point leaves
  every previously visible checkpoint intact (the only non-atomic case is
  re-saving an already-published step, where the old copy is moved aside —
  not deleted — for the instant of the publish).  The next save first
  REPUBLISHES any complete payload a crash left unpublished in staging,
  then garbage-collects the remaining stale ``tmp.*`` dirs.  One writer
  per ``ckpt_dir`` is assumed (as everywhere in this trainer).
* **Verified integrity**: ``meta.json`` carries a per-array manifest
  (sha256 of the raw array bytes, shape, dtype) plus the treedef string.
  ``verify_checkpoint`` is the public probe — it re-hashes every array and
  reports every discrepancy; ``restore_checkpoint`` verifies before
  deserializing, compares the saved treedef against the caller's ``like``
  structure, and **quarantines** a corrupt or incomplete step (renamed
  ``corrupt.<step>.<nonce>``, kept on disk for forensics, never counted as
  the newest step again) while walking back to the newest step that DOES
  verify.  A flipped bit, a truncated ``arrays.npz``, or a deleted
  ``meta.json`` therefore costs one checkpoint interval, not a silently
  wrong resume.
* **Keep-N**: old checkpoints garbage-collected; quarantined dirs are
  exempt from the sweep.
* **Topology-independent**: arrays are saved as host numpy (fully
  addressable gather); on restore the caller re-applies whatever
  shardings the CURRENT mesh dictates — a run checkpointed on 256 chips
  restarts on 512 or 64 (elastic re-shard), because nothing about the
  mesh is serialized.  Proven end-to-end by the chaos parity harness
  (tests/test_chaos_distributed.py): a preempted sharded run resumes onto
  a different shard count bitwise-identically.
* The data-loader cursor and the step counter ride along, so restarts
  are bitwise-reproducible.
"""

from __future__ import annotations

import hashlib
import json
import logging
import os
import re
import shutil
import uuid
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "latest_valid_step", "list_checkpoints", "verify_checkpoint",
           "quarantine_checkpoint", "CheckpointCorruptError"]

log = logging.getLogger("repro.checkpoint")

_STEP_RE = re.compile(r"^step_(\d+)$")
_TMP_RE = re.compile(r"^tmp\.(\d+)\.[0-9a-f]+(\.displaced)?$")
_CORRUPT_RE = re.compile(r"^corrupt\.(\d+)\.[0-9a-f]+$")

MANIFEST_VERSION = 2


class CheckpointCorruptError(RuntimeError):
    """An explicitly requested checkpoint failed integrity verification
    (hash/shape/dtype mismatch, truncated payload, or missing metadata)."""


def _recover_staging(ckpt_dir: str) -> None:
    """Republish complete staging dirs orphaned by a crash mid-publish.

    A crash between the two renames of a same-step re-save leaves
    ``step_<s>`` missing while ``tmp.<s>.<nonce>`` (new payload) and/or
    ``tmp.<s>.<nonce>.displaced`` (the previously published copy) hold a
    complete checkpoint.  Promote one of them — preferring the fresh
    payload over the displaced one — so the keep-N sweep that follows
    never deletes the only copy of a step."""
    by_step: dict = {}
    for name in os.listdir(ckpt_dir):
        m = _TMP_RE.match(name)
        if not m:
            continue
        path = os.path.join(ckpt_dir, name)
        complete = (os.path.exists(os.path.join(path, "meta.json"))
                    and os.path.exists(os.path.join(path, "arrays.npz")))
        if complete:
            # displaced (old) copies sort after fresh ones
            by_step.setdefault(int(m.group(1)), []).append(
                (bool(m.group(2)), path))
    for step, candidates in by_step.items():
        final = os.path.join(ckpt_dir, f"step_{step}")
        if os.path.exists(final):
            continue
        try:
            os.replace(sorted(candidates)[0][1], final)
        except OSError:
            # read paths also recover (a resuming process reads before it
            # saves) and must stay usable on read-only mounts or when the
            # single writer republishes concurrently — fall back to
            # whatever is published rather than raise
            pass


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def _array_digest(arr: np.ndarray) -> str:
    """sha256 over the raw C-contiguous bytes of ``arr`` — the content
    address the manifest records and ``verify_checkpoint`` re-derives."""
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def _file_digest(path: str) -> str:
    """sha256 of a file's raw bytes (chunked).  The whole-file digest of
    ``arrays.npz`` catches flips in zip slack/padding bytes that the
    per-array digests cannot see (np.load tolerates them)."""
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _meta_digest(meta: dict) -> str:
    """sha256 of the canonical (sorted-keys) JSON of ``meta`` minus the
    digest field itself — the whole-metadata self-check."""
    core = {k: v for k, v in meta.items() if k != "meta_sha256"}
    return hashlib.sha256(
        json.dumps(core, sort_keys=True).encode()).hexdigest()


def save_checkpoint(ckpt_dir: str, step: int, state: Any,
                    extra: Optional[dict] = None, keep: int = 3) -> str:
    """Save pytree ``state`` (+ JSON-serializable ``extra``) at ``step``.

    ``meta.json`` records a per-array integrity manifest (sha256, shape,
    dtype) and the treedef string; ``restore_checkpoint`` /
    ``verify_checkpoint`` check both.  Returns the published path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    # First, promote any complete-but-unpublished payload a crashed save
    # left behind — the sweep at the end deletes whatever staging remains.
    _recover_staging(ckpt_dir)
    # Unique staging name: a crashed save's leftover can never collide with
    # (and must never be deleted by) the current one before it publishes.
    tmp = os.path.join(ckpt_dir, f"tmp.{step}.{uuid.uuid4().hex[:8]}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp)

    flat, treedef = _flatten_with_names(state)
    arrays = {f"a{i}": np.asarray(jax.device_get(x))
              for i, x in enumerate(flat)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {name: {"sha256": _array_digest(a),
                       "shape": list(a.shape),
                       "dtype": str(a.dtype)}
                for name, a in arrays.items()}
    meta = {"n_arrays": len(flat),
            "treedef": str(treedef),
            "step": step,
            "format": MANIFEST_VERSION,
            "manifest": manifest,
            "npz_sha256": _file_digest(os.path.join(tmp, "arrays.npz")),
            "extra": extra or {}}
    # Self-digest over the canonical form of everything above: a flipped
    # byte anywhere in meta.json (cursor, treedef, manifest, or the digest
    # itself) fails verification, not just flips inside arrays.npz.
    meta["meta_sha256"] = _meta_digest(meta)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)

    # Publish WITHOUT deleting anything first.  ``os.replace`` cannot land
    # a directory on a non-empty target, so re-saving an existing step
    # moves the old copy aside (rename, still recoverable) for the instant
    # of the swap instead of rmtree-ing it beforehand — a crash between the
    # two renames leaves both the old and new payloads on disk as tmp-like
    # dirs and every OTHER published step untouched.
    if os.path.exists(final):
        displaced = tmp + ".displaced"
        os.replace(final, displaced)
        os.replace(tmp, final)
        shutil.rmtree(displaced, ignore_errors=True)
    else:
        os.replace(tmp, final)                  # atomic publish

    # keep-N garbage collection + stale staging dirs from crashed saves
    # (ours was renamed away above, so every remaining tmp.* is stale).
    # Quarantined ``corrupt.*`` dirs match neither pattern: never swept.
    steps = sorted(list_checkpoints(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
    for name in os.listdir(ckpt_dir):
        if name.startswith("tmp."):
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
    return final


def list_checkpoints(ckpt_dir: str):
    """Published step numbers (ascending) whose payload files are present.
    Quarantined ``corrupt.*`` dirs and staging ``tmp.*`` dirs are not
    checkpoints and never appear here."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if (m and os.path.exists(os.path.join(ckpt_dir, name, "meta.json"))
                and os.path.exists(
                    os.path.join(ckpt_dir, name, "arrays.npz"))):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest published step (no integrity verification — see
    ``latest_valid_step`` for the verified walk)."""
    # a resuming process must see a step whose publish was interrupted,
    # not silently fall back to an older one
    if os.path.isdir(ckpt_dir):
        _recover_staging(ckpt_dir)
    steps = list_checkpoints(ckpt_dir)
    return steps[-1] if steps else None


def verify_checkpoint(ckpt_dir: str, step: int) -> List[str]:
    """Integrity probe for one published step.  Returns a list of
    human-readable problems — empty means the checkpoint verifies.

    Checks: payload files present, ``meta.json`` parses, carries the
    integrity manifest, and matches its own self-digest (a flip in the
    cursor/extra bytes is as fatal as one in an array), ``arrays.npz``
    loads, the array set matches the manifest exactly, and every array's
    sha256/shape/dtype matches its manifest entry — so corrupting ANY
    byte of the payload is caught."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    if not os.path.isdir(d):
        return [f"step_{step}: directory missing"]
    problems = []
    meta_path = os.path.join(d, "meta.json")
    npz_path = os.path.join(d, "arrays.npz")
    if not os.path.exists(meta_path):
        return [f"step_{step}: meta.json missing"]
    if not os.path.exists(npz_path):
        return [f"step_{step}: arrays.npz missing"]
    try:
        with open(meta_path) as f:
            meta = json.load(f)
    except (ValueError, OSError) as e:
        # ValueError covers JSONDecodeError AND UnicodeDecodeError — a
        # flipped byte can make the file invalid UTF-8 before invalid JSON
        return [f"step_{step}: meta.json unreadable ({e})"]
    manifest = meta.get("manifest")
    if not isinstance(manifest, dict):
        return [f"step_{step}: no integrity manifest in meta.json "
                f"(format={meta.get('format')})"]
    if meta.get("meta_sha256") != _meta_digest(meta):
        return [f"step_{step}: meta.json self-digest mismatch"]
    if meta.get("npz_sha256") != _file_digest(npz_path):
        return [f"step_{step}: arrays.npz whole-file sha256 mismatch"]
    try:
        npz = np.load(npz_path)
    except Exception as e:  # truncated/garbled zip container
        return [f"step_{step}: arrays.npz unreadable ({e})"]
    try:
        names = set(npz.files)
        expect = set(manifest)
        if names != expect:
            problems.append(
                f"step_{step}: array set mismatch "
                f"(missing={sorted(expect - names)}, "
                f"unexpected={sorted(names - expect)})")
        if meta.get("n_arrays") != len(manifest):
            problems.append(f"step_{step}: n_arrays={meta.get('n_arrays')} "
                            f"!= manifest size {len(manifest)}")
        for name in sorted(expect & names):
            ent = manifest[name]
            try:
                arr = npz[name]
            except Exception as e:  # per-member decompression/CRC failure
                problems.append(f"step_{step}: array {name} unreadable "
                                f"({e})")
                continue
            if list(arr.shape) != list(ent["shape"]):
                problems.append(f"step_{step}: {name} shape {list(arr.shape)}"
                                f" != manifest {ent['shape']}")
            elif str(arr.dtype) != ent["dtype"]:
                problems.append(f"step_{step}: {name} dtype {arr.dtype} "
                                f"!= manifest {ent['dtype']}")
            elif _array_digest(arr) != ent["sha256"]:
                problems.append(f"step_{step}: {name} sha256 mismatch")
    finally:
        npz.close()
    return problems


def quarantine_checkpoint(ckpt_dir: str, step: int, reason: str,
                          event_log: Any = None) -> Optional[str]:
    """Move a corrupt/incomplete ``step_<step>`` aside as
    ``corrupt.<step>.<nonce>`` so it is never again selected as the newest
    step (and never GC'd by the keep-N sweep — kept for forensics).
    Returns the quarantine path, or None if the step dir vanished."""
    src = os.path.join(ckpt_dir, f"step_{step}")
    if not os.path.isdir(src):
        return None
    dst = os.path.join(ckpt_dir, f"corrupt.{step}.{uuid.uuid4().hex[:8]}")
    os.replace(src, dst)
    log.warning("quarantined corrupt checkpoint step %d -> %s (%s)",
                step, os.path.basename(dst), reason)
    if event_log is not None:
        event_log.emit("quarantine", step=step, cause=reason,
                       path=os.path.basename(dst))
    return dst


def latest_valid_step(ckpt_dir: str, event_log: Any = None) -> Optional[int]:
    """Newest step that passes ``verify_checkpoint``, quarantining every
    newer step that does not — including step dirs whose payload files are
    missing outright (e.g. a deleted ``meta.json``), which
    ``list_checkpoints`` cannot even list.  Returns None when nothing
    verifies."""
    if not os.path.isdir(ckpt_dir):
        return None
    _recover_staging(ckpt_dir)
    listed = set(list_checkpoints(ckpt_dir))
    all_steps = sorted(int(m.group(1)) for name in os.listdir(ckpt_dir)
                       if (m := _STEP_RE.match(name)))
    for step in reversed(all_steps):
        problems = ([f"step_{step}: incomplete payload"]
                    if step not in listed
                    else verify_checkpoint(ckpt_dir, step))
        if not problems:
            return step
        quarantine_checkpoint(ckpt_dir, step, "; ".join(problems),
                              event_log=event_log)
    return None


def restore_checkpoint(ckpt_dir: str, like: Any,
                       step: Optional[int] = None,
                       shardings: Any = None,
                       verify: bool = True,
                       event_log: Any = None) -> Tuple[Any, dict]:
    """Restore into the structure of ``like``.

    With ``step=None`` the newest checkpoint that passes integrity
    verification is selected: corrupt or incomplete newer steps are
    quarantined (``corrupt.<step>.<nonce>``) and the walk continues to the
    previous step — ``FileNotFoundError`` only when NOTHING verifies.  An
    explicitly requested ``step`` that fails verification raises
    ``CheckpointCorruptError`` (after quarantining it).  The saved treedef
    is compared against ``like``'s — a mismatch raises ``ValueError``
    rather than scattering arrays into the wrong slots.

    If ``shardings`` (a pytree of NamedSharding matching ``like``) is
    given, arrays are placed sharded — this is the elastic re-shard path.
    ``verify=False`` skips hashing (trusted local reads); the structural
    checks still run.
    """
    if os.path.isdir(ckpt_dir):
        _recover_staging(ckpt_dir)
    if step is None:
        if verify:
            step = latest_valid_step(ckpt_dir, event_log=event_log)
        else:
            step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoints in {ckpt_dir}")
    elif verify:
        problems = verify_checkpoint(ckpt_dir, step)
        if problems:
            quarantine_checkpoint(ckpt_dir, step, "; ".join(problems),
                                  event_log=event_log)
            raise CheckpointCorruptError(
                f"checkpoint step {step} failed verification: "
                + "; ".join(problems))
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    npz = np.load(os.path.join(d, "arrays.npz"))
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    if meta["n_arrays"] != len(flat_like):
        raise ValueError(
            f"structure mismatch: checkpoint step {step} holds "
            f"{meta['n_arrays']} arrays, caller structure has "
            f"{len(flat_like)}")
    saved_treedef = meta.get("treedef")
    if saved_treedef is not None and saved_treedef != str(treedef):
        raise ValueError(
            f"structure mismatch: checkpoint step {step} treedef\n  "
            f"{saved_treedef}\ndoes not match caller structure\n  "
            f"{treedef}")
    flat = [npz[f"a{i}"] for i in range(len(flat_like))]
    if shardings is not None:
        flat_sh = jax.tree_util.tree_flatten(shardings)[0]
        flat = [jax.device_put(x, s) for x, s in zip(flat, flat_sh)]
    else:
        flat = [jax.numpy.asarray(x) for x in flat]
    state = treedef.unflatten(flat)
    return state, meta["extra"]
