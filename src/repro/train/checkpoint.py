"""Topology-independent checkpointing: atomic npz + treedef JSON.

* **Atomic**: write to ``<dir>/tmp.<step>`` then ``os.replace`` — a
  crash mid-write never corrupts the latest checkpoint.
* **Keep-N**: old checkpoints garbage-collected.
* **Topology-independent**: arrays are saved as host numpy (fully
  addressable gather); on restore the caller re-applies whatever
  shardings the CURRENT mesh dictates — a run checkpointed on 256 chips
  restarts on 512 or 64 (elastic re-shard), because nothing about the
  mesh is serialized.
* The data-loader cursor and the step counter ride along, so restarts
  are bitwise-reproducible.
"""

from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "list_checkpoints"]

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save_checkpoint(ckpt_dir: str, step: int, state: Any,
                    extra: Optional[dict] = None, keep: int = 3) -> str:
    """Save pytree ``state`` (+ JSON-serializable ``extra``) at ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat, treedef = _flatten_with_names(state)
    arrays = {f"a{i}": np.asarray(jax.device_get(x))
              for i, x in enumerate(flat)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"n_arrays": len(flat),
            "treedef": str(treedef),
            "step": step,
            "extra": extra or {}}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish

    # keep-N garbage collection
    steps = sorted(list_checkpoints(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
    return final


def list_checkpoints(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_checkpoints(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, like: Any,
                       step: Optional[int] = None,
                       shardings: Any = None) -> Tuple[Any, dict]:
    """Restore into the structure of ``like``.  If ``shardings`` (a pytree
    of NamedSharding matching ``like``) is given, arrays are placed
    sharded — this is the elastic re-shard path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    npz = np.load(os.path.join(d, "arrays.npz"))
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    assert meta["n_arrays"] == len(flat_like), "structure mismatch"
    flat = [npz[f"a{i}"] for i in range(len(flat_like))]
    if shardings is not None:
        flat_sh = jax.tree_util.tree_flatten(shardings)[0]
        flat = [jax.device_put(x, s) for x, s in zip(flat, flat_sh)]
    else:
        flat = [jax.numpy.asarray(x) for x in flat]
    state = treedef.unflatten(flat)
    return state, meta["extra"]
