"""TrainState: params + optimizer state + step, as a plain pytree dict.

``tree_signature`` is the structural fingerprint the checkpoint integrity
manifest records and verifies (train/checkpoint.py): treedef string plus
per-leaf shape/dtype, so a restore into a mismatched model fails loudly
instead of scattering arrays into the wrong slots.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim.adamw import init_opt_state

__all__ = ["make_train_state", "param_count", "tree_signature"]


def make_train_state(params: Any, ef_pod: int = 0) -> dict:
    """Fresh training state for ``params``: AdamW moments zeroed, step 0.

    ``ef_pod > 1`` adds the int8-gradient-compression error-feedback
    residual ``opt["ef"]`` for a pod of that size (zeros shaped like
    params with a leading member axis — ``train/step.pod_residual``);
    it checkpoints, restores, and NaN-rolls-back with the rest of the
    optimizer state."""
    opt = init_opt_state(params)
    if ef_pod > 1:
        from repro.train.step import pod_residual
        opt["ef"] = pod_residual(params, ef_pod)
    return {"params": params,
            "opt": opt,
            "step": jnp.zeros((), jnp.int32)}


def param_count(state: dict) -> int:
    """Number of learnable scalars in ``state["params"]``."""
    return sum(x.size for x in jax.tree.leaves(state["params"]))


def tree_signature(tree: Any) -> dict:
    """JSON-serializable structural signature of a pytree: the treedef
    string plus each leaf's shape and dtype, in flatten order.  Two trees
    with equal signatures can exchange checkpointed arrays slot-for-slot;
    anything else is a structure mismatch."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return {"treedef": str(treedef),
            "leaves": [{"shape": list(getattr(x, "shape", ())),
                        "dtype": str(jnp.asarray(x).dtype)} for x in flat]}
