"""TrainState: params + optimizer state + step, as a plain pytree dict.

``tree_signature`` is the structural fingerprint the checkpoint integrity
manifest records and verifies (train/checkpoint.py): treedef string plus
per-leaf shape/dtype, so a restore into a mismatched model fails loudly
instead of scattering arrays into the wrong slots.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim.adamw import init_opt_state

__all__ = ["make_train_state", "param_count", "tree_signature"]


def make_train_state(params: Any) -> dict:
    """Fresh training state for ``params``: AdamW moments zeroed, step 0."""
    return {"params": params,
            "opt": init_opt_state(params),
            "step": jnp.zeros((), jnp.int32)}


def param_count(state: dict) -> int:
    """Number of learnable scalars in ``state["params"]``."""
    return sum(x.size for x in jax.tree.leaves(state["params"]))


def tree_signature(tree: Any) -> dict:
    """JSON-serializable structural signature of a pytree: the treedef
    string plus each leaf's shape and dtype, in flatten order.  Two trees
    with equal signatures can exchange checkpointed arrays slot-for-slot;
    anything else is a structure mismatch."""
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return {"treedef": str(treedef),
            "leaves": [{"shape": list(getattr(x, "shape", ())),
                        "dtype": str(jnp.asarray(x).dtype)} for x in flat]}
