"""TrainState: params + optimizer state + step, as a plain pytree dict."""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim.adamw import init_opt_state

__all__ = ["make_train_state", "param_count"]


def make_train_state(params: Any) -> dict:
    return {"params": params,
            "opt": init_opt_state(params),
            "step": jnp.zeros((), jnp.int32)}


def param_count(state: dict) -> int:
    return sum(x.size for x in jax.tree.leaves(state["params"]))
