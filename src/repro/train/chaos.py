"""Deterministic fault injection ("chaos engineering") for the trainer.

A seeded :class:`ChaosSchedule` injects planned faults at planned steps so
every recovery path in the trainer is exercised on demand instead of
waiting for production to exercise it.  The proof of correct recovery is
*parity*: a chaos run must finish bitwise-identical to the fault-free run
(tests/test_chaos.py, tests/test_chaos_distributed.py).

Spec grammar (``launch/train.py --chaos-spec``), events ``;``-separated::

    nan@S          poison gradients with NaN at step S (in-graph, via the
    nan@S+K        train step's traced ``poison`` flag — the jitted step
                   stays compiled); ``+K`` poisons K consecutive steps
                   (a burst long enough to trip FaultPolicy's rollback)
    preempt@S      raise ChaosPreemption AFTER step S completes —
                   simulates preemption / device loss; run_with_recovery
                   restores the newest valid checkpoint and resumes
    corrupt@S:M    corrupt the NEWEST published checkpoint after step S.
                   Modes M: ``bitflip`` (default; flip one seeded byte of
                   arrays.npz), ``truncate`` (cut arrays.npz in half),
                   ``delmeta`` (delete meta.json), ``orphan`` (plant a
                   partial tmp.* staging dir, as a crashed save would)
    slow@S:SEC     sleep SEC seconds before step S (straggler injection;
                   the driver's StragglerDetector must flag it)

Every event fires ONCE per process: after a rollback or in-process
restart replays the same step numbers, a fired event stays fired —
otherwise a ``preempt`` would re-kill every replay and the run could
never converge on the fault-free trajectory.  Corruption byte positions
come from the schedule's seeded RNG, so a chaos run is reproducible end
to end.
"""

from __future__ import annotations

import dataclasses
import logging
import os
import re
import time
from typing import Any, List, Optional, Tuple

import numpy as np

log = logging.getLogger("repro.chaos")

__all__ = ["ChaosEvent", "ChaosSchedule", "ChaosPreemption",
           "CORRUPTION_MODES", "corrupt_checkpoint"]

CORRUPTION_MODES = ("bitflip", "truncate", "delmeta", "orphan")

_EVENT_RE = re.compile(
    r"^(?P<kind>nan|preempt|corrupt|slow)@(?P<step>\d+)"
    r"(?:\+(?P<count>\d+))?(?::(?P<arg>[^;]+))?$")


class ChaosPreemption(RuntimeError):
    """Injected preemption/device-loss: the training loop dies here and
    the recovery orchestration (run_with_recovery, or a scheduler-level
    re-launch resuming from the checkpoint dir) must bring it back."""


@dataclasses.dataclass
class ChaosEvent:
    """One planned fault: ``kind`` at ``step`` with an optional ``arg``
    (corruption mode / slow-step seconds).  ``fired`` makes injection
    once-per-process so post-recovery replays run clean."""

    kind: str
    step: int
    arg: Optional[str] = None
    fired: bool = False


def _flip_byte(path: str, rng: np.random.Generator) -> int:
    """Flip one random byte of ``path`` in place; returns the offset."""
    size = os.path.getsize(path)
    off = int(rng.integers(0, size))
    with open(path, "r+b") as f:
        f.seek(off)
        byte = f.read(1)[0]
        f.seek(off)
        f.write(bytes([byte ^ 0xFF]))
    return off


def corrupt_checkpoint(ckpt_dir: str, mode: str,
                       rng: Optional[np.random.Generator] = None,
                       step: Optional[int] = None) -> Optional[int]:
    """Corrupt one published checkpoint in ``ckpt_dir`` (the newest, or
    ``step``) the way real storage faults do.  Returns the corrupted step
    number, or None when there was nothing to corrupt.

    Modes: ``bitflip`` — XOR one seeded byte of ``arrays.npz`` (caught by
    the sha256 manifest); ``truncate`` — cut ``arrays.npz`` to half size
    (unreadable container); ``delmeta`` — delete ``meta.json`` (incomplete
    payload); ``orphan`` — plant a partial ``tmp.<step>.<nonce>`` staging
    dir next to the published steps, as a save crashed mid-write would
    (must be GC'd, never republished)."""
    if mode not in CORRUPTION_MODES:
        raise ValueError(f"unknown corruption mode {mode!r}; "
                         f"expected one of {CORRUPTION_MODES}")
    # local import: chaos must stay importable without the checkpoint
    # machinery fully initialized (and vice versa — no cycle at import)
    from repro.train.checkpoint import list_checkpoints
    steps = list_checkpoints(ckpt_dir)
    if step is None:
        step = steps[-1] if steps else None
    if step is None:
        log.warning("chaos corrupt(%s): no published checkpoint in %s",
                    mode, ckpt_dir)
        return None
    rng = rng or np.random.default_rng(0)
    d = os.path.join(ckpt_dir, f"step_{step}")
    if mode == "bitflip":
        off = _flip_byte(os.path.join(d, "arrays.npz"), rng)
        log.warning("chaos: flipped byte %d of step %d arrays.npz",
                    off, step)
    elif mode == "truncate":
        path = os.path.join(d, "arrays.npz")
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(size // 2)
        log.warning("chaos: truncated step %d arrays.npz %d -> %d bytes",
                    step, size, size // 2)
    elif mode == "delmeta":
        os.remove(os.path.join(d, "meta.json"))
        log.warning("chaos: deleted step %d meta.json", step)
    elif mode == "orphan":
        nonce = "".join(rng.choice(list("0123456789abcdef"), 8))
        tmp = os.path.join(ckpt_dir, f"tmp.{step}.{nonce}")
        os.makedirs(tmp, exist_ok=True)
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            f.write(b"partial write, crashed mid-save")
        log.warning("chaos: planted orphan staging dir %s",
                    os.path.basename(tmp))
    return step


class ChaosSchedule:
    """A seeded plan of fault injections, driven by the training loop.

    Hooks, in loop order (see launch/train.py):

    * ``poison(step)`` — before the jitted step: 1.0 when a ``nan`` event
      covers this step (consumed), else 0.0.  Fed to the train step's
      traced ``poison`` flag.
    * ``pre_step(step)`` — straggler injection: sleeps any pending
      ``slow`` event's delay and returns it (0.0 otherwise).
    * ``post_step(step, ckpt_dir, event_log=None)`` — after the step's
      save point: applies pending ``corrupt`` events against ``ckpt_dir``,
      then raises :class:`ChaosPreemption` for a pending ``preempt``
      (corruption-before-preemption means one step can stage the classic
      "preempted AND the newest checkpoint is bad" double fault).
    """

    def __init__(self, events: List[ChaosEvent], seed: int = 0):
        self.events = list(events)
        self.seed = seed
        self.rng = np.random.default_rng(seed)

    @classmethod
    def parse(cls, spec: str, seed: int = 0) -> "ChaosSchedule":
        """Parse the ``--chaos-spec`` grammar (module docstring) into a
        schedule; raises ValueError on malformed specs."""
        events: List[ChaosEvent] = []
        for part in spec.split(";"):
            part = part.strip()
            if not part:
                continue
            m = _EVENT_RE.match(part)
            if not m:
                raise ValueError(
                    f"bad chaos event {part!r}; expected "
                    "kind@step[+count][:arg] with kind in "
                    "nan|preempt|corrupt|slow")
            kind = m.group("kind")
            step = int(m.group("step"))
            count = int(m.group("count") or 1)
            arg = m.group("arg")
            if count > 1 and kind != "nan":
                raise ValueError(f"{part!r}: only nan events take a "
                                 "+count burst length")
            if kind == "corrupt":
                arg = arg or "bitflip"
                if arg not in CORRUPTION_MODES:
                    raise ValueError(f"{part!r}: corruption mode must be "
                                     f"one of {CORRUPTION_MODES}")
            if kind == "slow":
                arg = arg or "0.05"
                float(arg)            # validates
            if kind in ("preempt",) and arg is not None:
                raise ValueError(f"{part!r}: {kind} takes no argument")
            for i in range(count):
                events.append(ChaosEvent(kind=kind, step=step + i, arg=arg))
        events.sort(key=lambda e: e.step)
        return cls(events, seed=seed)

    def _pending(self, kind: str, step: int) -> List[ChaosEvent]:
        return [e for e in self.events
                if e.kind == kind and e.step == step and not e.fired]

    def poison(self, step: int) -> float:
        """1.0 when a not-yet-fired ``nan`` event covers ``step`` (the
        event is consumed), else 0.0."""
        out = 0.0
        for e in self._pending("nan", step):
            e.fired = True
            out = 1.0
            log.warning("chaos: poisoning gradients at step %d", step)
        return out

    def pre_step(self, step: int) -> float:
        """Sleep and return any pending ``slow`` event's delay (seconds)
        for ``step``; 0.0 otherwise."""
        delay = 0.0
        for e in self._pending("slow", step):
            e.fired = True
            delay += float(e.arg)
        if delay > 0:
            log.warning("chaos: straggling step %d by %.3fs", step, delay)
            time.sleep(delay)
        return delay

    def post_step(self, step: int, ckpt_dir: Optional[str],
                  event_log: Any = None) -> None:
        """Fire pending ``corrupt`` then ``preempt`` events for ``step``
        (see class docstring for why in that order)."""
        for e in self._pending("corrupt", step):
            e.fired = True
            if not ckpt_dir:
                log.warning("chaos: corrupt event at step %d has no "
                            "ckpt dir; skipped", step)
                continue
            victim = corrupt_checkpoint(ckpt_dir, e.arg, rng=self.rng)
            if event_log is not None:
                event_log.emit("chaos_corrupt", step=step, cause=e.arg,
                               victim_step=victim)
        for e in self._pending("preempt", step):
            e.fired = True
            if event_log is not None:
                event_log.emit("chaos_preempt", step=step)
            raise ChaosPreemption(f"injected preemption after step {step}")

    def remaining(self) -> Tuple[ChaosEvent, ...]:
        """Events that have not fired yet (a finished chaos run should
        have none — asserting this catches specs aimed past the horizon)."""
        return tuple(e for e in self.events if not e.fired)
