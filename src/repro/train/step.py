"""Train/eval step factories.

``make_train_step(loss_fn, opt_cfg, ...)`` returns a jittable
``step(state, batch) -> (state, metrics)`` with:

  * optional microbatch gradient accumulation (``accum_steps`` splits the
    per-device batch along axis 0 and ``lax.scan``s the grads — constant
    memory in global batch; metrics are averaged across microbatches,
    mask-weighted for ``ce`` via ``ce_weight``, so logs describe the same
    batch the loss optimizes),
  * global-norm clipping + AdamW + cosine schedule,
  * a NaN/inf GUARD: if the gradient global-norm is non-finite the update
    is skipped entirely (params and opt state pass through) and
    ``metrics["skipped"]`` flags it — the fault-tolerance layer counts
    these (train/fault.py),
  * an optional chaos port (``chaos_guard=True``): the step takes a third
    traced ``poison`` scalar and multiplies the gradients by NaN whenever
    it is nonzero — an in-graph fault injection that exercises the guard
    without recompiling (train/chaos.py plans WHEN it fires).  With
    ``poison == 0`` the factor is exactly 1.0, so the arithmetic is
    bit-identical to a chaos-free step,
  * an optional data-parallel gradient reduction (``grad_axis``): the
    step pmean-reduces gradients over that named axis (for use inside a
    ``shard_map``), and with ``compress_grads=True`` the reduction runs
    through the int8 error-feedback compressor
    (``optim.compression.psum_compressed_ef``) with the per-member
    residual carried in ``state["opt"]["ef"]`` — the
    ``SPMConfig.compress_pod_grads`` knob.  ``make_pod_train_step`` wraps
    the whole step in that shard_map over a ("pod",) mesh.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim.adamw import OptimizerConfig, adamw_update
from repro.optim.compression import psum_compressed_ef

__all__ = ["make_train_step", "make_pod_train_step", "pod_residual",
           "make_eval_step"]


def _split_microbatches(batch: Any, accum_steps: int) -> Any:
    def re(x):
        b = x.shape[0]
        assert b % accum_steps == 0, (b, accum_steps)
        return x.reshape((accum_steps, b // accum_steps) + x.shape[1:])
    return jax.tree.map(re, batch)


def make_train_step(loss_fn: Callable, opt_cfg: OptimizerConfig, *,
                    accum_steps: int = 1,
                    nan_guard: bool = True,
                    chaos_guard: bool = False,
                    grad_axis: Optional[str] = None,
                    compress_grads: bool = False) -> Callable:
    """loss_fn(params, batch) -> (loss, metrics).

    With ``chaos_guard=True`` the returned step is
    ``step(state, batch, poison)`` where ``poison`` is a traced scalar:
    nonzero poisons the gradients with NaN IN-GRAPH (the jitted step stays
    compiled across healthy and poisoned steps), zero multiplies by an
    exact 1.0 — the fault-injection port of train/chaos.py.  Requires
    ``nan_guard`` so the poisoned update is skipped, not applied.

    With ``grad_axis`` the step reduces gradients (and loss/metrics) over
    that named mesh axis — it must then run inside a ``shard_map`` that
    binds the axis.  ``compress_grads=True`` swaps the pmean for the int8
    error-feedback compressed psum; the per-member residual lives in
    ``state["opt"]["ef"]`` (see ``pod_residual``) and rolls back with the
    rest of the optimizer state on NaN-guarded skips.  The chaos poison
    is applied AFTER the reduction so a NaN never enters the int8
    quantizer — the residual update of a poisoned step stays finite and
    is discarded by the same rollback."""
    if chaos_guard and not nan_guard:
        raise ValueError("chaos_guard requires nan_guard (a poisoned "
                         "update must be skipped, not applied)")
    if compress_grads and grad_axis is None:
        raise ValueError("compress_grads requires grad_axis (the int8 "
                         "compressor reduces over a named mesh axis)")

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        mb = _split_microbatches(batch, accum_steps)

        def body(carry, micro):
            acc, loss_acc = carry
            (loss, metrics), grads = grad_fn(params, micro)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), metrics

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, loss_sum), metrics = jax.lax.scan(
            body, (zero, jnp.zeros((), jnp.float32)), mb)
        scale = 1.0 / accum_steps
        grads = jax.tree.map(lambda g: g * scale, gsum)
        # average the stacked per-microbatch metrics — the logged numbers
        # must describe the WHOLE accumulated batch, not the last micro.
        # ce is a masked mean, so a plain mean of per-micro means would
        # skew under uneven masks: weight it by each micro's mask sum
        # (ce_weight from lm_loss) to recover the global masked mean.
        stacked = metrics
        metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), stacked)
        if (isinstance(stacked, dict) and "ce" in stacked
                and "ce_weight" in stacked):
            w = stacked["ce_weight"]
            wsum = jnp.maximum(jnp.sum(w), 1.0)
            metrics["ce"] = jnp.sum(stacked["ce"] * w) / wsum
            metrics["ce_weight"] = jnp.sum(w)
            if "ppl_proxy" in metrics:
                metrics["ppl_proxy"] = jnp.exp(jnp.clip(metrics["ce"],
                                                        max=20.0))
        return loss_sum * scale, metrics, grads

    def step(state: dict, batch: Any, poison: Any = None):
        loss, metrics, grads = compute_grads(state["params"], batch)
        new_ef = None
        if grad_axis is not None:
            if compress_grads:
                grads, new_ef = psum_compressed_ef(
                    grads, state["opt"]["ef"], grad_axis)
            else:
                grads = jax.tree.map(
                    lambda g: jax.lax.pmean(g, grad_axis), grads)
            loss = jax.lax.pmean(loss, grad_axis)
            metrics = jax.tree.map(
                lambda m: jax.lax.pmean(m, grad_axis), metrics)
        if chaos_guard:
            if poison is None:
                raise TypeError("chaos_guard step requires the poison "
                                "argument: step(state, batch, poison)")
            # nonzero poison -> NaN factor -> non-finite grad norm -> the
            # nan_guard below skips the update; zero poison multiplies by
            # an EXACT 1.0 so healthy steps are bit-identical to a
            # chaos-free build of the same step.
            factor = jnp.where(jnp.asarray(poison) != 0,
                               jnp.float32(jnp.nan), jnp.float32(1.0))
            grads = jax.tree.map(lambda g: g * factor.astype(g.dtype),
                                 grads)
        new_params, new_opt, info = adamw_update(
            state["params"], grads, state["opt"], opt_cfg)
        if new_ef is not None:
            # adamw passes "ef" through untouched; install the updated
            # residual BEFORE the nan_guard select so a skipped step also
            # rolls the residual back to its pre-step value.
            new_opt = {**new_opt, "ef": new_ef}
        metrics = dict(metrics)
        metrics.update(info)
        if nan_guard:
            ok = jnp.isfinite(info["grad_norm"]) & jnp.isfinite(loss)
            new_params = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_params,
                state["params"])
            new_opt = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_opt, state["opt"])
            metrics["skipped"] = (~ok).astype(jnp.float32)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return step


def pod_residual(params: Any, n_pod: int) -> Any:
    """Per-member error-feedback residual for ``make_pod_train_step``.

    Shaped like ``params`` with a leading ``(n_pod,)`` member axis — the
    residual is LOCAL state (each pod member keeps the quantization error
    of its own gradient shard), so it enters the pod step's ``shard_map``
    under ``P(axis)`` while params/optimizer moments stay replicated.
    Store it as ``state["opt"]["ef"]``; AdamW passes unknown optimizer
    keys through untouched and the NaN guard rolls it back with the rest
    of the optimizer state."""
    return jax.tree.map(
        lambda p: jnp.zeros((n_pod,) + p.shape, jnp.float32), params)


def make_pod_train_step(loss_fn: Callable, opt_cfg: OptimizerConfig,
                        mesh, *, axis: str = "pod",
                        compress: bool = True,
                        **step_kwargs) -> Callable:
    """Data-parallel train step over mesh axis ``axis`` via ``shard_map``.

    Wraps ``make_train_step(..., grad_axis=axis,
    compress_grads=compress)`` in a ``shard_map`` over ``mesh``: the
    batch is split along ``axis`` (leading dim), params / optimizer
    moments / step counter are replicated, and — when ``compress`` is on
    (the ``SPMConfig.compress_pod_grads`` knob) — the error-feedback
    residual ``state["opt"]["ef"]`` carries a leading ``(n_pod,)`` member
    axis (see ``pod_residual``) that is sliced to the local member inside
    the body.  Gradients reduce with the int8 error-feedback compressed
    psum (``compress=True``) or a plain pmean; loss and metrics are
    pmean-reduced either way so the returned values are replicated.
    Extra ``step_kwargs`` (``accum_steps``, ``nan_guard``,
    ``chaos_guard``) pass through to ``make_train_step``."""
    from jax.experimental.shard_map import shard_map

    step = make_train_step(loss_fn, opt_cfg, grad_axis=axis,
                           compress_grads=compress, **step_kwargs)

    def body(state, batch, poison):
        if compress:
            opt = dict(state["opt"])
            # (1, *shape) local slice of the member-axis residual
            opt["ef"] = jax.tree.map(lambda r: r[0], opt["ef"])
            state = {**state, "opt": opt}
        new_state, metrics = step(state, batch, poison)
        if compress:
            new_opt = dict(new_state["opt"])
            new_opt["ef"] = jax.tree.map(lambda r: r[None], new_opt["ef"])
            new_state = {**new_state, "opt": new_opt}
        return new_state, metrics

    opt_spec = {"mu": P(), "nu": P(), "count": P()}
    if compress:
        opt_spec["ef"] = P(axis)
    state_spec = {"params": P(), "opt": opt_spec, "step": P()}
    sharded = shard_map(
        body, mesh=mesh,
        in_specs=(state_spec, P(axis), P()),
        out_specs=(state_spec, P()),
        check_rep=False)

    def pod_step(state: dict, batch: Any, poison: Any = None):
        if poison is None:
            poison = jnp.zeros((), jnp.float32)
        return sharded(state, batch, jnp.asarray(poison))

    return pod_step


def make_eval_step(loss_fn: Callable) -> Callable:
    def step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics
    return step
