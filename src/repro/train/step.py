"""Train/eval step factories.

``make_train_step(loss_fn, opt_cfg, ...)`` returns a jittable
``step(state, batch) -> (state, metrics)`` with:

  * optional microbatch gradient accumulation (``accum_steps`` splits the
    per-device batch along axis 0 and ``lax.scan``s the grads — constant
    memory in global batch; metrics are averaged across microbatches,
    mask-weighted for ``ce`` via ``ce_weight``, so logs describe the same
    batch the loss optimizes),
  * global-norm clipping + AdamW + cosine schedule,
  * a NaN/inf GUARD: if the gradient global-norm is non-finite the update
    is skipped entirely (params and opt state pass through) and
    ``metrics["skipped"]`` flags it — the fault-tolerance layer counts
    these (train/fault.py),
  * an optional chaos port (``chaos_guard=True``): the step takes a third
    traced ``poison`` scalar and multiplies the gradients by NaN whenever
    it is nonzero — an in-graph fault injection that exercises the guard
    without recompiling (train/chaos.py plans WHEN it fires).  With
    ``poison == 0`` the factor is exactly 1.0, so the arithmetic is
    bit-identical to a chaos-free step.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim.adamw import OptimizerConfig, adamw_update

__all__ = ["make_train_step", "make_eval_step"]


def _split_microbatches(batch: Any, accum_steps: int) -> Any:
    def re(x):
        b = x.shape[0]
        assert b % accum_steps == 0, (b, accum_steps)
        return x.reshape((accum_steps, b // accum_steps) + x.shape[1:])
    return jax.tree.map(re, batch)


def make_train_step(loss_fn: Callable, opt_cfg: OptimizerConfig, *,
                    accum_steps: int = 1,
                    nan_guard: bool = True,
                    chaos_guard: bool = False) -> Callable:
    """loss_fn(params, batch) -> (loss, metrics).

    With ``chaos_guard=True`` the returned step is
    ``step(state, batch, poison)`` where ``poison`` is a traced scalar:
    nonzero poisons the gradients with NaN IN-GRAPH (the jitted step stays
    compiled across healthy and poisoned steps), zero multiplies by an
    exact 1.0 — the fault-injection port of train/chaos.py.  Requires
    ``nan_guard`` so the poisoned update is skipped, not applied."""
    if chaos_guard and not nan_guard:
        raise ValueError("chaos_guard requires nan_guard (a poisoned "
                         "update must be skipped, not applied)")

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        mb = _split_microbatches(batch, accum_steps)

        def body(carry, micro):
            acc, loss_acc = carry
            (loss, metrics), grads = grad_fn(params, micro)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), metrics

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, loss_sum), metrics = jax.lax.scan(
            body, (zero, jnp.zeros((), jnp.float32)), mb)
        scale = 1.0 / accum_steps
        grads = jax.tree.map(lambda g: g * scale, gsum)
        # average the stacked per-microbatch metrics — the logged numbers
        # must describe the WHOLE accumulated batch, not the last micro.
        # ce is a masked mean, so a plain mean of per-micro means would
        # skew under uneven masks: weight it by each micro's mask sum
        # (ce_weight from lm_loss) to recover the global masked mean.
        stacked = metrics
        metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), stacked)
        if (isinstance(stacked, dict) and "ce" in stacked
                and "ce_weight" in stacked):
            w = stacked["ce_weight"]
            wsum = jnp.maximum(jnp.sum(w), 1.0)
            metrics["ce"] = jnp.sum(stacked["ce"] * w) / wsum
            metrics["ce_weight"] = jnp.sum(w)
            if "ppl_proxy" in metrics:
                metrics["ppl_proxy"] = jnp.exp(jnp.clip(metrics["ce"],
                                                        max=20.0))
        return loss_sum * scale, metrics, grads

    def step(state: dict, batch: Any, poison: Any = None):
        loss, metrics, grads = compute_grads(state["params"], batch)
        if chaos_guard:
            if poison is None:
                raise TypeError("chaos_guard step requires the poison "
                                "argument: step(state, batch, poison)")
            # nonzero poison -> NaN factor -> non-finite grad norm -> the
            # nan_guard below skips the update; zero poison multiplies by
            # an EXACT 1.0 so healthy steps are bit-identical to a
            # chaos-free build of the same step.
            factor = jnp.where(jnp.asarray(poison) != 0,
                               jnp.float32(jnp.nan), jnp.float32(1.0))
            grads = jax.tree.map(lambda g: g * factor.astype(g.dtype),
                                 grads)
        new_params, new_opt, info = adamw_update(
            state["params"], grads, state["opt"], opt_cfg)
        metrics = dict(metrics)
        metrics.update(info)
        if nan_guard:
            ok = jnp.isfinite(info["grad_norm"]) & jnp.isfinite(loss)
            new_params = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_params,
                state["params"])
            new_opt = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_opt, state["opt"])
            metrics["skipped"] = (~ok).astype(jnp.float32)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return step


def make_eval_step(loss_fn: Callable) -> Callable:
    def step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics
    return step
