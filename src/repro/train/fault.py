"""Fault tolerance: NaN-skip accounting, recovery orchestration, the
structured fault-event log, and straggler detection.

In-step NaN/inf guarding lives in the jitted train step (train/step.py);
this module is the host-side policy around it:

* ``FaultPolicy.on_metrics``: count consecutive skipped steps; after
  ``max_skips`` in a row, roll back to the latest checkpoint (loss-scale
  blowups, corrupt batches).
* ``run_with_recovery``: wraps the training loop; on ANY exception
  (device loss, preemption signal) it sleeps an exponential backoff, then
  re-invokes the loop with ``RESUME_LATEST`` so the driver restores the
  newest VALID checkpoint and rewinds its loop/loader/schedule state
  coherently (launch/train.py).  Restarts are budgeted over a sliding
  window — a crash loop exhausts the budget and re-raises instead of
  spinning hot.  On a real cluster the scheduler restarts the binary and
  the driver's automatic resume covers the process-death case (the chaos
  harness exercises that path too, including onto a different shard
  count).
* ``FaultEventLog``: append-only JSONL observability surface — every
  skip / rollback / restart / quarantine / slow-step event lands here
  with step, cause, and wall time (docs/fault.md documents the schema).
* ``StragglerDetector``: per-step wall-time watchdog.  A step exceeding
  ``factor`` x the rolling median for ``k`` consecutive steps emits a
  ``slow_step`` event.  Remediation stays a scheduler-level action
  (cold-swap + topology-independent restore, train/checkpoint.py) — with
  single-controller JAX it cannot be in-graph — but the detection and
  the event trail are implemented here, not just documented.

The deterministic fault INJECTION side (what makes all of this testable)
lives in train/chaos.py.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
import threading
import time
from collections import deque
from typing import Any, Callable, List, Optional

log = logging.getLogger("repro.fault")

__all__ = ["FaultPolicy", "run_with_recovery", "RESUME_LATEST",
           "FaultEventLog", "StragglerDetector"]

# Resume-intent sentinel run_with_recovery passes to the training loop
# after a failure: "restore the newest valid checkpoint" (as opposed to
# ``None`` — a cold start that may still auto-resume if the driver finds
# checkpoints on disk).  Exported so drivers compare against the named
# constant instead of a magic ``-1``.
RESUME_LATEST = -1


@dataclasses.dataclass
class FaultPolicy:
    """Host-side skip accounting around the train step's NaN guard."""

    max_consecutive_skips: int = 5
    consecutive_skips: int = 0
    total_skips: int = 0

    def on_metrics(self, metrics: dict) -> bool:
        """Feed one step's metrics; returns True when a rollback should
        happen (``max_consecutive_skips`` skipped steps in a row)."""
        skipped = bool(metrics.get("skipped", 0.0))
        if skipped:
            self.consecutive_skips += 1
            self.total_skips += 1
            log.warning("step skipped (non-finite grads), %d consecutive",
                        self.consecutive_skips)
        else:
            self.consecutive_skips = 0
        return self.consecutive_skips >= self.max_consecutive_skips

    def reset(self) -> None:
        """Clear the consecutive-skip counter after a recovery action
        (rollback or restart); lifetime ``total_skips`` is kept."""
        self.consecutive_skips = 0


class FaultEventLog:
    """Append-only JSONL fault-event log (the observability surface).

    Each ``emit`` appends one JSON object: ``{"t": <wall time>,
    "kind": ..., "step": ..., "cause": ..., **fields}``.  Events are also
    kept in ``self.events`` for in-process inspection (tests, summaries).
    ``path=None`` keeps the log memory-only.  Thread-safe; writes are
    line-buffered appends so a crash loses at most the current line.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.events: List[dict] = []
        self._lock = threading.Lock()
        if path:
            os.makedirs(os.path.dirname(os.path.abspath(path)),
                        exist_ok=True)

    def emit(self, kind: str, step: Optional[int] = None,
             cause: Optional[str] = None, **fields: Any) -> dict:
        """Record one fault event; returns the event dict."""
        ev = {"t": time.time(), "kind": kind}
        if step is not None:
            ev["step"] = int(step)
        if cause is not None:
            ev["cause"] = cause
        ev.update(fields)
        with self._lock:
            self.events.append(ev)
            if self.path:
                with open(self.path, "a") as f:
                    f.write(json.dumps(ev) + "\n")
        return ev

    def kinds(self) -> List[str]:
        """The kinds of all events emitted so far, in order."""
        return [ev["kind"] for ev in self.events]


class StragglerDetector:
    """Rolling-median slow-step watchdog.

    ``observe(step, dt)`` returns True (and emits a ``slow_step`` event)
    when ``dt`` exceeds ``factor`` x the rolling median of the last
    ``window`` step times for ``patience`` consecutive steps.  The first
    ``min_samples`` observations only warm the window up — compile-time
    spikes on step 0 never trip it.
    """

    def __init__(self, factor: float = 1.5, window: int = 50,
                 patience: int = 1, min_samples: int = 5,
                 event_log: Optional[FaultEventLog] = None):
        self.factor = factor
        self.patience = patience
        self.min_samples = min_samples
        self.event_log = event_log
        self._times: deque = deque(maxlen=window)
        self._consecutive = 0

    def observe(self, step: int, dt: float) -> bool:
        """Feed one step's wall time; True when the straggler threshold
        has been met for ``patience`` consecutive steps."""
        times = sorted(self._times)
        median = times[len(times) // 2] if times else None
        self._times.append(dt)
        if median is None or len(times) < self.min_samples:
            return False
        if dt > self.factor * median:
            self._consecutive += 1
            if self._consecutive >= self.patience:
                log.warning("slow step %d: %.3fs > %.1fx median %.3fs",
                            step, dt, self.factor, median)
                if self.event_log is not None:
                    self.event_log.emit("slow_step", step=step,
                                        cause=f"{dt:.4f}s vs median "
                                              f"{median:.4f}s",
                                        dt=dt, median=median)
                return True
        else:
            self._consecutive = 0
        return False


def run_with_recovery(train_loop: Callable[[Optional[int]], Any],
                      max_restarts: int = 3,
                      backoff_base: float = 0.5,
                      backoff_max: float = 30.0,
                      restart_window: float = 600.0,
                      event_log: Optional[FaultEventLog] = None,
                      sleep: Callable[[float], None] = time.sleep) -> Any:
    """Run ``train_loop(resume)`` to completion, restarting on failure.

    The first invocation passes ``resume=None`` (cold start); every
    restart passes ``RESUME_LATEST``, the explicit instruction to restore
    the newest valid checkpoint.  Between restarts an exponential backoff
    (``backoff_base * 2**(attempt-1)``, capped at ``backoff_max``) is
    slept via the injectable ``sleep`` — no hot retry loop.  Restarts are
    budgeted over a sliding ``restart_window`` seconds: more than
    ``max_restarts`` failures inside the window re-raises the last
    exception (a crash loop must surface, not burn the cluster), while
    occasional faults spread over a long run never exhaust the budget.
    ``KeyboardInterrupt`` always propagates.  Emits ``restart`` /
    ``restart_budget_exhausted`` events to ``event_log``."""
    recent: deque = deque()
    attempt = 0
    resume: Optional[int] = None
    while True:
        try:
            return train_loop(resume)
        except KeyboardInterrupt:
            raise
        except Exception as e:          # noqa: BLE001 — any device fault
            now = time.monotonic()
            recent.append(now)
            while recent and now - recent[0] > restart_window:
                recent.popleft()
            attempt += 1
            if len(recent) > max_restarts:
                log.error("restart budget exhausted: %d failures within "
                          "%.0fs window", len(recent), restart_window)
                if event_log is not None:
                    event_log.emit("restart_budget_exhausted",
                                   cause=repr(e),
                                   failures_in_window=len(recent))
                raise
            backoff = min(backoff_base * (2.0 ** (attempt - 1)),
                          backoff_max)
            log.error("training loop failed (%s); restart %d (%d/%d in "
                      "window) from latest checkpoint after %.2fs backoff",
                      e, attempt, len(recent), max_restarts, backoff)
            if event_log is not None:
                event_log.emit("restart", cause=repr(e), attempt=attempt,
                               backoff_s=backoff)
            if backoff > 0:
                sleep(backoff)
            resume = RESUME_LATEST
