"""Fault tolerance: NaN-skip accounting, auto-restore, straggler notes.

In-step NaN/inf guarding lives in the jitted train step (train/step.py);
this module is the host-side policy around it:

* ``FaultPolicy.on_metrics``: count consecutive skipped steps; after
  ``max_skips`` in a row, roll back to the latest checkpoint (loss-scale
  blowups, corrupt batches).
* ``run_with_recovery``: wraps the training loop; on ANY exception
  (device loss, preemption signal) it restores the latest checkpoint and
  resumes — on a real cluster the scheduler restarts the binary and
  ``resume-latest`` in launch/train.py covers the process-death case.
* **Straggler mitigation** (documented policy, host-side): the launcher
  monitors per-step wall time across hosts; a host exceeding p99 x 1.5
  for ``k`` consecutive steps is cold-swapped — its replacement restores
  from the latest checkpoint (topology-independent restore makes this a
  plain resume).  With single-controller JAX this is a scheduler-level
  action, not in-graph.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Optional

log = logging.getLogger("repro.fault")

__all__ = ["FaultPolicy", "run_with_recovery"]


@dataclasses.dataclass
class FaultPolicy:
    max_consecutive_skips: int = 5
    consecutive_skips: int = 0
    total_skips: int = 0

    def on_metrics(self, metrics: dict) -> bool:
        """Returns True when a rollback should happen."""
        skipped = bool(metrics.get("skipped", 0.0))
        if skipped:
            self.consecutive_skips += 1
            self.total_skips += 1
            log.warning("step skipped (non-finite grads), %d consecutive",
                        self.consecutive_skips)
        else:
            self.consecutive_skips = 0
        return self.consecutive_skips >= self.max_consecutive_skips

    def reset(self) -> None:
        self.consecutive_skips = 0


def run_with_recovery(train_loop: Callable[[Optional[int]], Any],
                      max_restarts: int = 3) -> Any:
    """Run ``train_loop(resume_step)``; on exception, retry from the
    latest checkpoint up to ``max_restarts`` times."""
    restarts = 0
    while True:
        try:
            return train_loop(None if restarts == 0 else -1)
        except KeyboardInterrupt:
            raise
        except Exception as e:          # noqa: BLE001 — any device fault
            restarts += 1
            if restarts > max_restarts:
                raise
            log.error("training loop failed (%s); restart %d/%d from "
                      "latest checkpoint", e, restarts, max_restarts)
