"""KV-cache serving engine: batched prefill + decode loop.

``ServeEngine`` holds jitted prefill/decode closures for one ModelConfig;
``generate`` runs greedy or temperature sampling for a batch of prompts.
``serve_step`` (module-level) is the function the decode-shape dry-run
cells lower: one new token against a seq_len KV cache.

Decode hot loop: sampling is FUSED into the jitted decode step (one
compiled call per generated token — no host-side argmax/categorical
between steps), the per-step PRNG key is derived inside jit via
``fold_in``, and the loop issues exactly ``max_new_tokens - 1`` decode
calls after prefill (the old loop ran one extra decode whose logits were
discarded).  ``temperature > 0`` without a key is an error, not a silent
greedy fallback.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import causal_lm as LM
from repro.models import transformer as T

__all__ = ["ServeEngine", "serve_step"]


def serve_step(params: dict, cfg: T.ModelConfig, tokens: jax.Array,
               cache, cache_index: jax.Array):
    """One decode step for the whole batch: (B,) int32 -> (logits, cache).
    This is the unit the decode dry-run cells lower + compile."""
    return LM.decode_step(params, cfg, tokens, cache, cache_index)


def _sample(logits: jax.Array, key: jax.Array, temperature: jax.Array,
            greedy: bool) -> jax.Array:
    """Traced sampling head.  ``greedy`` is static (two compiled variants);
    ``temperature`` is traced so sweeping it never recompiles."""
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class ServeEngine:
    cfg: T.ModelConfig
    params: dict
    max_len: int
    cache_dtype: object = jnp.bfloat16

    def __post_init__(self):
        def step(params, tok, cache, prompt_len, key, step_idx,
                 temperature, greedy):
            logits, cache = serve_step(params, self.cfg, tok, cache,
                                       prompt_len + step_idx)
            k = jax.random.fold_in(key, step_idx + 1)
            return _sample(logits, k, temperature, greedy), cache

        # decode + sample in ONE compiled call per token
        self._step = jax.jit(step, static_argnames=("greedy",))
        self._sample_first = jax.jit(
            lambda logits, key, temperature, greedy:
                _sample(logits, jax.random.fold_in(key, 0), temperature,
                        greedy),
            static_argnames=("greedy",))

    def generate(self, prompts: jax.Array, *, max_new_tokens: int = 32,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None) -> jax.Array:
        """prompts: (B, T_prompt) int32 -> (B, max_new_tokens)."""
        greedy = temperature <= 0.0
        if not greedy and key is None:
            raise ValueError("temperature > 0 requires a PRNG key")
        if max_new_tokens <= 0:
            return jnp.zeros((prompts.shape[0], 0), jnp.int32)
        if key is None:
            key = jax.random.PRNGKey(0)  # unused: greedy takes no samples
        logits, cache = LM.prefill(self.params, self.cfg,
                                   max_len=self.max_len, tokens=prompts,
                                   cache_dtype=self.cache_dtype)
        idx = jnp.asarray(prompts.shape[1], jnp.int32)
        temp = jnp.asarray(temperature, jnp.float32)
        tok = self._sample_first(logits, key, temp, greedy=greedy)
        out = [tok]
        # the token sampled from step t's logits is decoded at step t+1;
        # the LAST sampled token is returned without a trailing decode
        for t in range(max_new_tokens - 1):
            tok, cache = self._step(self.params, tok, cache, idx, key,
                                    jnp.asarray(t, jnp.int32), temp,
                                    greedy=greedy)
            out.append(tok)
        return jnp.stack(out, axis=1)
