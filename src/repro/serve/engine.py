"""KV-cache serving engine: batched prefill + decode loop.

``ServeEngine`` holds jitted prefill/decode closures for one ModelConfig;
``generate`` runs greedy or temperature sampling for a batch of prompts.
``serve_step`` (module-level) is the function the decode-shape dry-run
cells lower: one new token against a seq_len KV cache.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import causal_lm as LM
from repro.models import transformer as T

__all__ = ["ServeEngine", "serve_step"]


def serve_step(params: dict, cfg: T.ModelConfig, tokens: jax.Array,
               cache, cache_index: jax.Array):
    """One decode step for the whole batch: (B,) int32 -> (logits, cache).
    This is the unit the decode dry-run cells lower + compile."""
    return LM.decode_step(params, cfg, tokens, cache, cache_index)


@dataclasses.dataclass
class ServeEngine:
    cfg: T.ModelConfig
    params: dict
    max_len: int
    cache_dtype: object = jnp.bfloat16

    def __post_init__(self):
        self._decode = jax.jit(
            lambda p, t, c, i: serve_step(p, self.cfg, t, c, i))

    def generate(self, prompts: jax.Array, *, max_new_tokens: int = 32,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None) -> jax.Array:
        """prompts: (B, T_prompt) int32 -> (B, max_new_tokens)."""
        B = prompts.shape[0]
        logits, cache = LM.prefill(self.params, self.cfg,
                                   max_len=self.max_len, tokens=prompts,
                                   cache_dtype=self.cache_dtype)
        idx = jnp.asarray(prompts.shape[1], jnp.int32)
        out = []
        tok = self._sample(logits, temperature, key, 0)
        for t in range(max_new_tokens):
            out.append(tok)
            logits, cache = self._decode(self.params, tok, cache, idx + t)
            tok = self._sample(logits, temperature, key, t + 1)
        return jnp.stack(out, axis=1)

    @staticmethod
    def _sample(logits: jax.Array, temperature: float,
                key: Optional[jax.Array], step: int) -> jax.Array:
        if temperature <= 0.0 or key is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        k = jax.random.fold_in(key, step)
        return jax.random.categorical(
            k, logits / temperature, axis=-1).astype(jnp.int32)
