"""KV-cache serving engine: batched prefill + decode loop.

``ServeEngine`` holds jitted prefill/decode closures for one ModelConfig;
``generate`` runs greedy or temperature sampling for a batch of prompts.
``serve_step`` (module-level) is the function the decode-shape dry-run
cells lower: one new token against a seq_len KV cache.

Decode hot loop: sampling is FUSED into the jitted decode step (one
compiled call per generated token — no host-side argmax/categorical
between steps), the per-step PRNG key is derived inside jit via
``fold_in``, and the loop issues exactly ``max_new_tokens - 1`` decode
calls after prefill (the old loop ran one extra decode whose logits were
discarded).  ``temperature > 0`` without a key is an error, not a silent
greedy fallback.

Non-finite robustness: a NaN/inf logit row (overflowed checkpoint,
corrupted KV cache) would send NaN through softmax and make
``jax.random.categorical`` return garbage — possibly out-of-range token
ids that crash downstream detokenizers.  ``_sample`` therefore guards
per row: any row with a non-finite logit degrades to a deterministic
in-range token (argmax over zeroed logits = token 0) instead of
propagating the NaN, and ``generate(..., return_flags=True)`` reports
which requests ever hit the guard so callers can flag/retry them.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.models import causal_lm as LM
from repro.models import transformer as T

__all__ = ["ServeEngine", "serve_step"]


def serve_step(params: dict, cfg: T.ModelConfig, tokens: jax.Array,
               cache, cache_index: jax.Array):
    """One decode step for the whole batch: (B,) int32 -> (logits, cache).
    This is the unit the decode dry-run cells lower + compile."""
    return LM.decode_step(params, cfg, tokens, cache, cache_index)


def _sample(logits: jax.Array, key: jax.Array, temperature: jax.Array,
            greedy: bool) -> Tuple[jax.Array, jax.Array]:
    """Traced sampling head; returns ``(tokens, bad)`` where ``bad`` is a
    per-row bool flagging rows whose logits were non-finite (those rows
    take a deterministic in-range fallback token instead of sampling from
    NaN).  ``greedy`` is static (two compiled variants); ``temperature``
    is traced so sweeping it never recompiles."""
    bad = ~jnp.all(jnp.isfinite(logits), axis=-1)
    # zero the whole row when any entry is non-finite: argmax/categorical
    # over an all-zero row is token 0 — deterministic and always in-range
    safe = jnp.where(bad[..., None], jnp.zeros_like(logits), logits)
    if greedy:
        tok = jnp.argmax(safe, axis=-1).astype(jnp.int32)
    else:
        tok = jax.random.categorical(
            key, safe / temperature, axis=-1).astype(jnp.int32)
        tok = jnp.where(bad, jnp.zeros_like(tok), tok)
    return tok, bad


@dataclasses.dataclass
class ServeEngine:
    cfg: T.ModelConfig
    params: dict
    max_len: int
    cache_dtype: object = jnp.bfloat16

    def __post_init__(self):
        def step(params, tok, cache, prompt_len, key, step_idx,
                 temperature, greedy):
            logits, cache = serve_step(params, self.cfg, tok, cache,
                                       prompt_len + step_idx)
            k = jax.random.fold_in(key, step_idx + 1)
            tok, bad = _sample(logits, k, temperature, greedy)
            return tok, bad, cache

        # decode + sample in ONE compiled call per token
        self._step = jax.jit(step, static_argnames=("greedy",))
        self._sample_first = jax.jit(
            lambda logits, key, temperature, greedy:
                _sample(logits, jax.random.fold_in(key, 0), temperature,
                        greedy),
            static_argnames=("greedy",))

    def generate(self, prompts: jax.Array, *, max_new_tokens: int = 32,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None,
                 return_flags: bool = False,
                 ) -> Union[jax.Array, Tuple[jax.Array, jax.Array]]:
        """prompts: (B, T_prompt) int32 -> (B, max_new_tokens).

        With ``return_flags=True`` returns ``(tokens, flags)`` where
        ``flags`` is a (B,) bool marking requests that hit the non-finite
        logits guard at ANY decode step (their tokens past that point are
        fallback output and the request should be flagged or retried)."""
        greedy = temperature <= 0.0
        if not greedy and key is None:
            raise ValueError("temperature > 0 requires a PRNG key")
        if max_new_tokens <= 0:
            empty = jnp.zeros((prompts.shape[0], 0), jnp.int32)
            if return_flags:
                return empty, jnp.zeros((prompts.shape[0],), bool)
            return empty
        if key is None:
            key = jax.random.PRNGKey(0)  # unused: greedy takes no samples
        logits, cache = LM.prefill(self.params, self.cfg,
                                   max_len=self.max_len, tokens=prompts,
                                   cache_dtype=self.cache_dtype)
        idx = jnp.asarray(prompts.shape[1], jnp.int32)
        temp = jnp.asarray(temperature, jnp.float32)
        tok, flags = self._sample_first(logits, key, temp, greedy=greedy)
        out = [tok]
        # the token sampled from step t's logits is decoded at step t+1;
        # the LAST sampled token is returned without a trailing decode
        for t in range(max_new_tokens - 1):
            tok, bad, cache = self._step(self.params, tok, cache, idx,
                                         key, jnp.asarray(t, jnp.int32),
                                         temp, greedy=greedy)
            flags = flags | bad
            out.append(tok)
        tokens = jnp.stack(out, axis=1)
        if return_flags:
            return tokens, flags
        return tokens
