"""KV-cache serving engines: fixed-batch generate + continuous batching.

``ServeEngine`` holds jitted prefill/decode closures for one ModelConfig;
``generate`` runs greedy or temperature sampling for a batch of prompts.
``serve_step`` (module-level) is the function the decode-shape dry-run
cells lower: one new token against a seq_len KV cache.

``ContinuousBatchingEngine`` is the production path: a pool of
``Request``s is admitted/evicted per decode tick into a fixed number of
compiled batch slots, so ONE compiled tick serves a churning pool
(``analysis/recompile.assert_compiles`` proves single-compile across the
churn).  Per-request state rides traced per-row operands — ``cache_index``
(each slot decodes at its own position into the ring/linear KV cache),
temperature/top-k/top-p, and a per-request PRNG key folded with the
per-request step counter, so token *i* of a request is sampled
identically whether it shares the batch or runs alone.  Prefill is split
from the decode tick: arrivals are bucketed to power-of-two lengths,
prefilled batched per bucket in one chunked-attention forward
(``causal_lm.prefill(length=...)``), and the resulting cache rows are
scattered into free slots with a traced-slot insert.  See
docs/serving.md.

Decode hot loop: sampling is FUSED into the jitted decode step (one
compiled call per generated token — no host-side argmax/categorical
between steps), the per-step PRNG key is derived inside jit via
``fold_in``, and the loop issues exactly ``max_new_tokens - 1`` decode
calls after prefill (the old loop ran one extra decode whose logits were
discarded).  ``temperature > 0`` without a key is an error, not a silent
greedy fallback.

Non-finite robustness: a NaN/inf logit row (overflowed checkpoint,
corrupted KV cache) would send NaN through softmax and make
``jax.random.categorical`` return garbage — possibly out-of-range token
ids that crash downstream detokenizers.  ``_sample`` therefore guards
per row: any row with a non-finite logit degrades to a deterministic
in-range token (argmax over zeroed logits = token 0) instead of
propagating the NaN, and ``generate(..., return_flags=True)`` reports
which requests ever hit the guard so callers can flag/retry them.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.models import causal_lm as LM
from repro.models import transformer as T

__all__ = ["ServeEngine", "serve_step", "Request",
           "ContinuousBatchingEngine"]


def serve_step(params: dict, cfg: T.ModelConfig, tokens: jax.Array,
               cache, cache_index: jax.Array):
    """One decode step for the whole batch: (B,) int32 -> (logits, cache).
    This is the unit the decode dry-run cells lower + compile."""
    return LM.decode_step(params, cfg, tokens, cache, cache_index)


def _sample(logits: jax.Array, key: jax.Array, temperature: jax.Array,
            greedy: bool) -> Tuple[jax.Array, jax.Array]:
    """Traced sampling head; returns ``(tokens, bad)`` where ``bad`` is a
    per-row bool flagging rows whose logits were non-finite (those rows
    take a deterministic in-range fallback token instead of sampling from
    NaN).  ``greedy`` is static (two compiled variants); ``temperature``
    is traced so sweeping it never recompiles."""
    bad = ~jnp.all(jnp.isfinite(logits), axis=-1)
    # zero the whole row when any entry is non-finite: argmax/categorical
    # over an all-zero row is token 0 — deterministic and always in-range
    safe = jnp.where(bad[..., None], jnp.zeros_like(logits), logits)
    if greedy:
        tok = jnp.argmax(safe, axis=-1).astype(jnp.int32)
    else:
        tok = jax.random.categorical(
            key, safe / temperature, axis=-1).astype(jnp.int32)
        tok = jnp.where(bad, jnp.zeros_like(tok), tok)
    return tok, bad


@dataclasses.dataclass
class ServeEngine:
    cfg: T.ModelConfig
    params: dict
    max_len: int
    cache_dtype: object = jnp.bfloat16

    def __post_init__(self):
        def step(params, tok, cache, prompt_len, key, step_idx,
                 temperature, greedy):
            logits, cache = serve_step(params, self.cfg, tok, cache,
                                       prompt_len + step_idx)
            k = jax.random.fold_in(key, step_idx + 1)
            tok, bad = _sample(logits, k, temperature, greedy)
            return tok, bad, cache

        # decode + sample in ONE compiled call per token
        self._step = jax.jit(step, static_argnames=("greedy",))
        self._sample_first = jax.jit(
            lambda logits, key, temperature, greedy:
                _sample(logits, jax.random.fold_in(key, 0), temperature,
                        greedy),
            static_argnames=("greedy",))

    def generate(self, prompts: jax.Array, *, max_new_tokens: int = 32,
                 temperature: float = 0.0,
                 key: Optional[jax.Array] = None,
                 return_flags: bool = False,
                 ) -> Union[jax.Array, Tuple[jax.Array, jax.Array]]:
        """prompts: (B, T_prompt) int32 -> (B, max_new_tokens).

        With ``return_flags=True`` returns ``(tokens, flags)`` where
        ``flags`` is a (B,) bool marking requests that hit the non-finite
        logits guard at ANY decode step (their tokens past that point are
        fallback output and the request should be flagged or retried)."""
        greedy = temperature <= 0.0
        if not greedy and key is None:
            raise ValueError("temperature > 0 requires a PRNG key")
        if max_new_tokens <= 0:
            empty = jnp.zeros((prompts.shape[0], 0), jnp.int32)
            if return_flags:
                return empty, jnp.zeros((prompts.shape[0],), bool)
            return empty
        if key is None:
            key = jax.random.PRNGKey(0)  # unused: greedy takes no samples
        logits, cache = LM.prefill(self.params, self.cfg,
                                   max_len=self.max_len, tokens=prompts,
                                   cache_dtype=self.cache_dtype)
        idx = jnp.asarray(prompts.shape[1], jnp.int32)
        temp = jnp.asarray(temperature, jnp.float32)
        tok, flags = self._sample_first(logits, key, temp, greedy=greedy)
        out = [tok]
        # the token sampled from step t's logits is decoded at step t+1;
        # the LAST sampled token is returned without a trailing decode
        for t in range(max_new_tokens - 1):
            tok, bad, cache = self._step(self.params, tok, cache, idx,
                                         key, jnp.asarray(t, jnp.int32),
                                         temp, greedy=greedy)
            flags = flags | bad
            out.append(tok)
        tokens = jnp.stack(out, axis=1)
        if return_flags:
            return tokens, flags
        return tokens


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One serving request for ``ContinuousBatchingEngine``.

    ``temperature <= 0`` is greedy; ``top_k <= 0`` (or >= vocab) and
    ``top_p`` outside (0, 1) disable those filters bit-exactly.  ``rid``
    pins the per-request PRNG stream (``fold_in(base_key, rid)``) and the
    result key; auto-assigned monotonically when None."""
    prompt: object
    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    rid: Optional[int] = None


def _sample_rows(logits: jax.Array, keys: jax.Array, temperature: jax.Array,
                 top_k: jax.Array, top_p: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """Per-row sampling head: each row has its own PRNG key, temperature,
    top-k, and top-p, all traced — one compiled variant serves every mix.

    Per-row math only (no cross-row reductions), so a row's token is
    bitwise-identical whether it shares the batch or samples alone.
    Greedy rows (``temperature <= 0``) take argmax; non-finite rows
    degrade to token 0 and are flagged in the returned ``bad`` mask, as in
    ``_sample``."""
    V = logits.shape[-1]
    bad = ~jnp.all(jnp.isfinite(logits), axis=-1)
    safe = jnp.where(bad[..., None], jnp.zeros_like(logits), logits)
    greedy_tok = jnp.argmax(safe, axis=-1).astype(jnp.int32)

    t = jnp.maximum(temperature, 1e-6)[:, None]
    scaled = safe.astype(jnp.float32) / t
    # top-k: kth-largest logit is the keep threshold (traced k per row)
    desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(desc, jnp.clip(top_k - 1, 0, V - 1)[:, None],
                              axis=-1)
    apply_k = ((top_k > 0) & (top_k < V))[:, None]
    scaled = jnp.where(apply_k & (scaled < kth), -jnp.inf, scaled)
    # top-p (nucleus) over the k-filtered distribution: keep the smallest
    # prefix of the sorted probs whose mass reaches p — i.e. drop a token
    # only if the mass strictly above it already covers p
    desc = jnp.sort(scaled, axis=-1)[:, ::-1]
    probs = jax.nn.softmax(desc, axis=-1)
    mass_above = jnp.cumsum(probs, axis=-1) - probs
    kept = mass_above < top_p[:, None]
    thr = jnp.min(jnp.where(kept, desc, jnp.inf), axis=-1, keepdims=True)
    apply_p = ((top_p > 0.0) & (top_p < 1.0))[:, None]
    scaled = jnp.where(apply_p & (scaled < thr), -jnp.inf, scaled)

    sampled = jax.vmap(jax.random.categorical)(keys, scaled)
    tok = jnp.where((temperature <= 0.0) | bad,
                    greedy_tok, sampled.astype(jnp.int32))
    return tok, bad


class ContinuousBatchingEngine:
    """Continuous-batching serve loop over ``slots`` compiled batch rows.

    The decode tick is jitted ONCE: every per-request quantity it touches
    (last token, cache position, sampling params, PRNG key, step counter,
    active mask) is a traced per-row operand, so admitting/evicting
    requests never retraces.  Prefill compiles per (bucket, group-size)
    pair — buckets are power-of-two so a handful of shapes serve any
    prompt-length mix.  Inactive slots still decode (their row is masked
    and their cache row is fully replaced at the next admit), which keeps
    the tick shape fixed.

    Only attention-mixer stacks are supported: chunked prefill and the
    per-row-``cache_index`` decode both need KV caches (SSM caches are
    strictly sequential single-token)."""

    def __init__(self, cfg: T.ModelConfig, params: dict, *, slots: int,
                 max_len: int, cache_dtype=jnp.bfloat16,
                 base_key: Optional[jax.Array] = None):
        if any(s.mixer != "attn" for s in cfg.layers):
            raise ValueError(
                "ContinuousBatchingEngine needs an attention-only stack; "
                f"{cfg.name} has SSM mixers (use ServeEngine)")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.base_key = (jax.random.PRNGKey(0) if base_key is None
                         else base_key)
        self._next_rid = 0
        # cache leaves are (B, S, H, D) unrolled / (G, B, S, H, D) scanned
        self._batch_axis = 1 if cfg.scanned else 0

        def tick(params, tok, cache, ci, active, keys, steps,
                 temp, top_k, top_p):
            logits, cache = LM.decode_step(params, cfg, tok, cache, ci)
            ks = jax.vmap(jax.random.fold_in)(keys, steps)
            new_tok, bad = _sample_rows(logits, ks, temp, top_k, top_p)
            new_tok = jnp.where(active, new_tok, tok)
            ci = jnp.where(active, ci + 1, ci)
            steps = jnp.where(active, steps + 1, steps)
            return new_tok, bad, cache, ci, steps

        def prefill(params, tokens, length):
            return LM.prefill(params, cfg, max_len=max_len, tokens=tokens,
                              cache_dtype=cache_dtype, length=length)

        def sample_first(logits, keys, temp, top_k, top_p):
            ks = jax.vmap(jax.random.fold_in)(
                keys, jnp.zeros((keys.shape[0],), jnp.int32))
            return _sample_rows(logits, ks, temp, top_k, top_p)

        def insert(cache, pcache, row, slot):
            ax = self._batch_axis

            def one(c, p):
                r = jax.lax.dynamic_index_in_dim(p, row, axis=ax,
                                                 keepdims=False)
                return jax.lax.dynamic_update_index_in_dim(
                    c, r.astype(c.dtype), slot, axis=ax)
            return jax.tree.map(one, cache, pcache)

        self._tick = jax.jit(tick)          # compiles ONCE for all churn
        self._prefill = jax.jit(prefill)    # per (bucket, group) shape
        self._sample_first = jax.jit(sample_first)
        self._insert = jax.jit(insert)      # traced row + slot

    # ---- host-side pool state -------------------------------------------

    def _reset(self):
        S = self.slots
        self._cache = T.init_cache(S, self.max_len, self.cfg,
                                   self.cache_dtype)
        self._tok = jnp.zeros((S,), jnp.int32)
        self._ci = jnp.zeros((S,), jnp.int32)
        self._active = jnp.zeros((S,), bool)
        self._keys = jnp.stack([jax.random.PRNGKey(0)] * S)
        self._steps = jnp.zeros((S,), jnp.int32)
        self._temp = jnp.zeros((S,), jnp.float32)
        self._topk = jnp.zeros((S,), jnp.int32)
        self._topp = jnp.ones((S,), jnp.float32)
        self._slot_req: list = [None] * S

    def _bucket(self, n: int) -> int:
        b = 8
        while b < n:
            b *= 2
        return min(b, self.max_len)

    def _admit(self, batch, tick_idx, results):
        """Bucket-batched prefill of ``batch`` [(slot, Request)], then
        scatter each prefilled row + its per-request state into its slot."""
        groups: dict = {}
        for slot, req in batch:
            prompt = jnp.asarray(req.prompt, jnp.int32).reshape(-1)
            groups.setdefault(self._bucket(prompt.shape[0]),
                              []).append((slot, req, prompt))
        for bucket, members in sorted(groups.items()):
            toks = jnp.stack(
                [jnp.pad(p, (0, bucket - p.shape[0])) for _, _, p in members])
            lens = jnp.asarray([p.shape[0] for _, _, p in members], jnp.int32)
            keys = jnp.stack([jax.random.fold_in(self.base_key, r.rid)
                              for _, r, _ in members])
            temp = jnp.asarray([r.temperature for _, r, _ in members],
                               jnp.float32)
            topk = jnp.asarray([r.top_k for _, r, _ in members], jnp.int32)
            topp = jnp.asarray([r.top_p for _, r, _ in members], jnp.float32)
            logits, pcache = self._prefill(self.params, toks, lens)
            first, bad = self._sample_first(logits, keys, temp, topk, topp)
            first, bad = jax.device_get((first, bad))
            for g, (slot, req, prompt) in enumerate(members):
                self._cache = self._insert(self._cache, pcache,
                                           jnp.asarray(g, jnp.int32),
                                           jnp.asarray(slot, jnp.int32))
                self._tok = self._tok.at[slot].set(int(first[g]))
                self._ci = self._ci.at[slot].set(prompt.shape[0])
                self._keys = self._keys.at[slot].set(keys[g])
                self._steps = self._steps.at[slot].set(1)
                self._temp = self._temp.at[slot].set(req.temperature)
                self._topk = self._topk.at[slot].set(req.top_k)
                self._topp = self._topp.at[slot].set(req.top_p)
                self._active = self._active.at[slot].set(True)
                res = results[req.rid]
                res["tokens"].append(int(first[g]))
                res["flagged"] |= bool(bad[g])
                res["admitted_tick"] = tick_idx
                self._slot_req[slot] = req

    def serve(self, requests, *, arrival_ticks=None):
        """Serve ``requests`` (list of :class:`Request`) to completion.

        ``arrival_ticks[i]`` (default 0) is the decode tick at which
        request *i* becomes admissible — the knob load generators use to
        model offered load.  Returns ``(results, stats)``: ``results``
        maps rid -> {tokens, flagged, admitted_tick, finished_tick};
        ``stats`` has ``ticks``, ``tokens`` (decoded total incl. prefill
        samples), and ``occupied_slot_ticks`` for occupancy/latency
        accounting."""
        for r in requests:
            if r.rid is None:
                r.rid = self._next_rid
                self._next_rid += 1
            if r.max_new_tokens < 1:
                raise ValueError("max_new_tokens must be >= 1")
            n = jnp.asarray(r.prompt).reshape(-1).shape[0]
            if n + r.max_new_tokens > self.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt ({n}) + max_new_tokens "
                    f"({r.max_new_tokens}) exceeds max_len={self.max_len}")
        arrival_ticks = list(arrival_ticks or [0] * len(requests))
        pending = sorted(zip(arrival_ticks, range(len(requests))))
        results = {r.rid: {"tokens": [], "flagged": False,
                           "admitted_tick": None, "finished_tick": None}
                   for r in requests}
        self._reset()
        stats = {"ticks": 0, "tokens": 0, "occupied_slot_ticks": 0}
        tick_idx = 0
        while pending or any(r is not None for r in self._slot_req):
            # admit arrivals into free slots
            free = [s for s in range(self.slots) if self._slot_req[s] is None]
            batch = []
            while pending and free and pending[0][0] <= tick_idx:
                _, i = pending.pop(0)
                batch.append((free.pop(0), requests[i]))
            if batch:
                self._admit(batch, tick_idx, results)
                # a max_new_tokens == 1 admit finishes without decoding
                for s, req in batch:
                    if len(results[req.rid]["tokens"]) >= req.max_new_tokens:
                        results[req.rid]["finished_tick"] = tick_idx
                        self._active = self._active.at[s].set(False)
                        self._slot_req[s] = None
                stats["tokens"] += len(batch)
            n_active = sum(r is not None for r in self._slot_req)
            if n_active:
                self._tok, bad, self._cache, self._ci, self._steps = \
                    self._tick(self.params, self._tok, self._cache, self._ci,
                               self._active, self._keys, self._steps,
                               self._temp, self._topk, self._topp)
                tok_h, bad_h = jax.device_get((self._tok, bad))
                for s in range(self.slots):
                    req = self._slot_req[s]
                    if req is not None:
                        res = results[req.rid]
                        res["tokens"].append(int(tok_h[s]))
                        res["flagged"] |= bool(bad_h[s])
                        if len(res["tokens"]) >= req.max_new_tokens:
                            res["finished_tick"] = tick_idx
                            self._active = self._active.at[s].set(False)
                            self._slot_req[s] = None
                stats["tokens"] += n_active
                stats["occupied_slot_ticks"] += n_active
            stats["ticks"] += 1
            tick_idx += 1
        return results, stats
