"""Serving: batched prefill + decode engine, continuous batching."""

from repro.serve.engine import (ContinuousBatchingEngine,  # noqa: F401
                                Request, ServeEngine, serve_step)
