"""Serving: batched prefill + decode engine."""

from repro.serve.engine import ServeEngine, serve_step  # noqa: F401
