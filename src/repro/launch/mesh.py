"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before its first
jax import, and smoke tests/benches must keep seeing 1 device.
"""

from __future__ import annotations

import numpy as np

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ("data", "model") = 256 chips.
    Multi-pod: (2, 16, 16) ("pod", "data", "model") = 512 chips.
    """
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, found "
            f"{len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            f"(launch/dryrun.py does this) or on real hardware")
    from jax.sharding import Mesh
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(axes=("data", "model")):
    """Degenerate 1x1 mesh over the local device (CPU smoke paths)."""
    import jax
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:1]).reshape((1, 1)), axes)
