"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  * build the production mesh (16x16 single pod / 2x16x16 multi-pod),
  * construct abstract state/batch/cache (ShapeDtypeStruct, no alloc),
  * jit the cell's step function with explicit in/out shardings,
  * ``.lower().compile()`` — success proves the distribution config is
    coherent (sharding match, no OOM-at-compile, collectives supported),
  * record memory_analysis / cost_analysis / collective bytes for
    EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
  python -m repro.launch.dryrun --all --linear-impl dense   # baseline
Results land in results/dryrun/<mesh>/<arch>__<shape>[__<impl>].json.
"""

import os

# the 512 virtual host devices must be requested before jax initializes
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, SHAPES, arch_shapes, get_config,
                           with_overrides)
from repro.configs.shapes import ShapeSpec
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import abstract_cache, abstract_state, input_specs
from repro.models import causal_lm as LM
from repro.models import transformer as T
from repro.optim.adamw import OptimizerConfig
from repro.parallel import sharding as SH
from repro.train.step import make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _batch_shardings(mesh, batch_specs, shape: ShapeSpec,
                     profile: str = "tp"):
    dp_base = SH.data_axes(mesh)
    dp = dp_base
    if profile.startswith("spm_dp") and shape.kind != "decode":
        # SPM collapses params to O(nL): the model axis carries BATCH for
        # train/prefill (full-mesh DP); vocab/EP params still use it.
        dp = dp + ("model",)

    def one(path, x):
        name = SH.tree_path_str(path)
        if name == "index":
            return NamedSharding(mesh, P())
        if name == "positions":                 # (3, B, S)
            return NamedSharding(mesh, P(None, dp, None))
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        if shape.kind == "decode" and shape.seq_sharded:
            return NamedSharding(mesh, P(*([None] * x.ndim)))   # B == 1
        if name == "tokens" and profile == "spm_dp_g2":
            # I6: token ids replicated over "model" so the vocab-sharded
            # gather lowers as mask+all-reduce instead of all-gathering
            # the table; embeds are re-pinned to full-mesh DP in-model.
            return NamedSharding(mesh,
                                 P(dp_base, *([None] * (x.ndim - 1))))
        return NamedSharding(mesh, P(dp, *([None] * (x.ndim - 1))))

    return jax.tree_util.tree_map_with_path(one, batch_specs)


def lower_cell(cfg: T.ModelConfig, shape: ShapeSpec, mesh,
               profile: str = "tp"):
    """Build + lower the cell's step function.  Returns the lowered jit."""
    import contextlib
    from repro.parallel.ctx import activation_sharding

    if profile == "spm_dp" and cfg.input_kind == "tokens":
        cfg = with_overrides(cfg, embed_onehot=True)
    # spm_dp_g: same shardings, gather-lowered lookup (I2 ablation)
    # spm_dp_g2: + tokens replicated over model, embeds constrained (I6)
    act_ctx = (activation_sharding(mesh, shard_heads=False, full_batch=True)
               if profile == "spm_dp_g2" and shape.kind != "decode"
               else contextlib.nullcontext())
    if profile == "spm_feat":
        # feature axis over "model": two_level SPM linears route through the
        # distributed executor (collective_permute cross stages)
        act_ctx = activation_sharding(mesh, shard_heads=False,
                                      shard_feature=True)
    batch = input_specs(cfg, shape)
    batch_sh = _batch_shardings(mesh, batch, shape, profile)

    if shape.kind == "train":
        state = abstract_state(cfg)
        state_sh = {
            "params": SH.param_shardings(mesh, state["params"], profile),
            "opt": {"mu": SH.param_shardings(mesh, state["opt"]["mu"],
                                             profile),
                    "nu": SH.param_shardings(mesh, state["opt"]["nu"],
                                             profile),
                    "count": NamedSharding(mesh, P())},
            "step": NamedSharding(mesh, P()),
        }
        opt_cfg = OptimizerConfig()
        step = make_train_step(lambda p, b: LM.lm_loss(p, b, cfg), opt_cfg)
        metrics_sh = None
        fn = jax.jit(step,
                     in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, metrics_sh))
        with act_ctx:
            lowered = fn.lower(state, batch)

    elif shape.kind == "prefill":
        params = abstract_state(cfg)["params"]
        params_sh = SH.param_shardings(mesh, params, profile)

        def prefill_fwd(p, b):
            logits, _, _ = T.forward(
                p, cfg, tokens=b.get("tokens"), embeds=b.get("embeds"),
                positions=b.get("positions"))
            return logits

        fn = jax.jit(prefill_fwd,
                     in_shardings=(params_sh, batch_sh),
                     out_shardings=None)
        with act_ctx:
            lowered = fn.lower(params, batch)

    else:  # decode
        params = abstract_state(cfg)["params"]
        params_sh = SH.param_shardings(mesh, params, profile)
        cache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
        cache_sh = SH.cache_specs(mesh, cache, seq_sharded=shape.seq_sharded)

        def serve_step(p, tok, c, idx):
            return LM.decode_step(p, cfg, tok, c, idx)

        fn = jax.jit(serve_step,
                     in_shardings=(params_sh, batch_sh["tokens"], cache_sh,
                                   batch_sh["index"]),
                     out_shardings=(None, cache_sh))
        lowered = fn.lower(params, batch["tokens"], cache, batch["index"])

    return lowered


def model_flops(cfg: T.ModelConfig, shape: ShapeSpec) -> dict:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (forward-only), N = non-embedding
    active params (MoE counts top_k + shared experts only)."""
    state = abstract_state(cfg)
    total = sum(int(jnp.prod(jnp.array(x.shape)))
                for x in jax.tree.leaves(state["params"]))
    flat = jax.tree_util.tree_flatten_with_path(state["params"])[0]
    embed = sum(int(jnp.prod(jnp.array(x.shape))) for p, x in flat
                if "embed" in SH.tree_path_str(p))
    expert = sum(int(jnp.prod(jnp.array(x.shape))) for p, x in flat
                 if "/experts/" in SH.tree_path_str(p))
    n_nonembed = total - embed
    if cfg.n_experts:
        active_frac = cfg.top_k / cfg.n_experts
        n_active = n_nonembed - expert + int(expert * active_frac)
    else:
        n_active = n_nonembed
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mf = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = 2 * n_active * tokens
    else:
        tokens = shape.global_batch          # one new token per sequence
        mf = 2 * n_active * tokens
    return {"params_total": total, "params_active_nonembed": n_active,
            "tokens": tokens, "model_flops": mf}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             linear_impl: str | None = None, save: bool = True,
             profile: str = "tp", remat: bool = True,
             bf16_logits: bool = False) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if linear_impl:
        cfg = with_overrides(cfg, linear_impl=linear_impl)
    if not remat:
        cfg = with_overrides(cfg, remat=False)
    if bf16_logits:
        cfg = with_overrides(cfg, logits_dtype="bfloat16")
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    if profile == "spm_feat":
        from repro.configs import with_feature_sharding
        if cfg.linear_impl == "dense":
            cfg = with_overrides(cfg, linear_impl="spm_general")
        cfg = with_feature_sharding(cfg, int(mesh.shape["model"]))
    n_chips = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "linear_impl": cfg.linear_impl, "n_chips": int(n_chips),
           "profile": profile, "remat": remat}
    t0 = time.time()
    try:
        with mesh:
            lowered = lower_cell(cfg, shape, mesh, profile)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = H.memory_analysis_terms(compiled)
        cost = H.cost_analysis_terms(compiled)
        coll = H.collective_bytes(compiled.as_text())
        mf = model_flops(cfg, shape)
        terms = H.roofline_terms(cost["flops"], cost["bytes_accessed"],
                                 coll["total"])
        rec.update({
            "ok": True, "t_lower_s": round(t_lower, 1),
            "t_compile_s": round(t_compile, 1),
            "memory": mem, "cost": cost, "collectives": coll,
            "model": mf, "roofline": terms,
            "useful_flops_ratio": (mf["model_flops"] / n_chips / cost["flops"]
                                   if cost["flops"] else None),
        })
        print(f"[OK] {arch} x {shape_name} x {mesh_kind} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s) "
              f"flops/chip={cost['flops']:.3g} "
              f"bytes/chip={cost['bytes_accessed']:.3g} "
              f"coll/chip={coll['total']:.3g} dom={terms['dominant']}")
    except Exception as e:   # noqa: BLE001 — record the failure, keep going
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
        print(f"[FAIL] {arch} x {shape_name} x {mesh_kind}: {e}")
    if save:
        d = os.path.join(RESULTS_DIR, mesh_kind)
        os.makedirs(d, exist_ok=True)
        suffix = f"__{linear_impl}" if linear_impl else ""
        if profile != "tp":
            suffix += f"__{profile}"
        if not remat:
            suffix += "__noremat"
        if bf16_logits:
            suffix += "__bf16logits"
        with open(os.path.join(d, f"{arch}__{shape_name}{suffix}.json"),
                  "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--linear-impl", default=None,
                    choices=(None, "dense", "spm_general", "spm_rotation"))
    ap.add_argument("--profile", default="tp",
                    choices=("tp", "spm_dp", "spm_dp_g", "spm_dp_g2",
                             "spm_feat"))
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--bf16-logits", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for sp in arch_shapes(arch):
                cells.append((arch, sp.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    n_fail = 0
    for mesh_kind in meshes:
        for arch, shape_name in cells:
            if args.skip_existing:
                suffix = f"__{args.linear_impl}" if args.linear_impl else ""
                if args.profile != "tp":
                    suffix += f"__{args.profile}"
                fp = os.path.join(RESULTS_DIR, mesh_kind,
                                  f"{arch}__{shape_name}{suffix}.json")
                if os.path.exists(fp):
                    with open(fp) as f:
                        if json.load(f).get("ok"):
                            continue
            rec = run_cell(arch, shape_name, mesh_kind, args.linear_impl,
                           profile=args.profile, remat=not args.no_remat,
                           bf16_logits=args.bf16_logits)
            n_fail += 0 if rec["ok"] else 1
    print(f"done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
